"""Serve a zoo architecture: prefill + batched greedy decode on CPU
(reduced config), demonstrating the same decode_step the dry-run lowers at
32k/500k context on the production mesh.

Run:  PYTHONPATH=src python examples/serve_llm.py [arch]
"""
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.launch.serve import generate
from repro.models.backbone import Model

arch = sys.argv[1] if len(sys.argv) > 1 else "mamba2-1.3b"
cfg = get_arch(arch, reduced=True)
if cfg.encoder_only:
    raise SystemExit(f"{arch} is encoder-only; pick one of "
                     f"{[a for a in ARCH_IDS if a != 'hubert-xlarge']}")

model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
B, P, G = 4, 32, 24
prompt = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab).astype(jnp.int32)

t0 = time.time()
out = generate(model, params, prompt, G)
dt = time.time() - t0
print(f"arch={arch} family={cfg.family}")
print(f"batch={B} prompt={P} generated={G} in {dt:.1f}s "
      f"({B*G/dt:.1f} tok/s incl. compile)")
print("first sequence tail:", np.asarray(out[0, -12:]))
