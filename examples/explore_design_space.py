"""Design-space exploration with a trained Tao model (paper §5.6 / Fig 15).

Sweeps L1-D cache sizes and branch predictors, comparing Tao's predicted
MPKI curves against detailed simulation — the use case DL-based simulators
exist for: evaluating design points ~10-1000x faster than detailed sim.

Run:  PYTHONPATH=src python examples/explore_design_space.py
"""
import dataclasses
import time

import numpy as np

from repro.core import FeatureConfig, TaoConfig, build_windows, extract_features, simulate_trace, train_tao
from repro.core.align import build_adjusted_trace
from repro.uarch import UARCH_B, get_benchmark, run_detailed, run_functional

N = 12_000
fcfg = FeatureConfig(n_buckets=256, n_queue=8, n_mem=16)
cfg = TaoConfig(window=33, d_model=64, n_heads=4, n_layers=2, d_ff=128,
                d_cat=32, features=fcfg)


def tao_for(uarch):
    prog = get_benchmark("dee")
    ft = run_functional(prog, N)
    det, _ = run_detailed(prog, ft, uarch)
    ds = build_windows(extract_features(build_adjusted_trace(det).adjusted, fcfg), cfg.window)
    return train_tao(cfg, ds, epochs=4, batch_size=16, lr=1e-3).params


print(f"{'design':24s} {'truth L1D MPKI':>15s} {'tao L1D MPKI':>13s} {'sim speed':>10s}")
for size_kb in (16, 32, 64, 128):
    ua = dataclasses.replace(UARCH_B, l1d_size=size_kb * 1024, name=f"L1D-{size_kb}KB")
    params = tao_for(ua)
    prog = get_benchmark("mcf")
    ft = run_functional(prog, N // 2)
    t0 = time.time()
    _, truth = run_detailed(prog, ft, ua)
    t_detailed = time.time() - t0
    sim = simulate_trace(params, ft, cfg)
    print(f"{ua.name:24s} {truth['l1d_mpki']:15.2f} {sim.l1d_mpki:13.2f} "
          f"{t_detailed/ max(sim.seconds,1e-9):9.1f}x")

print()
print(f"{'predictor':24s} {'truth br MPKI':>15s} {'tao br MPKI':>13s}")
for bp in ("Local", "BiMode", "Tournament", "TAGE_SC_L"):
    ua = dataclasses.replace(UARCH_B, branch_predictor=bp, name=f"BP-{bp}")
    params = tao_for(ua)
    prog = get_benchmark("xal")
    ft = run_functional(prog, N // 2)
    _, truth = run_detailed(prog, ft, ua)
    sim = simulate_trace(params, ft, cfg)
    print(f"{ua.name:24s} {truth['branch_mpki']:15.2f} {sim.branch_mpki:13.2f}")
