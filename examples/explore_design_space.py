"""Design-space exploration with the `repro.api` facade (paper §5.6 / Fig 15).

Sweeps L1-D cache sizes and branch predictors, comparing Tao's predicted
MPKI curves against detailed simulation — the use case DL-based simulators
exist for: evaluating design points ~10-1000x faster than detailed sim.
The L1D sweep runs through ``Session.sweep``, the async multi-trace
scheduler that double-buffers every (design, trace) pair through ONE
compiled step executable.

Run:  PYTHONPATH=src python examples/explore_design_space.py
"""
import time

from repro.api import DesignSpace, Session
from repro.core import FeatureConfig, TaoConfig
from repro.uarch import UARCH_B

N = 12_000
cfg = TaoConfig(window=33, d_model=64, n_heads=4, n_layers=2, d_ff=128,
                d_cat=32, features=FeatureConfig(n_buckets=256, n_queue=8, n_mem=16))
s = Session(cfg)
train = s.capture("dee", N)


def model_for(uarch):
    return s.train(uarch, [train], epochs=4, batch_size=16, lr=1e-3,
                   name=uarch.name)


# --- L1D size sweep, all design points through one async sweep -----------
space = DesignSpace.vary(UARCH_B, "l1d_size",
                         [kb * 1024 for kb in (16, 32, 64, 128)],
                         name_fmt="L1D-{value}B")
models = {ua.name: model_for(ua) for ua in space}
test = s.capture("mcf", N // 2)

t0 = time.time()
t_detailed = {ua.name: s.ground_truth(ua, test) for ua in space}
detailed_s = time.time() - t0

report = s.sweep(models, [test])
print(f"{'design':24s} {'truth L1D MPKI':>15s} {'tao L1D MPKI':>13s}")
for ua in space:
    sim = report.results[f"{ua.name}/{test.name}"]
    print(f"{ua.name:24s} {t_detailed[ua.name]['l1d_mpki']:15.2f} "
          f"{sim.l1d_mpki:13.2f}")
print(f"sweep: {report.num_traces} design-point sims in {report.seconds:.2f}s "
      f"({report.traces_per_s:.1f} traces/s, {report.num_compiles} compile) "
      f"vs {detailed_s:.2f}s detailed sim -> "
      f"{detailed_s / max(report.seconds, 1e-9):.1f}x")

# --- branch predictor sweep ----------------------------------------------
print()
print(f"{'predictor':24s} {'truth br MPKI':>15s} {'tao br MPKI':>13s}")
test_br = s.capture("xal", N // 2)
for bp in ("Local", "BiMode", "Tournament", "TAGE_SC_L"):
    ua = DesignSpace.vary(UARCH_B, "branch_predictor", [bp],
                          name_fmt="BP-{value}")[0]
    model = model_for(ua)
    truth = s.ground_truth(ua, test_br)
    sim = model.simulate(test_br)
    print(f"{ua.name:24s} {truth['branch_mpki']:15.2f} {sim.branch_mpki:13.2f}")
