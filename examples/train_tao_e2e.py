"""End-to-end driver: the paper's FULL workflow on the `repro.api` facade,
including the transfer-learning path that delivers the 18x speedup claim.

  Phase 1  design-space sampling + Mahalanobis pair selection   (§4.3)
  Phase 2  joint shared-embedding training on the selected pair (Alg. 1)
  Phase 3  fast enablement of an UNSEEN µarch: frozen embeddings +
           fine-tuned prediction layers on a small dataset       (§5.5)
  Phase 4  multi-metric simulation + comparison vs scratch training

This is the "train a ~100M-class model for a few hundred steps" e2e driver
(at CPU scale the Tao model is width-reduced; flip FULL=1 to use the
paper-scale config from repro/configs/tao.py).

Run:  PYTHONPATH=src python examples/train_tao_e2e.py
"""
import os
import time

from repro.api import DesignSpace, Session
from repro.ckpt import CheckpointManager
from repro.core import FeatureConfig, TaoConfig
from repro.uarch import UARCH_C

FULL = os.environ.get("FULL", "0") == "1"
N = 40_000 if FULL else 15_000
EPOCHS = 12 if FULL else 5
TRAIN_BENCHES = ("dee", "rom", "nab", "lee") if FULL else ("dee", "lee")

if FULL:
    from repro.configs.tao import CONFIG as cfg
else:
    cfg = TaoConfig(window=33, d_model=64, n_heads=4, n_layers=2, d_ff=128,
                    d_cat=32,
                    features=FeatureConfig(n_buckets=256, n_queue=8, n_mem=16))

s = Session(cfg)
traces = [s.capture(b, N) for b in TRAIN_BENCHES]

print("== Phase 1: design sampling + Mahalanobis selection ==")
space = DesignSpace.sample(8, seed=42)
i, j = space.select_pair(list(TRAIN_BENCHES[:1]), instructions=3000)
ua, ub = space[i], space[j]
print(f"  selected designs #{i} and #{j} "
      f"(fetch={ua.fetch_width}/{ub.fetch_width}, rob={ua.rob_size}/{ub.rob_size}, "
      f"bp={ua.branch_predictor}/{ub.branch_predictor})")

print("== Phase 2: joint shared-embedding training (Algorithm 1) ==")
mgr = CheckpointManager("/tmp/tao_e2e_ckpt", keep=2)
t0 = time.time()
# per-epoch checkpoints: keep=2 rotates, so a crash resumes from the
# latest epoch instead of restarting the whole phase
joint = s.train_joint(ua, ub, traces, method="tao", epochs=EPOCHS,
                      batch_size=16, lr=1e-3,
                      on_epoch=lambda ep, params, steps: mgr.save(params, steps))
t_joint = time.time() - t0
mgr.close()
for epoch, (la, lb) in enumerate(joint.losses):
    print(f"  epoch {epoch}: loss_a={la:.3f} loss_b={lb:.3f}")
print(f"  {joint.steps} steps in {t_joint:.0f}s")

print("== Phase 3: transfer to unseen µArch C (frozen embeddings) ==")
small_c = s.dataset(UARCH_C, [s.capture(TRAIN_BENCHES[0], N // 3)])
t0 = time.time()
transfer = joint.transfer(small_c, epochs=max(2, EPOCHS // 2),
                          batch_size=16, lr=1e-3, uarch=UARCH_C)
t_transfer = time.time() - t0

t0 = time.time()
scratch = s.train(UARCH_C, traces, epochs=EPOCHS, batch_size=16, lr=1e-3)
t_scratch = time.time() - t0
print(f"  transfer: {t_transfer:.0f}s   scratch: {t_scratch:.0f}s   "
      f"-> speedup {t_scratch / max(t_transfer, 1e-9):.1f}x (paper: 29.5x at full scale)")

print("== Phase 4: simulate unseen benchmarks on µArch C ==")
for bench in ("mcf", "cac"):
    tr = s.capture(bench, N // 2)
    truth = s.ground_truth(UARCH_C, tr)
    sim_t = transfer.simulate(tr)
    sim_s = scratch.simulate(tr)
    print(f"  {bench}: truth_cpi={truth['cpi']:.3f}  "
          f"transfer_cpi={sim_t.cpi:.3f} (err {sim_t.error_vs(truth['cpi']):.1f}%)  "
          f"scratch_cpi={sim_s.cpi:.3f} (err {sim_s.error_vs(truth['cpi']):.1f}%)")
print("done.")
