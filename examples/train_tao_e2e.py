"""End-to-end driver: the paper's FULL workflow, including the transfer-
learning path that delivers the 18x speedup claim.

  Phase 1  design-space sampling + Mahalanobis pair selection   (§4.3)
  Phase 2  joint shared-embedding training on the selected pair (Alg. 1)
  Phase 3  fast enablement of an UNSEEN µarch: frozen embeddings +
           fine-tuned prediction layers on a small dataset       (§5.5)
  Phase 4  multi-metric simulation + comparison vs scratch training

This is the "train a ~100M-class model for a few hundred steps" e2e driver
(at CPU scale the Tao model is width-reduced; flip FULL=1 to use the
paper-scale config from repro/configs/tao.py).

Run:  PYTHONPATH=src python examples/train_tao_e2e.py
"""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.core import (
    FeatureConfig,
    TaoConfig,
    build_windows,
    extract_features,
    init_multiarch,
    make_joint_step,
    measure_design_metrics,
    select_pair_mahalanobis,
    simulate_trace,
    train_tao,
    transfer_finetune,
)
from repro.core.align import build_adjusted_trace
from repro.core.dataset import concat_datasets
from repro.train.optim import AdamWConfig, adamw_init
from repro.uarch import UARCH_C, get_benchmark, run_detailed, run_functional, sample_design_space

FULL = os.environ.get("FULL", "0") == "1"
N = 40_000 if FULL else 15_000
EPOCHS = 12 if FULL else 5
TRAIN_BENCHES = ("dee", "rom", "nab", "lee") if FULL else ("dee", "lee")

if FULL:
    from repro.configs.tao import CONFIG as cfg
    fcfg = cfg.features
else:
    fcfg = FeatureConfig(n_buckets=256, n_queue=8, n_mem=16)
    cfg = TaoConfig(window=33, d_model=64, n_heads=4, n_layers=2, d_ff=128,
                    d_cat=32, features=fcfg)


def dataset_for(uarch, benches, n=N):
    parts = []
    for b in benches:
        prog = get_benchmark(b)
        ft = run_functional(prog, n)
        det, _ = run_detailed(prog, ft, uarch)
        parts.append(
            build_windows(extract_features(build_adjusted_trace(det).adjusted, fcfg),
                          cfg.window)
        )
    return concat_datasets(parts)


print("== Phase 1: design sampling + Mahalanobis selection ==")
designs = sample_design_space(8, seed=42)
metrics = measure_design_metrics(designs, list(TRAIN_BENCHES[:1]), instructions=3000)
i, j = select_pair_mahalanobis(metrics)
ua, ub = designs[i], designs[j]
print(f"  selected designs #{i} and #{j} "
      f"(fetch={ua.fetch_width}/{ub.fetch_width}, rob={ua.rob_size}/{ub.rob_size}, "
      f"bp={ua.branch_predictor}/{ub.branch_predictor})")

print("== Phase 2: joint shared-embedding training (Algorithm 1) ==")
ds_a = dataset_for(ua, TRAIN_BENCHES)
ds_b = dataset_for(ub, TRAIN_BENCHES)
params = init_multiarch(jax.random.PRNGKey(0), cfg)
opt = adamw_init(params)
step = make_joint_step(cfg, AdamWConfig(lr=1e-3), method="tao")
w = jnp.ones((2,))
rng = np.random.default_rng(0)
mgr = CheckpointManager("/tmp/tao_e2e_ckpt", keep=2)
t0 = time.time()
steps = 0
for epoch in range(EPOCHS):
    for ba, bb in zip(ds_a.batches(16, rng=rng), ds_b.batches(16, rng=rng)):
        ba["labels"] = {k: jnp.asarray(v) for k, v in ba.pop("labels").items()}
        bb["labels"] = {k: jnp.asarray(v) for k, v in bb.pop("labels").items()}
        params, opt, w, m = step(params, opt, w, jnp.ones((2,)), ba, bb)
        steps += 1
    mgr.save(params, steps)
    print(f"  epoch {epoch}: loss_a={float(m['loss_a']):.3f} "
          f"loss_b={float(m['loss_b']):.3f} ({steps} steps)")
t_joint = time.time() - t0
mgr.close()

print("== Phase 3: transfer to unseen µArch C (frozen embeddings) ==")
small_c = dataset_for(UARCH_C, TRAIN_BENCHES[:1], n=N // 3)
t0 = time.time()
res_transfer = transfer_finetune(cfg, params["embed"], params["A"], small_c,
                                 epochs=max(2, EPOCHS // 2), batch_size=16, lr=1e-3)
t_transfer = time.time() - t0

t0 = time.time()
res_scratch = train_tao(cfg, dataset_for(UARCH_C, TRAIN_BENCHES), epochs=EPOCHS,
                        batch_size=16, lr=1e-3)
t_scratch = time.time() - t0
print(f"  transfer: {t_transfer:.0f}s   scratch: {t_scratch:.0f}s   "
      f"-> speedup {t_scratch / max(t_transfer, 1e-9):.1f}x (paper: 29.5x at full scale)")

print("== Phase 4: simulate unseen benchmarks on µArch C ==")
for bench in ("mcf", "cac"):
    prog = get_benchmark(bench)
    ft = run_functional(prog, N // 2)
    _, truth = run_detailed(prog, ft, UARCH_C)
    sim_t = simulate_trace(res_transfer.params, ft, cfg)
    sim_s = simulate_trace(res_scratch.params, ft, cfg)
    print(f"  {bench}: truth_cpi={truth['cpi']:.3f}  "
          f"transfer_cpi={sim_t.cpi:.3f} (err {sim_t.error_vs(truth['cpi']):.1f}%)  "
          f"scratch_cpi={sim_s.cpi:.3f} (err {sim_s.error_vs(truth['cpi']):.1f}%)")
print("done.")
