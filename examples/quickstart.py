"""Quickstart: the Tao workflow end to end in ~2 minutes on CPU.

1. generate functional + detailed traces for a benchmark on µArch A
   (repro.uarch = the gem5 stand-in)
2. build the §4.1 adjusted training dataset (squash/nop re-attribution)
3. train a small multi-metric Tao model (§4.2)
4. simulate an UNSEEN benchmark from its functional trace alone and compare
   CPI / branch-MPKI / L1D-MPKI against the detailed simulator

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    FeatureConfig,
    TaoConfig,
    build_windows,
    extract_features,
    train_tao,
)
from repro.core.align import build_adjusted_trace, verify_alignment
from repro.core.dataset import concat_datasets
from repro.engine import EngineConfig, StreamingEngine
from repro.uarch import UARCH_A, get_benchmark, run_detailed, run_functional

N = 20_000

print("== 1. trace generation (gem5 stand-in) ==")
datasets = []
fcfg = FeatureConfig(n_buckets=256, n_queue=8, n_mem=16)
cfg = TaoConfig(window=33, d_model=64, n_heads=4, n_layers=2, d_ff=128,
                d_cat=32, features=fcfg)
for bench in ("dee", "lee"):
    prog = get_benchmark(bench)
    ft = run_functional(prog, N)
    det, summ = run_detailed(prog, ft, UARCH_A)
    al = build_adjusted_trace(det)
    v = verify_alignment(al, ft)
    print(f"  {bench}: cpi={summ['cpi']:.3f} squashed={al.num_squashed} "
          f"nops={al.num_nops} cycles_match={v['cycles_match']}")
    datasets.append(build_windows(extract_features(al.adjusted, fcfg), cfg.window))

print("== 2/3. dataset construction + training ==")
ds = concat_datasets(datasets)
res = train_tao(cfg, ds, epochs=8, batch_size=16, lr=1e-3)
print(f"  {len(ds)} windows, loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f} "
      f"in {res.seconds:.0f}s")

print("== 4. simulate an unseen benchmark (functional trace only) ==")
prog = get_benchmark("mcf")
ft = run_functional(prog, N // 2)
_, truth = run_detailed(prog, ft, UARCH_A)
# the streaming engine compiles its forward step once and keeps the CPI /
# MPKI accumulators on device; per-instruction arrays stay there too unless
# EngineConfig(collect=True) asks for them
engine = StreamingEngine(res.params, cfg, EngineConfig(batch_size=64))
sim = engine.simulate(ft)
print(f"  CPI:        truth={truth['cpi']:.3f}  tao={sim.cpi:.3f} "
      f"(err {sim.error_vs(truth['cpi']):.1f}%)")
print(f"  brMPKI:     truth={truth['branch_mpki']:.1f}  tao={sim.branch_mpki:.1f}")
print(f"  L1D MPKI:   truth={truth['l1d_mpki']:.1f}  tao={sim.l1d_mpki:.1f}")
print(f"  throughput: {sim.mips*1000:.0f} K instructions/s on CPU")
