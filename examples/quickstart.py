"""Quickstart: the Tao workflow end to end in ~2 minutes on CPU, written
against the `repro.api` Session facade.

1. capture a reusable functional trace per benchmark (repro.uarch = the
   gem5 stand-in; traces are µarch-agnostic, §4.1)
2. build the adjusted training dataset for µArch A and train a small
   multi-metric Tao model (§4.2)
3. simulate an UNSEEN benchmark from its functional trace alone and compare
   CPI / branch-MPKI / L1D-MPKI against the detailed simulator

Run:  PYTHONPATH=src python examples/quickstart.py
      (N=2000 EPOCHS=2 for the CI smoke run)
"""
import os

from repro.api import Session
from repro.core import FeatureConfig, TaoConfig
from repro.uarch import UARCH_A

N = int(os.environ.get("N", "20000"))
EPOCHS = int(os.environ.get("EPOCHS", "8"))

cfg = TaoConfig(window=33, d_model=64, n_heads=4, n_layers=2, d_ff=128,
                d_cat=32, features=FeatureConfig(n_buckets=256, n_queue=8, n_mem=16))
s = Session(cfg)

print("== 1. capture reusable functional traces (gem5 stand-in) ==")
train_traces = [s.capture(b, N) for b in ("dee", "lee")]
for tr in train_traces:
    truth = s.ground_truth(UARCH_A, tr)
    print(f"  {tr.name}: {tr.num_instructions} instrs, "
          f"detailed cpi={truth['cpi']:.3f}")

print("== 2. dataset construction + training (µArch A) ==")
model = s.train(UARCH_A, train_traces, epochs=EPOCHS, batch_size=16, lr=1e-3)
print(f"  loss {model.losses[0]:.3f} -> {model.losses[-1]:.3f} "
      f"in {model.seconds:.0f}s ({model.steps} steps)")

print("== 3. simulate an unseen benchmark (functional trace only) ==")
test = s.capture("mcf", N // 2)
truth = s.ground_truth(UARCH_A, test)
# the engine under model.simulate compiles its step once and keeps the
# metric accumulators on device; pass metrics=... for plug-in MetricSpecs
# and collect=True for per-instruction arrays (phase plots)
sim = model.simulate(test)
print(f"  CPI:        truth={truth['cpi']:.3f}  tao={sim.cpi:.3f} "
      f"(err {sim.error_vs(truth['cpi']):.1f}%)")
print(f"  brMPKI:     truth={truth['branch_mpki']:.1f}  tao={sim.branch_mpki:.1f}")
print(f"  L1D MPKI:   truth={truth['l1d_mpki']:.1f}  tao={sim.l1d_mpki:.1f}")
print(f"  metrics:    {sim.available_metrics}")
print(f"  throughput: {sim.mips*1000:.0f} K instructions/s on CPU")
