"""Simulation-as-a-service client demo: talk to the trace server over TCP.

Starts a ``TraceServer`` with two registered models in this process,
exposes it on localhost via the JSON-lines protocol, then acts as two
concurrent tenant clients submitting wire-encoded functional traces —
exactly what a remote client would do against
``python -m repro.launch.serve --store ... --models ...``.

Run:  PYTHONPATH=src python examples/serve_traces.py
"""
import asyncio
import json

import jax

from repro.api import Session, TrainedModel
from repro.core import FeatureConfig, TaoConfig, init_tao
from repro.launch.serve import serve_forever
from repro.serve import ModelRegistry, TraceServer, encode_trace

cfg = TaoConfig(window=9, d_model=16, n_heads=2, n_layers=1, d_ff=32, d_cat=8,
                features=FeatureConfig(n_buckets=64, n_queue=4, n_mem=8))
sess = Session(cfg)
traces = {b: sess.capture(b, n) for b, n in (("mcf", 1200), ("dee", 600))}

registry = ModelRegistry()
for i, name in enumerate(("base", "tuned")):
    registry.register(name, TrainedModel(
        params=init_tao(jax.random.PRNGKey(i), cfg), cfg=cfg, name=name))


async def client(tenant: str, port: int, jobs):
    """One tenant: pipeline requests over a single connection."""
    reader, writer = await asyncio.open_connection("127.0.0.1", port)
    for i, (model, bench) in enumerate(jobs):
        writer.write(json.dumps({
            "op": "simulate", "model": model, "tenant": tenant,
            "request_id": f"{tenant}-{i}",
            "trace": encode_trace(traces[bench].functional),
        }).encode() + b"\n")
    await writer.drain()
    for _ in jobs:
        resp = json.loads(await reader.readline())
        assert resp["ok"], resp
        r = resp["result"]
        print(f"  {r['request_id']}: model={r['model']} geom={r['geometry']} "
              f"cpi={r['metrics']['cpi']:.3f} "
              f"({r['total_s'] * 1e3:.1f} ms, coalesced={r['coalesced']})")
    writer.write(json.dumps({"op": "stats"}).encode() + b"\n")
    await writer.drain()
    stats = json.loads(await reader.readline())["stats"]
    writer.close()
    return stats


async def main():
    server = TraceServer(registry, batch_size=8, max_queue=32)
    async with server:
        server.warmup([len(t) for t in traces.values()])
        ready = asyncio.get_running_loop().create_future()
        tcp = asyncio.get_running_loop().create_task(
            serve_forever(server, "127.0.0.1", 0, ready))
        _, port = await ready
        stats_a, _ = await asyncio.gather(
            client("alice", port, [("base", "mcf"), ("tuned", "mcf"),
                                   ("base", "dee")]),
            client("bob", port, [("tuned", "dee"), ("base", "mcf")]),
        )
        tcp.cancel()
    print(f"server: {stats_a['completed']} served, "
          f"{stats_a['num_compiles']} compiles, "
          f"{stats_a['features_coalesced']} coalesced feature passes, "
          f"p99 latency {stats_a['latency_p99_s'] * 1e3:.1f} ms")


asyncio.run(main())
