"""Minimal functional NN primitives shared by the Tao model and the LM zoo.

Everything is a pure function over parameter pytrees (nested dicts of
jnp arrays).  No framework dependency: keeps the whole stack jit/pjit
friendly and easy to shard by tree-path rules.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def truncated_normal_init(key, shape, stddev: float, dtype=jnp.float32):
    # 2-sigma truncation, rescaled to preserve stddev (same as jax.nn init).
    unscaled = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
    return (unscaled * stddev / 0.87962566103423978).astype(dtype)


def init_dense(
    key,
    in_dim: int,
    out_dim: int,
    *,
    use_bias: bool = True,
    dtype=jnp.float32,
    scale: float = 1.0,
):
    """Fan-in scaled initialization."""
    std = scale / math.sqrt(in_dim)
    p = {"w": truncated_normal_init(key, (in_dim, out_dim), std, dtype)}
    if use_bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def init_embed(key, vocab: int, dim: int, dtype=jnp.float32):
    return {"table": truncated_normal_init(key, (vocab, dim), 1.0, dtype)}


def embed(p, ids):
    return p["table"][ids]


def init_layernorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y * p["scale"] + p["bias"]


def init_rmsnorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    # Normalize in fp32 for stability regardless of compute dtype.
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def silu(x):
    return jax.nn.silu(x)


def softmax_cross_entropy(logits, labels, num_classes: Optional[int] = None):
    """labels: int array; returns per-element CE."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logp.dtype)
    return -jnp.sum(onehot * logp, axis=-1)
