from .core import (
    dense,
    embed,
    gelu,
    init_dense,
    init_embed,
    init_layernorm,
    init_rmsnorm,
    layernorm,
    rmsnorm,
    silu,
    softmax_cross_entropy,
    truncated_normal_init,
)

__all__ = [
    "dense",
    "embed",
    "gelu",
    "silu",
    "init_dense",
    "init_embed",
    "init_layernorm",
    "init_rmsnorm",
    "layernorm",
    "rmsnorm",
    "softmax_cross_entropy",
    "truncated_normal_init",
]
