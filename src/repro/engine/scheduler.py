"""Async multi-trace sweep scheduler (design-space exploration fast path).

The streaming engine already reuses one compiled step across traces and —
because params are an *argument* of the jitted step — across every model of
the same shape.  This module adds the missing piece for DSE sweeps
(ROADMAP "async multi-trace scheduling"): a double-buffered trace queue
that overlaps the host-side work of trace i+1 (feature extraction +
window-view setup) with the device execution of trace i, so the device
never waits on the host pre-pass between traces.

    sweeper = TraceSweeper(cfg, EngineConfig(batch_size=64))
    report = sweeper.run([
        SweepJob("l1d16/mcf", params_16, trace_mcf),
        SweepJob("l1d16/xal", params_16, trace_xal),
        SweepJob("l1d32/mcf", params_32, trace_mcf),
        ...
    ])
    report.results["l1d16/mcf"].l1d_mpki
    report.num_compiles        # == 1 per effective-window geometry
    report.traces_per_s, report.queue_occupancy_mean

A producer thread prepares jobs into a bounded queue (``depth`` slots —
2 = classic double buffering); the consumer streams each prepared trace
through a per-params ``StreamingEngine`` whose jitted step comes from the
process-wide step cache, so the whole sweep compiles once per window
geometry no matter how many (model, trace) pairs it covers.  Each distinct
trace's features are extracted once and shared across every model
(sequential per-model engines re-extract per pair).  On CPU-only backends
the producer thread would contend with the step's own compute for the same
cores, so preparation runs inline there (``async_prepare`` overrides).
"""
from __future__ import annotations

import dataclasses
import functools
import queue
import threading
import time
from typing import Dict, Iterable, List, Optional, Tuple, Union

import jax
import numpy as np

from ..core.features import FeatureSet, extract_features
from ..core.model import TaoConfig
from ..resilience.faults import fault_point
from ..store.content import array_digest, config_token, content_key, tree_digest
from .metrics import resolve_metrics
from .plan import ExecutionPlan
from .runner import EngineConfig, SimulationResult, StreamingEngine

__all__ = ["SweepJob", "SweepReport", "TraceSweeper", "sweep_traces"]


@dataclasses.dataclass(frozen=True)
class SweepJob:
    """One (model, trace) pair of a sweep."""

    key: str                 # e.g. "l1d32KB/mcf"
    params: Dict             # model parameters (same TaoConfig shape)
    trace: np.ndarray        # functional trace (FUNC_TRACE_DTYPE)


@dataclasses.dataclass
class SweepReport:
    """Results plus the scheduler's own performance counters."""

    results: Dict[str, SimulationResult]
    seconds: float           # wall clock for the whole sweep
    num_traces: int
    num_instructions: int
    # step compilations performed DURING this sweep (at most 1 per window
    # geometry; 0 when an earlier run already warmed the shared step cache)
    num_compiles: int
    traces_per_s: float
    mips: float              # aggregate instructions/s over the sweep wall clock
    queue_occupancy_mean: float  # prepared jobs waiting when the consumer polls
    queue_occupancy_max: int
    queue_depth: int
    prepared_async: bool = False  # threaded producer (False = inline on CPU)
    plan_kind: str = "single"     # ExecutionPlan kind the sweep ran under
    num_shards: int = 1           # devices each step fanned out over
    # host feature pre-passes this sweep actually ran vs loaded from the
    # artifact store (0 extracted on a warm store = the zero-cold-start
    # invariant; both stay 0 on the pallas/fused backends, which extract
    # on device per trace)
    features_extracted: int = 0
    features_from_store: int = 0
    # jobs satisfied from crash-resume progress manifests (store entries
    # published by an earlier, possibly killed, run with the same
    # resume_key) — skipped entirely: no extraction, no device work
    jobs_skipped: int = 0

    def stats(self) -> Dict[str, Union[float, int, str]]:
        return {
            "traces_per_s": self.traces_per_s,
            "mips": self.mips,
            "num_compiles": self.num_compiles,
            "queue_occupancy_mean": self.queue_occupancy_mean,
            "queue_occupancy_max": self.queue_occupancy_max,
            "plan_kind": self.plan_kind,
            "num_shards": self.num_shards,
            "features_extracted": self.features_extracted,
            "features_from_store": self.features_from_store,
            "jobs_skipped": self.jobs_skipped,
        }

    def to_dict(self) -> Dict:
        """Stable JSON-clean form: scheduler counters plus every result's
        ``SimulationResult.to_dict()`` — what the serve/bench layers
        serialize instead of reaching into report internals."""
        return {
            "seconds": self.seconds,
            "num_traces": self.num_traces,
            "num_instructions": self.num_instructions,
            "queue_depth": self.queue_depth,
            "prepared_async": self.prepared_async,
            **self.stats(),
            "results": {k: r.to_dict() for k, r in self.results.items()},
        }


_STOP = object()


class TraceSweeper:
    """Double-buffer a queue of (model, trace) jobs through the shared
    cached executable."""

    def __init__(
        self,
        cfg: TaoConfig,
        ecfg: EngineConfig = EngineConfig(),
        *,
        depth: int = 2,
        async_prepare: Optional[bool] = None,
        store=None,
    ):
        if depth < 1:
            raise ValueError(f"queue depth must be >= 1, got {depth}")
        # Sharded sweeps are a composition: the engines the consumer builds
        # all resolve the same ExecutionPlan from this config, so the trace
        # queue fans out over models/traces while each step fans out over
        # the plan's batch axes.  Resolve eagerly so a bad (mesh, batch)
        # combination fails here, not mid-sweep.
        self.plan = ExecutionPlan.resolve(
            ecfg.mesh, batch_size=ecfg.batch_size, plan=ecfg.plan
        )
        self.cfg = cfg
        self.ecfg = ecfg
        self.depth = depth
        # Thread the host-side preparation only when an accelerator runs the
        # step: on a CPU-only backend the "device" compute occupies the same
        # cores, so a producer thread is pure contention (measured ~0.7x at
        # tiny scale) — prepare inline there instead (the per-trace feature
        # dedup still applies).  Overridable for tests / exotic hosts.
        if async_prepare is None:
            async_prepare = jax.default_backend() != "cpu"
        self.async_prepare = async_prepare
        # content-addressed artifact store (repro.store.ArtifactStore):
        # inference features persist/load across processes through it
        self.store = store

    def warmup(self, trace_lengths: Iterable[int]) -> Dict[str, int]:
        """AOT-compile the sweep's step for a declared geometry set before
        any jobs (or even params) exist: abstract params from
        ``jax.eval_shape`` lower through ``StreamingEngine.warmup``, and —
        with the persistent compilation cache enabled — a process that
        warms the same geometries later deserializes instead of compiling.
        Returns ``{"geometries": ..., "aot_compiled": ...}``."""
        from ..core.model import init_tao

        abstract = jax.eval_shape(
            functools.partial(init_tao, cfg=self.cfg), jax.random.PRNGKey(0)
        )
        engine = StreamingEngine(abstract, self.cfg, self.ecfg)
        entries = [engine.warmup(n) for n in sorted(set(trace_lengths))]
        return {
            "geometries": len(entries),
            "aot_compiled": sum(1 for e in entries if e.aot is not None),
        }

    # host-side preparation that the producer thread runs ahead of the device
    # producer-thread / inline feature prep: host NumPy on the raw trace,
    # runs before the trace's first dispatch
    # tao: cold
    def _prepare(
        self,
        job: SweepJob,
        cache: Dict[str, FeatureSet],
        digests: Dict[int, str],
        counts: Dict[str, int],
    ) -> Optional[FeatureSet]:
        fault_point("scheduler.prepare", payload=job.key)
        if self.ecfg.feature_backend in ("pallas", "fused"):
            # device-side extraction happens in the consumer (the device is
            # the contended resource); nothing to pre-compute on host.
            return None
        # DSE sweeps visit the same few traces once per design point: the
        # features are a pure function of (trace, FeatureConfig), so extract
        # each distinct trace once and share it across every model.  Dedup
        # is by *content* digest — the same identity scheme the artifact
        # store keys on — so two equal trace arrays loaded separately
        # still share one extraction (object ids would not).
        dg = digests.get(id(job.trace))
        if dg is None:
            dg = array_digest(job.trace)
            digests[id(job.trace)] = dg
        fs = cache.get(dg)
        if fs is not None:
            return fs
        key = content_key("features", dg, self.cfg.features)
        if self.store is not None:
            hit = self.store.get("features", key)
            if hit is not None:
                from ..store.store import tree_to_features

                fs = tree_to_features(hit[0])
                counts["from_store"] += 1
                cache[dg] = fs
                return fs
        fs = extract_features(job.trace, self.cfg.features, with_labels=False)
        counts["extracted"] += 1
        if self.store is not None:
            from ..store.store import features_to_tree

            self.store.put("features", key, features_to_tree(fs))
        cache[dg] = fs
        return fs

    def _progress_token(self) -> str:
        """Everything a sweep result is a function of besides (params,
        trace): model config, batch geometry, collect flag, spec set —
        part of every progress-manifest key so a resumed run with a
        different recipe never reuses stale results."""
        specs = resolve_metrics(self.ecfg.metrics)
        return "|".join((
            str(config_token(self.cfg)),
            f"b{self.ecfg.batch_size}",
            f"c{int(self.ecfg.collect)}",
            ",".join(s.name for s in specs),
        ))

    # tao: hot
    def run(
        self, jobs: Iterable[SweepJob], *, resume_key: Optional[str] = None
    ) -> SweepReport:
        jobs = list(jobs)
        if not jobs:
            raise ValueError("sweep needs at least one job")
        keys = [j.key for j in jobs]
        if len(set(keys)) != len(keys):
            raise ValueError(f"duplicate sweep job keys: {keys}")
        if resume_key is not None and self.store is None:
            raise ValueError("resume_key needs a store to hold the manifests")

        feat_cache: Dict[str, FeatureSet] = {}  # trace digest -> features
        digests: Dict[int, str] = {}            # id(trace) -> digest (memo)
        feat_counts = {"extracted": 0, "from_store": 0}
        occ: List[int] = []
        results: Dict[str, SimulationResult] = {}
        n_instr = 0
        n_total = len(jobs)

        # crash-resume: load the done set up front and only feed the
        # remainder to the producer — completed jobs cost zero extractions
        # and zero device work on the resumed run
        skipped = 0
        progress_keys: Dict[str, str] = {}
        if resume_key is not None:
            from ..resilience import manifest as _manifest

            token = self._progress_token()
            pdigests: Dict[int, str] = {}       # id(params) -> digest (memo)
            remaining: List[SweepJob] = []
            for job in jobs:
                dg = digests.get(id(job.trace))
                if dg is None:
                    dg = array_digest(job.trace)
                    digests[id(job.trace)] = dg
                pd = pdigests.get(id(job.params))
                if pd is None:
                    pd = tree_digest(job.params)
                    pdigests[id(job.params)] = pd
                pkey = _manifest.sweep_progress_key(
                    resume_key, job.key, dg, pd, token
                )
                progress_keys[job.key] = pkey
                res = _manifest.load_sweep_result(self.store, pkey)
                if res is not None:
                    results[job.key] = res
                    n_instr += res.num_instructions
                    skipped += 1
                else:
                    remaining.append(job)
            jobs = remaining

        # consumer state: engines share jitted steps via the process-wide
        # step cache; one per params object so a model's engine is reused
        # across its traces
        engines: Dict[int, StreamingEngine] = {}
        entries: Dict[int, object] = {}   # id(_CachedStep) -> _CachedStep
        baseline: Dict[int, int] = {}     # compiles before this sweep used it

        def consume(job: SweepJob, features: Optional[FeatureSet]) -> None:
            nonlocal n_instr
            fault_point("scheduler.consume", payload=job.key)
            engine = engines.get(id(job.params))
            if engine is None:
                engine = StreamingEngine(job.params, self.cfg, self.ecfg)
                engines[id(job.params)] = engine
            # snapshot the shared step entry BEFORE simulating, so the
            # report attributes only compiles this sweep triggered
            entry = engine.step_entry_for(len(job.trace))
            if id(entry) not in entries:
                entries[id(entry)] = entry
                baseline[id(entry)] = entry.compiles
            res = engine.simulate(job.trace, features=features)
            results[job.key] = res
            n_instr += res.num_instructions
            if resume_key is not None:
                from ..resilience import manifest as _manifest

                _manifest.publish_sweep_result(
                    self.store, progress_keys[job.key], res
                )

        t0 = time.perf_counter()
        if not self.async_prepare:
            # inline mode (CPU backends): no producer thread to contend with
            # the step's compute; the feature dedup still applies
            for job in jobs:
                consume(job, self._prepare(job, feat_cache, digests, feat_counts))
        else:
            q: "queue.Queue" = queue.Queue(maxsize=self.depth)
            error: List[BaseException] = []
            stop = threading.Event()  # set when the consumer bails out early

            def produce():
                try:
                    for job in jobs:
                        prepared = self._prepare(
                            job, feat_cache, digests, feat_counts
                        )
                        while not stop.is_set():
                            try:
                                q.put((job, prepared), timeout=0.1)
                                break
                            except queue.Full:
                                continue
                        if stop.is_set():
                            return
                except BaseException as e:  # surfaced in the consumer
                    error.append(e)
                finally:
                    while True:  # always deliver _STOP without blocking
                        try:
                            q.put(_STOP, timeout=0.1)
                            break
                        except queue.Full:
                            if stop.is_set():
                                break

            producer = threading.Thread(
                target=produce, name="trace-sweep-producer", daemon=True
            )
            producer.start()
            try:
                while True:
                    occ.append(q.qsize())
                    item = q.get()
                    if item is _STOP:
                        break
                    consume(*item)
            finally:
                # unblock the producer (it may be parked on a full queue)
                # and drop any prepared-but-unconsumed feature arrays
                stop.set()
                while True:
                    try:
                        q.get_nowait()
                    except queue.Empty:
                        break
            producer.join()
            if error:
                raise error[0]
        secs = time.perf_counter() - t0

        return SweepReport(
            results=results,
            seconds=secs,
            num_traces=n_total,
            num_instructions=n_instr,
            num_compiles=sum(
                e.compiles - baseline[i] for i, e in entries.items()
            ),
            traces_per_s=n_total / secs,
            mips=n_instr / 1e6 / secs,
            queue_occupancy_mean=float(np.mean(occ)) if occ else 0.0,  # tao: noqa[TAO002] occ is a host list of queue depths; runs once after the sweep loop
            queue_occupancy_max=int(np.max(occ)) if occ else 0,
            queue_depth=self.depth,
            prepared_async=self.async_prepare,
            plan_kind=self.plan.kind,
            num_shards=self.plan.num_shards,
            features_extracted=feat_counts["extracted"],
            features_from_store=feat_counts["from_store"],
            jobs_skipped=skipped,
        )


def sweep_traces(
    cfg: TaoConfig,
    jobs: Iterable[Tuple[str, Dict, np.ndarray]],
    ecfg: EngineConfig = EngineConfig(),
    *,
    depth: int = 2,
    async_prepare: Optional[bool] = None,
) -> SweepReport:
    """One-shot convenience wrapper over ``TraceSweeper``."""
    return TraceSweeper(cfg, ecfg, depth=depth, async_prepare=async_prepare).run(
        SweepJob(k, p, t) for k, p, t in jobs
    )
