"""Pluggable device-side metric accumulators for the streaming engine.

The engine's jitted step used to hard-code one carry dict (CPI fetch sum,
branch-mispredict count, L1D-miss count, trailing exec latency).  This
module replaces that with a registry of ``MetricSpec``s: each metric
declares its own device-side accumulator — an ``init`` pytree, an
``update`` that folds one batch into it *inside* the jitted step, and a
host-side ``finalize`` — and the engine composes every requested spec into
the single compiled executable.  New metrics (phase curves, per-opcode
CPI, cache-level histograms, ...) are plug-in code, not engine surgery:

    from repro.engine.metrics import MetricSpec, register_metric

    DRAM_HITS = MetricSpec(
        name="dram_hits",
        init=lambda: jnp.zeros((), jnp.int32),
        update=lambda c, ctx: c + ctx.psum(
            ((ctx.dlevel == NUM_DLEVELS - 1) & ctx.is_mem)
            .sum(dtype=jnp.int32)),
        finalize=lambda c, n: {"dram_hits": float(c)},
    )
    register_metric(DRAM_HITS)
    engine = StreamingEngine(params, cfg, EngineConfig(
        metrics=("cpi", "dram_hits")))

Specs run on device, under jit, and — when the engine is sharded — inside
``shard_map``; ``StepContext.psum``/``pmax`` are the cross-shard reducers
(identity on a single device), so a spec written against the context works
unchanged on a mesh.  ``ctx.batch`` exposes only the columns the engine
ships (feature INPUT_KEYS, ``valid``, ``is_branch``, ``is_mem``) — a spec
needing other trace columns must drive the step with
``stream_batches(extra=...)`` (see tests/test_api.py for a worked
example).  The built-in specs reproduce the legacy carry's values
bit-for-bit (enforced by ``tests/test_api.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple, Union

import jax
import jax.numpy as jnp

from ..uarch.isa import DLEVEL_L2, NUM_DLEVELS

__all__ = [
    "StepContext",
    "MetricSpec",
    "METRIC_REGISTRY",
    "DEFAULT_METRICS",
    "register_metric",
    "resolve_metrics",
    "CPI",
    "BRANCH_MPKI",
    "L1D_MPKI",
    "DLEVEL_HIST",
]


@dataclasses.dataclass(frozen=True)
class StepContext:
    """Everything a metric's ``update`` may read, for one (B, W) batch.

    All arrays are flattened to ``(B * W,)`` device arrays and live inside
    the jitted step (under ``shard_map`` they are the *local* shard).
    ``is_branch``/``is_mem`` are already masked to valid positions; the raw
    batch (feature columns, ``valid``, unmasked flags, ...) is in ``batch``.
    """

    valid: Any          # float32 validity mask (0.0 on padding)
    on: Any             # bool, valid > 0
    is_branch: Any      # bool, trace is_branch & on
    is_mem: Any         # bool, trace is_mem & on
    fetch_lat: Any      # float32, clamped >= 0
    exec_lat: Any       # float32, clamped >= 0
    mispred_prob: Any   # float32 sigmoid(mispred_logit)
    dlevel: Any         # int32 argmax(dlevel_logits)
    gidx: Any           # float32 global position key within the batch grid
    last_key: Any       # scalar: key of the globally-last valid position
                        # in this batch (-1.0 when the batch is all padding)
    psum: Callable[[Any], Any]   # cross-shard sum (identity off-mesh)
    pmax: Callable[[Any], Any]   # cross-shard max (identity off-mesh)
    sharded: bool
    batch: Dict[str, Any]

    def at_last(self, x) -> Any:
        """Value of ``x`` at the globally-last valid position of the batch
        (meaningful only when ``last_key >= 0``)."""
        if self.sharded:
            # the winning position lives on exactly one shard
            return self.psum(
                jnp.where(self.gidx == self.last_key, x, 0.0).sum(dtype=jnp.float32)
            )
        return x[jnp.argmax(jnp.where(self.on, self.gidx, -1.0)).astype(jnp.int32)]


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One device-side metric accumulator.

    ``init``     () -> device carry pytree (zeros)
    ``update``   (carry, StepContext) -> carry; traced into the jitted step
                 once per batch.  Cross-shard reductions must go through
                 ``ctx.psum``/``ctx.pmax``/``ctx.at_last``.
    ``finalize`` (host carry pytree, num_instructions) -> {metric: float};
                 runs on host after the single end-of-trace sync, and may
                 emit several named result metrics.
    """

    name: str
    init: Callable[[], Any]
    update: Callable[[Any, "StepContext"], Any]
    finalize: Callable[[Any, int], Dict[str, float]]


# ---------------------------------------------------------------------------
# Built-in specs (bit-for-bit the legacy carry)
# ---------------------------------------------------------------------------


def _cpi_init():
    # fetch_sum carries the only float rounding; the instruction count is
    # computed host-side from the window grid.
    return {
        "fetch_sum": jnp.zeros((), jnp.float32),
        "last_exec": jnp.zeros((), jnp.float32),
    }


def _cpi_update(carry, ctx: StepContext):
    part = ctx.psum((ctx.fetch_lat * ctx.valid).sum(dtype=jnp.float32))
    return {
        "fetch_sum": carry["fetch_sum"] + part,
        # retire-clock formulation: total cycles end at the last valid
        # instruction's exec latency, so track it across batches
        "last_exec": jnp.where(
            ctx.last_key >= 0, ctx.at_last(ctx.exec_lat), carry["last_exec"]
        ),
    }


def _cpi_finalize(carry, n: int) -> Dict[str, float]:
    total = float(carry["fetch_sum"] + carry["last_exec"])
    return {"cpi": total / max(n, 1), "total_cycles": total}


CPI = MetricSpec("cpi", _cpi_init, _cpi_update, _cpi_finalize)


def _int_count_init():
    # exact int32 counts (good to 2^31 instructions per trace)
    return jnp.zeros((), jnp.int32)


def _branch_update(carry, ctx: StepContext):
    return carry + ctx.psum(
        ((ctx.mispred_prob > 0.5) & ctx.is_branch).sum(dtype=jnp.int32)
    )


def _branch_finalize(carry, n: int) -> Dict[str, float]:
    return {"branch_mpki": 1000.0 * float(carry) / max(n, 1)}


BRANCH_MPKI = MetricSpec("branch_mpki", _int_count_init, _branch_update, _branch_finalize)


def _l1d_update(carry, ctx: StepContext):
    return carry + ctx.psum(
        ((ctx.dlevel >= DLEVEL_L2) & ctx.is_mem).sum(dtype=jnp.int32)
    )


def _l1d_finalize(carry, n: int) -> Dict[str, float]:
    return {"l1d_mpki": 1000.0 * float(carry) / max(n, 1)}


L1D_MPKI = MetricSpec("l1d_mpki", _int_count_init, _l1d_update, _l1d_finalize)


# A registered non-default plug-in: predicted data-access-level histogram
# over memory ops (cache-level composition, Fig. 11-style breakdowns).
def _dlevel_hist_init():
    return jnp.zeros((NUM_DLEVELS,), jnp.int32)


def _dlevel_hist_update(carry, ctx: StepContext):
    onehot = jax.nn.one_hot(ctx.dlevel, NUM_DLEVELS, dtype=jnp.int32)
    return carry + ctx.psum(
        (onehot * ctx.is_mem[:, None].astype(jnp.int32)).sum(axis=0)
    )


_DLEVEL_NAMES = ("none", "l1", "l2", "dram")


def _dlevel_hist_finalize(carry, n: int) -> Dict[str, float]:
    return {
        f"dlevel_{_DLEVEL_NAMES[i]}": float(carry[i]) for i in range(NUM_DLEVELS)
    }


DLEVEL_HIST = MetricSpec(
    "dlevel_hist", _dlevel_hist_init, _dlevel_hist_update, _dlevel_hist_finalize
)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

METRIC_REGISTRY: Dict[str, MetricSpec] = {}

# the legacy carry's metric set — what EngineConfig requests by default
DEFAULT_METRICS: Tuple[str, ...] = ("cpi", "branch_mpki", "l1d_mpki")


def register_metric(spec: MetricSpec, *, overwrite: bool = False) -> MetricSpec:
    if not overwrite and spec.name in METRIC_REGISTRY:
        raise ValueError(
            f"metric {spec.name!r} already registered "
            f"(pass overwrite=True to replace it)"
        )
    METRIC_REGISTRY[spec.name] = spec
    return spec


for _spec in (CPI, BRANCH_MPKI, L1D_MPKI, DLEVEL_HIST):
    register_metric(_spec)


def resolve_metrics(
    metrics: Tuple[Union[str, MetricSpec], ...],
) -> Tuple[MetricSpec, ...]:
    """Names -> registry lookup; MetricSpec instances pass through."""
    specs = []
    seen = set()
    for m in metrics:
        spec = m
        if isinstance(m, str):
            spec = METRIC_REGISTRY.get(m)
            if spec is None:
                raise KeyError(
                    f"unknown metric {m!r}; registered: "
                    f"{sorted(METRIC_REGISTRY)} (register_metric() adds more)"
                )
        elif not isinstance(m, MetricSpec):
            raise TypeError(f"metrics entries must be str or MetricSpec, got {m!r}")
        if spec.name in seen:
            raise ValueError(f"duplicate metric {spec.name!r}")
        seen.add(spec.name)
        specs.append(spec)
    if not specs:
        raise ValueError("at least one metric is required")
    return tuple(specs)
