"""Pluggable device-side metric accumulators for the streaming engine.

The engine's jitted step used to hard-code one carry dict (CPI fetch sum,
branch-mispredict count, L1D-miss count, trailing exec latency).  This
module replaces that with a registry of ``MetricSpec``s: each metric
declares its own device-side accumulator — an ``init`` pytree, an
``update`` that folds one batch into it *inside* the jitted step, and a
host-side ``finalize`` — and the engine composes every requested spec into
the single compiled executable.  New metrics (phase curves, per-opcode
CPI, cache-level histograms, ...) are plug-in code, not engine surgery:

    from repro.engine.metrics import MetricSpec, register_metric

    DRAM_HITS = MetricSpec(
        name="dram_hits",
        init=lambda: jnp.zeros((), jnp.int32),
        update=lambda c, ctx: c + ctx.psum(
            ((ctx.dlevel == NUM_DLEVELS - 1) & ctx.is_mem)
            .sum(dtype=jnp.int32)),
        finalize=lambda c, n: {"dram_hits": float(c)},
    )
    register_metric(DRAM_HITS)
    engine = StreamingEngine(params, cfg, EngineConfig(
        metrics=("cpi", "dram_hits")))

Specs run on device, under jit, and — whatever ``ExecutionPlan`` the
engine resolved — inside ``shard_map``; ``StepContext.psum``/``pmax`` are
the cross-shard reducers (identity on a single-device plan), so a spec
written against the context works unchanged on a mesh.  ``ctx.batch``
exposes only the columns the engine ships (feature INPUT_KEYS, ``valid``,
``is_branch``, ``is_mem``) — a spec needing other trace columns must
drive the step with ``stream_batches(extra=...)`` (see tests/test_api.py
for a worked example).  The built-in specs reproduce the legacy carry's
values bit-for-bit (enforced by ``tests/test_api.py``).

**Windowed (phase-curve) metrics.**  A spec may declare a fixed
``(num_chunks,)`` carry and scatter per-window contributions into trace
phases with ``ctx.windowed_sum`` — the engine threads the global window
grid (``ctx.win_index`` / ``ctx.num_windows``) through the carry, so
Fig. 11-style phase curves accumulate **on device** under every plan (no
``collect=True`` round-trips) and cross shards through ``psum`` like any
other carry.  ``windowed_spec`` builds one; ``cpi_phase`` / ``l1d_phase``
are registered examples.  Their finalized value is a ``(num_chunks,)``
ndarray in ``SimulationResult.metrics``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..uarch.isa import DLEVEL_L2, NUM_DLEVELS

__all__ = [
    "StepContext",
    "MetricSpec",
    "METRIC_REGISTRY",
    "DEFAULT_METRICS",
    "DEFAULT_PHASE_CHUNKS",
    "register_metric",
    "resolve_metrics",
    "windowed_spec",
    "CPI",
    "BRANCH_MPKI",
    "L1D_MPKI",
    "DLEVEL_HIST",
    "CPI_PHASE",
    "L1D_PHASE",
]


@dataclasses.dataclass(frozen=True)
class StepContext:
    """Everything a metric's ``update`` may read, for one (B, W) batch.

    All arrays are flattened to ``(B * W,)`` device arrays and live inside
    the jitted step (under ``shard_map`` they are the *local* shard).
    ``is_branch``/``is_mem`` are already masked to valid positions; the raw
    batch (feature columns, ``valid``, unmasked flags, ...) is in ``batch``.
    """

    valid: Any          # float32 validity mask (0.0 on padding)
    on: Any             # bool, valid > 0
    is_branch: Any      # bool, trace is_branch & on
    is_mem: Any         # bool, trace is_mem & on
    fetch_lat: Any      # float32, clamped >= 0
    exec_lat: Any       # float32, clamped >= 0
    mispred_prob: Any   # float32 sigmoid(mispred_logit)
    dlevel: Any         # int32 argmax(dlevel_logits)
    gidx: Any           # float32 global position key within the batch grid
    last_key: Any       # scalar: key of the globally-last valid position
                        # in this batch (-1.0 when the batch is all padding)
    psum: Callable[[Any], Any]   # cross-shard sum (identity off-mesh)
    pmax: Callable[[Any], Any]   # cross-shard max (identity off-mesh)
    sharded: bool
    batch: Dict[str, Any]
    # --- window grid (threaded through the engine's reserved carry) ---
    window: int = 0      # effective window length W (static)
    win_index: Any = None   # (B_local,) int32 TRACE-global window index of
                            # each local row (>= num_windows on padding rows)
    num_windows: Any = None  # int32 scalar: real windows in the whole trace

    # tao: hot
    def at_last(self, x) -> Any:
        """Value of ``x`` at the globally-last valid position of the batch
        (meaningful only when ``last_key >= 0``)."""
        if self.sharded:
            # the winning position lives on exactly one shard
            return self.psum(
                jnp.where(self.gidx == self.last_key, x, 0.0).sum(dtype=jnp.float32)
            )
        return x[jnp.argmax(jnp.where(self.on, self.gidx, -1.0)).astype(jnp.int32)]

    def per_window(self, x) -> Any:
        """Reshape a flattened ``(B_local*W,)`` array to local windows
        ``(B_local, W)``."""
        return x.reshape(-1, self.window)

    def chunk_of(self, num_chunks: int) -> Any:
        """Each local window's phase-chunk bucket in ``[0, num_chunks)``:
        the trace's window grid divided into ``num_chunks`` contiguous
        phases.  Padding windows clamp into the last bucket — harmless as
        long as their contribution is masked (``ctx.valid`` is 0 there).

        The index math is int32, so ``num_windows * num_chunks`` must fit
        in int32 — the engine enforces it per trace for any spec that
        declares ``MetricSpec.num_chunks`` (``windowed_spec`` does).
        """
        b = (self.win_index * num_chunks) // jnp.maximum(self.num_windows, 1)
        return jnp.clip(b, 0, num_chunks - 1)

    # tao: hot
    def windowed_sum(self, values, num_chunks: int) -> Any:
        """Scatter already-masked per-position ``values`` (``(B_local*W,)``;
        multiply by ``ctx.valid`` / ``ctx.on`` first) into a
        ``(num_chunks,)`` phase accumulator, summed across shards.  The
        carry stays a fixed shape no matter the trace length, so phase
        curves ride the same one-compile-per-geometry executable."""
        per_win = self.per_window(values).sum(axis=1)
        seg = jax.ops.segment_sum(
            per_win, self.chunk_of(num_chunks), num_segments=num_chunks
        )
        return self.psum(seg)


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One device-side metric accumulator.

    ``init``     () -> device carry pytree (zeros)
    ``update``   (carry, StepContext) -> carry; traced into the jitted step
                 once per batch.  Cross-shard reductions must go through
                 ``ctx.psum``/``ctx.pmax``/``ctx.at_last``.
    ``finalize`` (host carry pytree, num_instructions) -> {metric: value};
                 runs on host after the single end-of-trace sync, and may
                 emit several named result metrics.  Values are floats for
                 scalars or ndarrays for curves (windowed specs emit their
                 ``(num_chunks,)`` phase curve).
    """

    name: str
    init: Callable[[], Any]
    update: Callable[[Any, "StepContext"], Any]
    finalize: Callable[[Any, int], Dict[str, Any]]
    # windowed (phase-curve) specs declare their carry length here so the
    # engine can enforce the int32 chunk-index envelope
    # (num_windows * num_chunks < 2^31) before streaming a trace
    num_chunks: Optional[int] = None


# ---------------------------------------------------------------------------
# Built-in specs (bit-for-bit the legacy carry)
# ---------------------------------------------------------------------------


def _cpi_init():
    # fetch_sum carries the only float rounding; the instruction count is
    # computed host-side from the window grid.
    return {
        "fetch_sum": jnp.zeros((), jnp.float32),
        "last_exec": jnp.zeros((), jnp.float32),
    }


# tao: hot
def _cpi_update(carry, ctx: StepContext):
    part = ctx.psum((ctx.fetch_lat * ctx.valid).sum(dtype=jnp.float32))
    return {
        "fetch_sum": carry["fetch_sum"] + part,
        # retire-clock formulation: total cycles end at the last valid
        # instruction's exec latency, so track it across batches
        "last_exec": jnp.where(
            ctx.last_key >= 0, ctx.at_last(ctx.exec_lat), carry["last_exec"]
        ),
    }


# tao: cold
def _cpi_finalize(carry, n: int) -> Dict[str, float]:
    total = float(carry["fetch_sum"] + carry["last_exec"])
    return {"cpi": total / max(n, 1), "total_cycles": total}


CPI = MetricSpec("cpi", _cpi_init, _cpi_update, _cpi_finalize)


def _int_count_init():
    # exact int32 counts (good to 2^31 instructions per trace)
    return jnp.zeros((), jnp.int32)


# tao: hot
def _branch_update(carry, ctx: StepContext):
    return carry + ctx.psum(
        ((ctx.mispred_prob > 0.5) & ctx.is_branch).sum(dtype=jnp.int32)
    )


# tao: cold
def _branch_finalize(carry, n: int) -> Dict[str, float]:
    return {"branch_mpki": 1000.0 * float(carry) / max(n, 1)}


BRANCH_MPKI = MetricSpec("branch_mpki", _int_count_init, _branch_update, _branch_finalize)


# tao: hot
def _l1d_update(carry, ctx: StepContext):
    return carry + ctx.psum(
        ((ctx.dlevel >= DLEVEL_L2) & ctx.is_mem).sum(dtype=jnp.int32)
    )


# tao: cold
def _l1d_finalize(carry, n: int) -> Dict[str, float]:
    return {"l1d_mpki": 1000.0 * float(carry) / max(n, 1)}


L1D_MPKI = MetricSpec("l1d_mpki", _int_count_init, _l1d_update, _l1d_finalize)


# A registered non-default plug-in: predicted data-access-level histogram
# over memory ops (cache-level composition, Fig. 11-style breakdowns).
def _dlevel_hist_init():
    return jnp.zeros((NUM_DLEVELS,), jnp.int32)


# tao: hot
def _dlevel_hist_update(carry, ctx: StepContext):
    onehot = jax.nn.one_hot(ctx.dlevel, NUM_DLEVELS, dtype=jnp.int32)
    return carry + ctx.psum(
        (onehot * ctx.is_mem[:, None].astype(jnp.int32)).sum(axis=0)
    )


_DLEVEL_NAMES = ("none", "l1", "l2", "dram")


# tao: cold
def _dlevel_hist_finalize(carry, n: int) -> Dict[str, float]:
    return {
        f"dlevel_{_DLEVEL_NAMES[i]}": float(carry[i]) for i in range(NUM_DLEVELS)
    }


DLEVEL_HIST = MetricSpec(
    "dlevel_hist", _dlevel_hist_init, _dlevel_hist_update, _dlevel_hist_finalize
)


# ---------------------------------------------------------------------------
# Windowed (phase-curve) specs: a declared (num_chunks,) device carry
# ---------------------------------------------------------------------------

# Fig. 11's curves resolve fine at this granularity; authors pick their own
DEFAULT_PHASE_CHUNKS = 32


def windowed_spec(
    name: str,
    value: Callable[["StepContext"], Any],
    *,
    num_chunks: int = DEFAULT_PHASE_CHUNKS,
    count: Optional[Callable[["StepContext"], Any]] = None,
) -> MetricSpec:
    """A phase-curve MetricSpec: mean of ``value(ctx)`` per trace phase.

    ``value`` returns per-position contributions (``(B_local*W,)``, valid
    positions only are counted — the factory masks with ``ctx.valid``).
    ``count`` picks the denominator population per position (a bool mask;
    default all valid instructions) — e.g. ``count=lambda ctx:
    ctx.is_mem`` makes the curve a rate over memory ops rather than over
    all instructions.  The carry is ``{"sum": (num_chunks,) f32,
    "count": (num_chunks,) i32}`` — fixed shape, device-resident,
    ``psum``-combined across shards — and ``finalize`` emits ``{name:
    (num_chunks,) float32 ndarray}`` (phases with an empty population
    divide by a clamped count of 1, i.e. report 0).  Counts are exact
    int32 under every plan; sums are float32 partial sums (same
    accumulation discipline as the built-in ``cpi`` spec).
    """
    if num_chunks < 1:
        raise ValueError(f"num_chunks must be >= 1, got {num_chunks}")

    def init():
        return {
            "sum": jnp.zeros((num_chunks,), jnp.float32),
            "count": jnp.zeros((num_chunks,), jnp.int32),
        }

    # tao: hot
    def update(carry, ctx: "StepContext"):
        vals = value(ctx).astype(jnp.float32) * ctx.valid
        pop = ctx.on if count is None else count(ctx)
        return {
            "sum": carry["sum"] + ctx.windowed_sum(vals, num_chunks),
            "count": carry["count"]
            + ctx.windowed_sum(pop.astype(jnp.int32), num_chunks),
        }

    # tao: cold
    def finalize(carry, n: int) -> Dict[str, Any]:
        cnt = np.asarray(carry["count"], dtype=np.int64)
        curve = np.asarray(carry["sum"], dtype=np.float32) / np.maximum(cnt, 1)
        return {name: curve.astype(np.float32)}

    return MetricSpec(name, init, update, finalize, num_chunks=num_chunks)


# Fig. 11-style phase curves: per-phase CPI (mean fetch cycles per
# instruction) and per-phase L1D miss rate over memory ops (count=is_mem
# picks the denominator population).  Registered, not default — request
# them via EngineConfig.metrics / simulate(metrics=...).
CPI_PHASE = windowed_spec("cpi_phase", lambda ctx: ctx.fetch_lat)
L1D_PHASE = windowed_spec(
    "l1d_phase",
    lambda ctx: ((ctx.dlevel >= DLEVEL_L2) & ctx.is_mem).astype(jnp.float32),
    count=lambda ctx: ctx.is_mem,
)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

METRIC_REGISTRY: Dict[str, MetricSpec] = {}

# the legacy carry's metric set — what EngineConfig requests by default
DEFAULT_METRICS: Tuple[str, ...] = ("cpi", "branch_mpki", "l1d_mpki")


def register_metric(spec: MetricSpec, *, overwrite: bool = False) -> MetricSpec:
    if not overwrite and spec.name in METRIC_REGISTRY:
        raise ValueError(
            f"metric {spec.name!r} already registered "
            f"(pass overwrite=True to replace it)"
        )
    METRIC_REGISTRY[spec.name] = spec
    return spec


for _spec in (CPI, BRANCH_MPKI, L1D_MPKI, DLEVEL_HIST, CPI_PHASE, L1D_PHASE):
    register_metric(_spec)


def resolve_metrics(
    metrics: Tuple[Union[str, MetricSpec], ...],
) -> Tuple[MetricSpec, ...]:
    """Names -> registry lookup; MetricSpec instances pass through."""
    specs = []
    seen = set()
    for m in metrics:
        spec = m
        if isinstance(m, str):
            spec = METRIC_REGISTRY.get(m)
            if spec is None:
                raise KeyError(
                    f"unknown metric {m!r}; registered: "
                    f"{sorted(METRIC_REGISTRY)} (register_metric() adds more)"
                )
        elif not isinstance(m, MetricSpec):
            raise TypeError(f"metrics entries must be str or MetricSpec, got {m!r}")
        if spec.name in seen:
            raise ValueError(f"duplicate metric {spec.name!r}")
        seen.add(spec.name)
        specs.append(spec)
    if not specs:
        raise ValueError("at least one metric is required")
    return tuple(specs)
