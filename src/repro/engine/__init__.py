"""Streaming simulation engine: the device-resident §4.2 inference path.

See ``docs/engine.md`` for the data-flow architecture and
``benchmarks/bench_timing.py`` for the measured speedup over the legacy
host-loop path (``repro.core.simulate.simulate_trace_legacy``).  Metric
accumulators are pluggable (``engine.metrics``); multi-trace DSE sweeps
run through the async scheduler (``engine.scheduler``).
"""
from .metrics import (
    DEFAULT_METRICS,
    DEFAULT_PHASE_CHUNKS,
    METRIC_REGISTRY,
    MetricSpec,
    StepContext,
    register_metric,
    resolve_metrics,
    windowed_spec,
)
from .aot import (
    enable_persistent_cache,
    persistent_cache_status,
    xla_cache_counters,
)
from .plan import AxisContext, ExecutionPlan
from .runner import (
    FEATURE_BACKENDS,
    PER_INSTRUCTION_KEYS,
    PRECISIONS,
    EngineConfig,
    MetricNotCollectedError,
    MetricNotComputedError,
    SimulationResult,
    StreamingEngine,
    cache_stats,
    clear_step_cache,
    simulate_trace_engine,
)
from .scheduler import SweepJob, SweepReport, TraceSweeper, sweep_traces

__all__ = [
    "AxisContext",
    "ExecutionPlan",
    "cache_stats",
    "clear_step_cache",
    "enable_persistent_cache",
    "persistent_cache_status",
    "xla_cache_counters",
    "EngineConfig",
    "FEATURE_BACKENDS",
    "PER_INSTRUCTION_KEYS",
    "PRECISIONS",
    "DEFAULT_METRICS",
    "DEFAULT_PHASE_CHUNKS",
    "METRIC_REGISTRY",
    "MetricSpec",
    "StepContext",
    "register_metric",
    "resolve_metrics",
    "windowed_spec",
    "MetricNotCollectedError",
    "MetricNotComputedError",
    "SimulationResult",
    "StreamingEngine",
    "simulate_trace_engine",
    "SweepJob",
    "SweepReport",
    "TraceSweeper",
    "sweep_traces",
]
