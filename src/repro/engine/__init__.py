"""Streaming simulation engine: the device-resident §4.2 inference path.

See ``docs/engine.md`` for the data-flow architecture and
``benchmarks/bench_timing.py`` for the measured speedup over the legacy
host-loop path (``repro.core.simulate.simulate_trace_legacy``).
"""
from .runner import (
    FEATURE_BACKENDS,
    EngineConfig,
    SimulationResult,
    StreamingEngine,
    simulate_trace_engine,
)

__all__ = [
    "EngineConfig",
    "FEATURE_BACKENDS",
    "SimulationResult",
    "StreamingEngine",
    "simulate_trace_engine",
]
