"""Device-resident streaming simulation engine.

The fast path behind §4.2 inference: a functional trace flows through

  vectorized features  ->  zero-copy window views  ->  fixed-shape padded
  batches (+ validity mask)  ->  one jitted forward/accumulate step  ->
  device-resident metric accumulators (``MetricSpec`` registry).

Design points (each measured by ``benchmarks/bench_timing.py``):

  * **One compilation.**  Every batch has shape (batch_size, W); the ragged
    final batch is zero-padded and masked instead of retraced, so the whole
    run — and every later trace with the same effective window — reuses a
    single executable.
  * **On-device accumulation.**  The step folds each batch into the carry
    pytrees declared by the requested ``MetricSpec``s (``engine.metrics``):
    CPI / branch-MPKI / L1D-MPKI by default, anything plug-in code
    registers otherwise.  The instruction count comes from the window grid
    on host, and per-instruction arrays are only transferred when
    ``EngineConfig.collect`` asks for them.
  * **Prefetch.**  The next batch's host->device transfer is enqueued before
    the current result is consumed, overlapping copy with compute.
  * **Partitioning.**  Every placement/wrapping/index-mapping decision is
    owned by an ``ExecutionPlan`` (``engine/plan.py``), resolved once per
    ``EngineConfig`` from its ``plan=`` or ``mesh=``: the single-device
    plan is a no-op wrapper, a sharded plan runs the step under
    ``shard_map`` with the batch dimension split over the plan's batch
    axes, and specs reduce across shards through
    ``StepContext.psum``/``pmax``.  The plan is part of the step-cache
    key, so the one-compile guarantee holds per (geometry, plan).
  * **Feature backends.**  ``feature_backend="pallas"`` replaces the host
    NumPy feature pre-pass with the device scan kernels in
    ``kernels/features/``: raw trace columns are shipped once, features are
    extracted on device, and batches become device-side slices
    (bit-identical to the NumPy path; see docs/engine.md).
    ``feature_backend="fused"`` goes further: one megakernel launch per
    batch (``kernels/fused/``) produces the model inputs directly from the
    raw columns with the scan state carried across batches — features only
    ever exist at batch granularity, never as an O(trace) FeatureSet in
    HBM.  Still bit-identical; all three backends share the step cache.
  * **Precision.**  ``precision="int8"`` swaps the step's forward for the
    W8A8 quantized twin (``core/quant.py``): per-channel int8 weights +
    dynamic per-row int8 activations with int32 accumulation.  The
    quantized tree is computed once per engine (or injected pre-quantized
    via ``qparams=`` — the ArtifactStore / registry path) and the choice
    is part of the step-cache key.

``repro.api.Session`` / ``TrainedModel.simulate`` are the supported entry
points; ``core.simulate.simulate_trace`` survives as a deprecation shim and
the original host-loop implementation as ``simulate_trace_legacy``, which
the test suite holds the engine to.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Dict, Iterator, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from ..compat import Mesh, PartitionSpec as P
from ..core.dataset import INPUT_KEYS, num_windows, stream_batches
from ..core.features import FeatureSet, extract_features
from ..core.model import TaoConfig, tao_forward
from ..core.quant import quantize_tao_params, tao_forward_int8
from ..uarch.isa import NUM_REGS
from ..resilience.faults import fault_point
from .aot import abstract_like, compile_bytes_estimate
from .metrics import DEFAULT_METRICS, MetricSpec, StepContext, resolve_metrics
from .plan import ExecutionPlan

# NOTE: repro.kernels.features.ops / repro.kernels.fused.ops are imported
# lazily inside simulate(); a module-level import would close an import
# cycle (kernels.*.ops -> repro.core package init -> core.simulate ->
# engine.runner) and crash any consumer whose first repro import is the
# ops module.

__all__ = [
    "EngineConfig",
    "FEATURE_BACKENDS",
    "PRECISIONS",
    "PER_INSTRUCTION_KEYS",
    "MetricNotCollectedError",
    "MetricNotComputedError",
    "SimulationResult",
    "StreamingEngine",
    "cache_stats",
    "clear_step_cache",
    "prefetch_to_device",
    "simulate_trace_engine",
]


# ---------------------------------------------------------------------------
# Host→device prefetch, shared by the simulation engine and the streaming
# training pipeline (core/transfer.py).
# ---------------------------------------------------------------------------

_PREFETCH_STOP = object()


def _threaded_prefetch(host_batches, put, depth: int) -> Iterator:
    q: "queue.Queue" = queue.Queue(maxsize=depth)
    stop = threading.Event()
    error: list = []

    def produce():
        try:
            for b in host_batches:
                dev = put(b)
                while not stop.is_set():
                    try:
                        q.put(dev, timeout=0.1)
                        break
                    except queue.Full:
                        continue
                if stop.is_set():
                    return
        except BaseException as e:  # re-raised in the consumer
            error.append(e)
        finally:
            while not stop.is_set():
                try:
                    q.put(_PREFETCH_STOP, timeout=0.1)
                    break
                except queue.Full:
                    continue

    producer = threading.Thread(
        target=produce, name="batch-prefetch", daemon=True
    )
    producer.start()
    try:
        while True:
            item = q.get()
            if item is _PREFETCH_STOP:
                break
            yield item
    finally:
        # normal exhaustion, consumer error, or an abandoned generator:
        # unpark the producer and drop prepared-but-unconsumed batches
        stop.set()
        while True:
            try:
                q.get_nowait()
            except queue.Empty:
                break
        producer.join()
        if error:
            raise error[0]


def prefetch_to_device(
    host_batches: Iterator,
    device_put=None,
    *,
    threaded: Optional[bool] = None,
    depth: int = 2,
) -> Iterator:
    """Double-buffered host→device prefetch over a batch iterator.

    Two modes, following the sweep scheduler's measured policy
    (``engine/scheduler.py``):

    * **inline** (CPU default): batch i+1's transfer is enqueued before
      batch i is yielded — copy overlaps compute with zero thread overhead.
      On a CPU-only backend a producer thread would contend with the
      consumer's own compute for the same cores.
    * **threaded** (accelerator default): a daemon producer thread pushes
      transfers into a bounded queue ``depth`` deep, so the *host-side*
      work of producing batch i+1 (window gather, padding) also overlaps
      device execution of batch i.

    ``depth`` only shapes the threaded queue; inline mode is inherently
    one-ahead (depth 1) — a deeper inline buffer would just hold more
    host batches alive without adding overlap, since the consumer and
    producer share one thread.

    Producer errors re-raise in the consumer; abandoning the generator
    (``close()`` / early break) stops the producer thread.
    """
    if depth < 1:
        raise ValueError(f"prefetch depth must be >= 1, got {depth}")
    put = device_put if device_put is not None else jax.device_put
    if threaded is None:
        threaded = jax.default_backend() != "cpu"
    if threaded:
        return _threaded_prefetch(host_batches, put, depth)

    def inline():
        it = iter(host_batches)
        try:
            cur = put(next(it))
        except StopIteration:
            return
        for nxt in it:
            nxt_dev = put(nxt)
            yield cur
            cur = nxt_dev
        yield cur

    return inline()


FEATURE_BACKENDS = ("numpy", "pallas", "fused")

PRECISIONS = ("fp32", "int8")

# per-instruction prediction arrays the step can emit under collect=True
PER_INSTRUCTION_KEYS = ("fetch_lat", "exec_lat", "mispred_prob", "dlevel")

# SimulationResult instance attributes that would shadow a same-named
# metric (instance dict wins over __getattr__)
_RESERVED_RESULT_ATTRS = frozenset(
    ("num_instructions", "seconds", "mips", "metrics")
)

# reserved carry slot threading the trace's window grid (running window
# offset + total windows) through the step for windowed MetricSpecs
_GRID_KEY = "__grid__"


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    batch_size: int = 64
    collect: bool = False        # also return per-instruction predictions
    prefetch: bool = True        # overlap host->device copy with compute
    # Partitioning: pass a resolved ExecutionPlan, or just a mesh and the
    # engine resolves one (both None -> the single-device plan).
    mesh: Optional[Mesh] = None
    plan: Optional[ExecutionPlan] = None
    # "numpy": host NumPy pre-pass + per-batch host->device transfers.
    # "pallas": staged device extraction — the trace's int32/bool columns
    # are shipped once, the Pallas scan kernels compute brhist/memdist on
    # device, and batches are device-side slices of the materialized
    # feature arrays (bit-identical to the NumPy path; falls back to it
    # when addresses exceed the int32-exact window).
    # "fused": one megakernel launch per batch (kernels/fused/) produces
    # the model inputs straight from the raw columns, scan state carried
    # across batches — no O(trace) feature materialization (bit-identical;
    # same NumPy fallback).
    feature_backend: str = "numpy"
    feature_chunk: int = 512     # Pallas scan grid chunk (trace positions)
    # "fp32": exact float path.  "int8": W8A8 quantized forward — per-
    # channel int8 weights + dynamic per-row int8 activations, int32
    # accumulation (core/quant.py; gated on accuracy parity by
    # bench_accuracy).
    precision: str = "fp32"
    # device-side accumulators composed into the jitted step: registry names
    # or MetricSpec instances (see engine.metrics / docs/api.md)
    metrics: Tuple[Union[str, MetricSpec], ...] = DEFAULT_METRICS


class MetricNotCollectedError(AttributeError):
    """A per-instruction array was requested but the engine kept metrics on
    device (``EngineConfig.collect=False``)."""


class MetricNotComputedError(AttributeError):
    """A scalar metric was requested whose ``MetricSpec`` was not part of
    the simulation's ``EngineConfig.metrics``."""


class SimulationResult:
    """Aggregated metrics of one simulated trace.

    Scalar metrics (whatever the run's ``MetricSpec``s finalized — ``cpi``,
    ``total_cycles``, ``branch_mpki``, ``l1d_mpki`` with the default set)
    are attributes and live in ``.metrics``; per-instruction prediction
    arrays (``fetch_lat``, ``exec_lat``, ``mispred_prob``, ``dlevel``) are
    attributes only when the run collected them.  ``available_metrics``
    lists everything present; accessing an uncollected array raises
    ``MetricNotCollectedError`` and a metric that was never computed raises
    ``MetricNotComputedError`` (both are ``AttributeError`` subclasses).
    """

    def __init__(
        self,
        num_instructions: int,
        seconds: float,
        mips: float,
        metrics: Optional[Dict[str, float]] = None,
        arrays: Optional[Dict[str, Optional[np.ndarray]]] = None,
        **legacy,
    ):
        self.num_instructions = num_instructions
        self.seconds = seconds
        self.mips = mips
        self.metrics: Dict[str, float] = dict(metrics or {})
        self._arrays: Dict[str, Optional[np.ndarray]] = (
            dict(arrays)
            if arrays is not None
            else {k: None for k in PER_INSTRUCTION_KEYS}
        )
        # pre-facade keyword layout (cpi=..., fetch_lat=..., ...)
        for k, v in legacy.items():
            if k in PER_INSTRUCTION_KEYS:
                self._arrays[k] = v
            else:
                self.metrics[k] = v

    @property
    def available_metrics(self) -> Tuple[str, ...]:
        """Scalar metric names plus whichever per-instruction arrays were
        actually collected."""
        return tuple(self.metrics) + tuple(
            k for k, v in self._arrays.items() if v is not None
        )

    def __getattr__(self, name: str):
        if name.startswith("_"):
            raise AttributeError(name)
        d = self.__dict__
        metrics = d.get("metrics", {})
        if name in metrics:
            return metrics[name]
        arrays = d.get("_arrays", {})
        if name in arrays:
            v = arrays[name]
            if v is None:
                raise MetricNotCollectedError(
                    f"per-instruction array {name!r} was not collected "
                    f"(metrics stayed on device): simulate with collect=True "
                    f"(EngineConfig.collect). available_metrics="
                    f"{self.available_metrics}"
                )
            return v
        raise MetricNotComputedError(
            f"metric {name!r} was not computed by this simulation; "
            f"available_metrics={self.available_metrics} (request its "
            f"MetricSpec via EngineConfig.metrics / simulate(metrics=...))"
        )

    def error_vs(self, truth_cpi: float) -> float:
        return abs(self.cpi - truth_cpi) / truth_cpi * 100.0

    def to_dict(self, *, arrays: bool = False) -> Dict:
        """Stable JSON-clean form (the serve layer's wire contract):
        scalar metrics as floats, phase-curve metrics as lists, collected
        per-instruction arrays only under ``arrays=True`` (they are
        O(trace) large)."""
        out = {
            "num_instructions": int(self.num_instructions),
            "seconds": float(self.seconds),
            "mips": float(self.mips),
            "metrics": {
                k: (np.asarray(v).tolist() if isinstance(v, np.ndarray) else float(v))
                for k, v in self.metrics.items()
            },
            "available_metrics": list(self.available_metrics),
        }
        if arrays:
            out["arrays"] = {
                k: np.asarray(v).tolist()
                for k, v in self._arrays.items()
                if v is not None
            }
        return out

    def __repr__(self) -> str:
        scalars = ", ".join(
            f"{k}=curve{v.shape}" if isinstance(v, np.ndarray) else f"{k}={v:.4g}"
            for k, v in self.metrics.items()
        )
        collected = [k for k, v in self._arrays.items() if v is not None]
        return (
            f"SimulationResult(n={self.num_instructions}, {scalars}, "
            f"mips={self.mips:.4g}, collected={collected})"
        )


class _CachedStep:
    """A jitted step shared across engines with identical (cfg, ecfg):
    params are an argument, so design-space sweeps that train many models
    of the same shape reuse one executable.

    ``aot`` holds the ahead-of-time compiled executable once
    ``StreamingEngine.warmup`` has lowered the geometry (single-device
    plans only — a sharded call site infers shardings from its concrete
    arguments); engines dispatch ``aot or fn``.  ``est_bytes`` is the
    retained-bytes estimate ``cache_stats`` aggregates, known only for
    AOT-compiled entries.
    """

    __slots__ = ("fn", "compiles", "aot", "est_bytes")

    def __init__(self):
        self.fn = None
        self.compiles = 0
        self.aot = None
        self.est_bytes = None

    def __call__(self, params, carry, batch):
        # direct drivers (tests, custom loops) call the entry like the old
        # bare jitted step; always through ``fn`` — an AOT executable pins
        # input layouts (committed device params), which arbitrary callers
        # don't guarantee.  Engines pick ``aot`` themselves in simulate().
        return self.fn(params, carry, batch)


_STEP_CACHE: Dict[tuple, _CachedStep] = {}

# entry-reuse counters behind cache_stats(): a hit means an engine needed a
# step and an already-built entry (its own or the process cache's) served
# it; a miss means a new jitted step was constructed
_STEP_STATS: Dict[str, int] = {"hits": 0, "misses": 0}


def cache_stats() -> Dict[str, int]:
    """Inspect the process-wide step cache: entry count, hit/miss
    counters, trace-time compiles, and estimated retained executable bytes
    (measured for AOT-warmed entries; ``entries_unmeasured`` counts
    lazily-jitted entries whose executables the estimate cannot see)."""
    measured = [e.est_bytes for e in _STEP_CACHE.values() if e.est_bytes]
    return {
        "entries": len(_STEP_CACHE),
        "hits": _STEP_STATS["hits"],
        "misses": _STEP_STATS["misses"],
        "compiles": sum(e.compiles for e in _STEP_CACHE.values()),
        "aot_compiled": sum(1 for e in _STEP_CACHE.values() if e.aot is not None),
        "retained_bytes_est": sum(measured),
        "entries_unmeasured": sum(
            1 for e in _STEP_CACHE.values() if not e.est_bytes
        ),
    }


def clear_step_cache() -> int:
    """Drop every cached step (returns how many were dropped).  Engines
    already holding an entry keep it alive until they are collected; new
    engines re-build.  Hit/miss counters keep accumulating — snapshot
    ``cache_stats()`` around a region to attribute its traffic."""
    n = len(_STEP_CACHE)
    _STEP_CACHE.clear()
    return n


class StreamingEngine:
    """Compile once, stream any number of traces.

    An engine instance owns the jitted step for a (params-structure,
    TaoConfig, EngineConfig) triple; ``num_compiles`` counts actual traces
    of the step function, which the test suite pins to one per effective
    window length regardless of trace/batch geometry.
    """

    def __init__(
        self,
        params: Dict,
        cfg: TaoConfig,
        ecfg: EngineConfig = EngineConfig(),
        *,
        qparams: Optional[Dict] = None,
    ):
        if ecfg.batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {ecfg.batch_size}")
        if ecfg.feature_backend not in FEATURE_BACKENDS:
            raise ValueError(
                f"feature_backend must be one of {FEATURE_BACKENDS}, "
                f"got {ecfg.feature_backend!r}"
            )
        if ecfg.precision not in PRECISIONS:
            raise ValueError(
                f"precision must be one of {PRECISIONS}, "
                f"got {ecfg.precision!r}"
            )
        if ecfg.feature_chunk < 1:
            raise ValueError(
                f"feature_chunk must be >= 1, got {ecfg.feature_chunk}"
            )
        self._specs: Tuple[MetricSpec, ...] = resolve_metrics(ecfg.metrics)
        for s in self._specs:
            if s.name == _GRID_KEY:
                raise ValueError(
                    f"metric name {_GRID_KEY!r} is reserved for the "
                    "engine's window-grid carry"
                )
        # one partitioning decision for everything this engine does:
        # placement, shard_map wrapping, index mapping, reductions
        self.plan = ExecutionPlan.resolve(
            ecfg.mesh, batch_size=ecfg.batch_size, plan=ecfg.plan
        )
        self.plan.validate_batch(ecfg.batch_size)
        self.params = params
        self.cfg = cfg
        self.ecfg = ecfg
        # pre-quantized int8 tree (registry/store path); lazily computed
        # from the fp32 params otherwise when precision="int8"
        self._qparams = qparams
        self._steps: Dict[int, _CachedStep] = {}  # effective window -> step

    @property
    def num_compiles(self) -> int:
        """Traces of the step function across every step this engine used
        (shared with other engines of identical config — at most one per
        effective window and params structure either way)."""
        return sum(e.compiles for e in self._steps.values())

    # ---- jitted step ---------------------------------------------------

    # tao: step-builder[engine-step] ignore=entry
    def _build_step(self, w_eff: int, entry: _CachedStep):
        cfg = self.cfg
        collect = self.ecfg.collect
        plan = self.plan
        actx = plan.axis_context()
        specs = self._specs
        bsz_global = self.ecfg.batch_size
        # trace-time branch: the fp32 forward or its W8A8 quantized twin
        # (the choice is baked into the executable, hence the cache key)
        forward = tao_forward if self.ecfg.precision == "fp32" else tao_forward_int8

        def body(params, carry, batch):
            entry.compiles += 1  # runs at trace time only
            valid = batch["valid"].reshape(-1)
            out = forward(params, {k: batch[k] for k in INPUT_KEYS}, cfg)
            fetch = jnp.maximum(out["fetch_lat"], 0.0).reshape(-1)
            execl = jnp.maximum(out["exec_lat"], 0.0).reshape(-1)
            misp = jax.nn.sigmoid(out["mispred_logit"]).reshape(-1)
            dlev = jnp.argmax(out["dlevel_logits"], -1).astype(jnp.int32).reshape(-1)
            on = valid > 0
            br = batch["is_branch"].reshape(-1) & on
            mem = batch["is_mem"].reshape(-1) & on

            n_local = valid.shape[0]
            shard = actx.shard_index()  # 0 on the single-device plan
            gidx = (shard * n_local + jnp.arange(n_local)).astype(jnp.float32)
            # key of the globally-last valid position (-1 when none local)
            last_key = actx.pmax(jnp.max(jnp.where(on, gidx, -1.0)))

            # trace-global window index of each local row, from the grid
            # carry (windowed specs scatter phase contributions with it)
            grid = carry[_GRID_KEY]
            b_local = batch["valid"].shape[0]
            win_index = (
                grid["seen"]
                + shard * b_local
                + jnp.arange(b_local, dtype=jnp.int32)
            )

            ctx = StepContext(
                valid=valid,
                on=on,
                is_branch=br,
                is_mem=mem,
                fetch_lat=fetch,
                exec_lat=execl,
                mispred_prob=misp,
                dlevel=dlev,
                gidx=gidx,
                last_key=last_key,
                psum=actx.psum,
                pmax=actx.pmax,
                sharded=plan.sharded,
                batch=batch,
                window=w_eff,
                win_index=win_index,
                num_windows=grid["total"],
            )
            new_carry = {s.name: s.update(carry[s.name], ctx) for s in specs}
            new_carry[_GRID_KEY] = {
                "seen": grid["seen"] + jnp.int32(bsz_global),
                "total": grid["total"],
            }
            if collect:
                per = {
                    "fetch_lat": fetch,
                    "exec_lat": execl,
                    "mispred_prob": misp,
                    "dlevel": dlev,
                }
            else:
                per = {}
            return new_carry, per

        if not plan.sharded:
            return jax.jit(body)

        batched = plan.batch_spec()
        batch_specs = {
            k: batched for k in INPUT_KEYS + ("valid", "is_branch", "is_mem")
        }
        per_specs = (
            {k: batched for k in PER_INSTRUCTION_KEYS} if collect else {}
        )
        mapped = plan.wrap(
            body,
            in_specs=(P(), P(), batch_specs),
            out_specs=(P(), per_specs),
        )
        return jax.jit(mapped)

    def _get_step(self, w_eff: int) -> _CachedStep:
        entry = self._steps.get(w_eff)
        if entry is None:
            # Keyed on exactly what the compiled step depends on — notably
            # NOT prefetch or feature_backend, so "numpy", "pallas", and
            # "fused" engines of the same shape share one executable
            # (precision IS keyed: int8 bakes a different forward).  The
            # resolved plan (not the raw mesh) is the partitioning key, so
            # EngineConfig(mesh=m) and EngineConfig(plan=resolve(m)) also
            # share one.
            key = (  # tao: step-key[engine-step]
                self.cfg,
                self.ecfg.batch_size,
                self.ecfg.collect,
                self.ecfg.precision,
                self.plan,
                self._specs,
                w_eff,
            )
            entry = _STEP_CACHE.get(key)
            if entry is None:
                fault_point("engine.compile", payload=f"w{w_eff}")
                _STEP_STATS["misses"] += 1
                entry = _CachedStep()
                entry.fn = self._build_step(w_eff, entry)
                _STEP_CACHE[key] = entry
            else:
                _STEP_STATS["hits"] += 1
            self._steps[w_eff] = entry
        else:
            _STEP_STATS["hits"] += 1
        return entry

    def init_carry(self, n: int) -> Dict:
        """The initial carry for a trace of ``n`` instructions: every
        requested spec's ``init()`` plus the engine's reserved window-grid
        slot (running window offset + total windows — what windowed specs
        scatter phase contributions with).  Code driving the jitted step
        directly (custom batch columns via ``stream_batches(extra=...)``)
        must start from this, not a hand-built spec dict."""
        if n < 1:
            raise ValueError("cannot simulate an empty trace")
        nw = num_windows(n, self.cfg.window, self.cfg.window)
        for s in self._specs:
            # chunk_of's bucket math (win_index * num_chunks) is int32;
            # refuse traces that would silently wrap into bucket 0
            if s.num_chunks is not None and nw * s.num_chunks > 2**31 - 1:
                raise ValueError(
                    f"windowed spec {s.name!r}: num_windows ({nw}) * "
                    f"num_chunks ({s.num_chunks}) exceeds the int32 "
                    "chunk-index envelope; reduce num_chunks or split "
                    "the trace"
                )
        carry = {s.name: s.init() for s in self._specs}
        carry[_GRID_KEY] = {
            "seen": jnp.zeros((), jnp.int32),
            "total": jnp.asarray(nw, jnp.int32),
        }
        return carry

    def step_entry_for(self, n: int) -> _CachedStep:
        """The cached step entry ``simulate`` will use for a trace of
        length ``n`` (created lazily; its ``compiles`` counter lets callers
        like the sweep scheduler attribute compilations precisely)."""
        if n < 1:
            raise ValueError("cannot simulate an empty trace")
        w_eff = min(self.cfg.window, n)
        return self._get_step(w_eff)

    # ---- ahead-of-time compilation --------------------------------------

    def _abstract_batch(self, w_eff: int) -> Dict:
        """ShapeDtypeStructs of one step batch — the exact shapes/dtypes
        ``stream_batches`` (and the device-side pallas slicer, which is
        bit-compatible) produces for this engine's geometry."""
        b = self.ecfg.batch_size
        f = self.cfg.features
        sds = jax.ShapeDtypeStruct
        return {
            "opcode": sds((b, w_eff), jnp.int32),
            "regbits": sds((b, w_eff, NUM_REGS), jnp.float32),
            "flags": sds((b, w_eff, f.flags_dim), jnp.float32),
            "brhist": sds((b, w_eff, f.n_queue), jnp.float32),
            "memdist": sds((b, w_eff, f.n_mem), jnp.float32),
            "valid": sds((b, w_eff), jnp.float32),
            "is_branch": sds((b, w_eff), jnp.bool_),
            "is_mem": sds((b, w_eff), jnp.bool_),
        }

    def warmup(self, n: int) -> _CachedStep:
        """Compile the step for traces of length ``n`` ahead of time.

        Lowers from abstract (ShapeDtypeStruct) params and batch — so the
        engine may hold abstract params from ``jax.eval_shape`` — and
        compiles through the XLA client, populating the persistent
        compilation cache when ``engine.aot.enable_persistent_cache`` has
        pointed one at disk.  On a single-device, single-process plan the
        compiled executable is pinned on the entry and dispatched directly
        by ``simulate`` (zero retrace, zero dispatch-time lowering); on
        sharded plans the entry still gets built and traced (the warm
        persistent cache then serves the sharded call's own compile), but
        dispatch stays with the jitted function, which owns the
        shard-placement inference.  Idempotent per geometry.
        """
        entry = self.step_entry_for(n)
        if entry.aot is not None:
            return entry
        if self.plan.sharded or jax.process_count() > 1:
            return entry
        w_eff = min(self.cfg.window, n)
        lowered = entry.fn.lower(
            abstract_like(self._run_params()),
            abstract_like(self.init_carry(n)),
            self._abstract_batch(w_eff),
        )
        compiled = lowered.compile()
        entry.est_bytes = compile_bytes_estimate(compiled)
        entry.aot = compiled
        return entry

    def _run_params(self):
        """The parameter tree the step actually consumes: the engine's
        fp32 tree, or (``precision="int8"``) its quantized twin — the
        injected pre-quantized ``qparams`` when the api/registry layer
        resolved one from the ArtifactStore, otherwise computed once here
        (``jax.eval_shape`` keeps abstract param trees abstract, so AOT
        warmup works either way)."""
        if self.ecfg.precision != "int8":
            return self.params
        q = self._qparams
        if q is None:
            leaves = jax.tree_util.tree_leaves(self.params)
            if any(isinstance(x, jax.ShapeDtypeStruct) for x in leaves):
                q = jax.eval_shape(quantize_tao_params, self.params)
            else:
                q = quantize_tao_params(self.params)
            self._qparams = q
        return q

    def _committed_params(self):
        """Run params as committed device arrays (what an AOT executable's
        input layout expects); transferred once per engine."""
        p = getattr(self, "_dev_params", None)
        if p is None:
            p = jax.device_put(self._run_params())
            self._dev_params = p
        return p

    # ---- streaming -----------------------------------------------------

    def _prefetched(self, host_batches: Iterator[Dict]) -> Iterator[Dict]:
        """Enqueue batch i+1's transfer before batch i is consumed (inline
        on CPU, threaded producer on accelerator backends); placement is
        the plan's."""
        return prefetch_to_device(host_batches, self.plan.device_put)

    def _device_batches(
        self, arrays: Dict, w_eff: int, count: int
    ) -> Iterator[Dict]:
        """Batch iterator over device-resident feature arrays (the "pallas"
        backend): windows are device-side reshapes (the engine grid is
        non-overlapping, stride == window), the ragged tail is zero-padded
        on device, and per-batch slicing never touches the host."""
        bsz = self.ecfg.batch_size
        nw = count // w_eff
        nb = -(-nw // bsz)
        # arrays already carries the device-resident is_branch/is_mem bool
        # columns (device_feature_arrays ships them once for the flags).
        stacked = {}
        for k, v in arrays.items():
            v = v[:count].reshape((nw, w_eff) + v.shape[1:])
            if nb * bsz > nw:
                v = jnp.pad(v, [(0, nb * bsz - nw)] + [(0, 0)] * (v.ndim - 1))
            stacked[k] = v.reshape((nb, bsz) + v.shape[1:])
        valid = np.zeros((nb * bsz, w_eff), dtype=np.float32)
        valid[:nw] = 1.0
        stacked["valid"] = jnp.asarray(valid.reshape(nb, bsz, w_eff))
        for i in range(nb):
            batch = {k: v[i] for k, v in stacked.items()}
            # arrays are already device-resident; a sharded plan still
            # needs them re-laid-out across its batch axes
            yield self.plan.device_put(batch) if self.plan.sharded else batch

    def _fused_batches(
        self, cols: Dict, w_eff: int, count: int
    ) -> Iterator[Dict]:
        """Batch iterator for the "fused" backend: the raw int32/bool
        columns ship to the device once, then every batch is ONE megakernel
        launch (``kernels/fused/``) with the scan state carried across
        batches — model inputs are produced per batch and consumed by the
        step immediately, so no O(trace) feature materialization ever
        exists.  Window/padding/validity layout is exactly
        ``_device_batches``'s (bit-identical by construction)."""
        from ..kernels.fused.ops import FusedExtractor  # lazy: module note

        bsz = self.ecfg.batch_size
        nw = count // w_eff
        nb = -(-nw // bsz)
        per = bsz * w_eff
        extractor = FusedExtractor(
            {k: v[:count] for k, v in cols.items()},
            self.cfg.features,
            chunk=self.ecfg.feature_chunk,
            pad_to=nb * per,
        )
        valid = np.zeros((nb * bsz, w_eff), dtype=np.float32)
        valid[:nw] = 1.0
        valid = jnp.asarray(valid.reshape(nb, bsz, w_eff))
        for i in range(nb):
            feats = extractor.next_batch(per)
            batch = {
                k: v.reshape((bsz, w_eff) + v.shape[1:])
                for k, v in feats.items()
            }
            batch["valid"] = valid[i]
            yield self.plan.device_put(batch) if self.plan.sharded else batch

    # tao: hot
    def simulate(
        self,
        func_trace: np.ndarray,
        features: Optional[FeatureSet] = None,
    ) -> SimulationResult:
        t0 = time.perf_counter()
        fault_point("engine.simulate")
        cfg = self.cfg
        n = len(features) if features is not None else len(func_trace)
        if n == 0:
            raise ValueError("cannot simulate an empty trace")
        w_eff = min(cfg.window, n)
        # exact instruction count from the window grid (no float rounding)
        count = num_windows(n, cfg.window, cfg.window) * w_eff
        entry = self._get_step(w_eff)
        # AOT-warmed geometry: call the compiled executable directly (no
        # dispatch-time retracing; params must be committed device arrays)
        if entry.aot is not None:
            step = entry.aot
            params = self._committed_params()
        else:
            step = entry.fn
            params = self._run_params()

        dev_arrays = None
        fused_batches = None
        fs = features
        if fs is None and self.ecfg.feature_backend in ("pallas", "fused"):
            from ..kernels.features.ops import (  # lazy: see module note
                device_feature_arrays,
                trace_columns,
            )

            cols = trace_columns(func_trace, cfg.features)
            if cols is not None:  # addresses fit the int32-exact window
                if self.ecfg.feature_backend == "fused":
                    fused_batches = self._fused_batches(cols, w_eff, count)
                else:
                    dev_arrays = device_feature_arrays(
                        cols, cfg.features, chunk=self.ecfg.feature_chunk
                    )
        if fs is None and dev_arrays is None and fused_batches is None:
            fs = extract_features(func_trace, cfg.features, with_labels=False)

        if fused_batches is not None:
            batches = fused_batches
        elif dev_arrays is not None:
            batches = self._device_batches(dev_arrays, w_eff, count)
        else:
            host_batches = stream_batches(
                fs,
                cfg.window,
                self.ecfg.batch_size,
                stride=cfg.window,
                extra={
                    "is_branch": func_trace["is_branch"],
                    "is_mem": func_trace["is_mem"],
                },
            )
            batches = (
                self._prefetched(host_batches)
                if self.ecfg.prefetch
                else (self.plan.device_put(b) for b in host_batches)
            )

        # specs' init plus the window-grid slot: running global window
        # offset + total real windows (data, not shape — every trace
        # shares the executable)
        carry = self.init_carry(n)
        pers = []
        for batch in batches:
            carry, per = step(params, carry, batch)
            if self.ecfg.collect:
                pers.append(per)

        carry = jax.device_get(carry)  # single host sync for the whole trace
        metrics: Dict[str, float] = {}
        for s in self._specs:
            out = s.finalize(carry[s.name], count)
            clash = set(out) & set(metrics)
            if clash:
                raise ValueError(
                    f"metric spec {s.name!r} finalized key(s) {sorted(clash)} "
                    "already emitted by an earlier spec in this run"
                )
            reserved = set(out) & _RESERVED_RESULT_ATTRS
            if reserved:
                raise ValueError(
                    f"metric spec {s.name!r} finalized reserved key(s) "
                    f"{sorted(reserved)}: SimulationResult instance "
                    "attributes would shadow them"
                )
            metrics.update(out)
        secs = time.perf_counter() - t0

        arrays: Dict[str, Optional[np.ndarray]] = {
            k: None for k in PER_INSTRUCTION_KEYS
        }
        if self.ecfg.collect and pers:
            # one explicit sync for every batch's arrays (was a hidden
            # np.asarray device->host pull per batch per key)
            pers = jax.device_get(pers)
            for k in arrays:
                arrays[k] = np.concatenate([p[k] for p in pers])[:count]

        return SimulationResult(
            num_instructions=count,
            seconds=secs,
            mips=count / 1e6 / secs,
            metrics=metrics,
            arrays=arrays,
        )


def simulate_trace_engine(
    params: Dict,
    func_trace: np.ndarray,
    cfg: TaoConfig,
    batch_size: int = 64,
    features: Optional[FeatureSet] = None,
    collect: bool = False,
    mesh: Optional[Mesh] = None,
    plan: Optional[ExecutionPlan] = None,
    feature_backend: str = "numpy",
    precision: str = "fp32",
    metrics: Tuple[Union[str, MetricSpec], ...] = DEFAULT_METRICS,
) -> SimulationResult:
    """One-shot convenience wrapper: build an engine, stream one trace."""
    engine = StreamingEngine(
        params,
        cfg,
        EngineConfig(
            batch_size=batch_size,
            collect=collect,
            mesh=mesh,
            plan=plan,
            feature_backend=feature_backend,
            precision=precision,
            metrics=metrics,
        ),
    )
    return engine.simulate(func_trace, features=features)
