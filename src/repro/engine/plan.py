"""ExecutionPlan: one partitioning decision, consumed everywhere.

The engine's shard_map path, the sweep scheduler, and the streaming
trainer all need the same four answers when a mesh is (or is not) in
play:

  1. *placement* — how a host batch lands on device(s)
     (``device_put``: plain transfer, batch-sharded NamedSharding, or —
     multi-host — assembly from per-process shards);
  2. *wrapping* — whether a step body runs plain or under
     ``jax.shard_map`` (``wrap``);
  3. *index mapping* — how a shard-local row index becomes a global
     batch/window index (``AxisContext.shard_index``);
  4. *reduction* — how metric/grad partial sums cross shards
     (``AxisContext.psum``/``pmax``, identity off-mesh).

Before this module those answers were re-derived ad hoc at every
``ecfg.mesh is not None`` branch in the runner (and forbidden outright in
the scheduler).  Now they resolve **once** into an ``ExecutionPlan`` —
a frozen, hashable value that participates in the step-cache key, so a
single-device plan and an 8-way plan are just two cache entries of the
same machinery, sharded sweeps are a composition (trace queue × ``data``
axis) rather than a third copy of the branching, and the one-compile-
per-geometry guarantee extends to every path.

Plans are *pure partitioning*: mesh construction and multi-host bring-up
live in ``repro.distributed`` (``data_mesh`` / ``initialize_multihost`` /
``virtual_cpu_devices``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from ..compat import Mesh, NamedSharding, PartitionSpec as P, shard_map
from ..distributed.sharding import logical_to_spec

__all__ = ["AxisContext", "ExecutionPlan"]


@dataclasses.dataclass(frozen=True)
class AxisContext:
    """The traced-side face of a plan: cross-shard reducers plus the
    shard-index expression.  Every method is safe to call inside a jitted
    (and shard_mapped) step body; off-mesh they degrade to identities.
    """

    axes: Tuple[str, ...]        # mesh axes carrying the batch dimension
    sizes: Tuple[int, ...]       # their extents (row-major index order)

    @property
    def num_shards(self) -> int:
        n = 1
        for s in self.sizes:
            n *= s
        return n

    def psum(self, x):
        """Cross-shard sum (identity when the plan is single-device)."""
        return jax.lax.psum(x, self.axes) if self.axes else x

    def pmax(self, x):
        """Cross-shard max (identity when the plan is single-device)."""
        return jax.lax.pmax(x, self.axes) if self.axes else x

    def shard_index(self):
        """This shard's row-major linear index over the batch axes, as a
        traced int32 scalar (0 when single-device)."""
        if not self.axes:
            return jnp.int32(0)
        idx = jnp.int32(0)
        for a, s in zip(self.axes, self.sizes):
            idx = idx * s + jax.lax.axis_index(a)
        return idx


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """How one step executes over (possibly) many devices.

    Resolve once per ``EngineConfig`` (or trainer invocation) via
    :meth:`resolve`; the object is hashable and equality-comparable, so
    it slots directly into step-cache keys — two engines resolving the
    same mesh share one compiled executable.
    """

    kind: str                             # "single" | "sharded"
    mesh: Optional[Mesh] = None
    batch_axes: Tuple[str, ...] = ()      # mesh axes carrying "batch"

    # ---- construction ---------------------------------------------------

    @classmethod
    def single(cls) -> "ExecutionPlan":
        """The trivial plan: one device, identity reducers."""
        return cls(kind="single")

    @classmethod
    def resolve(
        cls,
        mesh: Optional[Mesh] = None,
        *,
        batch_size: int,
        plan: Optional["ExecutionPlan"] = None,
    ) -> "ExecutionPlan":
        """The plan for an (optional) mesh and a batch size.

        ``plan`` passes through after validation (its mesh wins; passing
        a *different* mesh alongside it is an error).  Without a mesh the
        result is the single-device plan.  With one, the rules table in
        ``distributed/sharding.py`` decides which mesh axes carry the
        ``batch`` logical axis (divisibility-checked against
        ``batch_size``); a mesh with no usable batch axes is rejected.
        """
        if plan is not None:
            if mesh is not None and mesh is not plan.mesh and mesh != plan.mesh:
                raise ValueError(
                    "both plan= and a different mesh= were given; the plan "
                    "already owns its mesh — pass one or the other"
                )
            plan.validate_batch(batch_size)
            return plan
        if mesh is None:
            return cls.single()
        spec = logical_to_spec(("batch",), shape=(batch_size,), mesh=mesh)
        entry = spec[0] if len(spec) else None
        if entry is None:
            raise ValueError(
                f"cannot shard batch_size={batch_size} over mesh "
                f"{dict(mesh.shape)}: no usable 'batch' mesh axes "
                "(see distributed.sharding.LOGICAL_RULES)"
            )
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        return cls(kind="sharded", mesh=mesh, batch_axes=axes)

    @classmethod
    def auto(cls, batch_size: int) -> "ExecutionPlan":
        """Sharded over all visible devices when there are several
        (``distributed.data_mesh()``), single-device otherwise."""
        if len(jax.devices()) > 1:
            from ..distributed.multihost import data_mesh

            return cls.resolve(data_mesh(), batch_size=batch_size)
        return cls.single()

    def __post_init__(self):
        if self.kind not in ("single", "sharded"):
            raise ValueError(f"plan kind must be single|sharded, got {self.kind!r}")
        if self.kind == "sharded" and (self.mesh is None or not self.batch_axes):
            raise ValueError("a sharded plan needs a mesh and batch axes")
        if self.kind == "single" and self.mesh is not None:
            raise ValueError("a single-device plan must not carry a mesh")

    # ---- queries --------------------------------------------------------

    @property
    def sharded(self) -> bool:
        return self.kind == "sharded"

    @property
    def num_shards(self) -> int:
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n

    def validate_batch(self, batch_size: int) -> None:
        """Reject batch sizes the plan cannot split evenly (shard_map jit
        arguments do not support uneven padding)."""
        if batch_size % self.num_shards:
            raise ValueError(
                f"batch_size={batch_size} does not divide over the plan's "
                f"{self.num_shards} shards (axes {self.batch_axes} of mesh "
                f"{dict(self.mesh.shape) if self.mesh else {}})"
            )

    def local_batch(self, batch_size: int) -> int:
        """Rows of a global batch each shard sees."""
        return batch_size // self.num_shards

    # ---- the four answers ----------------------------------------------

    def batch_spec(self) -> P:
        """PartitionSpec splitting a leading batch dimension."""
        if not self.sharded:
            return P()
        return P(self.batch_axes if len(self.batch_axes) > 1 else self.batch_axes[0])

    def batch_sharding(self) -> Optional[NamedSharding]:
        if not self.sharded:
            return None
        return NamedSharding(self.mesh, self.batch_spec())

    def device_put(self, batch):
        """Place a host batch (any pytree of leading-batch-dim arrays)
        according to the plan: plain transfer single-device, batch-
        sharded NamedSharding on a mesh, per-process assembly under
        multi-host.  Every process streams the same trace, so each holds
        the full global batch and contributes only its contiguous row
        slice (``data_mesh`` orders devices by process, so process ``p``
        owns rows ``[p*B/P, (p+1)*B/P)``)."""
        if not self.sharded:
            return jax.device_put(batch)
        sh = self.batch_sharding()
        pc = jax.process_count()
        if pc > 1:
            pi = jax.process_index()

            def put(v):
                n = v.shape[0]
                if n % pc:
                    raise ValueError(
                        f"global batch of {n} rows does not split over "
                        f"{pc} processes"
                    )
                per = n // pc
                return jax.make_array_from_process_local_data(
                    sh, v[pi * per : (pi + 1) * per]
                )

            return jax.tree.map(put, batch)
        return jax.device_put(batch, sh)

    def replicate(self, tree):
        """Place a pytree fully replicated across the plan's mesh (model
        params / optimizer state for data-parallel training).  Identity
        placement on the single-device plan — jit commits as usual."""
        if not self.sharded:
            return tree
        return jax.device_put(tree, NamedSharding(self.mesh, P()))

    def wrap(self, fn, in_specs, out_specs):
        """``shard_map`` the body on a sharded plan; identity otherwise.
        Callers jit the result either way."""
        if not self.sharded:
            return fn
        return shard_map(fn, mesh=self.mesh, in_specs=in_specs, out_specs=out_specs)

    def axis_context(self) -> AxisContext:
        """The traced-side reducers/index mapping (see ``AxisContext``)."""
        if not self.sharded:
            return AxisContext(axes=(), sizes=())
        return AxisContext(
            axes=self.batch_axes,
            sizes=tuple(self.mesh.shape[a] for a in self.batch_axes),
        )

    def describe(self) -> dict:
        """JSON-friendly summary (bench artifacts, reports)."""
        return {
            "kind": self.kind,
            "num_shards": self.num_shards,
            "batch_axes": list(self.batch_axes),
            "mesh_shape": dict(self.mesh.shape) if self.mesh is not None else {},
        }

    def cache_token(self) -> tuple:
        """Serializable identity for content-addressed cache keys
        (``repro.store``): the partitioning *shape* — kind, batch axes,
        mesh axis extents — with device objects excluded, so the same
        logical plan resolved in two processes (whose ``Mesh`` objects
        can never compare equal) maps to the same key."""
        return (
            "plan",
            self.kind,
            tuple(self.batch_axes),
            tuple(sorted(dict(self.mesh.shape).items()))
            if self.mesh is not None
            else (),
        )
