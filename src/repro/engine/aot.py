"""Zero-cold-start support: JAX persistent compilation cache + AOT helpers.

The step caches in ``engine/runner.py`` and ``train/trainer.py`` make
compiles-per-*process* the invariant (one per geometry).  This module
extends that to compiles-per-*cluster*:

  * ``enable_persistent_cache(dir)`` points JAX's persistent compilation
    cache at a directory (thresholds zeroed so every executable persists,
    including the small CPU-backend steps this repro's tests run).  Any
    later ``jit`` — or AOT ``lower().compile()`` — that re-derives an
    already-cached computation deserializes the executable instead of
    invoking XLA.
  * ``xla_cache_counters()`` counts *actual* XLA compiles vs disk
    deserializations via ``jax.monitoring`` events, which is how the
    cross-process tests assert "0 XLA compiles" in a warm process — the
    step caches' own ``compiles`` counters count traces, which still
    happen once per process.
  * ``abstract_like`` / ``compile_bytes_estimate`` back the engines'
    ``warmup()`` APIs: geometry declared up front is lowered from
    ``ShapeDtypeStruct``s and compiled ahead of time, so the first real
    batch runs a ready executable.

``Session(store=...)`` (repro.api) enables the persistent cache under the
artifact store root by default, so executables and artifacts share one
warm directory.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional

import jax

from ..compat import enable_compilation_cache_flags, register_monitoring_listener

__all__ = [
    "enable_persistent_cache",
    "persistent_cache_status",
    "xla_cache_counters",
    "abstract_like",
    "compile_bytes_estimate",
]

# monitoring events jax records around every compile request (see
# jax/_src/compiler.py): a "request" consults the cache, then exactly one
# of hit (deserialized from disk) or miss (XLA ran, result persisted).
_EVT_REQUESTS = "/jax/compilation_cache/compile_requests_use_cache"
_EVT_HITS = "/jax/compilation_cache/cache_hits"
_EVT_MISSES = "/jax/compilation_cache/cache_misses"

_COUNTERS: Dict[str, int] = {"requests": 0, "hits": 0, "misses": 0}
_LISTENING = False
_ENABLED_DIR: Optional[str] = None

# enable() honours this env var when no directory is passed — how
# subprocess tests and CI point every process at one shared cache
_ENV_DIR = "REPRO_COMPILE_CACHE"


def _listener(event: str, **kwargs) -> None:
    if event == _EVT_REQUESTS:
        _COUNTERS["requests"] += 1
    elif event == _EVT_HITS:
        _COUNTERS["hits"] += 1
    elif event == _EVT_MISSES:
        _COUNTERS["misses"] += 1


def enable_persistent_cache(directory: Optional[str] = None) -> str:
    """Turn on the JAX persistent compilation cache at ``directory``
    (default: ``$REPRO_COMPILE_CACHE`` or ``~/.cache/repro/xla``) and
    start counting hit/miss events.  Idempotent; re-enabling with a
    different directory repoints the cache.  Returns the directory."""
    global _LISTENING, _ENABLED_DIR
    if directory is None:
        directory = os.environ.get(_ENV_DIR) or os.path.join(
            os.path.expanduser("~"), ".cache", "repro", "xla"
        )
    directory = os.path.abspath(os.path.expanduser(directory))
    os.makedirs(directory, exist_ok=True)
    # flag names drifted across jax 0.4.x; the compat shim zeroes the
    # persistence thresholds where they exist and degrades to a no-op on
    # builds with no persistent cache at all (callers still run, cold)
    enable_compilation_cache_flags(directory)
    if not _LISTENING:
        _LISTENING = register_monitoring_listener(_listener)
    _ENABLED_DIR = directory
    return directory


def xla_cache_counters() -> Dict[str, int]:
    """Persistent-cache traffic since ``enable_persistent_cache``:
    ``requests`` (compile requests that consulted the cache), ``hits``
    (deserialized from disk — no XLA invocation), ``misses`` (XLA actually
    compiled).  A warm process shows ``misses == 0, requests > 0``."""
    return dict(_COUNTERS)


def persistent_cache_status() -> Dict[str, Any]:
    """JSON-friendly snapshot for bench artifacts: whether the cache is
    enabled, where, how many executables it holds, and this process's
    hit/miss traffic."""
    d = getattr(jax.config, "jax_compilation_cache_dir", None)
    entries = 0
    nbytes = 0
    if d and os.path.isdir(d):
        for name in os.listdir(d):
            if name.endswith("-cache"):
                entries += 1
                try:
                    nbytes += os.path.getsize(os.path.join(d, name))
                except OSError:
                    pass
    return {
        "enabled": bool(d),
        "dir": d,
        "entries": entries,
        "bytes": nbytes,
        **xla_cache_counters(),
    }


def abstract_like(tree: Any) -> Any:
    """ShapeDtypeStruct skeleton of a pytree — what ``warmup`` lowers from
    so no concrete params/batch need exist.  ShapeDtypeStruct leaves pass
    through, so abstract trees (``jax.eval_shape`` output) are accepted
    unchanged."""
    return jax.tree.map(
        lambda x: x
        if isinstance(x, jax.ShapeDtypeStruct)
        else jax.ShapeDtypeStruct(jax.numpy.shape(x), jax.numpy.result_type(x)),
        tree,
    )


def compile_bytes_estimate(compiled) -> Optional[int]:
    """Rough retained-bytes estimate for an AOT-compiled executable
    (generated code + temp allocations); None when the backend's
    ``memory_analysis`` cannot say."""
    try:
        m = compiled.memory_analysis()
        if m is None:
            return None
        total = 0
        for attr in (
            "generated_code_size_in_bytes",
            "temp_size_in_bytes",
            "output_size_in_bytes",
        ):
            v = getattr(m, attr, None)
            if v is not None:
                total += int(v)
        return total or None
    except Exception:
        return None
