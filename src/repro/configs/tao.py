"""The paper's own model at production scale: ROB-128 context windows
(W = 129), multi-metric heads — expressed as a TaoConfig for the core and an
ArchConfig-equivalent is unnecessary (Tao trains via repro.core)."""
from ..core.features import FeatureConfig
from ..core.model import TaoConfig

CONFIG = TaoConfig(
    window=129,
    d_model=512,
    n_heads=8,
    n_layers=6,
    d_ff=2048,
    d_cat=128,
    features=FeatureConfig(n_buckets=1024, n_queue=32, n_mem=64),
)
