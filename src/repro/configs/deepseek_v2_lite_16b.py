"""DeepSeek-V2-Lite-16B [moe] — 27L d=2048 16H, MLA (kv_lora=512, rope
head 64, nope head 128, v head 128); MoE: 64 routed experts top-6 + 2 shared,
expert d_ff=1408, first layer dense; vocab=102400.  [arXiv:2405.04434; hf]

Assignment note: the inline note "2 shared+160 routed" describes full
DeepSeek-V2; the Lite spec (64e top-6) from the main entry is used here.
"""
from ..models.config import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,           # dense first layer width
    vocab=102400,
    rope="rope",
    mlp_act="swiglu",
    norm="rmsnorm",
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        d_ff_expert=1408,
        num_shared=2,
        d_ff_shared=2816,
        first_dense_layers=1,
    ),
)
