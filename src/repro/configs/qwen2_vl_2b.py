"""Qwen2-VL-2B [vlm] — 28L d=1536 12H (GQA kv=2) d_ff=8960 vocab=151936,
M-RoPE; vision frontend is a STUB (precomputed patch embeddings).
[arXiv:2409.12191; hf]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    rope="mrope",
    mrope_sections=(16, 24, 24),
    mlp_act="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    frontend="vision_stub",
    frontend_dim=1280,
    vision_patches=64,
)
