"""Mamba2-1.3B [ssm] — 48L d=2048, attention-free, SSD state=128,
head_dim=64, expand=2, vocab=50280.  [arXiv:2405.21060; unverified]"""
from ..models.config import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    rope="none",
    norm="rmsnorm",
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_kernel=4, n_groups=1, chunk=256),
)
