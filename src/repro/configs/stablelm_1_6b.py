"""StableLM-2-1.6B [dense] — 24L d=2048 32H (MHA kv=32) d_ff=5632
vocab=100352.  LayerNorm, partial-rotary in the real model (full RoPE here;
noted in DESIGN.md).  [hf:stabilityai/stablelm-2-1_6b; unverified]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab=100352,
    qkv_bias=False,
    rope="rope",
    mlp_act="swiglu",
    norm="layernorm",
)
