"""Qwen2-0.5B [dense] — 24L d=896 14H (GQA kv=2) d_ff=4864 vocab=151936,
QKV bias, tied embeddings.  [arXiv:2407.10671; hf]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151936,
    qkv_bias=True,
    rope="rope",
    rope_theta=1_000_000.0,
    mlp_act="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
)
