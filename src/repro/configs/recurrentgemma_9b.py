"""RecurrentGemma-9B [hybrid] — 38L d=4096 16H (MQA kv=1) d_ff=12288
vocab=256000; RG-LRU + local attention (window 2048) in a 2:1 pattern.
[arXiv:2402.19427; unverified]"""
from ..models.config import ArchConfig, HybridConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    rope="rope",
    mlp_act="gelu",
    norm="rmsnorm",
    hybrid=HybridConfig(rec_per_unit=2, attn_per_unit=1, window=2048, conv_kernel=4),
)
