"""Assigned-architecture registry: one module per architecture id.

Usage: ``get_arch("qwen2-0.5b")`` -> ArchConfig;
``get_arch("qwen2-0.5b", reduced=True)`` -> CPU smoke-test variant.
"""
from __future__ import annotations

import importlib
from typing import List

from ..models.config import ArchConfig

_MODULES = {
    "qwen1.5-32b": "qwen1_5_32b",
    "qwen2-0.5b": "qwen2_0_5b",
    "stablelm-1.6b": "stablelm_1_6b",
    "glm4-9b": "glm4_9b",
    "mamba2-1.3b": "mamba2_1_3b",
    "hubert-xlarge": "hubert_xlarge",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "tao": "tao",
}

ARCH_IDS: List[str] = [k for k in _MODULES if k != "tao"]


def get_arch(name: str, reduced: bool = False) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    mod = importlib.import_module(f".{_MODULES[name]}", __package__)
    cfg: ArchConfig = mod.CONFIG
    return cfg.reduced() if reduced else cfg
