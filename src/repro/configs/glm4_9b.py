"""GLM-4-9B [dense] — 40L d=4096 32H (GQA kv=2) d_ff=13696 vocab=151552,
RoPE, QKV bias.  [hf:THUDM/glm-4-9b; hf]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=151552,
    qkv_bias=True,
    rope="rope",
    mlp_act="swiglu",
    norm="rmsnorm",
)
