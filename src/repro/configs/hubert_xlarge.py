"""HuBERT-XLarge [audio] — 48L d=1280 16H (MHA) d_ff=5120, encoder-only,
504 output classes.  Modality frontend is a STUB: input_specs() provides
precomputed frame embeddings; conv positional embedding replaced with RoPE
(DESIGN.md hardware-adaptation note).  [arXiv:2106.07447; unverified]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    rope="rope",
    mlp_act="gelu",
    norm="layernorm",
    encoder_only=True,
    frontend="audio_stub",
    frontend_dim=512,
)
