"""Qwen3-235B-A22B [moe] — 94L d=4096 64H (GQA kv=4, head_dim=128, QK-norm)
128 experts top-8, expert d_ff=1536, vocab=151936.  [hf:Qwen/Qwen3-235B-A22B; hf]"""
from ..models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    qk_norm=True,
    d_ff=1536,
    vocab=151936,
    rope="rope",
    rope_theta=1_000_000.0,
    mlp_act="swiglu",
    norm="rmsnorm",
    moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=1536),
)
