"""Qwen1.5-32B [dense]  — 64L d=5120 40H (MHA, kv=40) d_ff=27392 vocab=152064,
QKV bias, RoPE, SwiGLU.  [hf:Qwen/Qwen1.5-32B; hf]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab=152064,
    qkv_bias=True,
    rope="rope",
    rope_theta=1_000_000.0,
    mlp_act="swiglu",
    norm="rmsnorm",
)
