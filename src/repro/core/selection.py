"""§4.3 Training-dataset (µarch pair) selection for agnostic embeddings.

Measure per-design performance vectors (CPI, L1 miss rate, L2 miss rate,
branch mispredict rate) averaged over benchmarks, then pick the pair of
designs with maximum Mahalanobis distance.  Euclidean and random selection
are provided as the Fig. 14 baselines.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from ..uarch import MicroArchConfig, get_benchmark, run_detailed, run_functional

__all__ = [
    "measure_design_metrics",
    "mahalanobis_matrix",
    "select_pair_mahalanobis",
    "select_pair_euclidean",
    "select_random",
]

METRIC_NAMES = ("cpi", "l1d_miss_rate", "l2_miss_rate", "branch_mispred_rate")


def measure_design_metrics(
    designs: Sequence[MicroArchConfig],
    benchmarks: Sequence[str],
    instructions: int = 20000,
) -> np.ndarray:
    """Simulate each design over the benchmarks; returns (n_designs, 4)."""
    out = np.zeros((len(designs), len(METRIC_NAMES)))
    for i, cfg in enumerate(designs):
        accum = np.zeros(len(METRIC_NAMES))
        for bname in benchmarks:
            prog = get_benchmark(bname)
            ft = run_functional(prog, instructions)
            _, summ = run_detailed(prog, ft, cfg)
            accum += np.array([summ[m] for m in METRIC_NAMES])
        out[i] = accum / len(benchmarks)
    return out


def mahalanobis_matrix(metrics: np.ndarray) -> np.ndarray:
    """Pairwise Mahalanobis distances between design metric vectors."""
    cov = np.cov(metrics.T)
    # pinv guards against singular covariance for small design samples.
    s_inv = np.linalg.pinv(np.atleast_2d(cov))
    n = len(metrics)
    d = np.zeros((n, n))
    for i in range(n):
        for j in range(i + 1, n):
            diff = metrics[i] - metrics[j]
            d[i, j] = d[j, i] = float(np.sqrt(max(0.0, diff @ s_inv @ diff)))
    return d


def select_pair_mahalanobis(metrics: np.ndarray) -> Tuple[int, int]:
    d = mahalanobis_matrix(metrics)
    i, j = np.unravel_index(np.argmax(d), d.shape)
    return int(min(i, j)), int(max(i, j))


def select_pair_euclidean(metrics: np.ndarray) -> Tuple[int, int]:
    n = len(metrics)
    best, pair = -1.0, (0, 1)
    for i in range(n):
        for j in range(i + 1, n):
            d = float(np.linalg.norm(metrics[i] - metrics[j]))
            if d > best:
                best, pair = d, (i, j)
    return pair


def select_random(n_designs: int, k: int, seed: int = 0) -> List[int]:
    rng = np.random.default_rng(seed)
    return list(rng.choice(n_designs, size=k, replace=False))
