"""Tao's core contributions (paper §4) as composable modules."""
from .align import AlignedTrace, build_adjusted_trace, verify_alignment
from .dataset import (
    StreamingWindowDataset,
    WindowDataset,
    build_windows,
    concat_datasets,
    iter_window_digests,
    num_windows,
    stream_batches,
    window_view,
)
from .features import (
    NUM_OPCODES,
    FeatureConfig,
    FeatureSet,
    extract_features,
    extract_features_reference,
    signed_log,
)
from .model import (
    LOSS_WEIGHTS,
    TaoConfig,
    init_tao,
    multi_metric_loss,
    tao_forward,
)
from .multiarch import METHODS, init_multiarch, make_joint_step
from .selection import (
    measure_design_metrics,
    select_pair_euclidean,
    select_pair_mahalanobis,
    select_random,
)
from .transfer import TrainResult, train_tao, train_tao_impl, transfer_finetune

# NOTE: .simulate imports engine.runner, and engine.runner imports this
# package (core.dataset / core.features / core.model) — so the simulate
# symbols are exposed lazily (PEP 562) to keep `import repro.engine` /
# `import repro.api` working as the FIRST repro import.
_SIMULATE_SYMBOLS = (
    "SimulationResult",
    "simulate_trace",
    "simulate_trace_legacy",
    "phase_curves",
)


def __getattr__(name):
    if name in _SIMULATE_SYMBOLS:
        from . import simulate as _simulate

        return getattr(_simulate, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "AlignedTrace",
    "build_adjusted_trace",
    "verify_alignment",
    "StreamingWindowDataset",
    "WindowDataset",
    "build_windows",
    "concat_datasets",
    "iter_window_digests",
    "num_windows",
    "stream_batches",
    "window_view",
    "FeatureConfig",
    "FeatureSet",
    "extract_features",
    "extract_features_reference",
    "signed_log",
    "NUM_OPCODES",
    "TaoConfig",
    "init_tao",
    "tao_forward",
    "multi_metric_loss",
    "LOSS_WEIGHTS",
    "METHODS",
    "init_multiarch",
    "make_joint_step",
    "measure_design_metrics",
    "select_pair_mahalanobis",
    "select_pair_euclidean",
    "select_random",
    "SimulationResult",
    "simulate_trace",
    "simulate_trace_legacy",
    "phase_curves",
    "TrainResult",
    "train_tao",
    "train_tao_impl",
    "transfer_finetune",
]
