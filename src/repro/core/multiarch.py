"""§4.3 Microarchitecture-agnostic embedding training (Algorithm 1) and the
two baselines the paper compares against (Granite-style gradient averaging,
GradNorm loss weighting).

Parameter layout during joint training over two µarchs A and B:

    shared:  embed                       (the µarch-agnostic layers)
    per-µarch: adapt_X, pred_X           (adaptation + prediction networks)

Algorithm 1 (Tao):
  1. standard forward for L_A, L_B
  2. per-µarch grads for pred_X, adapt_X   (applied directly)
  3. shared-embedding grads g_X = dL_X/d(embed)  — note jax.grad computes the
     chain through the adaptation layer, i.e. exactly G_X·W_Xᵀ of the paper
  4. normalize each g_X leafwise: (g - mean) / (max - min)
  5. shared grad = average of normalized grads
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict

import jax
import jax.numpy as jnp

from ..train.optim import AdamWConfig, AdamWState, adamw_update
from ..train.trainer import cached_train_step
from .model import TaoConfig, apply_adapt, apply_embed, apply_pred, multi_metric_loss

__all__ = [
    "MultiArchState",
    "init_multiarch",
    "make_joint_step",
    "METHODS",
]

METHODS = ("tao", "tao_no_adapt", "granite", "gradnorm")


@dataclasses.dataclass
class MultiArchState:
    params: Dict           # {"embed":…, "A":{"adapt":…,"pred":…}, "B":{…}}
    opt: AdamWState
    gradnorm_w: jnp.ndarray  # (2,) learnable loss weights (GradNorm only)
    initial_losses: jnp.ndarray  # (2,) L_X(0) for GradNorm's rate term


def init_multiarch(key, cfg: TaoConfig) -> Dict:
    from .model import init_adapt_params, init_embed_params, init_pred_params

    ke, ka1, kp1, ka2, kp2 = jax.random.split(key, 5)
    return {
        "embed": init_embed_params(ke, cfg),
        "A": {"adapt": init_adapt_params(ka1, cfg), "pred": init_pred_params(kp1, cfg)},
        "B": {"adapt": init_adapt_params(ka2, cfg), "pred": init_pred_params(kp2, cfg)},
    }


def _forward_loss(embed_p, arch_p, batch, cfg: TaoConfig, use_adapt: bool):
    h = apply_embed(embed_p, batch, cfg)
    if use_adapt:
        h = apply_adapt(arch_p["adapt"], h)
    preds = apply_pred(arch_p["pred"], h, cfg)
    loss, parts = multi_metric_loss(preds, batch["labels"])
    return loss, parts


def _normalize_grad(g):
    """Paper's normalization: (X - mean) / (max - min), per gradient matrix."""

    def _n(x):
        x32 = x.astype(jnp.float32)
        mean = jnp.mean(x32)
        rng = jnp.max(x32) - jnp.min(x32)
        return ((x32 - mean) / (rng + 1e-8)).astype(x.dtype)

    return jax.tree.map(_n, g)


def make_joint_step(cfg: TaoConfig, opt_cfg: AdamWConfig, method: str = "tao"):
    """Build a jitted joint-training step over µarchs A and B.

    step(params, opt, gradnorm_w, initial_losses, batch_a, batch_b)
      -> (params, opt, gradnorm_w, metrics)

    Cached process-wide on (cfg, opt_cfg, method) — params/opt are
    arguments, so repeated joint runs of the same shape reuse one
    executable: exactly one trace per (batch, window) geometry.
    """
    if method not in METHODS:
        raise ValueError(f"method {method!r} not in {METHODS}")
    return cached_train_step(  # tao: step-key[joint-step]
        ("joint", cfg, opt_cfg, method),
        lambda entry: _build_joint_step(cfg, opt_cfg, method, entry),
    ).fn


# tao: step-builder[joint-step] ignore=entry
def _build_joint_step(cfg: TaoConfig, opt_cfg: AdamWConfig, method: str, entry):
    use_adapt = method in ("tao", "gradnorm")  # gradnorm baseline keeps its
    # own adaptation-free design in the paper; give it the same capacity but
    # no gradient surgery so the comparison isolates the combination rule.
    use_adapt_by_method = {
        "tao": True,
        "tao_no_adapt": False,
        "granite": False,
        "gradnorm": False,
    }
    use_adapt = use_adapt_by_method[method]
    alpha = 0.5  # GradNorm asymmetry

    def loss_a(embed_p, arch_p, batch):
        return _forward_loss(embed_p, arch_p, batch, cfg, use_adapt)

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def step(params, opt, gradnorm_w, initial_losses, batch_a, batch_b):
        entry.compiles += 1  # runs at trace time only
        embed_p = params["embed"]

        (la, _), (ga_embed, ga_arch) = jax.value_and_grad(
            loss_a, argnums=(0, 1), has_aux=True
        )(embed_p, params["A"], batch_a)
        (lb, _), (gb_embed, gb_arch) = jax.value_and_grad(
            loss_a, argnums=(0, 1), has_aux=True
        )(embed_p, params["B"], batch_b)

        new_gradnorm_w = gradnorm_w
        if method == "granite":
            g_embed = jax.tree.map(lambda a, b: 0.5 * (a + b), ga_embed, gb_embed)
        elif method in ("tao", "tao_no_adapt"):
            # Algorithm 1 line 5-6: normalize per-µarch embedding grads, average.
            na = _normalize_grad(ga_embed)
            nb = _normalize_grad(gb_embed)
            g_embed = jax.tree.map(lambda a, b: 0.5 * (a + b), na, nb)
        else:  # gradnorm
            wa, wb = gradnorm_w[0], gradnorm_w[1]
            g_embed = jax.tree.map(
                lambda a, b: 0.5 * (wa * a + wb * b), ga_embed, gb_embed
            )
            # GradNorm weight update: match per-task gradient norms scaled by
            # relative inverse training rate.
            def _gn(g):
                return jnp.sqrt(
                    sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(g))
                )

            gna = wa * _gn(ga_embed)
            gnb = wb * _gn(gb_embed)
            mean_gn = 0.5 * (gna + gnb)
            rate_a = la / jnp.maximum(initial_losses[0], 1e-6)
            rate_b = lb / jnp.maximum(initial_losses[1], 1e-6)
            mean_rate = 0.5 * (rate_a + rate_b)
            tgt_a = mean_gn * (rate_a / mean_rate) ** alpha
            tgt_b = mean_gn * (rate_b / mean_rate) ** alpha
            # d|gn_i - tgt_i|/dw_i with gn_i = w_i * ||g_i||
            d_wa = jnp.sign(gna - tgt_a) * _gn(ga_embed)
            d_wb = jnp.sign(gnb - tgt_b) * _gn(gb_embed)
            lr_w = 0.025
            wa = jnp.maximum(wa - lr_w * d_wa, 0.05)
            wb = jnp.maximum(wb - lr_w * d_wb, 0.05)
            # renormalize so weights sum to 2 (GradNorm convention)
            s = (wa + wb) / 2.0
            new_gradnorm_w = jnp.stack([wa / s, wb / s])

        grads = {"embed": g_embed, "A": ga_arch, "B": gb_arch}
        new_params, new_opt, gnorm = adamw_update(params, grads, opt, opt_cfg)
        metrics = {"loss_a": la, "loss_b": lb, "gnorm": gnorm}
        return new_params, new_opt, new_gradnorm_w, metrics

    return step


def eval_loss(params, batches, cfg: TaoConfig, arch: str, use_adapt: bool = True):
    """Average loss of one µarch head over a list of batches."""
    total, count = 0.0, 0
    fwd = jax.jit(
        lambda ep, ap, b: _forward_loss(ep, ap, b, cfg, use_adapt)[0]
    )
    for b in batches:
        total += float(fwd(params["embed"], params[arch], b))
        count += 1
    return total / max(count, 1)
