"""§4.1 Training-dataset construction: detailed↔functional trace alignment.

The detailed trace differs from the functional trace by (i) per-instruction
performance metrics and (ii) extra dynamic records — squashed speculative
instructions and stall nops.  We remove the extra records and re-attribute
their timing impact to the *fetch latency of the next committed instruction*
(paper Figure 2), producing an "adjusted trace": functional-trace order,
detailed-trace labels, with the total-cycle invariant preserved exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from ..uarch.isa import KIND_NOP, KIND_REAL, KIND_SQUASHED

__all__ = ["AlignedTrace", "build_adjusted_trace", "verify_alignment"]


# Adjusted-trace layout: functional static fields + supervised labels.
ADJ_DTYPE = np.dtype(
    [
        ("pc", np.int64),
        ("opcode", np.int16),
        ("dst", np.int8),
        ("src1", np.int8),
        ("src2", np.int8),
        ("is_branch", np.bool_),
        ("taken", np.bool_),
        ("is_mem", np.bool_),
        ("is_store", np.bool_),
        ("addr", np.int64),
        # labels
        ("fetch_lat", np.int32),   # adjusted: absorbs squashed/nop impact
        ("exec_lat", np.int32),
        ("mispred", np.bool_),
        ("dlevel", np.int8),
        ("icache_miss", np.bool_),
        ("tlb_miss", np.bool_),
    ]
)

_STATIC_FIELDS = (
    "pc",
    "opcode",
    "dst",
    "src1",
    "src2",
    "is_branch",
    "taken",
    "is_mem",
    "is_store",
    "addr",
)
_LABEL_FIELDS = ("exec_lat", "mispred", "dlevel", "icache_miss", "tlb_miss")


@dataclasses.dataclass
class AlignedTrace:
    """Adjusted trace + bookkeeping for invariant checks."""

    adjusted: np.ndarray          # ADJ_DTYPE records, committed order
    total_cycles_detailed: int    # max retire_clock over committed records
    num_squashed: int
    num_nops: int

    @property
    def total_cycles_adjusted(self) -> int:
        """Reconstruct total cycles from the adjusted trace alone:
        fetch clocks are the running sum of adjusted fetch latencies and the
        makespan is max(fetch_clock + exec_lat) (paper's retire-clock defn)."""
        if len(self.adjusted) == 0:
            return 0
        fetch_clock = np.cumsum(self.adjusted["fetch_lat"].astype(np.int64))
        return int(np.max(fetch_clock + self.adjusted["exec_lat"]))


def build_adjusted_trace(det_trace: np.ndarray) -> AlignedTrace:
    """Drop squashed/nop records, fold their timing into the next committed
    instruction's fetch latency."""
    kinds = det_trace["kind"]
    real_mask = kinds == KIND_REAL
    real = det_trace[real_mask]
    n = len(real)
    adj = np.zeros(n, dtype=ADJ_DTYPE)
    for f in _STATIC_FIELDS + _LABEL_FIELDS:
        adj[f] = real[f]

    # Adjusted fetch latency: delta between consecutive *committed* fetch
    # clocks.  Any squashed/nop records in between contributed to that delta,
    # which is precisely the re-attribution of Figure 2.
    fc = real["fetch_clock"].astype(np.int64)
    adj_fetch = np.empty(n, dtype=np.int64)
    if n:
        adj_fetch[0] = fc[0]
        adj_fetch[1:] = np.diff(fc)
    adj["fetch_lat"] = adj_fetch

    total_detailed = int(real["retire_clock"].max()) if n else 0
    return AlignedTrace(
        adjusted=adj,
        total_cycles_detailed=total_detailed,
        num_squashed=int((kinds == KIND_SQUASHED).sum()),
        num_nops=int((kinds == KIND_NOP).sum()),
    )


def verify_alignment(aligned: AlignedTrace, func_trace: np.ndarray) -> Dict:
    """Check the two §4.1 invariants:

    1. static-stream identity: the adjusted trace's committed instruction
       stream equals the functional trace (pc/opcode/regs/addr all match);
    2. cycle preservation: total cycles reconstructed from adjusted fetch
       latencies equal the detailed simulation's committed makespan.
    """
    adj = aligned.adjusted
    n = min(len(adj), len(func_trace))
    stream_ok = all(
        np.array_equal(adj[f][:n], func_trace[f][:n]) for f in _STATIC_FIELDS
    )
    cycles_ok = aligned.total_cycles_adjusted == aligned.total_cycles_detailed
    return {
        "stream_match": bool(stream_ok),
        "cycles_match": bool(cycles_ok),
        "total_cycles_adjusted": aligned.total_cycles_adjusted,
        "total_cycles_detailed": aligned.total_cycles_detailed,
        "n": n,
    }
