"""§4.2 The Tao multi-metric DL model.

Two-level embedding: per-category embeddings (opcode lookup table; linear
layers for register bitmap, branch history, access distance, flags) combined
by a linear layer into per-instruction embeddings.  Prediction layers:
multi-head self-attention blocks over a window of N+1 instructions (N = max
ROB in the design space = 128) followed by per-metric heads:

  fetch/exec latency  — linear (regression on log1p cycles)
  branch mispredict   — sigmoid
  data access level   — softmax over {none, L1, L2, mem}
  icache / TLB miss   — sigmoid

The model is split into three parameter groups, which is what §4.3's
transfer learning manipulates:
  "embed"  — shared, µarch-agnostic embedding layers
  "adapt"  — per-µarch embedding adaptation linear layer (the proactive
             negative-transfer fix)
  "pred"   — per-µarch self-attention prediction network + heads
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..nn.core import (
    dense,
    embed,
    gelu,
    init_dense,
    init_embed,
    init_layernorm,
    layernorm,
    softmax_cross_entropy,
)
from ..uarch.isa import NUM_DLEVELS
from .features import NUM_OPCODES, FeatureConfig

__all__ = [
    "TaoConfig",
    "init_tao",
    "init_embed_params",
    "init_adapt_params",
    "init_pred_params",
    "apply_embed",
    "apply_adapt",
    "apply_pred",
    "tao_forward",
    "multi_metric_loss",
    "LOSS_WEIGHTS",
]


@dataclasses.dataclass(frozen=True)
class TaoConfig:
    window: int = 129          # N+1, N = max ROB = 128 (paper §4.2)
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 512
    d_cat: int = 64            # per-category embedding width
    features: FeatureConfig = FeatureConfig()
    use_pallas: bool = False   # route attention through the Pallas kernel
    dtype: str = "float32"

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


# Latency prediction design (three iterations, logged in EXPERIMENTS.md):
#   1. log1p + huber regression — fits the median, under-predicts CPI ~4x on
#      the heavy-tailed latency distribution.
#   2. linear-space MSE — preserves the conditional mean for high-CPI code,
#      but the squared heavy-tail terms dominate the loss and low-CPI
#      (streaming, IPC>1) programs collapse to the mixture mean (450%+
#      error on rom/wrf/cac).
#   3. (current) DISCRETIZED latency classification over geometric buckets
#      with soft-expectation decoding: cross-entropy is scale-free per
#      instruction, so 0-cycle and 80-cycle regimes train equally well, and
#      E[lat] = sum p_k rep_k recovers a continuous estimate for CPI.
LAT_EDGES = np.array(
    [0, 1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128, 192], np.float32
)
NUM_LAT_BUCKETS = len(LAT_EDGES)
# representative value per bucket (midpoint of [edge, next_edge), 256 for top)
LAT_REPS = np.concatenate(
    [LAT_EDGES[:-1] + (np.diff(LAT_EDGES) - 1) / 2.0, [256.0]]
).astype(np.float32)
LAT_SCALE = 1.0  # retained for external callers; expectations are in cycles


def bucketize_latency(x):
    """Map latency cycles -> bucket index."""
    return jnp.clip(
        jnp.searchsorted(jnp.asarray(LAT_EDGES), x, side="right") - 1,
        0,
        NUM_LAT_BUCKETS - 1,
    )


def expected_latency(logits):
    """Decode latency = representative of the most-likely bucket.

    (4th iteration: soft expectation smears tail mass — the 256-cycle top
    bucket at p=0.01 adds +2.5 cycles everywhere, 3-6x over-predicting
    IPC>1 programs.  Argmax decoding: rom 65%->6%, wrf 221%->3% CPI error.)
    Inference-only: the loss trains the logits with cross-entropy.
    """
    return jnp.asarray(LAT_REPS)[jnp.argmax(logits, axis=-1)]

# Linear combination ratios for the multi-metric loss (paper trains all
# heads jointly with a linear ratio).
LOSS_WEIGHTS = {
    "fetch_lat": 1.0,
    "exec_lat": 1.0,
    "mispred": 0.5,
    "dlevel": 0.5,
    "icache_miss": 0.25,
    "tlb_miss": 0.25,
}


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------


def init_embed_params(key, cfg: TaoConfig) -> Dict:
    ks = jax.random.split(key, 6)
    f = cfg.features
    return {
        "opcode": init_embed(ks[0], NUM_OPCODES, cfg.d_cat),
        "regbits": init_dense(ks[1], 32, cfg.d_cat),
        "flags": init_dense(ks[2], f.flags_dim, cfg.d_cat),
        "brhist": init_dense(ks[3], f.n_queue, cfg.d_cat),
        "memdist": init_dense(ks[4], f.n_mem, cfg.d_cat),
        "combine": init_dense(ks[5], 5 * cfg.d_cat, cfg.d_model),
    }


def init_adapt_params(key, cfg: TaoConfig) -> Dict:
    # Near-identity init: adaptation starts as a gentle projection.
    w = jnp.eye(cfg.d_model) + 0.01 * jax.random.normal(
        key, (cfg.d_model, cfg.d_model)
    )
    return {"w": w, "b": jnp.zeros((cfg.d_model,))}


def _init_block(key, cfg: TaoConfig) -> Dict:
    ks = jax.random.split(key, 6)
    d, h = cfg.d_model, cfg.n_heads
    return {
        "ln1": init_layernorm(d),
        "qkv": init_dense(ks[0], d, 3 * d, use_bias=True),
        "proj": init_dense(ks[1], d, d, use_bias=True, scale=1.0 / math.sqrt(2 * cfg.n_layers)),
        "ln2": init_layernorm(d),
        "up": init_dense(ks[2], d, cfg.d_ff),
        "down": init_dense(ks[3], cfg.d_ff, d, scale=1.0 / math.sqrt(2 * cfg.n_layers)),
    }


def init_pred_params(key, cfg: TaoConfig) -> Dict:
    ks = jax.random.split(key, cfg.n_layers + 3)
    blocks = [_init_block(ks[i], cfg) for i in range(cfg.n_layers)]
    d = cfg.d_model
    kpos, khead = ks[-2], ks[-1]
    hs = jax.random.split(khead, 5)
    return {
        "pos": 0.02 * jax.random.normal(kpos, (cfg.window, d)),
        "blocks": blocks,
        "ln_f": init_layernorm(d),
        "head_lat": init_dense(hs[0], d, 2 * NUM_LAT_BUCKETS),  # fetch+exec buckets
        "head_branch": init_dense(hs[1], d, 1),
        "head_dlevel": init_dense(hs[2], d, NUM_DLEVELS),
        "head_icache": init_dense(hs[3], d, 1),
        "head_tlb": init_dense(hs[4], d, 1),
    }


def init_tao(key, cfg: TaoConfig) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "embed": init_embed_params(k1, cfg),
        "adapt": init_adapt_params(k2, cfg),
        "pred": init_pred_params(k3, cfg),
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def apply_embed(p: Dict, batch: Dict, cfg: TaoConfig) -> jnp.ndarray:
    """batch -> (B, W, d_model) instruction embeddings (shared layers)."""
    cats = [
        embed(p["opcode"], batch["opcode"]),
        dense(p["regbits"], batch["regbits"]),
        dense(p["flags"], batch["flags"]),
        dense(p["brhist"], batch["brhist"]),
        dense(p["memdist"], batch["memdist"]),
    ]
    x = jnp.concatenate(cats, axis=-1)
    return gelu(dense(p["combine"], x))


def apply_adapt(p: Dict, h: jnp.ndarray) -> jnp.ndarray:
    return h @ p["w"] + p["b"]


def _attention(q, k, v, causal: bool, use_pallas: bool):
    if use_pallas:
        from ..kernels.attention.ops import flash_attention

        return flash_attention(q, k, v, causal=causal)
    # jnp reference path (CPU training)
    *_, hd = q.shape
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(hd)
    if causal:
        w = q.shape[-2]
        mask = jnp.tril(jnp.ones((w, w), dtype=bool))
        scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def _block(p: Dict, h: jnp.ndarray, cfg: TaoConfig, causal: bool) -> jnp.ndarray:
    B, W, d = h.shape
    nh, hd = cfg.n_heads, cfg.head_dim
    x = layernorm(p["ln1"], h)
    qkv = dense(p["qkv"], x).reshape(B, W, 3, nh, hd)
    q, k, v = (qkv[:, :, i].transpose(0, 2, 1, 3) for i in range(3))
    o = _attention(q, k, v, causal, cfg.use_pallas)
    o = o.transpose(0, 2, 1, 3).reshape(B, W, d)
    h = h + dense(p["proj"], o)
    x = layernorm(p["ln2"], h)
    h = h + dense(p["down"], gelu(dense(p["up"], x)))
    return h


def apply_pred(
    p: Dict, h: jnp.ndarray, cfg: TaoConfig, causal: bool = True
) -> Dict[str, jnp.ndarray]:
    """Prediction network over adapted embeddings -> per-position metrics."""
    W = h.shape[1]
    h = h + p["pos"][:W]
    for blk in p["blocks"]:
        h = _block(blk, h, cfg, causal)
    h = layernorm(p["ln_f"], h)
    lat = dense(p["head_lat"], h)
    nb = NUM_LAT_BUCKETS
    return {
        "fetch_lat_logits": lat[..., :nb],
        "exec_lat_logits": lat[..., nb:],
        "fetch_lat": expected_latency(lat[..., :nb]),
        "exec_lat": expected_latency(lat[..., nb:]),
        "mispred_logit": dense(p["head_branch"], h)[..., 0],
        "dlevel_logits": dense(p["head_dlevel"], h),
        "icache_logit": dense(p["head_icache"], h)[..., 0],
        "tlb_logit": dense(p["head_tlb"], h)[..., 0],
    }


def tao_forward(params: Dict, batch: Dict, cfg: TaoConfig) -> Dict[str, jnp.ndarray]:
    h = apply_embed(params["embed"], batch, cfg)
    h = apply_adapt(params["adapt"], h)
    return apply_pred(params["pred"], h, cfg)


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def multi_metric_loss(
    preds: Dict[str, jnp.ndarray],
    labels: Dict[str, jnp.ndarray],
    weights: Optional[Dict[str, float]] = None,
) -> tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Combined multi-metric loss (linear ratio).  Branch / memory heads are
    masked to the instruction kinds they apply to.  Latencies are regressed
    with MSE in linear space (scaled by LAT_SCALE) — see the note above."""
    w = weights or LOSS_WEIGHTS
    br_mask = labels["is_branch"]
    mem_mask = labels["is_mem"]

    lat_f = softmax_cross_entropy(
        preds["fetch_lat_logits"], bucketize_latency(labels["fetch_lat"])
    ).mean()
    lat_e = softmax_cross_entropy(
        preds["exec_lat_logits"], bucketize_latency(labels["exec_lat"])
    ).mean()

    def _masked_bce(logit, target, mask):
        per = jnp.maximum(logit, 0) - logit * target + jnp.log1p(
            jnp.exp(-jnp.abs(logit))
        )
        return (per * mask).sum() / jnp.maximum(mask.sum(), 1.0)

    bce_br = _masked_bce(preds["mispred_logit"], labels["mispred"], br_mask)
    ce_dl = (
        softmax_cross_entropy(preds["dlevel_logits"], labels["dlevel"]) * mem_mask
    ).sum() / jnp.maximum(mem_mask.sum(), 1.0)
    bce_ic = _masked_bce(
        preds["icache_logit"], labels["icache_miss"], jnp.ones_like(br_mask)
    )
    bce_tlb = _masked_bce(preds["tlb_logit"], labels["tlb_miss"], mem_mask)

    parts = {
        "fetch_lat": lat_f,
        "exec_lat": lat_e,
        "mispred": bce_br,
        "dlevel": ce_dl,
        "icache_miss": bce_ic,
        "tlb_miss": bce_tlb,
    }
    total = sum(w[k] * v for k, v in parts.items())
    return total, parts
