"""int8 W8A8 quantized inference for the Tao model.

Scheme (``docs/kernels.md``):

  * **weights** — symmetric per-output-channel int8: ``scale_j =
    max|w[:, j]| / 127``, computed ONCE per parameter tree (at
    ``ModelRegistry.publish`` time or lazily on the first int8 simulate)
    and stored alongside the fp32 params in the ArtifactStore under a
    content key derived from the fp32 tree digest, so every process that
    resolves the model reuses the same scales;
  * **embedding table** — symmetric per-row int8 (each opcode's vector has
    its own scale);
  * **activations** — symmetric per-row *dynamic* int8: the scale is
    ``max|x|`` over the feature axis at run time (no calibration set
    needed — simulation batches are full windows, so the row statistics
    are stable);
  * **matmuls** — int8 x int8 accumulated in int32
    (``preferred_element_type``), dequantized by the rank-1 outer product
    of the two scales;
  * layernorms, softmax, gelu, the attention probability matmuls, biases,
    and the latency-bucket argmax decode stay fp32 — they are O(d) work or
    numerically load-bearing, and keeping them exact is what lets
    ``bench_accuracy``'s parity gate hold a tight band.

``tao_forward_int8`` mirrors ``core.model.tao_forward`` layer for layer;
the engine picks between them at trace time from
``EngineConfig.precision`` (the choice is part of the step-cache key).
Everything here is traceable, so ``jax.eval_shape(quantize_tao_params,
abstract_params)`` yields the abstract quantized tree AOT ``warmup()``
lowers from.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..nn.core import gelu, layernorm
from .model import NUM_LAT_BUCKETS, TaoConfig, _attention, expected_latency

__all__ = [
    "QUANT_VERSION",
    "qdense",
    "qembed",
    "quantize_dense",
    "quantize_embed",
    "quantize_tao_params",
    "tao_forward_int8",
]

# Versions the stored quantized trees (ArtifactStore content keys include
# it): bump on any scheme change so stale scales are recomputed, not reused.
QUANT_VERSION = 1


def _safe_scale(amax: jnp.ndarray) -> jnp.ndarray:
    # all-zero channels quantize to zeros either way; a unit scale avoids
    # the 0/0 and keeps the dequant exact
    return jnp.where(amax > 0.0, amax, 1.0).astype(jnp.float32) / 127.0


def quantize_dense(p: Dict) -> Dict:
    """{"w": (in, out), "b"?} -> {"w_q": int8, "scale": (out,), "b"?}."""
    w = p["w"]
    scale = _safe_scale(jnp.max(jnp.abs(w), axis=0))
    wq = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    out = {"w_q": wq, "scale": scale}
    if "b" in p:
        out["b"] = p["b"]
    return out


def quantize_embed(p: Dict) -> Dict:
    """{"table": (vocab, d)} -> per-row int8 table + (vocab,) scales."""
    t = p["table"]
    scale = _safe_scale(jnp.max(jnp.abs(t), axis=1))
    tq = jnp.clip(jnp.round(t / scale[:, None]), -127, 127).astype(jnp.int8)
    return {"table_q": tq, "scale": scale}


def quantize_tao_params(params: Dict) -> Dict:
    """fp32 Tao parameter tree -> its W8A8 inference twin (per-channel
    weight int8 + scales; norms/bias/pos stay fp32)."""
    e = params["embed"]
    pr = params["pred"]
    return {
        "embed": {
            "opcode": quantize_embed(e["opcode"]),
            "regbits": quantize_dense(e["regbits"]),
            "flags": quantize_dense(e["flags"]),
            "brhist": quantize_dense(e["brhist"]),
            "memdist": quantize_dense(e["memdist"]),
            "combine": quantize_dense(e["combine"]),
        },
        "adapt": quantize_dense(params["adapt"]),
        "pred": {
            "pos": pr["pos"],
            "blocks": [
                {
                    "ln1": dict(b["ln1"]),
                    "qkv": quantize_dense(b["qkv"]),
                    "proj": quantize_dense(b["proj"]),
                    "ln2": dict(b["ln2"]),
                    "up": quantize_dense(b["up"]),
                    "down": quantize_dense(b["down"]),
                }
                for b in pr["blocks"]
            ],
            "ln_f": dict(pr["ln_f"]),
            "head_lat": quantize_dense(pr["head_lat"]),
            "head_branch": quantize_dense(pr["head_branch"]),
            "head_dlevel": quantize_dense(pr["head_dlevel"]),
            "head_icache": quantize_dense(pr["head_icache"]),
            "head_tlb": quantize_dense(pr["head_tlb"]),
        },
    }


def qdense(p: Dict, x: jnp.ndarray) -> jnp.ndarray:
    """Quantized twin of ``nn.core.dense``: dynamic per-row activation
    int8, int32 accumulation, fp32 dequant + bias."""
    sx = _safe_scale(jnp.max(jnp.abs(x), axis=-1, keepdims=True))
    xq = jnp.clip(jnp.round(x / sx), -127, 127).astype(jnp.int8)
    y = jax.lax.dot_general(
        xq,
        p["w_q"],
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    ).astype(jnp.float32)
    y = y * (sx * p["scale"])
    if "b" in p:
        y = y + p["b"]
    return y


def qembed(p: Dict, ids: jnp.ndarray) -> jnp.ndarray:
    return p["table_q"][ids].astype(jnp.float32) * p["scale"][ids][..., None]


# ---------------------------------------------------------------------------
# forward — mirrors core.model layer for layer with quantized projections
# ---------------------------------------------------------------------------


def _apply_embed_q(p: Dict, batch: Dict, cfg: TaoConfig) -> jnp.ndarray:
    cats = [
        qembed(p["opcode"], batch["opcode"]),
        qdense(p["regbits"], batch["regbits"]),
        qdense(p["flags"], batch["flags"]),
        qdense(p["brhist"], batch["brhist"]),
        qdense(p["memdist"], batch["memdist"]),
    ]
    x = jnp.concatenate(cats, axis=-1)
    return gelu(qdense(p["combine"], x))


def _block_q(p: Dict, h: jnp.ndarray, cfg: TaoConfig, causal: bool) -> jnp.ndarray:
    B, W, d = h.shape
    nh, hd = cfg.n_heads, cfg.head_dim
    x = layernorm(p["ln1"], h)
    qkv = qdense(p["qkv"], x).reshape(B, W, 3, nh, hd)
    q, k, v = (qkv[:, :, i].transpose(0, 2, 1, 3) for i in range(3))
    o = _attention(q, k, v, causal, cfg.use_pallas)
    o = o.transpose(0, 2, 1, 3).reshape(B, W, d)
    h = h + qdense(p["proj"], o)
    x = layernorm(p["ln2"], h)
    h = h + qdense(p["down"], gelu(qdense(p["up"], x)))
    return h


def _apply_pred_q(
    p: Dict, h: jnp.ndarray, cfg: TaoConfig, causal: bool = True
) -> Dict[str, jnp.ndarray]:
    W = h.shape[1]
    h = h + p["pos"][:W]
    for blk in p["blocks"]:
        h = _block_q(blk, h, cfg, causal)
    h = layernorm(p["ln_f"], h)
    lat = qdense(p["head_lat"], h)
    nb = NUM_LAT_BUCKETS
    return {
        "fetch_lat_logits": lat[..., :nb],
        "exec_lat_logits": lat[..., nb:],
        "fetch_lat": expected_latency(lat[..., :nb]),
        "exec_lat": expected_latency(lat[..., nb:]),
        "mispred_logit": qdense(p["head_branch"], h)[..., 0],
        "dlevel_logits": qdense(p["head_dlevel"], h),
        "icache_logit": qdense(p["head_icache"], h)[..., 0],
        "tlb_logit": qdense(p["head_tlb"], h)[..., 0],
    }


def tao_forward_int8(
    params: Dict, batch: Dict, cfg: TaoConfig
) -> Dict[str, jnp.ndarray]:
    """Quantized twin of ``core.model.tao_forward`` over a tree from
    ``quantize_tao_params``; same output dict, same shapes/dtypes."""
    h = _apply_embed_q(params["embed"], batch, cfg)
    h = qdense(params["adapt"], h)
    return _apply_pred_q(params["pred"], h, cfg)
