"""Windowed training dataset construction over extracted features.

The model consumes windows of W = N+1 consecutive instructions and predicts
metrics for every position (causal attention), which is the batched
equivalent of the paper's "current instruction + N context instructions"
formulation.  Duplicate windows are removed (the paper de-duplicates
samples during preprocessing).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

from .features import FeatureSet

__all__ = ["WindowDataset", "build_windows", "concat_datasets"]

_INPUT_KEYS = ("opcode", "regbits", "flags", "brhist", "memdist")
_LABEL_KEYS = (
    "fetch_lat",
    "exec_lat",
    "mispred",
    "dlevel",
    "icache_miss",
    "tlb_miss",
    "is_branch",
    "is_mem",
)


@dataclasses.dataclass
class WindowDataset:
    """Stacked windows: inputs[k] has shape (num_windows, W, ...)."""

    inputs: Dict[str, np.ndarray]
    labels: Optional[Dict[str, np.ndarray]]

    def __len__(self) -> int:
        return len(self.inputs["opcode"])

    @property
    def window(self) -> int:
        return self.inputs["opcode"].shape[1]

    def batches(
        self, batch_size: int, rng: Optional[np.random.Generator] = None, drop_last: bool = True
    ) -> Iterator[Dict[str, np.ndarray]]:
        n = len(self)
        order = np.arange(n)
        if rng is not None:
            rng.shuffle(order)
        stop = n - (n % batch_size) if drop_last else n
        for lo in range(0, stop, batch_size):
            idx = order[lo : lo + batch_size]
            out = {k: v[idx] for k, v in self.inputs.items()}
            if self.labels is not None:
                out["labels"] = {k: v[idx] for k, v in self.labels.items()}
            yield out

    def subsample(self, n: int, seed: int = 0) -> "WindowDataset":
        if n >= len(self):
            return self
        idx = np.random.default_rng(seed).choice(len(self), size=n, replace=False)
        return WindowDataset(
            inputs={k: v[idx] for k, v in self.inputs.items()},
            labels=None
            if self.labels is None
            else {k: v[idx] for k, v in self.labels.items()},
        )


def build_windows(
    fs: FeatureSet,
    window: int,
    stride: Optional[int] = None,
    dedup: bool = True,
) -> WindowDataset:
    stride = stride or window
    n = len(fs)
    starts = list(range(0, max(1, n - window + 1), stride))

    def _stack(arr: np.ndarray) -> np.ndarray:
        return np.stack([arr[s : s + window] for s in starts])

    inputs = {
        "opcode": _stack(fs.opcode),
        "regbits": _stack(fs.regbits),
        "flags": _stack(fs.flags),
        "brhist": _stack(fs.brhist),
        "memdist": _stack(fs.memdist),
    }
    labels = None
    if fs.labels is not None:
        labels = {k: _stack(fs.labels[k]) for k in _LABEL_KEYS}

    if dedup:
        keep = _dedup_mask(inputs, labels)
        inputs = {k: v[keep] for k, v in inputs.items()}
        if labels is not None:
            labels = {k: v[keep] for k, v in labels.items()}

    return WindowDataset(inputs=inputs, labels=labels)


def _dedup_mask(inputs: Dict, labels: Optional[Dict]) -> np.ndarray:
    """Drop windows whose (features, labels) content is byte-identical."""
    n = len(inputs["opcode"])
    seen = set()
    keep = np.zeros(n, dtype=bool)
    lat = labels["fetch_lat"] if labels is not None else None
    for i in range(n):
        h = hashlib.blake2b(digest_size=16)
        h.update(inputs["opcode"][i].tobytes())
        h.update(inputs["memdist"][i].tobytes())
        h.update(inputs["brhist"][i].tobytes())
        if lat is not None:
            h.update(lat[i].tobytes())
            h.update(labels["exec_lat"][i].tobytes())
        d = h.digest()
        if d not in seen:
            seen.add(d)
            keep[i] = True
    return keep


def concat_datasets(parts: Sequence[WindowDataset]) -> WindowDataset:
    inputs = {
        k: np.concatenate([p.inputs[k] for p in parts]) for k in _INPUT_KEYS
    }
    labels = None
    if parts[0].labels is not None:
        labels = {
            k: np.concatenate([p.labels[k] for p in parts]) for k in _LABEL_KEYS
        }
    return WindowDataset(inputs=inputs, labels=labels)
