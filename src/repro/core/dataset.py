"""Windowed training dataset construction over extracted features.

The model consumes windows of W = N+1 consecutive instructions and predicts
metrics for every position (causal attention), which is the batched
equivalent of the paper's "current instruction + N context instructions"
formulation.  Duplicate windows are removed (the paper de-duplicates
samples during preprocessing).

Windowing is zero-copy: `window_view` returns a strided view
(`np.lib.stride_tricks.sliding_window_view`) so a trace of N instructions
costs O(N) memory regardless of the window/stride combination; data is only
materialized per-batch by `WindowDataset.batches` / the streaming engine.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

from .features import FeatureSet

__all__ = [
    "WindowDataset",
    "StreamingWindowDataset",
    "build_windows",
    "window_view",
    "num_windows",
    "stream_batches",
    "iter_window_digests",
    "concat_datasets",
    "INPUT_KEYS",
]


def num_windows(n: int, window: int, stride: int) -> int:
    """Number of windows the grid `range(0, max(1, n - window + 1), stride)`
    produces — the single source of truth shared by every windowing path."""
    return len(range(0, max(1, n - window + 1), stride))


def window_view(arr: np.ndarray, window: int, stride: int) -> np.ndarray:
    """(N, ...) -> zero-copy (num_windows, window, ...) strided view.

    Matches the legacy copying grid exactly, including the n < window case
    (a single truncated window, which genuinely requires a 1-row copy).
    """
    n = len(arr)
    if n < window:
        return arr[np.newaxis]
    view = np.lib.stride_tricks.sliding_window_view(arr, window, axis=0)
    # sliding_window_view appends the window axis last; put it after the
    # window-count axis (still a view — only strides change).
    view = np.moveaxis(view, -1, 1)
    return view[::stride]

INPUT_KEYS = ("opcode", "regbits", "flags", "brhist", "memdist")
_INPUT_KEYS = INPUT_KEYS  # internal alias
_LABEL_KEYS = (
    "fetch_lat",
    "exec_lat",
    "mispred",
    "dlevel",
    "icache_miss",
    "tlb_miss",
    "is_branch",
    "is_mem",
)


@dataclasses.dataclass
class WindowDataset:
    """Stacked windows: inputs[k] has shape (num_windows, W, ...)."""

    inputs: Dict[str, np.ndarray]
    labels: Optional[Dict[str, np.ndarray]]

    def __len__(self) -> int:
        return len(self.inputs["opcode"])

    @property
    def window(self) -> int:
        return self.inputs["opcode"].shape[1]

    def batches(
        self, batch_size: int, rng: Optional[np.random.Generator] = None, drop_last: bool = True
    ) -> Iterator[Dict[str, np.ndarray]]:
        n = len(self)
        order = np.arange(n)
        if rng is not None:
            rng.shuffle(order)
        stop = n - (n % batch_size) if drop_last else n
        for lo in range(0, stop, batch_size):
            idx = order[lo : lo + batch_size]
            out = {k: v[idx] for k, v in self.inputs.items()}
            if self.labels is not None:
                out["labels"] = {k: v[idx] for k, v in self.labels.items()}
            yield out

    def subsample(self, n: int, seed: int = 0) -> "WindowDataset":
        if n >= len(self):
            return self
        idx = np.random.default_rng(seed).choice(len(self), size=n, replace=False)
        return WindowDataset(
            inputs={k: v[idx] for k, v in self.inputs.items()},
            labels=None
            if self.labels is None
            else {k: v[idx] for k, v in self.labels.items()},
        )


def build_windows(
    fs: FeatureSet,
    window: int,
    stride: Optional[int] = None,
    dedup: bool = True,
) -> WindowDataset:
    stride = stride or window

    def _stack(arr: np.ndarray) -> np.ndarray:
        return window_view(arr, window, stride)

    inputs = {
        "opcode": _stack(fs.opcode),
        "regbits": _stack(fs.regbits),
        "flags": _stack(fs.flags),
        "brhist": _stack(fs.brhist),
        "memdist": _stack(fs.memdist),
    }
    labels = None
    if fs.labels is not None:
        labels = {k: _stack(fs.labels[k]) for k in _LABEL_KEYS}

    if dedup:
        keep = _dedup_mask(inputs, labels)
        inputs = {k: v[keep] for k, v in inputs.items()}
        if labels is not None:
            labels = {k: v[keep] for k, v in labels.items()}

    return WindowDataset(inputs=inputs, labels=labels)


def _pad_rows(arr: np.ndarray, rows: int) -> np.ndarray:
    out = np.zeros((rows,) + arr.shape[1:], dtype=arr.dtype)
    out[: len(arr)] = arr
    return out


def stream_batches(
    fs: FeatureSet,
    window: int,
    batch_size: int,
    stride: Optional[int] = None,
    pad: bool = True,
    extra: Optional[Dict[str, np.ndarray]] = None,
) -> Iterator[Dict[str, np.ndarray]]:
    """Stream fixed-shape window batches without materializing all windows.

    Windows come from zero-copy `window_view`s; each yielded batch is the only
    materialized copy, so peak host memory is O(trace + batch) even for
    multi-million-instruction traces.  Every batch carries a float32 "valid"
    mask of shape (batch_size, W); when `pad` is set the final ragged batch is
    zero-padded to `batch_size` rows (mask rows 0) so a single jit
    compilation covers the whole stream.  `extra` arrays (e.g. the trace's
    is_branch/is_mem columns) are windowed on the same grid and yielded
    alongside the feature keys.
    """
    stride = stride or window
    views = {k: window_view(getattr(fs, k), window, stride) for k in _INPUT_KEYS}
    if extra:
        views.update({k: window_view(v, window, stride) for k, v in extra.items()})
    nw = len(views["opcode"])
    w_eff = views["opcode"].shape[1]
    for lo in range(0, nw, batch_size):
        hi = min(lo + batch_size, nw)
        rows = batch_size if pad else hi - lo
        batch = {k: _pad_rows(v[lo:hi], rows) for k, v in views.items()}
        valid = np.zeros((rows, w_eff), dtype=np.float32)
        valid[: hi - lo] = 1.0
        batch["valid"] = valid
        yield batch


# windows hashed per contiguous block by iter_window_digests
_DEDUP_CHUNK = 2048


def iter_window_digests(
    inputs: Dict, labels: Optional[Dict], chunk: int = _DEDUP_CHUNK
) -> Iterator[bytes]:
    """Per-window blake2b digest stream, hashing contiguous row-blocks.

    Byte-compatible with the original per-row loop — a blake2b stream over
    concatenated updates equals one update over the concatenation, so
    assembling each window's bytes (opcode, memdist, brhist, then
    fetch/exec latencies when labels are present) into ONE contiguous row
    yields the exact same digests.  Per ``chunk`` windows the source arrays
    are block-copied into a single (rows, row_bytes) uint8 matrix and each
    row is hashed with one one-shot blake2b call over a zero-copy
    memoryview slice, replacing 3-5 per-row NumPy indexing + ``tobytes``
    copies + hash updates per window.  The remaining cost is the blake2b
    compression itself.  Works directly on zero-copy strided window views —
    at most ``chunk`` windows are materialized at a time, never the whole
    window set.
    """
    arrays = [inputs["opcode"], inputs["memdist"], inputs["brhist"]]
    if labels is not None:
        arrays += [labels["fetch_lat"], labels["exec_lat"]]
    n = len(arrays[0])
    row_bytes = [
        a.dtype.itemsize * int(np.prod(a.shape[1:], dtype=np.int64))
        for a in arrays
    ]
    total = sum(row_bytes)
    blake2b = hashlib.blake2b
    for lo in range(0, n, chunk):
        hi = min(lo + chunk, n)
        rows = hi - lo
        buf = np.empty((rows, total), np.uint8)
        col = 0
        for a, rb in zip(arrays, row_bytes):
            blk = np.ascontiguousarray(a[lo:hi])
            buf[:, col : col + rb] = blk.view(np.uint8).reshape(rows, rb)
            col += rb
        mv = memoryview(buf).cast("B")
        for i in range(rows):
            yield blake2b(
                mv[i * total : (i + 1) * total], digest_size=16
            ).digest()


def _dedup_mask(
    inputs: Dict, labels: Optional[Dict], seen: Optional[set] = None
) -> np.ndarray:
    """Drop windows whose (features, labels) content is byte-identical.

    ``seen`` — a digest reservoir (16 B per unique window) — lets streaming
    callers carry the keep-set across traces; by default each call dedups
    independently, exactly like the original per-row implementation.
    """
    n = len(inputs["opcode"])
    if seen is None:
        seen = set()
    keep = np.zeros(n, dtype=bool)
    for i, d in enumerate(iter_window_digests(inputs, labels)):
        if d not in seen:
            seen.add(d)
            keep[i] = True
    return keep


@dataclasses.dataclass
class _StreamPart:
    """One trace's zero-copy window views (plus label views)."""

    inputs: Dict[str, np.ndarray]
    labels: Optional[Dict[str, np.ndarray]]


class StreamingWindowDataset:
    """O(trace + batch) drop-in for ``WindowDataset`` over 1..N feature sets.

    Construction keeps only zero-copy ``window_view``s of the underlying
    ``FeatureSet`` arrays plus the streaming-dedup keep set (a blake2b
    digest reservoir: O(unique windows) memory, bit-identical keep set to
    ``_dedup_mask``).  ``batches`` shuffles a *window-index* permutation and
    gathers every batch straight out of the strided views, so peak host
    memory is O(traces + one batch) instead of O(all windows) — nothing
    beyond the yielded batch is ever materialized.

    ``dedup_scope="trace"`` (default) dedups each feature set independently,
    mirroring the materialized pipeline (``concat_datasets`` of per-trace
    ``build_windows``) — this is what makes the keep set, batch stream, and
    therefore the whole training trajectory bit-identical to the
    materialized path under the same seed.  ``"global"`` shares one
    reservoir across traces for strictly stronger dedup on multi-trace
    corpora.

    Interchangeable with ``WindowDataset`` wherever the ``batches`` /
    ``subsample`` / ``len`` contract is used (the trainers, the Session
    facade); the stacked ``.inputs``/``.labels`` arrays intentionally do
    not exist here — call ``materialize()`` when a consumer genuinely
    needs every window in memory.
    """

    def __init__(
        self,
        features,
        window: int,
        stride: Optional[int] = None,
        dedup: bool = True,
        dedup_scope: str = "trace",
    ):
        if isinstance(features, FeatureSet):
            features = [features]
        features = list(features)
        if not features:
            raise ValueError("StreamingWindowDataset needs >= 1 FeatureSet")
        if dedup_scope not in ("trace", "global"):
            raise ValueError(
                f"dedup_scope must be 'trace' or 'global', got {dedup_scope!r}"
            )
        stride = stride or window
        has_labels = features[0].labels is not None
        parts: List[_StreamPart] = []
        for fs in features:
            if (fs.labels is not None) != has_labels:
                raise ValueError(
                    "all feature sets of one dataset must agree on labels"
                )
            inputs = {
                k: window_view(getattr(fs, k), window, stride)
                for k in _INPUT_KEYS
            }
            labels = None
            if has_labels:
                labels = {
                    k: window_view(fs.labels[k], window, stride)
                    for k in _LABEL_KEYS
                }
            parts.append(_StreamPart(inputs=inputs, labels=labels))
        # geometry check BEFORE the dedup pass: views are free, hashing a
        # multi-million-window corpus is not
        w_effs = {p.inputs["opcode"].shape[1] for p in parts}
        if len(w_effs) != 1:
            raise ValueError(
                f"feature sets produce mixed effective windows "
                f"{sorted(w_effs)}: every trace of one dataset must share a "
                "window geometry (the jitted train step compiles per "
                "geometry)"
            )
        keeps: List[np.ndarray] = []
        reservoir: set = set()
        for part in parts:
            if dedup:
                seen = reservoir if dedup_scope == "global" else set()
                keep = np.flatnonzero(
                    _dedup_mask(part.inputs, part.labels, seen=seen)
                )
            else:
                keep = np.arange(len(part.inputs["opcode"]), dtype=np.int64)
            keeps.append(keep.astype(np.int64))
        self._parts = parts
        # flat kept-window index -> (part, local window) lookup: O(windows)
        # *integers*, the only per-window state the streaming path keeps
        self._part_id = np.concatenate(
            [np.full(len(k), i, np.int32) for i, k in enumerate(keeps)]
        )
        self._local = np.concatenate(keeps)
        self.num_dropped = (
            sum(len(p.inputs["opcode"]) for p in parts) - len(self._local)
        )

    def __len__(self) -> int:
        return len(self._local)

    @property
    def window(self) -> int:
        return self._parts[0].inputs["opcode"].shape[1]

    @property
    def has_labels(self) -> bool:
        return self._parts[0].labels is not None

    def _gather_key(
        self, views: List[np.ndarray], part_id: np.ndarray, local: np.ndarray
    ) -> np.ndarray:
        if len(views) == 1:
            return views[0][local]
        v0 = views[0]
        out = np.empty((len(part_id),) + v0.shape[1:], dtype=v0.dtype)
        for p in np.unique(part_id):
            m = part_id == p
            out[m] = views[p][local[m]]
        return out

    def gather(self, idx: np.ndarray) -> Dict[str, np.ndarray]:
        """Materialize the windows at kept positions ``idx`` — the only
        copy the streaming path ever makes (one batch at a time)."""
        part_id = self._part_id[idx]
        local = self._local[idx]
        out = {
            k: self._gather_key(
                [p.inputs[k] for p in self._parts], part_id, local
            )
            for k in _INPUT_KEYS
        }
        if self.has_labels:
            out["labels"] = {
                k: self._gather_key(
                    [p.labels[k] for p in self._parts], part_id, local
                )
                for k in _LABEL_KEYS
            }
        return out

    def batches(
        self,
        batch_size: int,
        rng: Optional[np.random.Generator] = None,
        drop_last: bool = True,
    ) -> Iterator[Dict[str, np.ndarray]]:
        """Same contract — and bit-identical batch stream for the same
        ``rng`` state — as ``WindowDataset.batches``, materializing only
        O(batch) windows via per-batch gather."""
        n = len(self)
        order = np.arange(n)
        if rng is not None:
            rng.shuffle(order)
        stop = n - (n % batch_size) if drop_last else n
        for lo in range(0, stop, batch_size):
            yield self.gather(order[lo : lo + batch_size])

    def subsample(self, n: int, seed: int = 0) -> "StreamingWindowDataset":
        """Uniform window subsample — same selection as
        ``WindowDataset.subsample`` (identical rng draw over identical
        length), but O(indices): only the kept-index lookup shrinks, the
        zero-copy views are shared with the parent."""
        if n >= len(self):
            return self
        idx = np.random.default_rng(seed).choice(len(self), size=n, replace=False)
        out = object.__new__(StreamingWindowDataset)
        out._parts = self._parts
        out._part_id = self._part_id[idx]
        out._local = self._local[idx]
        out.num_dropped = self.num_dropped
        return out

    def materialize(self) -> WindowDataset:
        """Copy every kept window into a ``WindowDataset`` (small runs and
        equivalence tests; defeats the purpose at scale)."""
        full = self.gather(np.arange(len(self)))
        return WindowDataset(
            inputs={k: full[k] for k in _INPUT_KEYS},
            labels=full.get("labels"),
        )


def concat_datasets(parts: Sequence[WindowDataset]) -> WindowDataset:
    inputs = {
        k: np.concatenate([p.inputs[k] for p in parts]) for k in _INPUT_KEYS
    }
    labels = None
    if parts[0].labels is not None:
        labels = {
            k: np.concatenate([p.labels[k] for p in parts]) for k in _LABEL_KEYS
        }
    return WindowDataset(inputs=inputs, labels=labels)
