"""Windowed training dataset construction over extracted features.

The model consumes windows of W = N+1 consecutive instructions and predicts
metrics for every position (causal attention), which is the batched
equivalent of the paper's "current instruction + N context instructions"
formulation.  Duplicate windows are removed (the paper de-duplicates
samples during preprocessing).

Windowing is zero-copy: `window_view` returns a strided view
(`np.lib.stride_tricks.sliding_window_view`) so a trace of N instructions
costs O(N) memory regardless of the window/stride combination; data is only
materialized per-batch by `WindowDataset.batches` / the streaming engine.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

from .features import FeatureSet

__all__ = [
    "WindowDataset",
    "build_windows",
    "window_view",
    "num_windows",
    "stream_batches",
    "concat_datasets",
    "INPUT_KEYS",
]


def num_windows(n: int, window: int, stride: int) -> int:
    """Number of windows the grid `range(0, max(1, n - window + 1), stride)`
    produces — the single source of truth shared by every windowing path."""
    return len(range(0, max(1, n - window + 1), stride))


def window_view(arr: np.ndarray, window: int, stride: int) -> np.ndarray:
    """(N, ...) -> zero-copy (num_windows, window, ...) strided view.

    Matches the legacy copying grid exactly, including the n < window case
    (a single truncated window, which genuinely requires a 1-row copy).
    """
    n = len(arr)
    if n < window:
        return arr[np.newaxis]
    view = np.lib.stride_tricks.sliding_window_view(arr, window, axis=0)
    # sliding_window_view appends the window axis last; put it after the
    # window-count axis (still a view — only strides change).
    view = np.moveaxis(view, -1, 1)
    return view[::stride]

INPUT_KEYS = ("opcode", "regbits", "flags", "brhist", "memdist")
_INPUT_KEYS = INPUT_KEYS  # internal alias
_LABEL_KEYS = (
    "fetch_lat",
    "exec_lat",
    "mispred",
    "dlevel",
    "icache_miss",
    "tlb_miss",
    "is_branch",
    "is_mem",
)


@dataclasses.dataclass
class WindowDataset:
    """Stacked windows: inputs[k] has shape (num_windows, W, ...)."""

    inputs: Dict[str, np.ndarray]
    labels: Optional[Dict[str, np.ndarray]]

    def __len__(self) -> int:
        return len(self.inputs["opcode"])

    @property
    def window(self) -> int:
        return self.inputs["opcode"].shape[1]

    def batches(
        self, batch_size: int, rng: Optional[np.random.Generator] = None, drop_last: bool = True
    ) -> Iterator[Dict[str, np.ndarray]]:
        n = len(self)
        order = np.arange(n)
        if rng is not None:
            rng.shuffle(order)
        stop = n - (n % batch_size) if drop_last else n
        for lo in range(0, stop, batch_size):
            idx = order[lo : lo + batch_size]
            out = {k: v[idx] for k, v in self.inputs.items()}
            if self.labels is not None:
                out["labels"] = {k: v[idx] for k, v in self.labels.items()}
            yield out

    def subsample(self, n: int, seed: int = 0) -> "WindowDataset":
        if n >= len(self):
            return self
        idx = np.random.default_rng(seed).choice(len(self), size=n, replace=False)
        return WindowDataset(
            inputs={k: v[idx] for k, v in self.inputs.items()},
            labels=None
            if self.labels is None
            else {k: v[idx] for k, v in self.labels.items()},
        )


def build_windows(
    fs: FeatureSet,
    window: int,
    stride: Optional[int] = None,
    dedup: bool = True,
) -> WindowDataset:
    stride = stride or window

    def _stack(arr: np.ndarray) -> np.ndarray:
        return window_view(arr, window, stride)

    inputs = {
        "opcode": _stack(fs.opcode),
        "regbits": _stack(fs.regbits),
        "flags": _stack(fs.flags),
        "brhist": _stack(fs.brhist),
        "memdist": _stack(fs.memdist),
    }
    labels = None
    if fs.labels is not None:
        labels = {k: _stack(fs.labels[k]) for k in _LABEL_KEYS}

    if dedup:
        keep = _dedup_mask(inputs, labels)
        inputs = {k: v[keep] for k, v in inputs.items()}
        if labels is not None:
            labels = {k: v[keep] for k, v in labels.items()}

    return WindowDataset(inputs=inputs, labels=labels)


def _pad_rows(arr: np.ndarray, rows: int) -> np.ndarray:
    out = np.zeros((rows,) + arr.shape[1:], dtype=arr.dtype)
    out[: len(arr)] = arr
    return out


def stream_batches(
    fs: FeatureSet,
    window: int,
    batch_size: int,
    stride: Optional[int] = None,
    pad: bool = True,
    extra: Optional[Dict[str, np.ndarray]] = None,
) -> Iterator[Dict[str, np.ndarray]]:
    """Stream fixed-shape window batches without materializing all windows.

    Windows come from zero-copy `window_view`s; each yielded batch is the only
    materialized copy, so peak host memory is O(trace + batch) even for
    multi-million-instruction traces.  Every batch carries a float32 "valid"
    mask of shape (batch_size, W); when `pad` is set the final ragged batch is
    zero-padded to `batch_size` rows (mask rows 0) so a single jit
    compilation covers the whole stream.  `extra` arrays (e.g. the trace's
    is_branch/is_mem columns) are windowed on the same grid and yielded
    alongside the feature keys.
    """
    stride = stride or window
    views = {k: window_view(getattr(fs, k), window, stride) for k in _INPUT_KEYS}
    if extra:
        views.update({k: window_view(v, window, stride) for k, v in extra.items()})
    nw = len(views["opcode"])
    w_eff = views["opcode"].shape[1]
    for lo in range(0, nw, batch_size):
        hi = min(lo + batch_size, nw)
        rows = batch_size if pad else hi - lo
        batch = {k: _pad_rows(v[lo:hi], rows) for k, v in views.items()}
        valid = np.zeros((rows, w_eff), dtype=np.float32)
        valid[: hi - lo] = 1.0
        batch["valid"] = valid
        yield batch


def _dedup_mask(inputs: Dict, labels: Optional[Dict]) -> np.ndarray:
    """Drop windows whose (features, labels) content is byte-identical."""
    n = len(inputs["opcode"])
    seen = set()
    keep = np.zeros(n, dtype=bool)
    lat = labels["fetch_lat"] if labels is not None else None
    for i in range(n):
        h = hashlib.blake2b(digest_size=16)
        h.update(inputs["opcode"][i].tobytes())
        h.update(inputs["memdist"][i].tobytes())
        h.update(inputs["brhist"][i].tobytes())
        if lat is not None:
            h.update(lat[i].tobytes())
            h.update(labels["exec_lat"][i].tobytes())
        d = h.digest()
        if d not in seen:
            seen.add(d)
            keep[i] = True
    return keep


def concat_datasets(parts: Sequence[WindowDataset]) -> WindowDataset:
    inputs = {
        k: np.concatenate([p.inputs[k] for p in parts]) for k in _INPUT_KEYS
    }
    labels = None
    if parts[0].labels is not None:
        labels = {
            k: np.concatenate([p.labels[k] for p in parts]) for k in _LABEL_KEYS
        }
    return WindowDataset(inputs=inputs, labels=labels)
