"""SimNet baseline (Li et al., SIGMETRICS'22) — the state of the art Tao
compares against.

Key contrasts with Tao, reproduced faithfully:
  * INPUT: µarch-SPECIFIC detailed-trace features — the model consumes
    branch-mispredict flags and data-access levels as inputs (so a new µarch
    needs a new detailed trace: the regeneration cost Table 4 charges it for).
  * MODEL: 1-D CNN (the paper's "C3 hybrid" configuration) over the
    instruction context window, numerical feature rows rather than learned
    per-category embeddings.
  * OUTPUT: instruction latency only (single-metric).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from ..nn.core import dense, gelu, init_dense
from ..train.optim import AdamWConfig, adamw_update

__all__ = ["SimNetConfig", "init_simnet", "simnet_forward", "simnet_features", "make_simnet_step"]


@dataclasses.dataclass(frozen=True)
class SimNetConfig:
    window: int = 129
    channels: int = 128
    n_conv: int = 3          # the C3 configuration
    kernel_size: int = 5
    feat_dim: int = 44       # opcode onehot(15) + regbits(... compressed) + metrics


def simnet_features(adj_trace: np.ndarray) -> Dict[str, np.ndarray]:
    """µarch-specific input rows: static properties + detailed-trace metrics.

    This is exactly what makes SimNet's inputs non-reusable across µarchs.
    """
    n = len(adj_trace)
    op = adj_trace["opcode"].astype(np.int64)
    onehot = np.zeros((n, 15), np.float32)
    onehot[np.arange(n), op] = 1.0
    regs = np.stack(
        [
            adj_trace["dst"].astype(np.float32) / 32.0,
            adj_trace["src1"].astype(np.float32) / 32.0,
            adj_trace["src2"].astype(np.float32) / 32.0,
        ],
        axis=1,
    )
    flags = np.stack(
        [
            adj_trace["is_branch"].astype(np.float32),
            adj_trace["taken"].astype(np.float32),
            adj_trace["is_mem"].astype(np.float32),
            adj_trace["is_store"].astype(np.float32),
        ],
        axis=1,
    )
    # µarch-specific metric inputs (SimNet's defining dependence):
    dlevel = np.zeros((n, 4), np.float32)
    dlevel[np.arange(n), adj_trace["dlevel"].astype(np.int64)] = 1.0
    metrics = np.concatenate(
        [
            dlevel,
            adj_trace["mispred"].astype(np.float32)[:, None],
            adj_trace["icache_miss"].astype(np.float32)[:, None],
            adj_trace["tlb_miss"].astype(np.float32)[:, None],
        ],
        axis=1,
    )
    addr = (adj_trace["addr"].astype(np.float64) % (1 << 20)) / float(1 << 20)
    x = np.concatenate(
        [onehot, regs, flags, metrics, addr[:, None].astype(np.float32)], axis=1
    )
    # pad feature dim to cfg.feat_dim
    want = SimNetConfig().feat_dim
    if x.shape[1] < want:
        x = np.pad(x, ((0, 0), (0, want - x.shape[1])))
    labels = np.stack(
        [
            adj_trace["fetch_lat"].astype(np.float32),
            adj_trace["exec_lat"].astype(np.float32),
        ],
        axis=1,
    )
    return {"x": x, "labels": labels}


def init_simnet(key, cfg: SimNetConfig) -> Dict:
    ks = jax.random.split(key, cfg.n_conv + 2)
    params = {"convs": []}
    cin = cfg.feat_dim
    for i in range(cfg.n_conv):
        params["convs"].append(
            {
                "w": 0.02
                * jax.random.normal(ks[i], (cfg.kernel_size, cin, cfg.channels)),
                "b": jnp.zeros((cfg.channels,)),
            }
        )
        cin = cfg.channels
    params["fc1"] = init_dense(ks[-2], cfg.channels, cfg.channels)
    params["head"] = init_dense(ks[-1], cfg.channels, 2)
    return params


def simnet_forward(params: Dict, x: jnp.ndarray, cfg: SimNetConfig) -> jnp.ndarray:
    """x: (B, W, F) -> (B, W, 2) latency predictions (log1p space).

    Causal 1-D convolutions: left-padded so position i sees only <= i.
    """
    h = x
    for conv in params["convs"]:
        k = conv["w"].shape[0]
        hp = jnp.pad(h, ((0, 0), (k - 1, 0), (0, 0)))
        h = jax.lax.conv_general_dilated(
            hp,
            conv["w"],
            window_strides=(1,),
            padding="VALID",
            dimension_numbers=("NWC", "WIO", "NWC"),
        )
        h = gelu(h + conv["b"])
    h = gelu(dense(params["fc1"], h))
    return dense(params["head"], h)


def make_simnet_step(cfg: SimNetConfig, opt_cfg: AdamWConfig):
    def loss_fn(params, batch):
        preds = simnet_forward(params, batch["x"], cfg)
        from .model import LAT_SCALE  # same linear-space regression as Tao

        tgt = batch["labels"] / LAT_SCALE
        return jnp.mean(jnp.square(preds - tgt))

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt, _ = adamw_update(params, grads, opt, opt_cfg)
        return params, opt, loss

    return step


def simnet_windows(feats: Dict[str, np.ndarray], window: int) -> Dict[str, np.ndarray]:
    n = len(feats["x"])
    starts = range(0, max(1, n - window + 1), window)
    return {
        "x": np.stack([feats["x"][s : s + window] for s in starts]),
        "labels": np.stack([feats["labels"][s : s + window] for s in starts]),
    }
