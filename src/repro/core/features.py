"""§4.2 Feature engineering from the µarch-agnostic functional trace.

Per-instruction features: opcode id (lookup-table embedding downstream),
register bitmap (src+dst, NUM_REGS wide), instruction flags.

Cross-instruction features:
  * branch-history hash table — N_b buckets × N_q outcomes keyed by
    (pc>>2) % N_b; a conditional branch's feature is its bucket's recent
    outcome queue (most-recent first; 0 for empty slots, ±1 for
    not-taken/taken).  Hash collisions deliberately mix histories of
    different branches, providing a lightweight global history (paper Fig 4).
  * memory access-distance queue — signed-log-compressed deltas between the
    current access address and the previous N_m accesses (paper Fig 3), a
    cheap stand-in for reuse/stack distance.

Defaults N_b=1024, N_q=32, N_m=64 are the paper's empirically chosen values
(§5.4); the benchmark harness sweeps them (Fig 12).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from ..uarch.isa import NUM_REGS, Op

__all__ = ["FeatureConfig", "FeatureSet", "extract_features", "NUM_OPCODES"]

NUM_OPCODES = len(Op)


@dataclasses.dataclass(frozen=True)
class FeatureConfig:
    n_buckets: int = 1024   # N_b
    n_queue: int = 32       # N_q
    n_mem: int = 64         # N_m

    @property
    def flags_dim(self) -> int:
        return 5  # is_branch, taken, is_mem, is_store, is_fp


@dataclasses.dataclass
class FeatureSet:
    """Model inputs (+ labels when built from an adjusted trace)."""

    opcode: np.ndarray      # (N,) int32
    regbits: np.ndarray     # (N, NUM_REGS) float32
    flags: np.ndarray       # (N, 5) float32
    brhist: np.ndarray      # (N, N_q) float32 in {-1, 0, +1}
    memdist: np.ndarray     # (N, N_m) float32 signed-log deltas
    labels: Optional[Dict[str, np.ndarray]] = None

    def __len__(self) -> int:
        return len(self.opcode)

    def slice(self, lo: int, hi: int) -> "FeatureSet":
        lab = None
        if self.labels is not None:
            lab = {k: v[lo:hi] for k, v in self.labels.items()}
        return FeatureSet(
            opcode=self.opcode[lo:hi],
            regbits=self.regbits[lo:hi],
            flags=self.flags[lo:hi],
            brhist=self.brhist[lo:hi],
            memdist=self.memdist[lo:hi],
            labels=lab,
        )


_FP_OPS = (int(Op.FALU), int(Op.FMUL), int(Op.FDIV))


def extract_features(
    trace: np.ndarray, cfg: FeatureConfig = FeatureConfig(), with_labels: bool = True
) -> FeatureSet:
    """`trace` is either an adjusted trace (ADJ_DTYPE, labels available) or a
    raw functional trace (FUNC_TRACE_DTYPE, inference path)."""
    n = len(trace)
    opcode = trace["opcode"].astype(np.int32)

    # ---- per-instruction features (vectorized) -------------------------
    regbits = np.zeros((n, NUM_REGS), dtype=np.float32)
    rows = np.arange(n)
    regbits[rows, trace["src1"].astype(np.int64)] = 1.0
    regbits[rows, trace["src2"].astype(np.int64)] = 1.0
    # dst included too (paper: both source and destination registers)
    regbits[rows, trace["dst"].astype(np.int64)] = 1.0

    is_fp = np.isin(opcode, _FP_OPS)
    flags = np.stack(
        [
            trace["is_branch"].astype(np.float32),
            trace["taken"].astype(np.float32),
            trace["is_mem"].astype(np.float32),
            trace["is_store"].astype(np.float32),
            is_fp.astype(np.float32),
        ],
        axis=1,
    )

    # ---- branch-history hash table (sequential over branches) ----------
    brhist = np.zeros((n, cfg.n_queue), dtype=np.float32)
    table = np.zeros((cfg.n_buckets, cfg.n_queue), dtype=np.float32)
    br_idx = np.nonzero(trace["is_branch"])[0]
    br_pc = (trace["pc"][br_idx] >> 2) % cfg.n_buckets
    br_taken = np.where(trace["taken"][br_idx], 1.0, -1.0).astype(np.float32)
    for j in range(len(br_idx)):
        b = br_pc[j]
        row = table[b]
        brhist[br_idx[j]] = row
        # push most-recent-first
        row[1:] = row[:-1]
        row[0] = br_taken[j]

    # ---- memory access-distance queue (sequential over mem ops) --------
    memdist = np.zeros((n, cfg.n_mem), dtype=np.float32)
    queue = np.zeros(cfg.n_mem, dtype=np.int64)
    filled = 0
    mem_idx = np.nonzero(trace["is_mem"])[0]
    addrs = trace["addr"][mem_idx].astype(np.int64)
    for j in range(len(mem_idx)):
        a = addrs[j]
        if filled:
            d = (a - queue[:filled]).astype(np.float64)
            memdist[mem_idx[j], :filled] = (
                np.sign(d) * np.log2(1.0 + np.abs(d)) / 32.0
            ).astype(np.float32)
        queue[1:] = queue[:-1]
        queue[0] = a
        if filled < cfg.n_mem:
            filled += 1

    labels = None
    if with_labels and "fetch_lat" in trace.dtype.names:
        labels = {
            "fetch_lat": trace["fetch_lat"].astype(np.float32),
            "exec_lat": trace["exec_lat"].astype(np.float32),
            "mispred": trace["mispred"].astype(np.float32),
            "dlevel": trace["dlevel"].astype(np.int32),
            "icache_miss": trace["icache_miss"].astype(np.float32),
            "tlb_miss": trace["tlb_miss"].astype(np.float32),
            "is_branch": trace["is_branch"].astype(np.float32),
            "is_mem": trace["is_mem"].astype(np.float32),
        }

    return FeatureSet(
        opcode=opcode,
        regbits=regbits,
        flags=flags,
        brhist=brhist,
        memdist=memdist,
        labels=labels,
    )
