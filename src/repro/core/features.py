"""§4.2 Feature engineering from the µarch-agnostic functional trace.

Per-instruction features: opcode id (lookup-table embedding downstream),
register bitmap (src+dst, NUM_REGS wide), instruction flags.

Cross-instruction features:
  * branch-history hash table — N_b buckets × N_q outcomes keyed by
    (pc>>2) % N_b; a conditional branch's feature is its bucket's recent
    outcome queue (most-recent first; 0 for empty slots, ±1 for
    not-taken/taken).  Hash collisions deliberately mix histories of
    different branches, providing a lightweight global history (paper Fig 4).
  * memory access-distance queue — signed-log-compressed deltas between the
    current access address and the previous N_m accesses (paper Fig 3), a
    cheap stand-in for reuse/stack distance.

Defaults N_b=1024, N_q=32, N_m=64 are the paper's empirically chosen values
(§5.4); the benchmark harness sweeps them (Fig 12).

Two implementations of the cross-instruction features:

  * `extract_features` — vectorized.  Branch history is computed per-bucket
    with a grouped (sort-by-bucket) formulation; the memory-distance queue is
    a lag-k difference.  Both loop over the queue depth (N_q / N_m, small
    constants) instead of over the trace.
  * `extract_features_reference` — the original per-branch / per-access
    interpreter loops, kept as the executable specification; the test suite
    asserts exact equivalence between the two.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import numpy as np

from ..uarch.isa import NUM_REGS, Op

__all__ = [
    "FeatureConfig",
    "FeatureSet",
    "extract_features",
    "extract_features_reference",
    "num_extractions",
    "signed_log",
    "SIGNED_LOG_COEFFS",
    "SIGNED_LOG_SQRT2",
    "NUM_OPCODES",
]

NUM_OPCODES = len(Op)

# process-wide count of full feature-extraction passes (the O(trace)
# host pre-pass) — snapshot before/after a region to prove it was served
# from cache/store instead of recomputed (the cross-process reuse tests
# pin this to zero against a warm store)
_NUM_EXTRACTIONS = 0


def num_extractions() -> int:
    """How many times ``extract_features`` has run in this process."""
    return _NUM_EXTRACTIONS


@dataclasses.dataclass(frozen=True)
class FeatureConfig:
    n_buckets: int = 1024   # N_b
    n_queue: int = 32       # N_q
    n_mem: int = 64         # N_m

    @property
    def flags_dim(self) -> int:
        return 5  # is_branch, taken, is_mem, is_store, is_fp


@dataclasses.dataclass
class FeatureSet:
    """Model inputs (+ labels when built from an adjusted trace)."""

    opcode: np.ndarray      # (N,) int32
    regbits: np.ndarray     # (N, NUM_REGS) float32
    flags: np.ndarray       # (N, 5) float32
    brhist: np.ndarray      # (N, N_q) float32 in {-1, 0, +1}
    memdist: np.ndarray     # (N, N_m) float32 signed-log deltas
    labels: Optional[Dict[str, np.ndarray]] = None

    def __len__(self) -> int:
        return len(self.opcode)

    @property
    def digest(self) -> str:
        """Stable content digest (blake2b over every array, labels
        included) — the identity the sweep scheduler's dedup and the
        artifact store share, instead of object ids.  Cached on first use;
        treat the arrays as immutable once hashed."""
        d = getattr(self, "_digest", None)
        if d is None:
            from ..store.content import tree_digest

            d = tree_digest(
                {
                    "opcode": self.opcode,
                    "regbits": self.regbits,
                    "flags": self.flags,
                    "brhist": self.brhist,
                    "memdist": self.memdist,
                    "labels": self.labels,
                }
            )
            self._digest = d
        return d

    def slice(self, lo: int, hi: int) -> "FeatureSet":
        lab = None
        if self.labels is not None:
            lab = {k: v[lo:hi] for k, v in self.labels.items()}
        return FeatureSet(
            opcode=self.opcode[lo:hi],
            regbits=self.regbits[lo:hi],
            flags=self.flags[lo:hi],
            brhist=self.brhist[lo:hi],
            memdist=self.memdist[lo:hi],
            labels=lab,
        )


_FP_OPS = (int(Op.FALU), int(Op.FMUL), int(Op.FDIV))


def _per_instruction(trace: np.ndarray, opcode: np.ndarray):
    n = len(trace)
    regbits = np.zeros((n, NUM_REGS), dtype=np.float32)
    rows = np.arange(n)
    regbits[rows, trace["src1"].astype(np.int64)] = 1.0
    regbits[rows, trace["src2"].astype(np.int64)] = 1.0
    # dst included too (paper: both source and destination registers)
    regbits[rows, trace["dst"].astype(np.int64)] = 1.0

    is_fp = np.isin(opcode, _FP_OPS)
    flags = np.stack(
        [
            trace["is_branch"].astype(np.float32),
            trace["taken"].astype(np.float32),
            trace["is_mem"].astype(np.float32),
            trace["is_store"].astype(np.float32),
            is_fp.astype(np.float32),
        ],
        axis=1,
    )
    return regbits, flags


def _labels(trace: np.ndarray, with_labels: bool):
    if not (with_labels and "fetch_lat" in trace.dtype.names):
        return None
    return {
        "fetch_lat": trace["fetch_lat"].astype(np.float32),
        "exec_lat": trace["exec_lat"].astype(np.float32),
        "mispred": trace["mispred"].astype(np.float32),
        "dlevel": trace["dlevel"].astype(np.int32),
        "icache_miss": trace["icache_miss"].astype(np.float32),
        "tlb_miss": trace["tlb_miss"].astype(np.float32),
        "is_branch": trace["is_branch"].astype(np.float32),
        "is_mem": trace["is_mem"].astype(np.float32),
    }


# ---------------------------------------------------------------------------
# Deterministic signed-log compression.
#
# sign(d) * log2(1 + |d|) / 32 evaluated as a FIXED sequence of exactly
# rounded float32 operations: exponent/mantissa split by bit manipulation,
# then an atanh-series polynomial (Horner) for log2 of the mantissa.  Every
# step is an individually rounded IEEE-754 float32 op, so NumPy and an
# op-per-dispatch jax evaluation (``repro.kernels.features.ops.signed_log_device``)
# produce bit-identical results — the property the pallas feature backend's
# exact-equivalence tests rely on.  A fused/jitted evaluation would NOT be
# bit-identical: XLA contracts `a*b + c` into fma, which rounds once instead
# of twice.  Max relative error vs true log2 is ~6e-8 (≈1 ulp).
# ---------------------------------------------------------------------------

# 2/ln2 * s^(2k) atanh-series coefficients: log2(m) = (2/ln2)·atanh(s) with
# s = (m-1)/(m+1); degree 13 keeps the error ≈1 ulp over m ∈ [√2/2, √2].
SIGNED_LOG_COEFFS = tuple(
    np.float32(2.0 / math.log(2.0) / k) for k in (1, 3, 5, 7, 9, 11, 13)
)
SIGNED_LOG_SQRT2 = np.float32(math.sqrt(2.0))


# tao: bitwise
def signed_log(d: np.ndarray) -> np.ndarray:
    """Signed-log-compress deltas to float32, bit-reproducibly (see above)."""
    d = np.asarray(d).astype(np.float32)
    a = np.abs(d)
    x = np.float32(1.0) + a
    bits = x.view(np.int32)
    e = ((bits >> 23) & np.int32(0xFF)) - np.int32(127)
    m = ((bits & np.int32(0x007FFFFF)) | np.int32(0x3F800000)).view(np.float32)
    big = m > SIGNED_LOG_SQRT2
    m = np.where(big, m * np.float32(0.5), m)
    e = (e + big).astype(np.float32)
    s = (m - np.float32(1.0)) / (m + np.float32(1.0))
    z = s * s
    p = np.full_like(z, SIGNED_LOG_COEFFS[-1])
    for c in SIGNED_LOG_COEFFS[-2::-1]:
        p = p * z
        p = p + c
    r = p * s
    r = r + e
    r = r * np.float32(1.0 / 32.0)
    return np.where(d < 0, -r, r)


_signed_log = signed_log


def _branch_history(trace: np.ndarray, cfg: FeatureConfig) -> np.ndarray:
    """Grouped (per-bucket) formulation of the branch-history hash table.

    The j-th branch mapping to bucket b sees that bucket's previous N_q
    outcomes, most-recent first.  A stable sort by bucket makes every bucket's
    branches contiguous, turning the lookup into lag-k gathers: only the queue
    depth (N_q) is a Python loop, each iteration vectorized over all branches.
    """
    n = len(trace)
    brhist = np.zeros((n, cfg.n_queue), dtype=np.float32)
    br_idx = np.nonzero(trace["is_branch"])[0]
    m = len(br_idx)
    if m == 0:
        return brhist
    bucket = ((trace["pc"][br_idx] >> 2) % cfg.n_buckets).astype(np.int64)
    taken = np.where(trace["taken"][br_idx], 1.0, -1.0).astype(np.float32)

    order = np.argsort(bucket, kind="stable")
    b_sorted = bucket[order]
    t_sorted = taken[order]
    pos = np.arange(m)
    # start index (in sorted order) of the group each branch belongs to
    is_head = np.empty(m, dtype=bool)
    is_head[0] = True
    is_head[1:] = b_sorted[1:] != b_sorted[:-1]
    group_start = np.maximum.accumulate(np.where(is_head, pos, 0))

    rows = np.zeros((m, cfg.n_queue), dtype=np.float32)
    for k in range(cfg.n_queue):
        src = pos - 1 - k
        valid = src >= group_start
        rows[valid, k] = t_sorted[src[valid]]
    brhist[br_idx[order]] = rows
    return brhist


def _memory_distance(trace: np.ndarray, cfg: FeatureConfig) -> np.ndarray:
    """Lag-k formulation of the access-distance queue: slot k of access j is
    the signed-log delta to access j-1-k.  Loops over N_m, not the trace."""
    n = len(trace)
    memdist = np.zeros((n, cfg.n_mem), dtype=np.float32)
    mem_idx = np.nonzero(trace["is_mem"])[0]
    m = len(mem_idx)
    if m < 2:
        return memdist
    addrs = trace["addr"][mem_idx].astype(np.int64)
    for k in range(min(cfg.n_mem, m - 1)):
        d = (addrs[k + 1 :] - addrs[: m - 1 - k]).astype(np.float64)
        memdist[mem_idx[k + 1 :], k] = _signed_log(d)
    return memdist


def extract_features(
    trace: np.ndarray, cfg: FeatureConfig = FeatureConfig(), with_labels: bool = True
) -> FeatureSet:
    """`trace` is either an adjusted trace (ADJ_DTYPE, labels available) or a
    raw functional trace (FUNC_TRACE_DTYPE, inference path)."""
    global _NUM_EXTRACTIONS
    _NUM_EXTRACTIONS += 1
    opcode = trace["opcode"].astype(np.int32)
    regbits, flags = _per_instruction(trace, opcode)
    return FeatureSet(
        opcode=opcode,
        regbits=regbits,
        flags=flags,
        brhist=_branch_history(trace, cfg),
        memdist=_memory_distance(trace, cfg),
        labels=_labels(trace, with_labels),
    )


def extract_features_reference(
    trace: np.ndarray, cfg: FeatureConfig = FeatureConfig(), with_labels: bool = True
) -> FeatureSet:
    """Original interpreter-loop implementation (executable specification for
    `extract_features`; quadratic-free but O(trace) Python overhead)."""
    n = len(trace)
    opcode = trace["opcode"].astype(np.int32)
    regbits, flags = _per_instruction(trace, opcode)

    # ---- branch-history hash table (sequential over branches) ----------
    brhist = np.zeros((n, cfg.n_queue), dtype=np.float32)
    table = np.zeros((cfg.n_buckets, cfg.n_queue), dtype=np.float32)
    br_idx = np.nonzero(trace["is_branch"])[0]
    br_pc = (trace["pc"][br_idx] >> 2) % cfg.n_buckets
    br_taken = np.where(trace["taken"][br_idx], 1.0, -1.0).astype(np.float32)
    for j in range(len(br_idx)):
        b = br_pc[j]
        row = table[b]
        brhist[br_idx[j]] = row
        # push most-recent-first
        row[1:] = row[:-1]
        row[0] = br_taken[j]

    # ---- memory access-distance queue (sequential over mem ops) --------
    memdist = np.zeros((n, cfg.n_mem), dtype=np.float32)
    queue = np.zeros(cfg.n_mem, dtype=np.int64)
    filled = 0
    mem_idx = np.nonzero(trace["is_mem"])[0]
    addrs = trace["addr"][mem_idx].astype(np.int64)
    for j in range(len(mem_idx)):
        a = addrs[j]
        if filled:
            d = (a - queue[:filled]).astype(np.float64)
            memdist[mem_idx[j], :filled] = _signed_log(d)
        queue[1:] = queue[:-1]
        queue[0] = a
        if filled < cfg.n_mem:
            filled += 1

    return FeatureSet(
        opcode=opcode,
        regbits=regbits,
        flags=flags,
        brhist=brhist,
        memdist=memdist,
        labels=_labels(trace, with_labels),
    )
