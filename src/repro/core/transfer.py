"""§4.3/§5.5 Transfer learning to an unseen microarchitecture.

Three regimes (paper Table 5):
  * scratch              — full model trained from random init
  * direct fine-tuning   — all parameters initialized from a donor model
  * shared + fine-tune   — Tao's scheme: µarch-agnostic embeddings FROZEN,
                           adaptation + prediction layers fine-tuned on a
                           small dataset (20M instructions in the paper)
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import Callable, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..train.optim import AdamWConfig, adamw_init, adamw_update
from ..train.trainer import CachedTrainStep, cached_train_step
from ..uarch.isa import NUM_REGS
from .dataset import StreamingWindowDataset, WindowDataset
from .model import TaoConfig, init_tao, multi_metric_loss, tao_forward

__all__ = [
    "TrainResult",
    "train_tao",
    "train_tao_impl",
    "transfer_finetune",
    "warmup_train_step",
]

# Both dataset flavors expose the same ``batches(batch_size, rng=...)``
# contract (bit-identical streams for the same rng); everything below is
# agnostic to which one it is handed.
TrainData = Union[WindowDataset, StreamingWindowDataset]


@dataclasses.dataclass
class TrainResult:
    params: Dict
    losses: List[float]
    eval_losses: List[float]
    seconds: float
    steps: int


# tao: step-builder[train-step]
def _make_step(cfg: TaoConfig, opt_cfg: AdamWConfig, trainable: str, plan=None):
    """trainable: 'all' or 'headonly' (freeze shared embeddings).

    The step is cached process-wide (``train.trainer.cached_train_step``):
    params and optimizer state are arguments, so every trainer invocation
    with the same (config, optimizer, trainable set, plan) shares one
    executable, and — because batches are fixed-shape — it traces exactly
    once per (batch, window) geometry.  ``plan`` (an ``ExecutionPlan``)
    only keys the cache here: the step itself stays a plain jit and GSPMD
    partitions it from the plan's input placements (batch sharded over
    the plan's axes, params/opt replicated), so a sharded and an
    unsharded trainer never share an executable under one trace counter."""

    def build(entry):
        def loss_fn(params, batch):
            preds = tao_forward(params, batch, cfg)
            loss, _ = multi_metric_loss(preds, batch["labels"])
            return loss

        if trainable == "all":

            @jax.jit
            def step(params, opt, batch):
                entry.compiles += 1  # runs at trace time only
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
                params, opt, _ = adamw_update(params, grads, opt, opt_cfg)
                return params, opt, loss

            return step

        @jax.jit
        def step(params, opt, batch):
            entry.compiles += 1  # runs at trace time only
            # Freeze the shared embedding group: grads only for adapt+pred.
            def loss_head(head_params, embed_params, batch):
                full = {"embed": embed_params, **head_params}
                return loss_fn(full, batch)

            head = {"adapt": params["adapt"], "pred": params["pred"]}
            loss, grads = jax.value_and_grad(loss_head)(head, params["embed"], batch)
            head, opt, _ = adamw_update(head, grads, opt, opt_cfg)
            return {"embed": params["embed"], **head}, opt, loss

        return step

    # the entry itself is callable (dispatching its AOT executable when
    # warmup_train_step has compiled one), so callers use it like the fn
    return cached_train_step(  # tao: step-key[train-step]
        ("tao", cfg, opt_cfg, trainable, plan), build
    )


def warmup_train_step(
    cfg: TaoConfig,
    *,
    batch_size: int = 16,
    lr: float = 3e-4,
    freeze_embed: bool = False,
    plan=None,
    window: Optional[int] = None,
) -> CachedTrainStep:
    """AOT-compile the cached train step for a training recipe ahead of
    any data: params/optimizer shapes come from ``jax.eval_shape`` over
    ``init_tao``, the batch from the dataset layer's declared geometry
    (``window`` defaults to ``cfg.window`` — pass the effective window for
    traces shorter than it).  Single-device only: on a sharded plan (or
    multi-process run) the entry is built but dispatch stays with the
    jitted step, whose first call the persistent compilation cache serves.
    Idempotent per (recipe, geometry)."""
    from ..engine.aot import abstract_like, compile_bytes_estimate

    if plan is not None and not plan.sharded:
        plan = None  # same normalization as train_tao_impl
    opt_cfg = AdamWConfig(lr=lr)
    trainable = "headonly" if freeze_embed else "all"
    entry = _make_step(cfg, opt_cfg, trainable, plan=plan)
    if entry.aot is not None:
        return entry
    if plan is not None or jax.process_count() > 1:
        return entry

    params = jax.eval_shape(
        functools.partial(init_tao, cfg=cfg), jax.random.PRNGKey(0)
    )
    if freeze_embed:
        opt = jax.eval_shape(
            adamw_init, {"adapt": params["adapt"], "pred": params["pred"]}
        )
    else:
        opt = jax.eval_shape(adamw_init, params)

    w = window if window is not None else cfg.window
    b = batch_size
    f = cfg.features
    sds = jax.ShapeDtypeStruct
    # the exact shapes/dtypes WindowDataset/StreamingWindowDataset batches
    # carry: INPUT_KEYS plus the label dict from features._labels
    batch = {
        "opcode": sds((b, w), jnp.int32),
        "regbits": sds((b, w, NUM_REGS), jnp.float32),
        "flags": sds((b, w, f.flags_dim), jnp.float32),
        "brhist": sds((b, w, f.n_queue), jnp.float32),
        "memdist": sds((b, w, f.n_mem), jnp.float32),
        "labels": {
            "fetch_lat": sds((b, w), jnp.float32),
            "exec_lat": sds((b, w), jnp.float32),
            "mispred": sds((b, w), jnp.float32),
            "dlevel": sds((b, w), jnp.int32),
            "icache_miss": sds((b, w), jnp.float32),
            "tlb_miss": sds((b, w), jnp.float32),
            "is_branch": sds((b, w), jnp.float32),
            "is_mem": sds((b, w), jnp.float32),
        },
    }
    compiled = entry.fn.lower(abstract_like(params), abstract_like(opt), batch).compile()
    entry.est_bytes = compile_bytes_estimate(compiled)
    entry.aot = compiled
    return entry


# tao: hot
def _run_epochs(
    params,
    step,
    dataset: TrainData,
    epochs: int,
    batch_size: int,
    opt,
    eval_fn: Optional[Callable] = None,
    seed: int = 0,
    target_loss: Optional[float] = None,
    prefetch: bool = True,
    plan=None,
    start_epoch: int = 0,
    rng_state: Optional[Dict] = None,
    losses: Optional[List[float]] = None,
    evals: Optional[List[float]] = None,
    steps: int = 0,
    checkpoint_cb: Optional[Callable] = None,
) -> Tuple[Dict, List[float], List[float], int]:
    # lazy: engine.runner imports core.dataset — a module-level import here
    # would close the cycle through the repro.core package init
    from ..engine.runner import prefetch_to_device

    if plan is not None and plan.sharded:
        # data-parallel training under the same ExecutionPlan the engine
        # uses: batches shard over the plan's batch axes (device_put
        # below), params/opt replicate, and GSPMD inserts the gradient
        # all-reduce.  The batch stream itself is untouched, so the
        # sampled windows match the single-device run exactly.
        plan.validate_batch(batch_size)
        params = plan.replicate(params)
        opt = plan.replicate(opt)

    rng = np.random.default_rng(seed)
    if rng_state is not None:
        # crash-resume: fast-forward the shuffle stream to where the
        # checkpointed epoch left it, so the remaining epochs draw exactly
        # the batches an uninterrupted run would have drawn
        rng.bit_generator.state = rng_state
    losses = list(losses) if losses else []
    evals = list(evals) if evals else []
    put = plan.device_put if plan is not None and plan.sharded else None
    for ep in range(start_epoch, epochs):
        nb = 0
        ep_losses: list = []
        batches = dataset.batches(batch_size, rng=rng)
        if prefetch:
            # double-buffered host→device transfer (and, on accelerator
            # backends, threaded batch gather) — numerics are unchanged:
            # the step sees the same arrays, just already device-resident
            batches = prefetch_to_device(batches, put)
        elif put is not None:
            batches = (put(b) for b in batches)
        for batch in batches:
            params, opt, loss = step(params, opt, batch)
            # keep the device scalar: a float() here would sync the
            # dispatch queue once per step and serialize the prefetch
            ep_losses.append(loss)
            nb += 1
            steps += 1
        # one explicit sync per epoch; summing the host scalars in step
        # order keeps the loss trajectory bit-identical to the old
        # per-step accumulation
        ep_losses = jax.device_get(ep_losses)
        ep_loss = 0.0
        for x in ep_losses:
            ep_loss += float(x)  # tao: noqa[TAO002] host numpy scalar from the per-epoch device_get above, not a device sync
        ep_loss /= max(nb, 1)
        losses.append(ep_loss)
        if eval_fn is not None:
            evals.append(float(jax.device_get(eval_fn(params))))
        if checkpoint_cb is not None:
            # rng state captured AFTER this epoch's batches were drawn —
            # exactly what the next epoch of a resumed run must start from
            checkpoint_cb(
                ep, params, opt, losses, evals, steps,
                rng.bit_generator.state,
            )
        if target_loss is not None and ep_loss <= target_loss:
            break
    return params, losses, evals, steps


def train_tao_impl(
    cfg: TaoConfig,
    dataset: TrainData,
    *,
    epochs: int = 10,
    batch_size: int = 16,
    lr: float = 3e-4,
    init_params: Optional[Dict] = None,
    freeze_embed: bool = False,
    eval_fn: Optional[Callable] = None,
    seed: int = 0,
    target_loss: Optional[float] = None,
    plan=None,
    store=None,
    resume_key: Optional[str] = None,
    manifest_every: int = 1,
) -> TrainResult:
    """Train (or fine-tune) a single-µarch Tao model.

    scratch            -> init_params=None,  freeze_embed=False
    direct fine-tune   -> init_params=donor, freeze_embed=False
    shared + fine-tune -> init_params={'embed': shared, ...}, freeze_embed=True

    ``dataset`` may be a materialized ``WindowDataset`` or a
    ``StreamingWindowDataset`` (O(trace + batch) host memory); both produce
    bit-identical loss trajectories for the same seed and keep-set.

    ``plan`` (an ``repro.engine.ExecutionPlan``) runs the cached step
    data-parallel over the plan's mesh — same batch stream, batches
    sharded over the batch axes, params replicated, gradient all-reduce
    by GSPMD.  ``train_step_compiles`` still counts one trace per
    (batch, window) geometry per plan.

    With ``store`` (an ``ArtifactStore``) and ``resume_key`` (the run's
    recipe identity — ``Session.train`` passes its params content key),
    every ``manifest_every``-th epoch publishes a crash-resume manifest
    (params, optimizer state, loss history, shuffle-rng state) through the
    store; a re-run after a SIGKILL picks up from the last checkpointed
    epoch with zero redundant step executions, and its loss trajectory
    and final params are bit-identical to an uninterrupted run.

    Internal implementation behind ``repro.api.Session.train`` /
    ``TrainedModel.transfer`` (and the ``train_tao`` deprecation shim).
    """
    if manifest_every < 1:
        raise ValueError(f"manifest_every must be >= 1, got {manifest_every}")
    key = jax.random.PRNGKey(seed)
    params = init_params if init_params is not None else init_tao(key, cfg)
    opt_cfg = AdamWConfig(lr=lr)
    trainable = "headonly" if freeze_embed else "all"
    if plan is not None and not plan.sharded:
        # the single-device plan is the default path; normalizing to None
        # keeps one step-cache entry (and one compile) for both spellings
        plan = None
    step = _make_step(cfg, opt_cfg, trainable, plan=plan)
    if freeze_embed:
        opt = adamw_init({"adapt": params["adapt"], "pred": params["pred"]})
    else:
        opt = adamw_init(params)

    start_epoch, rng_state, steps0 = 0, None, 0
    losses0: List[float] = []
    evals0: List[float] = []
    checkpoint_cb = None
    if store is not None and resume_key is not None:
        # lazy: resilience.manifest pulls in the store package
        from ..resilience.manifest import load_train_epoch, publish_train_epoch

        state = load_train_epoch(store, resume_key, epochs)
        if state is not None and state.get("rng_state") is not None:
            params = state["params"]
            # stored as a plain dict (the typed-path serializer holds
            # dict/list/tuple trees only) — rebuild the NamedTuple
            opt = type(opt)(**state["opt"])
            start_epoch = state["epoch"] + 1
            rng_state = state["rng_state"]
            losses0 = state["losses"]
            evals0 = state["eval_losses"]
            steps0 = state["steps"]

        def checkpoint_cb(ep, p, o, ls, ev, st, rs):
            if (ep + 1) % manifest_every and ep != epochs - 1:
                return
            publish_train_epoch(
                store, resume_key, ep, jax.device_get(p),
                jax.device_get(o)._asdict(), ls, ev, st, rs,
            )

    t0 = time.perf_counter()
    params, losses, evals, steps = _run_epochs(
        params, step, dataset, epochs, batch_size, opt, eval_fn, seed,
        target_loss, plan=plan, start_epoch=start_epoch, rng_state=rng_state,
        losses=losses0, evals=evals0, steps=steps0,
        checkpoint_cb=checkpoint_cb,
    )
    return TrainResult(
        params=params,
        losses=losses,
        eval_losses=evals,
        seconds=time.perf_counter() - t0,
        steps=steps,
    )


def train_tao(cfg: TaoConfig, dataset: TrainData, **kw) -> TrainResult:
    """Deprecated alias for :func:`train_tao_impl` — use the
    ``repro.api`` facade instead (``Session.train`` / ``model.transfer``)."""
    warnings.warn(
        "repro.core.train_tao is deprecated; use repro.api.Session.train(...) "
        "(or TrainedModel.transfer for fine-tuning)",
        DeprecationWarning,
        stacklevel=2,
    )
    return train_tao_impl(cfg, dataset, **kw)


def transfer_finetune(
    cfg: TaoConfig,
    shared_embed: Dict,
    donor_arch_params: Dict,
    small_dataset: TrainData,
    **kw,
) -> TrainResult:
    """Tao's fast path: frozen shared embeddings + donor-initialized heads,
    fine-tuned on a reduced dataset."""
    init = {
        "embed": shared_embed,
        "adapt": donor_arch_params["adapt"],
        "pred": donor_arch_params["pred"],
    }
    return train_tao_impl(
        cfg, small_dataset, init_params=init, freeze_embed=True, **kw
    )
