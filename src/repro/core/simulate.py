"""DL-based simulation (inference) driver.

Streams a functional trace through a trained Tao model and aggregates the
predicted performance metrics:

  CPI          = (sum of predicted fetch latencies + final exec latency) / N
                 (retire-clock formulation of §4.2)
  branch MPKI  = predicted mispredictions per 1000 instructions
  L1D MPKI     = predicted accesses with level >= L2 per 1000 instructions
  phase curves = per-chunk averages (Fig. 11)

`simulate_trace` is a DEPRECATED compatibility wrapper over the streaming
engine (`repro.engine`) — new code should go through the `repro.api`
facade (`TrainedModel.simulate` / `Session.sweep`).  The original
host-side batch loop survives as `simulate_trace_legacy` — it is the
executable specification the engine is tested against, and the baseline
`benchmarks/bench_timing.py` measures the engine's speedup over.
"""
from __future__ import annotations

import time
import warnings
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..engine.runner import SimulationResult, simulate_trace_engine
from ..uarch.isa import DLEVEL_L2
from .dataset import stream_batches
from .features import FeatureSet, extract_features_reference
from .model import TaoConfig, tao_forward

__all__ = [
    "SimulationResult",
    "simulate_trace",
    "simulate_trace_legacy",
    "phase_curves",
]


def simulate_trace(
    params: Dict,
    func_trace: np.ndarray,
    cfg: TaoConfig,
    batch_size: int = 64,
    features: Optional[FeatureSet] = None,
    collect: bool = True,
    feature_backend: str = "numpy",
) -> SimulationResult:
    """Deprecated engine-backed simulation — use
    ``repro.api.TrainedModel.simulate`` (same engine, same results).
    `collect=False` keeps all metrics on device (fastest; per-instruction
    arrays are then not collected).  `feature_backend="pallas"` fuses §4.2
    feature extraction into the device-resident stream (docs/engine.md)."""
    warnings.warn(
        "repro.core.simulate_trace is deprecated; use repro.api: "
        "TrainedModel(params, cfg).simulate(trace) or Session.sweep(...)",
        DeprecationWarning,
        stacklevel=2,
    )
    return simulate_trace_engine(
        params,
        func_trace,
        cfg,
        batch_size=batch_size,
        features=features,
        collect=collect,
        feature_backend=feature_backend,
    )


def simulate_trace_legacy(
    params: Dict,
    func_trace: np.ndarray,
    cfg: TaoConfig,
    batch_size: int = 64,
    features: Optional[FeatureSet] = None,
) -> SimulationResult:
    """Pre-engine host batch loop (reference implementation).

    Kept numerically verbatim apart from one fix: the branch/memory masks
    are taken with a single length-safe slice (the old double-slice
    under-filled the masks when the window grid overran the trace).  Uses
    the reference (interpreter-loop) feature extractor so it stays a
    faithful pre-refactor baseline end to end.  The windows now come from
    ``stream_batches`` over zero-copy views (``pad=False`` reproduces the
    old ragged batch slicing exactly) instead of a ``build_windows``
    materialization, so this labeling-side path no longer makes a full
    window copy of the trace — identical batch contents, O(batch) memory.
    """
    t0 = time.perf_counter()
    fs = features if features is not None else extract_features_reference(
        func_trace, cfg.features, with_labels=False
    )

    fwd = jax.jit(lambda p, b: tao_forward(p, b, cfg))

    fetch, execl, misp, dlev = [], [], [], []
    for batch in stream_batches(
        fs, cfg.window, batch_size, stride=cfg.window, pad=False
    ):
        batch.pop("valid")  # the legacy loop never padded: batches are ragged
        out = fwd(params, batch)
        fetch.append(np.asarray(out["fetch_lat"], np.float32))
        execl.append(np.asarray(out["exec_lat"], np.float32))
        misp.append(np.asarray(jax.nn.sigmoid(out["mispred_logit"]), np.float32))
        dlev.append(np.asarray(jnp.argmax(out["dlevel_logits"], -1), np.int32))

    fetch = np.maximum(np.concatenate(fetch).reshape(-1), 0.0)
    execl = np.maximum(np.concatenate(execl).reshape(-1), 0.0)
    misp = np.concatenate(misp).reshape(-1)
    dlev = np.concatenate(dlev).reshape(-1)
    n = len(fetch)

    # Masks from the trace itself (branch/memory heads only count where
    # valid).  The window grid covers the first n trace positions, so one
    # length-safe slice is all that is needed.
    covered = min(n, len(func_trace))
    is_branch = np.zeros(n, bool)
    is_mem = np.zeros(n, bool)
    is_branch[:covered] = func_trace["is_branch"][:covered]
    is_mem[:covered] = func_trace["is_mem"][:covered]

    total = float(fetch.sum() + (execl[-1] if n else 0.0))
    mispred_count = float((misp > 0.5)[is_branch].sum())
    l1d_miss_count = float((dlev >= DLEVEL_L2)[is_mem].sum())
    secs = time.perf_counter() - t0
    return SimulationResult(
        cpi=total / max(n, 1),
        total_cycles=total,
        branch_mpki=1000.0 * mispred_count / max(n, 1),
        l1d_mpki=1000.0 * l1d_miss_count / max(n, 1),
        num_instructions=n,
        seconds=secs,
        mips=n / 1e6 / secs,
        fetch_lat=fetch,
        exec_lat=execl,
        mispred_prob=misp,
        dlevel=dlev,
    )


def phase_curves(
    result: SimulationResult, chunk: int = 10_000
) -> Dict[str, np.ndarray]:
    """Per-chunk CPI / branch MPKI / L1D MPKI curves (Fig. 11)."""
    if "fetch_lat" not in result.available_metrics:
        raise ValueError(
            "phase_curves needs per-instruction predictions: simulate with "
            "collect=True (EngineConfig.collect)"
        )
    n = result.num_instructions
    m = n // chunk
    cpi = np.zeros(m)
    br = np.zeros(m)
    l1 = np.zeros(m)
    for i in range(m):
        s = slice(i * chunk, (i + 1) * chunk)
        cpi[i] = result.fetch_lat[s].mean()
        br[i] = 1000.0 * (result.mispred_prob[s] > 0.5).mean()
        l1[i] = 1000.0 * (result.dlevel[s] >= DLEVEL_L2).mean()
    return {"cpi": cpi, "branch_mpki": br, "l1d_mpki": l1}
