"""Assigned-architecture model zoo."""
from .backbone import Model
from .config import ArchConfig, HybridConfig, MLAConfig, MoEConfig, SSMConfig

__all__ = ["Model", "ArchConfig", "MoEConfig", "MLAConfig", "SSMConfig", "HybridConfig"]
