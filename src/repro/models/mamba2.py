"""Mamba-2 block (SSD — state-space duality, arXiv:2405.21060).

Training path uses the chunked SSD algorithm: within-chunk quadratic
(attention-like) term + across-chunk linear recurrence on (H, P, N) states,
scanned with lax.scan.  Decode path is the O(1) state update.

Layout: x (B, S, d_model) -> in_proj -> [z | xBC | dt]; depthwise causal
conv over xBC; SSD over heads H = d_inner / head_dim with state size N.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard
from ..nn.core import init_rmsnorm, rmsnorm, truncated_normal_init
from .config import ArchConfig

__all__ = [
    "init_mamba2",
    "mamba2_forward",
    "mamba2_decode",
    "mamba2_param_axes",
    "init_ssm_state",
    "ssd_chunked_ref",
]


def init_mamba2(key, cfg: ArchConfig) -> Dict:
    s = cfg.ssm
    d = cfg.d_model
    din = s.d_inner(d)
    H = s.n_heads(d)
    N = s.d_state
    G = s.n_groups
    dt = jnp.dtype(cfg.param_dtype)
    conv_dim = din + 2 * G * N
    ks = jax.random.split(key, 5)
    # dt bias initialized so softplus(dt_bias) spans [dt_min, dt_max]
    u = jax.random.uniform(ks[3], (H,), minval=math.log(s.dt_min), maxval=math.log(s.dt_max))
    dt_init = jnp.log(jnp.expm1(jnp.exp(u)))  # inverse softplus
    return {
        "in_proj": truncated_normal_init(
            ks[0], (d, 2 * din + 2 * G * N + H), 1.0 / math.sqrt(d), dt
        ),
        "conv_w": truncated_normal_init(ks[1], (s.conv_kernel, conv_dim), 0.5, dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "dt_bias": dt_init.astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "gate_norm": init_rmsnorm(din, dt),
        "out_proj": truncated_normal_init(ks[2], (din, d), 1.0 / math.sqrt(din), dt),
    }


def mamba2_param_axes(cfg: ArchConfig) -> Dict:
    return {
        "in_proj": ("fsdp", "mlp"),
        "conv_w": (None, "mlp"),
        "conv_b": ("mlp",),
        "A_log": (None,),
        "dt_bias": (None,),
        "D": (None,),
        "gate_norm": {"scale": (None,)},
        "out_proj": ("mlp", "fsdp"),
    }


def _split_proj(p, x, cfg: ArchConfig):
    s = cfg.ssm
    d = cfg.d_model
    din = s.d_inner(d)
    G, N = s.n_groups, s.d_state
    H = s.n_heads(d)
    cd = jnp.dtype(cfg.compute_dtype)
    zxbcdt = x.astype(cd) @ p["in_proj"].astype(cd)
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din : 2 * din + 2 * G * N]
    dt_raw = zxbcdt[..., 2 * din + 2 * G * N :]
    return z, xbc, dt_raw, (din, G, N, H)


def _causal_conv(xbc, w, b, kernel: int):
    """Depthwise causal conv along seq. xbc: (B,S,C)."""
    pad = jnp.pad(xbc, ((0, 0), (kernel - 1, 0), (0, 0)))
    # depthwise: sum_k pad[:, t+k, c] * w[k, c]
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(kernel)
    )
    return jax.nn.silu(out + b)


def ssd_chunked_ref(
    xh: jnp.ndarray,   # (B,S,H,P)
    dt: jnp.ndarray,   # (B,S,H)  (post-softplus)
    A: jnp.ndarray,    # (H,) negative decay rates
    Bm: jnp.ndarray,   # (B,S,G,N)
    Cm: jnp.ndarray,   # (B,S,G,N)
    chunk: int,
    return_state: bool = False,
):
    """Chunked SSD scan (pure jnp oracle; mirrors the Pallas kernel).

    Returns y: (B,S,H,P); with return_state also the final (B,H,N,P) state.
    """
    B_, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    nc = S // chunk
    rep = H // G

    xc = xh.reshape(B_, nc, chunk, H, P)
    dtc = dt.reshape(B_, nc, chunk, H)
    Bc = Bm.reshape(B_, nc, chunk, G, N)
    Cc = Cm.reshape(B_, nc, chunk, G, N)
    # expand groups to heads
    Bh = jnp.repeat(Bc, rep, axis=3)  # (B,nc,c,H,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    dA = dtc * A[None, None, None, :]           # (B,nc,c,H) negative
    cums = jnp.cumsum(dA, axis=2)               # within-chunk cumulative
    # within-chunk quadratic term
    # L[i,j] = exp(cums_i - cums_j) for i>=j
    diff = cums[:, :, :, None, :] - cums[:, :, None, :, :]  # (B,nc,i,j,H)
    ii = jnp.arange(chunk)
    causal = ii[:, None] >= ii[None, :]
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    # scores: C_i · B_j
    s = jnp.einsum("bnihd,bnjhd->bnijh", Ch, Bh)
    y_diag = jnp.einsum(
        "bnijh,bnjh,bnjhp->bnihp", s * L, dtc, xc
    )

    # chunk states: sum_j exp(cums_last - cums_j) dt_j B_j x_j
    decay_to_end = jnp.exp(cums[:, :, -1:, :] - cums)        # (B,nc,c,H)
    states = jnp.einsum("bnch,bnch,bnchd,bnchp->bnhdp",
                        decay_to_end, dtc, Bh, xc).astype(jnp.float32)
    chunk_decay = jnp.exp(cums[:, :, -1, :]).astype(jnp.float32)  # (B,nc,H)

    def scan_fn(carry, t):
        st, dec = t   # st: (B,H,N,P), dec: (B,H)
        new = carry * dec[:, :, None, None] + st
        return new, carry  # emit state BEFORE this chunk

    # fp32 carry: the inter-chunk recurrence is the numerically sensitive
    # (and dtype-stable) part regardless of compute dtype
    init = jnp.zeros((B_, H, N, P), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_fn,
        init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)        # (B,nc,H,N,P)

    # inter-chunk contribution: C_i · (decay_from_start_i * prev_state)
    decay_from_start = jnp.exp(cums)                          # (B,nc,c,H)
    y_off = jnp.einsum(
        "bnchd,bnhdp,bnch->bnchp",
        Ch.astype(jnp.float32),
        prev_states,
        decay_from_start.astype(jnp.float32),
    )
    y = (y_diag.astype(jnp.float32) + y_off).reshape(B_, S, H, P).astype(xh.dtype)
    if return_state:
        return y, final_state  # (B,H,N,P)
    return y


def mamba2_forward(p: Dict, x: jnp.ndarray, cfg: ArchConfig, return_state: bool = False):
    s = cfg.ssm
    cd = jnp.dtype(cfg.compute_dtype)
    B, S, d = x.shape
    z, xbc_raw, dt_raw, (din, G, N, H) = _split_proj(p, x, cfg)
    xbc = _causal_conv(xbc_raw, p["conv_w"].astype(cd), p["conv_b"].astype(cd), s.conv_kernel)
    xh = xbc[..., :din].reshape(B, S, H, s.head_dim)
    Bm = xbc[..., din : din + G * N].reshape(B, S, G, N)
    Cm = xbc[..., din + G * N :].reshape(B, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])  # (H,)

    xh = shard(xh, "batch", None, "mlp", None)
    state = None
    # pad the sequence to a chunk multiple (dt=0 rows are exact no-ops:
    # decay exp(0)=1 and zero state/output contribution)
    pad = (-S) % s.chunk
    if pad:
        xh_p = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm_p = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm_p = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        xh_p, dt_p, Bm_p, Cm_p = xh, dt, Bm, Cm
    if cfg.use_pallas and not return_state:
        from ..kernels.ssd.ops import ssd_scan

        y = ssd_scan(xh_p, dt_p.astype(cd), A, Bm_p, Cm_p, chunk=s.chunk)
    else:
        y = ssd_chunked_ref(
            xh_p, dt_p.astype(cd), A, Bm_p.astype(cd), Cm_p.astype(cd), s.chunk,
            return_state=return_state,
        )
        if return_state:
            y, state = y
    if pad:
        y = y[:, :S]
    y = y + xh * p["D"][None, None, :, None].astype(cd)
    y = y.reshape(B, S, din)
    y = rmsnorm(p["gate_norm"], y * jax.nn.silu(z))
    out = y @ p["out_proj"].astype(cd)
    out = shard(out, "batch", "seq", None)
    if return_state:
        # conv state: the last (K-1) pre-conv channels
        conv_state = xbc_raw[:, -(s.conv_kernel - 1) :, :].astype(jnp.float32)
        return out, {"ssm": state.astype(jnp.float32), "conv": conv_state}
    return out


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_ssm_state(cfg: ArchConfig, n_layers: int, batch: int):
    s = cfg.ssm
    d = cfg.d_model
    H = s.n_heads(d)
    conv_dim = s.d_inner(d) + 2 * s.n_groups * s.d_state
    return {
        "ssm": jnp.zeros((n_layers, batch, H, s.d_state, s.head_dim), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, s.conv_kernel - 1, conv_dim), jnp.float32),
    }


def ssm_state_axes(cfg: ArchConfig) -> Dict:
    return {
        "ssm": ("stack", "cache_batch", "mlp", None, None),
        "conv": ("stack", "cache_batch", None, "mlp"),
    }


def mamba2_decode(
    p: Dict, x: jnp.ndarray, layer_state: Dict, cfg: ArchConfig
) -> Tuple[jnp.ndarray, Dict]:
    """x: (B,1,d); state['ssm']: (B,H,N,P); state['conv']: (B,K-1,C)."""
    s = cfg.ssm
    cd = jnp.dtype(cfg.compute_dtype)
    B = x.shape[0]
    z, xbc, dt_raw, (din, G, N, H) = _split_proj(p, x, cfg)
    # conv state update
    hist = jnp.concatenate([layer_state["conv"], xbc.astype(jnp.float32)], axis=1)
    w = p["conv_w"].astype(jnp.float32)
    conv_out = jnp.einsum("bkc,kc->bc", hist, w) + p["conv_b"].astype(jnp.float32)
    xbc1 = jax.nn.silu(conv_out)[:, None, :].astype(cd)  # (B,1,C)
    new_conv = hist[:, 1:, :]

    xh = xbc1[..., :din].reshape(B, H, s.head_dim)
    Bm = xbc1[..., din : din + G * N].reshape(B, G, N)
    Cm = xbc1[..., din + G * N :].reshape(B, G, N)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)  # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])

    decay = jnp.exp(dt * A[None, :])  # (B,H)
    st = layer_state["ssm"]
    st_new = st * decay[..., None, None] + jnp.einsum(
        "bh,bhn,bhp->bhnp", dt, Bh.astype(jnp.float32), xh.astype(jnp.float32)
    )
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), st_new).astype(cd)
    y = y + xh * p["D"][None, :, None].astype(cd)
    y = y.reshape(B, 1, din)
    y = rmsnorm(p["gate_norm"], y * jax.nn.silu(z))
    out = y @ p["out_proj"].astype(cd)
    return out, {"ssm": st_new, "conv": new_conv}
