"""Mixture-of-Experts layer: top-k routing, capacity-bounded sort-based
dispatch, expert-parallel execution, optional shared experts
(DeepSeek-style), load-balance + router-z auxiliary losses.

Dispatch design (TPU-native): token-slots are sorted by expert id PER GROUP
(group = data shard = the all-to-all boundary, exactly as in real
expert-parallel systems) and scattered into a per-group (E, C, d) buffer
whose expert dim is sharded over `model` (EP); XLA lowers the cross-sharding
scatter/gather to all-to-alls.  Expert FFNs run as one batched einsum over
(G, E) — MXU friendly.

Two alternative formulations were evaluated and REFUTED (EXPERIMENTS.md
§Perf): a global sort (no groups) replicates the combine across the model
axis (80-270 GiB/device at 1M tokens); a vmap-free vectorized variant with
explicit (G, Tg*k, d) staging makes XLA replicate the inverse-permutation
gathers (260 GiB/device).  The vmapped per-group form below lowers an order
of magnitude leaner.
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard
from ..nn.core import truncated_normal_init
from .config import ArchConfig, MoEConfig
from .mlp import init_mlp, mlp_forward, mlp_param_axes

__all__ = ["init_moe", "moe_forward", "moe_param_axes", "DISPATCH_GROUPS"]

DISPATCH_GROUPS = 16  # = data shards: dispatch is local per group, like real EP


def init_moe(key, cfg: ArchConfig) -> Dict:
    m = cfg.moe
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    std_in = 1.0 / math.sqrt(d)
    std_out = 1.0 / math.sqrt(m.d_ff_expert)
    p = {
        "router": truncated_normal_init(ks[0], (d, m.num_experts), std_in, jnp.float32),
        "w_gate": truncated_normal_init(ks[1], (m.num_experts, d, m.d_ff_expert), std_in, dt),
        "w_up": truncated_normal_init(ks[2], (m.num_experts, d, m.d_ff_expert), std_in, dt),
        "w_down": truncated_normal_init(ks[3], (m.num_experts, m.d_ff_expert, d), std_out, dt),
    }
    if m.num_shared:
        d_sh = m.d_ff_shared or m.d_ff_expert * m.num_shared
        p["shared"] = init_mlp(ks[4], d, d_sh, "swiglu", dt)
    return p


def moe_param_axes(cfg: ArchConfig) -> Dict:
    ax = {
        "router": ("fsdp", None),
        "w_gate": ("experts", "fsdp", None),
        "w_up": ("experts", "fsdp", None),
        "w_down": ("experts", None, "fsdp"),
    }
    if cfg.moe.num_shared:
        ax["shared"] = mlp_param_axes("swiglu")
    return ax


def _route(logits: jnp.ndarray, m: MoEConfig) -> Tuple[jnp.ndarray, jnp.ndarray, Dict]:
    """logits (T, E) -> (weights (T,k), ids (T,k), aux losses)."""
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, m.top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # load-balance loss (Switch): E * sum_e f_e * p_e
    E = logits.shape[-1]
    pe = probs.mean(0)
    fe = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(1.0) / (
        ids.shape[0] * m.top_k
    )
    aux = {
        "load_balance": E * jnp.sum(fe * pe),
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
    }
    return weights, ids, aux


def _dispatch_group(xt, weights, ids, *, E, k, C, cd):
    """Sort-based dispatch for ONE token group (vmapped over groups).

    xt (Tg, d); weights/ids (Tg, k).  The intra-expert position is
    arange - segment_start after the sort (O(Tg*k), no (Tg*k, E)
    intermediate); slots beyond the per-group capacity C are dropped.
    """
    Tg, d = xt.shape
    flat_ids = ids.reshape(-1)                      # (Tg*k,)
    order = jnp.argsort(flat_ids)
    sorted_ids = flat_ids[order]
    counts = jnp.zeros((E,), jnp.int32).at[sorted_ids].add(1)
    seg_start = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_in_expert = jnp.arange(Tg * k, dtype=jnp.int32) - seg_start[sorted_ids]
    keep = pos_in_expert < C

    token_idx = order // k
    buf = jnp.zeros((E, C, d), cd)
    rows = jnp.where(keep, sorted_ids, E)           # drop -> OOB row
    cols = jnp.where(keep, pos_in_expert, 0)
    buf = buf.at[rows, cols].set(xt[token_idx].astype(cd), mode="drop")
    return buf, (rows, cols, keep, token_idx, order)


def _combine_group(y, meta, weights, *, E, k, cd, Tg, d):
    rows, cols, keep, token_idx, order = meta
    slot_out = y[rows.clip(0, E - 1), cols]          # (Tg*k, d)
    slot_out = jnp.where(keep[:, None], slot_out, 0.0)
    slot_w = weights.reshape(-1)[order].astype(cd)
    return jnp.zeros((Tg, d), cd).at[token_idx].add(slot_out * slot_w[:, None])


def moe_forward(p: Dict, x: jnp.ndarray, cfg: ArchConfig) -> Tuple[jnp.ndarray, Dict]:
    """x: (B,S,d) -> (B,S,d), aux losses."""
    m = cfg.moe
    cd = jnp.dtype(cfg.compute_dtype)
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    xt = shard(xt, "batch", None)

    logits = (xt.astype(jnp.float32)) @ p["router"]
    weights, ids, aux = _route(logits, m)

    k = m.top_k
    E = m.num_experts
    G = DISPATCH_GROUPS if T % DISPATCH_GROUPS == 0 else 1
    Tg = T // G
    C = max(1, int(m.capacity_factor * Tg * k / E))

    xg = shard(xt.reshape(G, Tg, d), "batch", None, None)
    wg = weights.reshape(G, Tg, k)
    ig = ids.reshape(G, Tg, k)

    disp = jax.vmap(
        functools.partial(_dispatch_group, E=E, k=k, C=C, cd=cd),
        in_axes=(0, 0, 0),
    )
    buf, meta = disp(xg, wg, ig)                    # buf: (G, E, C, d)
    buf = shard(buf, "batch", "experts", None, None)

    # expert FFN batched over (G, E)
    g_ = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(cd))
    u_ = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(cd))
    h = jax.nn.silu(g_) * u_
    h = shard(h, "batch", "experts", None, None)
    y = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(cd))
    y = shard(y, "batch", "experts", None, None)

    comb = jax.vmap(
        functools.partial(_combine_group, E=E, k=k, cd=cd, Tg=Tg, d=d),
        in_axes=(0, 0, 0),
    )
    out = comb(y, meta, wg).reshape(T, d)           # (G, Tg, d) -> (T, d)
    out = shard(out, "batch", None)

    if m.num_shared:
        out = out + mlp_forward(p["shared"], xt, cfg, "swiglu").reshape(T, d)

    out = out.reshape(B, S, d)
    return shard(out, "batch", "seq", None), aux
