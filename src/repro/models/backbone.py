"""Model assembly: embedding/frontends, scanned layer stacks, losses, and
serving entry points for every assigned architecture family.

Families:
  dense / moe / vlm          — causal decoder (attention or MLA + MLP/MoE)
  audio                      — bidirectional encoder, frame classification
  ssm                        — Mamba-2 stack
  hybrid                     — RecurrentGemma units (2×RG-LRU + 1×local attn)

Layers are scanned (params stacked on a leading L axis) with configurable
rematerialization, so the HLO stays compact at 94-layer scale and the
activation working set is one layer deep.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard
from ..nn.core import (
    init_layernorm,
    init_rmsnorm,
    layernorm,
    rmsnorm,
    truncated_normal_init,
)
from .attention import (
    apply_kv_cache_update,
    apply_mla_cache_update,
    attention_decode,
    attention_forward,
    attention_param_axes,
    init_attention,
    init_kv_cache,
    init_mla,
    init_mla_cache,
    kv_cache_axes,
    mla_cache_axes,
    mla_decode,
    mla_forward,
    mla_param_axes,
)
from .config import ArchConfig
from .mamba2 import (
    init_mamba2,
    init_ssm_state,
    mamba2_decode,
    mamba2_forward,
    mamba2_param_axes,
    ssm_state_axes,
)
from .mlp import init_mlp, mlp_forward, mlp_param_axes
from .moe import init_moe, moe_forward, moe_param_axes
from .rglru import (
    init_rglru_block,
    init_rglru_state,
    rglru_block_decode,
    rglru_block_forward,
    rglru_param_axes,
    rglru_state_axes,
)

__all__ = ["Model"]

VOCAB_CHUNK = 2048  # logit/CE chunk along seq to bound live logits


def _norm_init(cfg: ArchConfig):
    return init_rmsnorm if cfg.norm == "rmsnorm" else init_layernorm


def _norm_apply(cfg: ArchConfig):
    return rmsnorm if cfg.norm == "rmsnorm" else layernorm


def _remat(fn, cfg: ArchConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def _stack_init(init_fn, key, n: int):
    """vmap an init over layer keys -> params stacked on axis 0."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def _stacked_axes(layer_axes):
    """Prefix every leaf logical-axis tuple with the scan 'stack' dim."""
    return jax.tree.map(
        lambda ax: ("stack",) + tuple(ax),
        layer_axes,
        is_leaf=lambda x: isinstance(x, tuple),
    )


# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Model:
    """Functional model wrapper: init / param_axes / loss / serve paths."""

    cfg: ArchConfig

    # ---------------- layer definitions ----------------

    def _uses_moe_at(self, layer_in_stack: str) -> bool:
        return self.cfg.moe is not None and layer_in_stack == "main"

    def _init_tf_layer(self, key, moe: bool):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        ninit = _norm_init(cfg)
        p = {
            "ln_attn": ninit(cfg.d_model, jnp.dtype(cfg.param_dtype)),
            "attn": init_mla(k1, cfg) if cfg.mla else init_attention(k1, cfg),
            "ln_mlp": ninit(cfg.d_model, jnp.dtype(cfg.param_dtype)),
        }
        if moe:
            p["moe"] = init_moe(k2, cfg)
        else:
            p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_act, cfg.param_dtype)
        return p

    def _tf_layer_axes(self, moe: bool):
        cfg = self.cfg
        nax = {"scale": (None,)} if cfg.norm == "rmsnorm" else {
            "scale": (None,),
            "bias": (None,),
        }
        ax = {
            "ln_attn": nax,
            "attn": mla_param_axes(cfg) if cfg.mla else attention_param_axes(cfg),
            "ln_mlp": nax,
        }
        if moe:
            ax["moe"] = moe_param_axes(cfg)
        else:
            ax["mlp"] = mlp_param_axes(cfg.mlp_act)
        return ax

    def _tf_layer_fwd(self, p, x, positions, *, causal, window, moe: bool):
        cfg = self.cfg
        napply = _norm_apply(cfg)
        h = napply(p["ln_attn"], x)
        if cfg.mla:
            attn_out = mla_forward(p["attn"], h, cfg, positions)
        else:
            attn_out = attention_forward(
                p["attn"], h, cfg, positions, causal=causal, window=window
            )
        x = x + attn_out
        h = napply(p["ln_mlp"], x)
        if moe:
            mlp_out, aux = moe_forward(p["moe"], h, cfg)
        else:
            mlp_out, aux = mlp_forward(p["mlp"], h, cfg, cfg.mlp_act), None
        return x + mlp_out, aux

    def _tf_layer_decode(self, p, x, layer_cache, pos, *, moe: bool,
                         exclude_slot=None):
        """Read-only over layer_cache; returns (x, new_kv_rows)."""
        cfg = self.cfg
        napply = _norm_apply(cfg)
        h = napply(p["ln_attn"], x)
        if cfg.mla:
            attn_out, rows = mla_decode(p["attn"], h, layer_cache, pos, cfg)
        else:
            attn_out, rows = attention_decode(
                p["attn"], h, layer_cache, pos, cfg, exclude_slot=exclude_slot
            )
        x = x + attn_out
        h = napply(p["ln_mlp"], x)
        if moe:
            mlp_out, _ = moe_forward(p["moe"], h, cfg)
        else:
            mlp_out = mlp_forward(p["mlp"], h, cfg, cfg.mlp_act)
        return x + mlp_out, rows

    # ssm layer ---------------------------------------------------------

    def _init_ssm_layer(self, key):
        cfg = self.cfg
        return {
            "ln": _norm_init(cfg)(cfg.d_model, jnp.dtype(cfg.param_dtype)),
            "mixer": init_mamba2(key, cfg),
        }

    def _ssm_layer_axes(self):
        return {"ln": {"scale": (None,)}, "mixer": mamba2_param_axes(self.cfg)}

    # hybrid unit ---------------------------------------------------------

    def _init_hybrid_rec_layer(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        return {
            "ln_mix": _norm_init(cfg)(cfg.d_model, jnp.dtype(cfg.param_dtype)),
            "rec": init_rglru_block(k1, cfg),
            "ln_mlp": _norm_init(cfg)(cfg.d_model, jnp.dtype(cfg.param_dtype)),
            "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_act, cfg.param_dtype),
        }

    def _hybrid_rec_axes(self):
        return {
            "ln_mix": {"scale": (None,)},
            "rec": rglru_param_axes(self.cfg),
            "ln_mlp": {"scale": (None,)},
            "mlp": mlp_param_axes(self.cfg.mlp_act),
        }

    def _hybrid_rec_fwd(self, p, x, cfg):
        napply = _norm_apply(cfg)
        x = x + rglru_block_forward(p["rec"], napply(p["ln_mix"], x), cfg)
        x = x + mlp_forward(p["mlp"], napply(p["ln_mlp"], x), cfg, cfg.mlp_act)
        return x

    # ---------------- init ----------------

    def init(self, key) -> Dict:
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        dt = jnp.dtype(cfg.param_dtype)
        params: Dict[str, Any] = {
            "embed": {
                "table": truncated_normal_init(ks[0], (cfg.vocab, cfg.d_model), 0.02, dt)
            },
            "final_norm": _norm_init(cfg)(cfg.d_model, dt),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = truncated_normal_init(
                ks[1], (cfg.d_model, cfg.vocab), 1.0 / math.sqrt(cfg.d_model), dt
            )
        if cfg.frontend is not None:
            params["frontend"] = {
                "proj": truncated_normal_init(
                    ks[2], (cfg.frontend_dim, cfg.d_model), 1.0 / math.sqrt(cfg.frontend_dim), dt
                )
            }
        if cfg.family == "ssm":
            params["layers"] = _stack_init(self._init_ssm_layer, ks[4], cfg.n_layers)
        elif cfg.family == "hybrid":
            hy = cfg.hybrid
            unit = hy.rec_per_unit + hy.attn_per_unit
            n_units = cfg.n_layers // unit
            rem = cfg.n_layers - n_units * unit

            def init_unit(key):
                kr = jax.random.split(key, hy.rec_per_unit + 1)
                return {
                    "recs": _stack_init(
                        self._init_hybrid_rec_layer, kr[0], hy.rec_per_unit
                    ),
                    "attn": self._init_tf_layer(kr[-1], moe=False),
                }

            params["layers"] = _stack_init(init_unit, ks[4], n_units)
            if rem:
                params["tail"] = _stack_init(self._init_hybrid_rec_layer, ks[5], rem)
        elif cfg.moe is not None and cfg.moe.first_dense_layers:
            nd = cfg.moe.first_dense_layers
            params["dense_layers"] = _stack_init(
                lambda k: self._init_tf_layer(k, moe=False), ks[4], nd
            )
            params["layers"] = _stack_init(
                lambda k: self._init_tf_layer(k, moe=True), ks[5], cfg.n_layers - nd
            )
        else:
            moe = cfg.moe is not None
            params["layers"] = _stack_init(
                lambda k: self._init_tf_layer(k, moe=moe), ks[4], cfg.n_layers
            )
        return params

    def param_axes(self) -> Dict:
        cfg = self.cfg
        axes: Dict[str, Any] = {
            "embed": {"table": ("vocab", "fsdp")},
            "final_norm": {"scale": (None,)}
            if cfg.norm == "rmsnorm"
            else {"scale": (None,), "bias": (None,)},
        }
        if not cfg.tie_embeddings:
            axes["lm_head"] = ("fsdp", "vocab")
        if cfg.frontend is not None:
            axes["frontend"] = {"proj": (None, "fsdp")}
        if cfg.family == "ssm":
            axes["layers"] = _stacked_axes(self._ssm_layer_axes())
        elif cfg.family == "hybrid":
            hy = cfg.hybrid
            unit_axes = {
                "recs": _stacked_axes(self._hybrid_rec_axes()),
                "attn": self._tf_layer_axes(moe=False),
            }
            axes["layers"] = _stacked_axes(unit_axes)
            unit = hy.rec_per_unit + hy.attn_per_unit
            if cfg.n_layers % unit:
                axes["tail"] = _stacked_axes(self._hybrid_rec_axes())
        elif cfg.moe is not None and cfg.moe.first_dense_layers:
            axes["dense_layers"] = _stacked_axes(self._tf_layer_axes(moe=False))
            axes["layers"] = _stacked_axes(self._tf_layer_axes(moe=True))
        else:
            axes["layers"] = _stacked_axes(self._tf_layer_axes(moe=cfg.moe is not None))
        return axes

    # ---------------- forward (training / encoding) ----------------

    def _embed_inputs(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """-> (x (B,S,d), positions (B,S))."""
        cfg = self.cfg
        cd = jnp.dtype(cfg.compute_dtype)
        if cfg.family == "audio":
            x = batch["frames"].astype(cd) @ params["frontend"]["proj"].astype(cd)
        else:
            tokens = batch["tokens"]
            x = params["embed"]["table"].astype(cd)[tokens]
            if cfg.family == "vlm":
                patches = batch["patches"].astype(cd) @ params["frontend"]["proj"].astype(cd)
                x = jnp.concatenate([patches, x[:, patches.shape[1] :]], axis=1)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x = shard(x, "batch", "seq", None)
        return x, positions

    def _run_layers(self, params, x, positions) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """-> (hidden, aux_loss_sum)."""
        cfg = self.cfg
        causal = not cfg.encoder_only
        aux0 = jnp.zeros((), jnp.float32)

        if cfg.family == "ssm":

            def body(carry, lp):
                h = carry
                ln = _norm_apply(cfg)(lp["ln"], h)
                h = h + mamba2_forward(lp["mixer"], ln, cfg)
                return h, None

            x, _ = jax.lax.scan(_remat(body, cfg), x, params["layers"])
            return x, aux0

        if cfg.family == "hybrid":
            hy = cfg.hybrid

            def unit_body(carry, up):
                h = carry

                def rec_body(c, rp):
                    return self._hybrid_rec_fwd(rp, c, cfg), None

                h, _ = jax.lax.scan(rec_body, h, up["recs"])
                h, _ = self._tf_layer_fwd(
                    up["attn"], h, positions, causal=True, window=hy.window, moe=False
                )
                return h, None

            x, _ = jax.lax.scan(_remat(unit_body, cfg), x, params["layers"])
            if "tail" in params:

                def rec_body(c, rp):
                    return self._hybrid_rec_fwd(rp, c, cfg), None

                x, _ = jax.lax.scan(_remat(rec_body, cfg), x, params["tail"])
            return x, aux0

        # transformer stacks (dense / moe / vlm / audio)
        def make_body(moe: bool):
            def body(carry, lp):
                h, aux = carry
                h, layer_aux = self._tf_layer_fwd(
                    lp, h, positions, causal=causal, window=None, moe=moe
                )
                if layer_aux is not None and cfg.moe is not None:
                    m = cfg.moe
                    aux = aux + (
                        m.router_aux_weight * layer_aux["load_balance"]
                        + m.router_z_weight * layer_aux["router_z"]
                    )
                return (h, aux), None

            return body

        aux = aux0
        if cfg.moe is not None and cfg.moe.first_dense_layers:
            (x, aux), _ = jax.lax.scan(
                _remat(make_body(False), cfg), (x, aux), params["dense_layers"]
            )
        moe = cfg.moe is not None
        (x, aux), _ = jax.lax.scan(
            _remat(make_body(moe), cfg), (x, aux), params["layers"]
        )
        return x, aux

    def _logits_head(self, params):
        cfg = self.cfg
        if cfg.tie_embeddings:
            return params["embed"]["table"].T
        return params["lm_head"]

    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict]:
        """Next-token (or frame-classification) loss with chunked CE."""
        cfg = self.cfg
        cd = jnp.dtype(cfg.compute_dtype)
        x, positions = self._embed_inputs(params, batch)
        x, aux = self._run_layers(params, x, positions)
        x = _norm_apply(cfg)(params["final_norm"], x)
        head = self._logits_head(params).astype(cd)
        labels = batch["labels"]
        B, S = labels.shape

        if cfg.encoder_only:
            shift_x, shift_labels = x, labels
        else:
            shift_x, shift_labels = x[:, :-1], labels[:, 1:]
            S = S - 1

        csz = min(VOCAB_CHUNK, S)
        nchunk = S // csz

        @jax.checkpoint  # recompute chunk logits in backward
        def ce_chunk(carry, i):
            tot, cnt = carry
            xs = jax.lax.dynamic_slice_in_dim(shift_x, i * csz, csz, axis=1)
            ys = jax.lax.dynamic_slice_in_dim(shift_labels, i * csz, csz, axis=1)
            logits = (xs @ head).astype(jnp.float32)
            logits = shard(logits, "batch", None, "vocab")
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, ys[..., None], axis=-1)[..., 0]
            mask = (ys >= 0).astype(jnp.float32)
            tot = tot + jnp.sum((lse - gold) * mask)
            cnt = cnt + jnp.sum(mask)
            return (tot, cnt), None

        (tot, cnt), _ = jax.lax.scan(
            ce_chunk, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            jnp.arange(nchunk),
        )
        # remainder positions (S not divisible by chunk): fold in directly
        rem = S - nchunk * csz
        if rem > 0:
            xs = shift_x[:, nchunk * csz :]
            ys = shift_labels[:, nchunk * csz :]
            logits = (xs @ head).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, ys[..., None], axis=-1)[..., 0]
            mask = (ys >= 0).astype(jnp.float32)
            tot = tot + jnp.sum((lse - gold) * mask)
            cnt = cnt + jnp.sum(mask)

        ce = tot / jnp.maximum(cnt, 1.0)
        total = ce + aux
        return total, {"ce": ce, "aux": aux}

    def encode(self, params, batch) -> jnp.ndarray:
        """Encoder-only inference (hubert prefill cell): frame logits."""
        cfg = self.cfg
        cd = jnp.dtype(cfg.compute_dtype)
        x, positions = self._embed_inputs(params, batch)
        x, _ = self._run_layers(params, x, positions)
        x = _norm_apply(cfg)(params["final_norm"], x)
        return (x @ self._logits_head(params).astype(cd)).astype(jnp.float32)

    # ---------------- serving ----------------

    def prefill(self, params, batch) -> Tuple[jnp.ndarray, Dict]:
        """Process a full prompt; returns (last-token logits (B,V), cache).

        With cfg.prefill_chunks > 1 the prompt batch is processed in chunks
        via lax.map, bounding the transient working set (MoE dispatch /
        combine buffers scale with live tokens) at the cost of one cache
        re-layout."""
        cfg = self.cfg
        nc = cfg.prefill_chunks
        B = jax.tree.leaves(batch)[0].shape[0]
        if nc > 1 and B % nc == 0:
            chunked = jax.tree.map(
                lambda a: a.reshape((nc, B // nc) + a.shape[1:]), batch
            )
            logits, cache = jax.lax.map(
                lambda b: self._prefill_impl(params, b), chunked
            )
            logits = logits.reshape((B,) + logits.shape[2:])
            # (nc, L, bc, ...) -> (L, nc*bc, ...)
            cache = jax.tree.map(
                lambda a: jnp.moveaxis(a, 0, 1).reshape(
                    (a.shape[1], nc * a.shape[2]) + a.shape[3:]
                ),
                cache,
            )
            return logits, cache
        return self._prefill_impl(params, batch)

    def _prefill_impl(self, params, batch) -> Tuple[jnp.ndarray, Dict]:
        cfg = self.cfg
        cd = jnp.dtype(cfg.compute_dtype)
        x, positions = self._embed_inputs(params, batch)

        if cfg.family == "ssm":

            def body(carry, lp):
                h = carry
                ln = _norm_apply(cfg)(lp["ln"], h)
                out, st = mamba2_forward(lp["mixer"], ln, cfg, return_state=True)
                return h + out, st

            x, states = jax.lax.scan(_remat(body, cfg), x, params["layers"])
            cache = states
        elif cfg.family == "hybrid":
            hy = cfg.hybrid
            napply = _norm_apply(cfg)

            def rec_body(c, rp):
                out, st = rglru_block_forward(
                    rp["rec"], napply(rp["ln_mix"], c), cfg, return_state=True
                )
                c = c + out
                c = c + mlp_forward(rp["mlp"], napply(rp["ln_mlp"], c), cfg, cfg.mlp_act)
                return c, st

            def unit_body(carry, up):
                h = carry
                h, rec_states = jax.lax.scan(rec_body, h, up["recs"])
                h2 = napply(up["attn"]["ln_attn"], h)
                attn_out, (k, v) = attention_forward(
                    up["attn"]["attn"], h2, cfg, positions,
                    causal=True, window=hy.window, return_kv=True,
                )
                h = h + attn_out
                h = h + mlp_forward(
                    up["attn"]["mlp"], napply(up["attn"]["ln_mlp"], h), cfg, cfg.mlp_act
                )
                # keep only the last `window` keys (ring buffer contents)
                kv = (k[:, -hy.window :], v[:, -hy.window :])
                return h, (rec_states, kv)

            x, (ru, kvs) = jax.lax.scan(_remat(unit_body, cfg), x, params["layers"])
            rec = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), ru)
            if "tail" in params:
                x, tail_states = jax.lax.scan(
                    _remat(rec_body, cfg) if cfg.remat != "none" else rec_body,
                    x,
                    params["tail"],
                )
                rec = jax.tree.map(
                    lambda a, b: jnp.concatenate([a, b], 0), rec, tail_states
                )
            cache = {"attn": {"k": kvs[0], "v": kvs[1]}, "rec": rec}
        elif cfg.mla:

            def body(carry, xs):
                h, aux = carry
                lp = xs
                napply = _norm_apply(cfg)
                h2 = napply(lp["ln_attn"], h)
                attn_out, (c_kv, k_rope) = mla_forward(
                    lp["attn"], h2, cfg, positions, return_kv=True
                )
                h = h + attn_out
                h2 = napply(lp["ln_mlp"], h)
                if "moe" in lp:
                    mlp_out, _ = moe_forward(lp["moe"], h2, cfg)
                else:
                    mlp_out = mlp_forward(lp["mlp"], h2, cfg, cfg.mlp_act)
                return (h + mlp_out, aux), (c_kv, k_rope)

            aux0 = jnp.zeros((), jnp.float32)
            caches = []
            if cfg.moe is not None and cfg.moe.first_dense_layers:
                (x, _), kv_d = jax.lax.scan(
                    _remat(body, cfg), (x, aux0), params["dense_layers"]
                )
                caches.append(kv_d)
            (x, _), kv_m = jax.lax.scan(_remat(body, cfg), (x, aux0), params["layers"])
            caches.append(kv_m)
            c_kv = jnp.concatenate([c[0] for c in caches], 0)
            k_rope = jnp.concatenate([c[1] for c in caches], 0)
            cache = {"c_kv": c_kv, "k_rope": k_rope}
        else:
            moe = cfg.moe is not None

            def body(carry, lp):
                h = carry
                napply = _norm_apply(cfg)
                h2 = napply(lp["ln_attn"], h)
                attn_out, (k, v) = attention_forward(
                    lp["attn"], h2, cfg, positions, causal=True, return_kv=True
                )
                h = h + attn_out
                h2 = napply(lp["ln_mlp"], h)
                if moe:
                    mlp_out, _ = moe_forward(lp["moe"], h2, cfg)
                else:
                    mlp_out = mlp_forward(lp["mlp"], h2, cfg, cfg.mlp_act)
                return h + mlp_out, (k, v)

            x, (ks_, vs_) = jax.lax.scan(_remat(body, cfg), x, params["layers"])
            cache = {"k": ks_, "v": vs_}

        x = _norm_apply(cfg)(params["final_norm"], x)
        logits = (x[:, -1] @ self._logits_head(params).astype(cd)).astype(jnp.float32)
        logits = shard(logits, "batch", "vocab")
        return logits, cache

    def init_cache(self, batch: int, max_len: int) -> Dict:
        cfg = self.cfg
        if cfg.family == "ssm":
            return init_ssm_state(cfg, cfg.n_layers, batch)
        if cfg.family == "hybrid":
            hy = cfg.hybrid
            unit = hy.rec_per_unit + hy.attn_per_unit
            n_units = cfg.n_layers // unit
            rem = cfg.n_layers - n_units * unit
            cache = {
                "attn": init_kv_cache(cfg, n_units, batch, min(max_len, hy.window)),
                "rec": init_rglru_state(cfg, n_units * hy.rec_per_unit + rem, batch),
            }
            return cache
        if cfg.mla:
            return init_mla_cache(cfg, cfg.n_layers, batch, max_len)
        return init_kv_cache(cfg, cfg.n_layers, batch, max_len)

    def cache_axes(self) -> Dict:
        cfg = self.cfg
        if cfg.family == "ssm":
            return ssm_state_axes(cfg)
        if cfg.family == "hybrid":
            return {"attn": kv_cache_axes(cfg), "rec": rglru_state_axes(cfg)}
        if cfg.mla:
            return mla_cache_axes(cfg)
        return kv_cache_axes(cfg)

    def decode_step(self, params, cache, tokens, pos) -> Tuple[jnp.ndarray, Dict]:
        """One decode step.  tokens: (B,) int32; pos: scalar int32."""
        cfg = self.cfg
        cd = jnp.dtype(cfg.compute_dtype)
        x = params["embed"]["table"].astype(cd)[tokens][:, None, :]  # (B,1,d)

        if cfg.family == "ssm":

            def body(carry, xs):
                h = carry
                lp, st = xs
                ln = _norm_apply(cfg)(lp["ln"], h)
                out, new_st = mamba2_decode(lp["mixer"], ln, st, cfg)
                return h + out, new_st

            x, new_states = jax.lax.scan(body, x, (params["layers"], cache))
            new_cache = new_states
        elif cfg.family == "hybrid":
            hy = cfg.hybrid
            unit = hy.rec_per_unit + hy.attn_per_unit
            n_units = cfg.n_layers // unit
            rem = cfg.n_layers - n_units * unit
            rec_state = cache["rec"]
            # rec states grouped per unit: (n_units, rec_per_unit, B, w)
            ru = jax.tree.map(
                lambda a: a[: n_units * hy.rec_per_unit].reshape(
                    (n_units, hy.rec_per_unit) + a.shape[1:]
                ),
                rec_state,
            )
            napply = _norm_apply(cfg)
            # ring-buffer slot in the window cache
            win = cache["attn"]["k"].shape[2]
            slot = jnp.mod(pos, win)

            def unit_body(carry, xs):
                h = carry
                up, rst, att_cache = xs

                def rec_body(c, rxs):
                    rp, st = rxs
                    out, new_st = rglru_block_decode(
                        rp["rec"], napply(rp["ln_mix"], c), st, cfg
                    )
                    c = c + out
                    c = c + mlp_forward(rp["mlp"], napply(rp["ln_mlp"], c), cfg, cfg.mlp_act)
                    return c, new_st

                h, new_rst = jax.lax.scan(rec_body, h, (up["recs"], rst))
                h2 = napply(up["attn"]["ln_attn"], h)
                # ring buffer: the slot being overwritten holds the expired
                # (pos - window) entry -> exclude it; current token inline.
                attn_out, att_rows = attention_decode(
                    up["attn"]["attn"], h2, att_cache, pos, cfg,
                    exclude_slot=slot,
                )
                h = h + attn_out
                h = h + mlp_forward(
                    up["attn"]["mlp"], napply(up["attn"]["ln_mlp"], h), cfg, cfg.mlp_act
                )
                return h, (new_rst, att_rows)

            x, (new_ru, attn_rows) = jax.lax.scan(
                unit_body, x, (params["layers"], ru, cache["attn"])
            )
            new_attn = apply_kv_cache_update(cache["attn"], attn_rows, slot)
            new_rec = jax.tree.map(
                lambda a: a.reshape((n_units * hy.rec_per_unit,) + a.shape[2:]), new_ru
            )
            if rem:
                tail_state = jax.tree.map(
                    lambda a: a[n_units * hy.rec_per_unit :], rec_state
                )

                def rec_body(c, rxs):
                    rp, st = rxs
                    out, new_st = rglru_block_decode(
                        rp["rec"], napply(rp["ln_mix"], c), st, cfg
                    )
                    c = c + out
                    c = c + mlp_forward(rp["mlp"], napply(rp["ln_mlp"], c), cfg, cfg.mlp_act)
                    return c, new_st

                x, new_tail = jax.lax.scan(rec_body, x, (params["tail"], tail_state))
                new_rec = jax.tree.map(
                    lambda a, b: jnp.concatenate([a, b], 0), new_rec, new_tail
                )
            new_cache = {"attn": new_attn, "rec": new_rec}
        else:
            moe = cfg.moe is not None

            def body(carry, xs):
                h = carry
                lp, ca = xs
                h, rows = self._tf_layer_decode(lp, h, ca, pos, moe=moe)
                return h, rows

            if cfg.moe is not None and cfg.moe.first_dense_layers:
                nd = cfg.moe.first_dense_layers
                dense_cache = jax.tree.map(lambda a: a[:nd], cache)
                moe_cache = jax.tree.map(lambda a: a[nd:], cache)

                def body_dense(carry, xs):
                    h = carry
                    lp, ca = xs
                    h, rows = self._tf_layer_decode(lp, h, ca, pos, moe=False)
                    return h, rows

                x, r1 = jax.lax.scan(body_dense, x, (params["dense_layers"], dense_cache))
                x, r2 = jax.lax.scan(body, x, (params["layers"], moe_cache))
                rows = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0), r1, r2)
            else:
                x, rows = jax.lax.scan(body, x, (params["layers"], cache))
            # ONE donation-friendly cache write outside the layer scan
            if cfg.mla:
                new_cache = apply_mla_cache_update(cache, rows, pos)
            else:
                new_cache = apply_kv_cache_update(cache, rows, pos)

        x = _norm_apply(cfg)(params["final_norm"], x)
        cd = jnp.dtype(cfg.compute_dtype)
        logits = (x[:, 0] @ self._logits_head(params).astype(cd)).astype(jnp.float32)
        logits = shard(logits, "batch", "vocab")
        return logits, new_cache
