"""Griffin / RecurrentGemma recurrent block (RG-LRU, arXiv:2402.19427).

Block: x -> [branch1: linear -> gelu] ⊙ [branch2: linear -> causal conv ->
RG-LRU] -> out projection.  RG-LRU recurrence (diagonal, input-gated):

    r_t = sigmoid(W_r x_t);  i_t = sigmoid(W_i x_t)
    a_t = exp(c * softplus(Λ) * (-r_t))          (0 < a_t < 1, c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

Training uses an associative scan over the sequence (O(log S) depth — this
is the sub-quadratic path that makes the long_500k cell feasible); decode is
the O(1) state update.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard
from ..nn.core import truncated_normal_init
from .config import ArchConfig

__all__ = [
    "init_rglru_block",
    "rglru_block_forward",
    "rglru_block_decode",
    "rglru_param_axes",
    "init_rglru_state",
]

_C = 8.0


def init_rglru_block(key, cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    w = cfg.hybrid.lru_width or d
    k = cfg.hybrid.conv_kernel
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    std = 1.0 / math.sqrt(d)
    # Λ init so that a^c spans roughly [0.9, 0.999]
    lam = jax.random.uniform(ks[6], (w,), minval=0.0, maxval=1.0)
    a_init = 0.9 + 0.099 * lam
    lambda_init = jnp.log(jnp.expm1(-jnp.log(a_init) / _C))  # inv softplus
    return {
        "w_x": truncated_normal_init(ks[0], (d, w), std, dt),
        "w_gate": truncated_normal_init(ks[1], (d, w), std, dt),
        "conv_w": truncated_normal_init(ks[2], (k, w), 0.5, dt),
        "conv_b": jnp.zeros((w,), dt),
        "w_r": truncated_normal_init(ks[3], (w, w), 1.0 / math.sqrt(w), dt),
        "w_i": truncated_normal_init(ks[4], (w, w), 1.0 / math.sqrt(w), dt),
        "lambda": lambda_init.astype(jnp.float32),
        "out": truncated_normal_init(ks[5], (w, d), 1.0 / math.sqrt(w), dt),
    }


def rglru_param_axes(cfg: ArchConfig) -> Dict:
    return {
        "w_x": ("fsdp", "lru"),
        "w_gate": ("fsdp", "lru"),
        "conv_w": (None, "lru"),
        "conv_b": ("lru",),
        "w_r": ("fsdp", "lru"),
        "w_i": ("fsdp", "lru"),
        "lambda": ("lru",),
        "out": ("lru", "fsdp"),
    }


def _rglru_gates(p, u, cd):
    """u: (B,S,w) conv output -> (a, gated_input) both (B,S,w) fp32."""
    r = jax.nn.sigmoid(u @ p["w_r"].astype(cd)).astype(jnp.float32)
    i = jax.nn.sigmoid(u @ p["w_i"].astype(cd)).astype(jnp.float32)
    log_a = -_C * jax.nn.softplus(p["lambda"]) * r          # (B,S,w) <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u.astype(jnp.float32))
    return a, gated


def _causal_conv(x, w, b, kernel):
    pad = jnp.pad(x, ((0, 0), (kernel - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(kernel))
    return out + b


def rglru_block_forward(
    p: Dict, x: jnp.ndarray, cfg: ArchConfig, return_state: bool = False
):
    cd = jnp.dtype(cfg.compute_dtype)
    k = cfg.hybrid.conv_kernel
    gate = jax.nn.gelu(x.astype(cd) @ p["w_gate"].astype(cd), approximate=True)
    u_pre = x.astype(cd) @ p["w_x"].astype(cd)
    u_pre = shard(u_pre, "batch", "seq", "lru")
    u = _causal_conv(u_pre, p["conv_w"].astype(cd), p["conv_b"].astype(cd), k)
    a, gated = _rglru_gates(p, u, cd)

    # associative scan over the linear recurrence h_t = a_t h_{t-1} + b_t
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    y = h.astype(cd) * gate
    out = y @ p["out"].astype(cd)
    out = shard(out, "batch", "seq", None)
    if return_state:
        state = {
            "h": h[:, -1].astype(jnp.float32),
            "conv": u_pre[:, -(k - 1) :, :].astype(jnp.float32),
        }
        return out, state
    return out


def init_rglru_state(cfg: ArchConfig, n_rec_layers: int, batch: int):
    w = cfg.hybrid.lru_width or cfg.d_model
    k = cfg.hybrid.conv_kernel
    return {
        "h": jnp.zeros((n_rec_layers, batch, w), jnp.float32),
        "conv": jnp.zeros((n_rec_layers, batch, k - 1, w), jnp.float32),
    }


def rglru_state_axes(cfg: ArchConfig) -> Dict:
    return {
        "h": ("stack", "cache_batch", "lru"),
        "conv": ("stack", "cache_batch", None, "lru"),
    }


def rglru_block_decode(
    p: Dict, x: jnp.ndarray, state: Dict, cfg: ArchConfig
) -> Tuple[jnp.ndarray, Dict]:
    """x: (B,1,d); state h: (B,w), conv: (B,K-1,w)."""
    cd = jnp.dtype(cfg.compute_dtype)
    gate = jax.nn.gelu(x.astype(cd) @ p["w_gate"].astype(cd), approximate=True)
    u = x.astype(cd) @ p["w_x"].astype(cd)  # (B,1,w)
    hist = jnp.concatenate([state["conv"], u.astype(jnp.float32)], axis=1)
    conv_out = jnp.einsum("bkw,kw->bw", hist, p["conv_w"].astype(jnp.float32))
    u1 = (conv_out + p["conv_b"].astype(jnp.float32))[:, None, :].astype(cd)
    a, gated = _rglru_gates(p, u1, cd)
    h_new = a[:, 0] * state["h"] + gated[:, 0]
    y = h_new[:, None, :].astype(cd) * gate
    out = y @ p["out"].astype(cd)
    return out, {"h": h_new, "conv": hist[:, 1:, :]}
