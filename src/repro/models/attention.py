"""Attention layers for the zoo: MHA / GQA / MQA, MLA (DeepSeek), with RoPE /
M-RoPE, optional QKV bias and QK-norm, causal / bidirectional / local-window
masking, a flash-style chunked reference implementation (memory-safe at 32k+
sequence lengths), and decode paths over sharded KV caches.

Sharding strategy (see DESIGN.md §6):
  * If kv_heads divide the `model` axis -> tensor-parallel over heads.
  * Otherwise -> shard the query sequence over `model` (flash chunking keeps
    the working set bounded); KV replicated over `model`.
  * Decode caches are sharded (batch -> data, seq -> model); the softmax /
    context contractions over the sharded seq dim lower to all-reduces.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import current_mesh, shard
from ..nn.core import init_rmsnorm, rmsnorm, truncated_normal_init
from .config import ArchConfig
from .rotary import apply_mrope, apply_rope, text_mrope_positions

__all__ = [
    "init_attention",
    "attention_forward",
    "attention_decode",
    "init_mla",
    "mla_forward",
    "mla_decode",
    "flash_ref",
    "init_kv_cache",
    "init_mla_cache",
]


def _param(key, shape, fan_in, dtype):
    return truncated_normal_init(key, shape, 1.0 / math.sqrt(fan_in), dtype)


def _heads_shardable(n_kv_heads: int) -> bool:
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.shape:
        return True
    return n_kv_heads % mesh.shape["model"] == 0


# ---------------------------------------------------------------------------
# standard attention (MHA/GQA/MQA)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ArchConfig) -> Dict:
    d, H, Hkv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": _param(ks[0], (d, H, hd), d, dt),
        "wk": _param(ks[1], (d, Hkv, hd), d, dt),
        "wv": _param(ks[2], (d, Hkv, hd), d, dt),
        "wo": _param(ks[3], (H, hd, d), H * hd, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dt)
        p["bk"] = jnp.zeros((Hkv, hd), dt)
        p["bv"] = jnp.zeros((Hkv, hd), dt)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dt)
        p["k_norm"] = init_rmsnorm(hd, dt)
    return p


def attention_param_axes(cfg: ArchConfig) -> Dict:
    ax = {
        "wq": ("fsdp", "heads", "head_dim"),
        "wk": ("fsdp", "kv_heads", "head_dim"),
        "wv": ("fsdp", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "fsdp"),
    }
    if cfg.qkv_bias:
        ax.update(
            bq=("heads", "head_dim"),
            bk=("kv_heads", "head_dim"),
            bv=("kv_heads", "head_dim"),
        )
    if cfg.qk_norm:
        ax.update(q_norm={"scale": (None,)}, k_norm={"scale": (None,)})
    return ax


def _project_qkv(p, x, cfg: ArchConfig, positions):
    """x: (B,S,d) -> q (B,S,H,hd), k,v (B,S,Hkv,hd) with rope applied."""
    cd = jnp.dtype(cfg.compute_dtype)
    x = x.astype(cd)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    # (B,H,S,hd)
    q = q.transpose(0, 2, 1, 3)
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    if cfg.rope == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope == "mrope":
        pos3 = text_mrope_positions(positions)
        q = apply_mrope(q, pos3, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, pos3, cfg.mrope_sections, cfg.rope_theta)
    return q, k, v


def flash_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    block_q: int = 512,
    block_k: int = 512,
) -> jnp.ndarray:
    """Pure-jnp flash attention (online softmax over K blocks, scan over Q
    blocks).  Shapes: q (B,H,Sq,D), k/v (B,H,Sk,D) with GQA handled by the
    caller.  Memory: O(block_q * block_k) scores — safe at 32k+.

    `q_offset`: absolute position of q[0] (prefill continuation / decode).
    `window`: local attention span (keys with q_pos - k_pos >= window masked).
    """
    B, H, Sq, D = q.shape
    Dv = v.shape[-1]  # MLA: value head dim may differ from qk head dim
    Sk = k.shape[2]
    scale = 1.0 / math.sqrt(D)
    nq = -(-Sq // block_q)
    nk = -(-Sk // block_k)
    pad_q = nq * block_q - Sq
    pad_k = nk * block_k - Sk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    kp = kp.reshape(B, H, nk, block_k, D)
    vp = vp.reshape(B, H, nk, block_k, Dv)

    q_pos_base = jnp.arange(block_q)
    k_pos_base = jnp.arange(block_k)

    def q_block(qi, qblk):
        # qblk: (B,H,block_q,D)
        m0 = jnp.full((B, H, block_q), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, block_q), jnp.float32)
        a0 = jnp.zeros((B, H, block_q, Dv), jnp.float32)
        qpos = q_offset + qi * block_q + q_pos_base  # (block_q,)

        @jax.checkpoint  # flash semantics: recompute scores in backward
        def k_step(carry, ki):
            m, l, acc = carry
            kblk = kp[:, :, ki]
            vblk = vp[:, :, ki]
            s = jnp.einsum("bhqd,bhkd->bhqk", qblk, kblk).astype(jnp.float32) * scale
            kpos = ki * block_k + k_pos_base
            mask = jnp.ones((block_q, block_k), bool)
            if causal:
                mask &= qpos[:, None] >= kpos[None, :]
            if window is not None:
                mask &= qpos[:, None] - kpos[None, :] < window
            mask &= (kpos < Sk)[None, :]
            s = jnp.where(mask, s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(-1))
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p_ = jnp.exp(s - m_safe[..., None])
            p_ = jnp.where(mask, p_, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + p_.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p_.astype(qblk.dtype), vblk
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(k_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(q.dtype)

    qblocks = qp.reshape(B, H, nq, block_q, D).transpose(2, 0, 1, 3, 4)
    out = jax.lax.map(jax.checkpoint(lambda t: q_block(t[0], t[1])), (jnp.arange(nq), qblocks))
    out = out.transpose(1, 2, 0, 3, 4).reshape(B, H, nq * block_q, Dv)
    return out[:, :, :Sq]


def _pad_heads(x, msize: int):
    """Pad the head dim (axis 1) to a multiple of the model-axis size.

    Uneven GSPMD shardings triggered 'involuntary full rematerialization'
    copies in the SPMD partitioner (observed: 42 GiB/device temps on the
    40-head qwen1.5 cells).  Explicit zero-padding (40 -> 48 on a 16-way
    axis) keeps every collective even at <=20%% padded-head waste, and the
    output projection contracts the zero heads away exactly.
    """
    H = x.shape[1]
    pad = (-H) % msize
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return x, H


def _attend(q, k, v, cfg: ArchConfig, *, causal, window, q_offset=0):
    """GQA-aware attention dispatch: Pallas kernel or flash reference.

    After the GQA repeat all of q/k/v are (B, H, S, D); heads are padded to
    an even multiple of the `model` axis and sharded over it; batch over
    (pod, data).
    """
    B, H, Sq, D = q.shape
    Hkv = k.shape[1]
    if H != Hkv:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    mesh = current_mesh()
    msize = mesh.shape.get("model", 1) if mesh is not None else 1
    q, H0 = _pad_heads(q, msize)
    k, _ = _pad_heads(k, msize)
    v, _ = _pad_heads(v, msize)
    q = shard(q, "batch", "heads", None, None)
    k = shard(k, "batch", "heads", None, None)
    v = shard(v, "batch", "heads", None, None)
    if cfg.use_pallas and window is None:
        from ..kernels.attention.ops import flash_attention

        o = flash_attention(q, k, v, causal=causal, q_offset=q_offset)
    else:
        o = flash_ref(q, k, v, causal=causal, window=window, q_offset=q_offset)
    return o[:, :H0]


def attention_forward(
    p: Dict,
    x: jnp.ndarray,
    cfg: ArchConfig,
    positions: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    return_kv: bool = False,
):
    """Full-sequence attention (training / prefill). x: (B,S,d).

    With return_kv=True also returns (k, v) in cache layout (B,S,Hkv,hd) —
    the prefill path's per-layer cache contribution.
    """
    q, k, v = _project_qkv(p, x, cfg, positions)
    o = _attend(q, k, v, cfg, causal=causal, window=window)
    o = o.transpose(0, 2, 1, 3)  # (B,S,H,hd)
    cd = jnp.dtype(cfg.compute_dtype)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(cd))
    out = shard(out, "batch", "seq", None)
    if return_kv:
        kv = (k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3))
        return out, kv
    return out


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ArchConfig, n_layers: int, batch: int, max_len: int):
    """Stacked-layer KV cache (L, B, S, Hkv, hd) + scales for int8 mode."""
    hd = cfg.resolved_head_dim
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, hd)
    if cfg.kv_cache_dtype == "int8":
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:-1], jnp.float32),
            "v_scale": jnp.zeros(shape[:-1], jnp.float32),
        }
    return {
        "k": jnp.zeros(shape, jnp.dtype(cfg.kv_cache_dtype)),
        "v": jnp.zeros(shape, jnp.dtype(cfg.kv_cache_dtype)),
    }


def kv_cache_axes(cfg: ArchConfig) -> Dict:
    ax = ("stack", "cache_batch", "cache_seq", None, None)
    d = {"k": ax, "v": ax}
    if cfg.kv_cache_dtype == "int8":
        d["k_scale"] = ax[:-1]
        d["v_scale"] = ax[:-1]
    return d


def _quantize_kv(x):
    """(B,1,H,D) -> int8 + per (B,1,H) scale."""
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.maximum(amax.astype(jnp.float32), 1e-6) / 127.0
    q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def attention_decode(
    p: Dict,
    x: jnp.ndarray,
    layer_cache: Dict,
    pos: jnp.ndarray,
    cfg: ArchConfig,
    *,
    exclude_slot: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Single-token decode, READ-ONLY over the cache.

    x: (B,1,d); layer_cache k/v: (B,S,Hkv,hd).  Attends over the old cache
    (positions < pos; ring buffers additionally exclude the stale
    `exclude_slot`) plus the current token's k/v inline, and returns
    (out, (k_new, v_new)) — the caller performs ONE batched cache update
    outside the layer scan.  Rationale: updating a donated cache inside
    lax.scan forces XLA to keep a full pre-loop copy (observed +20
    GiB/device); a read-only loop plus a single elementwise select keeps
    the donated buffer truly in place.  The cache seq dim is sharded over
    `model`; softmax/context over it lower to all-reduces.
    """
    cd = jnp.dtype(cfg.compute_dtype)
    B = x.shape[0]
    positions = jnp.broadcast_to(pos[None], (B,))[:, None]  # (B,1)
    q, k_new, v_new = _project_qkv(p, x, cfg, positions)  # (B,H,1,hd)
    k_row = k_new.transpose(0, 2, 1, 3)  # (B,1,Hkv,hd)
    v_row = v_new.transpose(0, 2, 1, 3)

    int8 = "k_scale" in layer_cache
    if int8:
        k_all = layer_cache["k"].astype(cd) * layer_cache["k_scale"][..., None].astype(cd)
        v_all = layer_cache["v"].astype(cd) * layer_cache["v_scale"][..., None].astype(cd)
    else:
        k_all = layer_cache["k"].astype(cd)
        v_all = layer_cache["v"].astype(cd)

    k_all = shard(k_all, "cache_batch", "cache_seq", None, None)
    v_all = shard(v_all, "cache_batch", "cache_seq", None, None)

    S = k_all.shape[1]
    Hkv = k_all.shape[2]
    H = q.shape[1]
    scale = 1.0 / math.sqrt(q.shape[-1])
    qh = q[:, :, 0]  # (B,H,hd)
    kpos = jnp.arange(S)
    valid = kpos < pos
    if exclude_slot is not None:
        valid = valid & (kpos != exclude_slot)

    if H != Hkv:
        qg = qh.reshape(B, Hkv, H // Hkv, -1)
        s_cache = jnp.einsum("bgrd,bsgd->bgrs", qg, k_all).astype(jnp.float32) * scale
        s_cache = jnp.where(valid[None, None, None, :], s_cache, -1e30)
        s_new = jnp.einsum("bgrd,bgd->bgr", qg, k_row[:, 0].astype(cd)).astype(
            jnp.float32
        )[..., None] * scale
        scores = jnp.concatenate([s_cache, s_new], axis=-1)
        probs = jax.nn.softmax(scores, axis=-1).astype(cd)
        ctx = jnp.einsum("bgrs,bsgd->bgrd", probs[..., :S], v_all)
        ctx = ctx + probs[..., S:] * v_row[:, 0, :, None, :]
        ctx = ctx.reshape(B, H, -1)
    else:
        s_cache = jnp.einsum("bhd,bshd->bhs", qh, k_all).astype(jnp.float32) * scale
        s_cache = jnp.where(valid[None, None, :], s_cache, -1e30)
        s_new = jnp.einsum("bhd,bhd->bh", qh, k_row[:, 0].astype(cd)).astype(
            jnp.float32
        )[..., None] * scale
        scores = jnp.concatenate([s_cache, s_new], axis=-1)
        probs = jax.nn.softmax(scores, axis=-1).astype(cd)
        ctx = jnp.einsum("bhs,bshd->bhd", probs[..., :S], v_all)
        ctx = ctx + probs[..., S] [..., None] * v_row[:, 0].astype(cd)
    out = jnp.einsum("bhk,hkd->bd", ctx, p["wo"].astype(cd))[:, None]
    return out, (k_row, v_row)


def _sharded_seq_write(old: jnp.ndarray, rows: jnp.ndarray, pos) -> jnp.ndarray:
    """Write `rows` (L,B,1,...) at seq position `pos` (dim 2) of the
    (L,B,S,...) cache, truly in place.

    With the seq dim sharded over `model`, both dynamic_update_slice (SPMD
    'involuntary full rematerialization' copies) and full-size selects
    (XLA:CPU upcasts bf16 selects to f32: +2x cache in f32 temps) blow up.
    shard_map makes the update LOCAL: only the shard owning `pos` writes —
    a 1-row dynamic_slice/select/dynamic_update_slice per device.
    """
    from ..distributed.sharding import logical_to_spec

    mesh = current_mesh()
    trail = (None,) * (old.ndim - 3)

    def local_update(c, r, p_start):
        S_loc = c.shape[2]
        local = pos - p_start
        safe = jnp.clip(local, 0, S_loc - 1)
        cur = jax.lax.dynamic_slice_in_dim(c, safe, 1, axis=2)
        in_range = jnp.logical_and(local >= 0, local < S_loc)
        row = jax.lax.select(
            jnp.broadcast_to(in_range, cur.shape), r.astype(c.dtype), cur
        )
        return jax.lax.dynamic_update_slice_in_dim(c, row, safe, axis=2)

    if mesh is None or "model" not in mesh.shape or old.shape[2] % mesh.shape["model"]:
        return local_update(old, rows, jnp.int32(0))


    cache_spec = logical_to_spec(
        ("stack", "cache_batch", "cache_seq") + trail, old.shape, mesh
    )
    rows_spec = logical_to_spec(
        ("stack", "cache_batch", None) + trail, rows.shape, mesh
    )

    def body(c, r):
        idx = jax.lax.axis_index("model")
        return local_update(c, r, idx * c.shape[2])

    from ..compat import shard_map

    return shard_map(
        body, mesh=mesh, in_specs=(cache_spec, rows_spec), out_specs=cache_spec,
    )(old, rows)


def apply_kv_cache_update(cache: Dict, new_kv, write_slot) -> Dict:
    """One batched in-place write of the stacked per-layer rows into the
    (L,B,S,Hkv,hd) cache — donation-friendly.

    new_kv: (k_rows, v_rows) each (L,B,1,Hkv,hd) float.
    """
    k_rows, v_rows = new_kv
    if "k_scale" in cache:
        kq, ks = _quantize_kv(k_rows)
        vq, vs = _quantize_kv(v_rows)
        return {
            "k": _sharded_seq_write(cache["k"], kq, write_slot),
            "v": _sharded_seq_write(cache["v"], vq, write_slot),
            "k_scale": _sharded_seq_write(cache["k_scale"], ks, write_slot),
            "v_scale": _sharded_seq_write(cache["v_scale"], vs, write_slot),
        }
    return {
        "k": _sharded_seq_write(cache["k"], k_rows, write_slot),
        "v": _sharded_seq_write(cache["v"], v_rows, write_slot),
    }


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2) — compressed KV attention
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ArchConfig) -> Dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq": _param(ks[0], (d, H, qd), d, dt),
        "w_dkv": _param(ks[1], (d, m.kv_lora_rank), d, dt),
        "w_kr": _param(ks[2], (d, m.qk_rope_head_dim), d, dt),
        "kv_norm": init_rmsnorm(m.kv_lora_rank, dt),
        "w_uk": _param(ks[3], (m.kv_lora_rank, H, m.qk_nope_head_dim), m.kv_lora_rank, dt),
        "w_uv": _param(ks[4], (m.kv_lora_rank, H, m.v_head_dim), m.kv_lora_rank, dt),
        "wo": _param(ks[5], (H, m.v_head_dim, d), H * m.v_head_dim, dt),
    }


def mla_param_axes(cfg: ArchConfig) -> Dict:
    return {
        "wq": ("fsdp", "heads", None),
        "w_dkv": ("fsdp", None),
        "w_kr": ("fsdp", None),
        "kv_norm": {"scale": (None,)},
        "w_uk": (None, "heads", None),
        "w_uv": (None, "heads", None),
        "wo": ("heads", None, "fsdp"),
    }


def mla_forward(
    p: Dict,
    x: jnp.ndarray,
    cfg: ArchConfig,
    positions: jnp.ndarray,
    return_kv: bool = False,
):
    """Full-sequence MLA (training/prefill), causal."""
    m = cfg.mla
    cd = jnp.dtype(cfg.compute_dtype)
    x = x.astype(cd)
    B, S, _ = x.shape
    H = cfg.n_heads
    nope, rope_d = m.qk_nope_head_dim, m.qk_rope_head_dim

    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"].astype(cd))
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = rmsnorm(p["kv_norm"], jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(cd)))
    k_rope = apply_rope(
        jnp.einsum("bsd,dk->bsk", x, p["w_kr"].astype(cd))[:, None],
        positions,
        cfg.rope_theta,
    )  # (B,1,S,rope_d) shared across heads
    k_nope = jnp.einsum("bsr,rhk->bhsk", c_kv, p["w_uk"].astype(cd))
    v = jnp.einsum("bsr,rhk->bhsk", c_kv, p["w_uv"].astype(cd))

    qf = jnp.concatenate([q_nope, q_rope], -1)
    kf = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, H, S, rope_d))], -1)
    qf = shard(qf, "batch", "heads", None, None)
    kf = shard(kf, "batch", "heads", None, None)
    o = flash_ref(qf, kf, v, causal=True)
    out = jnp.einsum("bhsk,hkd->bsd", o, p["wo"].astype(cd))
    out = shard(out, "batch", "seq", None)
    if return_kv:
        # compressed cache: (c_kv (B,S,r), k_rope (B,S,rope_d))
        return out, (c_kv, k_rope[:, 0])
    return out


def init_mla_cache(cfg: ArchConfig, n_layers: int, batch: int, max_len: int):
    m = cfg.mla
    dt = jnp.dtype(cfg.kv_cache_dtype) if cfg.kv_cache_dtype != "int8" else jnp.bfloat16
    return {
        "c_kv": jnp.zeros((n_layers, batch, max_len, m.kv_lora_rank), dt),
        "k_rope": jnp.zeros((n_layers, batch, max_len, m.qk_rope_head_dim), dt),
    }


def mla_cache_axes(cfg: ArchConfig) -> Dict:
    return {
        "c_kv": ("stack", "cache_batch", "cache_seq", None),
        "k_rope": ("stack", "cache_batch", "cache_seq", None),
    }


def mla_decode(
    p: Dict,
    x: jnp.ndarray,
    layer_cache: Dict,
    pos: jnp.ndarray,
    cfg: ArchConfig,
) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Weight-absorbed MLA decode: attention runs directly over the
    compressed c_kv cache — the memory/bandwidth win MLA exists for.

    READ-ONLY over the cache (same rationale as attention_decode): returns
    (out, (c_new, kr_new)); the caller batches the cache write."""
    m = cfg.mla
    cd = jnp.dtype(cfg.compute_dtype)
    B = x.shape[0]
    x = x.astype(cd)
    positions = jnp.broadcast_to(pos[None], (B,))[:, None]

    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"].astype(cd))  # (B,H,1,qd)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)[:, :, 0]  # (B,H,rd)
    q_nope = q_nope[:, :, 0]

    c_new = rmsnorm(p["kv_norm"], jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(cd)))
    kr_new = apply_rope(
        jnp.einsum("bsd,dk->bsk", x, p["w_kr"].astype(cd))[:, None],
        positions,
        cfg.rope_theta,
    )[:, 0]  # (B,1,rd)

    c_all = shard(layer_cache["c_kv"].astype(cd), "cache_batch", "cache_seq", None)
    kr_all = shard(layer_cache["k_rope"].astype(cd), "cache_batch", "cache_seq", None)

    # absorbed scores: q_c = q_nope @ W_uk  -> (B,H,r); scores over c_kv
    q_c = jnp.einsum("bhk,rhk->bhr", q_nope, p["w_uk"].astype(cd))
    s_c = jnp.einsum("bhr,bsr->bhs", q_c, c_all)
    s_r = jnp.einsum("bhk,bsk->bhs", q_rope, kr_all)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (s_c + s_r).astype(jnp.float32) * scale
    S = c_all.shape[1]
    valid = jnp.arange(S) < pos
    scores = jnp.where(valid[None, None, :], scores, -1e30)
    # inline current-token score
    s_new = (
        jnp.einsum("bhr,br->bh", q_c, c_new[:, 0])
        + jnp.einsum("bhk,bk->bh", q_rope, kr_new[:, 0])
    ).astype(jnp.float32)[..., None] * scale
    scores = jnp.concatenate([scores, s_new], axis=-1)
    probs = jax.nn.softmax(scores, axis=-1).astype(cd)
    ctx_c = jnp.einsum("bhs,bsr->bhr", probs[..., :S], c_all)   # (B,H,r)
    ctx_c = ctx_c + probs[..., S][..., None] * c_new[:, 0][:, None, :]
    ctx = jnp.einsum("bhr,rhk->bhk", ctx_c, p["w_uv"].astype(cd))
    out = jnp.einsum("bhk,hkd->bd", ctx, p["wo"].astype(cd))[:, None]
    return out, (c_new, kr_new)


def apply_mla_cache_update(cache: Dict, new_rows, pos) -> Dict:
    """Batched in-place write of (L,B,1,·) rows into the MLA cache."""
    c_rows, kr_rows = new_rows
    return {
        "c_kv": _sharded_seq_write(cache["c_kv"], c_rows, pos),
        "k_rope": _sharded_seq_write(cache["k_rope"], kr_rows, pos),
    }
