"""Dense MLP blocks (SwiGLU / GELU) with TP sharding annotations."""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from ..distributed.sharding import shard
from ..nn.core import truncated_normal_init
from .config import ArchConfig

__all__ = ["init_mlp", "mlp_forward", "mlp_param_axes"]


def init_mlp(key, d_model: int, d_ff: int, act: str, param_dtype) -> Dict:
    dt = jnp.dtype(param_dtype)
    ks = jax.random.split(key, 3)
    std_in = 1.0 / math.sqrt(d_model)
    std_out = 1.0 / math.sqrt(d_ff)
    p = {
        "w_up": truncated_normal_init(ks[0], (d_model, d_ff), std_in, dt),
        "w_down": truncated_normal_init(ks[1], (d_ff, d_model), std_out, dt),
    }
    if act == "swiglu":
        p["w_gate"] = truncated_normal_init(ks[2], (d_model, d_ff), std_in, dt)
    return p


def mlp_param_axes(act: str) -> Dict:
    ax = {"w_up": ("fsdp", "mlp"), "w_down": ("mlp", "fsdp")}
    if act == "swiglu":
        ax["w_gate"] = ("fsdp", "mlp")
    return ax


def mlp_forward(p: Dict, x: jnp.ndarray, cfg: ArchConfig, act: str) -> jnp.ndarray:
    cd = jnp.dtype(cfg.compute_dtype)
    x = x.astype(cd)
    mid = (None,) * (x.ndim - 2)  # rank-general: (B,S,d) or flattened (T,d)
    up = x @ p["w_up"].astype(cd)
    up = shard(up, "batch", *mid, "mlp")
    if act == "swiglu":
        gate = x @ p["w_gate"].astype(cd)
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up, approximate=True)
    out = h @ p["w_down"].astype(cd)
    if x.ndim == 3:
        return shard(out, "batch", "seq", None)
    return shard(out, "batch", None)
