"""Architecture configuration schema for the assigned-architecture zoo.

Every assigned architecture is expressed as an ArchConfig instance in
`repro/configs/<id>.py`; reduced smoke-test variants are derived with
`.reduced()`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["MoEConfig", "MLAConfig", "SSMConfig", "HybridConfig", "ArchConfig"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3
    # layers before this index use a dense MLP (DeepSeek: first layer dense)
    first_dense_layers: int = 0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block parameters."""

    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    n_groups: int = 1
    chunk: int = 256
    dt_min: float = 0.001
    dt_max: float = 0.1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma-style: repeating (recurrent × rec_per_unit, attention)."""

    rec_per_unit: int = 2            # RG-LRU layers per unit
    attn_per_unit: int = 1           # local-attention layers per unit
    window: int = 2048               # local attention window
    lru_width: Optional[int] = None  # defaults to d_model
    conv_kernel: int = 4


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | ssm | moe | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None   # explicit (qwen3: 128); else d_model/n_heads
    qkv_bias: bool = False
    qk_norm: bool = False            # qwen3-style per-head RMSNorm on q,k
    rope: str = "rope"               # rope | mrope | none
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    mlp_act: str = "swiglu"          # swiglu | gelu
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    tie_embeddings: bool = False
    encoder_only: bool = False       # hubert: bidirectional, no decode
    frontend: Optional[str] = None   # audio_stub | vision_stub
    frontend_dim: int = 512          # stub embedding dim
    vision_patches: int = 64         # patches prepended per sample (vlm stub)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # numerics / memory policy
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "full"              # full | dots | none
    scan_layers: bool = True
    use_pallas: bool = False         # route attention/SSD through Pallas kernels
    kv_cache_dtype: str = "bfloat16"  # bfloat16 | int8 (beyond-paper opt)
    # process the prompt batch in chunks (lax.map) to bound prefill temps
    # (MoE dispatch/combine buffers scale with live tokens)
    prefill_chunks: int = 1

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Supports the long_500k cell (state-space or windowed attention)."""
        return self.family in ("ssm", "hybrid")

    def reduced(self) -> "ArchConfig":
        """Small same-family variant for CPU smoke tests."""
        kw = dataclasses.asdict(self)
        # dataclasses.asdict recurses; rebuild the nested configs.
        kw["moe"] = (
            dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 8),
                top_k=min(self.moe.top_k, 2),
                d_ff_expert=64,
                d_ff_shared=64 if self.moe.num_shared else 0,
                # dropless at smoke scale: capacity C >= Tg*k for any routing,
                # so prefill/decode consistency tests are exact
                capacity_factor=8.0,
            )
            if self.moe
            else None
        )
        kw["mla"] = (
            dataclasses.replace(self.mla, kv_lora_rank=32, qk_nope_head_dim=16,
                                qk_rope_head_dim=8, v_head_dim=16)
            if self.mla
            else None
        )
        kw["ssm"] = (
            dataclasses.replace(self.ssm, d_state=16, head_dim=16, chunk=32)
            if self.ssm
            else None
        )
        kw["hybrid"] = (
            dataclasses.replace(self.hybrid, window=32, lru_width=None)
            if self.hybrid
            else None
        )
        n_heads = min(self.n_heads, 4) if self.n_heads else 0
        n_kv = min(self.n_kv_heads, n_heads) if self.n_kv_heads else n_heads
        if n_kv and n_heads % n_kv:
            n_kv = 1
        if self.rope == "mrope":
            # keep sections summing to (reduced head_dim)/2 = 8
            kw["mrope_sections"] = (2, 3, 3)
        kw.update(
            n_layers=min(self.n_layers, 4)
            if not self.hybrid
            else (self.hybrid.rec_per_unit + self.hybrid.attn_per_unit) + 1,
            d_model=64,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_ff=128,
            vocab=512,
            head_dim=16 if self.head_dim is not None else None,
            frontend_dim=32,
            vision_patches=4,
            param_dtype="float32",
            compute_dtype="float32",
            remat="none",
            use_pallas=False,
        )
        return ArchConfig(**kw)
