"""Rotary position embeddings: standard RoPE and Qwen2-VL's M-RoPE.

M-RoPE splits the head_dim/2 rotary frequencies into (temporal, height,
width) sections with separate position ids per section; for pure-text tokens
all three position streams coincide, which reduces exactly to RoPE.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

__all__ = ["rope_freqs", "apply_rope", "apply_mrope", "text_mrope_positions"]


def rope_freqs(head_dim: int, theta: float = 10_000.0) -> jnp.ndarray:
    """(head_dim/2,) inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def _rotate(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, D); angles: broadcastable (..., S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles).astype(x.dtype)
    sin = jnp.sin(angles).astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    theta: float = 10_000.0,
) -> jnp.ndarray:
    """x: (B, H, S, D); positions: (B, S) or (S,)."""
    freqs = rope_freqs(x.shape[-1], theta)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,S,D/2)
    return _rotate(x, angles)


def text_mrope_positions(positions: jnp.ndarray) -> jnp.ndarray:
    """(B, S) -> (3, B, S): t/h/w streams coincide for text tokens."""
    if positions.ndim == 1:
        positions = positions[None, :]
    return jnp.broadcast_to(positions[None], (3,) + positions.shape)


def apply_mrope(
    x: jnp.ndarray,
    positions3: jnp.ndarray,
    sections: Tuple[int, int, int],
    theta: float = 10_000.0,
) -> jnp.ndarray:
    """x: (B, H, S, D); positions3: (3, B, S); sections sum to D/2."""
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)  # (D/2,)
    # angles per stream: (3, B, S, D/2)
    ang = positions3[..., None].astype(jnp.float32) * freqs
    # select stream per frequency section
    sel = jnp.concatenate(
        [jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)]
    )  # (D/2,)
    angles = jnp.moveaxis(ang, 0, -1)  # (B, S, D/2, 3)
    angles = jnp.take_along_axis(angles, sel[None, None, :, None], axis=-1)[..., 0]
    return _rotate(x, angles[:, None, :, :])
