"""Deterministic, checkpointable data pipelines.

LMDataPipeline: synthetic-token LM stream (Zipfian unigram + order-2 Markov
mixing, so a model actually has signal to learn) with a counter-based PRNG:
batch i is a pure function of (seed, i), so restoring `next_index` from a
checkpoint resumes the exact stream — no iterator state files, no host
coordination.  Per-host sharding slices the batch by host id (data-parallel
convention: host h feeds devices owning batch rows [h*b/H, (h+1)*b/H)).

TraceDataPipeline: streams Tao window datasets (repro.core.dataset) with the
same counter-based determinism.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import jax
import numpy as np

from ..core.dataset import WindowDataset
from ..models.config import ArchConfig

__all__ = ["LMDataPipeline", "TraceDataPipeline", "make_lm_batch_specs"]


def make_lm_batch_specs(cfg: ArchConfig, batch: int, seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for one global batch (dry-run input stand-ins)."""
    import jax.numpy as jnp

    if cfg.family == "audio":
        return {
            "frames": jax.ShapeDtypeStruct((batch, seq, cfg.frontend_dim), jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        }
    specs = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.family == "vlm":
        specs["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.vision_patches, cfg.frontend_dim), jnp.bfloat16
        )
    return specs


@dataclasses.dataclass
class LMDataPipeline:
    cfg: ArchConfig
    batch: int
    seq: int
    seed: int = 0
    next_index: int = 0          # checkpointable cursor
    host_id: int = 0
    num_hosts: int = 1

    def _host_slice(self) -> Tuple[int, int]:
        per = self.batch // self.num_hosts
        return self.host_id * per, (self.host_id + 1) * per

    def make_batch(self, index: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, index) -> global batch (host's slice)."""
        cfg = self.cfg
        lo, hi = self._host_slice()
        rng = np.random.default_rng((self.seed << 20) ^ index)
        b = hi - lo
        if cfg.family == "audio":
            frames = rng.standard_normal((b, self.seq, cfg.frontend_dim)).astype(np.float32)
            labels = rng.integers(0, cfg.vocab, size=(b, self.seq)).astype(np.int32)
            return {"frames": frames, "labels": labels}
        # Zipfian unigram mixed with a deterministic order-2 relation.
        v = cfg.vocab
        zipf = rng.zipf(1.3, size=(b, self.seq)).astype(np.int64)
        toks = np.minimum(zipf, v - 1)
        # second-order structure: with p=0.5, t[i] = f(t[i-1], t[i-2])
        mix = rng.random((b, self.seq)) < 0.5
        for i in range(2, self.seq):
            f = (toks[:, i - 1] * 31 + toks[:, i - 2] * 17 + 7) % v
            toks[:, i] = np.where(mix[:, i], f, toks[:, i])
        toks = toks.astype(np.int32)
        out = {"tokens": toks, "labels": toks.copy()}
        if cfg.family == "vlm":
            out["patches"] = rng.standard_normal(
                (b, cfg.vision_patches, cfg.frontend_dim)
            ).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.make_batch(self.next_index)
            self.next_index += 1

    def state_dict(self) -> Dict:
        return {"next_index": self.next_index, "seed": self.seed}

    def load_state_dict(self, state: Dict) -> None:
        self.next_index = int(state["next_index"])
        self.seed = int(state["seed"])


@dataclasses.dataclass
class TraceDataPipeline:
    """Counter-deterministic batches over a Tao WindowDataset."""

    dataset: WindowDataset
    batch: int
    seed: int = 0
    next_index: int = 0

    def make_batch(self, index: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 20) ^ index)
        idx = rng.choice(len(self.dataset), size=self.batch, replace=False)
        out = {k: v[idx] for k, v in self.dataset.inputs.items()}
        if self.dataset.labels is not None:
            out["labels"] = {k: v[idx] for k, v in self.dataset.labels.items()}
        return out

    def __iter__(self):
        while True:
            yield self.make_batch(self.next_index)
            self.next_index += 1

    def state_dict(self) -> Dict:
        return {"next_index": self.next_index, "seed": self.seed}

    def load_state_dict(self, state: Dict) -> None:
        self.next_index = int(state["next_index"])
        self.seed = int(state["seed"])
