from .pipeline import LMDataPipeline, TraceDataPipeline, make_lm_batch_specs

__all__ = ["LMDataPipeline", "TraceDataPipeline", "make_lm_batch_specs"]
