from .checkpoint import (
    CheckpointManager,
    latest_step,
    load_array_tree,
    restore_pytree,
    save_array_tree,
    save_pytree,
    write_array_tree,
)

__all__ = [
    "CheckpointManager",
    "save_pytree",
    "restore_pytree",
    "save_array_tree",
    "load_array_tree",
    "write_array_tree",
    "latest_step",
]
