"""Crash-consistent checkpointing with async writes and auto-resume.

Layout: <dir>/step_<N>/  containing one .npy per leaf (flattened tree paths)
plus a manifest; the step directory is written under a tmp name and
atomically renamed on commit, so a crash mid-write never corrupts the
latest checkpoint.  Restore picks the newest *committed* step.

This is deliberately tensorstore-free (offline container) but keeps the
properties that matter at scale: atomic commit, async write thread
(training continues while the previous step flushes), data-iterator state
included, and restore-into-resharded-mesh (arrays are saved unsharded per
host here; on a real multi-host deployment each host writes its shard files
and the loader reassembles -- the interface is the same).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

__all__ = ["save_pytree", "restore_pytree", "latest_step", "CheckpointManager"]

_MANIFEST = "manifest.json"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(tree, directory: str, extra: Optional[Dict] = None) -> None:
    """Atomic: writes to <dir>.tmp then renames."""
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    names = {}
    for i, (key, arr) in enumerate(flat.items()):
        fname = f"arr_{i}.bin"
        # raw-bytes serialization: np.save can't represent ml_dtypes (bf16)
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(np.ascontiguousarray(arr).tobytes())
        names[key] = {
            "file": fname,
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
        }
    manifest = {"arrays": names, "extra": extra or {}}
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)


def restore_pytree(template, directory: str):
    """Restore into the structure (and shardings, if any) of `template`.

    Template leaves may be arrays or ShapeDtypeStructs; restored arrays are
    device_put with the template's sharding when present — this is how a
    checkpoint taken on one mesh restores into a differently-sized mesh
    (elastic restart).
    """
    import ml_dtypes  # noqa: F401  (registers bfloat16 et al. with numpy)

    with open(os.path.join(directory, _MANIFEST)) as f:
        manifest = json.load(f)
    flat_template = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat_template[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        rec = manifest["arrays"][key]
        dtype = np.dtype(rec["dtype"]) if rec["dtype"] != "bfloat16" else np.dtype(
            ml_dtypes.bfloat16
        )
        with open(os.path.join(directory, rec["file"]), "rb") as f:
            arr = np.frombuffer(f.read(), dtype=dtype).reshape(rec["shape"]).copy()
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None and not isinstance(
            sharding, jax.sharding.SingleDeviceSharding
        ):
            leaves.append(jax.device_put(arr, sharding))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=getattr(leaf, "dtype", None)))
    return jax.tree_util.tree_unflatten(flat_template[1], leaves)


def read_extra(directory: str) -> Dict:
    with open(os.path.join(directory, _MANIFEST)) as f:
        return json.load(f)["extra"]


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = []
    for name in os.listdir(root):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(root, name, _MANIFEST)):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


class CheckpointManager:
    """Async checkpointing with bounded retention + preemption hook."""

    def __init__(self, root: str, keep: int = 3, use_async: bool = True):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._err: Optional[BaseException] = None
        self._async = use_async
        if use_async:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                tree, step, extra = item
                self._save_now(tree, step, extra)
            except BaseException as e:  # surfaced on next save()
                self._err = e
            finally:
                self._q.task_done()

    def _save_now(self, tree, step: int, extra):
        save_pytree(tree, os.path.join(self.root, f"step_{step}"), extra)
        self._gc()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.root)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s}"), ignore_errors=True)

    def save(self, tree, step: int, extra: Optional[Dict] = None, block: bool = False):
        if self._err is not None:
            err, self._err = self._err, None
            raise RuntimeError(f"async checkpoint failed: {err!r}") from err
        # Materialize device arrays on host before enqueueing (donated buffers
        # must not be touched by the training loop after this point).
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        if self._async and not block:
            self._q.put((host_tree, step, extra))
        else:
            self._save_now(host_tree, step, extra)

    def restore_latest(self, template):
        step = latest_step(self.root)
        if step is None:
            return None, None
        d = os.path.join(self.root, f"step_{step}")
        return restore_pytree(template, d), {"step": step, **read_extra(d)}

    def wait(self):
        if self._async:
            self._q.join()

    def close(self):
        if self._async:
            self.wait()
            self._q.put(None)
            self._thread.join(timeout=5)
