"""Crash-consistent checkpointing with async writes and auto-resume.

Layout: <dir>/step_<N>/  containing one .npy per leaf (flattened tree paths)
plus a manifest; the step directory is written under a tmp name and
atomically renamed on commit, so a crash mid-write never corrupts the
latest checkpoint.  Restore picks the newest *committed* step.

This is deliberately tensorstore-free (offline container) but keeps the
properties that matter at scale: atomic commit, async write thread
(training continues while the previous step flushes), data-iterator state
included, and restore-into-resharded-mesh (arrays are saved unsharded per
host here; on a real multi-host deployment each host writes its shard files
and the loader reassembles -- the interface is the same).
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Dict, Optional

import jax
import numpy as np

from ..compat import SingleDeviceSharding

__all__ = [
    "save_pytree",
    "restore_pytree",
    "save_array_tree",
    "load_array_tree",
    "write_array_tree",
    "latest_step",
    "CheckpointManager",
]

_MANIFEST = "manifest.json"


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save_pytree(tree, directory: str, extra: Optional[Dict] = None) -> None:
    """Atomic: writes to <dir>.tmp then renames."""
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    names = {}
    for i, (key, arr) in enumerate(flat.items()):
        fname = f"arr_{i}.bin"
        # raw-bytes serialization: np.save can't represent ml_dtypes (bf16)
        with open(os.path.join(tmp, fname), "wb") as f:
            f.write(np.ascontiguousarray(arr).tobytes())
        names[key] = {
            "file": fname,
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
        }
    manifest = {"arrays": names, "extra": extra or {}}
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)


def restore_pytree(template, directory: str):
    """Restore into the structure (and shardings, if any) of `template`.

    Template leaves may be arrays or ShapeDtypeStructs; restored arrays are
    device_put with the template's sharding when present — this is how a
    checkpoint taken on one mesh restores into a differently-sized mesh
    (elastic restart).
    """
    import ml_dtypes  # noqa: F401  (registers bfloat16 et al. with numpy)

    with open(os.path.join(directory, _MANIFEST)) as f:
        manifest = json.load(f)
    flat_template = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat_template[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        rec = manifest["arrays"][key]
        dtype = np.dtype(rec["dtype"]) if rec["dtype"] != "bfloat16" else np.dtype(
            ml_dtypes.bfloat16
        )
        with open(os.path.join(directory, rec["file"]), "rb") as f:
            arr = np.frombuffer(f.read(), dtype=dtype).reshape(rec["shape"]).copy()
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None and not isinstance(
            sharding, SingleDeviceSharding
        ):
            leaves.append(jax.device_put(arr, sharding))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=getattr(leaf, "dtype", None)))
    return jax.tree_util.tree_unflatten(flat_template[1], leaves)


# ---------------------------------------------------------------------------
# Template-free (typed-path) tree serialization.
#
# ``save_pytree``/``restore_pytree`` flatten paths to strings, which is fine
# when the reader holds a template of the tree (the trainer restoring into
# its own TrainState) but ambiguous without one: "pred/blocks/0" cannot say
# whether ``blocks`` is a dict with key "0" or a list.  The artifact store
# (repro.store) restores params trees in processes that never built the
# model, so these variants record each path segment *typed* — ["k", name]
# for a dict key, ["i", idx] for a sequence index — and rebuild the exact
# container structure on load.  None leaves are not representable (jax
# flattening drops them); trees holding None must encode absence as a
# missing dict key instead.
# ---------------------------------------------------------------------------


def _typed_paths(tree):
    recs = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        tp = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                tp.append(["k", p.key])
            elif isinstance(p, jax.tree_util.SequenceKey):
                tp.append(["i", p.idx])
            else:
                raise TypeError(
                    f"typed-path serialization supports dict/list/tuple "
                    f"trees only; cannot encode path entry {p!r}"
                )
        recs.append((tp, np.asarray(leaf)))
    return recs


def _dtype_record(arr: np.ndarray):
    # structured dtypes (functional traces) round-trip via descr; plain
    # dtypes via their name string
    return arr.dtype.descr if arr.dtype.names else str(arr.dtype)


def _dtype_from_record(rec):
    if isinstance(rec, list):
        return np.dtype([tuple(x) for x in rec])
    if rec == "bfloat16":
        import ml_dtypes

        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(rec)


def write_array_tree(tree, directory: str, extra: Optional[Dict] = None) -> None:
    """Write a typed-path manifest + raw array files directly into
    ``directory`` (caller owns atomicity — see ``save_array_tree`` for the
    tmp-and-rename variant)."""
    os.makedirs(directory, exist_ok=True)
    arrays = []
    for i, (tp, arr) in enumerate(_typed_paths(tree)):
        fname = f"arr_{i}.bin"
        with open(os.path.join(directory, fname), "wb") as f:
            f.write(np.ascontiguousarray(arr).tobytes())
        arrays.append(
            {
                "path": tp,
                "file": fname,
                "dtype": _dtype_record(arr),
                "shape": list(arr.shape),
                "bytes": int(arr.nbytes),
            }
        )
    manifest = {"format": "typed-paths-v1", "arrays": arrays, "extra": extra or {}}
    tmp_manifest = os.path.join(directory, _MANIFEST + ".tmp")
    with open(tmp_manifest, "w") as f:
        json.dump(manifest, f)
    # manifest lands last and atomically: a partial write is detectable as
    # "no manifest" rather than a truncated one
    os.replace(tmp_manifest, os.path.join(directory, _MANIFEST))


def save_array_tree(tree, directory: str, extra: Optional[Dict] = None) -> None:
    """Atomic template-free save: typed paths, raw bytes, tmp-then-rename."""
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    write_array_tree(tree, tmp, extra)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)


def load_array_tree(directory: str):
    """Rebuild ``(tree, extra)`` from a typed-path manifest — no template.

    Raises (FileNotFoundError / json / ValueError) on missing, truncated,
    or inconsistent entries; the artifact store treats any failure here as
    a cache miss and drops the entry.
    """
    with open(os.path.join(directory, _MANIFEST)) as f:
        manifest = json.load(f)
    if manifest.get("format") != "typed-paths-v1":
        raise ValueError(f"not a typed-path tree: {directory}")
    recs = manifest["arrays"]
    leaves = []
    for rec in recs:
        dtype = _dtype_from_record(rec["dtype"])
        with open(os.path.join(directory, rec["file"]), "rb") as f:
            buf = f.read()
        expect = int(np.prod(rec["shape"], dtype=np.int64)) * dtype.itemsize
        if len(buf) != expect:
            raise ValueError(
                f"truncated array file {rec['file']} in {directory}: "
                f"{len(buf)} bytes, expected {expect}"
            )
        arr = np.frombuffer(buf, dtype=dtype).reshape(rec["shape"]).copy()
        leaves.append((tuple(tuple(p) for p in rec["path"]), arr))

    if not leaves:  # extra-only entry (e.g. a ground-truth summary)
        return {}, manifest.get("extra", {})
    if len(leaves) == 1 and not leaves[0][0]:  # single leaf at the root
        return leaves[0][1], manifest.get("extra", {})

    root: Dict = {}
    for path, arr in leaves:
        node = root
        for depth, seg in enumerate(path):
            if depth == len(path) - 1:
                node[tuple(seg)] = arr
            else:
                node = node.setdefault(tuple(seg), {})

    def finalize(node):
        if not isinstance(node, dict):
            return node
        tags = {t for t, _ in node}
        if tags == {"i"}:
            idxs = sorted(k for _, k in node)
            if idxs != list(range(len(idxs))):
                raise ValueError(f"non-contiguous sequence indices {idxs}")
            return [finalize(node[("i", i)]) for i in idxs]
        if tags != {"k"}:
            raise ValueError(f"mixed container tags {tags} in typed-path tree")
        return {k: finalize(v) for (_, k), v in sorted(node.items())}

    return finalize(root), manifest.get("extra", {})


def read_extra(directory: str) -> Dict:
    with open(os.path.join(directory, _MANIFEST)) as f:
        return json.load(f)["extra"]


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = []
    for name in os.listdir(root):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(root, name, _MANIFEST)):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


class CheckpointManager:
    """Async checkpointing with bounded retention + preemption hook."""

    def __init__(self, root: str, keep: int = 3, use_async: bool = True):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue(maxsize=2)
        self._err: Optional[BaseException] = None
        self._async = use_async
        if use_async:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()

    def _worker(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                tree, step, extra = item
                self._save_now(tree, step, extra)
            except BaseException as e:  # surfaced on next save()
                self._err = e
            finally:
                self._q.task_done()

    def _save_now(self, tree, step: int, extra):
        save_pytree(tree, os.path.join(self.root, f"step_{step}"), extra)
        self._gc()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.root)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.root, f"step_{s}"), ignore_errors=True)

    def save(self, tree, step: int, extra: Optional[Dict] = None, block: bool = False):
        if self._err is not None:
            err, self._err = self._err, None
            raise RuntimeError(f"async checkpoint failed: {err!r}") from err
        # Materialize device arrays on host before enqueueing (donated buffers
        # must not be touched by the training loop after this point).
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        if self._async and not block:
            self._q.put((host_tree, step, extra))
        else:
            self._save_now(host_tree, step, extra)

    def restore_latest(self, template):
        step = latest_step(self.root)
        if step is None:
            return None, None
        d = os.path.join(self.root, f"step_{step}")
        return restore_pytree(template, d), {"step": step, **read_extra(d)}

    def wait(self):
        if self._async:
            self._q.join()

    def close(self):
        if self._async:
            self.wait()
            self._q.put(None)
            self._thread.join(timeout=5)
