"""Serving launcher: prefill + batched decode over the model zoo.

CPU demo:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \\
      --batch 4 --prompt-len 32 --gen 16

On real hardware the same step functions are jitted with the production
mesh shardings (see launch/dryrun.py decode cells).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_arch
from ..models.backbone import Model


def generate(model: Model, params, prompt: jnp.ndarray, gen: int, temperature: float = 0.0):
    """prompt: (B, P) -> tokens (B, P+gen).  Greedy when temperature == 0."""
    B, P = prompt.shape
    max_len = P + gen
    cfg = model.cfg

    logits, cache = jax.jit(model.prefill)(params, {"tokens": prompt})
    # re-home prefill cache into a max_len cache for attention families
    if cfg.family not in ("ssm", "hybrid") and "k" in cache:
        pad = max_len - cache["k"].shape[2]
        cache = {kk: jnp.pad(v, ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (v.ndim - 3))
                 for kk, v in cache.items()}
    elif cfg.mla and "c_kv" in cache:
        pad = max_len - cache["c_kv"].shape[2]
        cache = {kk: jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))) for kk, v in cache.items()}

    step = jax.jit(model.decode_step)
    key = jax.random.PRNGKey(0)
    toks = [prompt]
    cur = jnp.argmax(logits, -1).astype(jnp.int32)
    for t in range(gen):
        toks.append(cur[:, None])
        logits, cache = step(params, cache, cur, jnp.int32(P + t))
        if temperature > 0:
            key, sub = jax.random.split(key)
            cur = jax.random.categorical(sub, logits / temperature).astype(jnp.int32)
        else:
            cur = jnp.argmax(logits, -1).astype(jnp.int32)
    return jnp.concatenate(toks, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=args.reduced)
    model = Model(cfg)
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode path")
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab
    ).astype(jnp.int32)
    t0 = time.perf_counter()
    out = generate(model, params, prompt, args.gen, args.temperature)
    dt = time.perf_counter() - t0
    tput = args.batch * args.gen / dt
    print(f"generated {out.shape} in {dt:.2f}s -> {tput:.1f} tok/s")
    print("sample row:", np.asarray(out[0, -min(16, out.shape[1]):]))


if __name__ == "__main__":
    main()
