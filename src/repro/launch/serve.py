"""Trace-serving launcher: the simulation-as-a-service front end.

Serves named trained models from an artifact store to concurrent tenants
over a line-delimited JSON protocol (one request object per line, one
response object per line — trivially scriptable with ``nc`` or a
10-line client, see ``examples/serve_traces.py``)::

  PYTHONPATH=src python -m repro.launch.serve \\
      --store /var/tmp/repro-store --models skylake-base,big-l1d \\
      --port 7171 --batch-size 8 --warmup 1200,300

Requests (``op`` selects the verb)::

  {"op": "simulate", "model": "skylake-base", "trace": {...encode_trace},
   "tenant": "ci", "metrics": ["cpi"], "request_id": "r1"}
  {"op": "stats"}
  {"op": "models"}

Responses are ``{"ok": true, ...}`` or ``{"ok": false, "error": CODE,
"message": ..., "retry_after_s": ...}`` with the stable ``ServeError``
code vocabulary — QUEUE_FULL and CIRCUIT_OPEN carry the 429-style
backoff hint.  Responses are written as requests complete (pipelined
clients match them up by ``request_id``).

The front end is hostile-input hardened (docs/resilience.md): a line
over ``--max-line-bytes`` or a connection closed mid-line gets a
structured BAD_REQUEST and a clean close (never a stack trace, never an
unbounded buffer); a tenant that disconnects mid-reply loses only its
own responses; per-connection in-flight requests are capped so one
pipelining client cannot hold unbounded server memory.

``--demo`` needs no store: it registers two freshly initialized models,
drives mixed-tenant load in-process, and prints the ``ServerStats``
snapshot — the CI serve-smoke entrypoint.
"""
from __future__ import annotations

import argparse
import asyncio
import contextlib
import json
from typing import Optional

from ..resilience.faults import fault_point
from ..serve import (
    ModelRegistry,
    ServeError,
    ServeRequest,
    TraceServer,
    decode_trace,
)

__all__ = ["main", "serve_forever"]

# longest request line accepted (also the asyncio reader's buffer limit,
# so a tenant streaming garbage without a newline is bounded too)
DEFAULT_MAX_LINE_BYTES = 1 << 20
# in-flight requests per connection before reads backpressure
_MAX_CONN_TASKS = 64


async def _handle_line(server: TraceServer, line: bytes, writer, wlock) -> None:
    async def reply(obj: dict) -> None:
        try:
            async with wlock:
                fault_point("tcp.reply")
                writer.write(json.dumps(obj).encode() + b"\n")
                await writer.drain()
        except (ConnectionError, OSError):  # tao: fault-boundary tenant disconnected mid-reply; only its own responses are lost
            pass

    try:
        req = json.loads(line)
        op = req.get("op", "simulate")
    except (json.JSONDecodeError, AttributeError) as e:
        await reply({"ok": False, "error": "BAD_REQUEST",
                     "message": f"unparseable request: {e}"})
        return

    if op == "stats":
        await reply({"ok": True, "stats": server.stats().to_dict()})
        return
    if op == "models":
        await reply({"ok": True, "models": list(server.registry.names())})
        return
    if op != "simulate":
        await reply({"ok": False, "error": "BAD_REQUEST",
                     "message": f"unknown op {op!r}"})
        return

    rid = req.get("request_id")
    try:
        trace = decode_trace(req["trace"])
        sreq = ServeRequest(
            model=req["model"],
            trace=trace,
            tenant=req.get("tenant", "default"),
            metrics=tuple(req["metrics"]) if req.get("metrics") else None,
            request_id=rid,
            deadline_s=(
                float(req["deadline_s"]) if req.get("deadline_s") is not None
                else None
            ),
        )
    except ServeError as e:
        await reply({"ok": False, **e.to_dict()})
        return
    except (KeyError, ValueError, TypeError) as e:
        await reply({"ok": False, "error": "BAD_REQUEST", "message": str(e),
                     **({"request_id": rid} if rid else {})})
        return
    try:
        result = await server.submit(sreq)
    except ServeError as e:
        await reply({"ok": False, **e.to_dict()})
        return
    await reply({"ok": True, "result": result.to_dict()})


async def _serve_connection(server: TraceServer, reader, writer) -> None:
    wlock = asyncio.Lock()
    tasks = set()

    async def reply_err(message: str) -> None:
        obj = {"ok": False, "error": "BAD_REQUEST", "message": message}
        try:
            async with wlock:
                writer.write(json.dumps(obj).encode() + b"\n")
                await writer.drain()
        except (ConnectionError, OSError):  # tao: fault-boundary peer is already gone; nothing left to tell it
            pass

    try:
        while True:
            try:
                line = await reader.readuntil(b"\n")
            except asyncio.LimitOverrunError:
                # oversized line: the buffered prefix is garbage we refuse
                # to hold — structured error, then close
                await reply_err(
                    "request line exceeds the server's --max-line-bytes limit"
                )
                break
            except asyncio.IncompleteReadError as e:
                # EOF mid-line: a truncated request gets a structured
                # error; a bare EOF (clean disconnect) gets a clean close
                if e.partial.strip():
                    await reply_err(
                        "truncated request (connection closed mid-line)"
                    )
                break
            except (ConnectionResetError, OSError):
                break
            if not line.strip():
                continue
            while len(tasks) >= _MAX_CONN_TASKS:
                # backpressure one pipelining connection instead of
                # buffering unbounded in-flight requests for it
                done, _ = await asyncio.wait(
                    tasks, return_when=asyncio.FIRST_COMPLETED
                )
                tasks.difference_update(done)
            t = asyncio.get_running_loop().create_task(
                _handle_line(server, line, writer, wlock)
            )
            tasks.add(t)
            t.add_done_callback(tasks.discard)
        if tasks:
            await asyncio.gather(*tasks, return_exceptions=True)
    finally:
        writer.close()
        with contextlib.suppress(ConnectionError, OSError):
            await writer.wait_closed()


async def serve_forever(
    server: TraceServer, host: str, port: int,
    ready: Optional["asyncio.Future"] = None,
    max_line_bytes: int = DEFAULT_MAX_LINE_BYTES,
) -> None:
    """Run the TCP front end until cancelled (``server`` must be started).
    ``ready``, when given, resolves to the bound ``(host, port)`` — pass
    ``port=0`` for an ephemeral port and read the real one from it.
    ``max_line_bytes`` bounds both a single request line and the
    per-connection read buffer."""
    tcp = await asyncio.start_server(
        lambda r, w: _serve_connection(server, r, w), host, port,
        limit=max_line_bytes,
    )
    addr = tcp.sockets[0].getsockname()
    print(f"serving on {addr[0]}:{addr[1]} "
          f"(models: {', '.join(server.registry.names()) or '<none>'})")
    if ready is not None:
        ready.set_result((addr[0], addr[1]))
    async with tcp:
        await tcp.serve_forever()


async def _demo(args) -> None:
    """Self-contained mixed-tenant demo (no store, no trained weights)."""
    import jax

    from ..api import Session, TrainedModel
    from ..core import FeatureConfig, TaoConfig, init_tao

    cfg = TaoConfig(window=9, d_model=16, n_heads=2, n_layers=1, d_ff=32,
                    d_cat=8, features=FeatureConfig(n_buckets=64, n_queue=4,
                                                    n_mem=8))
    sess = Session(cfg)
    traces = [sess.capture("mcf", 1200), sess.capture("dee", 600),
              sess.capture("lee", 6)]
    registry = ModelRegistry()
    for i, name in enumerate(("base", "tuned")):
        registry.register(name, TrainedModel(
            params=init_tao(jax.random.PRNGKey(i), cfg), cfg=cfg, name=name))
    server = TraceServer(registry, batch_size=args.batch_size,
                         max_queue=args.max_queue)
    async with server:
        server.warmup([len(t) for t in traces])
        print(f"warm: {server.num_compiles} request-attributed compiles")

        async def tenant(name: str, count: int):
            out = []
            for i in range(count):
                tr = traces[i % len(traces)]
                fut = server.submit(ServeRequest(
                    model=("base", "tuned")[i % 2], trace=tr, tenant=name))
                out.append(await fut)
            return out

        done = await asyncio.gather(
            tenant("alice", 6), tenant("bob", 6), tenant("carol", 4),
            tenant("dave", 4))
        for res in done:
            r = res[0]
            print(f"  {r.tenant}: {len(res)} served, first {r.geometry} "
                  f"cpi={float(r.metrics['cpi']):.3f} "
                  f"({r.total_s * 1e3:.1f} ms)")
    print(json.dumps(server.stats().to_dict(), indent=1))


async def _main_async(args) -> None:
    if args.demo:
        await _demo(args)
        return
    if not args.store:
        raise SystemExit("--store is required (or use --demo)")
    registry = ModelRegistry(args.store)
    names = ([n for n in args.models.split(",") if n] if args.models
             else list(registry.names()))
    for name in names:
        registry.resolve(name)       # fail fast on unknown names
    server = TraceServer(
        registry, batch_size=args.batch_size, max_queue=args.max_queue,
        feature_backend=args.feature_backend,
    )
    async with server:
        if args.warmup:
            lengths = [int(x) for x in args.warmup.split(",") if x]
            info = server.warmup(lengths, models=names)
            print(f"warmup: {info['geometries']} geometries, "
                  f"{info['aot_compiled']} AOT-compiled")
        await serve_forever(server, args.host, args.port,
                            max_line_bytes=args.max_line_bytes)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="serve trained Tao models to concurrent tenants")
    ap.add_argument("--store", default=None,
                    help="artifact store root holding published models")
    ap.add_argument("--models", default=None,
                    help="comma-separated model names (default: all published)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=7171)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--max-queue", type=int, default=64)
    ap.add_argument("--feature-backend", default="numpy",
                    choices=("numpy", "pallas"))
    ap.add_argument("--warmup", default=None,
                    help="comma-separated trace lengths to AOT-compile for")
    ap.add_argument("--max-line-bytes", type=int,
                    default=DEFAULT_MAX_LINE_BYTES,
                    help="longest accepted request line (and the "
                         "per-connection read-buffer cap)")
    ap.add_argument("--demo", action="store_true",
                    help="self-contained in-process demo (no store needed)")
    args = ap.parse_args(argv)
    try:
        asyncio.run(_main_async(args))
    except KeyboardInterrupt:
        pass


if __name__ == "__main__":
    main()
