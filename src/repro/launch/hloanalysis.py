"""Hierarchical HLO cost analysis.

XLA:CPU's built-in ``cost_analysis()`` counts each while-loop body once, so
scanned-layer models under-report FLOPs and collective traffic by ~n_layers×.
This module re-derives both from ``compiled.as_text()`` with loop awareness:

  1. split the HLO module into named computations;
  2. count per-computation dot FLOPs (2 * prod(result) * prod(contracted))
     and collective result bytes;
  3. build the call graph (while bodies, fusions, calls, conditionals);
  4. extract while trip counts from the loop-condition's comparison constant;
  5. fold the tree from ENTRY, multiplying while bodies by their trip count.

The dot-FLOP counter is validated against cost_analysis() on loop-free
(fully unrolled) graphs in tests/test_roofline.py.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}

# NB: computation params may contain nested tuple parens — match greedily to
# the `-> ... {` tail instead of trying to parse the parameter list.
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_SHAPE = re.compile(r"(\w+)\[([0-9,]*)\]")
_DOT = re.compile(
    r"=\s*(\w+)\[([0-9,]*)\][^\s]*\s+dot\(([^)]*)\).*?"
    r"lhs_contracting_dims=\{([0-9,]*)\}", re.S
)
# XLA:CPU rewrites eligible dots to oneDNN matmul custom-calls (observed on
# single-device lowerings; SPMD-partitioned graphs keep `dot`).  Standard
# (m,k)x(k,n) layout: flops = 2*m*n*k with k = lhs last dim.
_ONEDNN = re.compile(
    r"=\s*(\w+)\[([0-9,]*)\][^\s]*\s+custom-call\(([^)]*)\).*?"
    r'custom_call_target="__onednn\$matmul"', re.S
)
_COLL = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([0-9,]*)\][^\s]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_WHILE = re.compile(r"while\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply|body|condition|branch_computations)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _dims(dimstr: str) -> List[int]:
    return [int(d) for d in dimstr.split(",") if d]


def _shape_bytes(dtype: str, dimstr: str) -> int:
    n = 1
    for d in _dims(dimstr):
        n *= d
    return n * DTYPE_BYTES.get(dtype, 4)


def _split_computations(hlo: str) -> Dict[str, str]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    entry_name = None
    for line in hlo.splitlines():
        m = _COMP_HDR.match(line.strip())
        if m and ("{" in line):
            cur = m.group(1)
            comps[cur] = []
            if line.strip().startswith("ENTRY"):
                entry_name = cur
            continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            comps[cur].append(line)
    out = {name: "\n".join(lines) for name, lines in comps.items()}
    out["__entry__"] = entry_name or ""
    return out


_DEF = re.compile(r"%([\w.\-]+)\s*=\s*(\w+)\[([0-9,]*)\]")
_OPERAND_NAME = re.compile(r"%([\w.\-]+)")


def _symbol_table(text: str) -> Dict[str, List[int]]:
    """op/parameter name -> result dims (names are unique module-wide)."""
    table: Dict[str, List[int]] = {}
    for m in _DEF.finditer(text):
        table[m.group(1)] = _dims(m.group(3))
    return table


def _operand_dims(operands: str, symbols: Dict[str, List[int]]) -> List[int]:
    """First operand's dims: inline type if printed, else symbol lookup
    (HLO printers differ on whether operand types appear inline)."""
    shapes = _SHAPE.findall(operands)
    if shapes:
        return _dims(shapes[0][1])
    names = _OPERAND_NAME.findall(operands)
    if names and names[0] in symbols:
        return symbols[names[0]]
    return []


def _dot_flops(body: str, symbols: Dict[str, List[int]]) -> float:
    total = 0.0
    for m in _DOT.finditer(body):
        rdtype, rdims, operands, lcd = m.groups()
        result = _dims(rdims)
        lhs_dims = _operand_dims(operands, symbols)
        k = 1
        for idx in _dims(lcd):
            if idx < len(lhs_dims):
                k *= lhs_dims[idx]
        n = 1
        for d in result:
            n *= d
        total += 2.0 * n * k
    for m in _ONEDNN.finditer(body):
        rdtype, rdims, operands = m.groups()
        result = _dims(rdims)
        lhs_dims = _operand_dims(operands, symbols)
        k = lhs_dims[-1] if lhs_dims else 1
        n = 1
        for d in result:
            n *= d
        total += 2.0 * n * k
    return total


def _collectives(body: str) -> Tuple[Dict[str, Dict], float, float]:
    per: Dict[str, Dict] = {}
    total = 0.0
    wire = 0.0
    factor = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}
    for m in _COLL.finditer(body):
        tup, dtype, dims, kind = m.groups()
        if tup is not None:
            nbytes = sum(_shape_bytes(dt, dm) for dt, dm in _SHAPE.findall(tup))
        else:
            nbytes = _shape_bytes(dtype, dims)
        e = per.setdefault(kind, {"count": 0, "bytes": 0.0})
        e["count"] += 1
        e["bytes"] += nbytes
        total += nbytes
        wire += nbytes * factor[kind]
    return per, total, wire


def _trip_count(cond_body: str) -> int:
    consts = [int(c) for c in _CONST.findall(cond_body)]
    return max(consts) if consts else 1


def analyze_hlo(hlo: str) -> Dict:
    comps = _split_computations(hlo)
    entry = comps.pop("__entry__")

    symbols = _symbol_table(hlo)
    local_flops = {n: _dot_flops(b, symbols) for n, b in comps.items()}
    local_coll = {n: _collectives(b) for n, b in comps.items()}

    # call edges with multipliers
    edges: Dict[str, List[Tuple[str, float]]] = {n: [] for n in comps}
    for name, body in comps.items():
        seen = set()
        for m in _WHILE.finditer(body):
            cond, wbody = m.groups()
            trips = _trip_count(comps.get(cond, ""))
            edges[name].append((wbody, float(trips)))
            seen.add(wbody)
            seen.add(cond)
        for m in _BRANCHES.finditer(body):
            for b in m.group(1).split(","):
                b = b.strip().lstrip("%")
                if b in comps:
                    edges[name].append((b, 1.0))
                    seen.add(b)
        for m in _CALLS.finditer(body):
            callee = m.group(1)
            if callee in comps and callee not in seen:
                edges[name].append((callee, 1.0))
                seen.add(callee)

    memo: Dict[str, Tuple[float, Dict, float, float]] = {}
    active: set = set()

    def fold(name: str):
        if name in memo:
            return memo[name]
        if name in active:  # cycle guard (shouldn't happen in HLO)
            return 0.0, {}, 0.0, 0.0
        active.add(name)
        flops = local_flops.get(name, 0.0)
        per, cbytes, wire = local_coll.get(name, ({}, 0.0, 0.0))
        per = {k: dict(v) for k, v in per.items()}
        for callee, mult in edges.get(name, ()):
            cf, cper, cb, cw = fold(callee)
            flops += mult * cf
            cbytes += mult * cb
            wire += mult * cw
            for k, v in cper.items():
                e = per.setdefault(k, {"count": 0, "bytes": 0.0})
                e["count"] += mult * v["count"]
                e["bytes"] += mult * v["bytes"]
        active.discard(name)
        memo[name] = (flops, per, cbytes, wire)
        return memo[name]

    flops, per, cbytes, wire = fold(entry)
    return {
        "dot_flops": flops,
        "collectives": per,
        "collective_bytes": cbytes,
        "collective_wire_bytes": wire,
    }
