"""Multi-pod dry-run: AOT lower + compile every (architecture × input shape ×
mesh) cell and extract the roofline terms.

For each cell this lowers the appropriate step function with
ShapeDtypeStruct stand-ins (no allocation):

  train_4k     -> train_step   (fwd+bwd+AdamW, microbatched)
  prefill_32k  -> prefill      (full-prompt forward, returns cache)
                  (hubert: encode — encoder-only has no cache)
  decode_32k   -> decode_step  (one token over a 32k cache)
  long_500k    -> decode_step  (SSM / hybrid state decode at 524288 context)

and records memory_analysis(), cost_analysis(), and the collective-op
inventory parsed from the compiled HLO into a JSON results file
(resumable: completed cells are skipped).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single --out results.json
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
"""
# The placeholder-device flag must be set before ANY other import triggers
# jax initialization (jax locks the device count on first init).
import os  # noqa: E402  isort: skip

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import time
import traceback
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..compat import NamedSharding, PartitionSpec
from ..configs import ARCH_IDS, get_arch
from ..data.pipeline import make_lm_batch_specs
from ..distributed.sharding import logical_to_spec, mesh_context, tree_shardings
from ..models.backbone import Model
from ..train.trainer import TrainConfig, batch_axes, init_state, make_train_step, state_axes
from .hloanalysis import analyze_hlo
from .mesh import make_production_mesh
from .roofline import analytic_flops, analytic_hbm_bytes

# ---------------------------------------------------------------------------
# cell definitions
# ---------------------------------------------------------------------------

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

# v5e constants for the roofline (per chip)
PEAK_FLOPS = 197e12      # bf16
HBM_BW = 819e9           # bytes/s
ICI_BW = 50e9            # bytes/s/link


def cell_supported(arch: str, shape: str) -> Tuple[bool, str]:
    cfg = get_arch(arch)
    if cfg.encoder_only and shape in ("decode_32k", "long_500k"):
        return False, "encoder-only: no decode step"
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: long_500k skipped (DESIGN.md)"
    return True, ""


def runnable_cells():
    for arch in ARCH_IDS:
        for shape in SHAPES:
            ok, why = cell_supported(arch, shape)
            if ok:
                yield arch, shape


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------


# kept under its historical name; the implementation is the shared
# resolver in distributed.sharding (also behind trainer.state_shardings)
_shardings_for = tree_shardings


def lower_cell(arch: str, shape: str, mesh, *, microbatches: int = 0,
               extra_cfg: Optional[Dict] = None):
    """Returns (lowered, meta) for one cell."""
    import dataclasses

    spec = SHAPES[shape]
    cfg = get_arch(arch)
    if extra_cfg:
        cfg = dataclasses.replace(cfg, **extra_cfg)
    kind = spec["kind"]
    B, S = spec["batch"], spec["seq"]
    # (chunked prefill was evaluated for the MoE cells and REFUTED: the
    # cache re-layout copy costs more than the dispatch temps it saves —
    # see EXPERIMENTS.md §Perf.  cfg.prefill_chunks stays available for
    # bandwidth-constrained serving hosts.)
    model = Model(cfg)

    if kind == "train" and microbatches == 0:
        # auto: keep the saved per-layer residuals (B_local/µb × S × d × 2B
        # × n_layers under full remat) near ~2 GiB/device
        data_ways = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        b_loc = max(1, B // data_ways)
        resid = cfg.n_layers * b_loc * S * cfg.d_model * 2
        microbatches = 1
        while resid / microbatches > 2 * 1024**3 and microbatches < b_loc:
            microbatches *= 2
    elif microbatches == 0:
        microbatches = 1

    with mesh_context(mesh):
        if kind == "train":
            tcfg = TrainConfig(microbatches=microbatches)
            step = make_train_step(model, tcfg)
            state_sds = jax.eval_shape(
                lambda k: init_state(model, k, tcfg), jax.random.PRNGKey(0)
            )
            s_axes = state_axes(model)
            state_sh = _shardings_for(s_axes, state_sds, mesh)
            batch_sds = make_lm_batch_specs(cfg, B, S)
            b_axes = batch_axes(model)
            batch_sh = _shardings_for(
                {k: tuple(v) for k, v in b_axes.items()}, batch_sds, mesh
            )
            fn = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            )
            lowered = fn.lower(state_sds, batch_sds)
            n_params = sum(
                int(np.prod(x.shape)) for x in jax.tree.leaves(state_sds.params)
            )
        elif kind == "prefill":
            params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            p_axes = model.param_axes()
            params_sh = _shardings_for(p_axes, params_sds, mesh)
            batch_sds = make_lm_batch_specs(cfg, B, S)
            batch_sds.pop("labels")
            b_axes = {k: tuple(v) for k, v in batch_axes(model).items() if k != "labels"}
            batch_sh = _shardings_for(b_axes, batch_sds, mesh)
            fwd = model.encode if cfg.encoder_only else model.prefill
            fn = jax.jit(fwd, in_shardings=(params_sh, batch_sh))
            lowered = fn.lower(params_sds, batch_sds)
            n_params = sum(
                int(np.prod(x.shape)) for x in jax.tree.leaves(params_sds)
            )
        else:  # decode
            params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            p_axes = model.param_axes()
            params_sh = _shardings_for(p_axes, params_sds, mesh)
            cache_sds = jax.eval_shape(lambda: model.init_cache(B, S))
            c_axes = model.cache_axes()
            cache_sh = _shardings_for(c_axes, cache_sds, mesh)
            tok_sds = jax.ShapeDtypeStruct((B,), jnp.int32)
            pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
            rep = NamedSharding(mesh, PartitionSpec())
            tok_sh = NamedSharding(
                mesh,
                logical_to_spec(("batch",), shape=(B,), mesh=mesh),
            )
            fn = jax.jit(
                model.decode_step,
                in_shardings=(params_sh, cache_sh, tok_sh, rep),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,),
            )
            lowered = fn.lower(params_sds, cache_sds, tok_sds, pos_sds)
            n_params = sum(
                int(np.prod(x.shape)) for x in jax.tree.leaves(params_sds)
            )

    meta = {"arch": arch, "shape": shape, "kind": kind, "batch": B, "seq": S,
            "n_params": n_params, "microbatches": microbatches}
    if kind == "decode":
        meta["cache_bytes"] = int(
            sum(
                int(np.prod(x.shape)) * x.dtype.itemsize
                for x in jax.tree.leaves(cache_sds)
            )
        )
    return lowered, meta, cfg


# ---------------------------------------------------------------------------
# analysis
# ---------------------------------------------------------------------------

def model_flops(cfg, meta) -> float:
    """6·N·D (train) / 2·N·D (inference) with N_active for MoE.

    N excludes the input embedding table when it is untied (a gather, not a
    matmul); tied tables participate in the logits matmul and stay counted.
    """
    n = meta["n_params"]
    if not cfg.tie_embeddings:
        n -= cfg.vocab * cfg.d_model
    if cfg.moe is not None:
        m = cfg.moe
        n_moe_layers = cfg.n_layers - m.first_dense_layers
        routed = 3 * cfg.d_model * m.d_ff_expert * m.num_experts * n_moe_layers
        active = routed * (m.top_k / m.num_experts)
        n = n - routed + active
    if meta["kind"] == "train":
        tokens = meta["batch"] * meta["seq"]
        return 6.0 * n * tokens
    if meta["kind"] == "prefill":
        tokens = meta["batch"] * meta["seq"]
        return 2.0 * n * tokens
    return 2.0 * n * meta["batch"]  # decode: one token per sequence


def analyze(lowered, compiled, meta, cfg, mesh) -> Dict:
    n_dev = mesh.devices.size
    mem = compiled.memory_analysis()
    from ..compat import cost_analysis

    ca = cost_analysis(compiled)
    hlo = compiled.as_text()
    h = analyze_hlo(hlo)  # loop-aware dot flops + collective bytes (per device)

    # FLOPs: loop-aware HLO dot count (per-device, post-SPMD).  The raw
    # cost_analysis value is recorded too — on scanned graphs it counts each
    # while body once (see hloanalysis.py docstring).
    flops_dev_hlo = float(h["dot_flops"])
    flops_global_analytic = analytic_flops(cfg, meta)
    flops_dev = max(flops_dev_hlo, flops_global_analytic / n_dev)

    cache_bytes = int(meta.get("cache_bytes", 0))
    bytes_global = analytic_hbm_bytes(cfg, meta, meta["n_params"], cache_bytes)
    bytes_dev = bytes_global / n_dev

    wire = float(h["collective_wire_bytes"])
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = wire / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())
    mf = model_flops(cfg, meta)
    useful_ratio = mf / (flops_dev * n_dev) if flops_dev else 0.0
    roofline_frac = (mf / n_dev / step_s) / PEAK_FLOPS if step_s > 0 else 0.0
    per_dev_hbm = (
        mem.argument_size_in_bytes
        + mem.output_size_in_bytes
        + mem.temp_size_in_bytes
        - mem.alias_size_in_bytes
    )
    # XLA:CPU legalizes bf16 elementwise/dynamic-update-slice ops through
    # f32 converts (verified in the HLO: convert->dus f32->convert around
    # the donated KV cache), inflating temp_size by ~2x cache for decode
    # cells.  TPU executes these natively in bf16 with in-place donation,
    # so we also record an analytic TPU-resident estimate for decode:
    # params + cache (donated/aliased) + 1 GiB working-set slack.
    pdt = 2 if cfg.param_dtype == "bfloat16" else 4
    if meta["kind"] == "decode":
        tpu_estimate = (
            meta["n_params"] * pdt + meta.get("cache_bytes", 0)
        ) / n_dev + 1 * 1024**3
    elif meta["kind"] == "train":
        # params (bf16) + Adam m (bf16) + v (f32) + f32 grads, all sharded
        # 256-way, + saved per-layer residuals (batch/µb × seq/SP × d) +
        # slack.  The gap vs memory_analysis is donation aliasing that the
        # CPU backend only partially performs (verified on a reduced case).
        msize = mesh.shape.get("model", 1)
        dsize = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        mb = max(1, meta.get("microbatches", 1))
        b_loc = max(1, meta["batch"] // dsize // mb)
        resid = cfg.n_layers * b_loc * (meta["seq"] // msize) * cfg.d_model * 2
        tpu_estimate = (
            meta["n_params"] * (pdt + 2 + 4 + 4) / n_dev + resid + 1 * 1024**3
        )
    elif meta["kind"] == "prefill" and cfg.moe is not None:
        # MoE prefill temps are dominated by (Tg*k, d) slot-staging buffers
        # that XLA:CPU legalizes to f32 (verified in the HLO dump: paired
        # convert->scatter/gather f32 around every bf16 staging tensor).
        # TPU keeps them bf16 -> halve the temp estimate.
        tpu_estimate = (
            mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes / 2
        )
    else:
        tpu_estimate = per_dev_hbm
    return {
        **meta,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": int(n_dev),
        "flops_per_device": flops_dev,
        "flops_per_device_hlo": flops_dev_hlo,
        "flops_per_device_analytic": flops_global_analytic / n_dev,
        "flops_per_device_xla_costanalysis": float(ca.get("flops", 0.0)),
        "bytes_per_device": bytes_dev,
        "bytes_per_device_xla_costanalysis": float(ca.get("bytes accessed", 0.0)),
        "collectives": h["collectives"],
        "collective_bytes_per_device": float(h["collective_bytes"]),
        "collective_wire_bytes": wire,
        "memory": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "alias_bytes": int(mem.alias_size_in_bytes),
            "per_device_total": int(per_dev_hbm),
            "tpu_estimate": int(tpu_estimate),
            "fits_16gb": bool(min(per_dev_hbm, tpu_estimate) <= 16 * 1024**3),
        },
        "roofline": {
            **terms,
            "dominant": dominant,
            "step_time_s": step_s,
            "model_flops": mf,
            "useful_flops_ratio": useful_ratio,
            "roofline_fraction": roofline_frac,
        },
    }


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape: str, multi_pod: bool, microbatches: int = 0,
             extra_cfg: Optional[Dict] = None) -> Dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered, meta, cfg = lower_cell(
        arch, shape, mesh, microbatches=microbatches, extra_cfg=extra_cfg
    )
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    rec = analyze(lowered, compiled, meta, cfg, mesh)
    rec["lower_s"] = t1 - t0
    rec["compile_s"] = t2 - t1
    rec["multi_pod"] = multi_pod
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    results = {}
    if os.path.exists(args.out):
        # always load: --force only re-runs the SELECTED cells (it must
        # never clobber the rest of the results file)
        with open(args.out) as f:
            results = json.load(f)

    cells = list(runnable_cells())
    if args.arch:
        cells = [(a, s) for a, s in cells if a == args.arch]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    for arch, shape in cells:
        for mp in meshes:
            key = f"{arch}|{shape}|{'multi' if mp else 'single'}"
            if key in results and not args.force:
                print(f"[skip] {key}")
                continue
            print(f"[run ] {key} ...", flush=True)
            try:
                rec = run_cell(arch, shape, mp, microbatches=args.microbatches)
                results[key] = rec
                r = rec["roofline"]
                print(
                    f"       ok: dominant={r['dominant']} step={r['step_time_s']:.4f}s "
                    f"roofline={r['roofline_fraction']*100:.1f}% "
                    f"mem/dev={rec['memory']['per_device_total']/2**30:.2f}GiB "
                    f"(lower {rec['lower_s']:.0f}s compile {rec['compile_s']:.0f}s)",
                    flush=True,
                )
            except Exception as e:
                results[key] = {"error": f"{type(e).__name__}: {e}"}
                print(f"       FAILED: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)

    n_ok = sum(1 for v in results.values() if "error" not in v)
    n_bad = sum(1 for v in results.values() if "error" in v)
    print(f"\ndone: {n_ok} ok, {n_bad} failed -> {args.out}")


if __name__ == "__main__":
    main()
