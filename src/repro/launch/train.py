"""Training launcher: real steps on the local device(s), with checkpointing,
auto-resume, preemption handling, and optional production-mesh dry-run.

Examples (CPU container):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \\
      --steps 20 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
  PYTHONPATH=src python -m repro.launch.train --arch tao --steps 50  # Tao model

On a real cluster the same script runs under `jax.distributed.initialize()`
with the production mesh (--mesh data,model=16,16); the per-host data
pipeline feeds its slice of the global batch.
"""
from __future__ import annotations

import argparse
import signal
import time

import jax
import jax.numpy as jnp

from ..ckpt import CheckpointManager
from ..configs import get_arch
from ..data.pipeline import LMDataPipeline
from ..distributed.sharding import mesh_context
from ..models.backbone import Model
from ..train.trainer import TrainConfig, init_state, make_train_step
from .mesh import make_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--mesh", default=None, help="e.g. data,model=2,2")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch, reduced=args.reduced)
    model = Model(cfg)
    tcfg = TrainConfig(lr=args.lr, total_steps=args.steps, warmup_steps=max(1, args.steps // 10),
                       microbatches=args.microbatches)
    step_fn = make_train_step(model, tcfg)

    mesh = None
    if args.mesh:
        names, sizes = args.mesh.split("=")
        mesh = make_mesh([int(x) for x in sizes.split(",")], names.split(","))

    pipeline = LMDataPipeline(cfg, args.batch, args.seq, seed=args.seed)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    state = init_state(model, jax.random.PRNGKey(args.seed), tcfg)
    start_step = 0
    if mgr is not None:
        restored, extra = mgr.restore_latest(state)
        if restored is not None:
            state = restored
            start_step = extra["step"]
            pipeline.load_state_dict(extra.get("data", {"next_index": start_step, "seed": args.seed}))
            print(f"[resume] from step {start_step}")

    # preemption hook: checkpoint immediately on SIGTERM, then exit cleanly
    preempted = {"flag": False}

    def _on_sigterm(signum, frame):
        preempted["flag"] = True

    signal.signal(signal.SIGTERM, _on_sigterm)

    jit_step = jax.jit(step_fn, donate_argnums=(0,))

    ctx = mesh_context(mesh) if mesh is not None else None
    if ctx is not None:
        ctx.__enter__()
    try:
        t0 = time.perf_counter()
        for i in range(start_step, args.steps):
            batch = jax.tree.map(jnp.asarray, pipeline.make_batch(i))
            pipeline.next_index = i + 1
            state, metrics = jit_step(state, batch)
            if i % 5 == 0 or i == args.steps - 1:
                print(
                    f"step {i:5d} loss {float(metrics['loss']):.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f} "
                    f"lr {float(metrics['lr']):.2e}"
                )
            if mgr is not None and (
                (i + 1) % args.ckpt_every == 0 or preempted["flag"]
            ):
                mgr.save(state, i + 1, extra={"data": pipeline.state_dict()},
                         block=preempted["flag"])
            if preempted["flag"]:
                print(f"[preempt] checkpointed at step {i+1}, exiting")
                break
        dt = time.perf_counter() - t0
        done = args.steps - start_step
        print(f"trained {done} steps in {dt:.1f}s ({done/max(dt,1e-9):.2f} steps/s)")
    finally:
        if ctx is not None:
            ctx.__exit__(None, None, None)
        if mgr is not None:
            mgr.close()


if __name__ == "__main__":
    main()
