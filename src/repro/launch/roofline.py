"""Analytic FLOP / HBM-traffic counters for the roofline analysis.

WHY ANALYTIC: XLA:CPU's ``compiled.cost_analysis()`` counts each while-loop
body ONCE, so any scan-over-layers HLO under-reports FLOPs by ~n_layers×
(verified in EXPERIMENTS.md §Dry-run: a 24-layer scanned model reports ~1
layer's FLOPs).  The dry-run therefore records BOTH numbers: the raw
cost_analysis values, and these analytic counts.  The analytic counter is
validated against cost_analysis on unrolled reduced configs (test
``tests/test_roofline.py``), where the two agree within a few percent.

Conventions:
  * matmul (m,k)x(k,n): 2*m*k*n flops.
  * training flops = fwd * (2 bwd + 1 fwd) = 3x; with full remat 4x.
  * causal attention context factor 1/2; local window uses min(window, S).
  * HBM traffic: parameter bytes x passes + optimizer state traffic +
    per-layer activation read/write estimate + cache traffic for decode.
"""
from __future__ import annotations

from typing import Dict

from ..models.config import ArchConfig

__all__ = ["analytic_flops", "analytic_hbm_bytes", "count_params"]


def _attn_flops_per_token(cfg: ArchConfig, ctx: int, window=None) -> float:
    """Projections + score/context matmuls for one token with `ctx` visible
    keys (already averaged for causality by the caller)."""
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    if cfg.mla:
        m = cfg.mla
        qd = m.qk_nope_head_dim + m.qk_rope_head_dim
        proj = 2 * d * H * qd              # q
        proj += 2 * d * (m.kv_lora_rank + m.qk_rope_head_dim)  # compress
        proj += 2 * m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
        proj += 2 * H * m.v_head_dim * d   # output
        scores = 2 * H * qd * ctx + 2 * H * m.v_head_dim * ctx
        return proj + scores
    proj = 2 * d * H * hd + 2 * 2 * d * Hkv * hd + 2 * H * hd * d
    scores = 2 * H * hd * ctx * 2  # qk + pv
    return proj + scores


def _mlp_flops_per_token(cfg: ArchConfig, d_ff: int) -> float:
    mats = 3 if cfg.mlp_act == "swiglu" else 2
    return mats * 2 * cfg.d_model * d_ff


def _moe_flops_per_token(cfg: ArchConfig) -> float:
    m = cfg.moe
    f = 2 * cfg.d_model * m.num_experts            # router
    f += m.top_k * 3 * 2 * cfg.d_model * m.d_ff_expert
    if m.num_shared:
        f += 3 * 2 * cfg.d_model * (m.d_ff_shared or m.d_ff_expert * m.num_shared)
    return f


def _ssd_flops_per_token(cfg: ArchConfig) -> float:
    s = cfg.ssm
    d = cfg.d_model
    din = s.d_inner(d)
    H = s.n_heads(d)
    G, N, P, c = s.n_groups, s.d_state, s.head_dim, s.chunk
    proj = 2 * d * (2 * din + 2 * G * N + H) + 2 * din * d
    conv = 2 * s.conv_kernel * (din + 2 * G * N)
    # intra-chunk: scores (c x N x c)/c per token = 2*c*N (G groups -> heads
    # share), y_diag 2*c*H*P; inter-chunk: states 2*N*P*H/c per token *c ≈
    # 2*N*P*H (build) + 2*N*P*H (apply)
    ssd = 2 * c * G * N + 2 * c * H * P + 4 * N * P * H
    return proj + conv + ssd


def _rglru_flops_per_token(cfg: ArchConfig) -> float:
    d = cfg.d_model
    w = cfg.hybrid.lru_width or d
    return 2 * d * w * 2 + 2 * w * w * 2 + 2 * w * d + 10 * w


def fwd_flops_per_token(cfg: ArchConfig, seq: int, kind: str) -> float:
    """Average forward flops per token at sequence length `seq`."""
    d, V = cfg.d_model, cfg.vocab
    if kind == "decode":
        ctx_full = seq            # decode sees the whole cache
    else:
        ctx_full = seq / 2        # causal average

    total = 0.0
    if cfg.family == "ssm":
        total += cfg.n_layers * _ssd_flops_per_token(cfg)
    elif cfg.family == "hybrid":
        hy = cfg.hybrid
        unit = hy.rec_per_unit + hy.attn_per_unit
        n_units = cfg.n_layers // unit
        n_rec = n_units * hy.rec_per_unit + (cfg.n_layers - n_units * unit)
        n_attn = n_units * hy.attn_per_unit
        ctx = min(hy.window, ctx_full)
        total += n_rec * (_rglru_flops_per_token(cfg) + _mlp_flops_per_token(cfg, cfg.d_ff))
        total += n_attn * (
            _attn_flops_per_token(cfg, ctx) + _mlp_flops_per_token(cfg, cfg.d_ff)
        )
    else:
        n_moe = 0
        n_dense = cfg.n_layers
        if cfg.moe is not None:
            n_moe = cfg.n_layers - cfg.moe.first_dense_layers
            n_dense = cfg.moe.first_dense_layers
        attn = _attn_flops_per_token(cfg, ctx_full)
        total += cfg.n_layers * attn
        total += n_dense * _mlp_flops_per_token(cfg, cfg.d_ff)
        if n_moe:
            total += n_moe * _moe_flops_per_token(cfg)
    total += 2 * d * V  # logits head (embedding gather ~ free)
    return total


def analytic_flops(cfg: ArchConfig, meta: Dict) -> float:
    """Global FLOPs for one step of the cell."""
    B, S, kind = meta["batch"], meta["seq"], meta["kind"]
    if kind == "decode":
        per_tok = fwd_flops_per_token(cfg, S, kind)
        return B * per_tok
    per_tok = fwd_flops_per_token(cfg, S, kind)
    tokens = B * S
    if kind == "train":
        mult = 4.0 if cfg.remat == "full" else 3.0
        return mult * tokens * per_tok
    return tokens * per_tok  # prefill


def count_params(cfg: ArchConfig) -> int:
    """Used for 6·N·D; computed from shapes at dry-run time instead — this
    helper exists for quick estimates in docs/tests."""
    raise NotImplementedError("dry-run counts params from eval_shape")


def analytic_hbm_bytes(cfg: ArchConfig, meta: Dict, n_params: int,
                       cache_bytes: int = 0) -> float:
    """Global HBM traffic estimate for one step."""
    B, S, kind = meta["batch"], meta["seq"], meta["kind"]
    pdt = 2 if cfg.param_dtype == "bfloat16" else 4
    adt = 2 if cfg.compute_dtype == "bfloat16" else 4
    tokens = B * (1 if kind == "decode" else S)
    # per-token per-layer activation traffic: ~8 residual-sized tensors rw
    act = tokens * cfg.n_layers * cfg.d_model * adt * 8
    if kind == "train":
        # params: fwd read + bwd read + remat read; grads write+read; adam
        # m/v read+write (fp32); param write
        p_traffic = n_params * (3 * pdt + 2 * 4 + 4 * 4 + pdt)
        return p_traffic + 3 * act
    if kind == "prefill":
        return n_params * pdt + act + cache_bytes
    # decode: all params + whole cache read once, small writes
    return n_params * pdt + cache_bytes + act
