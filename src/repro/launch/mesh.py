"""Production mesh construction.

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis carries
only data parallelism so the slower inter-pod fabric sees one gradient
all-reduce per step.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

from ..compat import make_mesh as _make

__all__ = ["make_production_mesh", "make_mesh", "SINGLE_POD", "MULTI_POD"]

SINGLE_POD = ((16, 16), ("data", "model"))
MULTI_POD = ((2, 16, 16), ("pod", "data", "model"))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small ones, e.g. (2,2))."""
    return _make(shape, axes)
