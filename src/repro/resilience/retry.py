"""Retry policy + failure classification for the serving layer.

The server distinguishes three failure classes when a dispatched request
raises:

  * **fatal** — already a ``ServeError`` (known tenant-visible surface:
    geometry mismatch, metric errors).  Fail the request as-is.
  * **transient** — flaky infrastructure: injected ``FaultError`` with
    ``transient=True``, OS/connection/timeout errors.  Worth a bounded
    exponential-backoff retry while the deadline allows.
  * **poison** — everything else at singleton granularity: the request
    deterministically breaks the step.  Quarantine its trace digest and
    reject with ``TRACE_REJECTED``.

``RetryPolicy`` is the bounded-backoff schedule; classification lives
here so the server, sweeper, and tests agree on it.
"""
from __future__ import annotations

import dataclasses

from .faults import FaultError

__all__ = ["RetryPolicy", "is_transient"]


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff.

    ``max_attempts`` counts total tries (1 = no retry).  The delay before
    retry ``k`` (k = 1 for the first retry) is
    ``min(base_delay_s * multiplier**(k-1), max_delay_s)``.
    """

    max_attempts: int = 3
    base_delay_s: float = 0.02
    multiplier: float = 2.0
    max_delay_s: float = 1.0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, got {self.max_attempts}")

    def delay(self, attempt: int) -> float:
        """Backoff before retry number ``attempt`` (1-based)."""
        return min(
            self.base_delay_s * self.multiplier ** max(attempt - 1, 0),
            self.max_delay_s,
        )


def is_transient(exc: BaseException) -> bool:
    """Whether a dispatch failure is worth retrying (vs poison)."""
    if isinstance(exc, FaultError):
        return exc.transient
    # OSError covers ConnectionError; TimeoutError is separate on 3.10
    return isinstance(exc, (OSError, TimeoutError))
