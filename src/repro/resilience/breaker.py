"""Per-key circuit breaker: shed doomed load instead of queueing it.

Classic three-state breaker, deliberately small:

  * **closed** — requests flow; ``failure_threshold`` *consecutive*
    hard failures trip it open.
  * **open** — ``allow()`` is False for ``cooldown_s``; callers shed with
    ``CIRCUIT_OPEN`` + ``retry_after_s`` instead of admitting work that
    will fail anyway.
  * **half-open** — after the cooldown one probe request is let through;
    its success closes the breaker, its failure re-opens it for another
    cooldown.

The clock is injectable so tests step time instead of sleeping.  The
server keys breakers by ``model/geometry`` — the unit that shares an
executable, and therefore a failure domain.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict

__all__ = ["CircuitBreaker"]


class CircuitBreaker:
    __slots__ = ("failure_threshold", "cooldown_s", "_clock", "state",
                 "failures", "trips", "_open_until", "_probing")

    def __init__(
        self,
        *,
        failure_threshold: int = 5,
        cooldown_s: float = 1.0,
        clock: Callable[[], float] = time.monotonic,
    ):
        if failure_threshold < 1:
            raise ValueError(
                f"failure_threshold must be >= 1, got {failure_threshold}"
            )
        self.failure_threshold = failure_threshold
        self.cooldown_s = cooldown_s
        self._clock = clock
        self.state = "closed"
        self.failures = 0           # consecutive hard failures
        self.trips = 0              # times the breaker opened
        self._open_until = 0.0
        self._probing = False

    def allow(self) -> bool:
        """Whether a new request may proceed (claims the half-open probe
        slot when the cooldown has elapsed)."""
        if self.state == "closed":
            return True
        if self.state == "open":
            if self._clock() < self._open_until:
                return False
            self.state = "half-open"
            self._probing = False
        # half-open: exactly one probe in flight at a time
        if self._probing:
            return False
        self._probing = True
        return True

    def record_success(self) -> None:
        self.state = "closed"
        self.failures = 0
        self._probing = False

    def record_failure(self) -> None:
        self.failures += 1
        if self.state == "half-open" or self.failures >= self.failure_threshold:
            self.state = "open"
            self.trips += 1
            self._open_until = self._clock() + self.cooldown_s
            self._probing = False

    @property
    def retry_after_s(self) -> float:
        """Backoff hint while open (0 once the cooldown elapsed)."""
        return max(0.0, self._open_until - self._clock())

    def snapshot(self) -> Dict[str, Any]:
        """JSON-clean state for ``ServerStats.breakers``."""
        return {
            "state": self.state,
            "failures": self.failures,
            "trips": self.trips,
            "retry_after_s": round(self.retry_after_s, 6),
        }
