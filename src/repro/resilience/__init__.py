"""Fault injection + resilience primitives (see docs/resilience.md).

Four pieces, each deliberately tiny and jax-free:

  * :mod:`.faults` — ``FaultPlan``/``inject()``/``fault_point()``: a
    deterministic, seedable chaos harness armed over named sites threaded
    through the store, engine, scheduler, serve, and launch layers.
  * :mod:`.retry` — ``RetryPolicy`` (bounded exponential backoff) and the
    transient-vs-poison failure classifier the server's dispatch uses.
  * :mod:`.breaker` — a per-``model/geometry`` ``CircuitBreaker`` that
    sheds load with ``retry_after_s`` instead of queueing doomed work.
  * :mod:`.manifest` — crash-resume progress manifests for sweeps and
    training, published through the artifact store.  (Imported lazily —
    ``from repro.resilience import manifest`` — because it pulls in the
    store package, which itself hooks ``fault_point``.)
"""
from __future__ import annotations

from .breaker import CircuitBreaker
from .faults import SITES, FaultError, FaultPlan, FaultSpec, fault_point, inject
from .retry import RetryPolicy, is_transient

__all__ = [
    "SITES",
    "CircuitBreaker",
    "FaultError",
    "FaultPlan",
    "FaultSpec",
    "RetryPolicy",
    "fault_point",
    "inject",
    "is_transient",
]
