"""Crash-resume progress manifests, published through the artifact store.

Long-running work (DSE sweeps, training runs) checkpoints *progress* —
not just final results — as ordinary content-addressed store entries, so
a SIGKILLed process resumes from the last completed trace/epoch with
zero redundant compiles or extractions:

  * ``TraceSweeper.run(jobs, resume_key=...)`` publishes one
    ``sweep_progress`` entry per completed job; a resumed run loads the
    done set up front and only feeds the remainder to the producer.
  * ``train_tao_impl(..., store=..., resume_key=...)`` publishes one
    ``train_epoch`` entry per epoch — params, optimizer state, loss
    history, and the NumPy bit-generator state, so the resumed epoch
    stream (shuffles included) is bit-identical to an uninterrupted run.

Keys compose the caller's ``resume_key`` (the recipe identity — e.g. the
session's content key for the run) with the per-unit identity, through
the same ``store.content`` scheme as every other artifact.  Entries are
immutable and atomic like all store objects: a kill mid-publish leaves a
torn tmp dir for ``gc``, never a half-entry.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import numpy as np

from ..store.content import content_key

__all__ = [
    "load_sweep_result",
    "load_train_epoch",
    "publish_sweep_result",
    "publish_train_epoch",
    "sweep_progress_key",
    "train_epoch_key",
]


# ---------------------------------------------------------------------------
# Sweep progress: one entry per completed (model, trace) job
# ---------------------------------------------------------------------------


def sweep_progress_key(
    resume_key: str, job_key: str, trace_digest: str, params_digest: str,
    geometry_token: str,
) -> str:
    return content_key(
        "sweep_progress", resume_key, job_key, trace_digest, params_digest,
        geometry_token,
    )


def publish_sweep_result(store, key: str, result) -> None:
    """Persist a ``SimulationResult``'s metrics (scalars + phase curves).
    Collected per-instruction arrays are NOT checkpointed — they are
    O(trace) large and recomputable; resumed results raise the usual
    ``MetricNotCollectedError`` on array access."""
    tree = {name: np.asarray(v) for name, v in result.metrics.items()}
    store.put(
        "sweep_progress", key, tree,
        {"num_instructions": int(result.num_instructions)},
    )


def load_sweep_result(store, key: str):
    """The checkpointed ``SimulationResult`` for ``key``, or None.
    ``seconds``/``mips`` are 0.0 — the resumed run did not simulate it."""
    hit = store.get("sweep_progress", key)
    if hit is None:
        return None
    from ..engine.runner import SimulationResult  # lazy: manifest stays jax-free

    tree, extra = hit
    metrics = {
        name: (arr if arr.ndim else arr[()]) for name, arr in tree.items()
    }
    return SimulationResult(
        num_instructions=int(extra.get("num_instructions", 0)),
        seconds=0.0,
        mips=0.0,
        metrics=metrics,
    )


# ---------------------------------------------------------------------------
# Training progress: one entry per completed epoch
# ---------------------------------------------------------------------------


def train_epoch_key(resume_key: str, epoch: int) -> str:
    return content_key("train_epoch", resume_key, str(epoch))


def publish_train_epoch(
    store,
    resume_key: str,
    epoch: int,
    params: Any,
    opt: Any,
    losses: List[float],
    eval_losses: List[float],
    steps: int,
    rng_state: Dict,
) -> None:
    """Checkpoint the state needed to continue bit-identically after
    ``epoch``: host params/opt trees, the loss history so far, and the
    dataset-shuffle rng's bit-generator state (JSON-clean by
    construction — plain ints)."""
    store.put(
        "train_epoch", train_epoch_key(resume_key, epoch),
        {"params": params, "opt": opt},
        {
            "epoch": int(epoch),
            "losses": [float(x) for x in losses],
            "eval_losses": [float(x) for x in eval_losses],
            "steps": int(steps),
            "rng_state": rng_state,
        },
    )


def load_train_epoch(
    store, resume_key: str, max_epochs: int
) -> Optional[Dict[str, Any]]:
    """The latest checkpointed epoch for ``resume_key`` strictly below
    ``max_epochs``, as a dict (params/opt/epoch/losses/eval_losses/
    steps/rng_state), or None when nothing is resumable."""
    for ep in range(max_epochs - 1, -1, -1):
        hit = store.get("train_epoch", train_epoch_key(resume_key, ep))
        if hit is None:
            continue
        tree, extra = hit
        return {
            "params": tree["params"],
            "opt": tree["opt"],
            "epoch": int(extra["epoch"]),
            "losses": [float(x) for x in extra.get("losses", [])],
            "eval_losses": [float(x) for x in extra.get("eval_losses", [])],
            "steps": int(extra.get("steps", 0)),
            "rng_state": extra.get("rng_state"),
        }
    return None
