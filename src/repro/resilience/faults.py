"""Deterministic, seedable fault injection for chaos testing.

The harness is two tiny pieces:

  * ``fault_point("site", payload=...)`` — a named hook threaded through
    the production code paths (store loads, step compiles, serve
    dispatch, TCP replies, ...).  With no plan active it is one global
    read and a ``None`` check — cheap enough for hot paths.

  * ``FaultPlan`` + ``inject(plan)`` — a context manager that arms a list
    of ``FaultSpec``s.  Each spec names a site and describes what happens
    there (raise an exception, sleep past a deadline), *when* it happens
    (after N clean hits, at most M times, only for payloads containing a
    substring, or with seeded probability ``p``), so every chaos test is
    reproducible from its plan alone.

Faults raised here carry a ``transient`` flag the serving layer's retry
classifier reads: transient faults model flaky infrastructure (worth a
backoff retry), non-transient ones model poison inputs (quarantine, do
not retry).  Sites are plain strings; the canonical set lives in
``SITES`` purely as documentation — ``fault_point`` accepts any name.

Thread-safe: sites fire from the serve dispatch/extract pools and the
sweep producer thread, so plan state is mutated under a lock (the sleep
of a ``delay`` fault happens outside it).
"""
from __future__ import annotations

import contextlib
import json
import os
import random
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

__all__ = [
    "SITES",
    "FaultError",
    "FaultPlan",
    "FaultSpec",
    "fault_point",
    "inject",
]


# the sites the repo threads through its layers (documentation, not an
# enforced registry — tests grep this when naming new hooks)
SITES: Tuple[str, ...] = (
    "store.load",          # ArtifactStore.get deserialization
    "engine.compile",      # StreamingEngine step-cache miss (jit/AOT build)
    "engine.simulate",     # StreamingEngine.simulate entry
    "scheduler.prepare",   # TraceSweeper producer-thread feature prep
    "scheduler.consume",   # TraceSweeper per-job device consume
    "serve.extract",       # TraceServer feature pre-pass (extract pool)
    "serve.dispatch",      # TraceServer per-request device dispatch
    "tcp.reply",           # launch.serve response write
)


class FaultError(RuntimeError):
    """An injected failure.  ``transient=True`` models flaky
    infrastructure (retry-worthy), ``False`` a deterministic poison."""

    def __init__(self, site: str, message: str = "injected fault", *,
                 transient: bool = False):
        super().__init__(f"{message} [site={site}]")
        self.site = site
        self.transient = transient


# exception classes a spec may raise instead of FaultError — kept to a
# closed set so env-supplied plans cannot name arbitrary types
_EXC_TYPES: Dict[str, type] = {
    "RuntimeError": RuntimeError,
    "ValueError": ValueError,
    "OSError": OSError,
    "ConnectionResetError": ConnectionResetError,
    "ConnectionError": ConnectionError,
    "TimeoutError": TimeoutError,
    "MemoryError": MemoryError,
}


class FaultSpec:
    """One arming rule: at ``site``, after ``after`` clean hits, fire at
    most ``times`` times (None = every hit), optionally only when
    ``match`` is a substring of the payload, optionally with seeded
    probability ``p``.  ``kind`` is ``"error"`` (raise) or ``"delay"``
    (sleep ``delay_s`` — models a hung step/worker)."""

    __slots__ = ("site", "kind", "times", "after", "match", "p",
                 "delay_s", "transient", "exc", "message")

    def __init__(
        self,
        site: str,
        *,
        kind: str = "error",
        times: Optional[int] = 1,
        after: int = 0,
        match: Optional[str] = None,
        p: Optional[float] = None,
        delay_s: float = 0.0,
        transient: bool = True,
        exc: Optional[str] = None,
        message: str = "injected fault",
    ):
        if kind not in ("error", "delay"):
            raise ValueError(f"fault kind must be 'error' or 'delay', got {kind!r}")
        if exc is not None and exc not in _EXC_TYPES:
            raise ValueError(
                f"unknown fault exception {exc!r}; one of {sorted(_EXC_TYPES)}"
            )
        self.site = site
        self.kind = kind
        self.times = times
        self.after = after
        self.match = match
        self.p = p
        self.delay_s = delay_s
        self.transient = transient
        self.exc = exc
        self.message = message

    def build_exception(self) -> BaseException:
        if self.exc is None:
            return FaultError(self.site, self.message, transient=self.transient)
        return _EXC_TYPES[self.exc](f"{self.message} [site={self.site}]")

    def to_dict(self) -> Dict[str, Any]:
        return {s: getattr(self, s) for s in self.__slots__}


class FaultPlan:
    """An armed set of specs plus its deterministic firing state.

    The plan records every fired fault in ``fired`` (site, payload, spec
    index) so a failing chaos test prints exactly which injections the
    run saw; ``hits`` counts per-site traffic whether or not anything
    fired.
    """

    def __init__(self, *faults: FaultSpec, seed: int = 0):
        self.faults: List[FaultSpec] = list(faults)
        self.seed = seed
        self.hits: Dict[str, int] = {}
        self.fired: List[Tuple[str, str, int]] = []
        self._seen: List[int] = [0] * len(self.faults)   # matched hits/spec
        self._shot: List[int] = [0] * len(self.faults)   # fires/spec
        self._rng = random.Random(seed)
        self._lock = threading.Lock()

    # called from fault_point under no assumption about the thread
    def hit(self, site: str, payload: Any = None) -> None:
        action: Optional[FaultSpec] = None
        with self._lock:
            self.hits[site] = self.hits.get(site, 0) + 1
            text = "" if payload is None else str(payload)
            for i, spec in enumerate(self.faults):
                if spec.site != site:
                    continue
                if spec.match is not None and spec.match not in text:
                    continue
                self._seen[i] += 1
                if self._seen[i] <= spec.after:
                    continue
                if spec.times is not None and self._shot[i] >= spec.times:
                    continue
                if spec.p is not None and self._rng.random() >= spec.p:
                    continue
                self._shot[i] += 1
                self.fired.append((site, text, i))
                action = spec
                break
        if action is None:
            return
        if action.kind == "delay":
            time.sleep(action.delay_s)
            return
        raise action.build_exception()

    @classmethod
    def from_env(cls, var: str = "REPRO_FAULT_PLAN") -> Optional["FaultPlan"]:
        """Build a plan from a JSON env knob (the CI chaos-smoke hook)::

            REPRO_FAULT_PLAN='{"seed": 7, "faults": [
                {"site": "store.load", "times": 2}]}'

        Returns None when the variable is unset/empty."""
        raw = os.environ.get(var, "").strip()
        if not raw:
            return None
        obj = json.loads(raw)
        specs = [FaultSpec(f.pop("site"), **f) for f in obj.get("faults", [])]
        return cls(*specs, seed=int(obj.get("seed", 0)))


_ACTIVE: Optional[FaultPlan] = None
_ARM_LOCK = threading.Lock()


def fault_point(site: str, payload: Any = None) -> None:
    """Production-side hook: no-op unless a plan is injected."""
    plan = _ACTIVE
    if plan is None:
        return
    plan.hit(site, payload)


@contextlib.contextmanager
def inject(plan: Optional[FaultPlan]):
    """Arm ``plan`` for the duration of the block (process-global, not
    reentrant — chaos tests run one plan at a time).  ``inject(None)``
    is a no-op pass-through so call sites can be unconditional."""
    global _ACTIVE
    if plan is None:
        yield None
        return
    with _ARM_LOCK:
        if _ACTIVE is not None:
            raise RuntimeError("a FaultPlan is already injected")
        _ACTIVE = plan
    try:
        yield plan
    finally:
        _ACTIVE = None
