"""Simulation-as-a-service: continuous batching over the streaming engine.

``TraceServer`` admits concurrent (trace, model) requests from many
tenants and routes them into the engine's per-geometry executable pool —
so concurrency never multiplies compiles, same-trace requests share one
feature pre-pass, admission is bounded with 429-style rejection, and
service order is fair across tenants and geometries.  ``ModelRegistry``
resolves names to trained/transfer-adapted heads through the artifact
store.  See docs/serve.md.
"""
from .registry import ModelRegistry
from .server import TraceServer
from .types import (
    ERROR_CODES,
    ServeError,
    ServeRequest,
    ServeResult,
    ServerStats,
    decode_trace,
    encode_trace,
)

__all__ = [
    "ERROR_CODES",
    "ModelRegistry",
    "ServeError",
    "ServeRequest",
    "ServeResult",
    "ServerStats",
    "TraceServer",
    "decode_trace",
    "encode_trace",
]
