"""Named model resolution for the trace server.

The artifact store is content-addressed — perfect for "has anyone computed
this?", useless for "give me the model called ``skylake-l1d32``".  The
registry bridges the two: a name maps to a ``serve_model`` store entry
(key = ``content_key("serve_model", name)``) whose payload is the params
tree and whose manifest extra carries the full ``TaoConfig`` (plain
dataclass fields), so any process sharing the store root can resolve a
name into a ready-to-simulate ``TrainedModel`` — trained heads and
transfer-adapted heads alike, since both are just ``TrainedModel``s.

Resolution order is memory first (models registered in-process, e.g. a
freshly transfer-adapted head), then the store.  ``resolve`` loads
through ``ArtifactStore.get``, which pins the entry for the duration of
the read — a GC racing in another process cannot delete it mid-stream.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple, Union

from ..core.features import FeatureConfig
from ..core.model import TaoConfig
from ..store import ArtifactStore, content_key
from .types import ServeError

__all__ = ["ModelRegistry"]

_KIND = "serve_model"


def _cfg_to_dict(cfg: TaoConfig) -> Dict:
    d = dataclasses.asdict(cfg)          # features nests as a plain dict
    return d


def _cfg_from_dict(d: Dict) -> TaoConfig:
    d = dict(d)
    feats = d.pop("features", None)
    if feats is not None:
        d["features"] = FeatureConfig(**feats)
    return TaoConfig(**d)


class ModelRegistry:
    """name -> ``TrainedModel``, in memory and (optionally) via the store."""

    def __init__(self, store: Optional[Union[ArtifactStore, str]] = None):
        if isinstance(store, str):
            store = ArtifactStore(store)
        self.store = store
        self._models: Dict[str, "object"] = {}   # name -> TrainedModel

    @staticmethod
    def key(name: str) -> str:
        return content_key(_KIND, name)

    # ---- registration ----------------------------------------------------

    def register(self, name: str, model, *, publish: bool = False) -> None:
        """Bind ``name`` to an in-process ``TrainedModel`` (a trained or
        transfer-adapted head).  ``publish=True`` also writes it to the
        store so other processes can resolve the same name."""
        self._models[name] = model
        if publish:
            self.publish(name, model)

    def publish(self, name: str, model, *, overwrite: bool = False) -> bool:
        """Persist ``name -> model`` into the store.  Names are mutable
        bindings over an immutable store, so re-publishing an existing
        name requires ``overwrite=True`` (which deletes the old entry
        first); without it a name collision raises."""
        if self.store is None:
            raise ValueError("registry has no store to publish into")
        key = self.key(name)
        if self.store.has(_KIND, key):
            if not overwrite:
                raise ValueError(
                    f"model name {name!r} is already published; pass "
                    "overwrite=True to rebind it"
                )
            self.store.delete(_KIND, key)
        ok = self.store.put(
            _KIND,
            key,
            model.params,
            {
                "name": name,
                "cfg": _cfg_to_dict(model.cfg),
                "sim_batch_size": int(model.sim_batch_size),
                "sim_feature_backend": model.sim_feature_backend,
                "sim_precision": getattr(model, "sim_precision", "fp32"),
            },
        )
        # Publish time is when the int8 scales are computed — every process
        # that later resolves this name and simulates with precision="int8"
        # reuses the same stored quantized tree instead of re-deriving it.
        from ..api.session import quantized_params_key  # lazy: api imports serve
        from ..core.quant import QUANT_VERSION, quantize_tao_params

        qkey = quantized_params_key(model.params)
        if not self.store.has("params_int8", qkey):
            self.store.put(
                "params_int8",
                qkey,
                quantize_tao_params(model.params),
                {"scheme": "w8a8-per-channel", "version": QUANT_VERSION,
                 "name": name},
            )
        return ok

    # ---- resolution ------------------------------------------------------

    def resolve(self, name: str):
        """The ``TrainedModel`` for ``name`` (memory first, then store).
        Raises ``ServeError(UNKNOWN_MODEL)`` when neither knows it.  A
        store-resolved model is cached in memory, so its engines (and the
        executables behind them) persist across requests."""
        model = self._models.get(name)
        if model is not None:
            return model
        if self.store is not None:
            hit = self.store.get(_KIND, self.key(name))
            if hit is not None:
                from ..api.session import TrainedModel  # lazy: api imports serve

                tree, extra = hit
                model = TrainedModel(
                    params=tree,
                    cfg=_cfg_from_dict(extra["cfg"]),
                    name=extra.get("name", name),
                    sim_batch_size=int(extra.get("sim_batch_size", 64)),
                    sim_feature_backend=extra.get("sim_feature_backend", "numpy"),
                    sim_precision=extra.get("sim_precision", "fp32"),
                    store=self.store,
                )
                self._models[name] = model
                return model
        raise ServeError(
            "UNKNOWN_MODEL",
            f"model {name!r} is not registered"
            + (" (and not published in the store)" if self.store else ""),
        )

    def names(self) -> Tuple[str, ...]:
        """Every resolvable name: in-memory bindings plus published ones."""
        out = set(self._models)
        out.update(name for name, _ in self.published())
        return tuple(sorted(out))

    def published(self) -> Iterator[Tuple[str, Dict]]:
        """``(name, extra)`` for every store-published model (manifest
        scan only — params stay on disk until resolved)."""
        if self.store is None:
            return
        for _, extra in self.store.list_extras(_KIND):
            if "name" in extra:
                yield extra["name"], extra

    def __contains__(self, name: str) -> bool:
        if name in self._models:
            return True
        return self.store is not None and self.store.has(_KIND, self.key(name))

    def __len__(self) -> int:
        return len(self.names())
