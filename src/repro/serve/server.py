"""Continuous-batching trace server over the streaming engine.

The product surface the paper implies: many tenants submit (trace, model)
requests, the server returns device-computed metrics.  What "continuous
batching" means for THIS engine: the compiled step is keyed by window
geometry, not by request, so the multi-tenant scheduling problem reduces
to routing every admitted request into the per-geometry executable pool
the engine already maintains —

  * a request NEVER triggers an XLA compile if any tenant has already
    paid for its geometry (process-wide step cache), and a server that
    ran ``warmup()`` over a declared geometry set — on top of the PR-6
    persistent compilation cache — starts at **0 compiles**;
  * same-trace requests coalesce through the scheduler's content-digest
    feature dedup: one host feature pre-pass (or one store load) serves
    every request for that trace, across tenants and models;
  * admission is bounded (``max_queue``): past the bound, ``submit``
    rejects with ``ServeError(QUEUE_FULL, retry_after_s=...)`` — the
    HTTP-429 analogue — instead of growing memory;
  * service order is fair: round-robin across geometry buckets, and
    round-robin across tenants inside each bucket, so a tenant flooding
    one geometry can neither starve other geometries nor other tenants.

Request lifecycle::

    submit() ─ validate (model / metrics / trace) ──► per-geometry bucket
                                                      (per-tenant FIFOs)
    scheduler loop ─ fairness pick ─► features (digest-coalesced, store-
    backed) ─► cached engine / executable ─► ServeResult future

Everything device-facing reuses the engine stack unchanged: results are
bit-identical to ``TrainedModel.simulate`` / ``Session.simulate`` because
they ARE the same executables.  ``set_plan`` re-resolves partitioning
(single device → mesh) between requests without a restart — engines are
cached per (model, EngineConfig), so plans swap by key, not by teardown.

The server is asyncio-native and single-loop: ``submit``/``stats`` must
run on the event loop thread; feature extraction and device dispatch are
pushed to small executors (extraction eagerly on accelerator backends,
inline with dispatch on CPU — the sweep scheduler's measured policy).

Failure handling (see docs/resilience.md): requests carry deadlines
(queued-too-long or hung-on-device both fail ``DEADLINE_EXCEEDED``, and
a hung dispatch thread is abandoned, not joined); transient dispatch
failures retry with bounded exponential backoff (``RetryPolicy``);
deterministic failures are isolated by batch bisection — the poison
trace's digest is quarantined and rejected with ``TRACE_REJECTED`` while
cohabitant requests of the same dispatch group re-run bit-identically;
and a per-``model/geometry`` circuit breaker sheds admissions with
``CIRCUIT_OPEN`` + ``retry_after_s`` after repeated hard failures
instead of queueing doomed work.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import itertools
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import numpy as np

from ..core.dataset import num_windows
from ..core.features import extract_features
from ..engine.metrics import DEFAULT_METRICS, resolve_metrics
from ..engine.plan import ExecutionPlan
from ..engine.runner import EngineConfig
from ..resilience.breaker import CircuitBreaker
from ..resilience.faults import fault_point
from ..resilience.retry import RetryPolicy, is_transient
from ..store.content import array_digest, content_key
from .registry import ModelRegistry
from .types import ServeError, ServeRequest, ServeResult, ServerStats

__all__ = ["TraceServer"]


@dataclasses.dataclass
class _Pending:
    """One admitted request plus everything resolved at admission."""

    req: ServeRequest
    future: "asyncio.Future"
    model: object                    # resolved TrainedModel
    trace_arr: np.ndarray
    n: int
    digest: str
    specs: tuple                     # resolved MetricSpec tuple
    geometry: str                    # bucket label
    t_submit: float
    coalesced: bool = False
    extract_s: float = 0.0
    attempts: int = 0                # dispatch tries so far (retry counter)
    deadline_at: Optional[float] = None   # perf_counter() bound, or None


class _Bucket:
    """Per-geometry queue: tenant FIFOs served round-robin."""

    __slots__ = ("label", "tenants", "trr", "served", "fill_sum",
                 "occ_sum", "occ_n", "occ_max")

    def __init__(self, label: str):
        self.label = label
        self.tenants: "collections.OrderedDict[str, collections.deque]" = (
            collections.OrderedDict()
        )
        self.trr = 0
        self.served = 0
        self.fill_sum = 0.0
        self.occ_sum = 0
        self.occ_n = 0
        self.occ_max = 0

    def push(self, p: _Pending) -> None:
        dq = self.tenants.get(p.req.tenant)
        if dq is None:
            dq = collections.deque()
            self.tenants[p.req.tenant] = dq
        dq.append(p)

    def pop_next(self) -> Optional[_Pending]:
        names = list(self.tenants)
        for i in range(len(names)):
            t = names[(self.trr + i) % len(names)]
            dq = self.tenants[t]
            if dq:
                self.trr = (self.trr + i + 1) % len(names)
                p = dq.popleft()
                if not dq:
                    del self.tenants[t]  # keep the tenant map bounded
                return p
        return None

    def depth(self) -> int:
        return sum(len(dq) for dq in self.tenants.values())

    def sample_occupancy(self) -> None:
        d = self.depth()
        self.occ_sum += d
        self.occ_n += 1
        self.occ_max = max(self.occ_max, d)


_LATENCY_WINDOW = 4096   # completions kept for the percentile estimators
_FEATURE_CACHE = 64      # trace digests whose features stay resident
_QUARANTINE_CAP = 256    # poison trace digests remembered (LRU)


class TraceServer:
    """Persistent asyncio serving layer over the engine's executable pool.

    ::

        registry = ModelRegistry(store)
        registry.register("base", model)
        server = TraceServer(registry, batch_size=8, store=store)
        async with server:
            fut = server.submit(ServeRequest(model="base", trace=tr))
            result = await fut            # ServeResult
        server.stats()                    # ServerStats snapshot
    """

    def __init__(
        self,
        registry: ModelRegistry,
        *,
        batch_size: int = 64,
        feature_backend: str = "numpy",
        precision: str = "fp32",
        max_queue: int = 64,
        metrics: Tuple = DEFAULT_METRICS,
        store=None,
        plan: Optional[ExecutionPlan] = None,
        mesh=None,
        extract_async: Optional[bool] = None,
        deadline_s: Optional[float] = None,
        retry: Optional[RetryPolicy] = None,
        breaker_threshold: int = 8,
        breaker_cooldown_s: float = 1.0,
        group_size: int = 1,
    ):
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        if group_size < 1:
            raise ValueError(f"group_size must be >= 1, got {group_size}")
        self.registry = registry
        self.batch_size = batch_size
        self.feature_backend = feature_backend
        self.precision = precision
        self.max_queue = max_queue
        self.default_metrics = resolve_metrics(metrics)
        self.store = store if store is not None else getattr(registry, "store", None)
        # one partitioning decision, swappable at runtime via set_plan()
        self._plan: Optional[ExecutionPlan] = None
        if plan is not None or mesh is not None:
            self._plan = ExecutionPlan.resolve(
                mesh, batch_size=batch_size, plan=plan
            )
        # eager (admission-time) extraction overlaps host feature work with
        # device compute; on CPU-only backends the threads would contend
        # with the step's own compute (scheduler.py's measured policy), so
        # extraction runs inline in the dispatch path there.
        if extract_async is None:
            extract_async = jax.default_backend() != "cpu"
        self.extract_async = extract_async

        # resilience: deadlines, bounded retry, per-key breakers, poison
        # quarantine, and the dispatch group size batch bisection splits
        self.deadline_s = deadline_s
        self.retry = retry if retry is not None else RetryPolicy()
        self.group_size = group_size
        self._breaker_threshold = breaker_threshold
        self._breaker_cooldown_s = breaker_cooldown_s
        self._breakers: Dict[str, CircuitBreaker] = {}
        self._quarantine: "collections.OrderedDict[str, str]" = (
            collections.OrderedDict()
        )
        self._requeues = 0                  # backoff timers not yet re-queued

        self._buckets: "collections.OrderedDict[tuple, _Bucket]" = (
            collections.OrderedDict()
        )
        self._brr = 0                       # bucket round-robin cursor
        self._depth = 0                     # total queued (admitted, unserved)
        self._seq = itertools.count()
        self._wake = asyncio.Event()
        self._task: Optional[asyncio.Task] = None
        self._stopping = False
        self._draining = False
        self._killing = False               # stop(drain=False): fail requeues
        self._started_at: Optional[float] = None

        # feature coalescing: trace digest -> executor future of FeatureSet
        self._feat_cache: "collections.OrderedDict[str, object]" = (
            collections.OrderedDict()
        )
        self._extract_pool = ThreadPoolExecutor(
            max_workers=2, thread_name_prefix="serve-extract"
        )
        # one dispatch thread: the device is the serialized resource; the
        # executable pool is shared so ordering, not parallelism, is what
        # the scheduler controls
        self._dispatch_pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="serve-dispatch"
        )

        # observability
        self.counters: Dict[str, int] = {
            "admitted": 0, "completed": 0, "failed": 0, "rejected": 0,
            "features_extracted": 0, "features_from_store": 0,
            "features_coalesced": 0, "retries": 0, "deadline_exceeded": 0,
            "quarantined": 0, "bisections": 0, "breaker_sheds": 0,
        }
        self._tenants: Dict[str, Dict[str, int]] = {}
        self._lat_total: "collections.deque" = collections.deque(
            maxlen=_LATENCY_WINDOW
        )
        self._lat_queue: "collections.deque" = collections.deque(
            maxlen=_LATENCY_WINDOW
        )
        self._service_ema: Optional[float] = None
        self._step_entries: Dict[int, object] = {}   # id -> _CachedStep
        self._step_baseline: Dict[int, int] = {}     # compiles at first sight

    # ---- lifecycle -------------------------------------------------------

    async def start(self) -> "TraceServer":
        if self._task is not None:
            raise RuntimeError("server already started")
        self._started_at = time.perf_counter()
        self._task = asyncio.get_running_loop().create_task(self._run())
        return self

    async def stop(self, *, drain: bool = True) -> None:
        """Stop admitting; ``drain=True`` serves the queue out first
        (including retries still waiting on their backoff timers),
        ``drain=False`` fails queued requests with SHUTTING_DOWN."""
        self._stopping = True
        if not drain:
            self._killing = True
            while True:
                p = self._next()
                if p is None:
                    break
                self._fail(p, ServeError(
                    "SHUTTING_DOWN", "server is shutting down",
                    request_id=p.req.request_id,
                ))
        self._draining = True
        self._wake.set()
        if self._task is not None:
            await self._task
            self._task = None
        self._extract_pool.shutdown(wait=True)
        self._dispatch_pool.shutdown(wait=True)

    async def shutdown(self, *, drain: bool = True) -> None:
        """Alias for :meth:`stop` (the operator-facing verb)."""
        await self.stop(drain=drain)

    async def __aenter__(self) -> "TraceServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # ---- admission -------------------------------------------------------

    # tao: hot
    def submit(self, req: ServeRequest) -> "asyncio.Future":
        """Admit one request (event-loop thread only).  Returns a future
        resolving to a ``ServeResult``; raises ``ServeError`` — QUEUE_FULL
        (with ``retry_after_s``), UNKNOWN_MODEL, BAD_REQUEST,
        TRACE_REJECTED (quarantined poison digest), CIRCUIT_OPEN,
        SHUTTING_DOWN — when the request is not admitted at all."""
        if self._stopping:
            raise ServeError("SHUTTING_DOWN", "server is shutting down")
        if self._depth >= self.max_queue:
            self.counters["rejected"] += 1
            t = self._tenant(req.tenant)
            t["rejected"] += 1
            raise ServeError(
                "QUEUE_FULL",
                f"admission queue at capacity ({self.max_queue})",
                retry_after_s=self._retry_after(),
                request_id=req.request_id,
            )
        model = self.registry.resolve(req.model)     # UNKNOWN_MODEL
        trace = req.trace
        arr = trace.functional if hasattr(trace, "functional") else np.asarray(trace)  # tao: noqa[TAO002] admission-time view of the tenant's host trace array, no device data exists yet
        n = len(arr)
        if n < 1:
            raise ServeError(
                "BAD_REQUEST", "trace is empty", request_id=req.request_id
            )
        try:
            specs = (
                self.default_metrics
                if req.metrics is None
                else resolve_metrics(tuple(req.metrics))
            )
        except (KeyError, TypeError, ValueError) as e:
            raise ServeError(
                "BAD_REQUEST", f"bad metrics: {e}", request_id=req.request_id
            ) from None
        if req.request_id is None:
            req.request_id = f"r{next(self._seq)}"
        w_eff = min(model.cfg.window, n)
        label = f"w{w_eff}b{self.batch_size}"
        digest = (
            trace.digest if hasattr(trace, "digest") else array_digest(arr)
        )
        if digest in self._quarantine:
            self.counters["rejected"] += 1
            self._tenant(req.tenant)["rejected"] += 1
            raise ServeError(
                "TRACE_REJECTED",
                f"trace {digest[:12]} is quarantined "
                f"({self._quarantine[digest]})",
                request_id=req.request_id,
            )
        br = self._breakers.get(f"{req.model}/{label}")
        if br is not None and not br.allow():
            self.counters["breaker_sheds"] += 1
            self.counters["rejected"] += 1
            self._tenant(req.tenant)["rejected"] += 1
            raise ServeError(
                "CIRCUIT_OPEN",
                f"circuit open for {req.model}/{label} "
                f"({br.failures} consecutive failures)",
                retry_after_s=br.retry_after_s,
                request_id=req.request_id,
            )
        dl = req.deadline_s if req.deadline_s is not None else self.deadline_s
        p = _Pending(
            req=req,
            future=asyncio.get_running_loop().create_future(),
            model=model,
            trace_arr=arr,
            n=n,
            digest=digest,
            specs=specs,
            geometry=label,
            t_submit=time.perf_counter(),
        )
        if dl is not None:
            p.deadline_at = p.t_submit + dl
        bkey = (model.cfg, w_eff, specs)
        bucket = self._buckets.get(bkey)
        if bucket is None:
            bucket = _Bucket(label)
            self._buckets[bkey] = bucket
        bucket.push(p)
        self._depth += 1
        self.counters["admitted"] += 1
        self._tenant(req.tenant)["admitted"] += 1
        if self.extract_async and self.feature_backend == "numpy":
            self._feature_entry(p)       # start the pre-pass immediately
        self._wake.set()
        return p.future

    def _tenant(self, name: str) -> Dict[str, int]:
        t = self._tenants.get(name)
        if t is None:
            t = {"admitted": 0, "completed": 0, "failed": 0, "rejected": 0}
            self._tenants[name] = t
        return t

    def _retry_after(self) -> float:
        est = self._service_ema if self._service_ema is not None else 0.05
        return max(0.01, est * max(1, self._depth))

    # ---- fairness pick ---------------------------------------------------

    def _next(self) -> Optional[_Pending]:
        if self._depth == 0:
            return None
        buckets = list(self._buckets.values())
        nb = len(buckets)
        for i in range(nb):
            b = buckets[(self._brr + i) % nb]
            p = b.pop_next()
            if p is not None:
                self._brr = (self._brr + i + 1) % nb
                self._depth -= 1
                return p
        return None

    # ---- features (digest-coalesced, store-backed) -----------------------

    def _feature_entry(self, p: _Pending):
        """The shared executor future computing ``p``'s FeatureSet; one
        per trace digest, LRU-bounded.  Marks ``p.coalesced`` when some
        earlier request already owns the pre-pass."""
        ent = self._feat_cache.get(p.digest)
        if ent is not None:
            self._feat_cache.move_to_end(p.digest)
            if not p.coalesced:
                p.coalesced = True
                self.counters["features_coalesced"] += 1
            return ent
        loop = asyncio.get_running_loop()
        ent = loop.run_in_executor(
            self._extract_pool, self._extract_sync, p.trace_arr,
            p.digest, p.model.cfg,
        )
        self._feat_cache[p.digest] = ent
        while len(self._feat_cache) > _FEATURE_CACHE:
            self._feat_cache.popitem(last=False)
        return ent

    # feature-pool thread: host NumPy pre-pass before any device work
    # tao: cold
    def _extract_sync(self, arr: np.ndarray, digest: str, cfg):
        """Runs on the extract pool: store lookup, else extract + publish
        (the identical key scheme as TraceSweeper / TrainedModel, so the
        server shares warm entries with every other consumer)."""
        fault_point("serve.extract", payload=digest)
        key = content_key("features", digest, cfg.features)
        if self.store is not None:
            hit = self.store.get("features", key)
            if hit is not None:
                from ..store.store import tree_to_features

                self.counters["features_from_store"] += 1
                return tree_to_features(hit[0])
        fs = extract_features(arr, cfg.features, with_labels=False)
        self.counters["features_extracted"] += 1
        if self.store is not None:
            from ..store.store import features_to_tree

            self.store.put("features", key, features_to_tree(fs))
        return fs

    # ---- dispatch --------------------------------------------------------

    def _engine_for(self, p: _Pending):
        try:
            return p.model.engine(EngineConfig(
                batch_size=self.batch_size,
                feature_backend=self.feature_backend,
                precision=self.precision,
                plan=self._plan,
                metrics=p.specs,
            ))
        except ValueError as e:
            # plan/batch divisibility, bad geometry: the tenant's request
            # cannot run under the server's current partitioning
            raise ServeError(
                "GEOMETRY_MISMATCH", str(e), request_id=p.req.request_id
            ) from None

    def _next_group(self) -> List[_Pending]:
        """The next dispatch group: the fairness pick plus up to
        ``group_size - 1`` more requests from the same bucket (they share
        an executable, so they form one continuous batch — and one
        bisection domain when something in it fails)."""
        group: List[_Pending] = []
        p = self._next()
        if p is None:
            return group
        group.append(p)
        if self.group_size > 1:
            b = self._buckets.get(
                (p.model.cfg, min(p.model.cfg.window, p.n), p.specs)
            )
            while b is not None and len(group) < self.group_size:
                q = b.pop_next()
                if q is None:
                    break
                self._depth -= 1
                group.append(q)
        return group

    # dispatch-pool thread: the whole group runs as one unit — a failure
    # anywhere aborts the batch (as a real poisoned device batch would),
    # and the async side bisects to isolate the culprit
    def _simulate_group(self, items: List[tuple]) -> List[object]:
        out = []
        for p, features, engine in items:
            fault_point("serve.dispatch", payload=p.digest)
            out.append(engine.simulate(p.trace_arr, features))
        return out

    def _breaker_for(self, p: _Pending) -> CircuitBreaker:
        key = f"{p.req.model}/{p.geometry}"
        br = self._breakers.get(key)
        if br is None:
            br = CircuitBreaker(
                failure_threshold=self._breaker_threshold,
                cooldown_s=self._breaker_cooldown_s,
            )
            self._breakers[key] = br
        return br

    def _expire(self, p: _Pending) -> None:
        self.counters["deadline_exceeded"] += 1
        self._breaker_for(p).record_failure()
        self._fail(p, ServeError(
            "DEADLINE_EXCEEDED",
            f"request exceeded its deadline after {p.attempts + 1} "
            "dispatch attempt(s)",
            request_id=p.req.request_id,
        ))

    def _requeue(self, p: _Pending) -> None:
        """Backoff timer fired: put the request back in its bucket (or
        fail it when the server was killed without draining)."""
        self._requeues -= 1
        if self._killing:
            self._fail(p, ServeError(
                "SHUTTING_DOWN", "server is shutting down",
                request_id=p.req.request_id,
            ))
            return
        bkey = (p.model.cfg, min(p.model.cfg.window, p.n), p.specs)
        bucket = self._buckets.get(bkey)
        if bucket is None:
            bucket = _Bucket(p.geometry)
            self._buckets[bkey] = bucket
        bucket.push(p)
        self._depth += 1
        self._wake.set()

    def _on_failure(self, p: _Pending, exc: BaseException) -> None:
        """Classify a singleton dispatch failure: fatal (ServeError) /
        transient (bounded backoff retry) / poison (quarantine digest,
        reject TRACE_REJECTED)."""
        if isinstance(exc, ServeError):
            self._fail(p, exc)
            return
        if is_transient(exc):
            p.attempts += 1
            now = time.perf_counter()
            delay = self.retry.delay(p.attempts)
            budget_ok = (
                p.deadline_at is None or now + delay < p.deadline_at
            )
            if p.attempts < self.retry.max_attempts and budget_ok:
                self.counters["retries"] += 1
                self._requeues += 1
                asyncio.get_running_loop().call_later(
                    delay, self._requeue, p
                )
                return
            self._breaker_for(p).record_failure()
            self._fail(p, ServeError.wrap(exc, request_id=p.req.request_id))
            return
        # deterministic poison: remember the digest so resubmits are shed
        # at admission (the tenant's input is at fault, not capacity — the
        # breaker does not count it)
        self._quarantine[p.digest] = type(exc).__name__
        while len(self._quarantine) > _QUARANTINE_CAP:
            self._quarantine.popitem(last=False)
        self.counters["quarantined"] += 1
        self._fail(p, ServeError(
            "TRACE_REJECTED",
            f"trace {p.digest[:12]} poisons its batch "
            f"({type(exc).__name__}) and was quarantined",
            request_id=p.req.request_id,
        ))

    def _abandon_pool(self, pool: ThreadPoolExecutor) -> None:
        """A dispatch hung past its deadline: abandon the pool (and the
        thread stuck inside it) so the next dispatch is not head-of-line
        blocked behind the hang."""
        if pool is self._dispatch_pool:
            self._dispatch_pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="serve-dispatch"
            )
        pool.shutdown(wait=False)

    def _complete(self, p: _Pending, res, t_start: float, t_done: float) -> None:
        bucket = self._buckets.get(
            (p.model.cfg, min(p.model.cfg.window, p.n), p.specs)
        )
        if bucket is not None:
            bucket.served += 1
            nw = num_windows(p.n, p.model.cfg.window, p.model.cfg.window)
            nb = -(-nw // self.batch_size)
            bucket.fill_sum += nw / (nb * self.batch_size)
        br = self._breakers.get(f"{p.req.model}/{p.geometry}")
        if br is not None:
            br.record_success()
        self.counters["completed"] += 1
        self._tenant(p.req.tenant)["completed"] += 1
        self._lat_total.append(t_done - p.t_submit)
        self._lat_queue.append(t_start - p.t_submit)
        result = ServeResult(
            request_id=p.req.request_id,
            model=p.req.model,
            tenant=p.req.tenant,
            geometry=p.geometry,
            num_instructions=res.num_instructions,
            metrics=dict(res.metrics),
            queue_s=t_start - p.t_submit,
            extract_s=p.extract_s,
            compute_s=t_done - t_start,
            total_s=t_done - p.t_submit,
            coalesced=p.coalesced,
        )
        if not p.future.done():
            p.future.set_result(result)

    async def _run_batch(self, group: List[_Pending]) -> None:
        """Resolve features/engines for a dispatch group and execute it.
        Per-request failures here (feature extraction, engine resolution)
        go through the retry/quarantine classifier without touching the
        group's healthy members."""
        t_start = time.perf_counter()
        items: List[tuple] = []
        for p in group:
            if p.deadline_at is not None and t_start >= p.deadline_at:
                self._expire(p)          # spent its budget in the queue
                continue
            try:
                features = None
                if self.feature_backend == "numpy":
                    t_f = time.perf_counter()
                    features = await self._feature_entry(p)
                    p.extract_s += time.perf_counter() - t_f
                engine = self._engine_for(p)
                entry = engine.step_entry_for(p.n)
                if id(entry) not in self._step_entries:
                    self._step_entries[id(entry)] = entry
                    self._step_baseline[id(entry)] = entry.compiles
                items.append((p, features, engine))
            except BaseException as e:
                # a failed extraction future must not poison the cache
                # for later requests of the same digest
                self._feat_cache.pop(p.digest, None)
                self._on_failure(p, e)
        if items:
            await self._run_items(items, t_start)

    async def _run_items(self, items: List[tuple], t_start: float) -> None:
        loop = asyncio.get_running_loop()
        timeout = None
        for p, _, _ in items:
            if p.deadline_at is not None:
                rem = p.deadline_at - time.perf_counter()
                timeout = rem if timeout is None else min(timeout, rem)
        pool = self._dispatch_pool
        fut = loop.run_in_executor(pool, self._simulate_group, items)
        try:
            if timeout is not None:
                results = await asyncio.wait_for(fut, max(timeout, 0.001))
            else:
                results = await fut
        except asyncio.TimeoutError as e:
            if timeout is None:
                # an injected/engine TimeoutError, not the deadline guard
                await self._on_group_error(items, e, t_start)
                return
            self._abandon_pool(pool)
            now = time.perf_counter()
            for p, features, engine in items:
                if p.deadline_at is not None and now >= p.deadline_at:
                    self._expire(p)
                else:
                    # cohabitant of the hung request: re-run on the fresh
                    # pool (simulate is pure — results are bit-identical)
                    await self._run_items([(p, features, engine)], t_start)
            return
        except BaseException as e:
            await self._on_group_error(items, e, t_start)
            return
        t_done = time.perf_counter()
        self._service_ema = (
            (t_done - t_start) if self._service_ema is None
            else 0.8 * self._service_ema + 0.2 * (t_done - t_start)
        )
        for (p, _, _), res in zip(items, results):
            self._complete(p, res, t_start, t_done)

    async def _on_group_error(
        self, items: List[tuple], exc: BaseException, t_start: float
    ) -> None:
        """Batch bisection: a group failure names no culprit (a poisoned
        device batch aborts wholesale), so split and re-run each half —
        re-simulation is pure, so survivors stay bit-identical — until
        the failure pins to a singleton, which the classifier handles."""
        if len(items) == 1:
            self._on_failure(items[0][0], exc)
            return
        self.counters["bisections"] += 1
        mid = len(items) // 2
        await self._run_items(items[:mid], t_start)
        await self._run_items(items[mid:], t_start)

    def _fail(self, p: _Pending, err: ServeError) -> None:
        self.counters["failed"] += 1
        self._tenant(p.req.tenant)["failed"] += 1
        if not p.future.done():
            p.future.set_exception(err)

    # tao: hot
    async def _run(self) -> None:
        while True:
            group = self._next_group()
            if not group:
                if self._draining:
                    if self._requeues == 0:
                        break
                    # retries are parked on backoff timers; let them land
                    await asyncio.sleep(0.005)
                    continue
                self._wake.clear()
                await self._wake.wait()
                continue
            for b in self._buckets.values():
                b.sample_occupancy()
            await self._run_batch(group)

    # ---- operations ------------------------------------------------------

    def set_plan(
        self, *, mesh=None, plan: Optional[ExecutionPlan] = None
    ) -> ExecutionPlan:
        """Swap the partitioning plan without a restart: subsequent
        requests resolve engines under the new plan (mesh=None and
        plan=None reverts to single-device).  In-flight requests finish
        under the plan they started with; executables for both plans
        coexist in the step cache, so flipping back is also compile-free."""
        if mesh is None and plan is None:
            self._plan = None
        else:
            self._plan = ExecutionPlan.resolve(
                mesh, batch_size=self.batch_size, plan=plan
            )
        return self._plan if self._plan is not None else ExecutionPlan.single()

    def warmup(
        self,
        trace_lengths: Iterable[int],
        models: Optional[Iterable[str]] = None,
    ) -> Dict[str, int]:
        """AOT-compile the serving executables for a declared geometry set
        (every registry model × every length) before any tenant connects.
        With the persistent compilation cache behind the store, a warm
        restart deserializes instead of compiling: a cluster-level, not
        process-level, cold start (see docs/store.md)."""
        names = list(models) if models is not None else list(self.registry.names())
        compiled = 0
        aot = 0
        for name in names:
            model = self.registry.resolve(name)
            engine = model.engine(EngineConfig(
                batch_size=self.batch_size,
                feature_backend=self.feature_backend,
                precision=self.precision,
                plan=self._plan,
                metrics=self.default_metrics,
            ))
            for n in sorted(set(trace_lengths)):
                entry = engine.warmup(n)
                if id(entry) not in self._step_entries:
                    self._step_entries[id(entry)] = entry
                    self._step_baseline[id(entry)] = entry.compiles
                compiled += 1
                aot += entry.aot is not None
        return {"geometries": compiled, "aot_compiled": aot}

    # ---- observability ---------------------------------------------------

    @property
    def num_compiles(self) -> int:
        """Step compiles attributable to requests served by THIS server
        (0 on a warm server — the multi-tenant one-compile guarantee)."""
        return sum(
            e.compiles - self._step_baseline[i]
            for i, e in self._step_entries.items()
        )

    @staticmethod
    def _pct(samples, q: float) -> float:
        return float(np.percentile(np.asarray(samples), q)) if samples else 0.0

    def stats(self) -> ServerStats:
        uptime = (
            time.perf_counter() - self._started_at
            if self._started_at is not None
            else 0.0
        )
        per_geo: Dict[str, Dict] = {}
        for b in self._buckets.values():
            g = per_geo.setdefault(b.label, {
                "queued": 0, "served": 0, "fill_sum": 0.0,
                "occ_max": 0, "occ_n": 0, "occ_sum": 0,
            })
            g["queued"] += b.depth()
            g["served"] += b.served
            g["fill_sum"] += b.fill_sum
            g["occ_sum"] += b.occ_sum
            g["occ_n"] += b.occ_n
            g["occ_max"] = max(g["occ_max"], b.occ_max)
        for g in per_geo.values():
            fill_sum = g.pop("fill_sum")
            occ_sum, occ_n = g.pop("occ_sum"), g.pop("occ_n")
            g["batch_fill_ratio"] = fill_sum / g["served"] if g["served"] else 0.0
            g["queue_occupancy_mean"] = occ_sum / occ_n if occ_n else 0.0
            g["queue_occupancy_max"] = g.pop("occ_max")
        served = self.counters["completed"]
        fills: List[float] = [
            g["batch_fill_ratio"] * g["served"]
            for g in per_geo.values() if g["served"]
        ]
        plan = self._plan if self._plan is not None else ExecutionPlan.single()
        return ServerStats(
            uptime_s=uptime,
            admitted=self.counters["admitted"],
            completed=served,
            failed=self.counters["failed"],
            rejected=self.counters["rejected"],
            queue_depth=self._depth,
            max_queue=self.max_queue,
            num_compiles=self.num_compiles,
            features_extracted=self.counters["features_extracted"],
            features_from_store=self.counters["features_from_store"],
            features_coalesced=self.counters["features_coalesced"],
            traces_per_s=served / uptime if uptime > 0 else 0.0,
            latency_p50_s=self._pct(self._lat_total, 50),
            latency_p99_s=self._pct(self._lat_total, 99),
            queue_p50_s=self._pct(self._lat_queue, 50),
            queue_p99_s=self._pct(self._lat_queue, 99),
            batch_fill_ratio=sum(fills) / served if served else 0.0,
            plan_kind=plan.kind,
            num_shards=plan.num_shards,
            retries=self.counters["retries"],
            deadline_exceeded=self.counters["deadline_exceeded"],
            quarantined=self.counters["quarantined"],
            bisections=self.counters["bisections"],
            breaker_sheds=self.counters["breaker_sheds"],
            breakers={k: b.snapshot() for k, b in self._breakers.items()},
            per_geometry=per_geo,
            per_tenant={k: dict(v) for k, v in self._tenants.items()},
        )
