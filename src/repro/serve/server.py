"""Continuous-batching trace server over the streaming engine.

The product surface the paper implies: many tenants submit (trace, model)
requests, the server returns device-computed metrics.  What "continuous
batching" means for THIS engine: the compiled step is keyed by window
geometry, not by request, so the multi-tenant scheduling problem reduces
to routing every admitted request into the per-geometry executable pool
the engine already maintains —

  * a request NEVER triggers an XLA compile if any tenant has already
    paid for its geometry (process-wide step cache), and a server that
    ran ``warmup()`` over a declared geometry set — on top of the PR-6
    persistent compilation cache — starts at **0 compiles**;
  * same-trace requests coalesce through the scheduler's content-digest
    feature dedup: one host feature pre-pass (or one store load) serves
    every request for that trace, across tenants and models;
  * admission is bounded (``max_queue``): past the bound, ``submit``
    rejects with ``ServeError(QUEUE_FULL, retry_after_s=...)`` — the
    HTTP-429 analogue — instead of growing memory;
  * service order is fair: round-robin across geometry buckets, and
    round-robin across tenants inside each bucket, so a tenant flooding
    one geometry can neither starve other geometries nor other tenants.

Request lifecycle::

    submit() ─ validate (model / metrics / trace) ──► per-geometry bucket
                                                      (per-tenant FIFOs)
    scheduler loop ─ fairness pick ─► features (digest-coalesced, store-
    backed) ─► cached engine / executable ─► ServeResult future

Everything device-facing reuses the engine stack unchanged: results are
bit-identical to ``TrainedModel.simulate`` / ``Session.simulate`` because
they ARE the same executables.  ``set_plan`` re-resolves partitioning
(single device → mesh) between requests without a restart — engines are
cached per (model, EngineConfig), so plans swap by key, not by teardown.

The server is asyncio-native and single-loop: ``submit``/``stats`` must
run on the event loop thread; feature extraction and device dispatch are
pushed to small executors (extraction eagerly on accelerator backends,
inline with dispatch on CPU — the sweep scheduler's measured policy).
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import itertools
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import numpy as np

from ..core.dataset import num_windows
from ..core.features import extract_features
from ..engine.metrics import DEFAULT_METRICS, resolve_metrics
from ..engine.plan import ExecutionPlan
from ..engine.runner import EngineConfig
from ..store.content import array_digest, content_key
from .registry import ModelRegistry
from .types import ServeError, ServeRequest, ServeResult, ServerStats

__all__ = ["TraceServer"]


@dataclasses.dataclass
class _Pending:
    """One admitted request plus everything resolved at admission."""

    req: ServeRequest
    future: "asyncio.Future"
    model: object                    # resolved TrainedModel
    trace_arr: np.ndarray
    n: int
    digest: str
    specs: tuple                     # resolved MetricSpec tuple
    geometry: str                    # bucket label
    t_submit: float
    coalesced: bool = False
    extract_s: float = 0.0


class _Bucket:
    """Per-geometry queue: tenant FIFOs served round-robin."""

    __slots__ = ("label", "tenants", "trr", "served", "fill_sum",
                 "occ_sum", "occ_n", "occ_max")

    def __init__(self, label: str):
        self.label = label
        self.tenants: "collections.OrderedDict[str, collections.deque]" = (
            collections.OrderedDict()
        )
        self.trr = 0
        self.served = 0
        self.fill_sum = 0.0
        self.occ_sum = 0
        self.occ_n = 0
        self.occ_max = 0

    def push(self, p: _Pending) -> None:
        dq = self.tenants.get(p.req.tenant)
        if dq is None:
            dq = collections.deque()
            self.tenants[p.req.tenant] = dq
        dq.append(p)

    def pop_next(self) -> Optional[_Pending]:
        names = list(self.tenants)
        for i in range(len(names)):
            t = names[(self.trr + i) % len(names)]
            dq = self.tenants[t]
            if dq:
                self.trr = (self.trr + i + 1) % len(names)
                p = dq.popleft()
                if not dq:
                    del self.tenants[t]  # keep the tenant map bounded
                return p
        return None

    def depth(self) -> int:
        return sum(len(dq) for dq in self.tenants.values())

    def sample_occupancy(self) -> None:
        d = self.depth()
        self.occ_sum += d
        self.occ_n += 1
        self.occ_max = max(self.occ_max, d)


_LATENCY_WINDOW = 4096   # completions kept for the percentile estimators
_FEATURE_CACHE = 64      # trace digests whose features stay resident


class TraceServer:
    """Persistent asyncio serving layer over the engine's executable pool.

    ::

        registry = ModelRegistry(store)
        registry.register("base", model)
        server = TraceServer(registry, batch_size=8, store=store)
        async with server:
            fut = server.submit(ServeRequest(model="base", trace=tr))
            result = await fut            # ServeResult
        server.stats()                    # ServerStats snapshot
    """

    def __init__(
        self,
        registry: ModelRegistry,
        *,
        batch_size: int = 64,
        feature_backend: str = "numpy",
        max_queue: int = 64,
        metrics: Tuple = DEFAULT_METRICS,
        store=None,
        plan: Optional[ExecutionPlan] = None,
        mesh=None,
        extract_async: Optional[bool] = None,
    ):
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.registry = registry
        self.batch_size = batch_size
        self.feature_backend = feature_backend
        self.max_queue = max_queue
        self.default_metrics = resolve_metrics(metrics)
        self.store = store if store is not None else getattr(registry, "store", None)
        # one partitioning decision, swappable at runtime via set_plan()
        self._plan: Optional[ExecutionPlan] = None
        if plan is not None or mesh is not None:
            self._plan = ExecutionPlan.resolve(
                mesh, batch_size=batch_size, plan=plan
            )
        # eager (admission-time) extraction overlaps host feature work with
        # device compute; on CPU-only backends the threads would contend
        # with the step's own compute (scheduler.py's measured policy), so
        # extraction runs inline in the dispatch path there.
        if extract_async is None:
            extract_async = jax.default_backend() != "cpu"
        self.extract_async = extract_async

        self._buckets: "collections.OrderedDict[tuple, _Bucket]" = (
            collections.OrderedDict()
        )
        self._brr = 0                       # bucket round-robin cursor
        self._depth = 0                     # total queued (admitted, unserved)
        self._seq = itertools.count()
        self._wake = asyncio.Event()
        self._task: Optional[asyncio.Task] = None
        self._stopping = False
        self._draining = False
        self._started_at: Optional[float] = None

        # feature coalescing: trace digest -> executor future of FeatureSet
        self._feat_cache: "collections.OrderedDict[str, object]" = (
            collections.OrderedDict()
        )
        self._extract_pool = ThreadPoolExecutor(
            max_workers=2, thread_name_prefix="serve-extract"
        )
        # one dispatch thread: the device is the serialized resource; the
        # executable pool is shared so ordering, not parallelism, is what
        # the scheduler controls
        self._dispatch_pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="serve-dispatch"
        )

        # observability
        self.counters: Dict[str, int] = {
            "admitted": 0, "completed": 0, "failed": 0, "rejected": 0,
            "features_extracted": 0, "features_from_store": 0,
            "features_coalesced": 0,
        }
        self._tenants: Dict[str, Dict[str, int]] = {}
        self._lat_total: "collections.deque" = collections.deque(
            maxlen=_LATENCY_WINDOW
        )
        self._lat_queue: "collections.deque" = collections.deque(
            maxlen=_LATENCY_WINDOW
        )
        self._service_ema: Optional[float] = None
        self._step_entries: Dict[int, object] = {}   # id -> _CachedStep
        self._step_baseline: Dict[int, int] = {}     # compiles at first sight

    # ---- lifecycle -------------------------------------------------------

    async def start(self) -> "TraceServer":
        if self._task is not None:
            raise RuntimeError("server already started")
        self._started_at = time.perf_counter()
        self._task = asyncio.get_running_loop().create_task(self._run())
        return self

    async def stop(self, *, drain: bool = True) -> None:
        """Stop admitting; ``drain=True`` serves the queue out first,
        ``drain=False`` fails queued requests with SHUTTING_DOWN."""
        self._stopping = True
        if not drain:
            while True:
                p = self._next()
                if p is None:
                    break
                self._fail(p, ServeError(
                    "SHUTTING_DOWN", "server is shutting down",
                    request_id=p.req.request_id,
                ))
        self._draining = True
        self._wake.set()
        if self._task is not None:
            await self._task
            self._task = None
        self._extract_pool.shutdown(wait=True)
        self._dispatch_pool.shutdown(wait=True)

    async def __aenter__(self) -> "TraceServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # ---- admission -------------------------------------------------------

    # tao: hot
    def submit(self, req: ServeRequest) -> "asyncio.Future":
        """Admit one request (event-loop thread only).  Returns a future
        resolving to a ``ServeResult``; raises ``ServeError`` — QUEUE_FULL
        (with ``retry_after_s``), UNKNOWN_MODEL, BAD_REQUEST,
        SHUTTING_DOWN — when the request is not admitted at all."""
        if self._stopping:
            raise ServeError("SHUTTING_DOWN", "server is shutting down")
        if self._depth >= self.max_queue:
            self.counters["rejected"] += 1
            t = self._tenant(req.tenant)
            t["rejected"] += 1
            raise ServeError(
                "QUEUE_FULL",
                f"admission queue at capacity ({self.max_queue})",
                retry_after_s=self._retry_after(),
                request_id=req.request_id,
            )
        model = self.registry.resolve(req.model)     # UNKNOWN_MODEL
        trace = req.trace
        arr = trace.functional if hasattr(trace, "functional") else np.asarray(trace)  # tao: noqa[TAO002] admission-time view of the tenant's host trace array, no device data exists yet
        n = len(arr)
        if n < 1:
            raise ServeError(
                "BAD_REQUEST", "trace is empty", request_id=req.request_id
            )
        try:
            specs = (
                self.default_metrics
                if req.metrics is None
                else resolve_metrics(tuple(req.metrics))
            )
        except (KeyError, TypeError, ValueError) as e:
            raise ServeError(
                "BAD_REQUEST", f"bad metrics: {e}", request_id=req.request_id
            ) from None
        if req.request_id is None:
            req.request_id = f"r{next(self._seq)}"
        w_eff = min(model.cfg.window, n)
        label = f"w{w_eff}b{self.batch_size}"
        digest = (
            trace.digest if hasattr(trace, "digest") else array_digest(arr)
        )
        p = _Pending(
            req=req,
            future=asyncio.get_running_loop().create_future(),
            model=model,
            trace_arr=arr,
            n=n,
            digest=digest,
            specs=specs,
            geometry=label,
            t_submit=time.perf_counter(),
        )
        bkey = (model.cfg, w_eff, specs)
        bucket = self._buckets.get(bkey)
        if bucket is None:
            bucket = _Bucket(label)
            self._buckets[bkey] = bucket
        bucket.push(p)
        self._depth += 1
        self.counters["admitted"] += 1
        self._tenant(req.tenant)["admitted"] += 1
        if self.extract_async and self.feature_backend == "numpy":
            self._feature_entry(p)       # start the pre-pass immediately
        self._wake.set()
        return p.future

    def _tenant(self, name: str) -> Dict[str, int]:
        t = self._tenants.get(name)
        if t is None:
            t = {"admitted": 0, "completed": 0, "failed": 0, "rejected": 0}
            self._tenants[name] = t
        return t

    def _retry_after(self) -> float:
        est = self._service_ema if self._service_ema is not None else 0.05
        return max(0.01, est * max(1, self._depth))

    # ---- fairness pick ---------------------------------------------------

    def _next(self) -> Optional[_Pending]:
        if self._depth == 0:
            return None
        buckets = list(self._buckets.values())
        nb = len(buckets)
        for i in range(nb):
            b = buckets[(self._brr + i) % nb]
            p = b.pop_next()
            if p is not None:
                self._brr = (self._brr + i + 1) % nb
                self._depth -= 1
                return p
        return None

    # ---- features (digest-coalesced, store-backed) -----------------------

    def _feature_entry(self, p: _Pending):
        """The shared executor future computing ``p``'s FeatureSet; one
        per trace digest, LRU-bounded.  Marks ``p.coalesced`` when some
        earlier request already owns the pre-pass."""
        ent = self._feat_cache.get(p.digest)
        if ent is not None:
            self._feat_cache.move_to_end(p.digest)
            if not p.coalesced:
                p.coalesced = True
                self.counters["features_coalesced"] += 1
            return ent
        loop = asyncio.get_running_loop()
        ent = loop.run_in_executor(
            self._extract_pool, self._extract_sync, p.trace_arr,
            p.digest, p.model.cfg,
        )
        self._feat_cache[p.digest] = ent
        while len(self._feat_cache) > _FEATURE_CACHE:
            self._feat_cache.popitem(last=False)
        return ent

    # feature-pool thread: host NumPy pre-pass before any device work
    # tao: cold
    def _extract_sync(self, arr: np.ndarray, digest: str, cfg):
        """Runs on the extract pool: store lookup, else extract + publish
        (the identical key scheme as TraceSweeper / TrainedModel, so the
        server shares warm entries with every other consumer)."""
        key = content_key("features", digest, cfg.features)
        if self.store is not None:
            hit = self.store.get("features", key)
            if hit is not None:
                from ..store.store import tree_to_features

                self.counters["features_from_store"] += 1
                return tree_to_features(hit[0])
        fs = extract_features(arr, cfg.features, with_labels=False)
        self.counters["features_extracted"] += 1
        if self.store is not None:
            from ..store.store import features_to_tree

            self.store.put("features", key, features_to_tree(fs))
        return fs

    # ---- dispatch --------------------------------------------------------

    def _engine_for(self, p: _Pending):
        try:
            return p.model.engine(EngineConfig(
                batch_size=self.batch_size,
                feature_backend=self.feature_backend,
                plan=self._plan,
                metrics=p.specs,
            ))
        except ValueError as e:
            # plan/batch divisibility, bad geometry: the tenant's request
            # cannot run under the server's current partitioning
            raise ServeError(
                "GEOMETRY_MISMATCH", str(e), request_id=p.req.request_id
            ) from None

    async def _dispatch(self, p: _Pending) -> None:
        loop = asyncio.get_running_loop()
        t_start = time.perf_counter()
        try:
            features = None
            if self.feature_backend == "numpy":
                t_f = time.perf_counter()
                features = await self._feature_entry(p)
                p.extract_s = time.perf_counter() - t_f
            engine = self._engine_for(p)
            entry = engine.step_entry_for(p.n)
            if id(entry) not in self._step_entries:
                self._step_entries[id(entry)] = entry
                self._step_baseline[id(entry)] = entry.compiles
            res = await loop.run_in_executor(
                self._dispatch_pool, engine.simulate, p.trace_arr, features
            )
        except BaseException as e:
            self._fail(p, ServeError.wrap(e, request_id=p.req.request_id))
            return
        t_done = time.perf_counter()
        self._service_ema = (
            (t_done - t_start) if self._service_ema is None
            else 0.8 * self._service_ema + 0.2 * (t_done - t_start)
        )
        bucket = self._buckets.get((p.model.cfg, min(p.model.cfg.window, p.n), p.specs))
        if bucket is not None:
            bucket.served += 1
            nw = num_windows(p.n, p.model.cfg.window, p.model.cfg.window)
            nb = -(-nw // self.batch_size)
            bucket.fill_sum += nw / (nb * self.batch_size)
        self.counters["completed"] += 1
        self._tenant(p.req.tenant)["completed"] += 1
        self._lat_total.append(t_done - p.t_submit)
        self._lat_queue.append(t_start - p.t_submit)
        result = ServeResult(
            request_id=p.req.request_id,
            model=p.req.model,
            tenant=p.req.tenant,
            geometry=p.geometry,
            num_instructions=res.num_instructions,
            metrics=dict(res.metrics),
            queue_s=t_start - p.t_submit,
            extract_s=p.extract_s,
            compute_s=t_done - t_start,
            total_s=t_done - p.t_submit,
            coalesced=p.coalesced,
        )
        if not p.future.done():
            p.future.set_result(result)

    def _fail(self, p: _Pending, err: ServeError) -> None:
        self.counters["failed"] += 1
        self._tenant(p.req.tenant)["failed"] += 1
        if not p.future.done():
            p.future.set_exception(err)

    # tao: hot
    async def _run(self) -> None:
        while True:
            p = self._next()
            if p is None:
                if self._draining:
                    break
                self._wake.clear()
                await self._wake.wait()
                continue
            for b in self._buckets.values():
                b.sample_occupancy()
            await self._dispatch(p)

    # ---- operations ------------------------------------------------------

    def set_plan(
        self, *, mesh=None, plan: Optional[ExecutionPlan] = None
    ) -> ExecutionPlan:
        """Swap the partitioning plan without a restart: subsequent
        requests resolve engines under the new plan (mesh=None and
        plan=None reverts to single-device).  In-flight requests finish
        under the plan they started with; executables for both plans
        coexist in the step cache, so flipping back is also compile-free."""
        if mesh is None and plan is None:
            self._plan = None
        else:
            self._plan = ExecutionPlan.resolve(
                mesh, batch_size=self.batch_size, plan=plan
            )
        return self._plan if self._plan is not None else ExecutionPlan.single()

    def warmup(
        self,
        trace_lengths: Iterable[int],
        models: Optional[Iterable[str]] = None,
    ) -> Dict[str, int]:
        """AOT-compile the serving executables for a declared geometry set
        (every registry model × every length) before any tenant connects.
        With the persistent compilation cache behind the store, a warm
        restart deserializes instead of compiling: a cluster-level, not
        process-level, cold start (see docs/store.md)."""
        names = list(models) if models is not None else list(self.registry.names())
        compiled = 0
        aot = 0
        for name in names:
            model = self.registry.resolve(name)
            engine = model.engine(EngineConfig(
                batch_size=self.batch_size,
                feature_backend=self.feature_backend,
                plan=self._plan,
                metrics=self.default_metrics,
            ))
            for n in sorted(set(trace_lengths)):
                entry = engine.warmup(n)
                if id(entry) not in self._step_entries:
                    self._step_entries[id(entry)] = entry
                    self._step_baseline[id(entry)] = entry.compiles
                compiled += 1
                aot += entry.aot is not None
        return {"geometries": compiled, "aot_compiled": aot}

    # ---- observability ---------------------------------------------------

    @property
    def num_compiles(self) -> int:
        """Step compiles attributable to requests served by THIS server
        (0 on a warm server — the multi-tenant one-compile guarantee)."""
        return sum(
            e.compiles - self._step_baseline[i]
            for i, e in self._step_entries.items()
        )

    @staticmethod
    def _pct(samples, q: float) -> float:
        return float(np.percentile(np.asarray(samples), q)) if samples else 0.0

    def stats(self) -> ServerStats:
        uptime = (
            time.perf_counter() - self._started_at
            if self._started_at is not None
            else 0.0
        )
        per_geo: Dict[str, Dict] = {}
        for b in self._buckets.values():
            g = per_geo.setdefault(b.label, {
                "queued": 0, "served": 0, "fill_sum": 0.0,
                "occ_max": 0, "occ_n": 0, "occ_sum": 0,
            })
            g["queued"] += b.depth()
            g["served"] += b.served
            g["fill_sum"] += b.fill_sum
            g["occ_sum"] += b.occ_sum
            g["occ_n"] += b.occ_n
            g["occ_max"] = max(g["occ_max"], b.occ_max)
        for g in per_geo.values():
            fill_sum = g.pop("fill_sum")
            occ_sum, occ_n = g.pop("occ_sum"), g.pop("occ_n")
            g["batch_fill_ratio"] = fill_sum / g["served"] if g["served"] else 0.0
            g["queue_occupancy_mean"] = occ_sum / occ_n if occ_n else 0.0
            g["queue_occupancy_max"] = g.pop("occ_max")
        served = self.counters["completed"]
        fills: List[float] = [
            g["batch_fill_ratio"] * g["served"]
            for g in per_geo.values() if g["served"]
        ]
        plan = self._plan if self._plan is not None else ExecutionPlan.single()
        return ServerStats(
            uptime_s=uptime,
            admitted=self.counters["admitted"],
            completed=served,
            failed=self.counters["failed"],
            rejected=self.counters["rejected"],
            queue_depth=self._depth,
            max_queue=self.max_queue,
            num_compiles=self.num_compiles,
            features_extracted=self.counters["features_extracted"],
            features_from_store=self.counters["features_from_store"],
            features_coalesced=self.counters["features_coalesced"],
            traces_per_s=served / uptime if uptime > 0 else 0.0,
            latency_p50_s=self._pct(self._lat_total, 50),
            latency_p99_s=self._pct(self._lat_total, 99),
            queue_p50_s=self._pct(self._lat_queue, 50),
            queue_p99_s=self._pct(self._lat_queue, 99),
            batch_fill_ratio=sum(fills) / served if served else 0.0,
            plan_kind=plan.kind,
            num_shards=plan.num_shards,
            per_geometry=per_geo,
            per_tenant={k: dict(v) for k, v in self._tenants.items()},
        )
