"""Typed request/response surface of the trace server.

The wire contract in one place: what a client submits (``ServeRequest``),
what it gets back (``ServeResult``), what an operator scrapes
(``ServerStats``), and the only exception a server lets escape
(``ServeError`` — every internal failure maps to one of its stable codes,
so engine internals never leak to tenants).  All response types have a
``to_dict()`` that is ``json.dumps``-clean; the TCP front-end
(``repro.launch.serve``) and any future HTTP shim serialize exactly these
dicts.

Functional traces are structured NumPy arrays; ``encode_trace`` /
``decode_trace`` round-trip them through JSON (dtype descr + shape +
base64 payload) for clients that submit raw traces over the wire.
"""
from __future__ import annotations

import base64
import dataclasses
from typing import Any, Dict, Optional, Tuple

import numpy as np

__all__ = [
    "ERROR_CODES",
    "ServeError",
    "ServeRequest",
    "ServeResult",
    "ServerStats",
    "decode_trace",
    "encode_trace",
]


# ---------------------------------------------------------------------------
# Wire codec for functional traces (structured arrays)
# ---------------------------------------------------------------------------


def encode_trace(arr: np.ndarray) -> Dict[str, Any]:
    """A functional trace as a JSON-clean dict (descr + shape + base64)."""
    arr = np.ascontiguousarray(arr)
    if arr.dtype.names:
        dtype: Any = [list(x) for x in arr.dtype.descr]
    else:
        dtype = arr.dtype.str
    return {
        "dtype": dtype,
        "shape": list(arr.shape),
        "data": base64.b64encode(arr.tobytes()).decode("ascii"),
    }


def decode_trace(payload: Dict[str, Any]) -> np.ndarray:
    """Inverse of :func:`encode_trace`."""
    rec = payload["dtype"]
    dtype = np.dtype([tuple(x) for x in rec] if isinstance(rec, list) else rec)
    raw = base64.b64decode(payload["data"])
    shape = tuple(payload["shape"])
    expect = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
    if len(raw) != expect:
        raise ValueError(
            f"trace payload is {len(raw)} bytes, expected {expect} for "
            f"dtype={dtype} shape={shape}"
        )
    return np.frombuffer(raw, dtype=dtype).reshape(shape).copy()


# ---------------------------------------------------------------------------
# Errors: the stable failure surface
# ---------------------------------------------------------------------------

# Every way a request can fail, as a closed vocabulary.  Codes — not
# exception reprs — are the tenant-visible contract:
#   QUEUE_FULL          admission queue at capacity (back off retry_after_s)
#   UNKNOWN_MODEL       model name not in the registry
#   BAD_REQUEST         malformed request (empty trace, unknown metric, ...)
#   GEOMETRY_MISMATCH   trace/batch geometry the server's plan cannot run
#   METRIC_NOT_COMPUTED requested metric absent from the run's spec set
#   METRIC_NOT_COLLECTED per-instruction array kept on device
#   SHUTTING_DOWN       server draining; request not admitted
#   DEADLINE_EXCEEDED   the per-request deadline elapsed before completion
#   TRACE_REJECTED      trace quarantined: it deterministically poisons a
#                       batch (bisection isolated it; resubmits are shed)
#   CIRCUIT_OPEN        the model/geometry breaker is open; shed with
#                       retry_after_s instead of queueing doomed work
#   INTERNAL            anything else (detail stays in server logs)
ERROR_CODES = (
    "QUEUE_FULL",
    "UNKNOWN_MODEL",
    "BAD_REQUEST",
    "GEOMETRY_MISMATCH",
    "METRIC_NOT_COMPUTED",
    "METRIC_NOT_COLLECTED",
    "SHUTTING_DOWN",
    "DEADLINE_EXCEEDED",
    "TRACE_REJECTED",
    "CIRCUIT_OPEN",
    "INTERNAL",
)


class ServeError(Exception):
    """The one exception a server surfaces to clients.

    ``code`` is from :data:`ERROR_CODES`; ``retry_after_s`` is set on
    QUEUE_FULL rejections (the 429-style backoff hint).  ``to_dict()``
    is the wire form.
    """

    def __init__(
        self,
        code: str,
        message: str,
        *,
        retry_after_s: Optional[float] = None,
        request_id: Optional[str] = None,
    ):
        if code not in ERROR_CODES:
            raise ValueError(f"unknown ServeError code {code!r}")
        super().__init__(f"{code}: {message}")
        self.code = code
        self.message = message
        self.retry_after_s = retry_after_s
        self.request_id = request_id

    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "error": self.code,
            "message": self.message,
        }
        if self.retry_after_s is not None:
            out["retry_after_s"] = round(float(self.retry_after_s), 6)
        if self.request_id is not None:
            out["request_id"] = self.request_id
        return out

    @classmethod
    def wrap(cls, exc: BaseException, request_id: Optional[str] = None) -> "ServeError":
        """Map an arbitrary internal exception onto the stable surface.
        Unrecognized exception types become INTERNAL with a generic
        message — tracebacks and engine internals never reach a tenant."""
        # local import: engine pulls in jax; keep types importable alone
        from ..engine.runner import (
            MetricNotCollectedError,
            MetricNotComputedError,
        )

        if isinstance(exc, ServeError):
            return exc
        if isinstance(exc, MetricNotCollectedError):
            return cls("METRIC_NOT_COLLECTED", str(exc), request_id=request_id)
        if isinstance(exc, MetricNotComputedError):
            return cls("METRIC_NOT_COMPUTED", str(exc), request_id=request_id)
        return cls(
            "INTERNAL",
            f"internal server error ({type(exc).__name__})",
            request_id=request_id,
        )


# ---------------------------------------------------------------------------
# Request / response
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServeRequest:
    """One tenant's ask: simulate ``trace`` under registry model ``model``.

    ``trace`` is a functional trace array or a ``repro.api.Trace`` (whose
    content digest then feeds the server's same-trace coalescing without a
    re-hash).  ``metrics=None`` means the server's default spec set —
    sticking to it keeps the request inside the warm executable pool;
    bespoke tuples are honored but compile their own step on first use.
    """

    model: str
    trace: Any                          # np.ndarray | repro.api.Trace
    tenant: str = "default"
    metrics: Optional[Tuple] = None     # names / MetricSpec instances
    request_id: Optional[str] = None    # assigned at admission when None
    # per-request deadline (seconds from admission; None = the server's
    # default).  Past it the request fails DEADLINE_EXCEEDED — whether it
    # is still queued or hung on the device.
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class ServeResult:
    """What a completed request returns: the metrics plus where the time
    went (queue wait vs feature prep vs device compute) and whether the
    feature pre-pass was shared with another request (``coalesced``)."""

    request_id: str
    model: str
    tenant: str
    geometry: str                       # bucket label, e.g. "w9b8"
    num_instructions: int
    metrics: Dict[str, Any]             # scalars + phase-curve arrays
    queue_s: float
    compute_s: float
    total_s: float
    extract_s: float = 0.0
    coalesced: bool = False

    def to_dict(self) -> Dict[str, Any]:
        metrics = {
            k: (np.asarray(v).tolist() if isinstance(v, np.ndarray) else v)
            for k, v in self.metrics.items()
        }
        return {
            "request_id": self.request_id,
            "model": self.model,
            "tenant": self.tenant,
            "geometry": self.geometry,
            "num_instructions": self.num_instructions,
            "metrics": metrics,
            "queue_s": round(self.queue_s, 6),
            "extract_s": round(self.extract_s, 6),
            "compute_s": round(self.compute_s, 6),
            "total_s": round(self.total_s, 6),
            "coalesced": self.coalesced,
        }


@dataclasses.dataclass
class ServerStats:
    """Point-in-time observability snapshot (``TraceServer.stats()``).

    ``per_geometry`` keys are bucket labels; each value carries the
    bucket's current queue occupancy, served count, and mean batch fill
    ratio (real windows / padded batch slots — 1.0 means every executable
    launch was full).  Latency percentiles are over a bounded window of
    recent completions.

    Degradation is observable, not silent: ``retries`` (transient-failure
    redispatches), ``deadline_exceeded``, ``quarantined`` (poison traces
    isolated by batch bisection), ``bisections`` (split rounds run),
    ``breaker_sheds`` (admissions refused by an open circuit), and
    ``breakers`` (per ``model/geometry`` breaker snapshots) count every
    resilience action the server took.
    """

    uptime_s: float
    admitted: int
    completed: int
    failed: int
    rejected: int
    queue_depth: int
    max_queue: int
    num_compiles: int
    features_extracted: int
    features_from_store: int
    features_coalesced: int
    traces_per_s: float
    latency_p50_s: float
    latency_p99_s: float
    queue_p50_s: float
    queue_p99_s: float
    batch_fill_ratio: float
    plan_kind: str
    num_shards: int
    retries: int
    deadline_exceeded: int
    quarantined: int
    bisections: int
    breaker_sheds: int
    breakers: Dict[str, Dict[str, Any]]
    per_geometry: Dict[str, Dict[str, Any]]
    per_tenant: Dict[str, Dict[str, int]]

    def to_dict(self) -> Dict[str, Any]:
        out = dataclasses.asdict(self)
        for k, v in out.items():
            if isinstance(v, float):
                out[k] = round(v, 6)
        return out
