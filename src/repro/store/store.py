"""Content-addressed artifact store: capture once per *cluster*, not per
process.

Grown out of ``repro.ckpt``: checkpoints answer "restore MY latest state",
the store answers "has ANYONE already computed this object?" — captured
functional traces, extracted ``FeatureSet``s, detailed-sim summaries, and
trained params, addressed by blake2b content keys (``store.content``)
derived from what the object is a pure function of (trace digest × feature
config × µarch config × training recipe).  A second process re-running a
sweep against a warm store does zero feature extraction and zero detailed
simulation; paired with the JAX persistent compilation cache
(``engine.aot``) it also does zero XLA compiles.

Layout (all under one root, safe to blow away wholesale):

    <root>/objects/<kind>/<key[:2]>/<key>/   one entry: manifest.json +
                                             arr_*.bin (ckpt typed-path
                                             format, template-free)
    <root>/tmp/                              unique staging dirs
    <root>/xla/                              JAX persistent compilation
                                             cache (when a Session enables
                                             it; managed by jax itself)

Concurrency and crash safety: entries are immutable once published.  A put
stages into ``tmp/<key>-<pid>-<nonce>`` and publishes with one
``os.rename`` — readers never observe a partial entry, and two processes
racing the same key resolve to whichever rename wins (identical content
either way).  A torn write from a hard kill leaves either an orphan in
``tmp/`` (swept by ``gc``) or an entry without a manifest / with a
truncated array file — ``get`` treats any load failure as a miss, deletes
the entry, and counts it in ``stats()["corrupt_dropped"]``.

Eviction: entries carry their last-use time (directory mtime, refreshed on
every hit); ``gc(max_bytes=..., max_age_s=...)`` drops least-recently-used
entries past the byte budget and anything older than the age bound.  A
store constructed with ``max_bytes=`` self-GCs after each put.
"""
from __future__ import annotations

import os
import shutil
import time
from typing import Any, Dict, List, Optional, Tuple

from ..ckpt.checkpoint import load_array_tree, write_array_tree

__all__ = ["ArtifactStore", "features_to_tree", "tree_to_features"]


def features_to_tree(fs) -> Dict[str, Any]:
    """A ``FeatureSet`` as the plain nested dict the store serializes
    (``labels`` key absent when None — typed-path trees cannot hold
    None leaves)."""
    tree = {
        "opcode": fs.opcode,
        "regbits": fs.regbits,
        "flags": fs.flags,
        "brhist": fs.brhist,
        "memdist": fs.memdist,
    }
    if fs.labels is not None:
        tree["labels"] = dict(fs.labels)
    return tree


def tree_to_features(tree: Dict[str, Any]):
    """Inverse of :func:`features_to_tree`."""
    from ..core.features import FeatureSet  # lazy: keep store import light

    return FeatureSet(
        opcode=tree["opcode"],
        regbits=tree["regbits"],
        flags=tree["flags"],
        brhist=tree["brhist"],
        memdist=tree["memdist"],
        labels=tree.get("labels"),
    )


class ArtifactStore:
    """Content-addressed object cache under one filesystem root."""

    def __init__(self, root: str, *, max_bytes: Optional[int] = None):
        self.root = os.path.abspath(os.path.expanduser(root))
        self.max_bytes = max_bytes
        os.makedirs(os.path.join(self.root, "objects"), exist_ok=True)
        os.makedirs(os.path.join(self.root, "tmp"), exist_ok=True)
        self.counters: Dict[str, int] = {
            "hits": 0,
            "misses": 0,
            "puts": 0,
            "put_races": 0,
            "corrupt_dropped": 0,
            "evicted": 0,
        }
        self._nonce = 0

    @property
    def xla_cache_dir(self) -> str:
        """Where a Session points the JAX persistent compilation cache so
        executables and artifacts travel (and GC) together."""
        return os.path.join(self.root, "xla")

    # ---- paths -----------------------------------------------------------

    def _entry_dir(self, kind: str, key: str) -> str:
        return os.path.join(self.root, "objects", kind, key[:2], key)

    def _stage_dir(self, key: str) -> str:
        self._nonce += 1
        return os.path.join(
            self.root, "tmp", f"{key}-{os.getpid()}-{self._nonce}"
        )

    # ---- core API --------------------------------------------------------

    def has(self, kind: str, key: str) -> bool:
        return os.path.exists(
            os.path.join(self._entry_dir(kind, key), "manifest.json")
        )

    def put(self, kind: str, key: str, tree: Any, extra: Optional[Dict] = None) -> bool:
        """Publish an entry (no-op when the key already exists — entries
        are immutable and content-addressed, so identical by construction).
        Returns True when this call created the entry."""
        dst = self._entry_dir(kind, key)
        if self.has(kind, key):
            return False
        stage = self._stage_dir(key)
        write_array_tree(tree, stage, extra)
        os.makedirs(os.path.dirname(dst), exist_ok=True)
        try:
            os.rename(stage, dst)
        except OSError:
            # lost a publish race with another process — their content is
            # byte-identical (same key), keep theirs
            shutil.rmtree(stage, ignore_errors=True)
            self.counters["put_races"] += 1
            return False
        self.counters["puts"] += 1
        if self.max_bytes is not None:
            self.gc(max_bytes=self.max_bytes)
        return True

    def get(self, kind: str, key: str) -> Optional[Tuple[Any, Dict]]:
        """``(tree, extra)`` for a published entry, or None.  Any load
        failure (partial write, bit rot, format drift) quarantines the
        entry and reports a miss — the caller recomputes and re-puts."""
        path = self._entry_dir(kind, key)
        if not os.path.exists(path):
            self.counters["misses"] += 1
            return None
        try:
            tree, extra = load_array_tree(path)
        except Exception:
            shutil.rmtree(path, ignore_errors=True)
            self.counters["corrupt_dropped"] += 1
            self.counters["misses"] += 1
            return None
        self.counters["hits"] += 1
        try:
            os.utime(path)  # LRU clock for gc()
        except OSError:
            pass
        return tree, extra

    # ---- maintenance -----------------------------------------------------

    def _entries(self) -> List[Tuple[str, int, float]]:
        """(entry_dir, bytes, last_use) for every published entry."""
        out = []
        obj_root = os.path.join(self.root, "objects")
        for kind in sorted(os.listdir(obj_root)):
            kdir = os.path.join(obj_root, kind)
            for prefix in sorted(os.listdir(kdir)):
                pdir = os.path.join(kdir, prefix)
                for key in sorted(os.listdir(pdir)):
                    edir = os.path.join(pdir, key)
                    try:
                        size = sum(
                            e.stat().st_size
                            for e in os.scandir(edir)
                            if e.is_file()
                        )
                        out.append((edir, size, os.stat(edir).st_mtime))
                    except OSError:
                        continue
        return out

    def stats(self) -> Dict[str, Any]:
        entries = self._entries()
        return {
            "root": self.root,
            "entries": len(entries),
            "bytes": sum(sz for _, sz, _ in entries),
            **self.counters,
        }

    def gc(
        self,
        *,
        max_bytes: Optional[int] = None,
        max_age_s: Optional[float] = None,
    ) -> Dict[str, int]:
        """Drop stale tmp dirs, then entries: first anything unused for
        longer than ``max_age_s``, then least-recently-used entries until
        the total is within ``max_bytes``."""
        dropped = 0
        tmp_root = os.path.join(self.root, "tmp")
        now = time.time()
        for name in os.listdir(tmp_root):
            p = os.path.join(tmp_root, name)
            try:
                if now - os.stat(p).st_mtime > 3600:  # torn writes only
                    shutil.rmtree(p, ignore_errors=True)
            except OSError:
                continue

        entries = sorted(self._entries(), key=lambda e: e[2])  # LRU first
        total = sum(sz for _, sz, _ in entries)
        keep = []
        for edir, size, mtime in entries:
            if max_age_s is not None and now - mtime > max_age_s:
                shutil.rmtree(edir, ignore_errors=True)
                total -= size
                dropped += 1
            else:
                keep.append((edir, size, mtime))
        if max_bytes is not None:
            for edir, size, _ in keep:
                if total <= max_bytes:
                    break
                shutil.rmtree(edir, ignore_errors=True)
                total -= size
                dropped += 1
        self.counters["evicted"] += dropped
        return {"evicted": dropped, "bytes": total}
