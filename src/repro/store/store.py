"""Content-addressed artifact store: capture once per *cluster*, not per
process.

Grown out of ``repro.ckpt``: checkpoints answer "restore MY latest state",
the store answers "has ANYONE already computed this object?" — captured
functional traces, extracted ``FeatureSet``s, detailed-sim summaries, and
trained params, addressed by blake2b content keys (``store.content``)
derived from what the object is a pure function of (trace digest × feature
config × µarch config × training recipe).  A second process re-running a
sweep against a warm store does zero feature extraction and zero detailed
simulation; paired with the JAX persistent compilation cache
(``engine.aot``) it also does zero XLA compiles.

Layout (all under one root, safe to blow away wholesale):

    <root>/objects/<kind>/<key[:2]>/<key>/   one entry: manifest.json +
                                             arr_*.bin (ckpt typed-path
                                             format, template-free)
    <root>/tmp/                              unique staging dirs
    <root>/xla/                              JAX persistent compilation
                                             cache (when a Session enables
                                             it; managed by jax itself)

Concurrency and crash safety: entries are immutable once published.  A put
stages into ``tmp/<key>-<pid>-<nonce>`` and publishes with one
``os.rename`` — readers never observe a partial entry, and two processes
racing the same key resolve to whichever rename wins (identical content
either way).  A torn write from a hard kill leaves either an orphan in
``tmp/`` (swept by ``gc``) or an entry without a manifest / with a
truncated array file — ``get`` treats any load failure as a miss, deletes
the entry, and counts it in ``stats()["corrupt_dropped"]``.

Eviction: entries carry their last-use time (directory mtime, refreshed on
every hit); ``gc(max_bytes=..., max_age_s=...)`` drops least-recently-used
entries past the byte budget and anything older than the age bound.  A
store constructed with ``max_bytes=`` self-GCs after each put.

Pinning: a reader that must not lose an entry mid-stream (a serving
process loading registry params, ``get`` itself while deserializing)
drops a ``.pin-<pid>-<nonce>`` marker file into the entry dir; ``gc`` —
in this or ANY process sharing the root — skips entries that hold a pin
from a live pid, and sweeps markers whose pid is gone.  ``get`` pins
implicitly for the duration of the load, so age/LRU eviction racing a
read can no longer delete the files out from under the deserializer;
``pin(kind, key)`` is the public context manager for longer holds.
"""
from __future__ import annotations

import contextlib
import json
import os
import shutil
import time
from typing import Any, Dict, Iterator, List, Optional, Tuple

from ..ckpt.checkpoint import load_array_tree, write_array_tree
from ..resilience.faults import fault_point

__all__ = ["ArtifactStore", "features_to_tree", "tree_to_features"]

_PIN_PREFIX = ".pin-"


class _PinLease:
    """One held pin marker.  Truthy when the marker landed (the entry
    existed at pin time).  ``release()`` is idempotent: an explicit
    release followed by the context-manager exit (or any double-unpin)
    is a no-op, never an unlink of a namesake marker."""

    __slots__ = ("path", "pinned")

    def __init__(self, path: str, pinned: bool):
        self.path = path
        self.pinned = pinned

    def __bool__(self) -> bool:
        return self.pinned

    def release(self) -> None:
        if not self.pinned:
            return
        self.pinned = False
        try:
            os.unlink(self.path)
        except OSError:
            pass


def features_to_tree(fs) -> Dict[str, Any]:
    """A ``FeatureSet`` as the plain nested dict the store serializes
    (``labels`` key absent when None — typed-path trees cannot hold
    None leaves)."""
    tree = {
        "opcode": fs.opcode,
        "regbits": fs.regbits,
        "flags": fs.flags,
        "brhist": fs.brhist,
        "memdist": fs.memdist,
    }
    if fs.labels is not None:
        tree["labels"] = dict(fs.labels)
    return tree


def tree_to_features(tree: Dict[str, Any]):
    """Inverse of :func:`features_to_tree`."""
    from ..core.features import FeatureSet  # lazy: keep store import light

    return FeatureSet(
        opcode=tree["opcode"],
        regbits=tree["regbits"],
        flags=tree["flags"],
        brhist=tree["brhist"],
        memdist=tree["memdist"],
        labels=tree.get("labels"),
    )


class ArtifactStore:
    """Content-addressed object cache under one filesystem root."""

    def __init__(self, root: str, *, max_bytes: Optional[int] = None):
        self.root = os.path.abspath(os.path.expanduser(root))
        self.max_bytes = max_bytes
        os.makedirs(os.path.join(self.root, "objects"), exist_ok=True)
        os.makedirs(os.path.join(self.root, "tmp"), exist_ok=True)
        self.counters: Dict[str, int] = {
            "hits": 0,
            "misses": 0,
            "puts": 0,
            "put_races": 0,
            "corrupt_dropped": 0,
            "evicted": 0,
            "gc_pin_skips": 0,
            "stale_pins_swept": 0,
        }
        self._nonce = 0

    @property
    def xla_cache_dir(self) -> str:
        """Where a Session points the JAX persistent compilation cache so
        executables and artifacts travel (and GC) together."""
        return os.path.join(self.root, "xla")

    # ---- paths -----------------------------------------------------------

    def _entry_dir(self, kind: str, key: str) -> str:
        return os.path.join(self.root, "objects", kind, key[:2], key)

    def _stage_dir(self, key: str) -> str:
        self._nonce += 1
        return os.path.join(
            self.root, "tmp", f"{key}-{os.getpid()}-{self._nonce}"
        )

    # ---- pinning ---------------------------------------------------------

    @contextlib.contextmanager
    def pin(self, kind: str, key: str):
        """Hold a read-lock on one entry: while the context is open, no
        ``gc`` sharing this root (any process on this host) will evict it.
        Yields a truthy ``_PinLease`` when the pin landed, a falsy one
        when the entry does not exist (already evicted / never published)
        — the caller recomputes.  The lease's ``release()`` may be called
        early (and repeatedly: it is idempotent, so the context exit after
        an explicit release is a no-op).  Pins are advisory markers tied
        to this pid; a crash leaves a stale marker that the next ``gc``
        sweeps once the pid is gone."""
        self._nonce += 1
        pinfile = os.path.join(
            self._entry_dir(kind, key),
            f"{_PIN_PREFIX}{os.getpid()}-{self._nonce}",
        )
        try:
            open(pinfile, "x").close()
            pinned = True
        except OSError:  # entry dir vanished (or pinfile collision)
            pinned = False
        lease = _PinLease(pinfile, pinned)
        try:
            yield lease
        finally:
            lease.release()

    @staticmethod
    def _pid_alive(pid: int) -> bool:
        try:
            os.kill(pid, 0)
        except ProcessLookupError:
            return False
        except OSError:  # EPERM etc.: someone else's live process
            return True
        return True

    def _sweep_stale_pins(self, edir: str) -> Tuple[bool, int]:
        """``(any live pin, stale markers removed)`` for one entry dir.
        Markers from dead pids (readers that were SIGKILLed mid-hold) are
        unlinked; anything unparseable is treated as stale too."""
        live, swept = False, 0
        try:
            names = os.listdir(edir)
        except OSError:
            return False, 0
        for name in names:
            if not name.startswith(_PIN_PREFIX):
                continue
            try:
                pid = int(name[len(_PIN_PREFIX):].split("-", 1)[0])
            except ValueError:
                pid = -1
            if pid > 0 and self._pid_alive(pid):
                live = True
            else:
                try:
                    os.unlink(os.path.join(edir, name))
                    swept += 1
                except OSError:
                    pass
        return live, swept

    def _has_live_pin(self, edir: str) -> bool:
        """True when any pin marker in the entry belongs to a live pid;
        markers from dead pids are swept as a side effect."""
        return self._sweep_stale_pins(edir)[0]

    # ---- core API --------------------------------------------------------

    def has(self, kind: str, key: str) -> bool:
        return os.path.exists(
            os.path.join(self._entry_dir(kind, key), "manifest.json")
        )

    def put(self, kind: str, key: str, tree: Any, extra: Optional[Dict] = None) -> bool:
        """Publish an entry (no-op when the key already exists — entries
        are immutable and content-addressed, so identical by construction).
        Returns True when this call created the entry."""
        dst = self._entry_dir(kind, key)
        if self.has(kind, key):
            return False
        stage = self._stage_dir(key)
        write_array_tree(tree, stage, extra)
        os.makedirs(os.path.dirname(dst), exist_ok=True)
        try:
            os.rename(stage, dst)
        except OSError:
            # lost a publish race with another process — their content is
            # byte-identical (same key), keep theirs
            shutil.rmtree(stage, ignore_errors=True)
            self.counters["put_races"] += 1
            return False
        self.counters["puts"] += 1
        if self.max_bytes is not None:
            self.gc(max_bytes=self.max_bytes)
        return True

    def get(self, kind: str, key: str) -> Optional[Tuple[Any, Dict]]:
        """``(tree, extra)`` for a published entry, or None.  Any load
        failure (partial write, bit rot, format drift) quarantines the
        entry and reports a miss — the caller recomputes and re-puts."""
        path = self._entry_dir(kind, key)
        if not os.path.exists(path):
            self.counters["misses"] += 1
            return None
        # pin for the duration of the load: a concurrent gc (this or any
        # other process on the root) cannot delete the files mid-read.
        # pinned=False means the entry vanished between exists() and the
        # pin — an ordinary miss, not corruption.
        with self.pin(kind, key) as pinned:
            if not pinned:
                self.counters["misses"] += 1
                return None
            try:
                fault_point("store.load", payload=key)
                tree, extra = load_array_tree(path)
            except Exception:
                shutil.rmtree(path, ignore_errors=True)
                self.counters["corrupt_dropped"] += 1
                self.counters["misses"] += 1
                return None
        self.counters["hits"] += 1
        try:
            os.utime(path)  # LRU clock for gc()
        except OSError:
            pass
        return tree, extra

    def delete(self, kind: str, key: str) -> bool:
        """Explicitly drop one entry (e.g. a registry name being
        re-published).  Returns True when something was removed.  Unlike
        gc this ignores pins — an explicit delete is an operator decision,
        not cache pressure."""
        path = self._entry_dir(kind, key)
        if not os.path.exists(path):
            return False
        shutil.rmtree(path, ignore_errors=True)
        return True

    def list_extras(self, kind: str) -> Iterator[Tuple[str, Dict]]:
        """Yield ``(key, extra)`` for every published entry of ``kind``,
        reading only the manifests (no array payloads) — how the model
        registry enumerates published names from a content-addressed
        namespace."""
        kdir = os.path.join(self.root, "objects", kind)
        if not os.path.isdir(kdir):
            return
        for prefix in sorted(os.listdir(kdir)):
            pdir = os.path.join(kdir, prefix)
            for key in sorted(os.listdir(pdir)):
                try:
                    with open(os.path.join(pdir, key, "manifest.json")) as f:
                        yield key, json.load(f).get("extra", {})
                except (OSError, ValueError):
                    continue

    # ---- maintenance -----------------------------------------------------

    def _entries(self) -> List[Tuple[str, int, float]]:
        """(entry_dir, bytes, last_use) for every published entry."""
        out = []
        obj_root = os.path.join(self.root, "objects")
        for kind in sorted(os.listdir(obj_root)):
            kdir = os.path.join(obj_root, kind)
            for prefix in sorted(os.listdir(kdir)):
                pdir = os.path.join(kdir, prefix)
                for key in sorted(os.listdir(pdir)):
                    edir = os.path.join(pdir, key)
                    try:
                        size = sum(
                            e.stat().st_size
                            for e in os.scandir(edir)
                            if e.is_file()
                        )
                        out.append((edir, size, os.stat(edir).st_mtime))
                    except OSError:
                        continue
        return out

    def stats(self) -> Dict[str, Any]:
        entries = self._entries()
        return {
            "root": self.root,
            "entries": len(entries),
            "bytes": sum(sz for _, sz, _ in entries),
            **self.counters,
        }

    def gc(
        self,
        *,
        max_bytes: Optional[int] = None,
        max_age_s: Optional[float] = None,
    ) -> Dict[str, int]:
        """Drop stale tmp dirs and dead-pid pin markers, then entries:
        first anything unused for longer than ``max_age_s``, then
        least-recently-used entries until the total is within
        ``max_bytes``."""
        dropped = 0
        tmp_root = os.path.join(self.root, "tmp")
        now = time.time()
        for name in os.listdir(tmp_root):
            p = os.path.join(tmp_root, name)
            try:
                if now - os.stat(p).st_mtime > 3600:  # torn writes only
                    shutil.rmtree(p, ignore_errors=True)
            except OSError:
                continue

        entries = sorted(self._entries(), key=lambda e: e[2])  # LRU first
        # sweep dead-pid pin markers over EVERY entry, not just the ones
        # under eviction pressure — a pin left by a SIGKILLed reader must
        # not outlive the next gc regardless of cache size or entry age
        stale = 0
        for edir, _, _ in entries:
            stale += self._sweep_stale_pins(edir)[1]
        self.counters["stale_pins_swept"] += stale
        total = sum(sz for _, sz, _ in entries)
        keep = []
        for edir, size, mtime in entries:
            if max_age_s is not None and now - mtime > max_age_s:
                if self._has_live_pin(edir):  # a reader is streaming it
                    self.counters["gc_pin_skips"] += 1
                    keep.append((edir, size, mtime))
                    continue
                shutil.rmtree(edir, ignore_errors=True)
                total -= size
                dropped += 1
            else:
                keep.append((edir, size, mtime))
        if max_bytes is not None:
            for edir, size, _ in keep:
                if total <= max_bytes:
                    break
                if self._has_live_pin(edir):
                    self.counters["gc_pin_skips"] += 1
                    continue
                shutil.rmtree(edir, ignore_errors=True)
                total -= size
                dropped += 1
        self.counters["evicted"] += dropped
        return {"evicted": dropped, "bytes": total, "stale_pins": stale}
