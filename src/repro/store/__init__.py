"""Content-addressed artifact store (traces, features, params) — see
``store.store`` for layout/atomicity and ``store.content`` for the
identity scheme shared with the sweep scheduler's feature dedup."""
from .content import (
    DIGEST_BYTES,
    array_digest,
    config_token,
    content_key,
    tree_digest,
)
from .store import ArtifactStore, features_to_tree, tree_to_features

__all__ = [
    "ArtifactStore",
    "DIGEST_BYTES",
    "array_digest",
    "config_token",
    "content_key",
    "features_to_tree",
    "tree_digest",
    "tree_to_features",
]
