"""Content addressing for the artifact store.

One identity scheme for everything the store holds: a blake2b digest over
the *content* of an object (arrays hashed as dtype + shape + raw bytes,
configs as a canonical recursive token), never over object identity or
repr strings.  The scheduler's per-trace feature dedup, ``Trace.digest`` /
``FeatureSet.digest``, and the store's on-disk keys all derive from here,
so the same trace observed by any of them maps to the same key.

This module is deliberately dependency-free (hashlib / numpy /
dataclasses only): it is imported from ``core.features`` and
``api.session``, and pulling in jax or any ``repro`` package here would
re-open the import cycle documented in ``engine/runner.py``.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Tuple

import numpy as np

__all__ = [
    "DIGEST_BYTES",
    "array_digest",
    "config_token",
    "content_key",
    "tree_digest",
]

# blake2b digest width — matches iter_window_digests (core/dataset.py),
# which pins 16 bytes as plenty for dedup at any realistic trace count.
DIGEST_BYTES = 16


def array_digest(arr: np.ndarray) -> str:
    """Stable hex digest of an array's dtype, shape, and raw bytes.

    Works for structured arrays (functional traces) and ml_dtypes arrays
    (bf16 params) alike: the dtype enters the hash via ``np.dtype.str`` /
    ``descr`` so e.g. an int32 and a float32 view of the same bytes get
    different digests.
    """
    arr = np.asarray(arr)
    h = hashlib.blake2b(digest_size=DIGEST_BYTES)
    if arr.dtype.names:  # structured dtype: .str is opaque ("|V35")
        h.update(repr(arr.dtype.descr).encode())
    else:
        h.update(arr.dtype.str.encode())
    h.update(repr(arr.shape).encode())
    h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def tree_digest(tree: Any) -> str:
    """Digest of a nested dict/list/tuple pytree of arrays (params trees).

    Structure and leaf positions are part of the hash; device arrays are
    pulled to host via ``np.asarray``.
    """
    h = hashlib.blake2b(digest_size=DIGEST_BYTES)

    def walk(node, path):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(node[k], path + (("k", k),))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, path + (("i", i),))
        elif node is None:
            h.update(repr((path, None)).encode())
        else:
            h.update(repr(path).encode())
            h.update(array_digest(node).encode())

    walk(tree, ())
    return h.hexdigest()


def config_token(obj: Any) -> Tuple:
    """Canonical, hashable, order-stable token of a config-like value.

    Recurses through dataclasses (field order), dicts (sorted keys),
    tuples/lists; arrays collapse to their ``array_digest``.  The token is
    what ``content_key`` serializes, so two configs compare equal iff
    their tokens do — object identity and repr formatting never leak in.
    """
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return (
            "dc",
            type(obj).__name__,
            tuple(
                (f.name, config_token(getattr(obj, f.name)))
                for f in dataclasses.fields(obj)
            ),
        )
    if isinstance(obj, dict):
        return ("d", tuple((k, config_token(v)) for k, v in sorted(obj.items())))
    if isinstance(obj, (tuple, list)):
        return ("t", tuple(config_token(v) for v in obj))
    if isinstance(obj, np.ndarray):
        return ("nd", array_digest(obj))
    if isinstance(obj, (str, bytes, bool, type(None))):
        return obj
    if isinstance(obj, (int, np.integer)):
        return int(obj)
    if isinstance(obj, (float, np.floating)):
        # repr round-trips float64 exactly; avoids 0.1 vs 0.1000...01 drift
        return ("f", repr(float(obj)))
    raise TypeError(
        f"config_token: cannot canonicalize {type(obj).__name__!r} — "
        "pass dataclasses, dicts, sequences, arrays, or primitives"
    )


def content_key(kind: str, *parts: Any) -> str:
    """The store key for an object: blake2b over (kind, token(parts)).

    ``kind`` namespaces the key ("trace", "features", "params", ...) so
    identical payload tokens of different kinds never collide.
    """
    h = hashlib.blake2b(digest_size=DIGEST_BYTES)
    h.update(repr((kind, tuple(config_token(p) for p in parts))).encode())
    return h.hexdigest()
