"""Functional simulator — the AtomicSimpleCPU analogue.

Executes a Program architecturally (no timing, no speculation) and emits the
µarch-agnostic functional trace Tao consumes.  This is the fast path: the
paper measures functional trace generation at ~25x the throughput of detailed
trace generation, a ratio our benchmark harness re-validates on this
substrate.
"""
from __future__ import annotations

import numpy as np

from .isa import FUNC_TRACE_DTYPE, Op
from .program import PC_STRIDE, Program

__all__ = ["run_functional"]

_WORD = 8  # bytes per memory word; trace addresses are byte addresses


def run_functional(program: Program, max_instructions: int) -> np.ndarray:
    """Run `program` for up to `max_instructions` committed instructions.

    Returns a structured array with FUNC_TRACE_DTYPE.  Execution wraps to the
    entry point if the program runs off the end (benchmarks are loop-shaped,
    so this models re-invoking the kernel, keeping traces arbitrarily long).
    """
    code = program.code
    n_static = len(code)
    regs = program.init_regs.astype(np.int64).copy()
    mem = program.init_mem.astype(np.int64).copy()
    mem_words = len(mem)

    # Unpack static code into parallel arrays for speed.
    ops = np.array([int(i.op) for i in code], dtype=np.int16)
    dsts = np.array([i.dst for i in code], dtype=np.int8)
    src1s = np.array([i.src1 for i in code], dtype=np.int8)
    src2s = np.array([i.src2 for i in code], dtype=np.int8)
    imms = np.array([i.imm for i in code], dtype=np.int64)
    targets = np.array([i.target for i in code], dtype=np.int64)

    out = np.zeros(max_instructions, dtype=FUNC_TRACE_DTYPE)
    o_pc = out["pc"]
    o_op = out["opcode"]
    o_dst = out["dst"]
    o_s1 = out["src1"]
    o_s2 = out["src2"]
    o_isbr = out["is_branch"]
    o_taken = out["taken"]
    o_ismem = out["is_mem"]
    o_isst = out["is_store"]
    o_addr = out["addr"]

    OP_IALU, OP_IMUL, OP_IDIV = int(Op.IALU), int(Op.IMUL), int(Op.IDIV)
    OP_FALU, OP_FMUL, OP_FDIV = int(Op.FALU), int(Op.FMUL), int(Op.FDIV)
    OP_LOAD, OP_STORE = int(Op.LOAD), int(Op.STORE)
    OP_BEQ, OP_BNE, OP_BLT, OP_BGE = (
        int(Op.BEQ),
        int(Op.BNE),
        int(Op.BLT),
        int(Op.BGE),
    )
    OP_JMP, OP_MOVI, OP_NOP = int(Op.JMP), int(Op.MOVI), int(Op.NOP)

    MASK = (1 << 40) - 1  # keep register values bounded

    pc = program.entry
    i = 0
    while i < max_instructions:
        if pc >= n_static:
            pc = program.entry
        op = int(ops[pc])
        dst = int(dsts[pc])
        s1 = int(src1s[pc])
        s2 = int(src2s[pc])
        imm = int(imms[pc])

        o_pc[i] = pc * PC_STRIDE
        o_op[i] = op
        o_dst[i] = dst
        o_s1[i] = s1
        o_s2[i] = s2

        next_pc = pc + 1
        if op == OP_IALU:
            if dst:
                regs[dst] = (regs[s1] + regs[s2] + imm) & MASK
        elif op == OP_MOVI:
            if dst:
                regs[dst] = imm & MASK
        elif op == OP_LOAD:
            w = (regs[s1] + imm) % mem_words
            if dst:
                regs[dst] = mem[w]
            o_ismem[i] = True
            o_addr[i] = w * _WORD
        elif op == OP_STORE:
            w = (regs[s1] + imm) % mem_words
            mem[w] = regs[s2]
            o_ismem[i] = True
            o_isst[i] = True
            o_addr[i] = w * _WORD
        elif op == OP_BEQ or op == OP_BNE or op == OP_BLT or op == OP_BGE:
            a = regs[s1]
            b = regs[s2]
            if op == OP_BEQ:
                taken = a == b
            elif op == OP_BNE:
                taken = a != b
            elif op == OP_BLT:
                taken = a < b
            else:
                taken = a >= b
            o_isbr[i] = True
            o_taken[i] = taken
            if taken:
                next_pc = int(targets[pc])
        elif op == OP_JMP:
            next_pc = int(targets[pc])
        elif op == OP_IMUL:
            if dst:
                # int() avoids int64 overflow for 2^40-range operands
                regs[dst] = (int(regs[s1]) * int(regs[s2])) & MASK
        elif op == OP_IDIV:
            if dst:
                d = regs[s2]
                regs[dst] = (regs[s1] // d) & MASK if d else 0
        elif op == OP_FALU:
            if dst:
                regs[dst] = ((regs[s1] + regs[s2]) >> 1) & MASK
        elif op == OP_FMUL:
            if dst:
                regs[dst] = ((int(regs[s1]) * 3 + int(regs[s2])) >> 2) & MASK
        elif op == OP_FDIV:
            if dst:
                d = regs[s2] | 1
                regs[dst] = (regs[s1] // d) & MASK
        elif op == OP_NOP:
            pass
        else:  # pragma: no cover - unreachable with a valid Program
            raise ValueError(f"bad opcode {op}")

        pc = next_pc
        i += 1

    return out
