"""Program representation + a tiny assembler DSL for the benchmark suite.

A Program is straight-line static code with labels resolved to instruction
indices (the "pc" is the instruction index; byte PCs are pc*4 to mimic a RISC
encoding for the branch-history hash features).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from .isa import Op

PC_STRIDE = 4  # byte distance between consecutive instructions


@dataclasses.dataclass
class Instr:
    op: Op
    dst: int = 0
    src1: int = 0
    src2: int = 0
    imm: int = 0          # memory offset (words) or MOVI immediate
    target: int = -1      # branch/jump target (instruction index)


@dataclasses.dataclass
class Program:
    """Static code + initial machine state."""

    name: str
    code: List[Instr]
    init_regs: np.ndarray            # (NUM_REGS,) int64
    init_mem: np.ndarray             # (mem_words,) int64
    entry: int = 0

    @property
    def num_static(self) -> int:
        return len(self.code)

    def byte_pc(self, idx: int) -> int:
        return idx * PC_STRIDE


class ProgramBuilder:
    """Minimal assembler: emit instructions, reference labels forward."""

    def __init__(self, name: str, mem_words: int = 1 << 16, seed: int = 0):
        self.name = name
        self.code: List[Instr] = []
        self.labels: Dict[str, int] = {}
        self.fixups: List[tuple] = []  # (instr_index, label)
        self.rng = np.random.default_rng(seed)
        self.init_regs = np.zeros(32, dtype=np.int64)
        self.init_mem = np.zeros(mem_words, dtype=np.int64)

    # -- label handling ------------------------------------------------
    def label(self, name: str) -> None:
        self.labels[name] = len(self.code)

    def _emit(self, instr: Instr, label: Optional[str] = None) -> None:
        if label is not None:
            self.fixups.append((len(self.code), label))
        self.code.append(instr)

    # -- instruction emitters -------------------------------------------
    def ialu(self, dst, s1, s2):
        self._emit(Instr(Op.IALU, dst, s1, s2))

    def imul(self, dst, s1, s2):
        self._emit(Instr(Op.IMUL, dst, s1, s2))

    def idiv(self, dst, s1, s2):
        self._emit(Instr(Op.IDIV, dst, s1, s2))

    def falu(self, dst, s1, s2):
        self._emit(Instr(Op.FALU, dst, s1, s2))

    def fmul(self, dst, s1, s2):
        self._emit(Instr(Op.FMUL, dst, s1, s2))

    def fdiv(self, dst, s1, s2):
        self._emit(Instr(Op.FDIV, dst, s1, s2))

    def load(self, dst, addr_reg, off=0):
        self._emit(Instr(Op.LOAD, dst, addr_reg, 0, imm=off))

    def store(self, addr_reg, val_reg, off=0):
        self._emit(Instr(Op.STORE, 0, addr_reg, val_reg, imm=off))

    def movi(self, dst, imm):
        self._emit(Instr(Op.MOVI, dst, 0, 0, imm=int(imm)))

    def beq(self, s1, s2, label):
        self._emit(Instr(Op.BEQ, 0, s1, s2), label)

    def bne(self, s1, s2, label):
        self._emit(Instr(Op.BNE, 0, s1, s2), label)

    def blt(self, s1, s2, label):
        self._emit(Instr(Op.BLT, 0, s1, s2), label)

    def bge(self, s1, s2, label):
        self._emit(Instr(Op.BGE, 0, s1, s2), label)

    def jmp(self, label):
        self._emit(Instr(Op.JMP), label)

    def nop(self):
        self._emit(Instr(Op.NOP))

    # -- finalize --------------------------------------------------------
    def build(self) -> Program:
        for idx, label in self.fixups:
            if label not in self.labels:
                raise KeyError(f"undefined label {label!r} in {self.name}")
            self.code[idx].target = self.labels[label]
        return Program(
            name=self.name,
            code=self.code,
            init_regs=self.init_regs,
            init_mem=self.init_mem,
        )
