"""Set-associative LRU caches and a small TLB for the detailed simulator."""
from __future__ import annotations

import numpy as np

__all__ = ["Cache", "TLB"]

LINE_BYTES = 64
PAGE_BYTES = 4096


class Cache:
    """Set-associative cache with true-LRU replacement.

    Implemented with numpy tag arrays + an LRU timestamp matrix; lookups are
    O(assoc) which is plenty fast for the trace lengths we simulate.
    """

    def __init__(self, size_bytes: int, assoc: int, line_bytes: int = LINE_BYTES):
        self.line_bytes = line_bytes
        self.assoc = assoc
        # Round the set count down for capacities not divisible by assoc*line
        # (e.g. 16KB 6-way); gem5 pads instead, the difference is immaterial.
        self.num_sets = max(1, size_bytes // (assoc * line_bytes))
        self.tags = np.full((self.num_sets, assoc), -1, dtype=np.int64)
        self.lru = np.zeros((self.num_sets, assoc), dtype=np.int64)
        self._tick = 0
        self.hits = 0
        self.misses = 0

    def access(self, byte_addr: int) -> bool:
        """Access `byte_addr`; returns True on hit. Fills the line on miss."""
        line = byte_addr // self.line_bytes
        s = line % self.num_sets
        tag = line // self.num_sets
        self._tick += 1
        tags = self.tags[s]
        for w in range(self.assoc):
            if tags[w] == tag:
                self.lru[s, w] = self._tick
                self.hits += 1
                return True
        # Miss: replace LRU way.
        w = int(np.argmin(self.lru[s]))
        self.tags[s, w] = tag
        self.lru[s, w] = self._tick
        self.misses += 1
        return False


class TLB:
    """Fully-associative LRU TLB over 4KB pages."""

    def __init__(self, entries: int = 64, page_bytes: int = PAGE_BYTES):
        self.entries = entries
        self.page_bytes = page_bytes
        self.pages = np.full(entries, -1, dtype=np.int64)
        self.lru = np.zeros(entries, dtype=np.int64)
        self._tick = 0
        self.hits = 0
        self.misses = 0

    def access(self, byte_addr: int) -> bool:
        page = byte_addr // self.page_bytes
        self._tick += 1
        hit = np.nonzero(self.pages == page)[0]
        if hit.size:
            self.lru[hit[0]] = self._tick
            self.hits += 1
            return True
        w = int(np.argmin(self.lru))
        self.pages[w] = page
        self.lru[w] = self._tick
        self.misses += 1
        return False
