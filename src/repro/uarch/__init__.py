"""µarch simulation substrate: the gem5 analogue Tao's data plane requires."""
from .config import (
    DESIGN_SPACE,
    UARCH_A,
    UARCH_B,
    UARCH_C,
    MicroArchConfig,
    enumerate_design_space,
    sample_design_space,
)
from .detailed import run_detailed, summarize_detailed
from .functional import run_functional
from .programs import ALL_BENCHMARKS, TEST_BENCHMARKS, TRAIN_BENCHMARKS, get_benchmark

__all__ = [
    "MicroArchConfig",
    "DESIGN_SPACE",
    "UARCH_A",
    "UARCH_B",
    "UARCH_C",
    "enumerate_design_space",
    "sample_design_space",
    "run_functional",
    "run_detailed",
    "summarize_detailed",
    "get_benchmark",
    "ALL_BENCHMARKS",
    "TRAIN_BENCHMARKS",
    "TEST_BENCHMARKS",
]
