"""Branch predictors for the detailed simulator.

Implements the four algorithms in the paper's design space (Table 3):
Local, BiMode, Tournament, and a lightweight TAGE (TAGE_SC_L stand-in).
All predictors share the predict(pc)->bool / update(pc, taken) interface and
keep their own global-history registers where applicable.
"""
from __future__ import annotations

import numpy as np

__all__ = ["make_predictor", "PREDICTOR_NAMES"]

PREDICTOR_NAMES = ("Local", "BiMode", "Tournament", "TAGE_SC_L")


class _Base:
    name = "base"

    def predict(self, pc: int) -> bool:  # pragma: no cover - interface
        raise NotImplementedError

    def update(self, pc: int, taken: bool) -> None:  # pragma: no cover
        raise NotImplementedError


def _ctr_update(table: np.ndarray, idx: int, taken: bool) -> None:
    """Saturating 2-bit counter update."""
    v = table[idx]
    if taken:
        if v < 3:
            table[idx] = v + 1
    else:
        if v > 0:
            table[idx] = v - 1


class LocalBP(_Base):
    """Per-PC local history -> pattern table of 2-bit counters."""

    name = "Local"

    def __init__(self, hist_bits: int = 8, entries: int = 1024):
        self.hist_bits = hist_bits
        self.hist = np.zeros(entries, dtype=np.int64)
        self.entries = entries
        self.pht = np.full(1 << hist_bits, 2, dtype=np.int8)
        self.mask = (1 << hist_bits) - 1

    def _idx(self, pc: int) -> int:
        return (pc >> 2) % self.entries

    def predict(self, pc: int) -> bool:
        h = self.hist[self._idx(pc)] & self.mask
        return self.pht[h] >= 2

    def update(self, pc: int, taken: bool) -> None:
        i = self._idx(pc)
        h = self.hist[i] & self.mask
        _ctr_update(self.pht, h, taken)
        self.hist[i] = ((self.hist[i] << 1) | int(taken)) & self.mask


class BiModeBP(_Base):
    """Bi-Mode: choice table selects between taken/not-taken biased tables."""

    name = "BiMode"

    def __init__(self, hist_bits: int = 12, entries: int = 4096):
        self.ghist = 0
        self.hist_bits = hist_bits
        self.mask = (1 << hist_bits) - 1
        self.entries = entries
        self.choice = np.full(entries, 2, dtype=np.int8)
        self.taken_t = np.full(entries, 2, dtype=np.int8)
        self.ntaken_t = np.full(entries, 1, dtype=np.int8)

    def predict(self, pc: int) -> bool:
        c = self.choice[(pc >> 2) % self.entries] >= 2
        idx = ((pc >> 2) ^ (self.ghist & self.mask)) % self.entries
        tbl = self.taken_t if c else self.ntaken_t
        return tbl[idx] >= 2

    def update(self, pc: int, taken: bool) -> None:
        cidx = (pc >> 2) % self.entries
        c = self.choice[cidx] >= 2
        idx = ((pc >> 2) ^ (self.ghist & self.mask)) % self.entries
        tbl = self.taken_t if c else self.ntaken_t
        pred = tbl[idx] >= 2
        # Bi-Mode partial update rule: direction table always updates; choice
        # updates unless the chosen table was correct while disagreeing with it.
        _ctr_update(tbl, idx, taken)
        if not (pred == taken and c != taken):
            _ctr_update(self.choice, cidx, taken)
        self.ghist = ((self.ghist << 1) | int(taken)) & self.mask


class TournamentBP(_Base):
    """Alpha 21264-style: local + gshare global, with a chooser."""

    name = "Tournament"

    def __init__(self, entries: int = 4096, hist_bits: int = 12):
        self.local = LocalBP(hist_bits=10, entries=entries)
        self.ghist = 0
        self.mask = (1 << hist_bits) - 1
        self.entries = entries
        self.gshare = np.full(entries, 2, dtype=np.int8)
        self.chooser = np.full(entries, 2, dtype=np.int8)  # >=2 -> use global

    def _gidx(self, pc: int) -> int:
        return ((pc >> 2) ^ (self.ghist & self.mask)) % self.entries

    def predict(self, pc: int) -> bool:
        use_global = self.chooser[(pc >> 2) % self.entries] >= 2
        if use_global:
            return self.gshare[self._gidx(pc)] >= 2
        return self.local.predict(pc)

    def update(self, pc: int, taken: bool) -> None:
        gpred = self.gshare[self._gidx(pc)] >= 2
        lpred = self.local.predict(pc)
        if gpred != lpred:
            _ctr_update(self.chooser, (pc >> 2) % self.entries, gpred == taken)
        _ctr_update(self.gshare, self._gidx(pc), taken)
        self.local.update(pc, taken)
        self.ghist = ((self.ghist << 1) | int(taken)) & self.mask


class TageLiteBP(_Base):
    """Lightweight TAGE: bimodal base + tagged tables at geometric histories.

    Stands in for gem5's TAGE_SC_L; same interface, much smaller tables.
    """

    name = "TAGE_SC_L"

    def __init__(self, entries: int = 2048, hist_lens=(4, 8, 16, 32)):
        self.base = np.full(entries, 2, dtype=np.int8)
        self.entries = entries
        self.hist_lens = hist_lens
        self.ghist = 0
        nt = len(hist_lens)
        self.tag = np.zeros((nt, entries), dtype=np.int32)
        self.ctr = np.full((nt, entries), 2, dtype=np.int8)
        self.useful = np.zeros((nt, entries), dtype=np.int8)

    def _fold(self, length: int) -> int:
        h = self.ghist & ((1 << length) - 1)
        f = 0
        while h:
            f ^= h & 0xFFF
            h >>= 12
        return f

    def _indices(self, pc: int):
        for t, L in enumerate(self.hist_lens):
            f = self._fold(L)
            idx = ((pc >> 2) ^ f ^ (f << 1)) % self.entries
            tag = ((pc >> 2) ^ (f * 3)) & 0xFFFF
            yield t, idx, tag

    def _provider(self, pc: int):
        provider = None
        for t, idx, tag in self._indices(pc):
            if self.tag[t, idx] == tag:
                provider = (t, idx)
        return provider

    def predict(self, pc: int) -> bool:
        prov = self._provider(pc)
        if prov is not None:
            t, idx = prov
            return self.ctr[t, idx] >= 2
        return self.base[(pc >> 2) % self.entries] >= 2

    def update(self, pc: int, taken: bool) -> None:
        prov = self._provider(pc)
        pred = self.predict(pc)
        if prov is not None:
            t, idx = prov
            _ctr_update(self.ctr[t], idx, taken)
            if pred == taken and self.useful[t, idx] < 3:
                self.useful[t, idx] += 1
        else:
            _ctr_update(self.base, (pc >> 2) % self.entries, taken)
        # On a mispredict, allocate in a longer-history table.
        if pred != taken:
            start = (prov[0] + 1) if prov is not None else 0
            for t, idx, tag in self._indices(pc):
                if t < start:
                    continue
                if self.useful[t, idx] == 0:
                    self.tag[t, idx] = tag
                    self.ctr[t, idx] = 2 if taken else 1
                    break
                self.useful[t, idx] -= 1
        self.ghist = ((self.ghist << 1) | int(taken)) & ((1 << 64) - 1)


_REGISTRY = {
    "Local": LocalBP,
    "BiMode": BiModeBP,
    "Tournament": TournamentBP,
    "TAGE_SC_L": TageLiteBP,
}


def make_predictor(name: str) -> _Base:
    if name not in _REGISTRY:
        raise KeyError(f"unknown branch predictor {name!r}; have {PREDICTOR_NAMES}")
    return _REGISTRY[name]()
