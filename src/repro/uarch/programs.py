"""Synthetic benchmark suite — stand-ins for the paper's SPEC CPU2017 subset.

Each generator builds a real Program (static code + initial state) whose
dynamic behaviour mimics the qualitative personality the paper attributes to
its SPEC counterpart:

  train:  dee (deepsjeng: branchy int, game tree),  rom (roms: fp streaming),
          nab (nab: fp gather/strided),              lee (leela: int pointer-chase,
                                                          small working set)
  test:   mcf (mcf: pointer-chase, cache-hostile),   xal (xalancbmk: irregular
                                                          branchy mixed),
          wrf (wrf: fp loops, medium locality),      cac (cactuBSSN: fp, heavy
                                                          sequential stores, few
                                                          branches)

The register conventions: r1-r9 scratch, r10-r15 loop counters/limits,
r16-r25 data pointers/values, r26-r31 constants.
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np

from .program import Program, ProgramBuilder

__all__ = [
    "TRAIN_BENCHMARKS",
    "TEST_BENCHMARKS",
    "ALL_BENCHMARKS",
    "get_benchmark",
]


def _rand_mem(b: ProgramBuilder, words: int, hi: int) -> None:
    b.init_mem[:words] = b.rng.integers(0, hi, size=words, dtype=np.int64)


def build_dee() -> Program:
    """Branchy integer workload: data-dependent branches over a PRNG stream
    with a small evaluation 'table' — deepsjeng-ish."""
    b = ProgramBuilder("dee", mem_words=1 << 14, seed=11)
    _rand_mem(b, 1 << 14, 1 << 20)
    b.movi(26, 1)                 # const 1
    b.movi(27, 8191)              # index mask
    b.movi(28, 613)               # multiplier for lcg-ish update
    b.movi(16, 12345)             # state
    b.movi(10, 0)                 # i
    b.movi(11, 4096)              # limit
    b.label("outer")
    b.movi(10, 0)
    b.label("loop")
    # state = state*613 + i (mod); idx = state & mask
    b.imul(16, 16, 28)
    b.ialu(16, 16, 10)
    b.ialu(1, 16, 0)
    # idx = state & mask  (emulated: load from mem[state % words])
    b.load(17, 1)                 # table lookup
    # two data-dependent branches on value parity/threshold
    b.movi(29, 1 << 19)
    b.blt(17, 29, "small")
    b.ialu(18, 18, 26)            # score++
    b.jmp("join1")
    b.label("small")
    b.ialu(19, 19, 26)
    b.label("join1")
    b.movi(30, 3)
    b.idiv(2, 17, 30)
    b.imul(3, 2, 30)
    b.bne(3, 17, "notdiv")
    b.ialu(20, 20, 17)
    b.label("notdiv")
    # nested short loop — tree expansion flavour
    b.movi(12, 0)
    b.movi(13, 3)
    b.label("inner")
    b.ialu(4, 17, 12)
    b.load(21, 4)
    b.blt(21, 29, "iskip")
    b.ialu(18, 18, 21)
    b.label("iskip")
    b.ialu(12, 12, 26)
    b.blt(12, 13, "inner")
    b.ialu(10, 10, 26)
    b.blt(10, 11, "loop")
    b.jmp("outer")
    return b.build()


def build_rom() -> Program:
    """FP streaming stencil over a large array: predictable branches,
    sequential memory — roms-ish."""
    b = ProgramBuilder("rom", mem_words=1 << 18, seed=22)
    _rand_mem(b, 1 << 18, 1 << 30)
    b.movi(26, 1)
    b.movi(10, 0)
    b.movi(11, (1 << 18) - 8)
    b.label("loop")
    b.load(16, 10, 0)
    b.load(17, 10, 1)
    b.load(18, 10, 2)
    b.falu(19, 16, 17)
    b.fmul(20, 19, 18)
    b.falu(21, 20, 16)
    b.fmul(22, 21, 17)
    b.store(10, 22, 3)
    b.ialu(10, 10, 26)
    b.blt(10, 11, "loop")
    b.movi(10, 0)
    b.jmp("loop")
    return b.build()


def build_nab() -> Program:
    """FP with strided + gathered access and divides — nab-ish (MD forces)."""
    b = ProgramBuilder("nab", mem_words=1 << 16, seed=33)
    _rand_mem(b, 1 << 16, 1 << 16)
    b.movi(26, 1)
    b.movi(27, 7)        # stride
    b.movi(10, 0)
    b.movi(11, 1 << 15)
    b.label("loop")
    b.imul(1, 10, 27)            # strided index
    b.load(16, 1)                # position
    b.load(17, 16)               # gather via index stored in memory
    b.falu(18, 16, 17)
    b.fmul(19, 18, 18)
    b.fdiv(20, 19, 18)           # 1/r^2 flavour
    b.falu(21, 21, 20)           # accumulate force
    b.store(1, 21, 1)
    b.ialu(10, 10, 26)
    b.blt(10, 11, "loop")
    b.movi(10, 0)
    b.jmp("loop")
    return b.build()


def build_lee() -> Program:
    """Int pointer chasing on a SMALL working set with branchy evaluation —
    leela-ish (fits in L1/L2, branch-limited)."""
    b = ProgramBuilder("lee", mem_words=1 << 10, seed=44)
    # build a random cycle over the small arena
    perm = b.rng.permutation(1 << 10).astype(np.int64)
    b.init_mem[perm] = np.roll(perm, 1)
    b.movi(26, 1)
    b.movi(16, 0)                 # cursor
    b.movi(10, 0)
    b.movi(11, 1 << 12)
    b.label("loop")
    b.load(16, 16)                # chase
    b.movi(29, 1 << 9)
    b.blt(16, 29, "low")
    b.ialu(18, 18, 16)
    b.jmp("j1")
    b.label("low")
    b.ialu(19, 19, 26)
    b.label("j1")
    b.movi(30, 5)
    b.idiv(2, 16, 30)
    b.imul(3, 2, 30)
    b.beq(3, 16, "mul5")
    b.ialu(20, 20, 26)
    b.label("mul5")
    b.ialu(10, 10, 26)
    b.blt(10, 11, "loop")
    b.movi(10, 0)
    b.jmp("loop")
    return b.build()


def build_mcf() -> Program:
    """Pointer chasing over a LARGE arena — cache hostile, memory-bound."""
    b = ProgramBuilder("mcf", mem_words=1 << 19, seed=55)
    perm = b.rng.permutation(1 << 19).astype(np.int64)
    b.init_mem[perm] = np.roll(perm, 1)
    b.movi(26, 1)
    b.movi(16, 0)
    b.movi(10, 0)
    b.movi(11, 1 << 14)
    b.label("loop")
    b.load(16, 16)               # long-latency chase
    b.load(17, 16, 1)            # dependent neighbour
    b.ialu(18, 18, 17)           # reduce
    b.movi(29, 1 << 18)
    b.blt(16, 29, "skip")
    b.ialu(19, 19, 26)
    b.label("skip")
    b.ialu(10, 10, 26)
    b.blt(10, 11, "loop")
    b.movi(10, 0)
    b.jmp("loop")
    return b.build()


def build_xal() -> Program:
    """Irregular mixed int: many unpredictable branches over hashed lookups —
    xalancbmk-ish."""
    b = ProgramBuilder("xal", mem_words=1 << 15, seed=66)
    _rand_mem(b, 1 << 15, 1 << 24)
    b.movi(26, 1)
    b.movi(28, 2654435761 % (1 << 30))
    b.movi(16, 777)
    b.movi(10, 0)
    b.movi(11, 1 << 13)
    b.label("loop")
    b.imul(16, 16, 28)
    b.load(17, 16)
    b.movi(29, 1 << 23)
    b.blt(17, 29, "c1")
    b.ialu(18, 18, 26)
    b.jmp("m1")
    b.label("c1")
    b.movi(30, 1 << 22)
    b.blt(17, 30, "c2")
    b.ialu(19, 19, 26)
    b.jmp("m1")
    b.label("c2")
    b.movi(31, 1 << 21)
    b.blt(17, 31, "c3")
    b.ialu(20, 20, 26)
    b.jmp("m1")
    b.label("c3")
    b.ialu(21, 21, 26)
    b.label("m1")
    b.store(16, 18)
    b.ialu(10, 10, 26)
    b.blt(10, 11, "loop")
    b.movi(10, 0)
    b.jmp("loop")
    return b.build()


def build_wrf() -> Program:
    """FP loops with medium locality and blocked access — wrf-ish."""
    b = ProgramBuilder("wrf", mem_words=1 << 17, seed=77)
    _rand_mem(b, 1 << 17, 1 << 28)
    b.movi(26, 1)
    b.movi(27, 64)      # block
    b.movi(10, 0)
    b.movi(11, 1 << 11) # outer
    b.label("outer")
    b.imul(1, 10, 27)
    b.movi(12, 0)
    b.label("inner")
    b.ialu(2, 1, 12)
    b.load(16, 2)
    b.load(17, 2, 1)
    b.fmul(18, 16, 17)
    b.falu(19, 19, 18)
    b.fdiv(20, 18, 16)
    b.store(2, 19, 2)
    b.ialu(12, 12, 26)
    b.blt(12, 27, "inner")
    b.ialu(10, 10, 26)
    b.blt(10, 11, "outer")
    b.movi(10, 0)
    b.jmp("outer")
    return b.build()


def build_cac() -> Program:
    """FP with heavy sequential STORES and few branches — cactuBSSN-ish
    (the paper notes cac has more stores, fewer branches)."""
    b = ProgramBuilder("cac", mem_words=1 << 18, seed=88)
    _rand_mem(b, 1 << 18, 1 << 28)
    b.movi(26, 1)
    b.movi(10, 0)
    b.movi(11, (1 << 18) - 16)
    b.label("loop")
    b.load(16, 10, 0)
    b.falu(17, 16, 16)
    b.fmul(18, 17, 16)
    b.fmul(19, 18, 17)
    b.falu(20, 19, 18)
    b.store(10, 17, 4)
    b.store(10, 18, 5)
    b.store(10, 19, 6)
    b.store(10, 20, 7)
    b.ialu(10, 10, 26)
    b.blt(10, 11, "loop")
    b.movi(10, 0)
    b.jmp("loop")
    return b.build()


TRAIN_BENCHMARKS: Dict[str, Callable[[], Program]] = {
    "dee": build_dee,
    "rom": build_rom,
    "nab": build_nab,
    "lee": build_lee,
}
TEST_BENCHMARKS: Dict[str, Callable[[], Program]] = {
    "mcf": build_mcf,
    "xal": build_xal,
    "wrf": build_wrf,
    "cac": build_cac,
}
ALL_BENCHMARKS: Dict[str, Callable[[], Program]] = {
    **TRAIN_BENCHMARKS,
    **TEST_BENCHMARKS,
}

_CACHE: Dict[str, Program] = {}


def get_benchmark(name: str) -> Program:
    if name not in ALL_BENCHMARKS:
        raise KeyError(f"unknown benchmark {name!r}; have {sorted(ALL_BENCHMARKS)}")
    if name not in _CACHE:
        _CACHE[name] = ALL_BENCHMARKS[name]()
    return _CACHE[name]
