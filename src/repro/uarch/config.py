"""Microarchitecture design space (paper Table 3) and named presets."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

__all__ = [
    "MicroArchConfig",
    "DESIGN_SPACE",
    "UARCH_A",
    "UARCH_B",
    "UARCH_C",
    "enumerate_design_space",
    "sample_design_space",
]


@dataclasses.dataclass(frozen=True)
class MicroArchConfig:
    """Single-core superscalar OoO design point (Table 3)."""

    name: str = "custom"
    fetch_width: int = 2
    rob_size: int = 32
    branch_predictor: str = "Local"  # Local | BiMode | Tournament | TAGE_SC_L
    l1d_assoc: int = 2
    l1d_size: int = 16 * 1024
    l1i_assoc: int = 2
    l1i_size: int = 8 * 1024
    l2_assoc: int = 2
    l2_size: int = 256 * 1024

    # Fixed timing parameters (not part of the swept space).
    l1_extra_lat: int = 2
    l2_extra_lat: int = 12
    mem_extra_lat: int = 80
    tlb_miss_lat: int = 20
    icache_l2_lat: int = 10
    icache_mem_lat: int = 50
    mispredict_restart: int = 2  # front-end refill after squash

    def key(self) -> tuple:
        """Design-space coordinates (excludes fixed timing params)."""
        return (
            self.fetch_width,
            self.rob_size,
            self.branch_predictor,
            self.l1d_assoc,
            self.l1d_size,
            self.l1i_assoc,
            self.l1i_size,
            self.l2_assoc,
            self.l2_size,
        )


# Table 3 parameter ranges.
DESIGN_SPACE: Dict[str, List] = {
    "fetch_width": [2, 3, 4],
    "rob_size": [32, 64, 96, 128],
    "branch_predictor": ["Local", "BiMode", "TAGE_SC_L", "Tournament"],
    "l1d_assoc": [2, 4, 6, 8],
    "l1d_size": [16 * 1024, 32 * 1024, 64 * 1024, 128 * 1024],
    "l1i_assoc": [2, 4, 6, 8],
    "l1i_size": [8 * 1024, 16 * 1024, 32 * 1024],
    "l2_assoc": [2, 4, 6, 8],
    "l2_size": [256 * 1024, 512 * 1024, 1024 * 1024, 2 * 1024 * 1024, 4 * 1024 * 1024],
}
# Note: cache sizes must stay divisible by assoc*64B; assoc=6 with 16KB etc.
# all satisfy this (16384 / (6*64) is not integral -> the Cache class would
# reject it, so assoc 6 is paired with sizes divisible by 6*64=384).  gem5
# allows this by padding; we round the set count down instead (see _mk_cache).


UARCH_A = MicroArchConfig(
    name="uArchA",
    fetch_width=2,
    rob_size=32,
    branch_predictor="Local",
    l1d_assoc=2,
    l1d_size=16 * 1024,
    l1i_assoc=2,
    l1i_size=8 * 1024,
    l2_assoc=2,
    l2_size=256 * 1024,
)

UARCH_B = MicroArchConfig(
    name="uArchB",
    fetch_width=3,
    rob_size=96,
    branch_predictor="BiMode",
    l1d_assoc=4,
    l1d_size=32 * 1024,
    l1i_assoc=4,
    l1i_size=16 * 1024,
    l2_assoc=4,
    l2_size=1024 * 1024,
)

UARCH_C = MicroArchConfig(
    name="uArchC",
    fetch_width=4,
    rob_size=128,
    branch_predictor="Tournament",
    l1d_assoc=8,
    l1d_size=64 * 1024,
    l1i_assoc=8,
    l1i_size=32 * 1024,
    l2_assoc=8,
    l2_size=4 * 1024 * 1024,
)


def enumerate_design_space() -> int:
    """Total number of design points (the paper reports 184,320... our space
    is 3*4*4*4*4*4*3*4*5 = 184,320 as well)."""
    n = 1
    for v in DESIGN_SPACE.values():
        n *= len(v)
    return n


def sample_design_space(n: int, seed: int = 0) -> List[MicroArchConfig]:
    """Randomly sample `n` distinct design points."""
    rng = np.random.default_rng(seed)
    keys = list(DESIGN_SPACE)
    seen = set()
    out: List[MicroArchConfig] = []
    while len(out) < n:
        kw = {k: DESIGN_SPACE[k][rng.integers(len(DESIGN_SPACE[k]))] for k in keys}
        cfg = MicroArchConfig(name=f"design{len(out)}", **kw)
        if cfg.key() in seen:
            continue
        seen.add(cfg.key())
        out.append(cfg)
    return out
