"""Detailed simulator — the O3CPU analogue.

Timing model (simplified out-of-order superscalar, faithful to the observable
behaviour §4.1 relies on):

* Fetch: `fetch_width` records per cycle; I-cache (L1I -> L2 -> mem) misses
  stall the front end; every fetched record (real, squashed, nop) gets a
  `fetch_clock`, and `fetch_lat` is the delta to the previously fetched record
  — exactly the quantity the paper re-attributes during dataset construction.
* Speculation: conditional branches are predicted (Local/BiMode/Tournament/
  TAGE); on a mispredict the wrong path is fetched from static code and
  emitted as KIND_SQUASHED records until the branch resolves, then the front
  end restarts — the next correct instruction's fetch_clock absorbs the full
  misprediction penalty (paper Figure 2).
* Stalls: when the ROB is full, a single KIND_NOP bubble record is emitted
  and fetch waits for the oldest in-flight instruction to retire (in-order
  retirement).
* Execution: issue waits on source-register readiness; exec latency = opcode
  class latency + data-hierarchy latency (L1/L2/mem + TLB) for loads.
  retire_clock = fetch_clock + (complete - fetch_clock) so the total-cycle
  invariant `max(retire_clock)` is preserved exactly by the §4.1 alignment.

Returns the detailed trace (DET_TRACE_DTYPE) including squashed/nop records
interleaved in fetch order, plus a summary dict of aggregate metrics.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, Tuple

import numpy as np

from .branch import make_predictor
from .cache import LINE_BYTES, Cache, TLB
from .config import MicroArchConfig
from .isa import (
    DET_TRACE_DTYPE,
    DLEVEL_L1,
    DLEVEL_L2,
    DLEVEL_MEM,
    DLEVEL_NONE,
    EXEC_LATENCY_ARR,
    KIND_NOP,
    KIND_REAL,
    KIND_SQUASHED,
    Op,
)
from .program import PC_STRIDE, Program

__all__ = ["run_detailed", "summarize_detailed"]

_BRANCH_SET = {int(Op.BEQ), int(Op.BNE), int(Op.BLT), int(Op.BGE)}
_MAX_WRONG_PATH = 48  # cap on squashed records per mispredict


def run_detailed(
    program: Program,
    func_trace: np.ndarray,
    cfg: MicroArchConfig,
) -> Tuple[np.ndarray, Dict]:
    code = program.code
    n_static = len(code)
    ops_s = np.array([int(i.op) for i in code], dtype=np.int16)
    dsts_s = np.array([i.dst for i in code], dtype=np.int8)
    src1_s = np.array([i.src1 for i in code], dtype=np.int8)
    src2_s = np.array([i.src2 for i in code], dtype=np.int8)
    tgt_s = np.array([i.target for i in code], dtype=np.int64)

    bp = make_predictor(cfg.branch_predictor)
    l1d = Cache(cfg.l1d_size, cfg.l1d_assoc)
    l1i = Cache(cfg.l1i_size, cfg.l1i_assoc)
    l2 = Cache(cfg.l2_size, cfg.l2_assoc)
    tlb = TLB()

    n = len(func_trace)
    # Worst case: every instruction is a mispredicted branch... be generous
    # but bounded; grow if needed.
    cap = int(n * 1.6) + 64
    out = np.zeros(cap, dtype=DET_TRACE_DTYPE)

    f_pc = func_trace["pc"]
    f_op = func_trace["opcode"]
    f_dst = func_trace["dst"]
    f_s1 = func_trace["src1"]
    f_s2 = func_trace["src2"]
    f_isbr = func_trace["is_branch"]
    f_taken = func_trace["taken"]
    f_ismem = func_trace["is_mem"]
    f_isst = func_trace["is_store"]
    f_addr = func_trace["addr"]

    reg_ready = np.zeros(32, dtype=np.int64)
    rob = deque()  # in-order completion times of in-flight instructions
    rob_size = cfg.rob_size
    fetch_width = cfg.fetch_width

    clock = 0          # current fetch cycle
    slot = 0           # fetch slot within current cycle
    last_fetch_clock = 0
    last_line = -1     # last fetched I-cache line
    w = 0              # write cursor into `out`
    n_squashed = 0
    n_nops = 0
    n_mispred = 0
    n_branches = 0
    inorder_complete = 0  # completion time of the most recent in-flight instr

    exec_lat_arr = EXEC_LATENCY_ARR
    l1_lat, l2_lat, mem_lat = cfg.l1_extra_lat, cfg.l2_extra_lat, cfg.mem_extra_lat
    tlb_lat = cfg.tlb_miss_lat
    ic_l2, ic_mem = cfg.icache_l2_lat, cfg.icache_mem_lat

    def fetch_advance():
        """Consume one fetch slot; returns the clock the record is fetched at."""
        nonlocal clock, slot
        c = clock
        slot += 1
        if slot >= fetch_width:
            slot = 0
            clock += 1
        return c

    def icache_access(pc_bytes: int) -> Tuple[int, bool]:
        """Front-end I-fetch; returns (extra stall cycles, missed)."""
        nonlocal last_line
        line = pc_bytes // LINE_BYTES
        if line == last_line:
            return 0, False
        last_line = line
        if l1i.access(pc_bytes):
            return 0, False
        if l2.access(pc_bytes):
            return ic_l2, True
        return ic_mem, True

    def ensure_cap(extra: int):
        nonlocal out, cap
        if w + extra >= cap:
            new_cap = int(cap * 1.5) + extra + 64
            new = np.zeros(new_cap, dtype=DET_TRACE_DTYPE)
            new[:w] = out[:w]
            out = new
            cap = new_cap

    for i in range(n):
        ensure_cap(2 + _MAX_WRONG_PATH)
        op = int(f_op[i])
        pc_bytes = int(f_pc[i])
        static_idx = pc_bytes // PC_STRIDE

        # ---- ROB occupancy: stall fetch if full -----------------------
        while rob and rob[0] <= clock:
            rob.popleft()
        if len(rob) >= rob_size:
            # Emit one stall bubble; fetch resumes when the head retires.
            head = rob.popleft()
            r = out[w]
            r["pc"] = pc_bytes
            r["opcode"] = int(Op.NOP)
            r["kind"] = KIND_NOP
            fc = fetch_advance()
            r["fetch_clock"] = fc
            r["fetch_lat"] = fc - last_fetch_clock
            r["exec_lat"] = 1
            r["retire_clock"] = fc + 1
            last_fetch_clock = fc
            w += 1
            n_nops += 1
            if head > clock:
                clock = int(head)
                slot = 0
            while rob and rob[0] <= clock:
                rob.popleft()

        # ---- front-end: I-cache ---------------------------------------
        ic_stall, ic_miss = icache_access(pc_bytes)
        if ic_stall:
            clock += ic_stall
            slot = 0

        fc = fetch_advance()

        # ---- execute ---------------------------------------------------
        s1 = int(f_s1[i])
        s2 = int(f_s2[i])
        issue = max(fc + 1, reg_ready[s1], reg_ready[s2])
        lat = int(exec_lat_arr[op])
        dlevel = DLEVEL_NONE
        tlb_miss = False
        if f_ismem[i]:
            addr = int(f_addr[i])
            if not tlb.access(addr):
                tlb_miss = True
                lat += tlb_lat
            if l1d.access(addr):
                dlevel = DLEVEL_L1
                lat += l1_lat if not f_isst[i] else 0
            elif l2.access(addr):
                dlevel = DLEVEL_L2
                lat += l2_lat if not f_isst[i] else 0
            else:
                dlevel = DLEVEL_MEM
                lat += mem_lat if not f_isst[i] else 0
        complete = issue + lat
        dst = int(f_dst[i])
        if dst:
            reg_ready[dst] = complete
        # In-order retirement: completion times are monotone in the ROB.
        inorder_complete = max(inorder_complete, complete)
        rob.append(inorder_complete)

        # ---- branch prediction / speculation ---------------------------
        mispred = False
        if op in _BRANCH_SET:
            n_branches += 1
            pred = bp.predict(pc_bytes)
            actual = bool(f_taken[i])
            bp.update(pc_bytes, actual)
            if pred != actual:
                mispred = True
                n_mispred += 1

        r = out[w]
        r["pc"] = pc_bytes
        r["opcode"] = op
        r["dst"] = dst
        r["src1"] = s1
        r["src2"] = s2
        r["is_branch"] = f_isbr[i]
        r["taken"] = f_taken[i]
        r["is_mem"] = f_ismem[i]
        r["is_store"] = f_isst[i]
        r["addr"] = f_addr[i]
        r["kind"] = KIND_REAL
        r["fetch_clock"] = fc
        r["fetch_lat"] = fc - last_fetch_clock
        r["exec_lat"] = complete - fc
        r["retire_clock"] = complete
        r["mispred"] = mispred
        r["dlevel"] = dlevel
        r["icache_miss"] = ic_miss
        r["tlb_miss"] = tlb_miss
        last_fetch_clock = fc
        w += 1

        if mispred:
            # Fetch the wrong path until the branch resolves at `complete`.
            actual = bool(f_taken[i])
            wrong_pc = int(tgt_s[static_idx]) if not actual else static_idx + 1
            resolve = complete
            nsq = 0
            while clock < resolve and nsq < _MAX_WRONG_PATH:
                if wrong_pc >= n_static:
                    wrong_pc = program.entry
                sop = int(ops_s[wrong_pc])
                sq = out[w]
                sq["pc"] = wrong_pc * PC_STRIDE
                sq["opcode"] = sop
                sq["dst"] = dsts_s[wrong_pc]
                sq["src1"] = src1_s[wrong_pc]
                sq["src2"] = src2_s[wrong_pc]
                sq["kind"] = KIND_SQUASHED
                sfc = fetch_advance()
                sq["fetch_clock"] = sfc
                sq["fetch_lat"] = sfc - last_fetch_clock
                sq["exec_lat"] = 1
                sq["retire_clock"] = sfc + 1
                last_fetch_clock = sfc
                w += 1
                nsq += 1
                n_squashed += 1
                # Wrong-path control flow: follow unconditional jumps,
                # fall through conditional branches.
                if sop == int(Op.JMP):
                    wrong_pc = int(tgt_s[wrong_pc])
                else:
                    wrong_pc += 1
            # Squash + front-end restart.
            clock = max(clock, resolve) + cfg.mispredict_restart
            slot = 0

    out = out[:w]
    total_cycles = int(out["retire_clock"].max()) if w else 0
    real_mask = out["kind"] == KIND_REAL
    summary = {
        "uarch": cfg.name,
        "num_committed": int(real_mask.sum()),
        "num_squashed": n_squashed,
        "num_nops": n_nops,
        "num_branches": n_branches,
        "num_mispred": n_mispred,
        "total_cycles": total_cycles,
        "cpi": total_cycles / max(1, int(real_mask.sum())),
        "l1d_miss_rate": l1d.misses / max(1, l1d.hits + l1d.misses),
        "l2_miss_rate": l2.misses / max(1, l2.hits + l2.misses),
        "branch_mispred_rate": n_mispred / max(1, n_branches),
        "l1d_mpki": 1000.0 * l1d.misses / max(1, int(real_mask.sum())),
        "branch_mpki": 1000.0 * n_mispred / max(1, int(real_mask.sum())),
    }
    return out, summary


def summarize_detailed(det: np.ndarray) -> Dict:
    """Aggregate metrics straight from a detailed trace array."""
    real = det[det["kind"] == KIND_REAL]
    n = max(1, len(real))
    branches = real["is_branch"].sum()
    return {
        "num_committed": len(real),
        "total_cycles": int(det["retire_clock"].max()) if len(det) else 0,
        "cpi": float(det["retire_clock"].max()) / n if len(det) else 0.0,
        "branch_mpki": 1000.0 * float(real["mispred"].sum()) / n,
        "l1d_mpki": 1000.0 * float((real["dlevel"] >= DLEVEL_L2).sum()) / n,
        "branch_mispred_rate": float(real["mispred"].sum()) / max(1, int(branches)),
    }
