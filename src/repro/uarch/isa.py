"""Tiny RISC ISA for the µarch simulation substrate.

The paper (Tao, SIGMETRICS'24) builds its datasets from gem5 traces of ARM
SPEC CPU2017 binaries.  Neither gem5 nor SPEC is available here, so we define
a small register machine whose functional/detailed simulators expose the same
observable interface gem5 does in the paper: functional traces carrying static
instruction properties, and detailed traces carrying per-instruction
performance metrics plus squashed-speculative and stall-nop records.
"""
from __future__ import annotations

import enum

import numpy as np

__all__ = [
    "Op",
    "NUM_REGS",
    "EXEC_LATENCY",
    "FUNC_TRACE_DTYPE",
    "DET_TRACE_DTYPE",
    "KIND_REAL",
    "KIND_SQUASHED",
    "KIND_NOP",
    "DLEVEL_NONE",
    "DLEVEL_L1",
    "DLEVEL_L2",
    "DLEVEL_MEM",
    "NUM_DLEVELS",
]

NUM_REGS = 32  # r0..r31; r0 is hardwired zero (writes ignored).


class Op(enum.IntEnum):
    """Opcode space.  Order is stable: feature engineering uses the int value."""

    IALU = 0    # dst = src1 op src2 (add/sub/and/or/xor/shift collapse here)
    IMUL = 1
    IDIV = 2
    FALU = 3
    FMUL = 4
    FDIV = 5
    LOAD = 6    # dst = mem[src1 + imm]
    STORE = 7   # mem[src1 + imm] = src2
    BEQ = 8     # branch if src1 == src2
    BNE = 9
    BLT = 10
    BGE = 11
    JMP = 12    # unconditional
    MOVI = 13   # dst = imm
    NOP = 14    # real nop in programs (distinct from pipeline stall nops)


# Conditional branch opcodes (used by predictors / feature engineering).
BRANCH_OPS = frozenset({Op.BEQ, Op.BNE, Op.BLT, Op.BGE})
MEM_OPS = frozenset({Op.LOAD, Op.STORE})

# Base execution latency (cycles) per opcode class, before memory effects.
EXEC_LATENCY = {
    Op.IALU: 1,
    Op.IMUL: 3,
    Op.IDIV: 12,
    Op.FALU: 2,
    Op.FMUL: 4,
    Op.FDIV: 14,
    Op.LOAD: 1,   # + data access latency from the memory hierarchy
    Op.STORE: 1,
    Op.BEQ: 1,
    Op.BNE: 1,
    Op.BLT: 1,
    Op.BGE: 1,
    Op.JMP: 1,
    Op.MOVI: 1,
    Op.NOP: 1,
}

EXEC_LATENCY_ARR = np.zeros(len(Op), dtype=np.int32)
for _op, _lat in EXEC_LATENCY.items():
    EXEC_LATENCY_ARR[int(_op)] = _lat

# ---------------------------------------------------------------------------
# Trace record layouts.
# ---------------------------------------------------------------------------

# Functional trace: static properties + architectural outcome only.  This is
# the µarch-agnostic input Tao consumes at inference time.
FUNC_TRACE_DTYPE = np.dtype(
    [
        ("pc", np.int64),
        ("opcode", np.int16),
        ("dst", np.int8),
        ("src1", np.int8),
        ("src2", np.int8),
        ("is_branch", np.bool_),
        ("taken", np.bool_),       # architectural branch outcome
        ("is_mem", np.bool_),
        ("is_store", np.bool_),
        ("addr", np.int64),        # byte address for mem ops, else 0
    ]
)

# Detailed trace record kinds.
KIND_REAL = 0       # committed instruction
KIND_SQUASHED = 1   # wrong-path instruction, squashed on branch resolution
KIND_NOP = 2        # pipeline stall bubble

# Data access levels (softmax target in the multi-metric model).
DLEVEL_NONE = 0
DLEVEL_L1 = 1
DLEVEL_L2 = 2
DLEVEL_MEM = 3
NUM_DLEVELS = 4

# Detailed trace: everything in the functional record, plus µarch metrics.
DET_TRACE_DTYPE = np.dtype(
    [
        ("pc", np.int64),
        ("opcode", np.int16),
        ("dst", np.int8),
        ("src1", np.int8),
        ("src2", np.int8),
        ("is_branch", np.bool_),
        ("taken", np.bool_),
        ("is_mem", np.bool_),
        ("is_store", np.bool_),
        ("addr", np.int64),
        ("kind", np.int8),          # KIND_*
        ("fetch_clock", np.int64),  # cycle the instruction was fetched
        ("fetch_lat", np.int32),    # fetch_clock delta vs previous fetched record
        ("exec_lat", np.int32),     # issue->complete latency
        ("retire_clock", np.int64), # fetch_clock + fetch_lat + exec_lat (paper defn)
        ("mispred", np.bool_),      # conditional branch was mispredicted
        ("dlevel", np.int8),        # DLEVEL_* for loads/stores
        ("icache_miss", np.bool_),
        ("tlb_miss", np.bool_),
    ]
)


def empty_func_trace(n: int) -> np.ndarray:
    return np.zeros(n, dtype=FUNC_TRACE_DTYPE)


def empty_det_trace(n: int) -> np.ndarray:
    return np.zeros(n, dtype=DET_TRACE_DTYPE)
