"""TAO001 compat-bypass and TAO006 deprecated-shim rules.

**TAO001** — jax API drift is shimmed in exactly one file,
``repro/compat.py`` (PR 1 consolidated the 0.4.x..0.6+ renames there; PR 5
removed the runner's duplicated ``shard_map`` fallback).  Any direct
``jax.experimental`` / ``jax.sharding`` import or attribute access outside
``compat.py`` re-opens that drift surface, so it is flagged.  The one
allowance: ``kernels/*/kernel.py`` may import ``jax.experimental.pallas``
(and ``...pallas.tpu``) — Pallas has no compat alias and kernel modules
are the declared lowering boundary.

**TAO006** — ``simulate_trace`` / ``train_tao`` are DeprecationWarning
shims since PR 3.  New call sites outside the shims' own modules (and the
tests that pin shim behavior) silently re-grow the pre-facade API.
"""
from __future__ import annotations

import ast
from typing import Iterator

from .core import Analysis, Finding, SourceFile, attr_chain, register_rule

_BANNED_ROOTS = ("jax.experimental", "jax.sharding")
_PALLAS_OK = ("jax.experimental.pallas",)

_DEPRECATED = {
    "simulate_trace": "TrainedModel.simulate / Session.sweep (repro.api)",
    "train_tao": "Session.train / TrainedModel.transfer (repro.api)",
}
# modules that define (or lazily re-export) the shims themselves
_SHIM_FILES = ("simulate.py", "transfer.py")


def _banned(modname: str) -> bool:
    return any(
        modname == r or modname.startswith(r + ".") for r in _BANNED_ROOTS
    )


def _pallas_allowed(modname: str) -> bool:
    return any(
        modname == r or modname.startswith(r + ".") for r in _PALLAS_OK
    )


def _iter_compat_bypass(sf: SourceFile) -> Iterator[Finding]:
    if sf.is_compat:
        return
    outer_attrs = _outermost_attrs(sf.tree)
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if not _banned(alias.name):
                    continue
                if sf.is_kernel and _pallas_allowed(alias.name):
                    continue
                yield Finding(
                    sf.display, node.lineno, node.col_offset, "TAO001",
                    f"direct `import {alias.name}` bypasses repro.compat — "
                    "route jax API drift through the compat shims",
                )
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if node.level or not _banned(mod):
                continue
            if sf.is_kernel and (
                _pallas_allowed(mod)
                or (mod == "jax.experimental"
                    and all(a.name == "pallas" for a in node.names))
            ):
                continue
            names = ", ".join(a.name for a in node.names)
            yield Finding(
                sf.display, node.lineno, node.col_offset, "TAO001",
                f"direct `from {mod} import {names}` bypasses repro.compat — "
                "import (or add) the shim in repro/compat.py instead",
            )
        elif isinstance(node, ast.Attribute) and node in outer_attrs:
            # only the outermost node of a chain (one finding for
            # jax.sharding.Mesh, not one per link)
            chain = attr_chain(node)
            if chain is not None and (
                chain.startswith("jax.experimental")
                or chain.startswith("jax.sharding")
            ):
                yield Finding(
                    sf.display, node.lineno, node.col_offset, "TAO001",
                    f"`{chain}` accessed directly — use repro.compat "
                    "(one-file fix for the next jax rename)",
                )


def _outermost_attrs(tree: ast.AST) -> set:
    """Attribute nodes that are not themselves the ``.value`` of an
    enclosing Attribute (i.e. the head of each dotted chain)."""
    inner = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and isinstance(
            node.value, ast.Attribute
        ):
            inner.add(node.value)
    return {
        n for n in ast.walk(tree)
        if isinstance(n, ast.Attribute) and n not in inner
    }


@register_rule(
    "TAO001",
    "compat bypass: jax.experimental/jax.sharding outside repro/compat.py "
    "(pallas allowed in kernels/*/kernel.py)",
)
def check_compat_bypass(sf: SourceFile, analysis: Analysis) -> Iterator[Finding]:
    return _iter_compat_bypass(sf)


@register_rule(
    "TAO006",
    "deprecated shim call (simulate_trace/train_tao) outside the shims "
    "and their tests",
)
def check_deprecated_shims(sf: SourceFile, analysis: Analysis) -> Iterator[Finding]:
    if sf.path.name in _SHIM_FILES or "tests" in sf.path.parts:
        return
    if sf.path.name == "__init__.py" and sf.path.parent.name == "core":
        return  # the lazy re-export point (PEP 562)
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Call):
            fn = node.func
            name = (
                fn.id if isinstance(fn, ast.Name)
                else fn.attr if isinstance(fn, ast.Attribute)
                else None
            )
            if name in _DEPRECATED:
                yield Finding(
                    sf.display, node.lineno, node.col_offset, "TAO006",
                    f"deprecated shim `{name}()` — use {_DEPRECATED[name]}",
                )
        elif isinstance(node, ast.ImportFrom):
            for alias in node.names:
                if alias.name in _DEPRECATED:
                    yield Finding(
                        sf.display, node.lineno, node.col_offset, "TAO006",
                        f"importing deprecated shim `{alias.name}` — use "
                        f"{_DEPRECATED[alias.name]}",
                    )
