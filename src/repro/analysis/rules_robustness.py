"""TAO008 (silent exception swallowing) + the error-code half of TAO007.

**TAO008** — the resilience layer (PR 9) legitimizes a few broad
exception handlers: fault boundaries that convert arbitrary failures
into retries, quarantines, or clean closes.  Everywhere else, a bare
``except:`` or a swallow-only ``except Exception: pass`` is how faults
become silent corruption — exactly what the chaos suite exists to
prevent.  This rule flags both, unless the handler line (or the line
directly above it) carries a ``# tao: fault-boundary <why>`` pragma
naming the site a deliberate seam.  A fault-boundary pragma that
annotates no handler is itself a finding, so stale annotations cannot
accumulate.

**TAO007 (codes)** — the ``ServeError`` code vocabulary is a wire
contract exactly like the ``to_dict`` key sets: the ``ERROR_CODES``
tuple in ``serve/types.py`` is read statically and diffed against
``schemas.WIRE_ERROR_CODES``, so adding DEADLINE_EXCEEDED (or dropping
QUEUE_FULL) without updating the declared contract fails CI.
"""
from __future__ import annotations

import ast
from typing import Iterator, Set

from .core import Analysis, Finding, SourceFile, register_rule
from .schemas import WIRE_ERROR_CODES

_BROAD = frozenset({"Exception", "BaseException"})


def _type_names(node: ast.AST) -> Set[str]:
    """Exception-type names an ``except ...:`` clause mentions (tuple
    clauses contribute every member)."""
    out: Set[str] = set()
    elts = node.elts if isinstance(node, ast.Tuple) else [node]
    for n in elts:
        if isinstance(n, ast.Name):
            out.add(n.id)
        elif isinstance(n, ast.Attribute):
            out.add(n.attr)
    return out


def _swallows(handler: ast.ExceptHandler) -> bool:
    """True when the handler body does nothing at all (``pass`` /
    ``...`` only) — the failure vanishes without a trace."""
    for stmt in handler.body:
        if isinstance(stmt, ast.Pass):
            continue
        if (
            isinstance(stmt, ast.Expr)
            and isinstance(stmt.value, ast.Constant)
            and stmt.value.value is Ellipsis
        ):
            continue
        return False
    return True


@register_rule(
    "TAO008",
    "silent exception swallowing: bare `except:` or a swallow-only "
    "`except Exception:` outside a `# tao: fault-boundary` site",
)
def check_fault_boundaries(sf: SourceFile, analysis: Analysis) -> Iterator[Finding]:
    if "tests" in sf.path.parts:
        return  # tests provoke failures on purpose
    marked = {
        p.line
        for plist in sf.pragmas.values()
        for p in plist
        if p.kind == "fault-boundary"
    }
    handlers = [
        n for n in ast.walk(sf.tree) if isinstance(n, ast.ExceptHandler)
    ]
    handler_lines = {h.lineno for h in handlers}

    for h in handlers:
        annotated = h.lineno in marked or (h.lineno - 1) in marked
        if h.type is None:
            if not annotated:
                yield Finding(
                    sf.display, h.lineno, h.col_offset, "TAO008",
                    "bare `except:` swallows everything up to "
                    "KeyboardInterrupt — name the exceptions, or mark a "
                    "deliberate seam with `# tao: fault-boundary <why>`",
                )
            continue
        if (
            _type_names(h.type) & _BROAD
            and _swallows(h)
            and not annotated
        ):
            yield Finding(
                sf.display, h.lineno, h.col_offset, "TAO008",
                "`except Exception`/`BaseException` with an empty body "
                "turns faults into silent corruption — handle or narrow "
                "it, or mark a deliberate seam with "
                "`# tao: fault-boundary <why>`",
            )

    # pragma hygiene: an annotation that guards nothing is stale
    for ln in sorted(marked):
        if ln not in handler_lines and (ln + 1) not in handler_lines:
            yield Finding(
                sf.display, ln, 0, "TAO008",
                "`# tao: fault-boundary` annotates no except handler "
                "(place it on the `except` line or directly above it)",
            )


@register_rule(
    "TAO007",
    "wire-contract drift: serve/types.py ERROR_CODES differs from "
    "schemas.WIRE_ERROR_CODES",
)
def check_error_codes(sf: SourceFile, analysis: Analysis) -> Iterator[Finding]:
    if not sf.display.replace("\\", "/").endswith("serve/types.py"):
        return
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Assign) or not any(
            isinstance(t, ast.Name) and t.id == "ERROR_CODES"
            for t in node.targets
        ):
            continue
        if not isinstance(node.value, (ast.Tuple, ast.List)):
            yield Finding(
                sf.display, node.lineno, node.col_offset, "TAO007",
                "ERROR_CODES is not a literal tuple — the analyzer cannot "
                "hold the failure surface to the declared contract",
            )
            return
        codes: Set[str] = set()
        for elt in node.value.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                codes.add(elt.value)
            else:
                yield Finding(
                    sf.display, elt.lineno, elt.col_offset, "TAO007",
                    "non-literal entry in ERROR_CODES — keep the code "
                    "vocabulary a tuple of string literals",
                )
                return
        for label, diff in (
            ("drops declared error code(s)", WIRE_ERROR_CODES - codes),
            ("adds undeclared error code(s)", codes - WIRE_ERROR_CODES),
        ):
            if diff:
                yield Finding(
                    sf.display, node.lineno, node.col_offset, "TAO007",
                    f"ERROR_CODES {label} {sorted(diff)} vs "
                    "schemas.WIRE_ERROR_CODES — update "
                    "src/repro/analysis/schemas.py in the same change",
                )
        return
