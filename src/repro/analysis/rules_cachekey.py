"""TAO003 — step-cache-key completeness.

The process-wide step caches (``engine/runner.py`` ``_STEP_CACHE``,
``train/trainer.py`` ``cached_train_step``) key a compiled step by a tuple
of everything the builder closure read.  Anything the builder reads but
the key omits is a **stale-cache bug**: two configs that differ only in
the omitted field silently share one compiled step.  PR 2 (backend left
out of the key) and PR 5 (plan added to the key by hand) were exactly
this class; this rule makes the invariant mechanical.

Wiring: the builder def carries ``# tao: step-builder[label]`` (with an
optional ``ignore=a,b`` list for parameters that are deliberately
key-free, e.g. the cached-entry callables threaded through for warmup);
the line holding the key tuple carries ``# tao: step-key[label]``.  For
each label the rule collects what the builder *reads* — maximal
``self.*`` attribute chains in Load context that are not themselves the
callee of a call, plus any referenced parameter — and requires each read
to appear in the key tuple, where a key element satisfies a read if it
is the read itself or a prefix of it (keying ``self.cfg`` covers
``self.cfg.d_model``: the whole config hashes in).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from .core import Analysis, Finding, SourceFile, attr_chain, register_rule


def _outermost_load_attrs(root: ast.AST) -> List[ast.Attribute]:
    """Heads of dotted chains (``self.a.b``, not its sub-chains) that are
    read, not written or deleted."""
    inner = set()
    for node in ast.walk(root):
        if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Attribute):
            inner.add(node.value)
    return [
        n for n in ast.walk(root)
        if isinstance(n, ast.Attribute)
        and n not in inner
        and isinstance(n.ctx, ast.Load)
    ]


def _builder_reads(fn: ast.AST, ignore: Tuple[str, ...]) -> Set[str]:
    """Everything a builder closure reads that must therefore be keyed."""
    call_funcs = {
        node.func for node in ast.walk(fn) if isinstance(node, ast.Call)
    }
    reads: Set[str] = set()
    for node in _outermost_load_attrs(fn):
        if node in call_funcs:
            continue  # a method being called, not a config value read
        chain = attr_chain(node)
        if chain and chain.startswith("self."):
            reads.add(chain)

    args = fn.args
    params = [
        a.arg
        for a in (
            list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs)
        )
    ]
    if args.vararg:
        params.append(args.vararg.arg)
    if args.kwarg:
        params.append(args.kwarg.arg)
    wanted = {p for p in params if p != "self" and p not in ignore}
    if wanted:
        referenced = {
            n.id for n in ast.walk(fn)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)
        }
        reads.update(wanted & referenced)
    return reads


def _key_elements(sf: SourceFile, line: int) -> Optional[List[str]]:
    """Unparsed elements of the key tuple on a ``step-key`` line: the
    outermost Tuple inside the statement covering that line."""
    stmt = sf.statement_at(line)
    if stmt is None:
        return None
    for node in ast.walk(stmt):  # walk is breadth-first: outermost first
        if isinstance(node, ast.Tuple):
            return [ast.unparse(e) for e in node.elts]
    return None


def _satisfied(read: str, keys: List[str]) -> bool:
    return any(read == k or read.startswith(k + ".") for k in keys)


@register_rule(
    "TAO003",
    "step-cache key tuple omits a value the step-builder closure reads "
    "(stale-cache hazard)",
)
def check_cache_keys(sf: SourceFile, analysis: Analysis) -> Iterator[Finding]:
    builders: Dict[str, List] = {}
    for fi in sf.funcs.values():
        if fi.builder is not None:
            builders.setdefault(fi.builder.label, []).append(fi)

    keys_by_label: Dict[str, List] = {}
    for plist in sf.pragmas.values():
        for p in plist:
            if p.kind == "step-key":
                keys_by_label.setdefault(p.label, []).append(p)

    for label, fis in sorted(builders.items()):
        key_pragmas = keys_by_label.pop(label, [])
        if not key_pragmas:
            for fi in fis:
                yield Finding(
                    sf.display, fi.node.lineno, fi.node.col_offset, "TAO003",
                    f"step-builder[{label}] has no matching "
                    f"`# tao: step-key[{label}]` line in this module",
                )
            continue
        elements: List[str] = []
        for p in key_pragmas:
            elts = _key_elements(sf, p.line)
            if elts is None:
                yield Finding(
                    sf.display, p.line, 0, "TAO003",
                    f"step-key[{label}] line holds no tuple literal to "
                    "check against",
                )
            else:
                elements.extend(elts)
        if not elements:
            continue
        for fi in fis:
            for read in sorted(_builder_reads(fi.node, fi.builder.ignore)):
                if not _satisfied(read, elements):
                    yield Finding(
                        sf.display, fi.node.lineno, fi.node.col_offset,
                        "TAO003",
                        f"step-builder[{label}] `{fi.qualname}` reads "
                        f"`{read}` but the step-key tuple does not include "
                        "it — two configs differing only there would share "
                        "a compiled step",
                    )

    for label, plist in sorted(keys_by_label.items()):
        for p in plist:
            yield Finding(
                sf.display, p.line, 0, "TAO003",
                f"step-key[{label}] has no matching "
                f"`# tao: step-builder[{label}]` def in this module",
            )
