"""``repro.analysis`` — the repo's own static analyzer + runtime sanitizer.

Static half (stdlib-only — CI's lint job runs it without jax installed):

    python -m repro.analysis --strict src benchmarks

Rule codes TAO001–TAO008 each encode an invariant a past PR earned the
hard way (see docs/analysis.md for the catalog).  Per-line suppressions
require a reason::

    x = float(v)  # tao: noqa[TAO002] post-sync epilogue, one call per trace

Runtime half: :func:`repro.analysis.sanitize.sanitized` (and the pytest
``sanitize`` marker) runs a block with device→host transfers disallowed,
NaN debugging on, and a hard compile budget — the dynamic enforcement of
the same invariants TAO002/TAO003 check statically.

Importing this package pulls only the static half; ``sanitize`` imports
jax lazily on first use.
"""
from __future__ import annotations

from .core import Analysis, Finding, Pragma, RULES, SourceFile, register_rule

# importing the rule modules registers their checkers
from . import rules_imports as _rules_imports      # noqa: F401  TAO001/TAO006
from . import rules_hotpath as _rules_hotpath      # noqa: F401  TAO002
from . import rules_cachekey as _rules_cachekey    # noqa: F401  TAO003
from . import rules_contracts as _rules_contracts  # noqa: F401  TAO004/TAO007
from . import rules_bitwise as _rules_bitwise      # noqa: F401  TAO005
from . import rules_robustness as _rules_robustness  # noqa: F401  TAO008 + TAO007 codes
from .schemas import WIRE_ERROR_CODES, WIRE_SCHEMAS

__all__ = [
    "Analysis",
    "Finding",
    "Pragma",
    "RULES",
    "SourceFile",
    "WIRE_ERROR_CODES",
    "WIRE_SCHEMAS",
    "register_rule",
    "run_paths",
]


def run_paths(paths, *, select=None):
    """Analyze files/directories; returns the driver's result dict
    (``findings`` / ``suppressed`` / ``unused_suppressions``)."""
    analysis = Analysis(select=select)
    for p in paths:
        analysis.add_path(p)
    return analysis.run()
