"""TAO004 (MetricSpec contract) and TAO007 (wire-contract drift).

**TAO004** — the engine enforces the MetricSpec contract at runtime
(``engine/runner.py``: finalize-key collisions, reserved
``SimulationResult`` attrs, the reserved ``__grid__`` carry slot), but
only for the spec combination a given run requests.  This rule lifts the
same checks to the registry level: every ``MetricSpec(...)`` /
``windowed_spec(...)`` constructed anywhere in the scanned tree is
checked against every other one, so a plug-in spec that collides with a
built-in fails CI even if no test happens to request both.

**TAO007** — ``to_dict()`` of the serve-protocol classes is parsed
statically (dict literals, conditional subscript stores, one level of
``**self.method()`` expansion, ``dataclasses.asdict(self)`` via the
dataclass's own annotated fields) and diffed against the declared
``schemas.WIRE_SCHEMAS``.  Adding a field to ``ServerStats`` without
updating the schema registry — the silent-drift path for the JSON-lines
protocol — is a finding on the ``to_dict`` line.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from .core import Analysis, Finding, SourceFile, attr_chain, register_rule
from .schemas import WIRE_SCHEMAS

# mirrors engine/runner.py (_RESERVED_RESULT_ATTRS, _GRID_KEY); the
# analyzer keeps its own copy so the static half stays stdlib-importable
_RESERVED_RESULT_ATTRS = frozenset(
    ("num_instructions", "seconds", "mips", "metrics")
)
_GRID_KEY = "__grid__"


# ---------------------------------------------------------------------------
# TAO004 — MetricSpec registry contract
# ---------------------------------------------------------------------------


def _const_str(node: Optional[ast.AST]) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


def _returned_dict_keys(fn: ast.AST) -> Tuple[Set[str], bool]:
    """Statically-known string keys of every dict a function returns,
    plus a ``dynamic`` flag when any returned dict has computed keys
    (f-strings, comprehensions) the analyzer cannot enumerate."""
    keys: Set[str] = set()
    dynamic = False
    bodies: List[ast.AST] = []
    if isinstance(fn, ast.Lambda):
        bodies = [fn.body]
    else:
        bodies = [
            n.value for n in ast.walk(fn)
            if isinstance(n, ast.Return) and n.value is not None
        ]
    for body in bodies:
        if isinstance(body, ast.Dict):
            for k in body.keys:
                s = _const_str(k)
                if s is not None:
                    keys.add(s)
                else:
                    dynamic = True
        elif isinstance(body, ast.DictComp):
            dynamic = True
        else:
            dynamic = True
    return keys, dynamic


def _spec_fact(sf: SourceFile, call: ast.Call) -> Optional[Dict]:
    """A ``MetricSpec(...)`` or ``windowed_spec(...)`` call site as a
    registry fact: spec name + statically-known finalize keys."""
    fname = attr_chain(call.func) or ""
    tail = fname.rsplit(".", 1)[-1]
    if tail not in ("MetricSpec", "windowed_spec"):
        return None

    args: Dict[str, ast.AST] = {}
    pos = ("name", "init", "update", "finalize", "num_chunks")
    for i, a in enumerate(call.args):
        if i < len(pos):
            args[pos[i]] = a
    for kw in call.keywords:
        if kw.arg:
            args[kw.arg] = kw.value

    name = _const_str(args.get("name"))
    if name is None:
        return None  # factory internals / dynamic name: nothing to pin

    if tail == "windowed_spec":
        # the factory's finalize emits exactly {name: curve}
        keys, dynamic = {name}, False
    else:
        fin = args.get("finalize")
        keys, dynamic = set(), True
        if isinstance(fin, ast.Lambda):
            keys, dynamic = _returned_dict_keys(fin)
        elif isinstance(fin, ast.Name):
            for fi in sf.funcs.values():
                if fi.name == fin.id and fi.parent == "":
                    keys, dynamic = _returned_dict_keys(fi.node)
                    break
    return {
        "path": sf.display,
        "line": call.lineno,
        "col": call.col_offset,
        "name": name,
        "keys": keys,
        "dynamic": dynamic,
    }


@register_rule(
    "TAO004",
    "MetricSpec contract violation: reserved __grid__/result-attr names "
    "or finalize-key collisions across registered specs",
)
def collect_metric_specs(sf: SourceFile, analysis: Analysis) -> Iterator[Finding]:
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        fact = _spec_fact(sf, node)
        if fact is None:
            continue
        analysis.metric_specs.append(fact)
        if fact["name"] == _GRID_KEY:
            yield Finding(
                sf.display, node.lineno, node.col_offset, "TAO004",
                f"metric spec named `{_GRID_KEY}` — that carry slot is "
                "reserved for the engine's window grid",
            )
        bad = fact["keys"] & _RESERVED_RESULT_ATTRS
        if bad:
            yield Finding(
                sf.display, node.lineno, node.col_offset, "TAO004",
                f"spec `{fact['name']}` finalizes reserved key(s) "
                f"{sorted(bad)} — SimulationResult instance attributes "
                "would shadow them",
            )


@register_rule(
    "TAO004",
    "MetricSpec finalize-key collision (cross-file)",
    finalizer=True,
)
def check_spec_collisions(analysis: Analysis) -> Iterator[Finding]:
    seen: Dict[str, Dict] = {}   # finalize key -> first fact emitting it
    names: Dict[str, Dict] = {}  # spec name -> first fact
    for fact in analysis.metric_specs:
        prev = names.get(fact["name"])
        if prev is not None and (prev["path"], prev["line"]) != (
            fact["path"], fact["line"]
        ):
            yield Finding(
                fact["path"], fact["line"], fact["col"], "TAO004",
                f"spec name `{fact['name']}` already constructed at "
                f"{prev['path']}:{prev['line']} — register_metric would "
                "refuse or silently shadow it",
            )
        names.setdefault(fact["name"], fact)
        for key in sorted(fact["keys"]):
            prev = seen.get(key)
            if prev is not None and prev["name"] != fact["name"]:
                yield Finding(
                    fact["path"], fact["line"], fact["col"], "TAO004",
                    f"spec `{fact['name']}` finalizes key `{key}` also "
                    f"emitted by spec `{prev['name']}` "
                    f"({prev['path']}:{prev['line']}) — requesting both "
                    "raises at runtime",
                )
            seen.setdefault(key, fact)


# ---------------------------------------------------------------------------
# TAO007 — wire-contract drift
# ---------------------------------------------------------------------------


def _dataclass_fields(cls: ast.ClassDef) -> Set[str]:
    return {
        t.target.id
        for t in cls.body
        if isinstance(t, ast.AnnAssign) and isinstance(t.target, ast.Name)
    }


def _method(cls: ast.ClassDef, name: str) -> Optional[ast.FunctionDef]:
    for node in cls.body:
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def _under_if(node: ast.AST, fn: ast.AST) -> bool:
    """Whether a statement sits under any If inside ``fn`` (conditional
    emission -> the key is optional on the wire)."""
    for outer in ast.walk(fn):
        if isinstance(outer, ast.If):
            for inner in ast.walk(outer):
                if inner is node:
                    return True
    return False


def _dict_literal_keys(
    d: ast.Dict, cls: ast.ClassDef
) -> Tuple[Set[str], bool]:
    """Keys of a dict literal; ``**self.method()`` entries expand one
    level through the class's own method."""
    keys: Set[str] = set()
    dynamic = False
    for k, v in zip(d.keys, d.values):
        if k is None:  # **expansion
            expanded = False
            if (
                isinstance(v, ast.Call)
                and attr_chain(v.func)
                and attr_chain(v.func).startswith("self.")
                and "." not in attr_chain(v.func)[5:]
            ):
                m = _method(cls, attr_chain(v.func)[5:])
                if m is not None:
                    sub, dyn = _returned_dict_keys(m)
                    keys |= sub
                    dynamic |= dyn
                    expanded = True
            if not expanded:
                dynamic = True
            continue
        s = _const_str(k)
        if s is not None:
            keys.add(s)
        else:
            dynamic = True
    return keys, dynamic


def _to_dict_keys(
    cls: ast.ClassDef, fn: ast.FunctionDef
) -> Tuple[Set[str], Set[str], bool]:
    """(required, optional, dynamic) key sets a ``to_dict`` emits."""
    required: Set[str] = set()
    optional: Set[str] = set()
    dynamic = False

    for node in ast.walk(fn):
        if isinstance(node, ast.Return) and isinstance(node.value, ast.Dict):
            keys, dyn = _dict_literal_keys(node.value, cls)
            required |= keys
            dynamic |= dyn
        elif isinstance(node, (ast.Assign, ast.AnnAssign)):
            # out = {...}  |  out: Dict = {...}  |  out = dataclasses.asdict(self)
            if isinstance(node.value, ast.Dict):
                keys, dyn = _dict_literal_keys(node.value, cls)
                required |= keys
                dynamic |= dyn
            elif (
                isinstance(node.value, ast.Call)
                and (attr_chain(node.value.func) or "").endswith("asdict")
            ):
                required |= _dataclass_fields(cls)
            # out["k"] = v  (conditional store -> optional wire key;
            # out[k] = ... with a computed key is a re-store, not new)
            targets = (
                node.targets if isinstance(node, ast.Assign) else [node.target]
            )
            for tgt in targets:
                if isinstance(tgt, ast.Subscript):
                    s = _const_str(tgt.slice)
                    if s is None:
                        continue
                    if _under_if(node, fn):
                        optional.add(s)
                    else:
                        required.add(s)
    optional -= required
    return required, optional, dynamic


@register_rule(
    "TAO007",
    "wire-contract drift: to_dict() key set differs from the declared "
    "schema in repro/analysis/schemas.py",
)
def check_wire_contracts(sf: SourceFile, analysis: Analysis) -> Iterator[Finding]:
    if "tests" in sf.path.parts:
        return
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.ClassDef) or node.name not in WIRE_SCHEMAS:
            continue
        # only the real definitions, not fixtures named alike elsewhere:
        # the schema maps class names, so any same-named class is held to
        # the contract — that is the point.
        fn = _method(node, "to_dict")
        if fn is None:
            continue
        analysis.wire_classes[node.name] = {"path": sf.display, "line": fn.lineno}
        schema = WIRE_SCHEMAS[node.name]
        required, optional, dynamic = _to_dict_keys(node, fn)
        if dynamic:
            yield Finding(
                sf.display, fn.lineno, fn.col_offset, "TAO007",
                f"{node.name}.to_dict emits keys the analyzer cannot "
                "enumerate statically — keep the wire dict a literal",
            )
            continue
        missing = schema.required - required
        extra = required - schema.required
        opt_missing = schema.optional - optional
        opt_extra = optional - schema.optional
        for label, diff in (
            ("misses required key(s)", missing),
            ("emits undeclared key(s)", extra),
            ("misses optional key(s)", opt_missing),
            ("emits undeclared optional key(s)", opt_extra),
        ):
            if diff:
                yield Finding(
                    sf.display, fn.lineno, fn.col_offset, "TAO007",
                    f"{node.name}.to_dict {label} {sorted(diff)} vs the "
                    "declared wire schema — update "
                    "src/repro/analysis/schemas.py in the same change",
                )


@register_rule(
    "TAO007",
    "wire-schema class missing from the scanned tree",
    finalizer=True,
)
def check_wire_coverage(analysis: Analysis) -> Iterator[Finding]:
    for name, schema in sorted(WIRE_SCHEMAS.items()):
        if name in analysis.wire_classes or not schema.home:
            continue
        # complain only when the class's home file was actually scanned —
        # a partial scan of other files is not drift
        home = next(
            (
                sf for sf in analysis.files
                if sf.display.replace("\\", "/").endswith(schema.home)
            ),
            None,
        )
        if home is not None:
            yield Finding(
                home.display, 1, 0, "TAO007",
                f"declared wire class `{name}` defines no to_dict here — "
                "renamed without updating repro/analysis/schemas.py?",
            )
