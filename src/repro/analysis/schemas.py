"""Declared wire schemas for the PR 7 JSON-lines serve protocol (TAO007).

These are the **contract**, written down once, here — the analyzer
statically extracts each class's ``to_dict`` key set and diffs it against
this registry, so a field added to (or dropped from) a result dataclass
cannot silently change what tenants parse.  Changing the wire format is
allowed; doing it without touching this file is not.

``required`` keys are always present in the emitted dict; ``optional``
keys are emitted conditionally (``SimulationResult.to_dict(arrays=True)``,
``ServeError`` retry/request-id hints).
"""
from __future__ import annotations

from typing import Dict, FrozenSet, NamedTuple


class WireSchema(NamedTuple):
    required: FrozenSet[str]
    optional: FrozenSet[str] = frozenset()
    # where the class lives (repo-relative suffix) — lets the analyzer
    # tell "class renamed away" from "that file was not scanned"
    home: str = ""


WIRE_SCHEMAS: Dict[str, WireSchema] = {
    # engine/runner.py — per-trace result
    "SimulationResult": WireSchema(
        home="engine/runner.py",
        required=frozenset(
            {
                "num_instructions",
                "seconds",
                "mips",
                "metrics",
                "available_metrics",
            }
        ),
        optional=frozenset({"arrays"}),
    ),
    # engine/scheduler.py — sweep counters + nested results
    "SweepReport": WireSchema(
        home="engine/scheduler.py",
        required=frozenset(
            {
                "seconds",
                "num_traces",
                "num_instructions",
                "queue_depth",
                "prepared_async",
                "traces_per_s",
                "mips",
                "num_compiles",
                "queue_occupancy_mean",
                "queue_occupancy_max",
                "plan_kind",
                "num_shards",
                "features_extracted",
                "features_from_store",
                "jobs_skipped",
                "results",
            }
        ),
    ),
    # serve/types.py — per-request wire result
    "ServeResult": WireSchema(
        home="serve/types.py",
        required=frozenset(
            {
                "request_id",
                "model",
                "tenant",
                "geometry",
                "num_instructions",
                "metrics",
                "queue_s",
                "extract_s",
                "compute_s",
                "total_s",
                "coalesced",
            }
        ),
    ),
    # serve/types.py — TraceServer.stats() observability snapshot
    "ServerStats": WireSchema(
        home="serve/types.py",
        required=frozenset(
            {
                "uptime_s",
                "admitted",
                "completed",
                "failed",
                "rejected",
                "queue_depth",
                "max_queue",
                "num_compiles",
                "features_extracted",
                "features_from_store",
                "features_coalesced",
                "traces_per_s",
                "latency_p50_s",
                "latency_p99_s",
                "queue_p50_s",
                "queue_p99_s",
                "batch_fill_ratio",
                "plan_kind",
                "num_shards",
                "retries",
                "deadline_exceeded",
                "quarantined",
                "bisections",
                "breaker_sheds",
                "breakers",
                "per_geometry",
                "per_tenant",
            }
        ),
    ),
    # serve/types.py — stable error surface
    "ServeError": WireSchema(
        home="serve/types.py",
        required=frozenset({"error", "message"}),
        optional=frozenset({"retry_after_s", "request_id"}),
    ),
}


# The closed ServeError code vocabulary, declared here exactly like the
# dict shapes above: TAO007 statically reads the ``ERROR_CODES`` tuple in
# serve/types.py and diffs it against this set, so a code added to (or
# dropped from) the failure surface cannot skip the contract review.
WIRE_ERROR_CODES: FrozenSet[str] = frozenset(
    {
        "QUEUE_FULL",
        "UNKNOWN_MODEL",
        "BAD_REQUEST",
        "GEOMETRY_MISMATCH",
        "METRIC_NOT_COMPUTED",
        "METRIC_NOT_COLLECTED",
        "SHUTTING_DOWN",
        "DEADLINE_EXCEEDED",
        "TRACE_REJECTED",
        "CIRCUIT_OPEN",
        "INTERNAL",
    }
)
