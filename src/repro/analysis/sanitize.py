"""Runtime sanitizer: the dynamic half of ``repro.analysis``.

TAO002/TAO003 catch host syncs and stale cache keys *statically*; this
module enforces the same invariants at runtime for the tests that opt in
(pytest marker ``sanitize``, wired in ``tests/conftest.py``):

  * ``jax.transfer_guard_device_to_host("disallow")`` — any implicit
    device→host transfer (a hidden ``float()``/``np.asarray`` on a
    device array) raises instead of silently stalling the dispatch
    queue.  Explicit ``jax.device_get`` — the sanctioned end-of-trace
    sync — stays allowed, exactly mirroring TAO002's exemption.
    **CPU-backend caveat**: CPU jax arrays alias host memory, so the
    pull is zero-copy and no guardable transfer event exists — the guard
    arms but cannot fire (and the full two-direction guard is unusable:
    it flags every eager ``jnp.zeros`` constant as host→device).  On CPU
    CI the teeth of a sanitized block are therefore ``debug_nans`` and
    the compile budget; the transfer guard bites on accelerator
    backends, where the stall it polices is also the one that matters.
  * ``jax.debug_nans`` — jitted computations re-run un-jitted on a NaN
    output and raise at the producing primitive.
  * **compile budget** — snapshots the process-wide step-cache compile
    counters (``repro.engine.runner.cache_stats()['compiles']`` and
    ``repro.train.trainer.train_step_compiles()``) on entry and raises
    ``CompileBudgetExceeded`` if the block compiled more than allowed:
    the one-compile-per-geometry invariant as a hard runtime check.

jax (and the engine/train modules) import lazily so the static analyzer —
which shares this package — stays importable in CI's jax-less lint job.
"""
from __future__ import annotations

import contextlib
from typing import Iterator, Optional

__all__ = ["CompileBudgetExceeded", "compiles_now", "sanitized"]


class CompileBudgetExceeded(AssertionError):
    """A sanitized block compiled more step executables than budgeted."""


def compiles_now() -> int:
    """Total step compiles so far, engine + trainer, process-wide."""
    from ..engine import runner as _runner
    from ..train import trainer as _trainer

    return int(_runner.cache_stats()["compiles"]) + int(
        _trainer.train_step_compiles()
    )


@contextlib.contextmanager
def sanitized(
    *,
    transfer_guard: Optional[str] = "disallow",
    debug_nans: bool = True,
    compile_budget: Optional[int] = None,
) -> Iterator[None]:
    """Run a block with the repo's runtime invariants hard-enforced.

    ``transfer_guard`` guards **implicit device→host** transfers only
    (explicit ``jax.device_get`` always passes; see the module note for
    the CPU-backend caveat).  Pass ``None`` to leave transfers alone,
    e.g. for code paths that legitimately sync mid-stream.

    ``compile_budget`` bounds *new* step compiles inside the block
    (``None`` = unbounded; ``0`` = the warm-cache contract: everything
    was compiled before the block started).
    """
    import jax

    start = compiles_now() if compile_budget is not None else 0
    with contextlib.ExitStack() as stack:
        if transfer_guard is not None:
            stack.enter_context(
                jax.transfer_guard_device_to_host(transfer_guard)
            )
        if debug_nans:
            stack.enter_context(jax.debug_nans(True))
        yield
    if compile_budget is not None:
        spent = compiles_now() - start
        if spent > compile_budget:
            raise CompileBudgetExceeded(
                f"sanitized block compiled {spent} step(s), budget was "
                f"{compile_budget} — a cache key miss or geometry change "
                "slipped into the hot path"
            )
