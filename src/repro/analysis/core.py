"""Shared machinery of the ``repro.analysis`` static analyzer.

One pass, three layers:

  * **pragmas** — ``# tao: ...`` comments parsed off the token stream
    (never out of string literals).  The grammar is small and closed:

      ``# tao: noqa[TAO002] <reason>``      suppress listed codes on this
                                            line; the reason is REQUIRED
      ``# tao: hot``                        this def is a hot-path seed
                                            (TAO002 reachability root)
      ``# tao: cold``                       this def is explicitly cold:
                                            reachability does not enter it
      ``# tao: bitwise``                    this def is under the bitwise
                                            NumPy-equality contract (TAO005)
      ``# tao: step-builder[label]``        this def builds a cached step
                                            (``ignore=a,b`` skips params)
      ``# tao: step-key[label]``            the cache-key tuple on this line
                                            belongs to builder ``label``
      ``# tao: fault-boundary <why>``       the broad exception handler on
                                            this line is a deliberate
                                            resilience seam (TAO008)

  * **SourceFile** — one parsed module: AST, pragma maps, and the def
    table the reachability / pairing rules consume.

  * **Analysis** — the driver: runs every registered checker over every
    file, applies suppressions (a suppression without a reason never
    suppresses — it becomes a TAO000 finding instead), and reports
    unused suppressions so stale ``noqa`` lines cannot accumulate.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Finding",
    "Pragma",
    "SourceFile",
    "Analysis",
    "RULES",
    "register_rule",
]


# code -> one-line description (filled by register_rule; TAO000 is the
# analyzer's own hygiene code and is never suppressible)
RULES: Dict[str, str] = {
    "TAO000": "malformed/bare `# tao:` pragma (suppressions require a reason)",
}

_CHECKERS: List[Callable] = []       # per-file checkers
_FINALIZERS: List[Callable] = []     # whole-fileset checkers


def register_rule(code: str, description: str, *, finalizer: bool = False):
    """Decorator: register a checker under a rule code.

    Per-file checkers are called ``check(sf, analysis)`` per SourceFile;
    finalizers are called ``check(analysis)`` once after every file was
    scanned (cross-file rules: finalize-key collisions, schema drift).
    """
    RULES.setdefault(code, description)

    def wrap(fn):
        (_FINALIZERS if finalizer else _CHECKERS).append(fn)
        return fn

    return wrap


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    code: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} {self.message}"

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Pragma:
    line: int
    kind: str                 # noqa | hot | cold | bitwise | step-builder | step-key | fault-boundary
    codes: Tuple[str, ...] = ()
    reason: str = ""
    label: str = ""
    ignore: Tuple[str, ...] = ()


_PRAGMA_RE = re.compile(r"#\s*tao:\s*(.*?)\s*$")
_NOQA_RE = re.compile(r"^noqa\s*(?:\[([A-Za-z0-9_,\s]*)\])?\s*:?\s*(.*)$", re.S)
_LABELED_RE = re.compile(
    r"^(step-builder|step-key)\s*\[([\w.-]+)\]\s*(?:ignore=([\w,\s]+))?\s*$"
)


def _parse_pragma(line: int, body: str) -> Pragma:
    if body.startswith("noqa"):
        m = _NOQA_RE.match(body)
        codes = tuple(
            c.strip().upper() for c in (m.group(1) or "").split(",") if c.strip()
        )
        return Pragma(line, "noqa", codes=codes, reason=(m.group(2) or "").strip())
    m = _LABELED_RE.match(body)
    if m:
        ignore = tuple(
            s.strip() for s in (m.group(3) or "").split(",") if s.strip()
        )
        return Pragma(line, m.group(1), label=m.group(2), ignore=ignore)
    if body in ("hot", "cold", "bitwise"):
        return Pragma(line, body)
    if body == "fault-boundary" or body.startswith("fault-boundary "):
        # trailing free text is the why — encouraged, not parsed
        return Pragma(
            line, "fault-boundary",
            reason=body[len("fault-boundary"):].strip(),
        )
    return Pragma(line, "malformed", reason=body)


@dataclasses.dataclass
class FuncInfo:
    """One def in the module's function table."""

    qualname: str
    name: str
    node: ast.AST            # FunctionDef | AsyncFunctionDef
    parent: Optional[str]    # enclosing qualname ("" for module level)
    in_class: Optional[str]  # nearest enclosing class name
    hot: bool = False
    cold: bool = False
    bitwise: bool = False
    builder: Optional[Pragma] = None   # step-builder pragma


class SourceFile:
    """A parsed module plus its pragma and def tables."""

    def __init__(self, path: Path, display: str, text: str):
        self.path = path
        self.display = display
        self.text = text
        self.tree = ast.parse(text, filename=display)
        self.pragmas: Dict[int, List[Pragma]] = {}
        self.noqa: Dict[int, Pragma] = {}
        self._scan_comments()
        self.funcs: Dict[str, FuncInfo] = {}
        self._build_func_table()

    # ---- classification helpers -----------------------------------------

    @property
    def is_compat(self) -> bool:
        return self.path.name == "compat.py"

    @property
    def is_kernel(self) -> bool:
        return self.path.name == "kernel.py" and "kernels" in self.path.parts

    # ---- comments --------------------------------------------------------

    def _scan_comments(self) -> None:
        try:
            tokens = tokenize.generate_tokens(io.StringIO(self.text).readline)
            for tok in tokens:
                if tok.type != tokenize.COMMENT:
                    continue
                m = _PRAGMA_RE.search(tok.string)
                if m is None:
                    continue
                p = _parse_pragma(tok.start[0], m.group(1))
                self.pragmas.setdefault(p.line, []).append(p)
                if p.kind == "noqa":
                    self.noqa[p.line] = p
        except tokenize.TokenError:
            pass  # ast.parse already succeeded; comments best-effort

    def pragmas_for_def(self, node: ast.AST) -> List[Pragma]:
        """Pragmas attached to a def: trailing on the ``def`` line or on
        the line directly above it (above any decorators too)."""
        lines = [node.lineno, node.lineno - 1]
        deco = getattr(node, "decorator_list", [])
        if deco:
            lines.append(min(d.lineno for d in deco) - 1)
        out: List[Pragma] = []
        for ln in lines:
            out.extend(self.pragmas.get(ln, ()))
        return out

    # ---- def table -------------------------------------------------------

    def _build_func_table(self) -> None:
        sf = self

        class V(ast.NodeVisitor):
            def __init__(self):
                self.stack: List[str] = []
                self.classes: List[str] = []

            def visit_ClassDef(self, node):
                self.stack.append(node.name)
                self.classes.append(node.name)
                self.generic_visit(node)
                self.classes.pop()
                self.stack.pop()

            def _def(self, node):
                qual = ".".join(self.stack + [node.name])
                fi = FuncInfo(
                    qualname=qual,
                    name=node.name,
                    node=node,
                    parent=".".join(self.stack) if self.stack else "",
                    in_class=self.classes[-1] if self.classes else None,
                )
                for p in sf.pragmas_for_def(node):
                    if p.kind == "hot":
                        fi.hot = True
                    elif p.kind == "cold":
                        fi.cold = True
                    elif p.kind == "bitwise":
                        fi.bitwise = True
                    elif p.kind == "step-builder":
                        fi.builder = p
                sf.funcs[qual] = fi
                self.stack.append(node.name)
                self.generic_visit(node)
                self.stack.pop()

            visit_FunctionDef = _def
            visit_AsyncFunctionDef = _def

        V().visit(self.tree)

    def statement_at(self, line: int) -> Optional[ast.stmt]:
        """The innermost statement whose span covers ``line``."""
        best: Optional[ast.stmt] = None
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.stmt):
                continue
            end = getattr(node, "end_lineno", node.lineno)
            if node.lineno <= line <= end:
                if best is None or (
                    node.lineno >= best.lineno
                    and end <= getattr(best, "end_lineno", best.lineno)
                ):
                    best = node
        return best


def body_nodes(fn: ast.AST) -> Iterable[ast.AST]:
    """Walk a function's lexical body, NOT descending into nested defs
    (nested defs have their own FuncInfo and are visited separately)."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def attr_chain(node: ast.AST) -> Optional[str]:
    """``self.ecfg.collect`` -> "self.ecfg.collect"; None when the chain
    does not bottom out in a Name."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class Analysis:
    """Driver: scan files, run checkers, apply suppressions."""

    def __init__(self, select: Optional[Sequence[str]] = None):
        self.select = set(select) if select else None
        self.files: List[SourceFile] = []
        self.errors: List[Finding] = []
        # cross-file fact stores (filled by per-file checkers, consumed
        # by finalizers)
        self.metric_specs: List[Dict] = []     # TAO004 facts
        self.wire_classes: Dict[str, Dict] = {}  # TAO007 facts

    # ---- input -----------------------------------------------------------

    def add_path(self, path: str) -> None:
        p = Path(path)
        if p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if any(part.startswith(".") for part in f.parts):
                    continue
                self._add_file(f)
        elif p.suffix == ".py":
            self._add_file(p)
        else:
            raise FileNotFoundError(f"not a python file or directory: {path}")

    def _add_file(self, p: Path) -> None:
        text = p.read_text(encoding="utf-8")
        try:
            self.files.append(SourceFile(p, str(p), text))
        except SyntaxError as e:
            self.errors.append(
                Finding(str(p), e.lineno or 1, e.offset or 0, "TAO000",
                        f"file does not parse: {e.msg}")
            )

    # ---- run -------------------------------------------------------------

    def run(self) -> Dict[str, List]:
        raw: List[Finding] = list(self.errors)
        for sf in self.files:
            for check in _CHECKERS:
                raw.extend(check(sf, self))
        for check in _FINALIZERS:
            raw.extend(check(self))

        if self.select is not None:
            raw = [f for f in raw if f.code in self.select or f.code == "TAO000"]

        noqa_by_file = {sf.display: sf.noqa for sf in self.files}
        used: Dict[Tuple[str, int], bool] = {}
        findings: List[Finding] = []
        suppressed: List[Tuple[Finding, str]] = []
        for f in raw:
            p = noqa_by_file.get(f.path, {}).get(f.line)
            if (
                p is not None
                and f.code != "TAO000"
                and f.code in p.codes
                and p.reason
            ):
                used[(f.path, p.line)] = True
                suppressed.append((f, p.reason))
            else:
                findings.append(f)

        # pragma hygiene: malformed pragmas, bare/codeless noqa, unknown
        # codes, unused suppressions
        unused: List[Finding] = []
        for sf in self.files:
            for plist in sf.pragmas.values():
                for p in plist:
                    if p.kind == "malformed":
                        findings.append(Finding(
                            sf.display, p.line, 0, "TAO000",
                            f"unrecognized tao pragma: {p.reason!r}",
                        ))
                    elif p.kind == "noqa":
                        if not p.codes:
                            findings.append(Finding(
                                sf.display, p.line, 0, "TAO000",
                                "bare `tao: noqa` — name the code(s): "
                                "`# tao: noqa[TAOxxx] <reason>`",
                            ))
                            continue
                        unknown = [c for c in p.codes if c not in RULES]
                        if unknown:
                            findings.append(Finding(
                                sf.display, p.line, 0, "TAO000",
                                f"unknown rule code(s) {unknown} in suppression",
                            ))
                        if not p.reason:
                            findings.append(Finding(
                                sf.display, p.line, 0, "TAO000",
                                f"suppression of {list(p.codes)} carries no "
                                "reason — `# tao: noqa[TAOxxx] <reason>`",
                            ))
                        elif not used.get((sf.display, p.line)):
                            unused.append(Finding(
                                sf.display, p.line, 0, "TAO000",
                                f"unused suppression of {list(p.codes)} "
                                "(nothing fired on this line — delete it)",
                            ))

        findings.sort(key=lambda f: (f.path, f.line, f.col, f.code))
        unused.sort(key=lambda f: (f.path, f.line))
        return {
            "findings": findings,
            "suppressed": suppressed,
            "unused_suppressions": unused,
        }
