"""CLI driver: ``python -m repro.analysis [--strict] paths...``.

Exit status 1 on any unsuppressed finding; ``--strict`` additionally
fails on unused suppressions (stale ``# tao: noqa`` lines), which is how
CI keeps the suppression inventory honest.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from . import RULES, run_paths


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Tao repo static analyzer (rule codes TAO001-TAO007; "
        "see docs/analysis.md)",
    )
    parser.add_argument(
        "paths", nargs="*", default=["src"],
        help="files or directories to scan (default: src)",
    )
    parser.add_argument(
        "--strict", action="store_true",
        help="also fail on unused suppressions",
    )
    parser.add_argument(
        "--select", metavar="CODES",
        help="comma-separated rule codes to run (default: all)",
    )
    parser.add_argument(
        "--format", choices=("text", "json"), default="text",
        help="output format",
    )
    parser.add_argument(
        "--list-rules", action="store_true",
        help="print the rule catalog and exit",
    )
    args = parser.parse_args(argv)

    if args.list_rules:
        for code in sorted(RULES):
            print(f"{code}  {RULES[code]}")
        return 0

    select = None
    if args.select:
        select = [c.strip().upper() for c in args.select.split(",") if c.strip()]
        unknown = [c for c in select if c not in RULES]
        if unknown:
            parser.error(f"unknown rule code(s): {unknown}")

    result = run_paths(args.paths, select=select)
    findings = result["findings"]
    unused = result["unused_suppressions"]
    failing = list(findings) + (list(unused) if args.strict else [])

    if args.format == "json":
        print(json.dumps(
            {
                "findings": [f.to_dict() for f in findings],
                "unused_suppressions": [f.to_dict() for f in unused],
                "suppressed": [
                    {**f.to_dict(), "reason": reason}
                    for f, reason in result["suppressed"]
                ],
            },
            indent=2,
        ))
        return 1 if failing else 0

    for f in findings:
        print(f.format())
    for f in unused:
        print(f.format())
    n_sup = len(result["suppressed"])
    if failing:
        print(
            f"\n{len(findings)} finding(s), {len(unused)} unused "
            f"suppression(s){' (strict)' if args.strict else ''}, "
            f"{n_sup} suppressed",
            file=sys.stderr,
        )
        return 1
    print(f"clean: 0 findings ({n_sup} suppressed with reasons)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
