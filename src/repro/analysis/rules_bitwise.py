"""TAO005 — fma-contraction hazard in bitwise-deterministic functions.

``core.features.signed_log`` (and its Pallas twin) carry a contract the
test suite pins: in-jit output is **bit-identical** to the NumPy
reference, which is why both are written as one-op-per-statement Horner
steps.  XLA is free to contract ``a * b + c`` written as a single
expression into an fma, whose differently-rounded result breaks
``np.array_equal`` on exactly the backends where it matters.  The hazard
pattern is purely syntactic: an ``Add``/``Sub`` whose operand is a
literal ``Mult`` expression.  Functions opt in with ``# tao: bitwise``;
the fix is always the same — hoist the product into its own statement.
"""
from __future__ import annotations

import ast
from typing import Iterator

from .core import Analysis, Finding, SourceFile, body_nodes, register_rule


def _is_mult(node: ast.AST) -> bool:
    return isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult)


@register_rule(
    "TAO005",
    "multiply fused into an add/sub inside a `# tao: bitwise` function "
    "(XLA may contract it into an fma and break NumPy bit-equality)",
)
def check_bitwise(sf: SourceFile, analysis: Analysis) -> Iterator[Finding]:
    for qual, fi in sorted(sf.funcs.items()):
        if not fi.bitwise:
            continue
        for node in body_nodes(fi.node):
            if not isinstance(node, ast.BinOp):
                continue
            if not isinstance(node.op, (ast.Add, ast.Sub)):
                continue
            if _is_mult(node.left) or _is_mult(node.right):
                op = "+" if isinstance(node.op, ast.Add) else "-"
                yield Finding(
                    sf.display, node.lineno, node.col_offset, "TAO005",
                    f"`a * b {op} c` shape in bitwise function `{qual}` — "
                    "XLA may fma-contract it; assign the product to its own "
                    "variable first (see core.features.signed_log)",
                )
