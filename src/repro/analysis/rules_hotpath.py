"""TAO002 — host synchronization inside a hot path.

The engine's throughput story (PR 1-7, and the failure mode SimNet/CAPSim
both report) rests on the hot loops being **host-sync-free**: the jitted
step is dispatched batch after batch and the single ``jax.device_get`` at
end of trace is the only device→host round trip.  A stray ``.item()``,
``float()``, ``np.asarray`` or ``block_until_ready`` in that loop stalls
the dispatch queue once per batch and the regression is invisible in unit
tests (results stay correct — only MIPS dies).

Mechanics: functions marked ``# tao: hot`` are reachability seeds (the
cached step builders' drivers in ``engine/runner.py``, ``core/transfer.py``,
``serve/server.py``, plus traced-side MetricSpec updates).  Reachability
propagates through same-module calls (``foo(...)`` and ``self.foo(...)``)
and into lexically nested defs; ``# tao: cold`` stops propagation where a
callee is cold by design (post-sync finalization, producer-thread prep).
Within the hot set, the five host-sync forms are flagged — unless their
argument is an **explicit** ``jax.device_get(...)`` call, which is the
sanctioned, visible way to cross the boundary (one obvious sync beats a
hidden one; the runtime sanitizer enforces the same contract with
``jax.transfer_guard``).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Set

from .core import (
    Analysis,
    Finding,
    SourceFile,
    attr_chain,
    body_nodes,
    register_rule,
)

_SYNC_METHODS = ("item", "tolist", "block_until_ready")
_SYNC_CALLS = {
    "float": "float()",
    "np.asarray": "np.asarray()",
    "numpy.asarray": "numpy.asarray()",
}


def _is_device_get(node: ast.AST) -> bool:
    """True for ``jax.device_get(...)`` / ``device_get(...)`` calls — the
    explicit sync form the rule accepts as an argument."""
    if not isinstance(node, ast.Call):
        return False
    chain = attr_chain(node.func)
    return chain in ("jax.device_get", "device_get")


def _callees(sf: SourceFile, fi) -> Set[str]:
    """Qualnames of same-module functions ``fi`` may call: plain-name
    calls match any def with that simple name; ``self.x(...)`` matches
    methods named ``x``."""
    names: Set[str] = set()
    for node in body_nodes(fi.node):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        if isinstance(fn, ast.Name):
            names.add(fn.id)
        elif (
            isinstance(fn, ast.Attribute)
            and isinstance(fn.value, ast.Name)
            and fn.value.id == "self"
        ):
            names.add(fn.attr)
    out: Set[str] = set()
    for qual, other in sf.funcs.items():
        if other.name in names:
            out.add(qual)
    return out


@register_rule(
    "TAO002",
    "host sync (.item/.tolist/float/np.asarray/block_until_ready) in a "
    "function reachable from a `# tao: hot` seed",
)
def check_hot_path(sf: SourceFile, analysis: Analysis) -> Iterator[Finding]:
    seeds = [q for q, fi in sf.funcs.items() if fi.hot]
    if not seeds:
        return

    origin: Dict[str, str] = {}   # hot qualname -> seed it is reachable from
    work: List[str] = []
    for q in seeds:
        origin[q] = q
        work.append(q)
    while work:
        q = work.pop()
        fi = sf.funcs[q]
        nxt: Set[str] = _callees(sf, fi)
        # lexically nested defs run in the hot region too
        nxt.update(
            other for other, o in sf.funcs.items()
            if o.parent == q
        )
        for callee in nxt:
            if callee in origin or sf.funcs[callee].cold:
                continue
            origin[callee] = origin[q]
            work.append(callee)

    for qual in sorted(origin):
        fi = sf.funcs[qual]
        via = (
            "" if qual == origin[qual]
            else f" (reachable from hot seed `{origin[qual]}`)"
        )
        for node in body_nodes(fi.node):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            label = None
            if isinstance(fn, ast.Attribute) and fn.attr in _SYNC_METHODS:
                label = f".{fn.attr}()"
            else:
                chain = attr_chain(fn)
                if chain in _SYNC_CALLS:
                    label = _SYNC_CALLS[chain]
            if label is None:
                continue
            if node.args and _is_device_get(node.args[0]):
                continue  # explicit device_get: the sanctioned sync form
            yield Finding(
                sf.display, node.lineno, node.col_offset, "TAO002",
                f"host sync `{label}` in hot path `{qual}`{via} — move it "
                "past the streaming loop or make the sync explicit via "
                "jax.device_get",
            )
