"""Logical-axis sharding rules (MaxText-style) for the multi-pod runtime.

Model code annotates activations/params with *logical* axis names; a rule
table maps them to mesh axes.  The mapper checks divisibility and silently
falls back to replication per-dimension, so every (arch × shape × mesh)
combination lowers even when e.g. 40 KV heads don't divide a 16-way model
axis.

Meshes:
  single-pod  (data=16, model=16)
  multi-pod   (pod=2, data=16, model=16)   — "pod" only ever carries batch.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax

from ..compat import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "LOGICAL_RULES",
    "mesh_context",
    "current_mesh",
    "logical_to_spec",
    "shard",
    "named_sharding",
    "spec_for_shape",
    "tree_shardings",
]

AxisSpec = Union[str, Tuple[str, ...], None]

# logical axis -> preferred mesh axes (joined), in priority order.
# "batch" spans the pod axis too: pure data parallelism across pods.
LOGICAL_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "seq": ("model",),          # sequence sharding (Megatron-SP style)
    "embed": (),                # residual d_model stays unsharded in activations
    "heads": ("model",),        # TP over attention heads
    "kv_heads": ("model",),
    # fallback TP dim: when a head count doesn't divide the model axis the
    # head_dim (always a multiple of 16 in the zoo) picks up the sharding
    "head_dim": ("model",),
    "mlp": ("model",),          # TP over FFN hidden
    "experts": ("model",),      # EP
    "expert_mlp": (),
    "vocab": ("model",),        # TP over vocab (embed + logits)
    "fsdp": ("data",),          # param d_model dim -> FSDP shard
    "conv": (),
    "state": (),
    "lru": ("model",),
    "cache_seq": ("model",),    # decode KV cache sharded along sequence
    "cache_batch": ("pod", "data"),
    "frames": (),
    "stack": (),                # scan-stacked layer dim, never sharded
}

_local = threading.local()


@contextlib.contextmanager
def mesh_context(mesh: Mesh, rules: Optional[Dict[str, Tuple[str, ...]]] = None):
    """Install a mesh + rule table; `shard()` is a no-op outside of it."""
    prev = getattr(_local, "ctx", None)
    _local.ctx = (mesh, rules or LOGICAL_RULES)
    try:
        from ..compat import activate_mesh

        with activate_mesh(mesh):
            yield mesh
    finally:
        _local.ctx = prev


def current_mesh() -> Optional[Mesh]:
    ctx = getattr(_local, "ctx", None)
    return ctx[0] if ctx else None


def _mesh_axis_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def logical_to_spec(
    logical_axes: Sequence[Optional[str]],
    shape: Optional[Sequence[int]] = None,
    mesh: Optional[Mesh] = None,
    rules: Optional[Dict[str, Tuple[str, ...]]] = None,
    allow_uneven: bool = False,
) -> P:
    """Map logical axis names to a PartitionSpec, checking divisibility when
    `shape` is given and degrading gracefully:

      * drop mesh axes missing from the mesh (e.g. "pod" on single-pod)
      * if the full axis-product doesn't divide the dim, try prefixes
      * replicate as the final fallback
    """
    ctx = getattr(_local, "ctx", None)
    if mesh is None and ctx:
        mesh = ctx[0]
    if rules is None:
        rules = (ctx[1] if ctx else LOGICAL_RULES)
    parts = []
    used: set = set()
    for i, name in enumerate(logical_axes):
        entry: AxisSpec = None
        if name is not None and mesh is not None:
            cand = tuple(a for a in rules.get(name, ()) if a in mesh.shape and a not in used)
            # prefer the longest prefix that divides the dim evenly
            want = cand
            while want:
                if shape is None or shape[i] % _mesh_axis_size(mesh, want) == 0:
                    break
                want = want[:-1]
            if not want and cand and shape is not None and allow_uneven:
                # GSPMD supports uneven (padded) sharding for activation
                # constraints (NOT for jit argument shardings); accept it when
                # the padding waste is < 2x (dim*2 >= shards): 40 heads on a
                # 16-way model axis pads to 48 instead of replicating 16x.
                uneven = cand
                while uneven:
                    if 2 * shape[i] >= _mesh_axis_size(mesh, uneven):
                        want = uneven
                        break
                    uneven = uneven[:-1]
            if want:
                entry = want if len(want) > 1 else want[0]
                used.update(want)
        parts.append(entry)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Apply a logical sharding constraint; no-op without a mesh context."""
    ctx = getattr(_local, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = logical_to_spec(
        logical_axes, shape=x.shape, mesh=mesh, rules=rules, allow_uneven=True
    )
    return jax.lax.with_sharding_constraint(x, spec)


def named_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def spec_for_shape(
    mesh: Mesh,
    logical_axes: Sequence[Optional[str]],
    shape: Sequence[int],
    rules: Optional[Dict[str, Tuple[str, ...]]] = None,
    allow_uneven: bool = False,
) -> NamedSharding:
    """NamedSharding for one array; forwards ``allow_uneven`` so callers
    get the same padded-sharding acceptance window as ``shard()``."""
    return NamedSharding(
        mesh,
        logical_to_spec(
            logical_axes, shape, mesh, rules, allow_uneven=allow_uneven
        ),
    )


def _is_axes_leaf(x) -> bool:
    """Logical-axis leaves are plain tuples of str/None (not NamedTuples,
    which are pytree nodes — e.g. TrainState axis trees)."""
    if x is None:
        return True
    return (
        isinstance(x, tuple)
        and not hasattr(x, "_fields")
        and all(e is None or isinstance(e, str) for e in x)
    )


def tree_shardings(tree_axes, tree_shapes, mesh: Mesh, rules=None):
    """Map a pytree of logical-axis tuples + matching shape pytree to
    NamedShardings (replicated where axes are None).

    The one partitioning helper the trainer, the launch dry-run, and the
    serving path share — hoisted here so every layer resolves logical
    axes through the same rule table.  ``tree_shapes`` leaves need only a
    ``.shape`` (ShapeDtypeStructs or arrays).
    """

    def one(axes, sds):
        if axes is None:
            return NamedSharding(mesh, P())
        return spec_for_shape(mesh, axes, sds.shape, rules)

    return jax.tree.map(one, tree_axes, tree_shapes, is_leaf=_is_axes_leaf)
