"""Mesh construction + multi-host runtime initialization.

This module owns *where the devices come from*; ``ExecutionPlan``
(``repro.engine.plan``) owns *how work is partitioned over them*.  Three
entry points cover every deployment shape:

  * ``initialize_multihost()`` — ``jax.distributed`` bring-up with a
    single-process fallback: on a laptop / single-host CI it is a no-op,
    on a pod slice (or with explicit coordinator args / the standard
    ``JAX_COORDINATOR_ADDRESS`` env) it joins the cluster, after which
    ``jax.devices()`` is the *global* device set and ``data_mesh()``
    spans hosts.
  * ``data_mesh()`` — the engine's mesh: every device on the ``data``
    axis (optionally ``("pod", "data")`` when ``pods`` is given), built
    through the compat shims so jax 0.4.x and 0.6+ agree.
  * ``virtual_cpu_devices(n)`` — the CI path: force the host CPU platform
    to present ``n`` devices (``XLA_FLAGS=--xla_force_host_platform_
    device_count``).  Must run before the jax backend initializes; raises
    with the exact flags to export when it is too late.

``topology_info()`` summarizes the runtime (device/process counts, mesh
shape, plan kind) — ``benchmarks/run.py --json`` embeds it so bench
artifacts are comparable across hosts.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Dict, Optional

__all__ = [
    "MultihostInfo",
    "initialize_multihost",
    "is_multihost",
    "data_mesh",
    "virtual_cpu_devices",
    "topology_info",
]

# env vars jax.distributed.initialize understands / we treat as the opt-in
_COORD_ENVS = ("JAX_COORDINATOR_ADDRESS", "COORDINATOR_ADDRESS")

_initialized: Optional["MultihostInfo"] = None


@dataclasses.dataclass(frozen=True)
class MultihostInfo:
    """What ``initialize_multihost`` decided and observed."""

    initialized: bool        # True when jax.distributed.initialize ran
    process_index: int
    process_count: int

    @property
    def is_multihost(self) -> bool:
        return self.process_count > 1


def initialize_multihost(
    coordinator_address: Optional[str] = None,
    num_processes: Optional[int] = None,
    process_id: Optional[int] = None,
    **kw,
) -> MultihostInfo:
    """Join (or skip) a multi-host jax cluster; idempotent.

    Runs ``jax.distributed.initialize`` only when the caller passed
    explicit coordinator args or the environment advertises one
    (``JAX_COORDINATOR_ADDRESS``); otherwise this is the single-process
    fallback — no cluster, no sockets, ``process_count == 1`` — so the
    same launch script works on a laptop, in CI, and on a pod slice.
    Call it before any other jax API touches the backend.
    """
    global _initialized
    wants_cluster = (
        coordinator_address is not None
        or num_processes not in (None, 1)
        or any(os.environ.get(e) for e in _COORD_ENVS)
    )
    if _initialized is not None:
        if wants_cluster and not _initialized.initialized:
            # an early no-arg call already resolved to the single-process
            # fallback; honoring the cached result would silently skip
            # the cluster join the caller is explicitly asking for
            raise RuntimeError(
                "initialize_multihost was already called without cluster "
                "arguments and fell back to single-process; call it with "
                "coordinator args FIRST (before any no-arg call touches "
                "the backend)"
            )
        return _initialized

    import jax

    if not wants_cluster:
        _initialized = MultihostInfo(
            initialized=False, process_index=0, process_count=1
        )
        return _initialized

    jax.distributed.initialize(
        coordinator_address=coordinator_address,
        num_processes=num_processes,
        process_id=process_id,
        **kw,
    )
    _initialized = MultihostInfo(
        initialized=True,
        process_index=jax.process_index(),
        process_count=jax.process_count(),
    )
    return _initialized


def is_multihost() -> bool:
    import jax

    return jax.process_count() > 1


def data_mesh(num_devices: Optional[int] = None, *, pods: Optional[int] = None):
    """The engine's data-parallel mesh over the *global* device set.

    ``(data=N,)`` by default; ``(pod=pods, data=N/pods)`` when ``pods``
    is given (the ``pod`` axis only ever carries batch, so inter-pod
    fabric sees pure data parallelism — same convention as
    ``launch/mesh.py``).  After ``initialize_multihost`` on a cluster,
    ``jax.devices()`` spans hosts and so does this mesh.
    """
    import jax

    from ..compat import make_mesh

    n = num_devices if num_devices is not None else len(jax.devices())
    if n < 1:
        raise ValueError(f"need at least one device, got {n}")
    if pods is None:
        return make_mesh((n,), ("data",))
    if n % pods:
        raise ValueError(f"{n} devices do not split into pods={pods}")
    return make_mesh((pods, n // pods), ("pod", "data"))


def virtual_cpu_devices(n: int) -> int:
    """CI path: make the host CPU platform present ``n`` XLA devices.

    Sets ``XLA_FLAGS=--xla_force_host_platform_device_count=n`` (and pins
    ``JAX_PLATFORMS=cpu``) if the jax backend has not initialized yet;
    raises with the exact environment to export when it is too late.
    Returns the resulting device count.  The ``shard-cpu`` CI job and the
    multi-device tests run under exactly this configuration.
    """
    if n < 1:
        raise ValueError(f"need n >= 1 virtual devices, got {n}")
    flag = f"--xla_force_host_platform_device_count={n}"

    # jax initializes backends lazily: if none exists yet, flags set now
    # still apply; if it turns out the backend already initialized (the
    # device-count check below), roll the env mutations back so the
    # failed attempt doesn't leak into this process or its children.
    saved_flags = os.environ.get("XLA_FLAGS")
    saved_platforms = os.environ.get("JAX_PLATFORMS")
    flags = saved_flags or ""
    if "xla_force_host_platform_device_count" in flags:
        # rewrite a leaked/stale count rather than keeping it: if the
        # backend has not initialized yet, the new value still wins
        import re

        flags = re.sub(
            r"--xla_force_host_platform_device_count=\d+", flag, flags
        )
        os.environ["XLA_FLAGS"] = flags
    else:
        os.environ["XLA_FLAGS"] = f"{flags} {flag}".strip()
    os.environ.setdefault("JAX_PLATFORMS", "cpu")

    import jax

    have = jax.device_count()
    if have < n:
        if saved_flags is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = saved_flags
        if saved_platforms is None:
            os.environ.pop("JAX_PLATFORMS", None)
        else:
            os.environ["JAX_PLATFORMS"] = saved_platforms
        raise RuntimeError(
            f"only {have} devices visible but {n} requested; the jax "
            "backend initialized before virtual_cpu_devices could set "
            f'XLA_FLAGS. Export XLA_FLAGS="{flag}" JAX_PLATFORMS=cpu '
            "before starting the process (see the shard-cpu CI job)."
        )
    return have


def topology_info(plan=None) -> Dict:
    """Runtime topology summary for bench artifacts / logs.

    Pass the ``ExecutionPlan`` the workload actually ran under to record
    it verbatim (``"plan"``); without one, only ``"default_plan"`` is
    reported — the plan ``data_mesh()`` WOULD resolve on this host — so
    artifacts never claim a partitioning that individual rows (which
    carry their own ``plan=...`` fields) did not use.
    """
    import jax

    n = jax.device_count()
    info = {
        "backend": jax.default_backend(),
        "device_count": n,
        "local_device_count": jax.local_device_count(),
        "process_index": jax.process_index(),
        "process_count": jax.process_count(),
    }
    if plan is not None:
        info["plan"] = plan.describe()
    else:
        info["default_plan"] = {
            "kind": "sharded" if n > 1 else "single",
            "mesh_shape": {"data": n} if n > 1 else {},
        }
    return info
