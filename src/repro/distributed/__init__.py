"""Distributed runtime: logical-axis sharding rules, mesh construction,
and multi-host initialization.

``sharding``   — the MaxText-style logical-axis rule table and mappers
                 (``logical_to_spec`` / ``shard`` / ``tree_shardings``).
``multihost``  — where devices come from: ``jax.distributed`` bring-up
                 with a single-process fallback, ``data_mesh()`` over the
                 global device set, and the ``virtual_cpu_devices`` CI
                 path (``XLA_FLAGS=--xla_force_host_platform_device_count``).

How work is *partitioned* over a mesh lives in ``repro.engine.plan``
(``ExecutionPlan``), which the simulation engine, the sweep scheduler,
and the streaming trainer all consume.
"""
from .multihost import (
    MultihostInfo,
    data_mesh,
    initialize_multihost,
    is_multihost,
    topology_info,
    virtual_cpu_devices,
)
from .sharding import (
    LOGICAL_RULES,
    current_mesh,
    logical_to_spec,
    mesh_context,
    named_sharding,
    shard,
    spec_for_shape,
    tree_shardings,
)

__all__ = [
    "LOGICAL_RULES",
    "mesh_context",
    "current_mesh",
    "logical_to_spec",
    "shard",
    "named_sharding",
    "spec_for_shape",
    "tree_shardings",
    "MultihostInfo",
    "initialize_multihost",
    "is_multihost",
    "data_mesh",
    "virtual_cpu_devices",
    "topology_info",
]
