from .sharding import (
    LOGICAL_RULES,
    current_mesh,
    logical_to_spec,
    mesh_context,
    named_sharding,
    shard,
    spec_for_shape,
)

__all__ = [
    "LOGICAL_RULES",
    "mesh_context",
    "current_mesh",
    "logical_to_spec",
    "shard",
    "named_sharding",
    "spec_for_shape",
]
