"""Jit'd public wrapper for the flash-attention kernel.

On TPU this lowers the Pallas kernel natively; on CPU (this container) the
kernel body executes under ``interpret=True``, which runs the same program
in Python for correctness validation.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ...compat import on_tpu
from .kernel import flash_attention_pallas

__all__ = ["default_block_size", "flash_attention"]

# Long windows amortize the per-tile softmax-state update over more MXU
# work: past this sequence length the default tile doubles to 256.
LONG_SEQ = 2048


def default_block_size(seq: int) -> int:
    """Default flash-attention tile edge for a sequence length."""
    return 256 if seq >= LONG_SEQ else 128


@functools.partial(
    jax.jit, static_argnames=("causal", "q_offset", "block_q", "block_k")
)
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    segment_ids: Optional[jnp.ndarray] = None,
    *,
    causal: bool = True,
    q_offset: int = 0,
    block_q: Optional[int] = None,
    block_k: Optional[int] = None,
) -> jnp.ndarray:
    """Flash attention over (B, H, S, D) operands (GQA pre-expanded).

    ``segment_ids`` ((B, Sk) int32, optional) confines attention to equal-
    id spans — packed windows never attend across their boundary.
    ``block_q``/``block_k`` default per sequence length
    (``default_block_size``: 256 for S >= 2048, else 128).
    """
    if block_q is None:
        block_q = default_block_size(q.shape[2])
    if block_k is None:
        block_k = default_block_size(k.shape[2])
    return flash_attention_pallas(
        q,
        k,
        v,
        segment_ids,
        causal=causal,
        q_offset=q_offset,
        block_q=block_q,
        block_k=block_k,
        interpret=not on_tpu(),
    )
