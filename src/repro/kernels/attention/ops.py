"""Jit'd public wrapper for the flash-attention kernel.

On TPU this lowers the Pallas kernel natively; on CPU (this container) the
kernel body executes under ``interpret=True``, which runs the same program
in Python for correctness validation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...compat import on_tpu
from .kernel import flash_attention_pallas

__all__ = ["flash_attention"]


@functools.partial(
    jax.jit, static_argnames=("causal", "q_offset", "block_q", "block_k")
)
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
) -> jnp.ndarray:
    """Flash attention over (B, H, S, D) operands (GQA pre-expanded)."""
    return flash_attention_pallas(
        q,
        k,
        v,
        causal=causal,
        q_offset=q_offset,
        block_q=block_q,
        block_k=block_k,
        interpret=not on_tpu(),
    )
