"""Pallas TPU flash-attention kernel.

Grid: (B, H, num_q_blocks, num_k_blocks) with the k dimension marked
"arbitrary" (sequential) so the online-softmax state (m, l, acc) lives in
VMEM scratch across k steps.  Block shapes are (block_q, head_dim) /
(block_k, head_dim) tiles staged HBM->VMEM by BlockSpec; head_dim and the
block sizes are multiples of 128 to keep the MXU fully utilized.

Causal masking happens at two granularities:

  * **static** — ``q_offset`` and the sequence lengths are trace-time
    constants, so k-blocks that sit entirely above the causal diagonal for
    EVERY q-block (``first_k > q_offset + Sq - 1``) are clamped out of the
    grid itself and never scheduled (zero DMA, zero FLOPs);
  * **dynamic** — within the clamped grid, a per-tile ``pl.when``
    predicate skips the remaining fully-masked (qi, ki) tiles of the
    triangular schedule, and the in-tile position mask handles the
    diagonal blocks element-wise.

Optional ``segment_ids`` fold a per-tile segment-equality mask into the
position mask so windows packed back-to-back in one sequence never attend
across their boundary (the fused backend's batched-window layout).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_kernel", "flash_attention_pallas"]

NEG_INF = -1e30


def flash_attention_kernel(
    *refs,
    block_q: int,
    block_k: int,
    seq_k: int,
    causal: bool,
    q_offset: int,
    scale: float,
    segmented: bool,
):
    if segmented:
        q_ref, k_ref, v_ref, segq_ref, segk_ref, o_ref = refs[:6]
        m_scr, l_scr, acc_scr = refs[6:]
    else:
        q_ref, k_ref, v_ref, o_ref = refs[:4]
        m_scr, l_scr, acc_scr = refs[4:]
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    )
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )

    # Fully-above-diagonal tiles: k-blocks masked for EVERY q-block were
    # already clamped out of the grid (static, see flash_attention_pallas);
    # the interior triangular skip depends on qi/ki — grid indices — so it
    # is necessarily a dynamic per-tile predicate.
    last_q = q_offset + qi * block_q + (block_q - 1)
    first_k = ki * block_k
    run = (last_q >= first_k) if causal else (ki >= 0)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                     # (bq, bk)
        mask = k_pos < seq_k
        if causal:
            mask &= q_pos >= k_pos
        if segmented:
            sq = segq_ref[0]                          # (bq,)
            sk = segk_ref[0]                          # (bk,)
            mask &= sq[:, None] == sk[None, :]
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                           # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)           # (bk, dv)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    segment_ids: jnp.ndarray | None = None,
    *,
    causal: bool = True,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """q,k,v: (B, H, S, D) (GQA already expanded).  Returns (B, H, Sq, D).

    ``segment_ids``: optional (B, Sk) int32 — positions only attend within
    their own segment (q rows take theirs from ``q_offset + row``, so
    ``Sq < Sk`` decode-style calls work too).
    """
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    Dv = v.shape[3]
    scale = 1.0 / math.sqrt(D)
    bq = min(block_q, max(Sq, 8))
    bk = min(block_k, max(Sk, 8))
    nq = -(-Sq // bq)
    nk = -(-Sk // bk)
    if causal:
        # Static diagonal clamp: q_offset/Sq/bk are trace-time ints, so
        # k-blocks past the last query position (first_k > q_offset+Sq-1,
        # i.e. masked for ALL q-blocks) are simply never part of the grid.
        nk = max(1, min(nk, -(-(q_offset + Sq) // bk)))
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, nq * bq - Sq), (0, 0)))
    # the clamp may leave nk*bk < Sk — those key blocks are dead for every
    # query, so slice them off (pad only when rounding UP to the tile edge)
    kv_len = nk * bk
    if kv_len >= Sk:
        kp = jnp.pad(k, ((0, 0), (0, 0), (0, kv_len - Sk), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, 0), (0, kv_len - Sk), (0, 0)))
    else:
        kp = k[:, :, :kv_len]
        vp = v[:, :, :kv_len]

    segmented = segment_ids is not None
    kernel = functools.partial(
        flash_attention_kernel,
        block_q=bq,
        block_k=bk,
        seq_k=Sk,
        causal=causal,
        q_offset=q_offset,
        scale=scale,
        segmented=segmented,
    )
    in_specs = [
        pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki: (b, h, ki, 0)),
        pl.BlockSpec((1, 1, bk, Dv), lambda b, h, qi, ki: (b, h, ki, 0)),
    ]
    operands = [qp, kp, vp]
    if segmented:
        if segment_ids.shape != (B, Sk):
            raise ValueError(
                f"segment_ids must be (B, Sk)=({B}, {Sk}), got "
                f"{segment_ids.shape}"
            )
        seg = segment_ids.astype(jnp.int32)
        # q rows read segment ids at their absolute positions; distinct
        # sentinels on the two pads keep padded rows from ever matching
        segq = jax.lax.dynamic_slice_in_dim(
            jnp.pad(seg, ((0, 0), (0, max(0, q_offset + Sq - Sk))),
                    constant_values=-2),
            q_offset, Sq, axis=1,
        )
        segq = jnp.pad(segq, ((0, 0), (0, nq * bq - Sq)), constant_values=-2)
        if kv_len >= Sk:
            segk = jnp.pad(seg, ((0, 0), (0, kv_len - Sk)), constant_values=-1)
        else:
            segk = seg[:, :kv_len]
        in_specs += [
            pl.BlockSpec((1, bq), lambda b, h, qi, ki: (b, qi)),
            pl.BlockSpec((1, bk), lambda b, h, qi, ki: (b, ki)),
        ]
        operands += [segq, segk]
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, bq, Dv), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, Dv), q.dtype),
        scratch_shapes=[
            pltpu_scratch((bq, 1)),
            pltpu_scratch((bq, 1)),
            pltpu_scratch((bq, Dv)),
        ],
        compiler_params=dict(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        )
        if not interpret
        else None,
        interpret=interpret,
    )(*operands)
    return out[:, :, :Sq, :]


def pltpu_scratch(shape):
    """VMEM f32 scratch allocation (TPU memory space)."""
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)
