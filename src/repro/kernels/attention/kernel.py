"""Pallas TPU flash-attention kernel.

Grid: (B, H, num_q_blocks, num_k_blocks) with the k dimension marked
"arbitrary" (sequential) so the online-softmax state (m, l, acc) lives in
VMEM scratch across k steps.  Block shapes are (block_q, head_dim) /
(block_k, head_dim) tiles staged HBM->VMEM by BlockSpec; head_dim and the
block sizes are multiples of 128 to keep the MXU fully utilized.

Causal masking is applied per-tile from absolute positions; fully-masked
tiles are skipped (the classic flash-attention triangular schedule).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_kernel", "flash_attention_pallas"]

NEG_INF = -1e30


def flash_attention_kernel(
    q_ref, k_ref, v_ref,       # inputs (VMEM tiles)
    o_ref,                     # output tile
    m_scr, l_scr, acc_scr,     # VMEM scratch carried over the k grid dim
    *,
    block_q: int,
    block_k: int,
    seq_k: int,
    causal: bool,
    q_offset: int,
    scale: float,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0
    )
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1
    )

    # Skip tiles that are entirely above the causal diagonal.
    first_q = q_offset + qi * block_q
    last_q = first_q + block_q - 1
    first_k = ki * block_k
    run = True
    if causal:
        run = last_q >= first_k  # static per-tile predicate? positions are
        # trace-time ints only when q_offset is static; keep dynamic:
        run = jnp.asarray(last_q >= first_k)

    @pl.when(run if causal else jnp.asarray(True))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                     # (bq, bk)
        mask = k_pos < seq_k
        if causal:
            mask &= q_pos >= k_pos
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                           # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_new = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)           # (bk, dv)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scr[...] = acc_scr[...] * corr + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """q,k,v: (B, H, S, D) (GQA already expanded).  Returns (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    Dv = v.shape[3]
    scale = 1.0 / math.sqrt(D)
    bq = min(block_q, max(Sq, 8))
    bk = min(block_k, max(Sk, 8))
    nq = -(-Sq // bq)
    nk = -(-Sk // bk)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, nq * bq - Sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, nk * bk - Sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, nk * bk - Sk), (0, 0)))

    kernel = functools.partial(
        flash_attention_kernel,
        block_q=bq,
        block_k=bk,
        seq_k=Sk,
        causal=causal,
        q_offset=q_offset,
        scale=scale,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, Dv), lambda b, h, qi, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, Dv), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, Dv), q.dtype),
        scratch_shapes=[
            pltpu_scratch((bq, 1)),
            pltpu_scratch((bq, 1)),
            pltpu_scratch((bq, Dv)),
        ],
        compiler_params=dict(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        )
        if not interpret
        else None,
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :Sq, :]


def pltpu_scratch(shape):
    """VMEM f32 scratch allocation (TPU memory space)."""
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)
