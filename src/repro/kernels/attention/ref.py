"""Pure-jnp oracle for the flash-attention kernel (naive softmax attention;
materializes the full score matrix — test shapes only)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    q_offset: int = 0,
) -> jnp.ndarray:
    """q,k,v: (B,H,S,D); returns (B,H,Sq,Dv) in fp32 math."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(D)
    if causal:
        qpos = q_offset + jnp.arange(Sq)
        kpos = jnp.arange(Sk)
        mask = qpos[:, None] >= kpos[None, :]
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
