"""Pure-jnp oracle for the flash-attention kernel (naive softmax attention;
materializes the full score matrix — test shapes only)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = ["attention_ref"]


def attention_ref(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    segment_ids: jnp.ndarray | None = None,
    *,
    causal: bool = True,
    q_offset: int = 0,
) -> jnp.ndarray:
    """q,k,v: (B,H,S,D); returns (B,H,Sq,Dv) in fp32 math.  Optional
    ``segment_ids`` (B, Sk): rows attend only within their own segment."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)
    ) / math.sqrt(D)
    mask = jnp.ones((B, 1, Sq, Sk), dtype=bool)
    if causal:
        qpos = q_offset + jnp.arange(Sq)
        kpos = jnp.arange(Sk)
        mask &= (qpos[:, None] >= kpos[None, :])[None, None]
    if segment_ids is not None:
        seg = segment_ids.astype(jnp.int32)
        segq = jax.lax.dynamic_slice_in_dim(
            jnp.pad(seg, ((0, 0), (0, max(0, q_offset + Sq - Sk))),
                    constant_values=-2),
            q_offset, Sq, axis=1,
        )
        mask &= (segq[:, :, None] == seg[:, None, :])[:, None]
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    # a row with zero visible keys softmaxes NaN; such rows are padding by
    # construction — zero them so bitwise comparisons stay meaningful
    p = jnp.where(jnp.any(mask, axis=-1, keepdims=True), p, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
