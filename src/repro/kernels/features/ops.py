"""Public wrappers for the §4.2 feature-extraction Pallas kernels.

On TPU the kernels lower natively through Mosaic; everywhere else they run
under ``interpret=True`` so CPU CI exercises the same programs.  The
contract — enforced by ``tests/test_feature_kernels.py`` — is that the
device extraction is **bit-identical** to the NumPy specification
(``core.features.extract_features`` / ``extract_features_reference``):

  * branch-history rows are copies of {-1, 0, +1} values (exact);
  * memory-distance deltas are int32 subtractions (exact) converted to
    float32 (correctly rounded), with the signed-log compression applied by
    ``signed_log_device`` — an op-per-dispatch jax twin of
    ``core.features.signed_log``.  Each multiply/add runs as its own XLA
    dispatch; fusing them into one jit would let XLA contract `a*b + c`
    into fma (one rounding instead of two) and break bit-equality.

``trace_columns`` does the cheap host-side prep (bucket hash on the int64
pc, int32 address narrowing) and returns None when addresses fall outside
the int32-exact window, in which case callers fall back to the NumPy path.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ...compat import on_tpu
from ...core import features as _features
from ...core.features import (
    SIGNED_LOG_COEFFS,
    SIGNED_LOG_SQRT2,
    FeatureConfig,
    FeatureSet,
)
from ...uarch.isa import NUM_REGS, Op
from .kernel import branch_history_pallas, memdist_delta_pallas

__all__ = [
    "signed_log_device",
    "branch_history_scan",
    "memdist_delta_scan",
    "trace_columns",
    "device_feature_arrays",
    "extract_features_device",
    "ADDR_EXACT_LIMIT",
]

# Addresses must stay within this bound for int32 deltas to be exact (and
# overflow-free: |a - b| < 2^31 when |a|, |b| < 2^30).
ADDR_EXACT_LIMIT = 2**30

DEFAULT_CHUNK = 512


# tao: bitwise
def signed_log_device(d: jnp.ndarray) -> jnp.ndarray:
    """Bit-exact jax twin of ``core.features.signed_log``.

    Must run EAGERLY (op per dispatch): each operation is then individually
    rounded, matching NumPy bit for bit.  Do not wrap in ``jax.jit`` — XLA's
    fma contraction of `a*b + c` would round once instead of twice and
    diverge from the NumPy backend in the last ulp.
    """
    d = jnp.asarray(d, jnp.float32)
    a = jnp.abs(d)
    x = jnp.float32(1.0) + a
    bits = jax.lax.bitcast_convert_type(x, jnp.int32)
    e = ((bits >> 23) & jnp.int32(0xFF)) - jnp.int32(127)
    m = jax.lax.bitcast_convert_type(
        (bits & jnp.int32(0x007FFFFF)) | jnp.int32(0x3F800000), jnp.float32
    )
    big = m > SIGNED_LOG_SQRT2
    m = jnp.where(big, m * jnp.float32(0.5), m)
    e = (e + big).astype(jnp.float32)
    s = (m - jnp.float32(1.0)) / (m + jnp.float32(1.0))
    z = s * s
    p = jnp.full_like(z, SIGNED_LOG_COEFFS[-1])
    for c in SIGNED_LOG_COEFFS[-2::-1]:
        p = p * z
        p = p + jnp.float32(c)
    r = p * s
    r = r + e
    r = r * jnp.float32(1.0 / 32.0)
    return jnp.where(d < 0, -r, r)


@functools.partial(
    jax.jit, static_argnames=("n_buckets", "n_queue", "chunk", "interpret")
)
def _branch_history_padded(bucket, outcome, *, n_buckets, n_queue, chunk, interpret):
    n = bucket.shape[0]
    nc = max(1, -(-n // chunk))
    pad = nc * chunk - n
    b2 = jnp.pad(bucket, (0, pad)).reshape(nc, chunk)
    o2 = jnp.pad(outcome, (0, pad)).reshape(nc, chunk)  # pad rows: non-branch
    out = branch_history_pallas(
        b2, o2, n_buckets=n_buckets, n_queue=n_queue, interpret=interpret
    )
    return out.reshape(nc * chunk, n_queue)[:n]


def branch_history_scan(
    bucket,
    outcome,
    *,
    n_buckets: int,
    n_queue: int,
    chunk: int = DEFAULT_CHUNK,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """(n,) bucket ids + outcomes -> (n, n_queue) branch-history features."""
    if interpret is None:
        interpret = not on_tpu()
    bucket = jnp.asarray(bucket, jnp.int32)
    outcome = jnp.asarray(outcome, jnp.float32)
    if bucket.shape[0] == 0:
        return jnp.zeros((0, n_queue), jnp.float32)
    return _branch_history_padded(
        bucket,
        outcome,
        n_buckets=n_buckets,
        n_queue=n_queue,
        chunk=chunk,
        interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("n_mem", "chunk", "interpret"))
def _memdist_padded(addr, mem, *, n_mem, chunk, interpret):
    n = addr.shape[0]
    nc = max(1, -(-n // chunk))
    pad = nc * chunk - n
    a2 = jnp.pad(addr, (0, pad)).reshape(nc, chunk)
    m2 = jnp.pad(mem, (0, pad)).reshape(nc, chunk)  # pad rows: non-mem
    out = memdist_delta_pallas(a2, m2, n_mem=n_mem, interpret=interpret)
    return out.reshape(nc * chunk, n_mem)[:n]


def memdist_delta_scan(
    addr,
    mem,
    *,
    n_mem: int,
    chunk: int = DEFAULT_CHUNK,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """(n,) int32 addresses + mem mask -> (n, n_mem) RAW float32 deltas."""
    if interpret is None:
        interpret = not on_tpu()
    addr = jnp.asarray(addr, jnp.int32)
    mem = jnp.asarray(mem, jnp.int32)
    if addr.shape[0] == 0:
        return jnp.zeros((0, n_mem), jnp.float32)
    return _memdist_padded(
        addr, mem, n_mem=n_mem, chunk=chunk, interpret=interpret
    )


def trace_columns(
    trace: np.ndarray, cfg: FeatureConfig
) -> Optional[Dict[str, np.ndarray]]:
    """Host-side prep of the device extraction inputs.

    Bucket hashing runs on the host so the int64 pc is handled exactly;
    everything shipped to the device is int32/float32.  Returns None when
    addresses exceed the int32-exact window (|addr| >= 2^30) — the caller
    must then fall back to the NumPy backend.
    """
    addr = trace["addr"]
    if len(addr) and int(np.abs(addr).max()) >= ADDR_EXACT_LIMIT:
        return None
    # Minimal payload (~28 B/instr): branch outcomes and the mem mask are
    # derived on device from the bool columns instead of being shipped as
    # widened duplicates.
    return {
        "bucket": ((trace["pc"] >> 2) % cfg.n_buckets).astype(np.int32),
        "addr": addr.astype(np.int32),
        "opcode": trace["opcode"].astype(np.int32),
        "dst": trace["dst"].astype(np.int32),
        "src1": trace["src1"].astype(np.int32),
        "src2": trace["src2"].astype(np.int32),
        "is_branch": trace["is_branch"],
        "taken": trace["taken"],
        "is_mem": trace["is_mem"],
        "is_store": trace["is_store"],
    }


@jax.jit
def _per_instruction_device(opcode, dst, src1, src2, is_branch, taken, is_mem, is_store):
    # Exact integer/boolean -> float32 ops only: safe to fuse in one jit.
    reg = jnp.arange(NUM_REGS, dtype=jnp.int32)[None, :]
    regbits = (
        (reg == dst[:, None]) | (reg == src1[:, None]) | (reg == src2[:, None])
    ).astype(jnp.float32)
    is_fp = (
        (opcode == int(Op.FALU)) | (opcode == int(Op.FMUL)) | (opcode == int(Op.FDIV))
    )
    flags = jnp.stack(
        [is_branch, taken, is_mem, is_store, is_fp], axis=1
    ).astype(jnp.float32)
    # Scan-kernel inputs derived on device (exact selects/casts): ±1/0
    # branch outcomes and the int32 mem mask.
    outcome = jnp.where(
        is_branch,
        jnp.where(taken, jnp.float32(1.0), jnp.float32(-1.0)),
        jnp.float32(0.0),
    )
    mem = is_mem.astype(jnp.int32)
    return regbits, flags, outcome, mem


def device_feature_arrays(
    cols: Dict[str, np.ndarray],
    cfg: FeatureConfig,
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: Optional[bool] = None,
) -> Dict[str, jnp.ndarray]:
    """Run the full device extraction; returns (n, ·) jnp arrays keyed like
    ``core.dataset.INPUT_KEYS``, plus the device-resident ``is_branch`` /
    ``is_mem`` bool columns so callers (the engine's device batch path)
    never re-upload them.  All values stay on device."""
    is_branch = jnp.asarray(cols["is_branch"])
    is_mem = jnp.asarray(cols["is_mem"])
    regbits, flags, outcome, mem = _per_instruction_device(
        jnp.asarray(cols["opcode"]),
        jnp.asarray(cols["dst"]),
        jnp.asarray(cols["src1"]),
        jnp.asarray(cols["src2"]),
        is_branch,
        jnp.asarray(cols["taken"]),
        is_mem,
        jnp.asarray(cols["is_store"]),
    )
    brhist = branch_history_scan(
        cols["bucket"],
        outcome,
        n_buckets=cfg.n_buckets,
        n_queue=cfg.n_queue,
        chunk=chunk,
        interpret=interpret,
    )
    deltas = memdist_delta_scan(
        cols["addr"],
        mem,
        n_mem=cfg.n_mem,
        chunk=chunk,
        interpret=interpret,
    )
    memdist = signed_log_device(deltas)  # eager: keeps NumPy bit-equality
    return {
        "opcode": jnp.asarray(cols["opcode"], jnp.int32),
        "regbits": regbits,
        "flags": flags,
        "brhist": brhist,
        "memdist": memdist,
        "is_branch": is_branch,
        "is_mem": is_mem,
    }


def extract_features_device(
    trace: np.ndarray,
    cfg: FeatureConfig = FeatureConfig(),
    with_labels: bool = True,
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: Optional[bool] = None,
) -> FeatureSet:
    """Drop-in twin of ``core.features.extract_features`` backed by the
    Pallas kernels; raises ValueError when addresses exceed the int32-exact
    window (use the NumPy extractor there)."""
    cols = trace_columns(trace, cfg)
    if cols is None:
        raise ValueError(
            f"trace addresses exceed |addr| < 2^30 (= {ADDR_EXACT_LIMIT}); "
            "int32 device deltas would be inexact — use extract_features"
        )
    arrays = device_feature_arrays(cols, cfg, chunk=chunk, interpret=interpret)
    return FeatureSet(
        opcode=np.asarray(arrays["opcode"]),
        regbits=np.asarray(arrays["regbits"]),
        flags=np.asarray(arrays["flags"]),
        brhist=np.asarray(arrays["brhist"]),
        memdist=np.asarray(arrays["memdist"]),
        labels=_features._labels(trace, with_labels),
    )
