"""Pallas TPU kernels for §4.2 cross-instruction feature extraction.

Both kernels are sequential scans over the trace, gridded over trace chunks
with the recurrent state carried in VMEM/SMEM scratch across grid steps —
the same chunk-carry pattern as the SSD kernel (``kernels/ssd/kernel.py``):

  * **branch history** — the (N_b, N_q) per-bucket outcome table lives in
    VMEM scratch; each trace position reads its bucket's queue (the feature
    row), then pushes the branch outcome most-recent-first.  Non-branch
    positions leave the table untouched and emit a zero row.
  * **memory distance** — the last N_m access addresses live in an int32
    VMEM queue (plus an SMEM fill counter).  Each memory access emits the
    raw address deltas against the queue; non-memory positions emit zeros.

The memory-distance kernel deliberately returns RAW int32-derived deltas as
float32 (int32 subtraction is exact; int→float32 conversion is correctly
rounded) rather than applying the signed-log compression in-kernel: inside
one compiled program XLA contracts `a*b + c` chains into fma, which breaks
bit-reproducibility against the NumPy backend.  The caller applies
``ops.signed_log_device`` — an op-per-dispatch twin of
``core.features.signed_log`` — to stay bit-identical (see the comment
there).

Grid semantics: the single chunk dimension is "arbitrary" (sequential), so
scratch state flows from chunk to chunk.  Off-TPU the same programs run
under ``interpret=True``, which is how CPU CI exercises them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["branch_history_pallas", "memdist_delta_pallas"]


def branch_history_kernel(
    bucket_ref,   # (1, chunk) int32 — (pc >> 2) % N_b, any value on pad rows
    outcome_ref,  # (1, chunk) f32  — +1 taken / -1 not-taken / 0 non-branch
    out_ref,      # out (1, chunk, n_queue) f32
    table_scr,    # VMEM (n_buckets, n_queue) f32 — carried across chunks
    *,
    chunk: int,
):
    ci = pl.program_id(0)

    @pl.when(ci == 0)
    def _init():
        table_scr[...] = jnp.zeros_like(table_scr)

    bucket = bucket_ref[0, :]
    outcome = outcome_ref[0, :]

    def body(i, carry):
        b = bucket[i]
        o = outcome[i]
        is_br = o != 0.0
        row = table_scr[pl.ds(b, 1), :]                     # (1, n_queue)
        out_ref[0, pl.ds(i, 1), :] = jnp.where(is_br, row, 0.0)
        pushed = jnp.concatenate(
            [jnp.full((1, 1), o, row.dtype), row[:, :-1]], axis=1
        )
        table_scr[pl.ds(b, 1), :] = jnp.where(is_br, pushed, row)
        return carry

    jax.lax.fori_loop(0, chunk, body, 0)


def memdist_delta_kernel(
    addr_ref,   # (1, chunk) int32 — byte address, any value on non-mem rows
    mem_ref,    # (1, chunk) int32 — 1 for memory ops, 0 otherwise
    out_ref,    # out (1, chunk, n_mem) f32 — raw deltas, 0 on invalid slots
    queue_scr,  # VMEM (1, n_mem) int32 — carried across chunks
    fill_scr,   # SMEM (1,) int32 — how many queue slots hold real addresses
    *,
    chunk: int,
    n_mem: int,
):
    ci = pl.program_id(0)

    @pl.when(ci == 0)
    def _init():
        queue_scr[...] = jnp.zeros_like(queue_scr)
        fill_scr[0] = 0

    addr = addr_ref[0, :]
    mem = mem_ref[0, :]
    slot = jax.lax.broadcasted_iota(jnp.int32, (1, n_mem), 1)

    def body(i, carry):
        a = addr[i]
        is_mem = mem[i] != 0
        q = queue_scr[...]                                  # (1, n_mem)
        filled = fill_scr[0]
        valid = (slot < filled) & is_mem
        delta = (a - q).astype(jnp.float32)                  # exact int32 sub
        out_ref[0, pl.ds(i, 1), :] = jnp.where(valid, delta, 0.0)
        pushed = jnp.concatenate(
            [jnp.full((1, 1), a, q.dtype), q[:, :-1]], axis=1
        )
        queue_scr[...] = jnp.where(is_mem, pushed, q)
        fill_scr[0] = jnp.where(
            is_mem, jnp.minimum(filled + 1, n_mem), filled
        )
        return carry

    jax.lax.fori_loop(0, chunk, body, 0)


def _vmem(shape, dtype=jnp.float32):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def _smem(shape, dtype=jnp.int32):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.SMEM(shape, dtype)


def branch_history_pallas(
    bucket: jnp.ndarray,   # (nc, chunk) int32
    outcome: jnp.ndarray,  # (nc, chunk) f32
    *,
    n_buckets: int,
    n_queue: int,
    interpret: bool = False,
) -> jnp.ndarray:
    nc, chunk = bucket.shape
    kernel = functools.partial(branch_history_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(nc,),
        in_specs=[
            pl.BlockSpec((1, chunk), lambda c: (c, 0)),
            pl.BlockSpec((1, chunk), lambda c: (c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, n_queue), lambda c: (c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nc, chunk, n_queue), jnp.float32),
        scratch_shapes=[_vmem((n_buckets, n_queue))],
        compiler_params=dict(dimension_semantics=("arbitrary",))
        if not interpret
        else None,
        interpret=interpret,
    )(bucket, outcome)


def memdist_delta_pallas(
    addr: jnp.ndarray,  # (nc, chunk) int32
    mem: jnp.ndarray,   # (nc, chunk) int32
    *,
    n_mem: int,
    interpret: bool = False,
) -> jnp.ndarray:
    nc, chunk = addr.shape
    kernel = functools.partial(memdist_delta_kernel, chunk=chunk, n_mem=n_mem)
    return pl.pallas_call(
        kernel,
        grid=(nc,),
        in_specs=[
            pl.BlockSpec((1, chunk), lambda c: (c, 0)),
            pl.BlockSpec((1, chunk), lambda c: (c, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, n_mem), lambda c: (c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nc, chunk, n_mem), jnp.float32),
        scratch_shapes=[_vmem((1, n_mem), jnp.int32), _smem((1,), jnp.int32)],
        compiler_params=dict(dimension_semantics=("arbitrary",))
        if not interpret
        else None,
        interpret=interpret,
    )(addr, mem)
