"""Pure-jnp scan oracles for the feature-extraction kernels.

Same role as ``kernels/attention/ref.py`` / ``kernels/ssd/ref.py``: a
direct, obviously-correct jax formulation the Pallas programs are tested
against.  The executable *NumPy* specification remains
``core.features.extract_features_reference``; these oracles mirror the
per-position scan semantics in jax so kernel tests can compare like with
like (raw deltas before signed-log compression, padded shapes, etc.).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["branch_history_scan_ref", "memdist_delta_scan_ref"]


@functools.partial(jax.jit, static_argnames=("n_buckets", "n_queue"))
def branch_history_scan_ref(
    bucket: jnp.ndarray,   # (n,) int32
    outcome: jnp.ndarray,  # (n,) f32 in {-1, 0, +1}
    *,
    n_buckets: int,
    n_queue: int,
) -> jnp.ndarray:
    """(n, n_queue) f32 — each branch's bucket queue before its own push."""

    def step(table, bo):
        b, o = bo
        is_br = o != 0.0
        row = table[b]
        out = jnp.where(is_br, row, 0.0)
        pushed = jnp.concatenate([o[None], row[:-1]])
        table = table.at[b].set(jnp.where(is_br, pushed, row))
        return table, out

    init = jnp.zeros((n_buckets, n_queue), jnp.float32)
    _, rows = jax.lax.scan(step, init, (bucket, outcome))
    return rows


@functools.partial(jax.jit, static_argnames=("n_mem",))
def memdist_delta_scan_ref(
    addr: jnp.ndarray,  # (n,) int32
    mem: jnp.ndarray,   # (n,) int32 (0/1)
    *,
    n_mem: int,
) -> jnp.ndarray:
    """(n, n_mem) f32 — raw address deltas vs the previous n_mem accesses."""

    def step(carry, am):
        queue, filled = carry
        a, m = am
        is_mem = m != 0
        valid = (jnp.arange(n_mem) < filled) & is_mem
        out = jnp.where(valid, (a - queue).astype(jnp.float32), 0.0)
        pushed = jnp.concatenate([a[None], queue[:-1]])
        queue = jnp.where(is_mem, pushed, queue)
        filled = jnp.where(is_mem, jnp.minimum(filled + 1, n_mem), filled)
        return (queue, filled), out

    init = (jnp.zeros((n_mem,), jnp.int32), jnp.int32(0))
    _, rows = jax.lax.scan(step, init, (addr, mem))
    return rows
