"""Jit'd public wrapper for the SSD chunked-scan kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...compat import on_tpu
from .kernel import ssd_pallas

__all__ = ["ssd_scan"]


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(
    xh: jnp.ndarray,
    dt: jnp.ndarray,
    A: jnp.ndarray,
    Bm: jnp.ndarray,
    Cm: jnp.ndarray,
    *,
    chunk: int = 256,
) -> jnp.ndarray:
    """Chunked SSD scan: xh (B,S,H,P), dt (B,S,H), A (H,), B/C (B,S,G,N)."""
    return ssd_pallas(xh, dt, A, Bm, Cm, chunk=chunk, interpret=not on_tpu())
