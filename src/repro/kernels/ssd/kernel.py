"""Pallas TPU kernel for the Mamba-2 SSD (state-space dual) chunked scan.

Grid: (B, H, num_chunks) with the chunk dimension "arbitrary" (sequential);
the (N, P) inter-chunk state lives in VMEM scratch and is carried across
chunk steps — the recurrent half of SSD.  Within a chunk the quadratic
(attention-like) form runs on the MXU:

    y_diag = (L ⊙ (C Bᵀ)) diag(dt) X          (c×c masked matmul)
    y_off  = exp(cums) ⊙ (C · state)
    state' = state · exp(cums_last) + Bᵀ diag(dt·decay_to_end) X

Chunk length and head_dim tiles are chosen MXU-friendly (multiples of 128
on the contraction dims where the config allows).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ssd_kernel", "ssd_pallas"]


def ssd_kernel(
    x_ref,     # (1, c, 1, P)
    dt_ref,    # (1, c, 1)
    a_ref,     # (1,)  decay rate for this head (negative)
    b_ref,     # (1, c, 1, N)
    c_ref,     # (1, c, 1, N)
    y_ref,     # out (1, c, 1, P)
    state_scr,  # VMEM (N, P) f32
    *,
    chunk: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # (c, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # (c,)
    a = a_ref[0].astype(jnp.float32)               # scalar (negative)
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)     # (c, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)     # (c, N)

    dA = dt * a                                    # (c,)
    cums = jnp.cumsum(dA)                          # (c,)

    # intra-chunk quadratic term
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(cums[:, None] - cums[None, :]), 0.0)
    s = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (c, c)
    w = s * L * dt[None, :]
    y = jax.lax.dot_general(
        w, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                              # (c, P)

    # inter-chunk contribution from the carried state
    state = state_scr[...]                         # (N, P)
    y += jnp.exp(cums)[:, None] * jax.lax.dot_general(
        Cm, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    # state update
    decay_to_end = jnp.exp(cums[-1] - cums)        # (c,)
    bw = Bm * (dt * decay_to_end)[:, None]         # (c, N)
    new_state = state * jnp.exp(cums[-1]) + jax.lax.dot_general(
        bw, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    state_scr[...] = new_state
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)


def ssd_pallas(
    xh: jnp.ndarray,   # (B, S, H, P)
    dt: jnp.ndarray,   # (B, S, H)
    A: jnp.ndarray,    # (H,)
    Bm: jnp.ndarray,   # (B, S, G, N)
    Cm: jnp.ndarray,   # (B, S, G, N)
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    B, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    rep = H // G

    kernel = functools.partial(ssd_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, ci: (b, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, ci: (b, ci, h)),
            pl.BlockSpec((1,), lambda b, h, ci: (h,)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, ci, _r=rep: (b, ci, h // _r, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, ci, _r=rep: (b, ci, h // _r, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, P), lambda b, h, ci: (b, ci, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), xh.dtype),
        scratch_shapes=[_vmem((N, P))],
        compiler_params=dict(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
        if not interpret
        else None,
        interpret=interpret,
    )(xh, dt, A, Bm, Cm)
    return out


def _vmem(shape):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)
