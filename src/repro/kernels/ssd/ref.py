"""Pure-jnp oracles for the SSD kernel.

Two independent references:
  * ``ssd_sequential_ref`` — the literal per-token recurrence (ground truth)
  * ``repro.models.mamba2.ssd_chunked_ref`` — the chunked formulation the
    model uses on CPU

The kernel is validated against both (tests/test_kernels.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ssd_sequential_ref"]


def ssd_sequential_ref(
    xh: jnp.ndarray,   # (B, S, H, P)
    dt: jnp.ndarray,   # (B, S, H)
    A: jnp.ndarray,    # (H,) negative
    Bm: jnp.ndarray,   # (B, S, G, N)
    Cm: jnp.ndarray,   # (B, S, G, N)
) -> jnp.ndarray:
    """Literal recurrence: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_tᵀ;
    y_t = C_t · h_t."""
    B, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)  # (B,S,H,N)
    Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    x = xh.astype(jnp.float32)
    d = dt.astype(jnp.float32)

    def step(state, t):
        decay = jnp.exp(d[:, t] * A[None, :])              # (B,H)
        upd = jnp.einsum("bh,bhn,bhp->bhnp", d[:, t], Bh[:, t], x[:, t])
        state = state * decay[..., None, None] + upd
        y = jnp.einsum("bhn,bhnp->bhp", Ch[:, t], state)
        return state, y

    init = jnp.zeros((B, H, N, P), jnp.float32)
    _, ys = jax.lax.scan(step, init, jnp.arange(S))
    return ys.transpose(1, 0, 2, 3).astype(xh.dtype)       # (B,S,H,P)
