"""Pure-jnp oracle for the fused extraction megakernel.

One ``lax.scan`` over trace positions carrying (branch table, address queue,
fill counter) — the direct, obviously-correct formulation of the state the
Pallas program threads through VMEM/SMEM scratch and across calls.  Kernel
tests compare like with like: raw memory-distance deltas (signed-log is the
caller's eager pass), explicit state in / state out.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["fused_scan_ref", "init_state_ref"]


def init_state_ref(n_buckets: int, n_queue: int, n_mem: int) -> Tuple:
    return (
        jnp.zeros((n_buckets, n_queue), jnp.float32),  # branch table
        jnp.zeros((n_mem,), jnp.int32),                # address queue
        jnp.int32(0),                                   # fill counter
    )


@functools.partial(jax.jit, static_argnames=("n_mem",))
def fused_scan_ref(
    bucket: jnp.ndarray,   # (n,) int32
    addr: jnp.ndarray,     # (n,) int32
    outcome: jnp.ndarray,  # (n,) f32 in {-1, 0, +1}
    mem: jnp.ndarray,      # (n,) int32 (0/1)
    state: Tuple,          # (table, queue, filled) from init_state_ref
    *,
    n_mem: int,
) -> Tuple[Dict[str, jnp.ndarray], Tuple]:
    """Both scans in one walk, state threaded explicitly: returns
    ``({"brhist": (n, n_queue), "memdist_raw": (n, n_mem)}, new_state)``."""

    def step(carry, x):
        table, queue, filled = carry
        b, a, o, m = x
        is_br = o != 0.0
        row = table[b]
        br_out = jnp.where(is_br, row, 0.0)
        table = table.at[b].set(
            jnp.where(is_br, jnp.concatenate([o[None], row[:-1]]), row)
        )
        is_mem = m != 0
        valid = (jnp.arange(n_mem) < filled) & is_mem
        md_out = jnp.where(valid, (a - queue).astype(jnp.float32), 0.0)
        queue = jnp.where(
            is_mem, jnp.concatenate([a[None], queue[:-1]]), queue
        )
        filled = jnp.where(is_mem, jnp.minimum(filled + 1, n_mem), filled)
        return (table, queue, filled), (br_out, md_out)

    state, (brhist, memdist) = jax.lax.scan(
        step, state, (bucket, addr, outcome, mem)
    )
    return {"brhist": brhist, "memdist_raw": memdist}, state
