"""Pallas TPU megakernel: raw trace columns -> every model input, one pass.

The staged ``"pallas"`` feature backend runs four device stages per trace —
a fused per-instruction jit (regbits/flags/outcome/mem), the branch-history
scan, the memory-distance scan, and the eager signed-log — and materializes
the full (n, 32 + flags + N_q + N_m) float32 FeatureSet in HBM before the
model's embedding stack reads it back.  At simulation batch sizes that
round-trip is the bandwidth bill (see docs/kernels.md).

This kernel collapses the three in-jit stages into ONE ``pallas_call`` whose
grid walks trace chunks sequentially ("arbitrary" dimension semantics), with
every recurrent structure carried in VMEM/SMEM scratch:

  * the (N_b, N_q) per-bucket branch-outcome table (VMEM),
  * the N_m-deep int32 address queue + SMEM fill counter,

and the vectorized per-instruction work (register bitmap via iota compare,
the 5-wide flag stack) done per chunk in the same kernel body.  Feature rows
exist only at batch granularity: the caller (``ops.FusedExtractor``) slices
one batch of raw columns, runs this kernel, and feeds the result straight to
the engine's jitted step — the O(trace) HBM FeatureSet never exists.

The scan state is additionally threaded ACROSS calls: the carry table/queue
enter as inputs and leave as outputs, loaded into scratch at the first grid
step and flushed on every step (same-block output revisiting — last write
wins), so batch k+1 continues exactly where batch k stopped.  That is what
lets a whole trace stream through fixed-size megakernel launches and stay
bit-identical to one monolithic scan.

Memory-distance deltas are RAW int32 subtractions cast to float32, exactly
like the staged kernel: the signed-log compression must run eagerly outside
any compiled program (XLA fma contraction of ``a*b + c`` breaks bitwise
equality with the NumPy backend — see ``kernels/features/ops``).

Off-TPU the same program runs under ``interpret=True`` (CPU CI).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["fused_feature_kernel", "fused_feature_pallas"]


def fused_feature_kernel(
    bucket_ref,    # (1, chunk) int32 — (pc >> 2) % N_b
    addr_ref,      # (1, chunk) int32 — byte address (|addr| < 2^30)
    opcode_ref,    # (1, chunk) int32
    dst_ref,       # (1, chunk) int32 — destination register id
    src1_ref,      # (1, chunk) int32
    src2_ref,      # (1, chunk) int32
    branch_ref,    # (1, chunk) int32 — 1 on branches
    taken_ref,     # (1, chunk) int32 — 1 on taken branches
    mem_ref,       # (1, chunk) int32 — 1 on memory ops
    store_ref,     # (1, chunk) int32 — 1 on stores
    table_in_ref,  # (n_buckets, n_queue) f32 — incoming branch-table carry
    mq_in_ref,     # (1, n_mem + 1) int32 — incoming queue slots + fill count
    regbits_ref,   # out (1, chunk, num_regs) f32
    flags_ref,     # out (1, chunk, n_flags) f32
    brhist_ref,    # out (1, chunk, n_queue) f32
    memdist_ref,   # out (1, chunk, n_mem) f32 — RAW deltas (signed-log later)
    table_out_ref, # out (n_buckets, n_queue) f32 — outgoing carry
    mq_out_ref,    # out (1, n_mem + 1) int32 — outgoing carry
    table_scr,     # VMEM (n_buckets, n_queue) f32
    queue_scr,     # VMEM (1, n_mem) int32
    fill_scr,      # SMEM (1,) int32
    *,
    chunk: int,
    n_mem: int,
    num_regs: int,
    fp_ops: Tuple[int, ...],
):
    ci = pl.program_id(0)

    @pl.when(ci == 0)
    def _load_state():
        table_scr[...] = table_in_ref[...]
        queue_scr[...] = mq_in_ref[:, :n_mem]
        fill_scr[0] = mq_in_ref[0, n_mem]

    bucket = bucket_ref[0, :]
    addr = addr_ref[0, :]
    br = branch_ref[0, :]
    tk = taken_ref[0, :]
    mm = mem_ref[0, :]

    # ---- per-instruction features: vectorized over the whole chunk ----
    # (exact integer/bool -> {0.0, 1.0} casts; any compute path is bitwise
    # identical to the staged _per_instruction_device jit)
    reg = jax.lax.broadcasted_iota(jnp.int32, (chunk, num_regs), 1)
    dst = dst_ref[0, :][:, None]
    s1 = src1_ref[0, :][:, None]
    s2 = src2_ref[0, :][:, None]
    regbits_ref[0] = ((reg == dst) | (reg == s1) | (reg == s2)).astype(
        jnp.float32
    )
    op = opcode_ref[0, :]
    is_fp = op == fp_ops[0]
    for c in fp_ops[1:]:
        is_fp |= op == c
    flags_ref[0] = jnp.stack(
        [br != 0, tk != 0, mm != 0, store_ref[0, :] != 0, is_fp], axis=1
    ).astype(jnp.float32)

    # ---- the two sequential scans, interleaved in one walk ----
    outcome = jnp.where(
        br != 0,
        jnp.where(tk != 0, jnp.float32(1.0), jnp.float32(-1.0)),
        jnp.float32(0.0),
    )
    slot = jax.lax.broadcasted_iota(jnp.int32, (1, n_mem), 1)

    def body(i, carry):
        # branch history: read the bucket's queue, push most-recent-first
        b = bucket[i]
        o = outcome[i]
        is_br = o != 0.0
        row = table_scr[pl.ds(b, 1), :]                      # (1, n_queue)
        brhist_ref[0, pl.ds(i, 1), :] = jnp.where(is_br, row, 0.0)
        pushed = jnp.concatenate(
            [jnp.full((1, 1), o, row.dtype), row[:, :-1]], axis=1
        )
        table_scr[pl.ds(b, 1), :] = jnp.where(is_br, pushed, row)
        # memory distance: raw deltas against the last n_mem addresses
        a = addr[i]
        is_mem = mm[i] != 0
        q = queue_scr[...]                                   # (1, n_mem)
        filled = fill_scr[0]
        valid = (slot < filled) & is_mem
        delta = (a - q).astype(jnp.float32)                   # exact int32 sub
        memdist_ref[0, pl.ds(i, 1), :] = jnp.where(valid, delta, 0.0)
        pushed_q = jnp.concatenate(
            [jnp.full((1, 1), a, q.dtype), q[:, :-1]], axis=1
        )
        queue_scr[...] = jnp.where(is_mem, pushed_q, q)
        fill_scr[0] = jnp.where(
            is_mem, jnp.minimum(filled + 1, n_mem), filled
        )
        return carry

    jax.lax.fori_loop(0, chunk, body, 0)

    # flush the carry every grid step (the state outputs map to the same
    # block on every step, so the last write — the final state — wins)
    table_out_ref[...] = table_scr[...]
    mq_out_ref[:, :n_mem] = queue_scr[...]
    mq_out_ref[:, n_mem:] = jnp.full((1, 1), fill_scr[0], jnp.int32)


def _vmem(shape, dtype=jnp.float32):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def _smem(shape, dtype=jnp.int32):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.SMEM(shape, dtype)


def fused_feature_pallas(
    bucket: jnp.ndarray,   # (nc, chunk) int32
    addr: jnp.ndarray,     # (nc, chunk) int32
    opcode: jnp.ndarray,   # (nc, chunk) int32
    dst: jnp.ndarray,      # (nc, chunk) int32
    src1: jnp.ndarray,     # (nc, chunk) int32
    src2: jnp.ndarray,     # (nc, chunk) int32
    branch: jnp.ndarray,   # (nc, chunk) int32 0/1
    taken: jnp.ndarray,    # (nc, chunk) int32 0/1
    mem: jnp.ndarray,      # (nc, chunk) int32 0/1
    store: jnp.ndarray,    # (nc, chunk) int32 0/1
    table: jnp.ndarray,    # (n_buckets, n_queue) f32 carry in
    mq: jnp.ndarray,       # (1, n_mem + 1) int32 carry in
    *,
    n_buckets: int,
    n_queue: int,
    n_mem: int,
    n_flags: int,
    num_regs: int,
    fp_ops: Tuple[int, ...],
    interpret: bool = False,
):
    """One fused pass over ``nc * chunk`` trace positions.  Returns
    ``(regbits, flags, brhist, memdist_raw, table_out, mq_out)`` — the last
    two being the scan carry to thread into the next call."""
    nc, chunk = bucket.shape
    kernel = functools.partial(
        fused_feature_kernel,
        chunk=chunk,
        n_mem=n_mem,
        num_regs=num_regs,
        fp_ops=fp_ops,
    )
    col = pl.BlockSpec((1, chunk), lambda c: (c, 0))
    table_spec = pl.BlockSpec((n_buckets, n_queue), lambda c: (0, 0))
    mq_spec = pl.BlockSpec((1, n_mem + 1), lambda c: (0, 0))
    return pl.pallas_call(
        kernel,
        grid=(nc,),
        in_specs=[col] * 10 + [table_spec, mq_spec],
        out_specs=[
            pl.BlockSpec((1, chunk, num_regs), lambda c: (c, 0, 0)),
            pl.BlockSpec((1, chunk, n_flags), lambda c: (c, 0, 0)),
            pl.BlockSpec((1, chunk, n_queue), lambda c: (c, 0, 0)),
            pl.BlockSpec((1, chunk, n_mem), lambda c: (c, 0, 0)),
            table_spec,
            mq_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nc, chunk, num_regs), jnp.float32),
            jax.ShapeDtypeStruct((nc, chunk, n_flags), jnp.float32),
            jax.ShapeDtypeStruct((nc, chunk, n_queue), jnp.float32),
            jax.ShapeDtypeStruct((nc, chunk, n_mem), jnp.float32),
            jax.ShapeDtypeStruct((n_buckets, n_queue), jnp.float32),
            jax.ShapeDtypeStruct((1, n_mem + 1), jnp.int32),
        ],
        scratch_shapes=[
            _vmem((n_buckets, n_queue)),
            _vmem((1, n_mem), jnp.int32),
            _smem((1,), jnp.int32),
        ],
        compiler_params=dict(dimension_semantics=("arbitrary",))
        if not interpret
        else None,
        interpret=interpret,
    )(bucket, addr, opcode, dst, src1, src2, branch, taken, mem, store, table, mq)
