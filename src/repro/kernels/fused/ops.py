"""Public wrappers for the fused feature-extraction megakernel.

The contract mirrors ``kernels/features/ops`` — and is enforced by
``tests/test_fused.py``: the fused pipeline is **bit-identical** to both the
staged Pallas backend and the NumPy specification.  That falls out of three
invariants:

  * regbits/flags/brhist are exact integer/bool -> {0.0, 1.0, ±1.0} values —
    any compute path produces the same bits;
  * memory-distance deltas leave the kernel RAW (exact int32 subtraction,
    correctly-rounded cast) and the signed-log compression runs EAGERLY via
    ``signed_log_device`` — never inside a compiled program, where XLA's fma
    contraction of ``a*b + c`` would diverge in the last ulp;
  * the scan state threads across calls exactly (float copies and int32
    values), so batch-granular extraction equals one monolithic scan.

``FusedExtractor`` is the streaming driver the engine's ``"fused"`` backend
uses: raw int32/bool columns ship to the device once (~30 B/instr — the
same payload as the staged backend), then each ``next_batch`` slices one
batch worth of columns device-side, runs ONE megakernel launch, applies the
eager signed-log, and hands the model-input dict straight to the jitted
step.  Features exist only at batch granularity — no O(trace) FeatureSet in
HBM (see docs/kernels.md for the bandwidth accounting).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...compat import on_tpu
from ...core.features import FeatureConfig
from ...uarch.isa import NUM_REGS, Op
from ..features.ops import DEFAULT_CHUNK, signed_log_device
from .kernel import fused_feature_pallas

__all__ = [
    "FusedExtractor",
    "fused_feature_columns",
    "init_fused_state",
]

# opcodes whose instructions set the is_fp flag (static in the kernel)
_FP_OPS = (int(Op.FALU), int(Op.FMUL), int(Op.FDIV))

# the raw trace columns a fused pass consumes, in kernel argument order
_COLUMN_KEYS = (
    "bucket", "addr", "opcode", "dst", "src1", "src2",
    "is_branch", "taken", "is_mem", "is_store",
)


def init_fused_state(cfg: FeatureConfig) -> Dict[str, jnp.ndarray]:
    """The scan carry threaded across megakernel calls: the (N_b, N_q)
    branch-outcome table and the address queue + fill counter packed into
    one int32 row (``mq[0, :n_mem]`` = queue, ``mq[0, n_mem]`` = fill)."""
    return {
        "table": jnp.zeros((cfg.n_buckets, cfg.n_queue), jnp.float32),
        "mq": jnp.zeros((1, cfg.n_mem + 1), jnp.int32),
    }


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_buckets", "n_queue", "n_mem", "n_flags", "chunk", "interpret"
    ),
)
def _fused_padded(
    bucket, addr, opcode, dst, src1, src2,
    is_branch, taken, is_mem, is_store,
    table, mq,
    *,
    n_buckets, n_queue, n_mem, n_flags, chunk, interpret,
):
    n = bucket.shape[0]
    nc = max(1, -(-n // chunk))
    pad = nc * chunk - n

    def prep(v):
        # pad rows are all-zero: non-branch, non-mem — the carried scan
        # state passes through them untouched
        return jnp.pad(v.astype(jnp.int32), (0, pad)).reshape(nc, chunk)

    regbits, flags, brhist, memdist, table_out, mq_out = fused_feature_pallas(
        prep(bucket), prep(addr), prep(opcode),
        prep(dst), prep(src1), prep(src2),
        prep(is_branch), prep(taken), prep(is_mem), prep(is_store),
        table, mq,
        n_buckets=n_buckets,
        n_queue=n_queue,
        n_mem=n_mem,
        n_flags=n_flags,
        num_regs=NUM_REGS,
        fp_ops=_FP_OPS,
        interpret=interpret,
    )
    m = nc * chunk
    return (
        regbits.reshape(m, NUM_REGS)[:n],
        flags.reshape(m, n_flags)[:n],
        brhist.reshape(m, n_queue)[:n],
        memdist.reshape(m, n_mem)[:n],
        table_out,
        mq_out,
    )


# tao: hot
def fused_feature_columns(
    cols: Dict,
    state: Dict[str, jnp.ndarray],
    cfg: FeatureConfig,
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: Optional[bool] = None,
) -> Tuple[Dict[str, jnp.ndarray], Dict[str, jnp.ndarray]]:
    """One fused device pass over (a slice of) the raw trace columns.

    Returns ``(features, new_state)`` where ``features`` holds the model
    inputs (``opcode``/``regbits``/``flags``/``brhist``/``memdist``) for
    exactly these positions and ``new_state`` is the scan carry to thread
    into the next slice.  Bit-identical to running the staged extraction
    over the concatenated slices.
    """
    if interpret is None:
        interpret = not on_tpu()
    regbits, flags, brhist, raw, table, mq = _fused_padded(
        jnp.asarray(cols["bucket"]),
        jnp.asarray(cols["addr"]),
        jnp.asarray(cols["opcode"]),
        jnp.asarray(cols["dst"]),
        jnp.asarray(cols["src1"]),
        jnp.asarray(cols["src2"]),
        jnp.asarray(cols["is_branch"]),
        jnp.asarray(cols["taken"]),
        jnp.asarray(cols["is_mem"]),
        jnp.asarray(cols["is_store"]),
        state["table"],
        state["mq"],
        n_buckets=cfg.n_buckets,
        n_queue=cfg.n_queue,
        n_mem=cfg.n_mem,
        n_flags=cfg.flags_dim,
        chunk=chunk,
        interpret=interpret,
    )
    memdist = signed_log_device(raw)  # eager: keeps NumPy bit-equality
    feats = {
        "opcode": jnp.asarray(cols["opcode"], jnp.int32),
        "regbits": regbits,
        "flags": flags,
        "brhist": brhist,
        "memdist": memdist,
    }
    return feats, {"table": table, "mq": mq}


class FusedExtractor:
    """Streams fixed-size feature batches out of device-resident raw trace
    columns, carrying the scan state across batches.

    ``cols`` is the host dict from ``kernels.features.ops.trace_columns``
    (already validated against the int32-exact address window); it ships to
    the device ONCE here, zero-padded to ``pad_to`` positions so every
    ``next_batch(m)`` slice is uniform (pad rows are non-branch/non-mem and
    leave the carry untouched).  Each call runs one megakernel launch plus
    the eager signed-log and returns the model-input dict for the next
    ``m`` positions, including the sliced ``is_branch``/``is_mem`` bool
    columns the engine's step masks with.
    """

    # one-time host->device column upload, not the batch loop
    # tao: cold
    def __init__(
        self,
        cols: Dict[str, np.ndarray],
        cfg: FeatureConfig,
        *,
        chunk: int = DEFAULT_CHUNK,
        pad_to: Optional[int] = None,
        interpret: Optional[bool] = None,
    ):
        n = len(cols["bucket"])
        pad_to = n if pad_to is None else pad_to
        if pad_to < n:
            raise ValueError(f"pad_to ({pad_to}) < column length ({n})")
        self._cols: Dict[str, jnp.ndarray] = {}
        for k in _COLUMN_KEYS:
            a = jnp.asarray(cols[k])
            if pad_to > n:
                a = jnp.pad(a, (0, pad_to - n))
            self._cols[k] = a
        self._cfg = cfg
        self._chunk = chunk
        self._interpret = interpret
        self._pos = 0
        self._limit = pad_to
        self.state = init_fused_state(cfg)

    # tao: hot
    def next_batch(self, m: int) -> Dict[str, jnp.ndarray]:
        lo = self._pos
        if lo + m > self._limit:
            raise ValueError(
                f"next_batch({m}) past the padded column end "
                f"({lo} + {m} > {self._limit})"
            )
        self._pos = lo + m
        sl = {k: v[lo : lo + m] for k, v in self._cols.items()}
        feats, self.state = fused_feature_columns(
            sl,
            self.state,
            self._cfg,
            chunk=self._chunk,
            interpret=self._interpret,
        )
        feats["is_branch"] = sl["is_branch"]
        feats["is_mem"] = sl["is_mem"]
        return feats
