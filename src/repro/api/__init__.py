"""Top-level facade for the Tao workflow.

    Session      capture traces, build datasets, train, sweep
    Trace        reusable functional-trace artifact
    TrainedModel simulate / transfer-fine-tune a trained model
    JointModel   §4.3 shared-embedding training result
    DesignSpace  design sampling + training-pair selection

``Session.dataset`` returns a materialized ``WindowDataset`` or — at and
above ``streaming_threshold`` instructions — an O(trace + batch)
``StreamingWindowDataset`` (bit-identical training trajectories; see
docs/api.md "Streaming training"), plus the engine's pluggable metric
surface (``MetricSpec`` /
``register_metric``) and the sweep scheduler's report type.  See
``docs/api.md`` for concepts and the MetricSpec authoring guide.
"""
from ..core.dataset import StreamingWindowDataset, WindowDataset
from ..engine.metrics import (
    DEFAULT_METRICS,
    METRIC_REGISTRY,
    MetricSpec,
    StepContext,
    register_metric,
    windowed_spec,
)
from ..engine.plan import ExecutionPlan
from ..engine.runner import (
    EngineConfig,
    MetricNotCollectedError,
    MetricNotComputedError,
    SimulationResult,
)
from ..engine.scheduler import SweepJob, SweepReport
from .session import DesignSpace, JointModel, Session, Trace, TrainedModel

__all__ = [
    "Session",
    "Trace",
    "TrainedModel",
    "JointModel",
    "DesignSpace",
    "WindowDataset",
    "StreamingWindowDataset",
    "EngineConfig",
    "ExecutionPlan",
    "SimulationResult",
    "MetricSpec",
    "windowed_spec",
    "StepContext",
    "register_metric",
    "METRIC_REGISTRY",
    "DEFAULT_METRICS",
    "MetricNotCollectedError",
    "MetricNotComputedError",
    "SweepJob",
    "SweepReport",
]
