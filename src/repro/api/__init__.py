"""Top-level facade for the Tao workflow.

    Session      capture traces, build datasets, train, sweep
    Trace        reusable functional-trace artifact
    TrainedModel simulate / transfer-fine-tune a trained model
    JointModel   §4.3 shared-embedding training result
    DesignSpace  design sampling + training-pair selection

``Session.dataset`` returns a materialized ``WindowDataset`` or — at and
above ``streaming_threshold`` instructions — an O(trace + batch)
``StreamingWindowDataset`` (bit-identical training trajectories; see
docs/api.md "Streaming training"), plus the engine's pluggable metric
surface (``MetricSpec`` /
``register_metric``) and the sweep scheduler's report type.  See
``docs/api.md`` for concepts and the MetricSpec authoring guide.

Zero cold start: ``Session(store=...)`` attaches a content-addressed
``ArtifactStore`` (and, with it, the JAX persistent compilation cache) so
traces, features, detailed-sim summaries, trained params, and compiled
executables all persist across processes; ``Session.warmup`` AOT-compiles
a declared geometry set up front.  See docs/store.md.

Serving: ``TraceServer``/``ModelRegistry`` (from ``repro.serve``) expose
registered models to concurrent tenants with continuous batching into the
warm executable pool; the typed wire surface — ``ServeRequest``,
``ServeResult``, ``ServerStats``, ``ServeError`` — is re-exported here.
See docs/serve.md.
"""
from ..core.dataset import StreamingWindowDataset, WindowDataset
from ..engine.aot import enable_persistent_cache, persistent_cache_status
from ..engine.metrics import (
    DEFAULT_METRICS,
    METRIC_REGISTRY,
    MetricSpec,
    StepContext,
    register_metric,
    windowed_spec,
)
from ..engine.plan import ExecutionPlan
from ..engine.runner import (
    EngineConfig,
    MetricNotCollectedError,
    MetricNotComputedError,
    SimulationResult,
)
from ..engine.scheduler import SweepJob, SweepReport
from ..serve import (
    ModelRegistry,
    ServeError,
    ServeRequest,
    ServeResult,
    ServerStats,
    TraceServer,
)
from ..store import ArtifactStore
from .session import DesignSpace, JointModel, Session, Trace, TrainedModel

__all__ = [
    "ArtifactStore",
    "Session",
    "enable_persistent_cache",
    "persistent_cache_status",
    "Trace",
    "TrainedModel",
    "JointModel",
    "DesignSpace",
    "WindowDataset",
    "StreamingWindowDataset",
    "EngineConfig",
    "ExecutionPlan",
    "SimulationResult",
    "MetricSpec",
    "windowed_spec",
    "StepContext",
    "register_metric",
    "METRIC_REGISTRY",
    "DEFAULT_METRICS",
    "MetricNotCollectedError",
    "MetricNotComputedError",
    "SweepJob",
    "SweepReport",
    "TraceServer",
    "ModelRegistry",
    "ServeRequest",
    "ServeResult",
    "ServerStats",
    "ServeError",
]
