"""The `repro.api` Session facade — Tao's paper workflow as one surface.

The paper's three contributions are workflow-level: functional traces that
are *reusable* across microarchitectures, one model that predicts *many*
performance metrics, and *fast transfer* between µarch configs.  This
module owns that workflow end to end:

    from repro.api import Session, DesignSpace
    from repro.uarch import UARCH_A

    s = Session(cfg)                                # one model config
    tr = s.capture("dee", 20_000)                   # reusable func trace
    model = s.train(UARCH_A, [tr], epochs=8)        # §4.2 multi-metric model
    res = model.simulate(s.capture("mcf", 10_000))  # CPI / MPKI on device
    res.cpi, res.branch_mpki, res.available_metrics

    joint = s.train_joint(ua, ub, [tr])             # §4.3 Algorithm 1
    fast = joint.transfer(s.dataset(uc, [tr]))      # frozen-embed fine-tune

    report = s.sweep({"a": model, "b": fast}, [tr1, tr2])   # async DSE sweep
    report.traces_per_s, report.num_compiles        # == 1 per geometry

Everything underneath is the existing machinery — ``core.transfer`` /
``core.multiarch`` for training, the streaming engine (with its pluggable
``MetricSpec`` registry) for simulation, and ``engine.scheduler`` for
double-buffered multi-trace sweeps.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..core.dataset import (
    StreamingWindowDataset,
    WindowDataset,
    build_windows,
    concat_datasets,
)
from ..core.align import build_adjusted_trace
from ..core.features import FeatureSet, extract_features
from ..core.model import TaoConfig, init_tao
from ..core.multiarch import METHODS, eval_loss, init_multiarch, make_joint_step
from ..core.selection import (
    measure_design_metrics,
    select_pair_euclidean,
    select_pair_mahalanobis,
    select_random,
)
from ..core.transfer import (
    TrainResult,
    train_tao_impl,
    transfer_finetune,
    warmup_train_step,
)
from ..engine.aot import enable_persistent_cache, persistent_cache_status
from ..engine.metrics import DEFAULT_METRICS, MetricSpec
from ..engine.plan import ExecutionPlan
from ..engine.runner import EngineConfig, SimulationResult, StreamingEngine
from ..engine.scheduler import SweepJob, SweepReport, TraceSweeper
from ..store import (
    ArtifactStore,
    array_digest,
    config_token,
    content_key,
    features_to_tree,
    tree_digest,
    tree_to_features,
)
from ..train.optim import AdamWConfig, adamw_init
from ..uarch import (
    MicroArchConfig,
    get_benchmark,
    run_detailed,
    run_functional,
    sample_design_space,
)
from ..uarch.program import Program

__all__ = [
    "Trace",
    "TrainedModel",
    "JointModel",
    "DesignSpace",
    "Session",
]

Metrics = Tuple[Union[str, MetricSpec], ...]
# Session.dataset returns either flavor; both feed train/train_joint/transfer
Dataset = Union[WindowDataset, StreamingWindowDataset]

# warn when one model accumulates this many engine configs (usually a sign
# of per-call inline MetricSpec construction — each config = an XLA compile)
_ENGINE_CACHE_WARN = 8


def _named(kind: str, items, name_of) -> Dict:
    """Sequence -> {name: item}, refusing silent collisions (a dict input
    passes through — its keys are already unique)."""
    if isinstance(items, dict):
        return items
    out: Dict = {}
    for i, item in enumerate(items):
        name = name_of(item) or f"{kind}{i}"
        if name in out:
            raise ValueError(
                f"duplicate {kind} name {name!r}; pass a dict with unique "
                f"keys or give each {kind} a distinct .name"
            )
        out[name] = item
    return out


# ---------------------------------------------------------------------------
# Artifacts
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Trace:
    """A reusable functional-trace artifact (µarch-agnostic by §4.1): one
    capture serves training datasets, ground truth, and simulation on every
    design point."""

    name: str
    functional: np.ndarray                     # FUNC_TRACE_DTYPE
    program: Program = dataclasses.field(repr=False)
    benchmark: Optional[str] = None

    def __len__(self) -> int:
        return len(self.functional)

    @property
    def num_instructions(self) -> int:
        return len(self.functional)

    @functools.cached_property
    def digest(self) -> str:
        """Stable blake2b content identity of the functional trace — the
        same scheme the sweep scheduler's feature dedup and the artifact
        store key on, so a trace re-captured in another process maps to
        the same cached artifacts."""
        return array_digest(self.functional)


def quantized_params_key(params: Dict) -> str:
    """Content key a params tree's int8 quantization is stored under:
    derived from the fp32 tree digest plus the scheme version
    (``core.quant.QUANT_VERSION``), so publish-time scales are shared by
    every process resolving the model and a scheme bump invalidates stale
    trees instead of silently reusing them."""
    from ..core.quant import QUANT_VERSION

    return content_key("params_int8", tree_digest(params), f"v{QUANT_VERSION}")


@dataclasses.dataclass
class TrainedModel:
    """Trained Tao parameters bound to their config: the simulate/transfer
    half of the workflow.  Engines are cached per EngineConfig, so repeated
    ``simulate`` calls (and every model of the same shape, via the
    process-wide step cache) reuse one compiled executable."""

    params: Dict
    cfg: TaoConfig
    name: str = "tao"
    uarch: Optional[MicroArchConfig] = None
    losses: List[float] = dataclasses.field(default_factory=list)
    seconds: float = 0.0
    steps: int = 0
    # simulate() defaults: Session.train stamps its batch_size,
    # feature_backend, precision, and ExecutionPlan here so simulate() and
    # Session.sweep() compile the same executable and take the same
    # feature/partitioning path
    sim_batch_size: int = 64
    sim_feature_backend: str = "numpy"
    sim_precision: str = "fp32"
    sim_plan: Optional[ExecutionPlan] = None
    # artifact store stamped by the owning Session: simulate() loads/saves
    # inference features through it, so a warm store skips extraction
    store: Optional[ArtifactStore] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    def __post_init__(self):
        self._engines: Dict[EngineConfig, StreamingEngine] = {}

    def engine(self, ecfg: Optional[EngineConfig] = None, **kw) -> StreamingEngine:
        """The cached StreamingEngine for an EngineConfig (or kwargs)."""
        if ecfg is None:
            ecfg = EngineConfig(**kw)
        elif kw:
            ecfg = dataclasses.replace(ecfg, **kw)
        engine = self._engines.get(ecfg)
        if engine is None:
            # int8 engines get the published/stored quantized tree so every
            # process (and the registry's serve path) shares one set of
            # scales instead of re-deriving them per engine
            qp = self.quantized_params() if ecfg.precision == "int8" else None
            engine = StreamingEngine(self.params, self.cfg, ecfg, qparams=qp)
            self._engines[ecfg] = engine
            if len(self._engines) == _ENGINE_CACHE_WARN:
                warnings.warn(
                    f"{len(self._engines)} engine configurations cached on "
                    f"model {self.name!r} — each costs an XLA compile. "
                    "Inline-constructed MetricSpecs hash by identity; reuse "
                    "module-level spec instances (register_metric) instead "
                    "of building them per call.",
                    RuntimeWarning,
                    stacklevel=3,
                )
        return engine

    def simulate(
        self,
        trace: Union[Trace, np.ndarray],
        *,
        metrics: Optional[Metrics] = None,
        collect: bool = False,
        batch_size: Optional[int] = None,
        feature_backend: Optional[str] = None,
        precision: Optional[str] = None,
        features: Optional[FeatureSet] = None,
        mesh=None,
        plan: Optional[ExecutionPlan] = None,
    ) -> SimulationResult:
        """Stream one functional trace through the model; ``metrics`` picks
        the device-side ``MetricSpec``s (default CPI + branch/L1D MPKI).
        ``plan=``/``mesh=`` override the model's stamped ``sim_plan``
        (inherited from ``Session(mesh=...)``); ``feature_backend=`` /
        ``precision=`` likewise override the stamped defaults
        (``"fused"``/``"int8"`` for the megakernel + W8A8 path —
        docs/api.md)."""
        if plan is None and mesh is None:
            plan = self.sim_plan
        backend = feature_backend or self.sim_feature_backend
        engine = self.engine(
            batch_size=batch_size if batch_size is not None else self.sim_batch_size,
            collect=collect,
            feature_backend=backend,
            precision=precision or self.sim_precision,
            mesh=mesh,
            plan=plan,
            metrics=tuple(metrics) if metrics is not None else DEFAULT_METRICS,
        )
        ft = trace.functional if isinstance(trace, Trace) else trace
        if features is None and self.store is not None and backend == "numpy":
            features = self._stored_features(trace, ft)
        return engine.simulate(ft, features=features)

    def _stored_features(self, trace, ft: np.ndarray) -> FeatureSet:
        """Inference features through the artifact store (same key the
        sweep scheduler uses, so simulate() and sweeps share entries)."""
        dg = trace.digest if isinstance(trace, Trace) else array_digest(ft)
        key = content_key("features", dg, self.cfg.features)
        hit = self.store.get("features", key)
        if hit is not None:
            return tree_to_features(hit[0])
        fs = extract_features(ft, self.cfg.features, with_labels=False)
        self.store.put("features", key, features_to_tree(fs))
        return fs

    def quantized_params(self) -> Dict:
        """The W8A8 quantized twin of ``params`` (``core/quant.py``):
        per-channel int8 weights + scales, computed once per model and —
        when the owning Session stamped an artifact store — persisted
        content-addressed next to the fp32 tree (the same key
        ``serve.ModelRegistry.publish`` writes), so any process resolving
        this model reuses the published scales instead of re-deriving
        them."""
        from ..core.quant import quantize_tao_params

        q = getattr(self, "_qparams", None)
        if q is not None:
            return q
        key = quantized_params_key(self.params)
        if self.store is not None:
            hit = self.store.get("params_int8", key)
            if hit is not None:
                self._qparams = hit[0]
                return hit[0]
        q = quantize_tao_params(self.params)
        if self.store is not None:
            self.store.put(
                "params_int8", key, q, {"scheme": "w8a8-per-channel"}
            )
        self._qparams = q
        return q

    @property
    def num_compiles(self) -> int:
        # engines of different feature backends share cached steps, so
        # dedupe the underlying entries before summing
        entries = {}
        for engine in self._engines.values():
            for entry in engine._steps.values():
                entries[id(entry)] = entry
        return sum(e.compiles for e in entries.values())

    def transfer(
        self,
        dataset: "Dataset",
        *,
        freeze_embed: bool = True,
        epochs: int = 10,
        batch_size: int = 16,
        lr: float = 3e-4,
        seed: int = 0,
        target_loss: Optional[float] = None,
        name: Optional[str] = None,
        uarch: Optional[MicroArchConfig] = None,
    ) -> "TrainedModel":
        """Fine-tune this model onto a new µarch's (small) dataset.
        ``freeze_embed=True`` is Tao's scheme (§4.3): the µarch-agnostic
        embedding stays fixed, only adaptation + prediction layers train."""
        res = train_tao_impl(
            self.cfg,
            dataset,
            epochs=epochs,
            batch_size=batch_size,
            lr=lr,
            init_params=self.params,
            freeze_embed=freeze_embed,
            seed=seed,
            target_loss=target_loss,
        )
        return _model_from_result(
            res, self.cfg, name or f"{self.name}-transfer", uarch,
            self.sim_batch_size, self.sim_feature_backend, self.sim_plan,
            self.store, self.sim_precision,
        )


def _model_from_result(
    res: TrainResult,
    cfg: TaoConfig,
    name: str,
    uarch: Optional[MicroArchConfig],
    sim_batch_size: int = 64,
    sim_feature_backend: str = "numpy",
    sim_plan: Optional[ExecutionPlan] = None,
    store: Optional[ArtifactStore] = None,
    sim_precision: str = "fp32",
) -> TrainedModel:
    return TrainedModel(
        params=res.params,
        cfg=cfg,
        name=name,
        uarch=uarch,
        losses=res.losses,
        seconds=res.seconds,
        steps=res.steps,
        sim_batch_size=sim_batch_size,
        sim_feature_backend=sim_feature_backend,
        sim_precision=sim_precision,
        sim_plan=sim_plan,
        store=store,
    )


@dataclasses.dataclass
class JointModel:
    """Result of §4.3 Algorithm-1 joint training over two µarchs: the
    µarch-agnostic embedding plus per-µarch adaptation/prediction heads."""

    params: Dict                      # {"embed": …, "A": {…}, "B": {…}}
    cfg: TaoConfig
    method: str
    losses: List[Tuple[float, float]]  # per-epoch (loss_a, loss_b)
    seconds: float = 0.0
    steps: int = 0
    sim_batch_size: int = 64          # inherited by head()/transfer() models
    sim_feature_backend: str = "numpy"
    sim_precision: str = "fp32"
    sim_plan: Optional[ExecutionPlan] = None
    store: Optional[ArtifactStore] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    @property
    def embedding(self) -> Dict:
        """The frozen, µarch-agnostic embedding parameters."""
        return self.params["embed"]

    def head(self, arch: str = "A", name: Optional[str] = None) -> TrainedModel:
        """Assemble one µarch's full model (shared embedding + its heads)."""
        if arch not in ("A", "B"):
            raise ValueError(f"arch must be 'A' or 'B', got {arch!r}")
        if self.method != "tao":
            # only Algorithm 1 trains the adaptation layers; the other
            # methods' heads were trained on NON-adapted embeddings, and
            # tao_forward applies adapt unconditionally — simulating would
            # route through random weights and silently skew predictions
            raise ValueError(
                f"head() needs trained adaptation layers, which method="
                f"{self.method!r} does not produce; use transfer(...) "
                "(which fine-tunes them) or method='tao'"
            )
        return TrainedModel(
            params={"embed": self.params["embed"], **self.params[arch]},
            cfg=self.cfg,
            name=name or f"joint-{self.method}-{arch}",
            sim_batch_size=self.sim_batch_size,
            sim_feature_backend=self.sim_feature_backend,
            sim_precision=self.sim_precision,
            sim_plan=self.sim_plan,
            store=self.store,
        )

    def transfer(
        self,
        dataset: "Dataset",
        *,
        donor: str = "A",
        epochs: int = 10,
        batch_size: int = 16,
        lr: float = 3e-4,
        seed: int = 0,
        target_loss: Optional[float] = None,
        name: Optional[str] = None,
        uarch: Optional[MicroArchConfig] = None,
    ) -> TrainedModel:
        """Tao's fast enablement of an unseen µarch: frozen shared
        embeddings + donor-initialized heads, fine-tuned on a small
        dataset (paper Table 5's 29.5x-cheaper regime)."""
        if donor not in ("A", "B"):
            raise ValueError(f"donor must be 'A' or 'B', got {donor!r}")
        res = transfer_finetune(
            self.cfg,
            self.params["embed"],
            self.params[donor],
            dataset,
            epochs=epochs,
            batch_size=batch_size,
            lr=lr,
            seed=seed,
            target_loss=target_loss,
        )
        return _model_from_result(
            res, self.cfg, name or f"transfer-{self.method}", uarch,
            self.sim_batch_size, self.sim_feature_backend, self.sim_plan,
            self.store, self.sim_precision,
        )

    def eval_loss(self, batches, arch: str = "A") -> float:
        # evaluation must mirror training: only method="tao" trains the
        # adaptation layers (multiarch.use_adapt_by_method), so only it
        # routes eval through them
        return eval_loss(
            self.params, batches, self.cfg, arch, use_adapt=self.method == "tao"
        )


# ---------------------------------------------------------------------------
# Design space
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DesignSpace:
    """A set of µarch design points plus the paper's training-pair
    selection (§4.3 Mahalanobis distance over quick detailed-sim metrics)."""

    designs: List[MicroArchConfig]
    # the detailed-sim measurement pass is the expensive half of selection;
    # cache it so comparing selection methods measures once
    _metrics: Dict[tuple, np.ndarray] = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )

    @classmethod
    def sample(cls, n: int, seed: int = 0) -> "DesignSpace":
        return cls(designs=list(sample_design_space(n, seed=seed)))

    @classmethod
    def vary(
        cls,
        base: MicroArchConfig,
        field: str,
        values: Sequence,
        name_fmt: str = "{field}{value}",
    ) -> "DesignSpace":
        """Axis sweep: replace one config field across ``values``."""
        return cls(designs=[
            dataclasses.replace(
                base, **{field: v},
                name=name_fmt.format(field=field, value=v),
            )
            for v in values
        ])

    def __len__(self) -> int:
        return len(self.designs)

    def __iter__(self):
        return iter(self.designs)

    def __getitem__(self, i: int) -> MicroArchConfig:
        return self.designs[i]

    def select_pair(
        self,
        benchmarks: Sequence[str],
        *,
        method: str = "mahalanobis",
        instructions: int = 3000,
        seed: int = 0,
    ) -> Tuple[int, int]:
        """Pick the joint-training pair (paper Fig. 14: MD > Euclid > rand).
        Returns indices into ``self.designs``."""
        if method == "random":
            i, j = select_random(len(self.designs), 2, seed=seed)
            return int(i), int(j)
        mkey = (tuple(benchmarks), instructions)
        metrics = self._metrics.get(mkey)
        if metrics is None:
            metrics = measure_design_metrics(
                self.designs, benchmarks, instructions=instructions
            )
            self._metrics[mkey] = metrics
        if method == "mahalanobis":
            return select_pair_mahalanobis(metrics)
        if method == "euclidean":
            return select_pair_euclidean(metrics)
        raise ValueError(
            f"method must be mahalanobis|euclidean|random, got {method!r}"
        )


# ---------------------------------------------------------------------------
# Session
# ---------------------------------------------------------------------------


class Session:
    """One Tao workflow: a model configuration plus the paper's verbs.

    ``capture`` -> reusable functional traces; ``dataset`` -> §4.1 adjusted
    windows for a design point; ``train``/``train_joint`` -> models;
    ``model.simulate``/``sweep`` -> device-resident multi-metric inference.
    """

    def __init__(
        self,
        cfg: Optional[TaoConfig] = None,
        *,
        batch_size: int = 64,
        feature_backend: str = "numpy",
        precision: str = "fp32",
        seed: int = 0,
        streaming_threshold: Optional[int] = 1_000_000,
        mesh=None,
        plan: Optional[ExecutionPlan] = None,
        store: Optional[Union[ArtifactStore, str]] = None,
        compile_cache: Union[None, bool, str] = None,
    ):
        self.cfg = cfg if cfg is not None else TaoConfig()
        self.batch_size = batch_size
        self.feature_backend = feature_backend
        # Default inference precision stamped onto trained models
        # ("fp32" | "int8"); training itself always runs fp32.
        self.precision = precision
        self.seed = seed
        # Content-addressed artifact store (repro.store): captured traces,
        # labeled/inference FeatureSets, detailed-sim summaries, and
        # trained params persist across processes through it — the second
        # process running the same workflow recomputes none of them.
        if isinstance(store, str):
            store = ArtifactStore(store)
        self.store = store
        # JAX persistent compilation cache: auto-enabled alongside a store
        # (executables land under store.xla_cache_dir so artifacts and
        # binaries travel — and get wiped — together).  compile_cache=False
        # opts out; True or a path enables it without a store.
        if compile_cache is None:
            if store is not None:
                enable_persistent_cache(store.xla_cache_dir)
        elif compile_cache is True:
            enable_persistent_cache()
        elif compile_cache is not False:
            enable_persistent_cache(compile_cache)
        # One partitioning decision for the whole workflow: models trained
        # by this session simulate under it, and Session.sweep composes the
        # trace queue with it.  None (the default, when no mesh/plan is
        # given) means the single-device path.
        self.plan: Optional[ExecutionPlan] = None
        if mesh is not None or plan is not None:
            self.plan = ExecutionPlan.resolve(
                mesh, batch_size=batch_size, plan=plan
            )
        # dataset()/train() switch to the O(trace + batch) streaming
        # pipeline when the traces hold at least this many instructions
        # combined (None disables the automatic switch); pass
        # ``streaming=True/False`` per call to override.  Below the
        # threshold the materialized WindowDataset is kept — small runs,
        # subsample(), and the equivalence tests rely on it.
        self.streaming_threshold = streaming_threshold
        self._traces: Dict[tuple, Trace] = {}
        # key -> (pinned traces, dataset); see Session.dataset
        self._datasets: Dict[tuple, Tuple[Tuple[Trace, ...], Dataset]] = {}
        # (uarch key, id(trace)) -> (pinned trace, detailed trace, summary):
        # ground_truth and dataset share one detailed-sim run per pair (the
        # most expensive operation in the workflow)
        self._detailed: Dict[tuple, tuple] = {}

    # ---- step 1: reusable functional traces ----------------------------

    def capture(
        self,
        benchmark: Union[str, Program],
        n: int,
        name: Optional[str] = None,
    ) -> Trace:
        """Run the functional (AtomicSimpleCPU-analogue) simulator once;
        the artifact is reusable across every µarch (paper Fig. 10)."""
        if isinstance(benchmark, Program):
            # key on the object: two Programs sharing a .name must not
            # alias (the cached Trace pins the Program, so its id is
            # stable for the life of the entry)
            prog, bench, source = benchmark, benchmark.name, id(benchmark)
        else:
            prog, bench, source = get_benchmark(benchmark), benchmark, benchmark
        name = name or f"{bench}:{n}"
        key = (source, n, name)  # a custom name never shadows the default
        cached = self._traces.get(key)
        if cached is not None:
            return cached
        # named benchmarks are pure functions of (benchmark, n): store-
        # backed (custom Program objects are not serializable — skip them)
        skey = None
        if self.store is not None and isinstance(source, str):
            skey = content_key("trace", bench, n)
            hit = self.store.get("trace", skey)
            if hit is not None:
                tr = Trace(
                    name=name, functional=hit[0]["functional"],
                    program=prog, benchmark=bench,
                )
                self._traces[key] = tr
                return tr
        tr = Trace(
            name=name,
            functional=run_functional(prog, n),
            program=prog,
            benchmark=bench,
        )
        if skey is not None:
            self.store.put("trace", skey, {"functional": tr.functional})
        self._traces[key] = tr
        return tr

    def _run_detailed(self, uarch: MicroArchConfig, trace: Trace):
        key = (uarch.key(), id(trace))
        cached = self._detailed.get(key)
        if cached is None:
            det, summ = run_detailed(trace.program, trace.functional, uarch)
            cached = (trace, det, summ)  # pin the trace so id() stays valid
            self._detailed[key] = cached
        return cached[1], cached[2]

    def ground_truth(self, uarch: MicroArchConfig, trace: Trace) -> Dict[str, float]:
        """Detailed-simulator metrics for a trace on one design point."""
        skey = None
        if self.store is not None:
            skey = content_key(
                "detail_summary", trace.digest, config_token(uarch)
            )
            hit = self.store.get("detail_summary", skey)
            if hit is not None:
                return dict(hit[1]["summary"])
        _, summ = self._run_detailed(uarch, trace)
        if skey is not None:
            # pure-JSON payload: rides in the manifest, no array files
            self.store.put("detail_summary", skey, {}, {"summary": dict(summ)})
        return summ

    def _adjusted_features(self, uarch: MicroArchConfig, tr: Trace) -> FeatureSet:
        """Labeled per-trace FeatureSet for (trace, µarch): detailed sim →
        §4.1 cycle re-attribution → feature extraction.  Store-backed — a
        warm artifact store skips all three (the expensive half of
        building a training dataset)."""
        skey = None
        if self.store is not None:
            skey = content_key(
                "features_labeled", tr.digest, config_token(uarch),
                self.cfg.features,
            )
            hit = self.store.get("features_labeled", skey)
            if hit is not None:
                return tree_to_features(hit[0])
        det, _ = self._run_detailed(uarch, tr)
        al = build_adjusted_trace(det)
        fs = extract_features(al.adjusted, self.cfg.features)
        if skey is not None:
            self.store.put("features_labeled", skey, features_to_tree(fs))
        return fs

    # ---- datasets (§4.1 adjusted traces -> windows) --------------------

    def dataset(
        self,
        uarch: MicroArchConfig,
        traces: Union[Trace, Iterable[Trace]],
        *,
        dedup: bool = True,
        streaming: Optional[bool] = None,
        dedup_scope: str = "trace",
    ) -> Dataset:
        """Detailed-sim each trace on ``uarch``, re-attribute squash/nop
        cycles (§4.1), extract features, window, and concatenate.

        ``streaming=None`` (default) picks the pipeline by size: at or above
        ``Session.streaming_threshold`` combined instructions the result is
        a ``StreamingWindowDataset`` — zero-copy window views + streaming
        dedup, O(trace + batch) host memory, bit-identical training
        trajectory — otherwise a materialized ``WindowDataset``.
        ``dedup_scope="global"`` (streaming pipeline only) shares the dedup
        reservoir across traces; the default per-trace scope matches the
        materialized pipeline exactly."""
        if isinstance(traces, Trace):
            traces = [traces]
        traces = list(traces)
        if streaming is None:
            streaming = (
                self.streaming_threshold is not None
                and sum(len(t) for t in traces) >= self.streaming_threshold
            )
        if dedup_scope != "trace" and not streaming:
            raise ValueError(
                "dedup_scope is a streaming-pipeline option; the "
                "materialized pipeline always dedups per trace (pass "
                "streaming=True for cross-trace dedup)"
            )
        # key on the trace objects themselves (captures are session-cached,
        # so the normal path hits) — names alone could collide across
        # different traces and hand back the wrong windows.  The cache entry
        # pins the Trace objects so an id() is never recycled while its key
        # is live.
        key = (uarch.key(), tuple(id(t) for t in traces), dedup,
               bool(streaming), dedup_scope, self.cfg.features,
               self.cfg.window)
        cached = self._datasets.get(key)
        if cached is not None:
            return cached[1]
        if streaming:
            # keep only the per-trace FeatureSets (O(trace)); windowing,
            # dedup, and batch materialization all stream from views
            fsets = [self._adjusted_features(uarch, tr) for tr in traces]
            ds: Dataset = StreamingWindowDataset(
                fsets, self.cfg.window, dedup=dedup, dedup_scope=dedup_scope
            )
        else:
            ds = concat_datasets([
                build_windows(
                    self._adjusted_features(uarch, tr),
                    self.cfg.window,
                    dedup=dedup,
                )
                for tr in traces
            ])
        self._datasets[key] = (tuple(traces), ds)
        return ds

    # ---- step 2: training ----------------------------------------------

    def train(
        self,
        uarch: Optional[MicroArchConfig] = None,
        traces: Optional[Union[Trace, Iterable[Trace]]] = None,
        *,
        dataset: Optional[Dataset] = None,
        streaming: Optional[bool] = None,
        epochs: int = 10,
        batch_size: int = 16,
        lr: float = 3e-4,
        init: Optional[Union[TrainedModel, Dict]] = None,
        freeze_embed: bool = False,
        seed: Optional[int] = None,
        target_loss: Optional[float] = None,
        eval_fn=None,
        name: Optional[str] = None,
        plan: Optional[ExecutionPlan] = None,
    ) -> TrainedModel:
        """Train (or fine-tune) a single-µarch model.  Give ``traces`` and
        the session builds the adjusted dataset for ``uarch`` — streaming
        (O(trace + batch) memory) at or above ``streaming_threshold``
        combined instructions, materialized below; ``streaming=`` forces
        either pipeline.  Or pass a prebuilt ``dataset`` directly.
        ``plan=`` runs the cached train step data-parallel over an
        ExecutionPlan's mesh (explicit opt-in — the session's simulation
        plan is not applied to training automatically because the train
        ``batch_size`` must divide its shards)."""
        if dataset is not None and streaming is not None:
            raise ValueError(
                "streaming= only controls how the session builds a dataset "
                "from traces; it cannot change an explicit dataset= (pass "
                "the right flavor directly)"
            )
        init_params = init.params if isinstance(init, TrainedModel) else init
        model_name = name or (uarch.name if uarch is not None else "tao")
        # Trained params are a pure function of the full recipe when the
        # session builds the dataset itself (streaming and materialized
        # pipelines are bit-identical, so streaming= stays out of the key).
        # An explicit dataset= or eval_fn= has state the key cannot see —
        # those train unconditionally.
        skey = None
        if (
            self.store is not None
            and dataset is None
            and eval_fn is None
            and uarch is not None
            and traces is not None
        ):
            trs = [traces] if isinstance(traces, Trace) else list(traces)
            skey = content_key(
                "params",
                config_token(self.cfg),
                config_token(uarch),
                tuple(t.digest for t in trs),
                epochs,
                batch_size,
                lr,
                freeze_embed,
                self.seed if seed is None else seed,
                target_loss,
                tree_digest(init_params) if init_params is not None else None,
                plan.cache_token() if plan is not None else None,
            )
            hit = self.store.get("params", skey)
            if hit is not None:
                tree, extra = hit
                return TrainedModel(
                    params=tree, cfg=self.cfg, name=model_name, uarch=uarch,
                    losses=[float(x) for x in extra.get("losses", [])],
                    seconds=0.0, steps=int(extra.get("steps", 0)),
                    sim_batch_size=self.batch_size,
                    sim_feature_backend=self.feature_backend,
                    sim_precision=self.precision,
                    sim_plan=self.plan, store=self.store,
                )
        if dataset is None:
            if uarch is None or traces is None:
                raise ValueError(
                    "train needs (uarch, traces) to build a dataset, or an "
                    "explicit dataset="
                )
            dataset = self.dataset(uarch, traces, streaming=streaming)
        # skey doubles as the crash-resume identity: with a store, every
        # epoch checkpoints a progress manifest, so a SIGKILLed train
        # resumes from the last completed epoch (bit-identical losses and
        # params) instead of starting over
        res = train_tao_impl(
            self.cfg,
            dataset,
            epochs=epochs,
            batch_size=batch_size,
            lr=lr,
            init_params=init_params,
            freeze_embed=freeze_embed,
            eval_fn=eval_fn,
            seed=self.seed if seed is None else seed,
            target_loss=target_loss,
            plan=plan,
            store=self.store if skey is not None else None,
            resume_key=skey,
        )
        if skey is not None:
            self.store.put(
                "params", skey, res.params,
                {"losses": [float(x) for x in res.losses],
                 "steps": int(res.steps)},
            )
        return _model_from_result(
            res, self.cfg, model_name,
            uarch, self.batch_size, self.feature_backend, self.plan,
            self.store, self.precision,
        )

    def init_model(self, seed: Optional[int] = None, name: str = "init") -> TrainedModel:
        """An untrained model (random init) — engine smoke tests, sweeps."""
        key = jax.random.PRNGKey(self.seed if seed is None else seed)
        return TrainedModel(
            params=init_tao(key, self.cfg), cfg=self.cfg, name=name,
            sim_batch_size=self.batch_size,
            sim_feature_backend=self.feature_backend,
            sim_precision=self.precision,
            sim_plan=self.plan,
            store=self.store,
        )

    def train_joint(
        self,
        uarch_a: MicroArchConfig,
        uarch_b: MicroArchConfig,
        traces: Optional[Union[Trace, Iterable[Trace]]] = None,
        *,
        datasets: Optional[Tuple[Dataset, Dataset]] = None,
        streaming: Optional[bool] = None,
        method: str = "tao",
        epochs: int = 6,
        batch_size: int = 16,
        lr: float = 1e-3,
        seed: Optional[int] = None,
        on_epoch=None,
    ) -> JointModel:
        """§4.3 Algorithm 1: jointly train the µarch-agnostic embedding
        over two design points (``method`` picks the gradient-combination
        rule: {'tao', 'tao_no_adapt', 'granite', 'gradnorm'}).
        ``on_epoch(epoch, params, steps)`` runs after every epoch —
        checkpointing hook (see examples/train_tao_e2e.py)."""
        if method not in METHODS:
            raise ValueError(f"method {method!r} not in {METHODS}")
        if datasets is not None:
            if streaming is not None:
                raise ValueError(
                    "streaming= only controls how the session builds "
                    "datasets from traces; it cannot change explicit "
                    "datasets= (pass the right flavor directly)"
                )
            ds_a, ds_b = datasets
        else:
            if traces is None:
                raise ValueError("train_joint needs traces= or datasets=")
            ds_a = self.dataset(uarch_a, traces, streaming=streaming)
            ds_b = self.dataset(uarch_b, traces, streaming=streaming)
        short = min(len(ds_a), len(ds_b))
        if short < batch_size:
            raise ValueError(
                f"joint datasets have {short} windows < batch_size="
                f"{batch_size}: no full batch, training would be a no-op "
                "(shrink batch_size or capture longer traces)"
            )
        seed = self.seed if seed is None else seed
        params = init_multiarch(jax.random.PRNGKey(seed), self.cfg)
        opt = adamw_init(params)
        step = make_joint_step(self.cfg, AdamWConfig(lr=lr), method=method)
        w = jnp.ones((2,))
        initial = None
        rng = np.random.default_rng(seed)
        losses: List[Tuple[float, float]] = []
        steps = 0
        import time as _time

        from ..engine.runner import prefetch_to_device

        t0 = _time.perf_counter()
        for ep in range(epochs):
            m = None
            # inline (depth-1) prefetch for BOTH datasets: batch i+1's
            # host gather + transfer is enqueued while step(i) runs.
            # Deliberately not the threaded mode: the two generators share
            # one rng (shuffle drawn lazily at first next, A then B), and
            # producer threads would race on it — inline wrapping consumes
            # the rng in exactly the pre-prefetch order, keeping the batch
            # streams bit-identical.
            for ba, bb in zip(
                prefetch_to_device(
                    ds_a.batches(batch_size, rng=rng), threaded=False
                ),
                prefetch_to_device(
                    ds_b.batches(batch_size, rng=rng), threaded=False
                ),
            ):
                ba["labels"] = {k: jnp.asarray(v) for k, v in ba.pop("labels").items()}
                bb["labels"] = {k: jnp.asarray(v) for k, v in bb.pop("labels").items()}
                params, opt, w, m = step(
                    params, opt, w,
                    initial if initial is not None else jnp.ones((2,)),
                    ba, bb,
                )
                if initial is None:
                    initial = jnp.asarray(
                        [float(m["loss_a"]), float(m["loss_b"])]
                    )
                steps += 1
            if m is not None:
                losses.append((float(m["loss_a"]), float(m["loss_b"])))
            if on_epoch is not None:
                on_epoch(ep, params, steps)
        return JointModel(
            params=params,
            cfg=self.cfg,
            method=method,
            losses=losses,
            seconds=_time.perf_counter() - t0,
            steps=steps,
            sim_batch_size=self.batch_size,
            sim_feature_backend=self.feature_backend,
            sim_precision=self.precision,
            sim_plan=self.plan,
            store=self.store,
        )

    # ---- step 3: multi-trace simulation --------------------------------

    def sweep(
        self,
        models: Union[Sequence[TrainedModel], Dict[str, TrainedModel]],
        traces: Union[Sequence[Trace], Dict[str, Trace]],
        *,
        metrics: Optional[Metrics] = None,
        batch_size: Optional[int] = None,
        feature_backend: Optional[str] = None,
        precision: Optional[str] = None,
        collect: bool = False,
        depth: int = 2,
        async_prepare: Optional[bool] = None,
        mesh=None,
        plan: Optional[ExecutionPlan] = None,
        resume_key: Optional[str] = None,
    ) -> SweepReport:
        """Async DSE sweep: every (model, trace) pair streams through one
        shared compiled step; each distinct trace is prepared once (shared
        across models) and — on accelerator backends — the next trace's
        host-side prep is double-buffered behind the device execution of
        the current one.  Result keys are ``model/trace``.

        Sharded sweeps compose the trace queue with an ``ExecutionPlan``:
        pass ``plan=``/``mesh=`` (or construct the session with one) and
        every job's step fans out over the plan's ``data`` axes while the
        one-compile-per-geometry guarantee still holds
        (``report.num_compiles``, ``report.plan_kind``).

        ``resume_key=`` (any stable string naming the sweep; needs the
        session store) makes the sweep crash-resumable: each completed job
        publishes a progress manifest, and a re-run with the same key
        skips finished jobs entirely (``report.jobs_skipped``) with
        bit-identical results."""
        models = _named("model", models, lambda m: m.name)
        traces = _named("trace", traces, lambda t: t.name)
        for name, m in models.items():
            if m.cfg != self.cfg:
                raise ValueError(
                    f"model {name!r} was built for a different TaoConfig; "
                    "sweeps share one compiled step per session config"
                )
        if plan is None and mesh is None:
            plan = self.plan
        ecfg = EngineConfig(
            batch_size=batch_size or self.batch_size,
            feature_backend=feature_backend or self.feature_backend,
            precision=precision or self.precision,
            collect=collect,
            mesh=mesh,
            plan=plan,
            metrics=tuple(metrics) if metrics is not None else DEFAULT_METRICS,
        )
        jobs = [
            SweepJob(f"{mn}/{tn}", model.params, tr.functional)
            for mn, model in models.items()
            for tn, tr in traces.items()
        ]
        return TraceSweeper(
            self.cfg, ecfg, depth=depth, async_prepare=async_prepare,
            store=self.store,
        ).run(jobs, resume_key=resume_key)

    # ---- zero cold start ------------------------------------------------

    def warmup(
        self,
        geometries: Iterable[Union[int, Tuple[int, int]]],
        *,
        plans: Optional[Iterable[Optional[ExecutionPlan]]] = None,
        train: Union[None, bool, Iterable[Dict]] = None,
        metrics: Optional[Metrics] = None,
        collect: bool = False,
    ) -> Dict[str, object]:
        """AOT-compile the session's executables for a declared geometry
        set before any trace, params, or dataset exists.

        ``geometries`` lists trace lengths (``int``, simulated at the
        session batch size) or ``(length, batch_size)`` pairs; ``plans``
        extends the set over extra ExecutionPlans (default: the session's
        own).  ``train=True`` additionally warms the default train step
        (``train=[{"batch_size": ..., "lr": ..., ...}]`` for specific
        recipes).  With the persistent compilation cache enabled (any
        ``Session(store=...)``), the executables serialize to disk — a
        later process calling ``warmup`` with the same geometries
        deserializes instead of compiling, and its first ``simulate``/
        ``train`` hits a ready executable: zero cold start."""
        mets = tuple(metrics) if metrics is not None else DEFAULT_METRICS
        plan_list = list(plans) if plans is not None else [self.plan]
        geos = []
        for g in geometries:
            if isinstance(g, (tuple, list)):
                n, bs = g
            else:
                n, bs = g, self.batch_size
            geos.append((int(n), int(bs)))
        abstract = jax.eval_shape(
            functools.partial(init_tao, cfg=self.cfg), jax.random.PRNGKey(0)
        )
        engines: Dict[tuple, StreamingEngine] = {}
        compiled = 0
        aot = 0
        for plan in plan_list:
            for n, bs in sorted(set(geos)):
                ekey = (bs, plan)
                eng = engines.get(ekey)
                if eng is None:
                    ecfg = EngineConfig(
                        batch_size=bs,
                        feature_backend=self.feature_backend,
                        precision=self.precision,
                        collect=collect,
                        plan=plan,
                        metrics=mets,
                    )
                    eng = StreamingEngine(abstract, self.cfg, ecfg)
                    engines[ekey] = eng
                entry = eng.warmup(n)
                compiled += 1
                aot += entry.aot is not None
        trained = 0
        if train:
            recipes = [{}] if train is True else list(train)
            for r in recipes:
                warmup_train_step(
                    self.cfg,
                    batch_size=r.get("batch_size", 16),
                    lr=r.get("lr", 3e-4),
                    freeze_embed=r.get("freeze_embed", False),
                    plan=r.get("plan"),
                    window=r.get("window"),
                )
                trained += 1
        return {
            "sim_geometries": compiled,
            "sim_aot": aot,
            "train_steps": trained,
            "compile_cache": persistent_cache_status(),
        }
