"""Distributed trainer: sharded train_step with microbatch gradient
accumulation, mixed precision, checkpoint/restart fault tolerance, and the
sharding rules from repro.distributed.

Design for 1000+ nodes (see DESIGN.md §6):
  * pjit-style GSPMD: one jitted train_step over the global mesh; the `pod`
    axis carries pure data parallelism so only the gradient all-reduce
    crosses the inter-pod fabric.
  * Microbatching via lax.scan bounds activation memory and lets XLA overlap
    the per-microbatch reduce-scatter with backward compute.
  * Optimizer state shards with the parameters (FSDP rules), fp32 m/v over
    bf16 params.
  * Fault tolerance: atomic checkpoints (repro.ckpt), auto-resume from the
    latest valid step, preemption-signal hook.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import tree_shardings
from ..models.backbone import Model
from .optim import AdamWConfig, AdamWState, adamw_init, adamw_update, make_lr_schedule

__all__ = [
    "TrainConfig",
    "TrainState",
    "make_train_step",
    "init_state",
    "state_axes",
    "state_shardings",
    "CachedTrainStep",
    "cached_train_step",
    "cache_stats",
    "clear_train_step_cache",
    "train_step_compiles",
]


# ---------------------------------------------------------------------------
# Process-wide train-step cache (mirrors the simulation engine's step cache
# in engine/runner.py).  Parameters and optimizer state are *arguments* of
# every step built through here, so trainer invocations with an identical
# (model config, optimizer config, trainable set) key — and every model of
# the same shape — share one executable instead of re-jitting per call.
# ---------------------------------------------------------------------------


class CachedTrainStep:
    """A jitted train step plus its trace counter.

    ``compiles`` is bumped inside the traced body (trace time only), so it
    counts actual XLA compilations: with fixed-shape batches that is exactly
    one per (batch, window) geometry — the invariant the streaming training
    pipeline's tests and ``benchmarks/bench_train.py`` pin.

    Entries are callable with the step signature; once
    ``core.transfer.warmup_train_step`` has AOT-compiled the geometry
    (``aot``), calls dispatch straight to the compiled executable.
    """

    __slots__ = ("fn", "compiles", "aot", "est_bytes")

    def __init__(self):
        self.fn = None
        self.compiles = 0
        self.aot = None
        self.est_bytes = None

    def __call__(self, params, opt, batch):
        step = self.aot if self.aot is not None else self.fn
        return step(params, opt, batch)


_TRAIN_STEP_CACHE: Dict[tuple, CachedTrainStep] = {}

# entry-reuse counters behind cache_stats(): a hit means a trainer
# invocation found its step already built, a miss that a new one was jitted
_TRAIN_STEP_STATS: Dict[str, int] = {"hits": 0, "misses": 0}

# warn when the cache accumulates this many entries: each one pins a jitted
# step (and its XLA executables) for process lifetime — usually a sign of a
# hyperparameter sweep varying the optimizer config per call
_TRAIN_CACHE_WARN = 16


def cached_train_step(key: tuple, build) -> CachedTrainStep:
    """The cached step entry for ``key``, built once via ``build(entry)``.

    ``build`` receives the entry so the step body can bump
    ``entry.compiles`` when traced; the key must cover everything the built
    closure depends on (configs, trainable set, method — NOT params, which
    are arguments).
    """
    entry = _TRAIN_STEP_CACHE.get(key)
    if entry is None:
        _TRAIN_STEP_STATS["misses"] += 1
        entry = CachedTrainStep()
        entry.fn = build(entry)
        _TRAIN_STEP_CACHE[key] = entry
        if cache_stats()["entries"] == _TRAIN_CACHE_WARN:
            import warnings

            warnings.warn(
                f"{len(_TRAIN_STEP_CACHE)} train-step configurations cached "
                "process-wide — each pins a compiled executable for process "
                "lifetime. Sweeping lr/optimizer settings per call creates "
                "one entry each; reuse configs where possible.",
                RuntimeWarning,
                stacklevel=3,
            )
    else:
        _TRAIN_STEP_STATS["hits"] += 1
    return entry


def cache_stats() -> Dict[str, int]:
    """Inspect the process-wide train-step cache — same shape as the
    engine's ``repro.engine.cache_stats()``: entries, hit/miss counters,
    trace-time compiles, estimated retained bytes for AOT-warmed entries
    (the ``_TRAIN_CACHE_WARN`` warning fires off these same counters)."""
    measured = [e.est_bytes for e in _TRAIN_STEP_CACHE.values() if e.est_bytes]
    return {
        "entries": len(_TRAIN_STEP_CACHE),
        "hits": _TRAIN_STEP_STATS["hits"],
        "misses": _TRAIN_STEP_STATS["misses"],
        "compiles": sum(e.compiles for e in _TRAIN_STEP_CACHE.values()),
        "aot_compiled": sum(
            1 for e in _TRAIN_STEP_CACHE.values() if e.aot is not None
        ),
        "retained_bytes_est": sum(measured),
        "entries_unmeasured": sum(
            1 for e in _TRAIN_STEP_CACHE.values() if not e.est_bytes
        ),
    }


def clear_train_step_cache() -> int:
    """Drop every cached train step (returns how many).  Counters keep
    accumulating; snapshot ``cache_stats()`` to attribute a region."""
    n = len(_TRAIN_STEP_CACHE)
    _TRAIN_STEP_CACHE.clear()
    return n


def train_step_compiles() -> int:
    """Total train-step traces across the process — snapshot before/after a
    training run to attribute the compiles it triggered."""
    return sum(e.compiles for e in _TRAIN_STEP_CACHE.values())


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    microbatches: int = 1
    opt_m_dtype: str = "bfloat16"  # low-precision Adam first moment


class TrainState(NamedTuple):
    step: jnp.ndarray
    params: Any
    opt: AdamWState


def init_state(model: Model, key, tcfg: TrainConfig) -> TrainState:
    params = model.init(key)
    return TrainState(
        step=jnp.zeros((), jnp.int32),
        params=params,
        opt=adamw_init(params, m_dtype=tcfg.opt_m_dtype),
    )


def state_axes(model: Model) -> TrainState:
    """Logical-axis pytree mirroring TrainState (for shardings)."""
    paxes = model.param_axes()
    return TrainState(
        step=(),
        params=paxes,
        opt=AdamWState(step=(), mu=paxes, nu=paxes),
    )


def state_shardings(model: Model, state, mesh) -> TrainState:
    """NamedShardings for a TrainState on ``mesh`` — the trainer-side
    consumer of the shared ``distributed.tree_shardings`` resolver (the
    launch dry-run resolves batches and decode caches through the same
    helper).  ``state`` may be a TrainState of arrays or of
    ShapeDtypeStructs (e.g. from ``jax.eval_shape``)."""
    return tree_shardings(state_axes(model), state, mesh)


def make_train_step(
    model: Model, tcfg: TrainConfig
) -> Callable[[TrainState, Dict], Tuple[TrainState, Dict]]:
    """Build the (un-jitted) train_step; caller jits with in/out shardings."""
    opt_cfg = AdamWConfig(
        lr=tcfg.lr, weight_decay=tcfg.weight_decay, clip_norm=tcfg.clip_norm,
        m_dtype=tcfg.opt_m_dtype,
    )
    sched = make_lr_schedule(tcfg.lr, tcfg.warmup_steps, tcfg.total_steps)

    def loss_fn(params, batch):
        loss, parts = model.loss(params, batch)
        return loss, parts

    def train_step(state: TrainState, batch: Dict) -> Tuple[TrainState, Dict]:
        nm = tcfg.microbatches
        if nm > 1:
            # split batch on the leading axis into microbatches and scan
            def split(x):
                b = x.shape[0]
                return x.reshape((nm, b // nm) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                g_acc, l_acc = carry
                (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mb
                )
                g_acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype), g_acc, grads)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (grads, loss_sum), _ = jax.lax.scan(
                acc_fn, (g0, jnp.zeros((), jnp.float32)), micro
            )
            grads = jax.tree.map(lambda g: g / nm, grads)
            loss = loss_sum / nm
            parts = {}
        else:
            (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, batch
            )

        lr = sched(state.step)
        params, opt, gnorm = adamw_update(state.params, grads, state.opt, opt_cfg, lr=lr)
        new_state = TrainState(step=state.step + 1, params=params, opt=opt)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr, **parts}
        return new_state, metrics

    return train_step


def batch_axes(model: Model) -> Dict:
    """Logical axes for the input batch pytree."""
    cfg = model.cfg
    if cfg.family == "audio":
        return {"frames": ("batch", "seq", None), "labels": ("batch", "seq")}
    b = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    if cfg.family == "vlm":
        b["patches"] = ("batch", None, None)
    return b
