"""Optimizers (pure pytree implementations — no optax dependency).

AdamW with decoupled weight decay, global-norm clipping, and a linear-warmup
cosine-decay schedule.  State is a pytree mirroring the parameters, so it
shards with the same rules as the parameters (FSDP-friendly).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update", "clip_by_global_norm", "make_lr_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0
    # Low-precision first moment (beyond-paper memory optimization): m is
    # smooth/bounded so bf16 storage costs ~nothing in quality and saves
    # 2 bytes/param; v keeps fp32 (wide dynamic range).  Update math is fp32.
    m_dtype: str = "float32"


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params, m_dtype: str = "float32") -> AdamWState:
    mdt = jnp.dtype(m_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
        nu=jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    )


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads), gnorm


def adamw_update(
    params,
    grads,
    state: AdamWState,
    cfg: AdamWConfig,
    lr: Optional[jnp.ndarray] = None,
):
    """Returns (new_params, new_state, grad_norm)."""
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        leaves = jax.tree.leaves(grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    step = state.step + 1
    lr_t = cfg.lr if lr is None else lr
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def _upd(p, g, m, v):
        # fp32 math; stored moments keep their state dtype (aliasing-safe:
        # input and output state dtypes must match for buffer donation)
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = _upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    return (
        tdef.unflatten(new_p),
        AdamWState(step=step, mu=tdef.unflatten(new_m), nu=tdef.unflatten(new_v)),
        gnorm,
    )


def make_lr_schedule(
    base_lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1
) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(1.0, step / jnp.maximum(1.0, warmup_steps))
        frac = jnp.clip(
            (step - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps), 0.0, 1.0
        )
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup_steps, warm, base_lr * cos)

    return sched
