"""Shims over jax API drift (0.4.x .. 0.6+), collected in one place.

Every site that needs one of these imports it from here, so the next jax
rename is a one-file fix.
"""
from __future__ import annotations

import jax

__all__ = [
    "Mesh",
    "NamedSharding",
    "PartitionSpec",
    "SingleDeviceSharding",
    "shard_map",
    "make_mesh",
    "activate_mesh",
    "cost_analysis",
    "on_tpu",
    "enable_compilation_cache_flags",
    "register_monitoring_listener",
]

# The sharding types the rest of the repo may name.  They have moved once
# already (jax.experimental.maps/pjit era -> jax.sharding); importing them
# from here keeps the next move a one-file fix.  repro.analysis TAO001
# flags any direct jax.sharding/jax.experimental use outside this module.
Mesh = jax.sharding.Mesh
NamedSharding = jax.sharding.NamedSharding
PartitionSpec = jax.sharding.PartitionSpec
SingleDeviceSharding = jax.sharding.SingleDeviceSharding


def on_tpu() -> bool:
    """True when the default jax backend is a real TPU.

    The kernel wrappers in ``repro.kernels`` use this to pick native Mosaic
    lowering on TPU and ``interpret=True`` everywhere else, so CPU CI runs
    the same Pallas programs.
    """
    return jax.default_backend() == "tpu"

if hasattr(jax, "shard_map"):  # jax >= 0.6
    shard_map = jax.shard_map
else:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401


def make_mesh(shape, axes):
    """jax.make_mesh; newer jax wants explicit axis types, 0.4.x has none."""
    try:
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    except (AttributeError, TypeError):
        return jax.make_mesh(tuple(shape), tuple(axes))


def activate_mesh(mesh):
    """Context manager activating a mesh: jax.set_mesh on >= 0.6; on 0.4.x
    the Mesh object is itself the context manager."""
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh


def cost_analysis(compiled):
    """compiled.cost_analysis() returns a dict on recent jax, a one-element
    list of dicts on 0.4.x."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def enable_compilation_cache_flags(directory: str) -> bool:
    """Point jax's persistent compilation cache at ``directory``; returns
    False when this jax build has no persistent-cache support at all.  The
    size/time thresholds are zeroed where the flags exist (their names and
    availability drifted across 0.4.x) so even sub-millisecond CPU-sized
    executables persist — exactly the ones this repro's cold-start tests
    replay."""
    try:
        jax.config.update("jax_compilation_cache_dir", directory)
    except (AttributeError, KeyError, ValueError):
        return False
    for flag, value in (
        ("jax_persistent_cache_min_entry_size_bytes", -1),
        ("jax_persistent_cache_min_compile_time_secs", 0.0),
    ):
        try:
            jax.config.update(flag, value)
        except (AttributeError, KeyError, ValueError):
            pass
    return True


def register_monitoring_listener(callback) -> bool:
    """jax.monitoring.register_event_listener where available (the event
    stream the persistent-cache hit/miss counters ride on); returns False
    on jax builds without it — counters then just stay 0."""
    mon = getattr(jax, "monitoring", None)
    if mon is None or not hasattr(mon, "register_event_listener"):
        return False
    mon.register_event_listener(callback)
    return True
