"""Shims over jax API drift (0.4.x .. 0.6+), collected in one place.

Every site that needs one of these imports it from here, so the next jax
rename is a one-file fix.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "make_mesh", "activate_mesh", "cost_analysis", "on_tpu"]


def on_tpu() -> bool:
    """True when the default jax backend is a real TPU.

    The kernel wrappers in ``repro.kernels`` use this to pick native Mosaic
    lowering on TPU and ``interpret=True`` everywhere else, so CPU CI runs
    the same Pallas programs.
    """
    return jax.default_backend() == "tpu"

if hasattr(jax, "shard_map"):  # jax >= 0.6
    shard_map = jax.shard_map
else:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401


def make_mesh(shape, axes):
    """jax.make_mesh; newer jax wants explicit axis types, 0.4.x has none."""
    try:
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    except (AttributeError, TypeError):
        return jax.make_mesh(tuple(shape), tuple(axes))


def activate_mesh(mesh):
    """Context manager activating a mesh: jax.set_mesh on >= 0.6; on 0.4.x
    the Mesh object is itself the context manager."""
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh


def cost_analysis(compiled):
    """compiled.cost_analysis() returns a dict on recent jax, a one-element
    list of dicts on 0.4.x."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca
