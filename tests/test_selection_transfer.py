"""Mahalanobis design selection (§4.3) + transfer-learning regimes (§5.5)."""
import jax
import numpy as np

from repro.core.selection import (
    mahalanobis_matrix,
    measure_design_metrics,
    select_pair_mahalanobis,
    select_random,
)
from repro.core.transfer import train_tao, transfer_finetune


def test_mahalanobis_matrix_properties():
    rng = np.random.default_rng(0)
    m = rng.normal(size=(6, 4))
    d = mahalanobis_matrix(m)
    assert d.shape == (6, 6)
    assert np.allclose(d, d.T)
    assert np.allclose(np.diag(d), 0)
    assert (d >= 0).all()


def test_mahalanobis_picks_outlier_pair():
    # cluster + two opposite outliers: the outlier pair is farthest
    rng = np.random.default_rng(1)
    pts = rng.normal(size=(8, 4)) * 0.05
    pts[0] = [3, 3, 3, 3]
    pts[1] = [-3, -3, -3, -3]
    i, j = select_pair_mahalanobis(pts)
    assert {i, j} == {0, 1}


def test_mahalanobis_scale_invariant_euclidean_not():
    """The paper picks Mahalanobis because it normalizes metric scales; the
    clean statement of that property: rescaling one metric column leaves
    the MD matrix unchanged, while Euclidean distances change arbitrarily."""
    rng = np.random.default_rng(2)
    pts = rng.normal(size=(6, 4))
    scaled = pts.copy()
    scaled[:, 0] *= 1000.0
    d1 = mahalanobis_matrix(pts)
    d2 = mahalanobis_matrix(scaled)
    np.testing.assert_allclose(d1, d2, rtol=1e-6, atol=1e-8)
    # euclidean selection generally flips to the scaled column's extremes
    e1 = np.linalg.norm(pts[0] - pts[1])
    e2 = np.linalg.norm(scaled[0] - scaled[1])
    assert abs(e1 - e2) > 1.0


def test_select_random_distinct():
    sel = select_random(10, 4, seed=3)
    assert len(set(sel)) == 4


def test_measure_design_metrics_shape():
    from repro.uarch import UARCH_A, UARCH_B

    m = measure_design_metrics([UARCH_A, UARCH_B], ["lee"], instructions=800)
    assert m.shape == (2, 4)
    assert (m[:, 0] > 0).all()  # CPI positive


def test_transfer_freezes_shared_embeddings(small_tao_setup):
    cfg, ds, _, _ = small_tao_setup
    donor = train_tao(cfg, ds.subsample(12), epochs=1, batch_size=4)
    res = transfer_finetune(
        cfg,
        donor.params["embed"],
        donor.params,
        ds.subsample(12),
        epochs=2,
        batch_size=4,
    )
    for a, b in zip(
        jax.tree.leaves(donor.params["embed"]), jax.tree.leaves(res.params["embed"])
    ):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # prediction layers did change
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(
            jax.tree.leaves(donor.params["pred"]), jax.tree.leaves(res.params["pred"])
        )
    )
    assert changed
