"""Pallas feature-extraction kernels vs the NumPy executable specification.

The contract is EXACT (bitwise) equivalence: branch-history rows move only
{-1, 0, +1} values, memory-distance deltas are exact int32 subtractions,
and the signed-log compression runs as an op-per-dispatch jax twin of
``core.features.signed_log`` (both sides a fixed chain of individually
rounded float32 ops).  Covers hash-collision-heavy traces (many PCs per
bucket), empty-queue boundaries, chunk-boundary geometry, and the int32
address-window fallback.
"""
import numpy as np
import pytest

from repro.core.features import (
    FeatureConfig,
    extract_features,
    extract_features_reference,
    signed_log,
)
from repro.kernels.features.ops import (
    ADDR_EXACT_LIMIT,
    branch_history_scan,
    extract_features_device,
    memdist_delta_scan,
    signed_log_device,
    trace_columns,
)
from repro.kernels.features.ref import (
    branch_history_scan_ref,
    memdist_delta_scan_ref,
)
from repro.uarch import get_benchmark, run_functional
from repro.uarch.isa import FUNC_TRACE_DTYPE, Op

FIELDS = ("opcode", "regbits", "flags", "brhist", "memdist")


def _assert_featuresets_bitwise(a, b, msg=""):
    for f in FIELDS:
        np.testing.assert_array_equal(
            getattr(a, f), getattr(b, f), err_msg=f"{msg}/{f}"
        )


def _random_trace(n, rng, branch_p=0.4, mem_p=0.4, pc_mod=64, addr_hi=1 << 20):
    t = np.zeros(n, dtype=FUNC_TRACE_DTYPE)
    t["pc"] = rng.integers(0, pc_mod, n) * 4
    t["opcode"] = rng.integers(0, len(Op), n)
    t["dst"] = rng.integers(0, 32, n)
    t["src1"] = rng.integers(0, 32, n)
    t["src2"] = rng.integers(0, 32, n)
    t["is_branch"] = rng.random(n) < branch_p
    t["taken"] = rng.random(n) < 0.5
    t["is_mem"] = (rng.random(n) < mem_p) & ~t["is_branch"]
    t["is_store"] = t["is_mem"] & (rng.random(n) < 0.5)
    t["addr"] = np.where(t["is_mem"], rng.integers(0, addr_hi, n), 0)
    return t


# ---------------------------------------------------------------------------
# signed-log determinism
# ---------------------------------------------------------------------------


def test_signed_log_numpy_jax_bitwise_identical():
    """The NumPy spec and its eager-jax twin agree bit for bit."""
    rng = np.random.default_rng(7)
    d = np.concatenate(
        [
            np.arange(-4096, 4096),
            rng.integers(-(2**24), 2**24, 100_000),
            rng.integers(-(2**31) + 1, 2**31 - 1, 50_000),
            [0, 1, -1, 2**24, -(2**24), 2**31 - 100],
        ]
    ).astype(np.float32)
    a = signed_log(d)
    b = np.asarray(signed_log_device(d))
    np.testing.assert_array_equal(a.view(np.int32), b.view(np.int32))


def test_signed_log_accuracy_vs_true_log2():
    rng = np.random.default_rng(8)
    d = rng.integers(1, 2**24, 20_000).astype(np.float64)
    got = signed_log(d).astype(np.float64)
    want = np.log2(1.0 + d) / 32.0
    np.testing.assert_allclose(got, want, rtol=2e-7, atol=0)


# ---------------------------------------------------------------------------
# kernels vs jnp scan oracles (padding / chunk geometry)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,chunk", [(64, 64), (100, 32), (7, 32), (515, 128)])
def test_branch_history_kernel_vs_scan_ref(n, chunk):
    rng = np.random.default_rng(n * 31 + chunk)
    n_buckets, n_queue = 8, 5
    bucket = rng.integers(0, n_buckets, n).astype(np.int32)
    outcome = rng.choice([-1.0, 0.0, 1.0], n).astype(np.float32)
    ker = branch_history_scan(
        bucket, outcome, n_buckets=n_buckets, n_queue=n_queue, chunk=chunk
    )
    ref = branch_history_scan_ref(
        bucket, outcome, n_buckets=n_buckets, n_queue=n_queue
    )
    np.testing.assert_array_equal(np.asarray(ker), np.asarray(ref))


@pytest.mark.parametrize("n,chunk", [(64, 64), (100, 32), (7, 32), (515, 128)])
def test_memdist_kernel_vs_scan_ref(n, chunk):
    rng = np.random.default_rng(n * 37 + chunk)
    n_mem = 6
    addr = rng.integers(0, 1 << 20, n).astype(np.int32)
    mem = (rng.random(n) < 0.6).astype(np.int32)
    ker = memdist_delta_scan(addr, mem, n_mem=n_mem, chunk=chunk)
    ref = memdist_delta_scan_ref(addr, mem, n_mem=n_mem)
    np.testing.assert_array_equal(np.asarray(ker), np.asarray(ref))


def test_kernels_empty_input():
    assert branch_history_scan(
        np.zeros(0, np.int32), np.zeros(0, np.float32), n_buckets=4, n_queue=3
    ).shape == (0, 3)
    assert memdist_delta_scan(
        np.zeros(0, np.int32), np.zeros(0, np.int32), n_mem=4
    ).shape == (0, 4)


# ---------------------------------------------------------------------------
# device extraction vs the NumPy executable specification (bitwise)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bench", ["mcf", "dee", "lee"])
def test_device_extraction_matches_reference_bitwise(bench):
    ft = run_functional(get_benchmark(bench), 2500)
    for cfg in (
        FeatureConfig(n_buckets=32, n_queue=4, n_mem=8),
        FeatureConfig(n_buckets=2, n_queue=3, n_mem=2),
    ):
        ref = extract_features_reference(ft, cfg, with_labels=False)
        dev = extract_features_device(ft, cfg, with_labels=False, chunk=256)
        _assert_featuresets_bitwise(ref, dev, msg=f"{bench}/{cfg.n_buckets}")


def test_device_extraction_hash_collision_heavy():
    """Many distinct PCs folded into very few buckets (paper Fig 4's
    deliberate aliasing) — the device table must mix histories exactly as
    the per-branch interpreter loop does."""
    rng = np.random.default_rng(3)
    t = _random_trace(4000, rng, branch_p=0.8, mem_p=0.15, pc_mod=512)
    for cfg in (
        FeatureConfig(n_buckets=1, n_queue=4, n_mem=4),
        FeatureConfig(n_buckets=2, n_queue=8, n_mem=4),
        FeatureConfig(n_buckets=3, n_queue=5, n_mem=4),  # non-power-of-two
    ):
        ref = extract_features_reference(t, cfg, with_labels=False)
        dev = extract_features_device(t, cfg, with_labels=False, chunk=512)
        _assert_featuresets_bitwise(ref, dev, msg=f"nb={cfg.n_buckets}")


def test_device_extraction_empty_queue_boundaries():
    """First-branch / first-access rows see empty queues; traces with no
    branches or no memory ops at all stay all-zero."""
    cfg = FeatureConfig(n_buckets=4, n_queue=3, n_mem=3)
    rng = np.random.default_rng(5)
    cases = {
        "no_branches": _random_trace(300, rng, branch_p=0.0, mem_p=0.5),
        "no_mem": _random_trace(300, rng, branch_p=0.5, mem_p=0.0),
        "neither": _random_trace(300, rng, branch_p=0.0, mem_p=0.0),
        "single": _random_trace(1, rng),
        "pair": _random_trace(2, rng),
    }
    for name, t in cases.items():
        ref = extract_features_reference(t, cfg, with_labels=False)
        dev = extract_features_device(t, cfg, with_labels=False, chunk=64)
        _assert_featuresets_bitwise(ref, dev, msg=name)
    assert not extract_features_device(
        cases["neither"], cfg, with_labels=False
    ).brhist.any()


def test_device_extraction_matches_vectorized_bitwise():
    """All three implementations (reference loop, vectorized NumPy, Pallas)
    agree bitwise on a mem-heavy trace with negative/zero/duplicate deltas."""
    rng = np.random.default_rng(11)
    t = _random_trace(2000, rng, branch_p=0.3, mem_p=0.7, addr_hi=1 << 24)
    cfg = FeatureConfig(n_buckets=16, n_queue=6, n_mem=12)
    ref = extract_features_reference(t, cfg, with_labels=False)
    vec = extract_features(t, cfg, with_labels=False)
    dev = extract_features_device(t, cfg, with_labels=False)
    _assert_featuresets_bitwise(ref, vec, msg="vec")
    _assert_featuresets_bitwise(ref, dev, msg="dev")


def test_trace_columns_rejects_wide_addresses():
    t = _random_trace(16, np.random.default_rng(0))
    t["addr"][3] = ADDR_EXACT_LIMIT  # exactly at the limit -> reject
    assert trace_columns(t, FeatureConfig()) is None
    with pytest.raises(ValueError):
        extract_features_device(t, FeatureConfig(), with_labels=False)


def test_device_extraction_labels_passthrough(small_tao_setup):
    cfg, _, al, _ = small_tao_setup
    dev = extract_features_device(al.adjusted, cfg.features, with_labels=True)
    ref = extract_features_reference(al.adjusted, cfg.features, with_labels=True)
    _assert_featuresets_bitwise(ref, dev, msg="adjusted")
    assert dev.labels is not None
    np.testing.assert_array_equal(dev.labels["fetch_lat"], ref.labels["fetch_lat"])
