"""Trace-server (simulation-as-a-service) tests.

Covers the PR-7 serving surface: continuous batching into the engine's
per-geometry executable pool (the acceptance test: a warm server under
concurrent mixed-tenant load — 2 geometries, 2 models, 4 clients —
performs 0 request-attributed compiles and 0 redundant feature
extractions while returning metrics bit-identical to direct
``TrainedModel.simulate``), tenant fairness, queue-bound backpressure
with 429-style retry hints, content-digest feature coalescing (memory and
store), the stable ``ServeError`` code vocabulary, the model registry's
publish/resolve round-trip, the JSON-lines TCP front end, and the
``to_dict`` wire contracts on results, reports, and stats.
"""
from __future__ import annotations

import asyncio
import json

import jax
import numpy as np
import pytest

from repro.api import (
    ModelRegistry,
    ServeError,
    ServeRequest,
    ServeResult,
    Session,
    TraceServer,
    TrainedModel,
)
from repro.core import FeatureConfig, TaoConfig, init_tao
from repro.engine.runner import (
    MetricNotCollectedError,
    MetricNotComputedError,
)
from repro.serve import decode_trace, encode_trace
from repro.store import ArtifactStore

CFG = TaoConfig(
    window=9, d_model=16, n_heads=2, n_layers=1, d_ff=32, d_cat=8,
    features=FeatureConfig(n_buckets=64, n_queue=4, n_mem=8),
)


@pytest.fixture(scope="module")
def sess():
    return Session(CFG)


@pytest.fixture(scope="module")
def traces(sess):
    # two distinct window geometries under one config: w_eff=9 and w_eff=6
    return {
        "long": sess.capture("mcf", 1200),
        "mid": sess.capture("dee", 600),
        "short": sess.capture("lee", 6),
    }


@pytest.fixture(scope="module")
def models():
    return {
        name: TrainedModel(
            params=init_tao(jax.random.PRNGKey(i), CFG), cfg=CFG, name=name
        )
        for i, name in enumerate(("base", "tuned"))
    }


@pytest.fixture()
def registry(models):
    reg = ModelRegistry()
    for name, m in models.items():
        reg.register(name, m)
    return reg


def _serve(coro):
    return asyncio.run(coro)


# ---------------------------------------------------------------------------
# Acceptance: warm server, mixed tenants/geometries/models, 0 compiles,
# 0 redundant extractions, bit-identical to direct simulate
# ---------------------------------------------------------------------------


@pytest.mark.sanitize
def test_warm_server_mixed_load_zero_compiles(registry, traces, models):
    load = {
        "alice": [("base", "long"), ("tuned", "short")],
        "bob": [("tuned", "long"), ("base", "mid")],
        "carol": [("base", "long"), ("base", "short")],
        "dave": [("tuned", "mid"), ("tuned", "long")],
    }

    async def run():
        server = TraceServer(registry, batch_size=8, max_queue=64)
        async with server:
            server.warmup([len(t) for t in traces.values()])
            assert server.num_compiles == 0

            async def tenant(name, jobs):
                futs = [
                    server.submit(ServeRequest(model=m, trace=traces[t],
                                               tenant=name))
                    for m, t in jobs
                ]
                return await asyncio.gather(*futs)

            out = await asyncio.gather(
                *(tenant(name, jobs) for name, jobs in load.items())
            )
            stats = server.stats()
        return out, stats, server

    out, stats, server = _serve(run())

    # 0 XLA compiles attributed to serving; warmup paid for everything
    assert server.num_compiles == 0
    assert stats.num_compiles == 0
    assert stats.completed == 8 and stats.failed == 0

    # 0 redundant extractions: one pre-pass per distinct trace, the other
    # five requests coalesced onto them
    assert stats.features_extracted == 3
    assert stats.features_coalesced == 5

    # both geometries and all four tenants were served
    assert set(stats.per_geometry) == {"w9b8", "w6b8"}
    assert set(stats.per_tenant) == {"alice", "bob", "carol", "dave"}

    # bit-identical to the direct path (same executables, same features)
    for (tname, jobs), res in zip(load.items(), out):
        for (mname, tkey), r in zip(jobs, res):
            assert r.tenant == tname and r.model == mname
            direct = models[mname].simulate(traces[tkey], batch_size=8)
            assert r.num_instructions == direct.num_instructions
            for k, v in r.metrics.items():
                assert np.array_equal(
                    np.asarray(v), np.asarray(direct.metrics[k])
                ), (tname, k)


# ---------------------------------------------------------------------------
# Fairness: round-robin across tenants within a geometry
# ---------------------------------------------------------------------------


def test_tenant_fairness_interleaving(registry, traces):
    order = []

    async def run():
        server = TraceServer(registry, batch_size=8, max_queue=64)
        async with server:
            futs = []
            # tenant A floods 12 requests before B's 4 arrive; all same
            # geometry, so only tenant round-robin separates them
            for i in range(12):
                f = server.submit(ServeRequest(model="base",
                                               trace=traces["long"],
                                               tenant="A", request_id=f"A{i}"))
                f.add_done_callback(lambda _f: order.append("A"))
                futs.append(f)
            for i in range(4):
                f = server.submit(ServeRequest(model="base",
                                               trace=traces["long"],
                                               tenant="B", request_id=f"B{i}"))
                f.add_done_callback(lambda _f: order.append("B"))
                futs.append(f)
            await asyncio.gather(*futs)

    _serve(run())
    assert len(order) == 16
    # B's k-th completion must land by slot 2k+1 (strict alternation while
    # both tenants have work) — a flooding tenant cannot starve B
    b_slots = [i for i, t in enumerate(order) if t == "B"]
    assert len(b_slots) == 4
    for k, slot in enumerate(b_slots):
        assert slot <= 2 * k + 1, (order, b_slots)


# ---------------------------------------------------------------------------
# Backpressure: bounded admission, 429-style rejection, recovery
# ---------------------------------------------------------------------------


def test_backpressure_queue_full_and_recovery(registry, traces):
    async def run():
        server = TraceServer(registry, batch_size=8, max_queue=4)
        async with server:
            futs = [
                server.submit(ServeRequest(model="base",
                                           trace=traces["short"]))
                for _ in range(4)
            ]
            with pytest.raises(ServeError) as ei:
                server.submit(ServeRequest(model="base",
                                           trace=traces["short"]))
            err = ei.value
            assert err.code == "QUEUE_FULL"
            assert err.retry_after_s is not None and err.retry_after_s > 0
            d = err.to_dict()
            assert d["error"] == "QUEUE_FULL" and "retry_after_s" in d
            rejected_at = server.stats().rejected

            await asyncio.gather(*futs)          # drain
            # after draining, admission works again
            r = await server.submit(ServeRequest(model="base",
                                                 trace=traces["short"]))
            assert r.num_instructions == len(traces["short"])
            return rejected_at, server.stats()

    rejected_at, stats = _serve(run())
    assert rejected_at == 1
    assert stats.rejected == 1 and stats.completed == 5


# ---------------------------------------------------------------------------
# Feature coalescing: in-memory dedup and store-backed reuse
# ---------------------------------------------------------------------------


def test_feature_coalescing_across_models_and_store(registry, traces,
                                                    tmp_path):
    store = ArtifactStore(str(tmp_path / "s"))

    async def run(reg):
        server = TraceServer(reg, batch_size=8, store=store)
        async with server:
            futs = [
                server.submit(ServeRequest(model=m, trace=traces["mid"]))
                for m in ("base", "tuned", "base")
            ]
            await asyncio.gather(*futs)
        return server.stats()

    s1 = _serve(run(registry))
    # one extraction serves all three requests (two models, one digest)
    assert s1.features_extracted == 1
    assert s1.features_from_store == 0
    assert s1.features_coalesced == 2

    # a fresh server over the same store: zero extractions, store hit
    reg2 = ModelRegistry()
    for name in ("base", "tuned"):
        reg2.register(name, registry.resolve(name))
    s2 = _serve(run(reg2))
    assert s2.features_extracted == 0
    assert s2.features_from_store == 1
    assert s2.features_coalesced == 2


# ---------------------------------------------------------------------------
# Error surface: the stable code vocabulary
# ---------------------------------------------------------------------------


def test_error_codes_unknown_model_bad_request(registry, traces):
    async def run():
        server = TraceServer(registry, batch_size=8)
        async with server:
            with pytest.raises(ServeError) as ei:
                server.submit(ServeRequest(model="nope",
                                           trace=traces["short"]))
            assert ei.value.code == "UNKNOWN_MODEL"

            empty = np.empty(0, traces["short"].functional.dtype)
            with pytest.raises(ServeError) as ei:
                server.submit(ServeRequest(model="base", trace=empty))
            assert ei.value.code == "BAD_REQUEST"

            with pytest.raises(ServeError) as ei:
                server.submit(ServeRequest(model="base",
                                           trace=traces["short"],
                                           metrics=("no_such_metric",)))
            assert ei.value.code == "BAD_REQUEST"

    _serve(run())


def test_error_wrap_mapping_never_leaks():
    assert ServeError.wrap(MetricNotCollectedError("x")).code == \
        "METRIC_NOT_COLLECTED"
    assert ServeError.wrap(MetricNotComputedError("x")).code == \
        "METRIC_NOT_COMPUTED"
    e = ServeError.wrap(RuntimeError("secret internal path /etc/x"))
    assert e.code == "INTERNAL"
    assert "secret" not in e.message and "/etc" not in e.message
    # already-a-ServeError passes through untouched
    orig = ServeError("QUEUE_FULL", "full", retry_after_s=1.0)
    assert ServeError.wrap(orig) is orig
    with pytest.raises(ValueError):
        ServeError("NOT_A_CODE", "x")


def test_shutdown_rejects_and_drain_false_fails_pending(registry, traces):
    async def run():
        server = TraceServer(registry, batch_size=8)
        await server.start()
        fut = server.submit(ServeRequest(model="base",
                                         trace=traces["short"]))
        await server.stop(drain=False)
        with pytest.raises(ServeError) as ei:
            await fut
        assert ei.value.code == "SHUTTING_DOWN"
        with pytest.raises(ServeError) as ei:
            server.submit(ServeRequest(model="base", trace=traces["short"]))
        assert ei.value.code == "SHUTTING_DOWN"

    _serve(run())


# ---------------------------------------------------------------------------
# Registry: publish/resolve round-trip through the store
# ---------------------------------------------------------------------------


def test_registry_publish_resolve_roundtrip(models, traces, tmp_path):
    store = ArtifactStore(str(tmp_path / "s"))
    reg = ModelRegistry(store)
    reg.register("served", models["base"], publish=True)
    assert "served" in reg and len(reg) == 1

    # a fresh registry over the same store resolves the name cold
    reg2 = ModelRegistry(store)
    assert "served" in reg2
    assert dict(reg2.published())["served"]["cfg"]["window"] == CFG.window
    m = reg2.resolve("served")
    assert m.cfg == CFG
    r_direct = models["base"].simulate(traces["short"], batch_size=8)
    r_resolved = m.simulate(traces["short"], batch_size=8)
    assert r_resolved.cpi == r_direct.cpi

    # name rebinding is explicit
    with pytest.raises(ValueError, match="overwrite"):
        reg2.publish("served", models["tuned"])
    reg2.publish("served", models["tuned"], overwrite=True)
    reg3 = ModelRegistry(store)
    got = reg3.resolve("served")
    leaves = list(zip(jax.tree.leaves(got.params),
                      jax.tree.leaves(models["tuned"].params)))
    assert all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in leaves)

    with pytest.raises(ServeError) as ei:
        reg3.resolve("never-published")
    assert ei.value.code == "UNKNOWN_MODEL"


# ---------------------------------------------------------------------------
# Plan switching (multi-device only)
# ---------------------------------------------------------------------------


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >= 2 devices for a sharded plan")
def test_set_plan_switch_without_restart(registry, traces):
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:2]), ("data",))

    async def run():
        server = TraceServer(registry, batch_size=8)
        async with server:
            r1 = await server.submit(ServeRequest(model="base",
                                                  trace=traces["long"]))
            plan = server.set_plan(mesh=mesh)
            assert plan.kind == "sharded" and plan.num_shards == 2
            r2 = await server.submit(ServeRequest(model="base",
                                                  trace=traces["long"]))
            server.set_plan()                      # back to single-device
            r3 = await server.submit(ServeRequest(model="base",
                                                  trace=traces["long"]))
            stats = server.stats()
        assert np.asarray(r1.metrics["cpi"]) == pytest.approx(
            np.asarray(r2.metrics["cpi"]), rel=1e-5)
        assert np.array_equal(np.asarray(r1.metrics["cpi"]),
                              np.asarray(r3.metrics["cpi"]))
        assert stats.plan_kind == "single"

    _serve(run())


# ---------------------------------------------------------------------------
# TCP front end (JSON lines)
# ---------------------------------------------------------------------------


def test_tcp_front_end_simulate_stats_models(registry, traces, models):
    from repro.launch.serve import serve_forever

    async def run():
        server = TraceServer(registry, batch_size=8, max_queue=16)
        async with server:
            ready = asyncio.get_running_loop().create_future()
            tcp = asyncio.get_running_loop().create_task(
                serve_forever(server, "127.0.0.1", 0, ready))
            _, port = await ready
            reader, writer = await asyncio.open_connection("127.0.0.1", port)

            def send(obj):
                writer.write(json.dumps(obj).encode() + b"\n")

            send({"op": "models"})
            send({"op": "simulate", "model": "base", "tenant": "wire",
                  "request_id": "w0",
                  "trace": encode_trace(traces["short"].functional)})
            send({"op": "simulate", "model": "nope", "request_id": "w1",
                  "trace": encode_trace(traces["short"].functional)})
            writer.write(b"this is not json\n")
            await writer.drain()
            resps = [json.loads(await reader.readline()) for _ in range(4)]
            send({"op": "stats"})            # after the simulate completed
            await writer.drain()
            resps.append(json.loads(await reader.readline()))
            writer.close()
            tcp.cancel()
        return resps

    resps = _serve(run())
    by_kind = {}
    for r in resps:
        if "models" in r:
            by_kind["models"] = r
        elif "stats" in r:
            by_kind["stats"] = r
        elif r.get("ok") and "result" in r:
            by_kind["result"] = r
        elif r.get("error") == "UNKNOWN_MODEL":
            by_kind["unknown"] = r
        elif r.get("error") == "BAD_REQUEST":
            by_kind["bad"] = r
    assert set(by_kind) == {"models", "stats", "result", "unknown", "bad"}
    assert by_kind["models"]["models"] == ["base", "tuned"]
    assert by_kind["result"]["result"]["request_id"] == "w0"
    assert by_kind["result"]["result"]["metrics"]["cpi"] > 0
    assert by_kind["stats"]["stats"]["completed"] >= 1


def test_trace_wire_codec_roundtrip(traces):
    arr = traces["mid"].functional
    enc = encode_trace(arr)
    json.dumps(enc)                                  # wire-clean
    dec = decode_trace(enc)
    assert dec.dtype == arr.dtype
    np.testing.assert_array_equal(dec, arr)
    bad = dict(enc)
    bad["shape"] = [len(arr) + 1]
    with pytest.raises(ValueError, match="bytes"):
        decode_trace(bad)


# ---------------------------------------------------------------------------
# to_dict wire contracts
# ---------------------------------------------------------------------------


def test_to_dict_contracts_json_clean(registry, traces, models, sess):
    async def run():
        server = TraceServer(registry, batch_size=8)
        async with server:
            r = await server.submit(ServeRequest(
                model="base", trace=traces["mid"], request_id="rid"))
            stats = server.stats()
        return r, stats

    r, stats = _serve(run())
    assert isinstance(r, ServeResult)
    d = json.loads(json.dumps(r.to_dict()))
    assert d["request_id"] == "rid" and d["geometry"] == "w9b8"
    assert isinstance(d["metrics"]["cpi"], float)
    sd = json.loads(json.dumps(stats.to_dict()))
    assert sd["completed"] == 1 and "per_geometry" in sd

    # SimulationResult / SweepReport wire forms (satellite contract)
    sim = models["base"].simulate(traces["short"], batch_size=8)
    simd = json.loads(json.dumps(sim.to_dict()))
    assert simd["metrics"]["cpi"] == pytest.approx(sim.cpi)
    rep = sess.sweep({"m": models["base"]}, {"t": traces["short"]},
                     batch_size=8)
    repd = json.loads(json.dumps(rep.to_dict()))
    assert repd["results"]["m/t"]["metrics"]["cpi"] == pytest.approx(
        rep.results["m/t"].cpi)
    assert repd["num_traces"] == 1
