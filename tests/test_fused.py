"""Fused trace->logits megakernel + int8 quantized-path tests.

The tentpole contracts, each enforced bitwise or with a declared band:

  * the fused megakernel == the ``lax.scan`` oracle == the staged Pallas
    extraction, bit-for-bit, across chunk/length geometry sweeps;
  * batch-granular extraction with the scan state threaded across
    ``FusedExtractor.next_batch`` calls == one monolithic pass;
  * ``feature_backend="fused"`` produces CPI / MPKI / phase curves
    bit-identical to the ``"pallas"`` and ``"numpy"`` backends, while
    SHARING their compiled step (one compile per geometry, ever);
  * the int8 W8A8 path holds the ``bench_accuracy`` parity band
    (|dCPI|/CPI <= 5%, |dMPKI| <= max(10%, 5.0)) and gets its own
    step-cache entry (precision is part of the key);
  * a warm server with the fused backend serves with 0 compiles under
    ``sanitized(compile_budget=0)``.
"""
import asyncio

import jax
import numpy as np
import pytest

from repro.core import FeatureConfig, TaoConfig, init_tao
from repro.engine import (
    EngineConfig,
    StreamingEngine,
    cache_stats,
    clear_step_cache,
)
from repro.kernels.features.ops import (
    device_feature_arrays,
    signed_log_device,
    trace_columns,
)
from repro.kernels.fused.ops import (
    FusedExtractor,
    fused_feature_columns,
    init_fused_state,
)
from repro.kernels.fused.ref import fused_scan_ref, init_state_ref
from repro.uarch import get_benchmark, run_functional
from repro.uarch.isa import FUNC_TRACE_DTYPE, Op

FCFG = FeatureConfig(n_buckets=32, n_queue=4, n_mem=8)
CFG = TaoConfig(
    window=17, d_model=32, n_heads=2, n_layers=1, d_ff=64, d_cat=16, features=FCFG
)

FEATURE_FIELDS = ("opcode", "regbits", "flags", "brhist", "memdist")


def _random_trace(n, rng, branch_p=0.4, mem_p=0.4, pc_mod=64, addr_hi=1 << 20):
    t = np.zeros(n, dtype=FUNC_TRACE_DTYPE)
    t["pc"] = rng.integers(0, pc_mod, n) * 4
    t["opcode"] = rng.integers(0, len(Op), n)
    t["dst"] = rng.integers(0, 32, n)
    t["src1"] = rng.integers(0, 32, n)
    t["src2"] = rng.integers(0, 32, n)
    t["is_branch"] = rng.random(n) < branch_p
    t["taken"] = t["is_branch"] & (rng.random(n) < 0.5)
    t["is_mem"] = ~t["is_branch"] & (rng.random(n) < mem_p)
    t["is_store"] = t["is_mem"] & (rng.random(n) < 0.4)
    t["addr"] = np.where(t["is_mem"], rng.integers(0, addr_hi, n), 0)
    return t


def _assert_bitwise(a, b, msg=""):
    a = np.asarray(a)
    b = np.asarray(b)
    if a.dtype == np.float32:
        np.testing.assert_array_equal(
            a.view(np.int32), b.view(np.int32), err_msg=msg
        )
    else:
        np.testing.assert_array_equal(a, b, err_msg=msg)


@pytest.fixture(scope="module")
def trace():
    return run_functional(get_benchmark("mcf"), 3000)


@pytest.fixture(scope="module")
def params():
    return init_tao(jax.random.PRNGKey(0), CFG)


# ---------------------------------------------------------------------------
# Layer 1: megakernel vs the scan oracle vs the staged backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "n,chunk",
    [(1, 256), (2, 256), (255, 256), (256, 256), (257, 256),
     (1000, 128), (1000, 512), (777, 333)],
)
def test_fused_matches_scan_ref(n, chunk):
    rng = np.random.default_rng(n * 31 + chunk)
    t = _random_trace(n, rng)
    cols = trace_columns(t, FCFG)
    feats, state = fused_feature_columns(
        cols, init_fused_state(FCFG), FCFG, chunk=chunk
    )
    outcome = np.where(
        t["is_branch"], np.where(t["taken"], 1.0, -1.0), 0.0
    ).astype(np.float32)
    ref, ref_state = fused_scan_ref(
        cols["bucket"], cols["addr"], outcome,
        cols["is_mem"].astype(np.int32),
        init_state_ref(FCFG.n_buckets, FCFG.n_queue, FCFG.n_mem),
        n_mem=FCFG.n_mem,
    )
    _assert_bitwise(feats["brhist"], ref["brhist"], "brhist")
    _assert_bitwise(
        feats["memdist"], signed_log_device(ref["memdist_raw"]), "memdist"
    )
    # carried state agrees too (table float-exact, queue/fill integer)
    _assert_bitwise(state["table"], ref_state[0], "table")
    _assert_bitwise(state["mq"][0, : FCFG.n_mem], ref_state[1], "queue")
    assert int(state["mq"][0, FCFG.n_mem]) == int(ref_state[2])


@pytest.mark.parametrize("bench", ["mcf", "dee", "lee"])
def test_fused_matches_staged_bitwise(bench):
    t = run_functional(get_benchmark(bench), 2500)
    cols = trace_columns(t, FCFG)
    staged = device_feature_arrays(cols, FCFG)
    fused, _ = fused_feature_columns(cols, init_fused_state(FCFG), FCFG)
    for f in FEATURE_FIELDS:
        _assert_bitwise(fused[f], staged[f], f"{bench}/{f}")


def test_fused_collision_and_boundary_geometry():
    rng = np.random.default_rng(7)
    for t in (
        _random_trace(4000, rng, branch_p=0.8, mem_p=0.15, pc_mod=8),
        _random_trace(300, rng, branch_p=0.0, mem_p=0.5),
        _random_trace(300, rng, branch_p=0.5, mem_p=0.0),
        _random_trace(1, rng),
    ):
        cols = trace_columns(t, FCFG)
        staged = device_feature_arrays(cols, FCFG)
        fused, _ = fused_feature_columns(cols, init_fused_state(FCFG), FCFG)
        for f in FEATURE_FIELDS:
            _assert_bitwise(fused[f], staged[f], f)


def test_fused_state_threading_across_batches():
    """Uneven batch slices with the carry threaded across megakernel calls
    == one monolithic pass (the streaming-engine contract)."""
    rng = np.random.default_rng(11)
    t = _random_trace(3000, rng)
    cols = trace_columns(t, FCFG)
    one, _ = fused_feature_columns(cols, init_fused_state(FCFG), FCFG)

    ex = FusedExtractor(cols, FCFG, pad_to=3300)
    got = {f: [] for f in FEATURE_FIELDS}
    for m in (700, 700, 700, 700, 500):
        b = ex.next_batch(m)
        for f in FEATURE_FIELDS:
            got[f].append(np.asarray(b[f]))
    for f in FEATURE_FIELDS:
        _assert_bitwise(np.concatenate(got[f])[:3000], one[f], f)
    # padded tail is inert, but running past it is a caller bug
    with pytest.raises(ValueError):
        ex.next_batch(301)
    with pytest.raises(ValueError):
        FusedExtractor(cols, FCFG, pad_to=100)


# ---------------------------------------------------------------------------
# Layer 2: the engine's "fused" backend
# ---------------------------------------------------------------------------

PHASE_METRICS = ("cpi", "branch_mpki", "l1d_mpki", "cpi_phase", "l1d_phase")


@pytest.mark.sanitize
def test_engine_fused_backend_bit_identical(params, trace):
    results = {}
    for backend in ("numpy", "pallas", "fused"):
        e = StreamingEngine(
            params, CFG,
            EngineConfig(batch_size=13, feature_backend=backend,
                         metrics=PHASE_METRICS),
        )
        results[backend] = e.simulate(trace)
        assert e.num_compiles == 1, (backend, e.num_compiles)
    base = results["numpy"]
    for backend in ("pallas", "fused"):
        r = results[backend]
        for m in ("cpi", "branch_mpki", "l1d_mpki"):
            assert r.metrics[m] == base.metrics[m], (backend, m)
        for m in ("cpi_phase", "l1d_phase"):
            _assert_bitwise(
                getattr(r, m), getattr(base, m), f"{backend}/{m}"
            )


def test_engine_fused_collect_arrays_bitwise(params, trace):
    a = StreamingEngine(
        params, CFG,
        EngineConfig(batch_size=16, feature_backend="pallas", collect=True),
    ).simulate(trace)
    b = StreamingEngine(
        params, CFG,
        EngineConfig(batch_size=16, feature_backend="fused", collect=True),
    ).simulate(trace)
    for k in ("fetch_lat", "exec_lat", "mispred_prob", "dlevel"):
        _assert_bitwise(getattr(a, k), getattr(b, k), k)


def test_engine_fused_short_and_ragged_traces(params):
    from repro.core.simulate import simulate_trace

    for n in (1, 5, CFG.window - 1, CFG.window, CFG.window + 1, 400):
        ft = run_functional(get_benchmark("lee"), n)
        a = simulate_trace(params, ft, CFG, batch_size=13,
                           feature_backend="pallas")
        b = simulate_trace(params, ft, CFG, batch_size=13,
                           feature_backend="fused")
        assert a.cpi == b.cpi, n


def test_fused_shares_compiled_step_across_backends(params, trace):
    """feature_backend stays out of the step-cache key: the fused engine
    reuses the executable a numpy/pallas engine already compiled — the
    compile-count guard for 'fused = 1 compile per geometry, shared'."""
    # earlier tests may have compiled this exact geometry into the
    # process-wide cache — start cold so the counts are deterministic
    clear_step_cache()
    before = cache_stats()["entries"]
    e_np = StreamingEngine(
        params, CFG, EngineConfig(batch_size=11, feature_backend="numpy")
    )
    e_np.simulate(trace)
    e_fu = StreamingEngine(
        params, CFG, EngineConfig(batch_size=11, feature_backend="fused")
    )
    e_fu.simulate(trace)
    assert e_np.num_compiles == 1
    assert e_fu.num_compiles == 1          # same shared _CachedStep entry
    assert cache_stats()["entries"] == before + 1


def test_engine_rejects_unknown_precision(params):
    with pytest.raises(ValueError, match="precision"):
        StreamingEngine(params, CFG, EngineConfig(precision="fp16"))


# ---------------------------------------------------------------------------
# Layer 3: int8 quantized path
# ---------------------------------------------------------------------------


def test_qdense_matches_fp32_within_band():
    from repro.core.quant import qdense, quantize_dense
    from repro.nn.core import dense

    rng = np.random.default_rng(3)
    p = {
        "w": np.asarray(rng.standard_normal((64, 48)), np.float32),
        "b": np.asarray(rng.standard_normal(48), np.float32),
    }
    x = np.asarray(rng.standard_normal((10, 64)), np.float32)
    qp = quantize_dense(p)
    assert np.asarray(qp["w_q"]).dtype == np.int8
    y32 = np.asarray(dense(p, x))
    y8 = np.asarray(qdense(qp, x))
    # W8A8 keeps ~2 decimal digits on unit-scale data
    err = np.abs(y8 - y32).max() / (np.abs(y32).max() + 1e-9)
    assert err < 0.05, err


def test_quantize_handles_zero_channels():
    from repro.core.quant import qdense, quantize_dense

    p = {"w": np.zeros((8, 4), np.float32)}
    qp = quantize_dense(p)
    y = np.asarray(qdense(qp, np.ones((2, 8), np.float32)))
    assert np.all(y == 0.0) and np.all(np.isfinite(np.asarray(qp["scale"])))


def test_engine_int8_parity_band(params, trace):
    """int8 CPI within 5% relative of fp32; MPKIs within max(10%, 5.0) —
    the same bands ``bench_accuracy``'s fig9 gate enforces on trained
    checkpoints.  The MPKI band is the wide one by design: MPKIs count
    argmax class decisions, which quantization noise flips in whole-event
    steps near decision boundaries (and random-init params, used here,
    put every margin at a coin flip — the worst case)."""
    fp = StreamingEngine(
        params, CFG, EngineConfig(batch_size=16, feature_backend="fused")
    ).simulate(trace)
    q = StreamingEngine(
        params, CFG,
        EngineConfig(batch_size=16, feature_backend="fused", precision="int8"),
    ).simulate(trace)
    assert abs(q.cpi - fp.cpi) / fp.cpi <= 0.05, (q.cpi, fp.cpi)
    for m in ("branch_mpki", "l1d_mpki"):
        a, b = q.metrics[m], fp.metrics[m]
        assert abs(a - b) <= max(0.10 * b, 5.0), (m, a, b)


def test_int8_gets_own_step_cache_entry(params, trace):
    """precision IS part of the step key (int8 bakes a different forward);
    both int8 engines then share one entry across feature backends."""
    clear_step_cache()
    before = cache_stats()["entries"]
    r32 = StreamingEngine(
        params, CFG, EngineConfig(batch_size=9, feature_backend="fused")
    ).simulate(trace)
    q_a = StreamingEngine(
        params, CFG,
        EngineConfig(batch_size=9, feature_backend="fused", precision="int8"),
    )
    q_b = StreamingEngine(
        params, CFG,
        EngineConfig(batch_size=9, feature_backend="pallas", precision="int8"),
    )
    ra = q_a.simulate(trace)
    rb = q_b.simulate(trace)
    assert cache_stats()["entries"] == before + 2   # fp32 + int8, not 3
    assert ra.cpi == rb.cpi                         # backends still bit-equal
    assert ra.cpi != r32.cpi or ra.metrics != r32.metrics


def test_int8_quantized_params_persist_in_store(tmp_path, params, trace):
    """TrainedModel.quantized_params computes the scales once, stores them
    content-addressed, and a second model resolves the same tree."""
    from repro.api.session import TrainedModel, quantized_params_key
    from repro.store import ArtifactStore

    store = ArtifactStore(str(tmp_path))
    m = TrainedModel(params=params, cfg=CFG, name="q", store=store)
    r8 = m.simulate(trace, precision="int8", batch_size=16)
    qk = quantized_params_key(params)
    assert store.has("params_int8", qk)
    m2 = TrainedModel(params=params, cfg=CFG, name="q2", store=store)
    r8b = m2.simulate(trace, precision="int8", batch_size=16)
    assert r8.cpi == r8b.cpi


# ---------------------------------------------------------------------------
# Layer 4: warm serving on the fused backend, compile budget 0
# ---------------------------------------------------------------------------


@pytest.mark.sanitize
def test_warm_serve_fused_zero_compiles(params):
    from repro.analysis.sanitize import sanitized
    from repro.api import ModelRegistry, ServeRequest, Session, TraceServer, TrainedModel

    sess = Session(CFG)
    traces = {
        "long": sess.capture("mcf", 1200),
        "short": sess.capture("lee", 600),
    }
    reg = ModelRegistry()
    reg.register("base", TrainedModel(params=params, cfg=CFG, name="base"))

    async def run():
        server = TraceServer(reg, batch_size=8, feature_backend="fused")
        async with server:
            server.warmup([len(t) for t in traces.values()])
            with sanitized(transfer_guard=None, debug_nans=False,
                           compile_budget=0):
                futs = [
                    server.submit(ServeRequest(model="base", trace=tr))
                    for tr in traces.values()
                ]
                out = await asyncio.gather(*futs)
        return out, server

    out, server = asyncio.run(run())
    assert server.num_compiles == 0
    direct = {
        name: TrainedModel(params=params, cfg=CFG, name="d").simulate(
            tr, batch_size=8, feature_backend="fused"
        )
        for name, tr in traces.items()
    }
    for res, (name, _) in zip(out, traces.items()):
        assert res.metrics["cpi"] == direct[name].cpi, name
