"""§4.3 multi-architecture training (Algorithm 1) + baselines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import METHODS, TaoConfig, init_multiarch, make_joint_step
from repro.core.align import build_adjusted_trace
from repro.core.dataset import build_windows
from repro.core.features import FeatureConfig, extract_features
from repro.core.multiarch import _normalize_grad
from repro.train.optim import AdamWConfig, adamw_init
from repro.uarch import UARCH_A, UARCH_B, get_benchmark, run_detailed, run_functional


@pytest.fixture(scope="module")
def joint_setup():
    fcfg = FeatureConfig(n_buckets=64, n_queue=4, n_mem=8)
    cfg = TaoConfig(window=17, d_model=32, n_heads=2, n_layers=1, d_ff=64,
                    d_cat=16, features=fcfg)
    prog = get_benchmark("dee")
    ft = run_functional(prog, 3000)
    batches = {}
    for name, ua in (("A", UARCH_A), ("B", UARCH_B)):
        det, _ = run_detailed(prog, ft, ua)
        fs = extract_features(build_adjusted_trace(det).adjusted, fcfg)
        ds = build_windows(fs, cfg.window)
        b = {k: jnp.asarray(v[:8]) for k, v in ds.inputs.items()}
        b["labels"] = {k: jnp.asarray(v[:8]) for k, v in ds.labels.items()}
        batches[name] = b
    return cfg, batches


def test_normalize_grad_bounds():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(16, 16)) * 100)}
    n = _normalize_grad(g)["w"]
    # (X - mean)/(max - min): range <= 1, near-zero mean
    assert float(n.max() - n.min()) <= 1.0 + 1e-5
    assert abs(float(n.mean())) < 1e-5


@pytest.mark.parametrize("method", METHODS)
def test_joint_step_decreases_loss(joint_setup, method):
    cfg, batches = joint_setup
    params = init_multiarch(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    step = make_joint_step(cfg, AdamWConfig(lr=2e-3), method=method)
    w = jnp.ones((2,))
    il = jnp.ones((2,))
    first = None
    for i in range(12):
        params, opt, w, metrics = step(params, opt, w, il, batches["A"], batches["B"])
        if i == 0:
            first = (float(metrics["loss_a"]), float(metrics["loss_b"]))
            il = jnp.asarray(first)
    last = (float(metrics["loss_a"]), float(metrics["loss_b"]))
    assert last[0] < first[0], method
    assert last[1] < first[1], method


def test_adaptation_layer_rotates_gradients(joint_setup):
    """The W·Wᵀ back-projection must change the shared-embedding gradient
    direction relative to the no-adaptation path (the §4.3 negative-transfer
    argument)."""
    cfg, batches = joint_setup
    from repro.core.multiarch import _forward_loss

    params = init_multiarch(jax.random.PRNGKey(1), cfg)

    def g_embed(use_adapt):
        f = lambda ep: _forward_loss(ep, params["A"], batches["A"], cfg, use_adapt)[0]
        return jax.grad(f)(params["embed"])

    ga = g_embed(True)
    gb = g_embed(False)
    # cosine between the two gradient fields differs from 1 (rotation)
    va = jnp.concatenate([x.reshape(-1) for x in jax.tree.leaves(ga)])
    vb = jnp.concatenate([x.reshape(-1) for x in jax.tree.leaves(gb)])
    cos = float(jnp.vdot(va, vb) / (jnp.linalg.norm(va) * jnp.linalg.norm(vb)))
    assert cos < 0.9999


def test_gradnorm_weights_update(joint_setup):
    cfg, batches = joint_setup
    params = init_multiarch(jax.random.PRNGKey(2), cfg)
    opt = adamw_init(params)
    step = make_joint_step(cfg, AdamWConfig(lr=1e-3), method="gradnorm")
    w = jnp.ones((2,))
    il = jnp.asarray([1.0, 1.0])
    params, opt, w2, _ = step(params, opt, w, il, batches["A"], batches["B"])
    assert w2.shape == (2,)
    # renormalized to sum 2
    assert float(w2.sum()) == pytest.approx(2.0, abs=1e-4)
