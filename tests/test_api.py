"""repro.api facade + MetricSpec registry tests.

Covers the PR-3 surface: Session capture/dataset/train/train_joint/sweep,
TrainedModel simulate/transfer, the pluggable metric registry (built-in
specs bit-for-bit against the legacy carry, custom specs against NumPy
oracles), SimulationResult ergonomics, and the deprecation shims."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    DesignSpace,
    EngineConfig,
    MetricNotCollectedError,
    MetricNotComputedError,
    MetricSpec,
    Session,
    TrainedModel,
    register_metric,
)
from repro.core import FeatureConfig, TaoConfig, init_tao, tao_forward
from repro.core.dataset import INPUT_KEYS, num_windows, stream_batches
from repro.core.features import extract_features
from repro.engine import METRIC_REGISTRY, SimulationResult, StreamingEngine
from repro.engine.metrics import resolve_metrics
from repro.uarch import UARCH_A, UARCH_B, get_benchmark, run_functional
from repro.uarch.isa import DLEVEL_L2, NUM_DLEVELS

FCFG = FeatureConfig(n_buckets=32, n_queue=4, n_mem=8)
CFG = TaoConfig(
    window=17, d_model=32, n_heads=2, n_layers=1, d_ff=64, d_cat=16, features=FCFG
)


@pytest.fixture(scope="module")
def sess():
    return Session(CFG)


@pytest.fixture(scope="module")
def trace(sess):
    return sess.capture("mcf", 3000)


@pytest.fixture(scope="module")
def params():
    return init_tao(jax.random.PRNGKey(0), CFG)


@pytest.fixture(scope="module")
def model(params):
    return TrainedModel(params=params, cfg=CFG, name="m0")


# ---------------------------------------------------------------------------
# Built-in MetricSpecs vs the legacy carry (bit-for-bit)
# ---------------------------------------------------------------------------


def _legacy_carry_metrics(params, func_trace, cfg, batch_size):
    """Verbatim reimplementation of the pre-registry engine step (the
    hardcoded 4-scalar carry of PR 1/2) as a NumPy-driven jax oracle."""
    fs = extract_features(func_trace, cfg.features, with_labels=False)
    n = len(func_trace)
    w_eff = min(cfg.window, n)
    count = num_windows(n, cfg.window, cfg.window) * w_eff

    @jax.jit
    def body(params, carry, batch):
        valid = batch["valid"].reshape(-1)
        out = tao_forward(params, {k: batch[k] for k in INPUT_KEYS}, cfg)
        fetch = jnp.maximum(out["fetch_lat"], 0.0).reshape(-1)
        execl = jnp.maximum(out["exec_lat"], 0.0).reshape(-1)
        misp = jax.nn.sigmoid(out["mispred_logit"]).reshape(-1)
        dlev = jnp.argmax(out["dlevel_logits"], -1).astype(jnp.int32).reshape(-1)
        on = valid > 0
        br = batch["is_branch"].reshape(-1) & on
        mem = batch["is_mem"].reshape(-1) & on
        gidx = jnp.arange(valid.shape[0], dtype=jnp.float32)
        last_key = jnp.max(jnp.where(on, gidx, -1.0))
        part = {
            "fetch_sum": (fetch * valid).sum(dtype=jnp.float32),
            "mispred": ((misp > 0.5) & br).sum(dtype=jnp.int32),
            "l1d": ((dlev >= DLEVEL_L2) & mem).sum(dtype=jnp.int32),
        }
        exec_tail = execl[jnp.argmax(jnp.where(on, gidx, -1.0)).astype(jnp.int32)]
        new_carry = {k: carry[k] + part[k] for k in part}
        new_carry["last_exec"] = jnp.where(last_key >= 0, exec_tail, carry["last_exec"])
        return new_carry

    carry = {
        "fetch_sum": jnp.zeros((), jnp.float32),
        "mispred": jnp.zeros((), jnp.int32),
        "l1d": jnp.zeros((), jnp.int32),
        "last_exec": jnp.zeros((), jnp.float32),
    }
    for batch in stream_batches(
        fs, cfg.window, batch_size, stride=cfg.window,
        extra={"is_branch": func_trace["is_branch"], "is_mem": func_trace["is_mem"]},
    ):
        carry = body(params, carry, batch)
    carry = jax.device_get(carry)
    total = float(carry["fetch_sum"] + carry["last_exec"])
    return {
        "cpi": total / max(count, 1),
        "total_cycles": total,
        "branch_mpki": 1000.0 * float(carry["mispred"]) / max(count, 1),
        "l1d_mpki": 1000.0 * float(carry["l1d"]) / max(count, 1),
    }


@pytest.mark.parametrize("bench,n,bsz", [("mcf", 3000, 64), ("dee", 1000, 13), ("lee", 13 * 17, 13)])
@pytest.mark.parametrize("backend", ["numpy", "pallas"])
def test_builtin_specs_match_legacy_carry_bitwise(params, bench, n, bsz, backend):
    ft = run_functional(get_benchmark(bench), n)
    oracle = _legacy_carry_metrics(params, ft, CFG, bsz)
    res = StreamingEngine(
        params, CFG, EngineConfig(batch_size=bsz, feature_backend=backend)
    ).simulate(ft)
    for k, v in oracle.items():
        assert res.metrics[k] == v, (k, backend)


# ---------------------------------------------------------------------------
# Custom MetricSpecs (defined here, not in engine/) vs NumPy oracles
# ---------------------------------------------------------------------------


def test_custom_metric_spec_matches_numpy_oracle(params, trace):
    hi_lat = MetricSpec(
        name="hi_lat",
        init=lambda: jnp.zeros((), jnp.int32),
        update=lambda c, ctx: c
        + ctx.psum(((ctx.fetch_lat > 2.0) & ctx.on).sum(dtype=jnp.int32)),
        finalize=lambda c, n: {
            "hi_lat_count": float(c),
            "hi_lat_frac": float(c) / max(n, 1),
        },
    )
    mdl = TrainedModel(params=params, cfg=CFG)
    res = mdl.simulate(
        trace, collect=True, batch_size=13,
        metrics=("cpi", "branch_mpki", "l1d_mpki", hi_lat),
    )
    # NumPy oracle from the collected per-instruction predictions
    expect = int((res.fetch_lat > 2.0).sum())
    assert res.hi_lat_count == expect
    assert res.hi_lat_frac == expect / res.num_instructions
    assert res.metrics["cpi"] == res.cpi  # built-ins still present


def test_custom_vector_carry_spec_taken_branches(params, trace):
    """A spec with a pytree carry reading raw batch columns (ctx.batch)."""
    taken = MetricSpec(
        name="taken",
        init=lambda: {"n": jnp.zeros((), jnp.int32)},
        update=lambda c, ctx: {
            "n": c["n"]
            + ctx.psum(
                (ctx.batch["taken"].reshape(-1).astype(bool) & ctx.is_branch)
                .sum(dtype=jnp.int32)
            )
        },
        finalize=lambda c, n: {"taken_branches": float(c["n"])},
    )
    ft = trace.functional
    # the engine only ships is_branch/is_mem by default; pass taken through
    # the features extra path by simulating off raw trace windows
    fs = extract_features(ft, CFG.features, with_labels=False)
    n = len(ft)
    count = num_windows(n, CFG.window, CFG.window) * min(CFG.window, n)

    engine = StreamingEngine(
        params, CFG, EngineConfig(batch_size=16, metrics=("cpi", taken))
    )
    # init_carry includes the engine's reserved window-grid slot; driving
    # the step off a hand-built spec dict is no longer valid
    carry = engine.init_carry(n)
    step = engine._get_step(min(CFG.window, n))
    for batch in stream_batches(
        fs, CFG.window, 16, stride=CFG.window,
        extra={
            "is_branch": ft["is_branch"],
            "is_mem": ft["is_mem"],
            "taken": ft["taken"],
        },
    ):
        carry, _ = step(engine.params, carry, batch)
    carry = jax.device_get(carry)
    got = taken.finalize(carry["taken"], count)["taken_branches"]
    expect = float((ft["taken"][:count] & ft["is_branch"][:count]).sum())
    assert got == expect


def test_registered_dlevel_hist_matches_oracle(params, trace):
    mdl = TrainedModel(params=params, cfg=CFG)
    res = mdl.simulate(trace, collect=True, metrics=("cpi", "dlevel_hist"))
    ft = trace.functional
    mem = ft["is_mem"][: res.num_instructions]
    oracle = np.bincount(res.dlevel[mem], minlength=NUM_DLEVELS)
    names = ("dlevel_none", "dlevel_l1", "dlevel_l2", "dlevel_dram")
    for i, name in enumerate(names):
        assert res.metrics[name] == float(oracle[i])


def test_finalize_output_key_collision_rejected(params, trace):
    clashing = MetricSpec(
        name="cycles2",
        init=lambda: jnp.zeros((), jnp.float32),
        update=lambda c, ctx: c + ctx.psum((ctx.exec_lat * ctx.valid).sum()),
        finalize=lambda c, n: {"total_cycles": float(c)},  # cpi also emits it
    )
    mdl = TrainedModel(params=params, cfg=CFG)
    with pytest.raises(ValueError, match="total_cycles"):
        mdl.simulate(trace, metrics=("cpi", clashing))


def test_metric_registry_errors(params):
    with pytest.raises(KeyError):
        StreamingEngine(params, CFG, EngineConfig(metrics=("nope",)))
    with pytest.raises(ValueError):
        resolve_metrics(("cpi", "cpi"))
    with pytest.raises(ValueError):
        resolve_metrics(())
    with pytest.raises(TypeError):
        resolve_metrics((42,))
    with pytest.raises(ValueError):
        register_metric(METRIC_REGISTRY["cpi"])  # already registered
    assert set(("cpi", "branch_mpki", "l1d_mpki", "dlevel_hist")) <= set(
        METRIC_REGISTRY
    )


# ---------------------------------------------------------------------------
# SimulationResult ergonomics
# ---------------------------------------------------------------------------


def test_result_uncollected_metric_raises_clear_error(model, trace):
    res = model.simulate(trace, collect=False)
    assert set(res.available_metrics) == {
        "cpi", "total_cycles", "branch_mpki", "l1d_mpki"
    }
    with pytest.raises(MetricNotCollectedError, match="collect=True"):
        res.fetch_lat
    with pytest.raises(MetricNotCollectedError):
        res.mispred_prob
    with pytest.raises(MetricNotComputedError, match="available_metrics"):
        res.dlevel_none  # spec not requested
    with pytest.raises(AttributeError):
        res.definitely_not_a_metric


def test_result_collected_metrics_accessible(model, trace):
    res = model.simulate(trace, collect=True)
    assert "fetch_lat" in res.available_metrics
    assert res.fetch_lat.shape == (res.num_instructions,)
    assert res.dlevel.dtype == np.int32
    assert res.cpi == res.metrics["cpi"]
    assert "cpi" in repr(res) and "fetch_lat" in repr(res)


def test_result_legacy_constructor_kwargs():
    r = SimulationResult(
        num_instructions=10, seconds=1.0, mips=1e-5,
        cpi=2.0, total_cycles=20.0, branch_mpki=1.0, l1d_mpki=0.5,
        fetch_lat=np.ones(10, np.float32),
    )
    assert r.cpi == 2.0 and r.metrics["total_cycles"] == 20.0
    assert r.fetch_lat.sum() == 10.0
    assert r.error_vs(4.0) == 50.0
    with pytest.raises(MetricNotCollectedError):
        r.exec_lat


# ---------------------------------------------------------------------------
# Deprecation shims
# ---------------------------------------------------------------------------


def test_simulate_trace_shim_warns_and_matches(params, trace, model):
    from repro.core import simulate_trace

    with pytest.warns(DeprecationWarning, match="repro.api"):
        old = simulate_trace(params, trace.functional, CFG, batch_size=13)
    new = model.simulate(trace, collect=True, batch_size=13)
    assert old.num_instructions == new.num_instructions
    assert old.cpi == new.cpi
    assert old.branch_mpki == new.branch_mpki
    assert old.l1d_mpki == new.l1d_mpki
    np.testing.assert_array_equal(old.fetch_lat, new.fetch_lat)


def test_train_tao_shim_warns_and_matches(sess, trace):
    from repro.core import train_tao

    ds = sess.dataset(UARCH_A, trace).subsample(16)
    with pytest.warns(DeprecationWarning, match="Session.train"):
        old = train_tao(CFG, ds, epochs=2, batch_size=8, lr=2e-3, seed=3)
    new = sess.train(dataset=ds, epochs=2, batch_size=8, lr=2e-3, seed=3)
    assert old.losses == new.losses
    for a, b in zip(jax.tree.leaves(old.params), jax.tree.leaves(new.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_facade_emits_no_deprecation_warnings(sess, trace, model):
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        ds = sess.dataset(UARCH_A, trace).subsample(8)
        sess.train(dataset=ds, epochs=1, batch_size=8)
        model.simulate(trace)
    ours = [
        w for w in rec
        if issubclass(w.category, DeprecationWarning) and "repro" in str(w.message)
    ]
    assert not ours, [str(w.message) for w in ours]


# ---------------------------------------------------------------------------
# Session workflow
# ---------------------------------------------------------------------------


def test_capture_is_cached_and_reusable(sess):
    a = sess.capture("dee", 1200)
    b = sess.capture("dee", 1200)
    assert a is b
    assert a.num_instructions == len(a) == 1200
    assert sess.capture("dee", 800) is not a
    # a custom name never shadows (or inherits) the default-named capture
    named = sess.capture("dee", 1200, name="warmup")
    assert named.name == "warmup" and named is not a
    assert sess.capture("dee", 1200).name == "dee:1200"
    assert sess.capture("dee", 1200, name="warmup") is named


def test_capture_distinct_programs_same_name_do_not_alias(sess):
    import copy

    prog = get_benchmark("dee")
    prog2 = copy.copy(prog)  # distinct object, same .name
    a = sess.capture(prog, 600)
    b = sess.capture(prog2, 600)
    assert a is not b
    assert a.program is prog and b.program is prog2
    assert sess.capture(prog, 600) is a  # same object still caches


def test_model_sim_batch_size_follows_session(trace):
    cfg = TaoConfig(
        window=29, d_model=32, n_heads=2, n_layers=1, d_ff=64, d_cat=16,
        features=FCFG,
    )
    sess = Session(cfg, batch_size=16)
    mdl = sess.init_model()
    assert mdl.sim_batch_size == 16
    mdl.simulate(trace)  # compiles the (batch=16, w_eff) step
    # the sweep uses the same executable: zero additional compiles
    report = sess.sweep([mdl], [sess.capture("mcf", 1500)])
    assert report.num_compiles == 0


def test_train_and_transfer_freeze_embed(sess, trace):
    ds = sess.dataset(UARCH_A, trace)
    mdl = sess.train(UARCH_A, [trace], epochs=1, batch_size=8, lr=1e-3)
    assert mdl.uarch == UARCH_A and len(mdl.losses) == 1
    ft = sess.train(dataset=ds.subsample(8), epochs=1, batch_size=4, init=mdl)
    assert np.isfinite(ft.losses[-1])
    tr = mdl.transfer(ds.subsample(8), epochs=1, batch_size=4)
    for a, b in zip(
        jax.tree.leaves(mdl.params["embed"]), jax.tree.leaves(tr.params["embed"])
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    res = tr.simulate(trace)
    assert np.isfinite(res.cpi) and res.cpi > 0


def test_dataset_cache_distinguishes_same_named_traces(sess):
    a = sess.capture("dee", 900, name="x")
    b = sess.capture("lee", 900, name="x")
    ds_a = sess.dataset(UARCH_A, [a])
    ds_b = sess.dataset(UARCH_A, [b])
    assert ds_a is sess.dataset(UARCH_A, [a])  # cache hit on same object
    assert ds_a is not ds_b  # same name, different trace -> different data
    assert not np.array_equal(ds_a.inputs["opcode"], ds_b.inputs["opcode"])


def test_joint_eval_loss_mirrors_training_adapt_usage(sess, trace):
    """Only method='tao' trains the adaptation layers, so only it may eval
    through them (gradnorm & co. would otherwise score random params)."""
    from repro.core.multiarch import eval_loss as core_eval

    ds = sess.dataset(UARCH_A, trace).subsample(8)
    batches = []
    for b in ds.batches(4):
        b["labels"] = {k: jnp.asarray(v) for k, v in b.pop("labels").items()}
        batches.append(b)
        break
    for method, use_adapt in (("gradnorm", False), ("tao", True)):
        joint = sess.train_joint(
            UARCH_A, UARCH_B, datasets=(ds, ds), method=method,
            epochs=1, batch_size=4,
        )
        got = joint.eval_loss(batches, "A")
        want = core_eval(joint.params, batches, CFG, "A", use_adapt=use_adapt)
        assert got == want, method


def test_train_joint_on_epoch_hook(sess, trace):
    ds = sess.dataset(UARCH_A, trace).subsample(8)
    seen = []
    sess.train_joint(
        UARCH_A, UARCH_B, datasets=(ds, ds), epochs=2, batch_size=4,
        on_epoch=lambda ep, params, steps: seen.append((ep, steps)),
    )
    assert [e for e, _ in seen] == [0, 1]
    assert seen[-1][1] > 0


def test_train_joint_rejects_dataset_smaller_than_batch(sess, trace):
    ds = sess.dataset(UARCH_A, trace).subsample(4)
    with pytest.raises(ValueError, match="no full batch"):
        sess.train_joint(UARCH_A, UARCH_B, datasets=(ds, ds), epochs=1,
                         batch_size=64)


def test_joint_transfer_rejects_bad_donor(sess, trace):
    ds = sess.dataset(UARCH_A, trace).subsample(8)
    joint = sess.train_joint(UARCH_A, UARCH_B, datasets=(ds, ds), epochs=1,
                             batch_size=4)
    with pytest.raises(ValueError, match="donor"):
        joint.transfer(ds, donor="embed")


def test_joint_head_requires_trained_adapt(sess, trace):
    """Non-tao methods never train the adaptation layers, so head() would
    silently simulate through random weights — it must refuse."""
    ds = sess.dataset(UARCH_A, trace).subsample(8)
    joint = sess.train_joint(UARCH_A, UARCH_B, datasets=(ds, ds),
                             method="granite", epochs=1, batch_size=4)
    with pytest.raises(ValueError, match="adaptation"):
        joint.head("A")
    # transfer() is fine: it fine-tunes the adapt layers it initializes
    mdl = joint.transfer(ds, epochs=1, batch_size=4)
    assert np.isfinite(mdl.losses[-1])


def test_finalize_reserved_key_rejected(params, trace):
    shadowing = MetricSpec(
        name="walltime",
        init=lambda: jnp.zeros((), jnp.float32),
        update=lambda c, ctx: c,
        finalize=lambda c, n: {"seconds": float(c)},  # instance attr wins
    )
    mdl = TrainedModel(params=params, cfg=CFG)
    with pytest.raises(ValueError, match="reserved"):
        mdl.simulate(trace, metrics=("cpi", shadowing))


def test_ground_truth_and_dataset_share_one_detailed_run(monkeypatch, trace):
    import repro.api.session as api_session

    sess = Session(CFG)
    tr = sess.capture("dee", 800)
    calls = []
    real = api_session.run_detailed

    def counting(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(api_session, "run_detailed", counting)
    summ = sess.ground_truth(UARCH_A, tr)
    sess.dataset(UARCH_A, [tr])
    assert summ == sess.ground_truth(UARCH_A, tr)
    assert len(calls) == 1  # one detailed sim serves truth + dataset


def test_session_feature_backend_stamped_on_models(trace):
    sess = Session(CFG, feature_backend="pallas")
    mdl = sess.init_model()
    assert mdl.sim_feature_backend == "pallas"
    # both paths produce identical metrics (backends are bit-identical)
    a = mdl.simulate(trace)                            # pallas via default
    b = mdl.simulate(trace, feature_backend="numpy")   # explicit override
    assert a.cpi == b.cpi and a.l1d_mpki == b.l1d_mpki


def test_design_space_select_pair_caches_measurement(monkeypatch):
    import repro.api.session as api_session

    space = DesignSpace.sample(3, seed=5)
    calls = []
    real = api_session.measure_design_metrics

    def counting(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(api_session, "measure_design_metrics", counting)
    a = space.select_pair(["dee"], method="mahalanobis", instructions=500)
    b = space.select_pair(["dee"], method="euclidean", instructions=500)
    assert len(calls) == 1  # one detailed-sim pass serves both methods
    assert a and b


def test_train_joint_and_transfer(sess, trace):
    joint = sess.train_joint(
        UARCH_A, UARCH_B, [trace], method="tao", epochs=1, batch_size=8
    )
    assert len(joint.losses) == 1 and joint.steps > 0
    head = joint.head("A")
    assert np.isfinite(head.simulate(trace).cpi)
    small = sess.dataset(UARCH_B, trace).subsample(8)
    mdl = joint.transfer(small, epochs=1, batch_size=4)
    for a, b in zip(
        jax.tree.leaves(joint.embedding), jax.tree.leaves(mdl.params["embed"])
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError):
        joint.head("C")


def test_train_requires_data(sess):
    with pytest.raises(ValueError, match="dataset"):
        sess.train(epochs=1)


def test_design_space_helpers():
    space = DesignSpace.vary(UARCH_B, "l1d_size", [1024, 2048, 4096])
    assert len(space) == 3
    assert [d.l1d_size for d in space] == [1024, 2048, 4096]
    assert space[0].name == "l1d_size1024"
    sampled = DesignSpace.sample(5, seed=1)
    i, j = sampled.select_pair(["dee"], method="random", seed=2)
    assert i != j and 0 <= i < 5 and 0 <= j < 5
    with pytest.raises(ValueError):
        sampled.select_pair(["dee"], method="cosine")


# ---------------------------------------------------------------------------
# Async multi-trace sweep
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window,async_prepare", [(19, False), (23, True)])
def test_sweep_four_uarchs_two_traces_single_compile(trace, window, async_prepare):
    # fresh config per mode -> fresh step-cache entry, so the compile count
    # below is attributable to this sweep alone (inline and threaded modes)
    cfg = TaoConfig(
        window=window, d_model=32, n_heads=2, n_layers=1, d_ff=64, d_cat=16,
        features=FCFG,
    )
    sess = Session(cfg, batch_size=16)
    models = {f"u{i}": sess.init_model(seed=i, name=f"u{i}") for i in range(4)}
    traces = [sess.capture("mcf", 1500), sess.capture("lee", 1100)]
    report = sess.sweep(models, traces, async_prepare=async_prepare)

    assert report.prepared_async == async_prepare
    assert report.num_traces == 8 and len(report.results) == 8
    assert report.num_compiles == 1  # one executable for the whole sweep
    assert report.traces_per_s > 0 and report.mips > 0
    assert 0.0 <= report.queue_occupancy_mean <= report.queue_depth
    assert report.queue_occupancy_max <= report.queue_depth
    # results identical to the single-trace engine path
    for name, mdl in models.items():
        for tr in traces:
            swept = report.results[f"{name}/{tr.name}"]
            solo = mdl.simulate(tr, batch_size=16)
            assert swept.cpi == solo.cpi
            assert swept.branch_mpki == solo.branch_mpki
            assert swept.l1d_mpki == solo.l1d_mpki
    assert report.stats()["num_compiles"] == 1
    # a second sweep over the warm cache compiles nothing
    again = sess.sweep(models, traces, async_prepare=async_prepare)
    assert again.num_compiles == 0


def test_sweep_rejects_duplicate_model_names(sess, trace, params):
    a = TrainedModel(params=params, cfg=CFG, name="tao")
    b = TrainedModel(params=params, cfg=CFG, name="tao")
    with pytest.raises(ValueError, match="duplicate model name"):
        sess.sweep([a, b], [trace])


def test_model_num_compiles_dedupes_shared_steps(params):
    # fresh config -> fresh cache entries attributable to this model alone
    cfg = TaoConfig(
        window=23, d_model=32, n_heads=2, n_layers=1, d_ff=64, d_cat=16,
        features=FCFG,
    )
    mdl = TrainedModel(params=init_tao(jax.random.PRNGKey(0), cfg), cfg=cfg)
    ft = run_functional(get_benchmark("dee"), 500)
    mdl.simulate(ft, batch_size=16)
    mdl.simulate(ft, batch_size=16, feature_backend="pallas")
    # two engines, one shared executable (the step-cache key excludes the
    # feature backend) -> one compile, not two
    assert len(mdl._engines) == 2
    assert mdl.num_compiles == 1


def test_sweep_rejects_mismatched_config(sess, trace, params):
    other_cfg = TaoConfig(
        window=21, d_model=32, n_heads=2, n_layers=1, d_ff=64, d_cat=16,
        features=FCFG,
    )
    alien = TrainedModel(params=params, cfg=other_cfg, name="alien")
    with pytest.raises(ValueError, match="different TaoConfig"):
        sess.sweep([alien], [trace])


def test_sweep_duplicate_keys_rejected(sess, trace, model):
    from repro.engine import SweepJob, TraceSweeper

    sweeper = TraceSweeper(CFG, EngineConfig(batch_size=16))
    jobs = [
        SweepJob("same", model.params, trace.functional),
        SweepJob("same", model.params, trace.functional),
    ]
    with pytest.raises(ValueError, match="duplicate"):
        sweeper.run(jobs)
    with pytest.raises(ValueError):
        sweeper.run([])
    with pytest.raises(ValueError):
        TraceSweeper(CFG, EngineConfig(), depth=0)


@pytest.mark.parametrize("async_prepare", [False, True])
def test_sweep_consumer_error_propagates(sess, model, async_prepare):
    """A failing job must abort the sweep cleanly in both prepare modes
    (threaded mode must not leave the producer parked on a full queue)."""
    import threading

    good = sess.capture("dee", 400).functional
    bad = np.zeros(0, dtype=good.dtype)
    from repro.engine import SweepJob, TraceSweeper

    sweeper = TraceSweeper(
        CFG, EngineConfig(batch_size=16), async_prepare=async_prepare
    )
    jobs = [SweepJob("bad", model.params, bad)] + [
        SweepJob(f"g{i}", model.params, good) for i in range(4)
    ]
    before = threading.active_count()
    with pytest.raises(ValueError, match="empty trace"):
        sweeper.run(jobs)
    # the producer thread (if any) wound down instead of leaking
    for _ in range(50):
        if threading.active_count() <= before:
            break
        import time

        time.sleep(0.05)
    assert threading.active_count() <= before
