"""Roofline machinery: the loop-aware HLO analyzer is validated against
XLA's own cost_analysis on loop-free graphs, and trip-count folding is
checked scanned-vs-unrolled."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hloanalysis import analyze_hlo


def _compile(fn, *sds):
    return jax.jit(fn).lower(*sds).compile()


from repro.compat import cost_analysis as _cost_analysis


def test_dot_flops_matches_cost_analysis_loop_free():
    def f(a, b, c):
        return (a @ b) @ c

    sds = [
        jax.ShapeDtypeStruct((64, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 32), jnp.float32),
    ]
    c = _compile(f, *sds)
    ours = analyze_hlo(c.as_text())["dot_flops"]
    xla = _cost_analysis(c)["flops"]
    assert ours == pytest.approx(xla, rel=0.05), (ours, xla)


def test_scan_trip_count_folding():
    """flops(scan of N matmuls) must be ~N x flops(one matmul)."""
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    N = 12

    def one(x_, w_):
        return x_ @ w_

    def scanned(x_, w_):
        def body(c, _):
            return c @ w_, None

        c, _ = jax.lax.scan(body, x_, None, length=N)
        return c

    c1 = _compile(one, x, w)
    cN = _compile(scanned, x, w)
    f1 = analyze_hlo(c1.as_text())["dot_flops"]
    fN = analyze_hlo(cN.as_text())["dot_flops"]
    assert fN == pytest.approx(N * f1, rel=0.05), (f1, fN)
    # and confirm XLA's own analysis UNDER-counts the scan (the reason this
    # module exists) — if XLA ever fixes this, we can drop the custom parse
    xla_fN = _cost_analysis(cN)["flops"]
    assert xla_fN < fN * 0.5


def test_collectives_counted_inside_loops():
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.launch.hloanalysis import analyze_hlo
    from repro.compat import activate_mesh, make_mesh
    mesh = make_mesh((8,), ("model",))
    with activate_mesh(mesh):
        def f(w, x):
            def body(c, _):
                y = c @ w                      # contraction over sharded dim
                y = jax.lax.with_sharding_constraint(y, P(None, "model"))
                return y, None
            c, _ = jax.lax.scan(body, x, None, length=10)
            return c.sum()
        wsds = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        xsds = jax.ShapeDtypeStruct((8, 64), jnp.float32)
        c = jax.jit(f, in_shardings=(
            jax.sharding.NamedSharding(mesh, P("model", None)),
            jax.sharding.NamedSharding(mesh, P(None, "model")),
        )).lower(wsds, xsds).compile()
        h = analyze_hlo(c.as_text())
        counts = sum(v["count"] for v in h["collectives"].values())
        assert counts >= 10, h["collectives"]   # one per loop iteration
        print("COLL_OK", counts)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"  # placeholder devices; avoid TPU probing
    p = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=300, env=env)
    assert p.returncode == 0, p.stderr[-3000:]
    assert "COLL_OK" in p.stdout


def test_analytic_flops_sane_for_dense_arch():
    """Analytic counter vs 6·N·D: same order, analytic >= forward share."""
    from repro.configs import get_arch
    from repro.launch.roofline import analytic_flops

    cfg = get_arch("glm4-9b")
    meta = {"batch": 256, "seq": 4096, "kind": "train"}
    af = analytic_flops(cfg, meta)
    # ~9.4B params (w/o embeddings) * 6 * 1M tokens
    n_eff = 9.0e9
    six_nd = 6 * n_eff * 256 * 4096
    assert 0.5 * six_nd < af < 4 * six_nd, (af, six_nd)
