"""Multi-device integration tests (subprocess with 8 placeholder devices):
sharded training runs, elastic restart across mesh shapes, and one real
dry-run cell end to end."""
import os
import subprocess
import sys
import textwrap


ROOT = os.path.join(os.path.dirname(__file__), "..")
SRC = os.path.join(ROOT, "src")


def _run(script: str, timeout=560) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    # Force CPU: --xla_force_host_platform_device_count works with it, and
    # leaving JAX_PLATFORMS unset would probe for a real TPU (libtpu ships in
    # the image), which hangs on a stale /tmp/libtpu_lockfile after any
    # killed run.
    env["JAX_PLATFORMS"] = "cpu"
    p = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        timeout=timeout,
        env=env,
    )
    assert p.returncode == 0, f"stdout:\n{p.stdout}\nstderr:\n{p.stderr[-4000:]}"
    return p.stdout


def test_sharded_train_and_elastic_restart(tmp_path):
    script = textwrap.dedent(f"""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_arch
    from repro.models.backbone import Model
    from repro.train.trainer import TrainConfig, init_state, make_train_step, state_axes, batch_axes
    from repro.launch.mesh import make_mesh
    from repro.launch.dryrun import _shardings_for
    from repro.distributed.sharding import mesh_context
    from repro.ckpt import CheckpointManager
    from repro.data.pipeline import LMDataPipeline

    cfg = get_arch("qwen2-0.5b", reduced=True)
    model = Model(cfg)
    tcfg = TrainConfig(lr=1e-3, total_steps=6, warmup_steps=1)
    pipe = LMDataPipeline(cfg, batch=8, seq=32, seed=0)

    def train_on(mesh_shape, axes, state, steps, start):
        mesh = make_mesh(mesh_shape, axes)
        with mesh_context(mesh):
            s_ax = state_axes(model)
            st_sh = _shardings_for(s_ax, jax.eval_shape(lambda: state), mesh)
            step = jax.jit(make_train_step(model, tcfg),
                           in_shardings=(st_sh, None), out_shardings=(st_sh, None))
            state = jax.device_put(state, st_sh)
            m = None
            for i in range(start, start + steps):
                state, m = step(state, jax.tree.map(jnp.asarray, pipe.make_batch(i)))
            return jax.tree.map(lambda x: np.asarray(x), state), float(m["loss"])

    state = init_state(model, jax.random.PRNGKey(0), tcfg)
    state = jax.tree.map(lambda x: np.asarray(x), state)
    state, l1 = train_on((2, 2), ("data", "model"), state, 3, 0)
    mgr = CheckpointManager(r"{tmp_path}", use_async=False)
    mgr.save(state, 3)

    # elastic restart: restore the same checkpoint into a DIFFERENT mesh
    restored, extra = mgr.restore_latest(state)
    state2, l2 = train_on((4, 2), ("data", "model"), restored, 3, 3)
    assert np.isfinite(l2)
    print("LOSSES", l1, l2)
    """)
    out = _run(script)
    assert "LOSSES" in out


def test_dryrun_cell_end_to_end():
    """Smallest real cell through run_cell (512-device mesh, AOT compile)."""
    script = textwrap.dedent("""
    from repro.launch.dryrun import run_cell
    rec = run_cell("qwen2-0.5b", "decode_32k", multi_pod=False)
    assert rec["memory"]["fits_16gb"], rec["memory"]
    assert rec["roofline"]["dominant"] in ("compute_s", "memory_s", "collective_s")
    assert rec["flops_per_device"] > 0
    print("CELL_OK", rec["roofline"]["dominant"])
    """)
    out = _run(script)
    assert "CELL_OK" in out


def test_multipod_mesh_builds_and_shards():
    script = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax, jax.numpy as jnp
    from repro.launch.mesh import make_production_mesh
    from repro.distributed.sharding import mesh_context, logical_to_spec
    mesh = make_production_mesh(multi_pod=True)
    assert mesh.devices.size == 512
    assert mesh.shape == {"pod": 2, "data": 16, "model": 16}
    spec = logical_to_spec(("batch", None), shape=(256, 64), mesh=mesh)
    assert spec == jax.sharding.PartitionSpec(("pod", "data"))
    print("MESH_OK")
    """)
    out = _run(script)
    assert "MESH_OK" in out
