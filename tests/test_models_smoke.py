"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs (deliverable f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.models.backbone import Model
from repro.train.trainer import TrainConfig, init_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=32):
    if cfg.family == "audio":
        return {
            "frames": jnp.asarray(
                np.random.default_rng(0).normal(size=(B, S, cfg.frontend_dim)),
                jnp.float32,
            ),
            "labels": jnp.zeros((B, S), jnp.int32),
        }
    b = {
        "tokens": jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, (B, S)), jnp.int32
        ),
        "labels": jnp.asarray(
            np.random.default_rng(1).integers(0, cfg.vocab, (B, S)), jnp.int32
        ),
    }
    if cfg.family == "vlm":
        b["patches"] = jnp.zeros((B, cfg.vision_patches, cfg.frontend_dim))
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_loss_finite(arch):
    cfg = get_arch(arch, reduced=True)
    model = Model(cfg)
    params = model.init(KEY)
    loss, parts = jax.jit(model.loss)(params, _batch(cfg))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-1.3b", "qwen3-moe-235b-a22b",
                                  "recurrentgemma-9b", "deepseek-v2-lite-16b"])
def test_train_step_improves(arch):
    cfg = get_arch(arch, reduced=True)
    model = Model(cfg)
    tcfg = TrainConfig(lr=5e-3, total_steps=10, warmup_steps=1)
    state = init_state(model, KEY, tcfg)
    step = jax.jit(make_train_step(model, tcfg))
    batch = _batch(cfg)
    losses = []
    for _ in range(6):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
        assert np.isfinite(losses[-1]), arch
    assert losses[-1] < losses[0], (arch, losses)


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS if a != "hubert-xlarge"])
def test_decode_step_shapes(arch):
    cfg = get_arch(arch, reduced=True)
    model = Model(cfg)
    params = model.init(KEY)
    B = 2
    cache = model.init_cache(B, 64)
    step = jax.jit(model.decode_step)
    logits, cache = step(params, cache, jnp.zeros((B,), jnp.int32), jnp.int32(0))
    logits, cache = step(params, cache, jnp.ones((B,), jnp.int32), jnp.int32(1))
    assert logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), arch


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-1.3b", "recurrentgemma-9b",
                                  "deepseek-v2-lite-16b"])
def test_prefill_matches_decode(arch):
    """Prefill(prompt) then decode(t) must equal prefill(prompt + t):
    the KV-cache/state handoff is consistent."""
    cfg = get_arch(arch, reduced=True)
    model = Model(cfg)
    params = model.init(KEY)
    B, P = 1, 16
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, P + 1)), jnp.int32)

    logits_full, _ = jax.jit(model.prefill)(params, {"tokens": toks})

    logits_pre, cache = jax.jit(model.prefill)(params, {"tokens": toks[:, :P]})
    # attention caches from prefill have seq length P; pad to P+1
    def _pad(v):
        if v.ndim >= 3 and v.shape[2] == P:
            pad = [(0, 0)] * v.ndim
            pad[2] = (0, 1)
            return jnp.pad(v, pad)
        return v

    if cfg.family not in ("ssm",):
        cache = jax.tree.map(_pad, cache)
    logits_dec, _ = jax.jit(model.decode_step)(
        params, cache, toks[:, P], jnp.int32(P)
    )
    np.testing.assert_allclose(
        np.asarray(logits_dec[0]), np.asarray(logits_full[0]), atol=2e-3, rtol=2e-3
    )


def test_hubert_encode_shapes():
    cfg = get_arch("hubert-xlarge", reduced=True)
    model = Model(cfg)
    params = model.init(KEY)
    out = jax.jit(model.encode)(params, _batch(cfg))
    assert out.shape == (2, 32, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_vlm_patches_change_output():
    cfg = get_arch("qwen2-vl-2b", reduced=True)
    model = Model(cfg)
    params = model.init(KEY)
    b = _batch(cfg)
    l1, _ = jax.jit(model.loss)(params, b)
    b2 = dict(b)
    b2["patches"] = b["patches"] + 1.0
    l2, _ = jax.jit(model.loss)(params, b2)
    assert float(l1) != pytest.approx(float(l2))
