"""ExecutionPlan partitioning layer tests.

Three tiers:
  * pure plan resolution / distributed helpers (always run);
  * in-process multi-device tests, active when the process already has
    >= 8 XLA devices (the ``shard-cpu`` CI job runs the suite under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``);
  * one subprocess acceptance test that runs everywhere: single-device
    vs 8-virtual-device plans must produce identical metrics — CPI/MPKI
    and windowed phase curves — on both feature backends, with the
    one-compile-per-geometry guarantee intact, plus a data-sharded
    ``Session.sweep`` and a plan-parallel trainer run.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import FeatureConfig, TaoConfig, init_tao, num_windows
from repro.distributed import data_mesh, initialize_multihost, topology_info
from repro.engine import (
    DEFAULT_PHASE_CHUNKS,
    EngineConfig,
    ExecutionPlan,
    StreamingEngine,
    windowed_spec,
)
from repro.uarch import get_benchmark, run_functional

FCFG = FeatureConfig(n_buckets=32, n_queue=4, n_mem=8)
CFG = TaoConfig(
    window=17, d_model=32, n_heads=2, n_layers=1, d_ff=64, d_cat=16, features=FCFG
)

multidevice = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs >= 8 XLA devices (shard-cpu CI job sets "
    "XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)


@pytest.fixture(scope="module")
def trace():
    return run_functional(get_benchmark("mcf"), 3000)


@pytest.fixture(scope="module")
def params():
    return init_tao(jax.random.PRNGKey(0), CFG)


# ---------------------------------------------------------------------------
# Plan resolution (pure, single device)
# ---------------------------------------------------------------------------


def test_single_plan_properties():
    plan = ExecutionPlan.resolve(None, batch_size=16)
    assert not plan.sharded
    assert plan.kind == "single"
    assert plan.num_shards == 1
    assert plan.local_batch(16) == 16
    assert plan.batch_sharding() is None
    actx = plan.axis_context()
    x = np.float32(3.0)
    assert actx.psum(x) is x and actx.pmax(x) is x
    assert int(actx.shard_index()) == 0
    plan.validate_batch(7)  # anything divides 1 shard


def test_sharded_plan_resolution():
    mesh = jax.make_mesh((1,), ("data",))
    plan = ExecutionPlan.resolve(mesh, batch_size=16)
    assert plan.sharded and plan.batch_axes == ("data",)
    assert plan.num_shards == 1
    assert plan.batch_sharding() is not None
    assert plan.describe()["mesh_shape"] == {"data": 1}
    # resolving the same mesh again gives an EQUAL plan (step-cache key)
    assert plan == ExecutionPlan.resolve(mesh, batch_size=16)
    # a resolved plan passes through resolve()
    assert ExecutionPlan.resolve(None, batch_size=16, plan=plan) is plan


def test_plan_rejects_mesh_without_batch_axis():
    mesh = jax.make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="batch"):
        ExecutionPlan.resolve(mesh, batch_size=16)


def test_plan_rejects_conflicting_mesh_and_plan():
    mesh = jax.make_mesh((1,), ("data",))
    other = jax.make_mesh((1,), ("pod", "data")[-1:])  # distinct object, equal
    plan = ExecutionPlan.resolve(mesh, batch_size=16)
    # an equal mesh is fine; a *different* one is rejected
    assert ExecutionPlan.resolve(other, batch_size=16, plan=plan) is plan
    model_mesh = jax.make_mesh((1,), ("model",))
    with pytest.raises(ValueError, match="plan"):
        ExecutionPlan.resolve(model_mesh, batch_size=16, plan=plan)


def test_plan_constructor_invariants():
    with pytest.raises(ValueError):
        ExecutionPlan(kind="weird")
    with pytest.raises(ValueError):
        ExecutionPlan(kind="sharded")  # no mesh/axes
    with pytest.raises(ValueError):
        ExecutionPlan(kind="single", mesh=jax.make_mesh((1,), ("data",)))


def test_engine_shares_step_across_mesh_and_plan_spelling(params, trace):
    """EngineConfig(mesh=m) and EngineConfig(plan=resolve(m)) must hit the
    same step-cache entry — the plan, not the spelling, is the key."""
    mesh = jax.make_mesh((1,), ("data",))
    plan = ExecutionPlan.resolve(mesh, batch_size=19)
    e_mesh = StreamingEngine(params, CFG, EngineConfig(batch_size=19, mesh=mesh))
    e_plan = StreamingEngine(params, CFG, EngineConfig(batch_size=19, plan=plan))
    e_mesh.simulate(trace)
    e_plan.simulate(trace)
    assert e_mesh.num_compiles == 1
    assert e_plan.num_compiles == 1  # same shared entry, no second trace


# ---------------------------------------------------------------------------
# Distributed helpers
# ---------------------------------------------------------------------------


def test_initialize_multihost_single_process_fallback():
    info = initialize_multihost()
    assert not info.initialized
    assert info.process_count == 1 and info.process_index == 0
    assert not info.is_multihost
    # idempotent
    assert initialize_multihost() is info
    # ... but an explicit cluster request after the fallback must not be
    # silently swallowed by the cache
    with pytest.raises(RuntimeError, match="single-process"):
        initialize_multihost(coordinator_address="example:1234", num_processes=2)


def test_plan_auto_matches_device_count():
    plan = ExecutionPlan.auto(batch_size=jax.device_count() * 2)
    if jax.device_count() > 1:
        assert plan.sharded
        assert plan.num_shards == jax.device_count()
        assert plan == ExecutionPlan.resolve(
            data_mesh(), batch_size=jax.device_count() * 2
        )
    else:
        assert plan == ExecutionPlan.single()


def test_data_mesh_shapes():
    mesh = data_mesh(1)
    assert dict(mesh.shape) == {"data": 1}
    with pytest.raises(ValueError):
        data_mesh(0)
    with pytest.raises(ValueError):
        data_mesh(3, pods=2)  # 3 devices don't split into 2 pods


def test_topology_info_keys():
    info = topology_info()
    assert info["device_count"] >= 1
    assert set(info) >= {"backend", "process_count", "default_plan"}
    assert info["default_plan"]["kind"] in ("single", "sharded")
    assert "mesh_shape" in info["default_plan"]
    # with an explicit plan, the actual plan is recorded verbatim
    info = topology_info(plan=ExecutionPlan.single())
    assert info["plan"] == ExecutionPlan.single().describe()
    assert "default_plan" not in info


def test_virtual_cpu_devices_too_late_raises():
    from repro.distributed import virtual_cpu_devices

    saved = {k: os.environ.get(k) for k in ("XLA_FLAGS", "JAX_PLATFORMS")}
    try:
        have = jax.device_count()  # backend is initialized by now
        with pytest.raises(RuntimeError, match="XLA_FLAGS"):
            virtual_cpu_devices(have + 1)
        assert virtual_cpu_devices(have) == have  # satisfiable is fine
        with pytest.raises(ValueError):
            virtual_cpu_devices(0)
    finally:  # don't leak the flags into envs later subprocesses inherit
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


# ---------------------------------------------------------------------------
# Windowed (phase-curve) MetricSpecs
# ---------------------------------------------------------------------------


def test_windowed_metric_stays_on_device_and_matches_oracle(params, trace):
    """cpi_phase must equal the host oracle computed from the collected
    per-instruction arrays — while itself never requiring collect=True."""
    nc = DEFAULT_PHASE_CHUNKS
    e = StreamingEngine(
        params,
        CFG,
        EngineConfig(batch_size=13, collect=True, metrics=("cpi", "cpi_phase")),
    )
    res = e.simulate(trace)
    curve = res.cpi_phase
    assert curve.shape == (nc,) and curve.dtype == np.float32

    w_eff = min(CFG.window, len(trace))
    nw = num_windows(len(trace), CFG.window, CFG.window)
    count = nw * w_eff
    win = np.arange(count) // w_eff
    chunk = np.clip(win * nc // nw, 0, nc - 1)
    sums = np.bincount(chunk, weights=res.fetch_lat.astype(np.float64), minlength=nc)
    cnts = np.bincount(chunk, minlength=nc)
    oracle = sums / np.maximum(cnts, 1)
    np.testing.assert_allclose(curve, oracle, rtol=1e-5, atol=1e-5)

    # the same curve with collect=False: metrics on device all the way
    e2 = StreamingEngine(
        params, CFG, EngineConfig(batch_size=13, metrics=("cpi", "cpi_phase"))
    )
    res2 = e2.simulate(trace)
    np.testing.assert_array_equal(res2.cpi_phase, curve)
    assert "fetch_lat" not in res2.available_metrics
    # numpy and pallas backends agree bit-for-bit on the curve
    e3 = StreamingEngine(
        params,
        CFG,
        EngineConfig(
            batch_size=13, feature_backend="pallas", metrics=("cpi", "cpi_phase")
        ),
    )
    np.testing.assert_array_equal(e3.simulate(trace).cpi_phase, curve)


def test_windowed_metric_short_and_ragged_traces(params):
    for n in (9, 17, 13 * 17 + 5):
        ft = run_functional(get_benchmark("dee"), n)
        e = StreamingEngine(
            params, CFG, EngineConfig(batch_size=13, metrics=("cpi", "l1d_phase"))
        )
        r = e.simulate(ft)
        assert r.l1d_phase.shape == (DEFAULT_PHASE_CHUNKS,)
        assert np.all(np.isfinite(r.l1d_phase))


def test_windowed_spec_factory_validation():
    with pytest.raises(ValueError):
        windowed_spec("bad", lambda ctx: ctx.fetch_lat, num_chunks=0)


def test_l1d_phase_is_rate_over_memory_ops(params, trace):
    """l1d_phase's denominator population is memory ops (count=is_mem),
    not all instructions — checked against the collected arrays."""
    nc = DEFAULT_PHASE_CHUNKS
    e = StreamingEngine(
        params,
        CFG,
        EngineConfig(batch_size=13, collect=True, metrics=("cpi", "l1d_phase")),
    )
    res = e.simulate(trace)
    count = res.num_instructions
    w_eff = min(CFG.window, len(trace))
    nw = num_windows(len(trace), CFG.window, CFG.window)
    chunk = np.clip((np.arange(count) // w_eff) * nc // nw, 0, nc - 1)
    from repro.uarch.isa import DLEVEL_L2

    mem = trace["is_mem"][:count]
    miss = (res.dlevel >= DLEVEL_L2) & mem
    misses = np.bincount(chunk, weights=miss.astype(np.float64), minlength=nc)
    mems = np.bincount(chunk, weights=mem.astype(np.float64), minlength=nc)
    oracle = misses / np.maximum(mems, 1)
    np.testing.assert_allclose(res.l1d_phase, oracle, rtol=1e-6, atol=1e-7)


def test_windowed_chunk_index_envelope_enforced(params):
    """num_windows * num_chunks must fit int32 — the engine refuses the
    trace instead of letting chunk_of silently wrap."""
    huge = windowed_spec(
        "huge_phase", lambda ctx: ctx.fetch_lat, num_chunks=2**31 - 1
    )
    e = StreamingEngine(params, CFG, EngineConfig(metrics=(huge,)))
    with pytest.raises(ValueError, match="envelope"):
        e.init_carry(CFG.window * 2)  # nw=2 -> 2 * (2^31-1) overflows


def test_grid_key_is_reserved(params):
    from repro.engine.metrics import MetricSpec

    bad = MetricSpec(
        name="__grid__",
        init=lambda: 0,
        update=lambda c, ctx: c,
        finalize=lambda c, n: {},
    )
    with pytest.raises(ValueError, match="reserved"):
        StreamingEngine(params, CFG, EngineConfig(metrics=("cpi", bad)))


def test_custom_windowed_spec_num_chunks(params, trace):
    spec = windowed_spec(
        "mispred_phase", lambda ctx: ctx.mispred_prob, num_chunks=7
    )
    e = StreamingEngine(params, CFG, EngineConfig(batch_size=16, metrics=(spec,)))
    r = e.simulate(trace)
    assert r.mispred_phase.shape == (7,)
    assert np.all((r.mispred_phase >= 0) & (r.mispred_phase <= 1))


# ---------------------------------------------------------------------------
# In-process multi-device (active under the shard-cpu CI job)
# ---------------------------------------------------------------------------

METRICS = ("cpi", "branch_mpki", "l1d_mpki", "cpi_phase", "l1d_phase")


@multidevice
def test_plans_bit_identical_metrics_inprocess(params, trace):
    single = StreamingEngine(
        params, CFG, EngineConfig(batch_size=32, metrics=METRICS)
    )
    a = single.simulate(trace)
    for mesh in (data_mesh(), data_mesh(pods=2)):
        sharded = StreamingEngine(
            params, CFG, EngineConfig(batch_size=32, mesh=mesh, metrics=METRICS)
        )
        b = sharded.simulate(trace)
        assert a.cpi == b.cpi, dict(mesh.shape)
        assert a.branch_mpki == b.branch_mpki
        assert a.l1d_mpki == b.l1d_mpki
        np.testing.assert_array_equal(a.cpi_phase, b.cpi_phase)
        np.testing.assert_array_equal(a.l1d_phase, b.l1d_phase)
        assert sharded.num_compiles == 1


@multidevice
def test_sharded_sweep_inprocess(trace):
    from repro.api import Session

    sess = Session(CFG, batch_size=32, mesh=data_mesh())
    assert sess.plan is not None and sess.plan.sharded
    models = {f"m{i}": sess.init_model(seed=i, name=f"m{i}") for i in range(2)}
    traces = {
        "mcf": sess.capture("mcf", 1500),
        "dee": sess.capture("dee", 1200),
    }
    report = sess.sweep(models, traces)
    assert report.plan_kind == "sharded"
    assert report.num_shards == 8
    assert report.num_compiles <= 1  # one geometry -> at most one compile
    # every pair agrees with a direct sharded simulate
    for mn, mdl in models.items():
        for tn, tr in traces.items():
            direct = mdl.simulate(tr)
            assert report.results[f"{mn}/{tn}"].cpi == direct.cpi


# ---------------------------------------------------------------------------
# Subprocess acceptance (runs on any host)
# ---------------------------------------------------------------------------


def test_plans_acceptance_subprocess():
    """Single-device vs 8-virtual-device shard_map plan: identical CPI /
    MPKI and windowed phase curves on BOTH feature backends, one compile
    per geometry, a data-sharded Session.sweep (2 models x 2 traces, one
    compile), and a plan-parallel trainer run with its compile guard."""
    script = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from repro.api import Session
    from repro.core import TaoConfig, FeatureConfig, init_tao
    from repro.core.transfer import train_tao_impl
    from repro.core.dataset import build_windows
    from repro.core.features import extract_features
    from repro.core.align import build_adjusted_trace
    from repro.distributed import data_mesh
    from repro.engine import StreamingEngine, EngineConfig, ExecutionPlan
    from repro.train.trainer import train_step_compiles
    from repro.uarch import UARCH_A, get_benchmark, run_functional, run_detailed

    fcfg = FeatureConfig(n_buckets=64, n_queue=4, n_mem=8)
    cfg = TaoConfig(window=17, d_model=32, n_heads=2, n_layers=1, d_ff=64,
                    d_cat=16, features=fcfg)
    params = init_tao(jax.random.PRNGKey(0), cfg)
    ft = run_functional(get_benchmark("mcf"), 3000)
    METRICS = ("cpi", "branch_mpki", "l1d_mpki", "cpi_phase", "l1d_phase")

    mesh = data_mesh()
    assert dict(mesh.shape) == {"data": 8}

    # 1. bit-identical metrics across plans, both backends
    a = StreamingEngine(params, cfg, EngineConfig(
        batch_size=32, metrics=METRICS)).simulate(ft)
    for backend in ("numpy", "pallas"):
        e = StreamingEngine(params, cfg, EngineConfig(
            batch_size=32, mesh=mesh, feature_backend=backend,
            metrics=METRICS))
        b = e.simulate(ft)
        assert b.cpi == a.cpi, (backend, b.cpi, a.cpi)
        assert b.branch_mpki == a.branch_mpki
        assert b.l1d_mpki == a.l1d_mpki
        assert np.array_equal(b.cpi_phase, a.cpi_phase), backend
        assert np.array_equal(b.l1d_phase, a.l1d_phase), backend
        assert e.num_compiles == 1, (backend, e.num_compiles)

    # 2. data-sharded Session.sweep: 2 models x 2 traces, one compile.
    # batch_size=16 is a FRESH geometry (part 1 used 32), so the single
    # compile below is attributable to the sweep alone.
    sess = Session(cfg, batch_size=16, mesh=mesh)
    models = {f"m{i}": sess.init_model(seed=i, name=f"m{i}") for i in range(2)}
    traces = {"mcf": sess.capture("mcf", 1500), "dee": sess.capture("dee", 1200)}
    report = sess.sweep(models, traces, metrics=METRICS)
    assert report.plan_kind == "sharded" and report.num_shards == 8
    assert report.num_compiles == 1, report.num_compiles
    for mn, mdl in models.items():
        for tn, tr in traces.items():
            assert report.results[f"{mn}/{tn}"].cpi == mdl.simulate(
                tr, metrics=METRICS).cpi

    # windowed curves came off-device without collect=True
    r = report.results["m0/mcf"]
    assert r.cpi_phase.shape == (32,)
    assert "fetch_lat" not in r.available_metrics

    # 3. trainer under the plan: same batch stream, grads all-reduced
    prog = get_benchmark("lee")
    t = run_functional(prog, 2000)
    det, _ = run_detailed(prog, t, UARCH_A)
    ds = build_windows(
        extract_features(build_adjusted_trace(det).adjusted, fcfg), cfg.window)
    plan = ExecutionPlan.resolve(mesh, batch_size=16)
    c0 = train_step_compiles()
    ref = train_tao_impl(cfg, ds, epochs=2, batch_size=16, seed=0)
    par = train_tao_impl(cfg, ds, epochs=2, batch_size=16, seed=0, plan=plan)
    # one trace for the unsharded entry + one for the plan's entry
    assert train_step_compiles() - c0 == 2, train_step_compiles() - c0
    np.testing.assert_allclose(par.losses, ref.losses, rtol=1e-4)
    print("PLAN_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"  # virtual devices; avoid TPU probing
    p = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=560, env=env)
    assert p.returncode == 0, p.stderr[-3000:]
    assert "PLAN_OK" in p.stdout
