"""Logical-axis sharding rule engine (pure spec logic, no multi-device)."""
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import logical_to_spec


class FakeMesh:
    """Duck-typed mesh: logical_to_spec only reads .shape."""

    def __init__(self, shape):
        self.shape = shape


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_batch_spans_pod_and_data():
    spec = logical_to_spec(("batch", "seq"), shape=(256, 4096), mesh=MULTI)
    assert spec == P(("pod", "data"), "model")


def test_batch_prefix_fallback_when_pod_product_too_big():
    # batch 8 < pod*data=32: falls back to the divisible prefix ("pod",)
    spec = logical_to_spec(("batch",), shape=(8,), mesh=MULTI)
    assert spec == P("pod")


def test_divisibility_fallback_replicates():
    # 14 heads on a 16-way model axis -> replicated (even-sharding mode)
    spec = logical_to_spec((None, "heads", None), shape=(4, 14, 64), mesh=SINGLE)
    assert spec == P()


def test_uneven_allowed_for_activations():
    spec = logical_to_spec(
        (None, "heads", None), shape=(4, 14, 64), mesh=SINGLE, allow_uneven=True
    )
    assert spec == P(None, "model")


def test_uneven_rejected_when_waste_too_high():
    # 2 kv heads on 16 shards would waste 8x: stay replicated even uneven
    spec = logical_to_spec(
        (None, "kv_heads"), shape=(4, 2), mesh=SINGLE, allow_uneven=True
    )
    assert spec == P()


def test_head_dim_picks_up_model_when_heads_cannot():
    spec = logical_to_spec(
        ("fsdp", "heads", "head_dim"), shape=(5120, 40, 128), mesh=SINGLE
    )
    assert spec == P("data", None, "model")


def test_no_double_axis_use():
    # heads takes model; head_dim must not reuse it
    spec = logical_to_spec(
        ("fsdp", "heads", "head_dim"), shape=(4096, 32, 128), mesh=SINGLE
    )
    assert spec == P("data", "model")  # trailing None trimmed


def test_pod_axis_missing_on_single_pod():
    spec = logical_to_spec(("batch",), shape=(256,), mesh=SINGLE)
    assert spec == P("data")


def test_experts_on_model():
    spec = logical_to_spec(
        ("experts", "fsdp", None), shape=(128, 4096, 1536), mesh=SINGLE
    )
    assert spec == P("model", "data")


def test_vocab_sharding():
    spec = logical_to_spec(("vocab", "fsdp"), shape=(152064, 5120), mesh=SINGLE)
    assert spec == P("model", "data")


# ---------------------------------------------------------------------------
# Degradation paths (satellite: missing axes, uneven window, prefix
# fallback, used-axis exclusion) + mesh_context nesting/restore
# ---------------------------------------------------------------------------


def test_rule_axes_entirely_missing_from_mesh_replicate():
    # every axis the "batch" rule names is absent -> replicated, no error
    tiny = FakeMesh({"model": 4})
    assert logical_to_spec(("batch",), shape=(64,), mesh=tiny) == P()


def test_custom_rules_missing_axis_dropped_then_divisibility():
    rules = {"batch": ("expansion", "data")}  # "expansion" never exists
    spec = logical_to_spec(("batch",), shape=(64,), mesh=SINGLE, rules=rules)
    assert spec == P("data")
    # and with an indivisible dim the surviving axis is dropped too
    assert logical_to_spec(("batch",), shape=(7,), mesh=SINGLE, rules=rules) == P()


def test_uneven_acceptance_window_boundary():
    # waste threshold is 2*dim >= shards: dim=8 on 16 shards is EXACTLY on
    # the boundary (pads 8 -> 16, 2x) and is accepted ...
    spec = logical_to_spec(
        (None, "heads"), shape=(4, 8), mesh=SINGLE, allow_uneven=True
    )
    assert spec == P(None, "model")
    # ... dim=7 is past it (>2x waste) and replicates
    spec = logical_to_spec(
        (None, "heads"), shape=(4, 7), mesh=SINGLE, allow_uneven=True
    )
    assert spec == P()


def test_uneven_prefix_fallback_on_multipod():
    # batch=20 on pod*data=32: the divisible even PREFIX ("pod",) wins
    # before uneven padding is even considered ...
    spec = logical_to_spec(
        ("batch",), shape=(20,), mesh=MULTI, allow_uneven=True
    )
    assert spec == P("pod")
    # ... batch=21 divides no even prefix, so uneven over the full
    # product applies (pads 21 -> 32, within the 2x waste window)
    spec = logical_to_spec(
        ("batch",), shape=(21,), mesh=MULTI, allow_uneven=True
    )
    assert spec == P(("pod", "data"))


def test_used_axis_exclusion_with_uneven():
    # "seq" takes model; "heads" cannot reuse it even with uneven allowed
    spec = logical_to_spec(
        ("seq", "heads"), shape=(4096, 40), mesh=SINGLE, allow_uneven=True
    )
    assert spec == P("model")


def test_spec_for_shape_forwards_allow_uneven():
    """spec_for_shape must honor allow_uneven like shard() does, instead
    of silently running in even-only mode."""
    import jax as _jax

    from repro.distributed.sharding import spec_for_shape

    mesh = _jax.make_mesh((1,), ("model",))
    rules = {"heads": ("model",)}
    # 1-device mesh: everything divides, so exercise the code path by
    # comparing against logical_to_spec with the same flag on a fake mesh
    sh = spec_for_shape(mesh, (None, "heads"), (4, 14), rules, allow_uneven=True)
    assert sh.spec == logical_to_spec(
        (None, "heads"), shape=(4, 14), mesh=mesh, rules=rules, allow_uneven=True
    )
    # and the flag actually changes the pure-spec result on a 16-way mesh
    assert logical_to_spec(
        (None, "heads"), shape=(4, 14), mesh=SINGLE, allow_uneven=True
    ) != logical_to_spec((None, "heads"), shape=(4, 14), mesh=SINGLE)


def test_mesh_context_nesting_and_restore():
    import jax as _jax

    from repro.distributed.sharding import current_mesh, mesh_context

    outer = _jax.make_mesh((1,), ("data",))
    inner = _jax.make_mesh((1,), ("model",))
    assert current_mesh() is None
    with mesh_context(outer):
        assert current_mesh() is outer
        with mesh_context(inner):
            assert current_mesh() is inner
        assert current_mesh() is outer  # inner exit restores outer
    assert current_mesh() is None

    # exception inside the context must still restore the previous one
    with pytest.raises(RuntimeError, match="boom"):
        with mesh_context(outer):
            with mesh_context(inner):
                raise RuntimeError("boom")
    assert current_mesh() is None


def test_mesh_context_custom_rules_scope():
    import jax as _jax

    from repro.distributed.sharding import mesh_context

    mesh = _jax.make_mesh((1,), ("data",))
    rules = {"batch": ("data",), "heads": ()}
    with mesh_context(mesh, rules):
        # context rules flow into logical_to_spec when none are passed
        assert logical_to_spec(("batch",), shape=(8,)) == P("data")
        assert logical_to_spec(("heads",), shape=(8,)) == P()
    # outside, the default table is back (no mesh -> replicated)
    assert logical_to_spec(("batch",), shape=(8,)) == P()
