"""Logical-axis sharding rule engine (pure spec logic, no multi-device)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import LOGICAL_RULES, logical_to_spec


class FakeMesh:
    """Duck-typed mesh: logical_to_spec only reads .shape."""

    def __init__(self, shape):
        self.shape = shape


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_batch_spans_pod_and_data():
    spec = logical_to_spec(("batch", "seq"), shape=(256, 4096), mesh=MULTI)
    assert spec == P(("pod", "data"), "model")


def test_batch_prefix_fallback_when_pod_product_too_big():
    # batch 8 < pod*data=32: falls back to the divisible prefix ("pod",)
    spec = logical_to_spec(("batch",), shape=(8,), mesh=MULTI)
    assert spec == P("pod")


def test_divisibility_fallback_replicates():
    # 14 heads on a 16-way model axis -> replicated (even-sharding mode)
    spec = logical_to_spec((None, "heads", None), shape=(4, 14, 64), mesh=SINGLE)
    assert spec == P()


def test_uneven_allowed_for_activations():
    spec = logical_to_spec(
        (None, "heads", None), shape=(4, 14, 64), mesh=SINGLE, allow_uneven=True
    )
    assert spec == P(None, "model")


def test_uneven_rejected_when_waste_too_high():
    # 2 kv heads on 16 shards would waste 8x: stay replicated even uneven
    spec = logical_to_spec(
        (None, "kv_heads"), shape=(4, 2), mesh=SINGLE, allow_uneven=True
    )
    assert spec == P()


def test_head_dim_picks_up_model_when_heads_cannot():
    spec = logical_to_spec(
        ("fsdp", "heads", "head_dim"), shape=(5120, 40, 128), mesh=SINGLE
    )
    assert spec == P("data", None, "model")


def test_no_double_axis_use():
    # heads takes model; head_dim must not reuse it
    spec = logical_to_spec(
        ("fsdp", "heads", "head_dim"), shape=(4096, 32, 128), mesh=SINGLE
    )
    assert spec == P("data", "model")  # trailing None trimmed


def test_pod_axis_missing_on_single_pod():
    spec = logical_to_spec(("batch",), shape=(256,), mesh=SINGLE)
    assert spec == P("data")


def test_experts_on_model():
    spec = logical_to_spec(
        ("experts", "fsdp", None), shape=(128, 4096, 1536), mesh=SINGLE
    )
    assert spec == P("model", "data")


def test_vocab_sharding():
    spec = logical_to_spec(("vocab", "fsdp"), shape=(152064, 5120), mesh=SINGLE)
    assert spec == P("model", "data")
