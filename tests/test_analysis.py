"""`repro.analysis` — fixture tests for every rule code plus the
suppression grammar and the runtime sanitizer plumbing.

Each rule gets at least one positive fixture (the bug class it encodes,
reduced to a few lines) and one negative fixture (the sanctioned idiom it
must NOT flag).  Fixtures are written to tmp_path and run through the real
driver, so pragma parsing, def-table construction, suppression handling
and the finalizers are all exercised end to end.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import RULES, WIRE_SCHEMAS, run_paths

REPO = Path(__file__).resolve().parents[1]


def _run(tmp_path, files, select=None):
    for rel, text in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return run_paths([str(tmp_path)], select=select)


def _codes(res):
    return [f.code for f in res["findings"]]


def _clean(res):
    assert res["findings"] == [], [f.format() for f in res["findings"]]


# ---------------------------------------------------------------------------
# TAO001 — compat bypass
# ---------------------------------------------------------------------------


def test_tao001_direct_import_flagged(tmp_path):
    res = _run(tmp_path, {"mod.py": "import jax.sharding\n"})
    assert _codes(res) == ["TAO001"]
    assert "repro.compat" in res["findings"][0].message


def test_tao001_from_import_and_attribute_flagged(tmp_path):
    res = _run(
        tmp_path,
        {
            "mod.py": """\
            from jax.experimental import pallas
            import jax

            def f(mesh):
                return jax.sharding.NamedSharding(mesh, None)
            """
        },
    )
    assert _codes(res) == ["TAO001", "TAO001"]
    # one finding per dotted chain, not one per attribute link
    assert sum("jax.sharding.NamedSharding" in f.message for f in res["findings"]) == 1


def test_tao001_pallas_allowed_only_in_kernel_modules(tmp_path):
    src = "from jax.experimental import pallas as pl\n"
    res = _run(
        tmp_path,
        {
            "kernels/attention/kernel.py": src,  # declared lowering boundary
            "kernels/attention/ops.py": src,     # not a kernel module
        },
    )
    assert [(f.code, Path(f.path).name) for f in res["findings"]] == [
        ("TAO001", "ops.py")
    ]


def test_tao001_compat_itself_exempt(tmp_path):
    res = _run(tmp_path, {"compat.py": "import jax.experimental.pallas\n"})
    _clean(res)


# ---------------------------------------------------------------------------
# TAO002 — host sync in hot path
# ---------------------------------------------------------------------------


def test_tao002_sync_in_hot_seed_flagged(tmp_path):
    res = _run(
        tmp_path,
        {
            "mod.py": """\
            # tao: hot
            def run(xs):
                total = 0.0
                for x in xs:
                    total += float(x)
                return total
            """
        },
    )
    assert _codes(res) == ["TAO002"]
    assert "float()" in res["findings"][0].message


def test_tao002_reaches_callees_and_nested_defs(tmp_path):
    res = _run(
        tmp_path,
        {
            "mod.py": """\
            # tao: hot
            def run(xs):
                def inner(x):
                    return x.tolist()
                return [collect(inner(x)) for x in xs]

            def collect(x):
                return x.item()
            """
        },
    )
    msgs = sorted(f.message for f in res["findings"])
    assert _codes(res) == ["TAO002", "TAO002"]
    assert any("`.item()`" in m and "reachable from hot seed `run`" in m for m in msgs)
    assert any("`.tolist()`" in m and "run.inner" in m for m in msgs)


def test_tao002_explicit_device_get_sanctioned(tmp_path):
    res = _run(
        tmp_path,
        {
            "mod.py": """\
            import jax

            # tao: hot
            def run(xs):
                out = step(xs)
                return float(jax.device_get(out))

            def step(xs):
                return xs
            """
        },
    )
    _clean(res)


def test_tao002_cold_stops_propagation(tmp_path):
    res = _run(
        tmp_path,
        {
            "mod.py": """\
            # tao: hot
            def run(xs):
                return finalize(xs)

            # post-sync epilogue, runs once per trace
            # tao: cold
            def finalize(xs):
                return [x.item() for x in xs]
            """
        },
    )
    _clean(res)


# ---------------------------------------------------------------------------
# TAO003 — step-cache-key completeness
# ---------------------------------------------------------------------------

_BUILDER = """\
class Runner:
    # tao: step-builder[step] ignore=entry
    def _build(self, entry, batch):
        return self.cfg.d_model + self.backend + batch

    def _get(self, batch):
        key = (  # tao: step-key[step]
            {key}
        )
        return key
"""


def test_tao003_missing_key_member_flagged(tmp_path):
    res = _run(
        tmp_path, {"mod.py": _BUILDER.format(key='"tag", self.cfg, batch,')}
    )
    assert _codes(res) == ["TAO003"]
    assert "`self.backend`" in res["findings"][0].message


def test_tao003_prefix_key_covers_deep_read(tmp_path):
    # keying self.cfg covers self.cfg.d_model: the whole config hashes in
    res = _run(
        tmp_path,
        {"mod.py": _BUILDER.format(key='"tag", self.cfg, self.backend, batch,')},
    )
    _clean(res)


def test_tao003_unpaired_pragmas_flagged(tmp_path):
    res = _run(
        tmp_path,
        {
            "mod.py": """\
            class Runner:
                # tao: step-builder[orphan-builder]
                def _build(self):
                    return self.cfg

                def _get(self):
                    return (  # tao: step-key[orphan-key]
                        "tag", self.cfg,
                    )
            """
        },
    )
    msgs = " | ".join(f.message for f in res["findings"])
    assert _codes(res) == ["TAO003", "TAO003"]
    assert "orphan-builder" in msgs and "orphan-key" in msgs


# ---------------------------------------------------------------------------
# TAO004 — MetricSpec registry contract
# ---------------------------------------------------------------------------


def test_tao004_reserved_names_flagged(tmp_path):
    res = _run(
        tmp_path,
        {
            "mod.py": """\
            from repro.engine.metrics import MetricSpec

            GRID = MetricSpec("__grid__", None, None, lambda s: {"g": s})
            BAD = MetricSpec("x", None, None, lambda s: {"mips": s})
            """
        },
    )
    msgs = sorted(f.message for f in res["findings"])
    assert _codes(res) == ["TAO004", "TAO004"]
    assert any("__grid__" in m for m in msgs)
    assert any("reserved key(s) ['mips']" in m for m in msgs)


def test_tao004_cross_file_finalize_collision(tmp_path):
    res = _run(
        tmp_path,
        {
            "a.py": 'SPEC_A = MetricSpec("a", None, None, lambda s: {"curve": s})\n',
            "b.py": 'SPEC_B = MetricSpec("b", None, None, lambda s: {"curve": s})\n',
        },
    )
    assert _codes(res) == ["TAO004"]
    assert "finalizes key `curve` also emitted by spec `a`" in res["findings"][0].message


def test_tao004_distinct_specs_clean(tmp_path):
    res = _run(
        tmp_path,
        {
            "a.py": 'SPEC_A = MetricSpec("a", None, None, lambda s: {"a_curve": s})\n',
            "b.py": 'SPEC_B = windowed_spec("b", "cycles")\n',
        },
    )
    _clean(res)


# ---------------------------------------------------------------------------
# TAO005 — fused multiply-add under the bitwise contract
# ---------------------------------------------------------------------------


def test_tao005_mul_add_in_bitwise_fn_flagged(tmp_path):
    res = _run(
        tmp_path,
        {
            "mod.py": """\
            # tao: bitwise
            @some_decorator
            def poly(x, c):
                return x * 2.0 + c

            def unmarked(x, c):
                return x * 2.0 + c
            """
        },
    )
    # pragma attaches above the decorator; the unmarked twin stays clean
    assert _codes(res) == ["TAO005"]
    assert res["findings"][0].line == 4  # the contractable expression


def test_tao005_separated_ops_clean(tmp_path):
    res = _run(
        tmp_path,
        {
            "mod.py": """\
            # tao: bitwise
            def poly(x, c):
                p = x * 2.0
                return p + c
            """
        },
    )
    _clean(res)


# ---------------------------------------------------------------------------
# TAO006 — deprecated shims
# ---------------------------------------------------------------------------


def test_tao006_shim_call_and_import_flagged(tmp_path):
    res = _run(
        tmp_path,
        {
            "mod.py": """\
            from repro.core import simulate_trace

            def f(p, t, c):
                return simulate_trace(p, t, c)
            """
        },
    )
    assert _codes(res) == ["TAO006", "TAO006"]
    assert all("repro.api" in f.message for f in res["findings"])


def test_tao006_shim_definition_modules_exempt(tmp_path):
    res = _run(
        tmp_path,
        {"simulate.py": "def simulate_trace(p, t, c):\n    return None\n"},
    )
    _clean(res)


# ---------------------------------------------------------------------------
# TAO007 — wire-contract drift
# ---------------------------------------------------------------------------

_SERVE_ERROR = """\
import dataclasses

@dataclasses.dataclass
class ServeError:
    error: str
    message: str

    def to_dict(self):
        out = dataclasses.asdict(self)
        if self.error == "busy":
            out["retry_after_s"] = 1.0
            out["request_id"] = "r"
        return out
"""


def test_tao007_matching_schema_clean(tmp_path):
    res = _run(tmp_path, {"mod.py": _SERVE_ERROR})
    _clean(res)


def test_tao007_undeclared_key_flagged(tmp_path):
    res = _run(
        tmp_path,
        {
            "mod.py": """\
            class ServeError:
                def to_dict(self):
                    return {"error": 1, "message": 2, "stowaway": 3}
            """
        },
    )
    assert set(_codes(res)) == {"TAO007"}
    assert any(
        "emits undeclared key(s) ['stowaway']" in f.message
        for f in res["findings"]
    )


def test_tao007_missing_key_flagged(tmp_path):
    res = _run(
        tmp_path,
        {
            "mod.py": """\
            class ServeError:
                def to_dict(self):
                    return {"error": 1}
            """
        },
    )
    assert any(
        f.code == "TAO007" and "misses required key(s) ['message']" in f.message
        for f in res["findings"]
    )


def test_tao007_dynamic_keys_flagged(tmp_path):
    res = _run(
        tmp_path,
        {
            "mod.py": """\
            class ServeError:
                def to_dict(self):
                    k = "message"
                    return {"error": 1, k: 2}
            """
        },
    )
    assert _codes(res) == ["TAO007"]
    assert "cannot" in res["findings"][0].message


def test_tao007_coverage_fires_only_for_scanned_home(tmp_path):
    # a file at the schema's declared home with the class renamed away
    res = _run(
        tmp_path,
        {"serve/types.py": "class RenamedError:\n    pass\n"},
    )
    assert all(c == "TAO007" for c in _codes(res)) and _codes(res)
    assert any("`ServeError`" in f.message for f in res["findings"])
    # ...but a partial scan elsewhere is not drift
    res = _run(tmp_path / "other", {"mod.py": "x = 1\n"})
    _clean(res)


def test_wire_schema_matches_runtime_dataclass():
    """The declared ServerStats schema tracks the real dataclass — the
    asdict() path TAO007 expands statically."""
    import dataclasses

    from repro.serve.types import ServerStats

    names = {f.name for f in dataclasses.fields(ServerStats)}
    assert names == WIRE_SCHEMAS["ServerStats"].required


# ---------------------------------------------------------------------------
# TAO000 — pragma hygiene + the suppression grammar
# ---------------------------------------------------------------------------


def test_reasoned_suppression_suppresses_and_is_recorded(tmp_path):
    res = _run(
        tmp_path,
        {
            "mod.py": (
                "import jax.sharding"
                "  # tao: noqa[TAO001] fixture: reasoned suppressions work\n"
            )
        },
    )
    _clean(res)
    assert len(res["suppressed"]) == 1
    finding, reason = res["suppressed"][0]
    assert finding.code == "TAO001" and "reasoned" in reason
    assert res["unused_suppressions"] == []


def test_reasonless_suppression_does_not_suppress(tmp_path):
    res = _run(
        tmp_path,
        {"mod.py": "import jax.sharding  # tao: noqa[TAO001]\n"},
    )
    # the TAO001 still fires AND the bad pragma is a TAO000
    assert sorted(_codes(res)) == ["TAO000", "TAO001"]
    assert any("no reason" in f.message for f in res["findings"])


def test_bare_and_unknown_code_noqa_flagged(tmp_path):
    res = _run(
        tmp_path,
        {
            "mod.py": """\
            x = 1  # tao: noqa
            y = 2  # tao: noqa[TAO999] no such rule
            """
        },
    )
    msgs = " | ".join(f.message for f in res["findings"])
    assert "bare `tao: noqa`" in msgs
    assert "unknown rule code(s) ['TAO999']" in msgs


def test_unused_suppression_reported(tmp_path):
    res = _run(
        tmp_path,
        {"mod.py": "x = 1  # tao: noqa[TAO002] nothing fires here\n"},
    )
    _clean(res)
    assert len(res["unused_suppressions"]) == 1
    assert "delete it" in res["unused_suppressions"][0].message


def test_malformed_pragma_flagged(tmp_path):
    # trailing prose after hot/cold/bitwise is NOT part of the grammar —
    # explanations belong on their own comment line above
    res = _run(
        tmp_path,
        {
            "mod.py": """\
            # tao: hot because the loop is hot
            def run(xs):
                return xs
            """
        },
    )
    assert _codes(res) == ["TAO000"]
    assert "unrecognized tao pragma" in res["findings"][0].message


def test_select_filters_rules_but_keeps_hygiene(tmp_path):
    res = _run(
        tmp_path,
        {
            "mod.py": """\
            import jax.sharding
            from repro.core import simulate_trace
            """
        },
        select=["TAO006"],
    )
    assert _codes(res) == ["TAO006"]


def test_rule_registry_is_complete():
    assert {f"TAO00{i}" for i in range(8)} <= set(RULES)


# ---------------------------------------------------------------------------
# the CLI (what CI runs) and the repo's own tree
# ---------------------------------------------------------------------------


def _cli(*args, cwd=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        capture_output=True,
        text=True,
        env=env,
        cwd=cwd or REPO,
    )


def test_cli_exit_codes(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("import jax.sharding\n")
    good = tmp_path / "good.py"
    good.write_text("x = 1\n")

    r = _cli(str(bad))
    assert r.returncode == 1 and "TAO001" in r.stdout

    r = _cli(str(good))
    assert r.returncode == 0 and "clean" in r.stdout

    r = _cli("--list-rules")
    assert r.returncode == 0 and "TAO003" in r.stdout


def test_repo_tree_is_clean_under_strict():
    """The gate CI applies: src + benchmarks, strict, zero findings."""
    res = run_paths([str(REPO / "src"), str(REPO / "benchmarks")])
    _clean(res)
    assert res["unused_suppressions"] == []
    # every suppression in the tree carries a reason (the driver enforces
    # it, but assert the shipped state explicitly)
    assert all(reason for _, reason in res["suppressed"])


# ---------------------------------------------------------------------------
# runtime sanitizer
# ---------------------------------------------------------------------------


def test_compile_budget_exceeded_raises(monkeypatch):
    from repro.analysis import sanitize as S

    counts = iter([10, 13])  # 3 compiles inside the block, budget 2
    monkeypatch.setattr(S, "compiles_now", lambda: next(counts))
    with pytest.raises(S.CompileBudgetExceeded, match="budget was 2"):
        with S.sanitized(transfer_guard=None, debug_nans=False, compile_budget=2):
            pass


def test_compile_budget_within_budget_passes(monkeypatch):
    from repro.analysis import sanitize as S

    counts = iter([10, 12])
    monkeypatch.setattr(S, "compiles_now", lambda: next(counts))
    with S.sanitized(transfer_guard=None, debug_nans=False, compile_budget=2):
        pass


def test_compile_budget_is_assertion_error():
    from repro.analysis.sanitize import CompileBudgetExceeded

    assert issubclass(CompileBudgetExceeded, AssertionError)


def test_debug_nans_catches_nan_inside_sanitized():
    import jax.numpy as jnp

    from repro.analysis.sanitize import sanitized

    with pytest.raises(FloatingPointError):
        with sanitized(transfer_guard=None):
            jnp.log(jnp.array(-1.0)).block_until_ready()
