import os
import sys

# Tests run single-device (the dry-run subprocesses set their own flags).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

from repro.uarch import UARCH_A, UARCH_B, get_benchmark, run_detailed, run_functional

TRACE_LEN = 6000


@pytest.fixture(scope="session")
def dee_traces():
    prog = get_benchmark("dee")
    ft = run_functional(prog, TRACE_LEN)
    det, summ = run_detailed(prog, ft, UARCH_A)
    return prog, ft, det, summ


@pytest.fixture(scope="session")
def small_tao_setup():
    """Tiny Tao config + dataset used across model tests."""
    from repro.core import FeatureConfig, TaoConfig, build_windows, extract_features
    from repro.core.align import build_adjusted_trace

    prog = get_benchmark("lee")
    ft = run_functional(prog, 4000)
    det, _ = run_detailed(prog, ft, UARCH_A)
    al = build_adjusted_trace(det)
    fcfg = FeatureConfig(n_buckets=64, n_queue=4, n_mem=8)
    fs = extract_features(al.adjusted, fcfg)
    cfg = TaoConfig(
        window=17, d_model=32, n_heads=2, n_layers=1, d_ff=64, d_cat=16, features=fcfg
    )
    ds = build_windows(fs, cfg.window)
    return cfg, ds, al, ft
