import os
import sys

# Tests run single-device (the dry-run subprocesses set their own flags).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest

from repro.uarch import UARCH_A, get_benchmark, run_detailed, run_functional

TRACE_LEN = 6000


@pytest.fixture(autouse=True)
def _sanitize_marker(request):
    """Tests marked ``@pytest.mark.sanitize`` run with the repo's runtime
    invariants hard-enforced: implicit device->host transfers raise
    (explicit jax.device_get stays allowed) and NaNs fail at the producing
    primitive.  Marker kwargs pass through to ``sanitized`` — e.g.
    ``@pytest.mark.sanitize(compile_budget=0)`` for warm-cache tests."""
    marker = request.node.get_closest_marker("sanitize")
    if marker is None:
        yield
        return
    from repro.analysis.sanitize import sanitized

    with sanitized(**marker.kwargs):
        yield


@pytest.fixture(scope="session")
def dee_traces():
    prog = get_benchmark("dee")
    ft = run_functional(prog, TRACE_LEN)
    det, summ = run_detailed(prog, ft, UARCH_A)
    return prog, ft, det, summ


@pytest.fixture(scope="session")
def small_tao_setup():
    """Tiny Tao config + dataset used across model tests."""
    from repro.core import FeatureConfig, TaoConfig, build_windows, extract_features
    from repro.core.align import build_adjusted_trace

    prog = get_benchmark("lee")
    ft = run_functional(prog, 4000)
    det, _ = run_detailed(prog, ft, UARCH_A)
    al = build_adjusted_trace(det)
    fcfg = FeatureConfig(n_buckets=64, n_queue=4, n_mem=8)
    fs = extract_features(al.adjusted, fcfg)
    cfg = TaoConfig(
        window=17, d_model=32, n_heads=2, n_layers=1, d_ff=64, d_cat=16, features=fcfg
    )
    ds = build_windows(fs, cfg.window)
    return cfg, ds, al, ft
