"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU):
shape/dtype sweeps + hypothesis property checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # property-based when available; example-based fallback otherwise
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.kernels.attention.ops import flash_attention
from repro.kernels.attention.ref import attention_ref
from repro.kernels.ssd.ops import ssd_scan
from repro.kernels.ssd.ref import ssd_sequential_ref
from repro.models.attention import flash_ref
from repro.models.mamba2 import ssd_chunked_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,Sq,Sk,D,causal",
    [
        (1, 1, 64, 64, 32, True),
        (2, 4, 128, 128, 64, True),
        (1, 2, 96, 160, 32, False),   # non-square, padded blocks
        (2, 2, 256, 256, 128, True),
    ],
)
def test_flash_kernel_vs_ref(B, H, Sq, Sk, D, causal, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, D), dtype)
    k = jax.random.normal(ks[1], (B, H, Sk, D), dtype)
    v = jax.random.normal(ks[2], (B, H, Sk, D), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


def test_flash_jnp_ref_matches_naive():
    """The model's chunked flash_ref (used in every zoo arch) against the
    naive oracle, including the local-window mask."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, H, S, D = 2, 2, 96, 32
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    out = flash_ref(q, k, v, causal=True, block_q=32, block_k=32)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)

    # windowed: compare against explicit masked softmax
    win = 16
    outw = flash_ref(q, k, v, causal=True, window=win, block_q=32, block_k=32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(D)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = (qpos >= kpos) & (qpos - kpos < win)
    s = jnp.where(mask[None, None], s, -jnp.inf)
    refw = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(outw), np.asarray(refw), atol=2e-5, rtol=2e-5)


def test_flash_q_offset_decode_semantics():
    """q_offset: a 1-token query at position P equals full-prefix attention."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, H, S, D = 1, 2, 64, 32
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    full = attention_ref(q, k, v, causal=True)
    last = flash_attention(q[:, :, -1:], k, v, causal=True, q_offset=S - 1,
                           block_q=32, block_k=32)
    np.testing.assert_allclose(
        np.asarray(last[:, :, 0]), np.asarray(full[:, :, -1]), atol=2e-5, rtol=2e-5
    )


def _check_flash_kernel(B, H, S, D):
    ks = jax.random.split(jax.random.PRNGKey(B * 100 + H * 10 + S + D), 3)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)


if HAVE_HYPOTHESIS:
    test_flash_kernel_property = settings(max_examples=8, deadline=None)(
        given(
            B=st.integers(1, 2),
            H=st.integers(1, 3),
            S=st.sampled_from([32, 48, 80]),
            D=st.sampled_from([16, 32]),
        )(_check_flash_kernel)
    )
else:
    test_flash_kernel_property = pytest.mark.parametrize(
        "B,H,S,D",
        [(1, 1, 32, 16), (2, 3, 48, 32), (1, 2, 80, 16), (2, 1, 80, 32)],
    )(_check_flash_kernel)


@pytest.mark.parametrize("causal", [True, False])
def test_flash_segment_ids_block_attention(causal):
    """Packed windows: positions attend only within their own segment, at
    and across block boundaries (segments deliberately not tile-aligned)."""
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    B, H, S, D = 2, 2, 96, 32
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    seg = jnp.asarray(
        np.repeat(np.arange(8), 12)[None].repeat(B, 0), jnp.int32
    )
    out = flash_attention(q, k, v, seg, causal=causal, block_q=32, block_k=32)
    ref = attention_ref(q, k, v, seg, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    # segment isolation is literal: each segment equals attention run on
    # that segment alone
    for s0 in (0, 12, 84):
        solo = attention_ref(
            q[:, :, s0:s0 + 12], k[:, :, s0:s0 + 12], v[:, :, s0:s0 + 12],
            causal=causal,
        )
        np.testing.assert_allclose(
            np.asarray(out[:, :, s0:s0 + 12]), np.asarray(solo),
            atol=2e-5, rtol=2e-5,
        )


def test_flash_segment_ids_with_q_offset():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    B, H, S, D = 1, 2, 64, 32
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    seg = jnp.asarray(np.repeat([0, 1], 32)[None], jnp.int32)
    last8 = flash_attention(q[:, :, -8:], k, v, seg, causal=True,
                            q_offset=S - 8, block_q=8, block_k=32)
    ref = attention_ref(q[:, :, -8:], k, v, seg, causal=True, q_offset=S - 8)
    np.testing.assert_allclose(np.asarray(last8), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_segment_ids_shape_validated():
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(ks[0], (1, 1, 32, 16))
    bad = jnp.zeros((1, 16), jnp.int32)
    with pytest.raises(ValueError, match="segment_ids"):
        flash_attention(q, q, q, bad, block_q=16, block_k=16)


def test_flash_causal_clamp_skips_dead_k_blocks():
    """The static diagonal clamp: k-blocks past the last query position
    are never part of the grid.  Observable two ways: NaNs planted in the
    dead key region cannot poison the output (those tiles are never
    computed), and the result matches the oracle."""
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    B, H, S, D = 1, 2, 128, 32
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    # queries cover positions [0, 16): keys from 32 on are causally dead
    k_poison = k.at[:, :, 32:].set(jnp.nan)
    v_poison = v.at[:, :, 32:].set(jnp.nan)
    out = flash_attention(q[:, :, :16], k_poison, v_poison, causal=True,
                          block_q=16, block_k=16)
    assert np.isfinite(np.asarray(out)).all()
    ref = attention_ref(q[:, :, :16], k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    # mid-window offsets clamp to ceil((q_offset+Sq)/bk) blocks
    mid = flash_attention(q[:, :, 48:64], k, v, causal=True, q_offset=48,
                          block_q=16, block_k=16)
    refm = attention_ref(q[:, :, 48:64], k, v, causal=True, q_offset=48)
    np.testing.assert_allclose(np.asarray(mid), np.asarray(refm),
                               atol=2e-5, rtol=2e-5)


def test_flash_default_block_sizes():
    from repro.kernels.attention.ops import default_block_size

    assert default_block_size(512) == 128
    assert default_block_size(2048) == 256
    # defaults apply when block_q/block_k are omitted and stay correct
    ks = jax.random.split(jax.random.PRNGKey(10), 3)
    q = jax.random.normal(ks[0], (1, 1, 200, 32))
    out = flash_attention(q, q, q, causal=True)   # S=200 -> 128 tiles
    ref = attention_ref(q, q, q, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "B,S,H,P,G,N,c",
    [
        (2, 128, 4, 16, 1, 8, 32),
        (1, 64, 2, 8, 2, 16, 16),
        (1, 256, 8, 32, 1, 16, 64),
    ],
)
def test_ssd_kernel_vs_oracles(B, S, H, P, G, N, c):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    seq = ssd_sequential_ref(xh, dt, A, Bm, Cm)
    chk = ssd_chunked_ref(xh, dt, A, Bm, Cm, chunk=c)
    ker = ssd_scan(xh, dt, A, Bm, Cm, chunk=c)
    np.testing.assert_allclose(np.asarray(chk), np.asarray(seq), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(seq), atol=1e-4, rtol=1e-4)


def test_ssd_final_state_matches_sequential():
    """Chunked scan's returned final state equals the literal recurrence's."""
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    B, S, H, P, G, N, c = 1, 96, 2, 8, 1, 8, 32
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    _, state = ssd_chunked_ref(xh, dt, A, Bm, Cm, chunk=c, return_state=True)

    # sequential state
    Bh = jnp.repeat(Bm, H // G, axis=2)
    st = jnp.zeros((B, H, N, P))
    for t in range(S):
        decay = jnp.exp(dt[:, t] * A[None, :])
        st = st * decay[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhnp", dt[:, t], Bh[:, t], xh[:, t]
        )
    np.testing.assert_allclose(np.asarray(state), np.asarray(st), atol=1e-4, rtol=1e-4)


def test_ssd_dtype_bf16():
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    B, S, H, P, G, N, c = 1, 64, 2, 8, 1, 8, 32
    xh = jax.random.normal(ks[0], (B, S, H, P), jnp.bfloat16)
    dt = (jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))) * 0.1).astype(jnp.bfloat16)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = (jax.random.normal(ks[3], (B, S, G, N)) * 0.5).astype(jnp.bfloat16)
    Cm = (jax.random.normal(ks[4], (B, S, G, N)) * 0.5).astype(jnp.bfloat16)
    ker = ssd_scan(xh, dt, A, Bm, Cm, chunk=c)
    seq = ssd_sequential_ref(xh, dt, A, Bm, Cm)
    np.testing.assert_allclose(
        np.asarray(ker, np.float32), np.asarray(seq, np.float32), atol=5e-2, rtol=5e-2
    )
