"""End-to-end system test: the paper's full workflow on a reduced scale.

trace generation (2 µarchs) -> §4.1 dataset construction -> §4.3 joint
training of shared embeddings -> transfer to an unseen µarch (frozen
embeddings + fine-tune) -> §4.2 multi-metric simulation of an unseen
benchmark -> sanity-check the predicted metrics against the detailed
simulator's ground truth.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    FeatureConfig,
    TaoConfig,
    build_windows,
    extract_features,
    init_multiarch,
    make_joint_step,
    simulate_trace,
    transfer_finetune,
)
from repro.core.align import build_adjusted_trace, verify_alignment
from repro.train.optim import AdamWConfig, adamw_init
from repro.uarch import (
    UARCH_A,
    UARCH_B,
    UARCH_C,
    get_benchmark,
    run_detailed,
    run_functional,
)

N_INSTR = 8000


def test_pipeline_smoke():
    """Fast tier-1 stand-in for the full pipeline: trace -> adjusted dataset
    -> (untrained) model -> engine simulation produces finite metrics."""
    from repro.core import init_tao, simulate_trace

    fcfg = FeatureConfig(n_buckets=64, n_queue=4, n_mem=8)
    cfg = TaoConfig(
        window=17, d_model=32, n_heads=2, n_layers=1, d_ff=64, d_cat=16,
        features=fcfg,
    )
    prog = get_benchmark("dee")
    ft = run_functional(prog, 2000)
    det, _ = run_detailed(prog, ft, UARCH_A)
    al = build_adjusted_trace(det)
    assert verify_alignment(al, ft)["cycles_match"]
    ds = build_windows(extract_features(al.adjusted, fcfg), cfg.window)
    assert len(ds) > 0

    params = init_tao(jax.random.PRNGKey(0), cfg)
    sim = simulate_trace(params, ft, cfg, collect=False)
    assert np.isfinite(sim.cpi) and sim.cpi > 0
    assert np.isfinite(sim.branch_mpki) and np.isfinite(sim.l1d_mpki)
    assert sim.num_instructions == (2000 // cfg.window) * cfg.window


@pytest.mark.slow
def test_full_paper_pipeline():
    fcfg = FeatureConfig(n_buckets=128, n_queue=8, n_mem=16)
    cfg = TaoConfig(
        window=33, d_model=48, n_heads=4, n_layers=2, d_ff=96, d_cat=24,
        features=fcfg,
    )

    # --- trace generation + dataset construction (train benchmarks) -----
    def dataset_for(uarch, benches, n=N_INSTR):
        parts = []
        from repro.core.dataset import concat_datasets

        for b in benches:
            prog = get_benchmark(b)
            ft = run_functional(prog, n)
            det, _ = run_detailed(prog, ft, uarch)
            al = build_adjusted_trace(det)
            v = verify_alignment(al, ft)
            assert v["stream_match"] and v["cycles_match"]
            parts.append(build_windows(extract_features(al.adjusted, fcfg), cfg.window))
        return concat_datasets(parts)

    ds_a = dataset_for(UARCH_A, ["dee", "lee"])
    ds_b = dataset_for(UARCH_B, ["dee", "lee"])

    # --- joint shared-embedding training (Algorithm 1) -------------------
    params = init_multiarch(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    step = make_joint_step(cfg, AdamWConfig(lr=1.5e-3), method="tao")
    w = jnp.ones((2,))
    il = jnp.ones((2,))
    rng = np.random.default_rng(0)
    first = last = None
    for _epoch in range(6):
        for ba, bb in zip(ds_a.batches(8, rng=rng), ds_b.batches(8, rng=rng)):
            ba["labels"] = {k: jnp.asarray(v) for k, v in ba.pop("labels").items()}
            bb["labels"] = {k: jnp.asarray(v) for k, v in bb.pop("labels").items()}
            params, opt, w, m = step(params, opt, w, il, ba, bb)
            if first is None:
                first = float(m["loss_a"] + m["loss_b"])
            last = float(m["loss_a"] + m["loss_b"])
    assert last < first, (first, last)

    # --- transfer to unseen µArch C (frozen embeddings) ------------------
    ds_c = dataset_for(UARCH_C, ["dee"], n=4000)
    res = transfer_finetune(
        cfg, params["embed"], params["A"], ds_c, epochs=4, batch_size=8, lr=1.5e-3
    )
    # frozen:
    for a, b in zip(jax.tree.leaves(params["embed"]), jax.tree.leaves(res.params["embed"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))

    # --- simulate an unseen benchmark on µArch C --------------------------
    prog = get_benchmark("mcf")
    ft = run_functional(prog, 4000)
    det, truth = run_detailed(prog, ft, UARCH_C)
    sim = simulate_trace(res.params, ft, cfg)
    assert np.isfinite(sim.cpi) and sim.cpi > 0
    # reduced-scale model: just require the right order of magnitude
    assert sim.error_vs(truth["cpi"]) < 100.0, (sim.cpi, truth["cpi"])
