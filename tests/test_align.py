"""§4.1 dataset-construction invariants (the paper's Figure 2 property:
squashed/nop removal preserves total cycles exactly)."""
import numpy as np
import pytest

try:  # property-based when available; example-based fallback otherwise
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

from repro.core.align import build_adjusted_trace, verify_alignment
from repro.uarch import (
    UARCH_A,
    UARCH_B,
    UARCH_C,
    get_benchmark,
    run_detailed,
    run_functional,
    sample_design_space,
)
from repro.uarch.isa import KIND_NOP, KIND_REAL, KIND_SQUASHED


@pytest.mark.parametrize("uarch", [UARCH_A, UARCH_B, UARCH_C], ids=lambda c: c.name)
@pytest.mark.parametrize("bench", ["dee", "mcf", "cac"])
def test_alignment_invariants(bench, uarch):
    prog = get_benchmark(bench)
    ft = run_functional(prog, 4000)
    det, _ = run_detailed(prog, ft, uarch)
    al = build_adjusted_trace(det)
    v = verify_alignment(al, ft)
    assert v["stream_match"], (bench, uarch.name)
    assert v["cycles_match"], (bench, uarch.name, v)
    assert len(al.adjusted) == 4000


def test_adjusted_fetch_absorbs_overhead(dee_traces):
    """Instructions following a squashed/nop run must absorb its latency."""
    _, ft, det, _ = dee_traces
    al = build_adjusted_trace(det)
    kinds = det["kind"]
    # find a committed instruction directly preceded by extra records
    extra_mask = kinds != KIND_REAL
    real_idx = np.nonzero(~extra_mask)[0]
    found = 0
    for j in range(1, len(real_idx)):
        lo, hi = real_idx[j - 1], real_idx[j]
        n_extra = hi - lo - 1
        if n_extra > 0:
            # adjusted fetch_lat spans all removed records
            base = det["fetch_clock"][hi] - det["fetch_clock"][lo]
            assert al.adjusted["fetch_lat"][j] == base
            found += 1
        if found > 10:
            break
    assert found > 0, "trace had no squashed/nop runs to verify"


def test_squashed_fraction_plausible(dee_traces):
    """Paper Fig 10(a): extra records are dominated by squashed instructions
    on branchy code."""
    _, _, det, _ = dee_traces
    n_sq = int((det["kind"] == KIND_SQUASHED).sum())
    n_nop = int((det["kind"] == KIND_NOP).sum())
    assert n_sq > 0
    assert n_sq > n_nop  # branchy benchmark: speculation dominates stalls


def _check_alignment_at_design_point(seed):
    cfg = sample_design_space(1, seed=seed)[0]
    prog = get_benchmark("xal")
    ft = run_functional(prog, 1500)
    det, _ = run_detailed(prog, ft, cfg)
    al = build_adjusted_trace(det)
    v = verify_alignment(al, ft)
    assert v["stream_match"] and v["cycles_match"], (cfg, v)


if HAVE_HYPOTHESIS:
    test_alignment_holds_across_design_space = settings(
        max_examples=8, deadline=None
    )(given(st.integers(0, 10_000))(_check_alignment_at_design_point))
else:
    test_alignment_holds_across_design_space = pytest.mark.parametrize(
        "seed", [0, 17, 1234, 4242, 9999]
    )(_check_alignment_at_design_point)
