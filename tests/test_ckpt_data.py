"""Checkpointing (fault tolerance) + data pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, latest_step, restore_pytree, save_pytree
from repro.configs import get_arch
from repro.data.pipeline import LMDataPipeline


def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2,), jnp.bfloat16), "d": jnp.int32(7)},
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    d = str(tmp_path / "step_5")
    save_pytree(t, d, extra={"step": 5})
    r = restore_pytree(t, d)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_atomic_commit_no_tmp_left(tmp_path):
    d = str(tmp_path / "step_1")
    save_pytree(_tree(), d)
    assert os.path.isdir(d)
    assert not os.path.exists(d + ".tmp")


def test_latest_step_ignores_partial(tmp_path):
    root = str(tmp_path)
    save_pytree(_tree(), os.path.join(root, "step_10"))
    save_pytree(_tree(), os.path.join(root, "step_20"))
    # simulate a crash mid-write: un-committed tmp dir + manifest-less dir
    os.makedirs(os.path.join(root, "step_30.tmp"))
    os.makedirs(os.path.join(root, "step_40"))  # no manifest inside
    assert latest_step(root) == 20


def test_manager_auto_resume_and_gc(tmp_path):
    root = str(tmp_path)
    mgr = CheckpointManager(root, keep=2, use_async=False)
    t = _tree()
    for s in (1, 2, 3, 4):
        t = jax.tree.map(lambda x: x + 1 if x.dtype != jnp.int32 else x, t)
        mgr.save(t, s, extra={"note": s})
    restored, extra = mgr.restore_latest(t)
    assert extra["step"] == 4
    assert extra["note"] == 4
    # retention: only last 2 kept
    steps = sorted(n for n in os.listdir(root) if n.startswith("step_"))
    assert steps == ["step_3", "step_4"]
    mgr.close()


def test_manager_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), use_async=True)
    mgr.save(_tree(), 1)
    mgr.wait()
    assert latest_step(str(tmp_path)) == 1
    mgr.close()


def test_trainstate_resume_continues_identically(tmp_path):
    """Train 4 steps; vs train 2, checkpoint, restore, train 2 more -> same
    final loss (crash/restart transparency, incl. data-iterator state)."""
    from repro.models.backbone import Model
    from repro.train.trainer import TrainConfig, init_state, make_train_step

    cfg = get_arch("qwen2-0.5b", reduced=True)
    model = Model(cfg)
    tcfg = TrainConfig(lr=1e-3, total_steps=10, warmup_steps=1)
    step = jax.jit(make_train_step(model, tcfg))
    pipe = LMDataPipeline(cfg, batch=4, seq=32, seed=1)

    def run(state, start, n, pipe):
        m = None
        for i in range(start, start + n):
            state, m = step(state, jax.tree.map(jnp.asarray, pipe.make_batch(i)))
        return state, m

    s0 = init_state(model, jax.random.PRNGKey(0), tcfg)
    ref_state, ref_m = run(s0, 0, 4, pipe)

    s1 = init_state(model, jax.random.PRNGKey(0), tcfg)
    s1, _ = run(s1, 0, 2, pipe)
    mgr = CheckpointManager(str(tmp_path), use_async=False)
    mgr.save(s1, 2, extra={"data": {"next_index": 2, "seed": 1}})
    restored, extra = mgr.restore_latest(s1)
    pipe2 = LMDataPipeline(cfg, batch=4, seq=32)
    pipe2.load_state_dict(extra["data"])
    s2, m2 = run(restored, 2, 2, pipe2)
    assert float(m2["loss"]) == pytest.approx(float(ref_m["loss"]), rel=1e-5)


def test_lm_pipeline_deterministic_and_sharded():
    cfg = get_arch("qwen2-0.5b", reduced=True)
    p1 = LMDataPipeline(cfg, batch=8, seq=16, seed=3)
    p2 = LMDataPipeline(cfg, batch=8, seq=16, seed=3)
    b1 = p1.make_batch(5)
    b2 = p2.make_batch(5)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    # host sharding: 2 hosts slice the global batch disjointly... each host
    # draws its own rows (4 each)
    h0 = LMDataPipeline(cfg, batch=8, seq=16, seed=3, host_id=0, num_hosts=2)
    h1 = LMDataPipeline(cfg, batch=8, seq=16, seed=3, host_id=1, num_hosts=2)
    assert h0.make_batch(0)["tokens"].shape[0] == 4
    assert h1.make_batch(0)["tokens"].shape[0] == 4


def test_pipeline_has_learnable_structure():
    cfg = get_arch("qwen2-0.5b", reduced=True)
    p = LMDataPipeline(cfg, batch=4, seq=64, seed=0)
    toks = p.make_batch(0)["tokens"]
    # the order-2 relation holds for ~half the positions
    f = (toks[:, 1:-1] * 31 + toks[:, :-2] * 17 + 7) % cfg.vocab
    frac = (toks[:, 2:] == f).mean()
    assert frac > 0.3
