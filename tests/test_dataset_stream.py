"""Streaming training pipeline tests: chunked window digests vs the
per-row reference, streaming dedup vs the materialized keep-set, seeded
shuffle determinism, bit-for-bit loss trajectories, the one-compile-per-
geometry guarantee, and the 1M-instruction memory cap (slow)."""
import hashlib
import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.api import Session
from repro.core import FeatureConfig, TaoConfig
from repro.core.dataset import (
    StreamingWindowDataset,
    WindowDataset,
    build_windows,
    concat_datasets,
    iter_window_digests,
    num_windows,
    window_view,
)
from repro.core.features import NUM_OPCODES, FeatureSet
from repro.core.transfer import train_tao_impl
from repro.train.trainer import train_step_compiles
from repro.uarch import UARCH_A
from repro.uarch.isa import NUM_REGS

FCFG = FeatureConfig(n_buckets=32, n_queue=4, n_mem=6)
CFG = TaoConfig(
    window=17, d_model=32, n_heads=2, n_layers=1, d_ff=64, d_cat=16, features=FCFG
)
ROOT = os.path.join(os.path.dirname(__file__), "..")


def make_fs(n, seed=0, with_labels=True, dup_block=None):
    """Random FeatureSet; ``dup_block=(window, every)`` copies window-aligned
    block 0 over every ``every``-th block so windows collide byte-for-byte."""
    rng = np.random.default_rng(seed)
    labels = None
    if with_labels:
        labels = {
            "fetch_lat": rng.integers(0, 8, n).astype(np.float32),
            "exec_lat": rng.integers(1, 12, n).astype(np.float32),
            "mispred": (rng.random(n) < 0.1).astype(np.float32),
            "dlevel": rng.integers(0, 4, n).astype(np.int32),
            "icache_miss": (rng.random(n) < 0.05).astype(np.float32),
            "tlb_miss": (rng.random(n) < 0.02).astype(np.float32),
            "is_branch": (rng.random(n) < 0.2).astype(np.float32),
            "is_mem": (rng.random(n) < 0.3).astype(np.float32),
        }
    fs = FeatureSet(
        opcode=rng.integers(0, NUM_OPCODES, n).astype(np.int32),
        regbits=(rng.random((n, NUM_REGS)) < 0.1).astype(np.float32),
        flags=(rng.random((n, 5)) < 0.3).astype(np.float32),
        brhist=rng.integers(-1, 2, (n, FCFG.n_queue)).astype(np.float32),
        memdist=rng.standard_normal((n, FCFG.n_mem)).astype(np.float32),
        labels=labels,
    )
    if dup_block:
        w, every = dup_block
        for k in range(every, n // w, every):
            lo = k * w
            arrs = [fs.opcode, fs.regbits, fs.flags, fs.brhist, fs.memdist]
            if labels:
                arrs += list(labels.values())
            for arr in arrs:
                arr[lo : lo + w] = arr[:w]
    return fs


def assert_datasets_equal(a: WindowDataset, b: WindowDataset):
    assert len(a) == len(b)
    for k in a.inputs:
        np.testing.assert_array_equal(a.inputs[k], b.inputs[k], err_msg=k)
    assert (a.labels is None) == (b.labels is None)
    if a.labels is not None:
        for k in a.labels:
            np.testing.assert_array_equal(a.labels[k], b.labels[k], err_msg=k)


# ---------------------------------------------------------------------------
# Digests
# ---------------------------------------------------------------------------


def per_row_digests(inputs, labels):
    """The pre-vectorization per-row hashing loop, verbatim."""
    out = []
    lat = labels["fetch_lat"] if labels is not None else None
    for i in range(len(inputs["opcode"])):
        h = hashlib.blake2b(digest_size=16)
        h.update(inputs["opcode"][i].tobytes())
        h.update(inputs["memdist"][i].tobytes())
        h.update(inputs["brhist"][i].tobytes())
        if lat is not None:
            h.update(lat[i].tobytes())
            h.update(labels["exec_lat"][i].tobytes())
        out.append(h.digest())
    return out


@pytest.mark.parametrize("with_labels", [True, False])
@pytest.mark.parametrize("chunk", [1, 3, 64, 2048])
def test_chunked_digests_match_per_row_reference(with_labels, chunk):
    fs = make_fs(700, seed=3, with_labels=with_labels, dup_block=(17, 4))
    views = {
        k: window_view(getattr(fs, k), 17, 17)
        for k in ("opcode", "memdist", "brhist")
    }
    labs = None
    if with_labels:
        labs = {
            k: window_view(fs.labels[k], 17, 17)
            for k in ("fetch_lat", "exec_lat")
        }
    got = list(iter_window_digests(views, labs, chunk=chunk))
    assert got == per_row_digests(views, labs)


# ---------------------------------------------------------------------------
# Streaming dedup vs the materialized keep-set
# ---------------------------------------------------------------------------


def test_streaming_dedup_matches_materialized_collision_heavy():
    fs = make_fs(3000, seed=1, dup_block=(17, 3))  # every 3rd window collides
    ds_m = build_windows(fs, 17)
    ds_s = StreamingWindowDataset(fs, 17)
    assert ds_s.num_dropped > 0  # the collisions are real
    assert len(ds_s) < num_windows(3000, 17, 17)
    assert_datasets_equal(ds_s.materialize(), ds_m)


def test_streaming_multi_trace_matches_concat():
    parts = [
        make_fs(2000, seed=1, dup_block=(17, 4)),
        make_fs(1500, seed=2),
        make_fs(2000, seed=1, dup_block=(17, 4)),  # identical to part 0
    ]
    ds_m = concat_datasets([build_windows(p, 17) for p in parts])
    ds_s = StreamingWindowDataset(parts, 17)
    assert_datasets_equal(ds_s.materialize(), ds_m)
    # "trace" scope keeps cross-trace duplicates (like the materialized
    # pipeline); "global" shares the digest reservoir and drops them
    ds_g = StreamingWindowDataset(parts, 17, dedup_scope="global")
    assert len(ds_g) == len(StreamingWindowDataset(parts[:2], 17))


def test_streaming_dedup_disabled_and_no_labels():
    fs = make_fs(1200, seed=4, with_labels=False, dup_block=(17, 2))
    ds = StreamingWindowDataset(fs, 17, dedup=False)
    assert len(ds) == num_windows(1200, 17, 17)
    batch = next(ds.batches(8))
    assert "labels" not in batch
    assert batch["opcode"].shape == (8, 17)


def test_streaming_rejects_mixed_geometry_and_bad_scope():
    long, short = make_fs(400, seed=0), make_fs(9, seed=1)  # 9 < window
    with pytest.raises(ValueError, match="mixed effective windows"):
        StreamingWindowDataset([long, short], 17)
    with pytest.raises(ValueError, match="dedup_scope"):
        StreamingWindowDataset(long, 17, dedup_scope="session")
    with pytest.raises(ValueError, match=">= 1 FeatureSet"):
        StreamingWindowDataset([], 17)


def test_streaming_subsample_matches_materialized():
    """subsample() draws the same windows as WindowDataset.subsample (same
    rng over the same length) but only shrinks the index lookup."""
    fs = make_fs(2500, seed=7, dup_block=(17, 4))
    ds_m = build_windows(fs, 17)
    ds_s = StreamingWindowDataset(fs, 17)
    sub_m = ds_m.subsample(24, seed=9)
    sub_s = ds_s.subsample(24, seed=9)
    assert isinstance(sub_s, StreamingWindowDataset)
    assert_datasets_equal(sub_s.materialize(), sub_m)
    assert ds_s.subsample(10**9) is ds_s  # n >= len: same object


# ---------------------------------------------------------------------------
# Seeded shuffle
# ---------------------------------------------------------------------------


def test_seeded_shuffle_bitwise_matches_materialized():
    fs = make_fs(2500, seed=5, dup_block=(17, 5))
    ds_m = build_windows(fs, 17)
    ds_s = StreamingWindowDataset(fs, 17)
    r_m, r_s = np.random.default_rng(11), np.random.default_rng(11)
    n_batches = 0
    for bm, bs in zip(ds_m.batches(16, rng=r_m), ds_s.batches(16, rng=r_s)):
        for k in ("opcode", "regbits", "flags", "brhist", "memdist"):
            np.testing.assert_array_equal(bm[k], bs[k], err_msg=k)
        for k in bm["labels"]:
            np.testing.assert_array_equal(bm["labels"][k], bs["labels"][k])
        n_batches += 1
    assert n_batches == len(ds_m) // 16


def test_seeded_shuffle_deterministic_and_seed_sensitive():
    fs = make_fs(2000, seed=6)
    ds = StreamingWindowDataset(fs, 17)
    first = [b["opcode"] for b in ds.batches(8, rng=np.random.default_rng(3))]
    again = [b["opcode"] for b in ds.batches(8, rng=np.random.default_rng(3))]
    other = [b["opcode"] for b in ds.batches(8, rng=np.random.default_rng(4))]
    for a, b in zip(first, again):
        np.testing.assert_array_equal(a, b)
    assert any(not np.array_equal(a, o) for a, o in zip(first, other))


# ---------------------------------------------------------------------------
# Training: bit-for-bit trajectory + one compile per geometry
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def session_and_trace():
    s = Session(CFG, streaming_threshold=1000)
    return s, s.capture("lee", 3500)


def test_session_streaming_threshold_and_types(session_and_trace):
    s, tr = session_and_trace
    auto = s.dataset(UARCH_A, [tr])  # 3500 >= threshold -> streaming
    assert isinstance(auto, StreamingWindowDataset)
    assert auto is s.dataset(UARCH_A, [tr])  # cache hit
    mat = s.dataset(UARCH_A, [tr], streaming=False)
    assert isinstance(mat, WindowDataset)
    big = Session(CFG)  # default threshold: 1M instructions
    tr2 = big.capture("lee", 3500)
    assert isinstance(big.dataset(UARCH_A, [tr2]), WindowDataset)
    # cross-trace dedup reaches the facade (streaming pipeline only)
    dup = s.dataset(UARCH_A, [tr, tr], streaming=True, dedup_scope="global")
    assert len(dup) == len(auto)
    with pytest.raises(ValueError, match="streaming-pipeline option"):
        s.dataset(UARCH_A, [tr], streaming=False, dedup_scope="global")


def test_streaming_train_bitwise_matches_materialized(session_and_trace):
    s, tr = session_and_trace
    ds_s = s.dataset(UARCH_A, [tr])
    ds_m = s.dataset(UARCH_A, [tr], streaming=False)
    assert len(ds_s) == len(ds_m)  # same dedup keep-set
    res_s = train_tao_impl(CFG, ds_s, epochs=2, batch_size=16, seed=0)
    res_m = train_tao_impl(CFG, ds_m, epochs=2, batch_size=16, seed=0)
    assert res_s.losses == res_m.losses  # bit-for-bit, not approx
    assert res_s.steps == res_m.steps
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        res_s.params,
        res_m.params,
    )


def test_one_compile_per_geometry_across_streaming_epochs(session_and_trace):
    s, tr = session_and_trace
    ds = s.dataset(UARCH_A, [tr])
    # a distinctive lr keys a fresh cached step regardless of test order
    before = train_step_compiles()
    train_tao_impl(CFG, ds, epochs=3, batch_size=8, seed=1, lr=2.625e-4)
    assert train_step_compiles() - before == 1
    # same geometry + config again: zero new compiles
    before = train_step_compiles()
    train_tao_impl(CFG, ds, epochs=1, batch_size=8, seed=2, lr=2.625e-4)
    assert train_step_compiles() - before == 0


def test_streaming_train_via_session_facade(session_and_trace):
    s, tr = session_and_trace
    model = s.train(UARCH_A, [tr], epochs=1, batch_size=16, streaming=True)
    assert len(model.losses) == 1 and np.isfinite(model.losses[0])
    res = model.simulate(tr)
    assert np.isfinite(res.cpi)


def test_streaming_flag_rejected_with_explicit_dataset(session_and_trace):
    """streaming= cannot silently apply to a prebuilt dataset= — it only
    controls how the session builds one from traces."""
    s, tr = session_and_trace
    ds = s.dataset(UARCH_A, [tr], streaming=False)
    with pytest.raises(ValueError, match="explicit dataset"):
        s.train(dataset=ds, streaming=True, epochs=1)
    from repro.uarch import UARCH_B

    with pytest.raises(ValueError, match="explicit"):
        s.train_joint(UARCH_A, UARCH_B, datasets=(ds, ds), streaming=False)


# ---------------------------------------------------------------------------
# Memory cap (slow): 1M-instruction synthetic trace
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_streaming_memory_cap_1m_instructions():
    """Train on a ~1M-instruction synthetic trace: the streaming data path
    must stay under a constant RSS cap and beat the materialized path's
    peak by >= 5x (the acceptance target; recorded by BENCH_train.json)."""

    def measure(mode):
        env = dict(os.environ)
        env["JAX_PLATFORMS"] = "cpu"  # subprocess must never probe TPU
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(ROOT, "src")]
            + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
        )
        env["BENCH_SCALE"] = "tiny"
        p = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_train",
             "--measure", mode, "--n", "1000000"],
            capture_output=True, text=True, timeout=2400, env=env, cwd=ROOT,
        )
        assert p.returncode == 0, p.stderr[-3000:]
        return json.loads(p.stdout.strip().splitlines()[-1])

    stream = measure("stream")
    mat = measure("materialized")
    assert stream["loss0"] == mat["loss0"]  # same keep-set, same batches
    assert stream["train_compiles_total"] == 1  # one compile per geometry
    ratio = mat["peak_rss_delta_mb"] / max(stream["peak_rss_delta_mb"], 1e-9)
    assert ratio >= 5.0, (stream, mat)
    assert stream["peak_rss_delta_mb"] < 128.0, stream
