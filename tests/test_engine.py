"""Streaming engine tests: vectorized features vs the reference loops,
zero-copy windowing vs the copying grid, legacy-vs-engine metric
equivalence, and the one-compile guarantee."""
import jax
import numpy as np
import pytest

from repro.core import (
    FeatureConfig,
    TaoConfig,
    extract_features,
    extract_features_reference,
    init_tao,
    num_windows,
    stream_batches,
    window_view,
)
from repro.core.simulate import simulate_trace, simulate_trace_legacy
from repro.engine import EngineConfig, MetricNotCollectedError, StreamingEngine
from repro.uarch import get_benchmark, run_functional

FCFG = FeatureConfig(n_buckets=32, n_queue=4, n_mem=8)
CFG = TaoConfig(
    window=17, d_model=32, n_heads=2, n_layers=1, d_ff=64, d_cat=16, features=FCFG
)


@pytest.fixture(scope="module")
def trace():
    return run_functional(get_benchmark("mcf"), 3000)


@pytest.fixture(scope="module")
def params():
    return init_tao(jax.random.PRNGKey(0), CFG)


# ---------------------------------------------------------------------------
# Layer 1: feature extraction
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bench", ["mcf", "dee", "lee"])
def test_vectorized_features_match_reference(bench):
    ft = run_functional(get_benchmark(bench), 2500)
    for cfg in (FCFG, FeatureConfig(n_buckets=2, n_queue=3, n_mem=2)):
        vec = extract_features(ft, cfg, with_labels=False)
        ref = extract_features_reference(ft, cfg, with_labels=False)
        for f in ("opcode", "regbits", "flags", "brhist", "memdist"):
            np.testing.assert_array_equal(
                getattr(vec, f), getattr(ref, f), err_msg=f"{bench}/{f}"
            )


def test_vectorized_features_degenerate_traces():
    from repro.uarch.isa import empty_func_trace

    for n in (0, 1, 2):
        t = empty_func_trace(n)  # no branches, no memory ops
        vec = extract_features(t, FCFG, with_labels=False)
        ref = extract_features_reference(t, FCFG, with_labels=False)
        np.testing.assert_array_equal(vec.brhist, ref.brhist)
        np.testing.assert_array_equal(vec.memdist, ref.memdist)


# ---------------------------------------------------------------------------
# Layer 2: windowing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "n,window,stride",
    [(100, 16, 16), (100, 16, 4), (100, 16, 1), (15, 16, 16), (16, 16, 16), (17, 16, 16)],
)
def test_window_view_matches_copying_grid(n, window, stride):
    arr = np.arange(n * 3, dtype=np.float32).reshape(n, 3)
    starts = list(range(0, max(1, n - window + 1), stride))
    expect = np.stack([arr[s : s + window] for s in starts])
    got = window_view(arr, window, stride)
    np.testing.assert_array_equal(got, expect)
    assert len(got) == num_windows(n, window, stride)
    # zero-copy: the view shares memory with the source (n >= window case)
    if n >= window:
        assert np.shares_memory(got, arr)


def test_stream_batches_padding_and_masks(trace):
    fs = extract_features(trace, FCFG, with_labels=False)
    W, B = CFG.window, 7
    nw = num_windows(len(trace), W, W)
    assert nw % B != 0  # exercises the ragged final batch
    seen = 0
    for batch in stream_batches(
        fs, W, B, extra={"is_branch": trace["is_branch"]}
    ):
        assert batch["opcode"].shape == (B, W)
        assert batch["is_branch"].shape == (B, W)
        rows = int(batch["valid"][:, 0].sum())
        # valid rows are a prefix; padded rows are fully zero
        assert (batch["valid"][:rows] == 1.0).all()
        assert (batch["valid"][rows:] == 0.0).all()
        assert (batch["opcode"][rows:] == 0).all()
        seen += rows
    assert seen == nw


# ---------------------------------------------------------------------------
# Layer 3: engine vs legacy
# ---------------------------------------------------------------------------


def test_engine_matches_legacy_metrics(params, trace):
    legacy = simulate_trace_legacy(params, trace, CFG, batch_size=64)
    eng = simulate_trace(params, trace, CFG, batch_size=64, collect=True)
    assert eng.num_instructions == legacy.num_instructions
    assert np.isclose(eng.cpi, legacy.cpi, rtol=1e-5)
    assert np.isclose(eng.total_cycles, legacy.total_cycles, rtol=1e-5)
    # counts are integers: padding must not perturb them at all
    assert eng.branch_mpki == legacy.branch_mpki
    assert eng.l1d_mpki == legacy.l1d_mpki
    np.testing.assert_allclose(eng.fetch_lat, legacy.fetch_lat, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(eng.exec_lat, legacy.exec_lat, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        eng.mispred_prob, legacy.mispred_prob, rtol=1e-5, atol=1e-6
    )
    np.testing.assert_array_equal(eng.dlevel, legacy.dlevel)


@pytest.mark.sanitize
def test_engine_single_compile_across_uneven_batches(params, trace):
    engine = StreamingEngine(params, CFG, EngineConfig(batch_size=13))
    r1 = engine.simulate(trace)                                   # ragged tail
    r2 = engine.simulate(run_functional(get_benchmark("dee"), 1000))
    r3 = engine.simulate(run_functional(get_benchmark("lee"), 13 * 17))
    assert engine.num_compiles == 1, engine.num_compiles
    for r in (r1, r2, r3):
        assert np.isfinite(r.cpi) and r.cpi > 0
        # metrics stayed on device: per-instruction arrays not collected
        assert "fetch_lat" not in r.available_metrics
        with pytest.raises(MetricNotCollectedError):
            r.fetch_lat


@pytest.mark.sanitize
def test_engine_collect_off_keeps_metrics_on_device(params, trace):
    eng = simulate_trace(params, trace, CFG, collect=False)
    with pytest.raises(MetricNotCollectedError):
        eng.fetch_lat
    with pytest.raises(MetricNotCollectedError):
        eng.dlevel
    full = simulate_trace(params, trace, CFG, collect=True)
    assert np.isclose(eng.cpi, full.cpi, rtol=1e-6)
    assert eng.branch_mpki == full.branch_mpki


def test_engine_short_trace_matches_legacy(params):
    ft = run_functional(get_benchmark("dee"), 9)  # n < window
    legacy = simulate_trace_legacy(params, ft, CFG)
    eng = simulate_trace(params, ft, CFG)
    assert eng.num_instructions == legacy.num_instructions == 9
    assert np.isclose(eng.cpi, legacy.cpi, rtol=1e-5)


def test_engine_sharded_path_matches(params, trace):
    mesh = jax.make_mesh((1,), ("data",))
    plain = StreamingEngine(params, CFG, EngineConfig(batch_size=16))
    sharded = StreamingEngine(
        params, CFG, EngineConfig(batch_size=16, mesh=mesh, collect=True)
    )
    a = plain.simulate(trace)
    b = sharded.simulate(trace)
    assert np.isclose(a.cpi, b.cpi, rtol=1e-5)
    assert a.branch_mpki == b.branch_mpki
    assert a.l1d_mpki == b.l1d_mpki
    legacy = simulate_trace_legacy(params, trace, CFG)
    np.testing.assert_allclose(b.fetch_lat, legacy.fetch_lat, rtol=1e-5, atol=1e-5)


@pytest.mark.sanitize
def test_engine_feature_backends_bitwise_identical(params, trace):
    """The "pallas" backend must reproduce the "numpy" backend exactly:
    same FeatureSet bits in, same jitted step, same metrics out."""
    from repro.kernels.features.ops import device_feature_arrays, trace_columns

    cols = trace_columns(trace, FCFG)
    assert cols is not None
    dev = device_feature_arrays(cols, FCFG, chunk=256)
    host = extract_features(trace, FCFG, with_labels=False)
    for k in ("opcode", "regbits", "flags", "brhist", "memdist"):
        np.testing.assert_array_equal(np.asarray(dev[k]), getattr(host, k), err_msg=k)

    e_np = StreamingEngine(params, CFG, EngineConfig(batch_size=13, collect=True))
    e_pl = StreamingEngine(
        params,
        CFG,
        EngineConfig(
            batch_size=13, collect=True, feature_backend="pallas", feature_chunk=256
        ),
    )
    a = e_np.simulate(trace)
    b = e_pl.simulate(trace)
    assert a.num_instructions == b.num_instructions
    assert a.cpi == b.cpi
    assert a.total_cycles == b.total_cycles
    assert a.branch_mpki == b.branch_mpki
    assert a.l1d_mpki == b.l1d_mpki
    np.testing.assert_array_equal(a.fetch_lat, b.fetch_lat)
    np.testing.assert_array_equal(a.exec_lat, b.exec_lat)
    np.testing.assert_array_equal(a.mispred_prob, b.mispred_prob)
    np.testing.assert_array_equal(a.dlevel, b.dlevel)


def test_engine_backends_share_compiled_step(params, trace):
    """feature_backend is not part of the step-cache key: a pallas engine
    created after a numpy one reuses the same executable (and vice versa)."""
    e_np = StreamingEngine(params, CFG, EngineConfig(batch_size=11))
    e_pl = StreamingEngine(
        params, CFG, EngineConfig(batch_size=11, feature_backend="pallas")
    )
    e_np.simulate(trace)
    e_pl.simulate(trace)
    assert e_np.num_compiles == 1
    assert e_pl.num_compiles == 1  # same shared _CachedStep entry


def test_engine_pallas_short_and_ragged_traces(params):
    for n in (9, 17, 18, 13 * 17 + 5):
        ft = run_functional(get_benchmark("dee"), n)
        a = simulate_trace(params, ft, CFG, batch_size=13)
        b = simulate_trace(params, ft, CFG, batch_size=13, feature_backend="pallas")
        assert a.num_instructions == b.num_instructions
        assert a.cpi == b.cpi, n
        assert a.branch_mpki == b.branch_mpki


def test_engine_pallas_wide_address_fallback(params, trace):
    """Addresses outside the int32-exact window fall back to the NumPy
    extractor — metrics must still match the numpy backend exactly."""
    t = trace.copy()
    t["addr"][::7] = 2**40
    a = simulate_trace(params, t, CFG, batch_size=16)
    b = simulate_trace(params, t, CFG, batch_size=16, feature_backend="pallas")
    assert a.cpi == b.cpi
    assert a.l1d_mpki == b.l1d_mpki


def test_engine_pallas_sharded_matches(params, trace):
    mesh = jax.make_mesh((1,), ("data",))
    plain = StreamingEngine(params, CFG, EngineConfig(batch_size=16))
    sharded = StreamingEngine(
        params,
        CFG,
        EngineConfig(batch_size=16, mesh=mesh, feature_backend="pallas"),
    )
    a = plain.simulate(trace)
    b = sharded.simulate(trace)
    assert np.isclose(a.cpi, b.cpi, rtol=1e-6)
    assert a.branch_mpki == b.branch_mpki
    assert a.l1d_mpki == b.l1d_mpki


def test_engine_rejects_unknown_feature_backend(params):
    with pytest.raises(ValueError):
        StreamingEngine(params, CFG, EngineConfig(feature_backend="cuda"))
    with pytest.raises(ValueError):
        StreamingEngine(
            params, CFG, EngineConfig(feature_backend="pallas", feature_chunk=0)
        )


def test_feature_ops_importable_first():
    """repro.kernels.features.ops must be importable as the FIRST repro
    import (regression: a module-level ops import in engine.runner closed
    an import cycle through the repro.core package init)."""
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"
    p = subprocess.run(
        [sys.executable, "-c",
         "import repro.kernels.features.ops as o; print(o.ADDR_EXACT_LIMIT)"],
        capture_output=True, text=True, timeout=120, env=env,
    )
    assert p.returncode == 0, p.stderr[-2000:]


def test_prefetch_helper_inline_and_threaded():
    """The shared prefetch helper must preserve order in both modes,
    propagate producer errors, and survive an abandoned consumer."""
    from repro.engine.runner import prefetch_to_device

    items = [{"i": np.full((3,), i)} for i in range(25)]
    for threaded in (False, True):
        out = list(
            prefetch_to_device(iter(items), device_put=lambda b: b,
                               threaded=threaded)
        )
        assert [int(o["i"][0]) for o in out] == list(range(25)), threaded
    assert list(prefetch_to_device(iter(()), threaded=True)) == []

    def bad():
        yield {"i": np.zeros(1)}
        raise RuntimeError("producer boom")

    with pytest.raises(RuntimeError, match="producer boom"):
        list(prefetch_to_device(bad(), device_put=lambda b: b, threaded=True))

    gen = prefetch_to_device(iter(items), device_put=lambda b: b, threaded=True)
    assert int(next(gen)["i"][0]) == 0
    gen.close()  # abandoning the consumer must stop the producer thread

    with pytest.raises(ValueError):
        next(prefetch_to_device(iter(items), depth=0, threaded=True))


def test_engine_rejects_mesh_without_data_axis(params):
    mesh = jax.make_mesh((1,), ("model",))
    with pytest.raises(ValueError):
        StreamingEngine(params, CFG, EngineConfig(batch_size=16, mesh=mesh))


def test_engine_multidevice_shard_map():
    """8 placeholder devices: data and pod+data meshes must reproduce the
    legacy metrics exactly (subprocess so XLA device flags apply)."""
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from repro.core import TaoConfig, FeatureConfig, init_tao
    from repro.core.simulate import simulate_trace_legacy
    from repro.engine import StreamingEngine, EngineConfig
    from repro.uarch import get_benchmark, run_functional

    fcfg = FeatureConfig(n_buckets=64, n_queue=4, n_mem=8)
    cfg = TaoConfig(window=17, d_model=32, n_heads=2, n_layers=1, d_ff=64,
                    d_cat=16, features=fcfg)
    params = init_tao(jax.random.PRNGKey(0), cfg)
    ft = run_functional(get_benchmark("mcf"), 3000)
    leg = simulate_trace_legacy(params, ft, cfg)
    for shape, names in [((8,), ("data",)), ((2, 4), ("pod", "data"))]:
        mesh = jax.make_mesh(shape, names)
        e = StreamingEngine(params, cfg,
                            EngineConfig(batch_size=32, mesh=mesh))
        r = e.simulate(ft)
        assert abs(r.cpi - leg.cpi) / leg.cpi < 1e-5, (names, r.cpi, leg.cpi)
        assert r.branch_mpki == leg.branch_mpki
        assert r.l1d_mpki == leg.l1d_mpki
        assert e.num_compiles == 1
    print("SHARD_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env["JAX_PLATFORMS"] = "cpu"  # placeholder devices; avoid TPU probing
    p = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, timeout=560, env=env)
    assert p.returncode == 0, p.stderr[-3000:]
    assert "SHARD_OK" in p.stdout
