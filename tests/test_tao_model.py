"""Tao DL model: shapes, masked losses, overfit sanity, simulation driver."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    init_tao,
    multi_metric_loss,
    simulate_trace,
    tao_forward,
    train_tao,
)


def _batch_from(ds, n=4):
    b = {k: jnp.asarray(v[:n]) for k, v in ds.inputs.items()}
    b["labels"] = {k: jnp.asarray(v[:n]) for k, v in ds.labels.items()}
    return b


def test_forward_shapes(small_tao_setup):
    cfg, ds, _, _ = small_tao_setup
    params = init_tao(jax.random.PRNGKey(0), cfg)
    batch = _batch_from(ds)
    out = jax.jit(lambda p, b: tao_forward(p, b, cfg))(params, batch)
    B, W = batch["opcode"].shape
    assert out["fetch_lat"].shape == (B, W)
    assert out["dlevel_logits"].shape == (B, W, 4)
    for v in out.values():
        assert bool(jnp.all(jnp.isfinite(v)))


def test_loss_masking(small_tao_setup):
    """Branch loss only counts branch positions: zeroing non-branch targets
    must not change it."""
    cfg, ds, _, _ = small_tao_setup
    params = init_tao(jax.random.PRNGKey(0), cfg)
    batch = _batch_from(ds)
    preds = tao_forward(params, batch, cfg)
    _, parts = multi_metric_loss(preds, batch["labels"])

    labels2 = dict(batch["labels"])
    labels2["mispred"] = labels2["mispred"] * labels2["is_branch"]
    _, parts2 = multi_metric_loss(preds, labels2)
    assert float(parts["mispred"]) == pytest.approx(float(parts2["mispred"]))


def test_overfit_small_dataset(small_tao_setup):
    cfg, ds, _, _ = small_tao_setup
    small = ds.subsample(16)
    res = train_tao(cfg, small, epochs=12, batch_size=8, lr=2e-3)
    # MSE latency loss starts large (squared cycles); require steady descent
    assert res.losses[-1] < res.losses[0] * 0.8, res.losses
    assert res.losses[-1] < res.losses[len(res.losses) // 2], res.losses


def test_simulation_driver(small_tao_setup):
    cfg, ds, al, ft = small_tao_setup
    res = train_tao(cfg, ds, epochs=2, batch_size=8)
    sim = simulate_trace(res.params, ft, cfg)
    assert sim.num_instructions > 0
    assert sim.cpi > 0
    assert np.isfinite(sim.total_cycles)
    assert sim.fetch_lat.shape[0] == sim.num_instructions


def test_deterministic_init(small_tao_setup):
    cfg, _, _, _ = small_tao_setup
    a = init_tao(jax.random.PRNGKey(7), cfg)
    b = init_tao(jax.random.PRNGKey(7), cfg)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert jnp.array_equal(la, lb)
