"""§4.2 feature-engineering tests."""
import numpy as np
import pytest

from repro.core.features import FeatureConfig, extract_features
from repro.uarch.isa import FUNC_TRACE_DTYPE, NUM_REGS, Op


def _mk_trace(rows):
    t = np.zeros(len(rows), dtype=FUNC_TRACE_DTYPE)
    for i, r in enumerate(rows):
        for k, v in r.items():
            t[i][k] = v
    return t


def test_regbits_contains_sources_and_dest():
    t = _mk_trace([{"opcode": int(Op.IALU), "dst": 3, "src1": 5, "src2": 7}])
    fs = extract_features(t, FeatureConfig(), with_labels=False)
    bits = np.nonzero(fs.regbits[0])[0].tolist()
    assert set(bits) == {3, 5, 7}
    assert fs.regbits.shape == (1, NUM_REGS)


def test_opcode_passthrough_and_flags():
    t = _mk_trace(
        [
            {"opcode": int(Op.FMUL)},
            {"opcode": int(Op.LOAD), "is_mem": True, "addr": 64},
            {"opcode": int(Op.STORE), "is_mem": True, "is_store": True, "addr": 8},
            {"opcode": int(Op.BEQ), "is_branch": True, "taken": True},
        ]
    )
    fs = extract_features(t, FeatureConfig(), with_labels=False)
    assert fs.opcode.tolist() == [int(Op.FMUL), int(Op.LOAD), int(Op.STORE), int(Op.BEQ)]
    assert fs.flags[0, 4] == 1.0            # is_fp
    assert fs.flags[1, 2] == 1.0            # is_mem
    assert fs.flags[2, 3] == 1.0            # is_store
    assert fs.flags[3, 0] == 1.0 and fs.flags[3, 1] == 1.0  # branch, taken


def test_branch_history_hash_table():
    cfg = FeatureConfig(n_buckets=4, n_queue=3)
    pc = 16  # bucket (16>>2) % 4 == 0
    rows = [
        {"opcode": int(Op.BEQ), "pc": pc, "is_branch": True, "taken": True},
        {"opcode": int(Op.BEQ), "pc": pc, "is_branch": True, "taken": False},
        {"opcode": int(Op.BEQ), "pc": pc, "is_branch": True, "taken": True},
    ]
    fs = extract_features(_mk_trace(rows), cfg, with_labels=False)
    # first branch: empty history
    assert fs.brhist[0].tolist() == [0.0, 0.0, 0.0]
    # second: sees [taken] = [+1]
    assert fs.brhist[1].tolist() == [1.0, 0.0, 0.0]
    # third: most-recent-first [not-taken, taken]
    assert fs.brhist[2].tolist() == [-1.0, 1.0, 0.0]


def test_branch_hash_collision_mixes_histories():
    """Two different PCs in the same bucket share a queue (paper Fig 4)."""
    cfg = FeatureConfig(n_buckets=2, n_queue=2)
    pc_a, pc_b = 0, 8  # (0>>2)%2 == (8>>2)%2 == 0
    rows = [
        {"opcode": int(Op.BEQ), "pc": pc_a, "is_branch": True, "taken": True},
        {"opcode": int(Op.BEQ), "pc": pc_b, "is_branch": True, "taken": False},
    ]
    fs = extract_features(_mk_trace(rows), cfg, with_labels=False)
    assert fs.brhist[1].tolist() == [1.0, 0.0]  # sees pc_a's outcome


def test_memdist_signed_log_deltas():
    cfg = FeatureConfig(n_mem=2)
    rows = [
        {"opcode": int(Op.LOAD), "is_mem": True, "addr": 100},
        {"opcode": int(Op.LOAD), "is_mem": True, "addr": 108},
        {"opcode": int(Op.LOAD), "is_mem": True, "addr": 100},
    ]
    fs = extract_features(_mk_trace(rows), cfg, with_labels=False)
    assert fs.memdist[0].tolist() == [0.0, 0.0]          # first access: empty
    d1 = fs.memdist[1]
    assert d1[0] == pytest.approx(np.log2(1 + 8) / 32.0)  # +8 delta
    d2 = fs.memdist[2]
    assert d2[0] == pytest.approx(-np.log2(1 + 8) / 32.0)  # -8 (most recent)
    assert d2[1] == pytest.approx(0.0)                     # same addr as [0]


def test_nonbranch_nonmem_rows_zero():
    t = _mk_trace([{"opcode": int(Op.IALU)}])
    fs = extract_features(t, FeatureConfig(), with_labels=False)
    assert not fs.brhist[0].any()
    assert not fs.memdist[0].any()


def test_feature_backends_bitwise_identical_on_unit_traces():
    """NumPy and Pallas backends agree bit for bit on the hand-built unit
    traces above (collisions, empty queues, signed deltas included)."""
    from repro.kernels.features.ops import extract_features_device

    cfg = FeatureConfig(n_buckets=2, n_queue=3, n_mem=2)
    rows = [
        {"opcode": int(Op.BEQ), "pc": 0, "is_branch": True, "taken": True},
        {"opcode": int(Op.BEQ), "pc": 8, "is_branch": True, "taken": False},
        {"opcode": int(Op.LOAD), "is_mem": True, "addr": 100},
        {"opcode": int(Op.LOAD), "is_mem": True, "addr": 108},
        {"opcode": int(Op.STORE), "is_mem": True, "is_store": True, "addr": 100},
        {"opcode": int(Op.FMUL), "dst": 3, "src1": 5, "src2": 7},
        {"opcode": int(Op.BEQ), "pc": 16, "is_branch": True, "taken": True},
    ]
    t = _mk_trace(rows)
    host = extract_features(t, cfg, with_labels=False)
    dev = extract_features_device(t, cfg, with_labels=False, chunk=4)
    for f in ("opcode", "regbits", "flags", "brhist", "memdist"):
        np.testing.assert_array_equal(getattr(host, f), getattr(dev, f), err_msg=f)


def test_labels_from_adjusted_trace(small_tao_setup):
    _, ds, al, _ = small_tao_setup
    assert ds.labels is not None
    assert set(ds.labels) >= {"fetch_lat", "exec_lat", "mispred", "dlevel"}
    assert (ds.labels["fetch_lat"] >= 0).all()
    assert (ds.labels["dlevel"] <= 3).all()
